//! Streamed-vs-materialized differential test: every paper scenario,
//! simulated from its lazy stream, must produce a **byte-identical**
//! `SimReport` to the materialized path across all five policies —
//! same jobs, same schedule (ties included), same makespan/utilization
//! bits. Extends the `sweep_differential` discipline (parallel == and
//! sequential grids) to the workload axis: lazy == materialized.

use uwfq::config::Config;
use uwfq::sched::PolicyKind;
use uwfq::sim::{self, SimReport};
use uwfq::workload::gtrace::{gtrace, gtrace_stream, GtraceParams};
use uwfq::workload::stream::{materialize, scale_stream, JobStream, ScaleParams, VecStream};
use uwfq::workload::{scenarios, tracefile};

fn cfg(policy: PolicyKind) -> Config {
    Config::default().with_cores(8).with_policy(policy)
}

/// Full byte-level fingerprint of a report: every completed-job field
/// (floats by bit pattern) plus the aggregate columns.
fn fingerprint(rep: &SimReport) -> (Vec<(u64, u32, String, u64, u64, u64)>, u64, u64) {
    (
        rep.completed
            .iter()
            .map(|c| {
                (
                    c.job,
                    c.user,
                    c.name.to_string(),
                    c.submit,
                    c.finish,
                    c.slot_time.to_bits(),
                )
            })
            .collect(),
        rep.makespan_s.to_bits(),
        rep.utilization.to_bits(),
    )
}

/// Assert stream == materialized for one workload across all policies.
fn assert_differential<S, F>(tag: &str, jobs: Vec<uwfq::core::job::JobSpec>, mut mk_stream: F)
where
    S: JobStream,
    F: FnMut() -> S,
{
    for policy in PolicyKind::ALL {
        let mat = sim::simulate(cfg(policy), jobs.clone());
        let streamed = sim::simulate_stream(cfg(policy), mk_stream());
        assert_eq!(
            fingerprint(&mat),
            fingerprint(&streamed),
            "{tag}: streamed run diverged from materialized under {}",
            policy.name()
        );
        assert_eq!(mat.completed.len(), jobs.len(), "{tag}: lost jobs");
    }
}

#[test]
fn scenario1_streamed_matches_materialized() {
    // Scaled-down scenario 1 (Poisson infrequent users + frequent
    // bursts) so the 5-policy matrix stays debug-test fast.
    let w = scenarios::scenario1(7, 90.0, 3, 25.0);
    assert_differential("scenario1", w.jobs, || {
        scenarios::scenario1_stream(7, 90.0, 3, 25.0)
    });
}

#[test]
fn scenario2_streamed_matches_materialized() {
    let w = scenarios::scenario2(1, 6, 0.5);
    assert_differential("scenario2", w.jobs, || scenarios::scenario2_stream(1, 6, 0.5));
}

#[test]
fn gtrace_streamed_matches_materialized() {
    let mut p = GtraceParams::default();
    p.window_s = 90.0;
    p.users = 8;
    p.heavy_users = 2;
    p.cores = 8;
    let w = gtrace(11, &p);
    assert_differential("gtrace", w.jobs, || gtrace_stream(11, &p));
}

#[test]
fn tracefile_streamed_matches_materialized() {
    const SAMPLE: &str = "\
job,user,arrival_s,slot_s,stages,heavy
t0,1,0.0,40.0,2,1
t1,2,1.5,6.0,1,0
t2,1,2.0,25.0,3,1
t3,3,2.0,4.0,1,0
t4,2,8.0,10.0,2,0
";
    let w = tracefile::load_csv(SAMPLE).unwrap();
    assert_differential("tracefile", w.jobs, || tracefile::stream_csv(SAMPLE).unwrap());
}

#[test]
fn scale_workload_streamed_matches_materialized() {
    // The scale generator itself: materializing the stream and replaying
    // it through the exact path must match streaming it directly.
    let params = ScaleParams {
        users: 20,
        jobs: 300,
        cores: 8,
        target_utilization: 0.8,
        seed: 5,
    };
    let jobs = materialize(scale_stream(&params));
    assert_eq!(jobs.len(), 300);
    assert_differential("scale", jobs, || scale_stream(&params));
}

#[test]
fn workload_adapter_roundtrip() {
    // Workload::into_stream is the thin materialized adapter: streaming
    // it is identical to handing the vector to `simulate`.
    let w = scenarios::scenario2(1, 5, 0.5);
    let mat = sim::simulate(cfg(PolicyKind::Uwfq), w.jobs.clone());
    let streamed = sim::simulate_stream(cfg(PolicyKind::Uwfq), VecStream::new(w.jobs));
    assert_eq!(fingerprint(&mat), fingerprint(&streamed));
}
