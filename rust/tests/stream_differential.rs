//! Registry-wide differential property test: for **every** scenario in
//! the registry (paper workloads, the scale workload, and the stress
//! scenarios), the streamed and collected forms must be the *same
//! workload* —
//!
//! 1. job-level parity: collecting the stream twice yields identical job
//!    lists (stable arrival order, float fields compared by bit pattern);
//! 2. schedule-level parity: simulating the stream produces a
//!    **byte-identical** `SimReport` to simulating the collected job
//!    vector, across **all five** policies.
//!
//! This replaces the per-scenario parity tests that existed when each
//! workload had hand-wired materialized/streamed twin functions: since
//! the registry defines each workload once (stream constructor + generic
//! `collect()` adapter), the property is enforced generically, and any
//! newly registered scenario is covered automatically.

use uwfq::config::Config;
use uwfq::sched::PolicyKind;
use uwfq::sim;
use uwfq::workload::registry::Registry;
use uwfq::workload::stream::materialize;
use uwfq::workload::ScenarioSpec;

mod common;
use common::fingerprint;

fn cfg(policy: PolicyKind) -> Config {
    Config::default().with_cores(8).with_policy(policy)
}

/// Debug-test-fast shapes per scenario: each entry's own quick overrides
/// plus extra shrinking for the ones whose quick shape is still large.
/// Every registered scenario must appear in the sweep below — the test
/// fails if a new registration is left uncovered.
fn test_spec(name: &str) -> ScenarioSpec {
    let sc = Registry::global().get(name).unwrap();
    let mut spec = ScenarioSpec::new(name);
    for &(k, v) in sc.quick_overrides() {
        spec = spec.with(k, v);
    }
    match name {
        "scenario1" => spec.with("burst", "3").with("poisson_gap_s", "25"),
        "scenario2" => spec,
        "gtrace" => spec.with("window_s", "90").with("users", "8").with("heavy_users", "2"),
        "tracefile" => spec.with("path", &trace_fixture()),
        // The checked-in golden fixture; a warmup below the row count
        // exercises the streaming freeze + post-warmup path.
        "trace" => spec
            .with("path", &format!("{}/tests/data/trace_small_a.csv", env!("CARGO_MANIFEST_DIR")))
            .with("warmup", "8")
            .with("cores", "8"),
        "scale" => spec.with("users", "20").with("jobs", "300").with("cores", "8"),
        "bursty" => spec.with("users", "3").with("rate", "1.5"),
        "heavytail" => spec.with("users", "3").with("jobs_per_user", "12"),
        "diurnal" => spec.with("users", "4").with("mean_rate", "0.1"),
        other => panic!("scenario '{other}' has no test shape — add one here"),
    }
}

/// A small CSV trace on disk for the `tracefile` entry.
fn trace_fixture() -> String {
    const SAMPLE: &str = "\
job,user,arrival_s,slot_s,stages,heavy
t0,1,0.0,40.0,2,1
t1,2,1.5,6.0,1,0
t2,1,2.0,25.0,3,1
t3,3,2.0,4.0,1,0
t4,2,8.0,10.0,2,0
";
    let dir = std::env::temp_dir().join(format!("uwfq_reg_diff_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    std::fs::write(&path, SAMPLE).unwrap();
    path.to_str().unwrap().to_string()
}

#[test]
fn every_scenario_streamed_equals_collected_across_all_policies() {
    let seed = 13;
    let names = Registry::global().names();
    assert!(names.len() >= 7, "registry shrank: {names:?}");
    for name in names {
        let spec = test_spec(name);

        // Job-level parity: two independent builds collect identically,
        // with nondecreasing arrivals (the stream contract).
        let collected = spec.workload(seed).unwrap();
        let streamed_jobs = materialize(spec.build(seed).unwrap().stream);
        assert_eq!(collected.jobs.len(), streamed_jobs.len(), "{name}: job count");
        assert!(!collected.jobs.is_empty(), "{name}: empty test workload");
        let mut last = 0;
        for (a, b) in collected.jobs.iter().zip(&streamed_jobs) {
            assert_eq!(a.user, b.user, "{name}");
            assert_eq!(a.arrival, b.arrival, "{name}");
            assert_eq!(a.name, b.name, "{name}");
            assert_eq!(a.weight.to_bits(), b.weight.to_bits(), "{name}");
            assert_eq!(a.stages.len(), b.stages.len(), "{name}");
            for (sa, sb) in a.stages.iter().zip(&b.stages) {
                assert_eq!(sa.slot_time.to_bits(), sb.slot_time.to_bits(), "{name}");
                assert_eq!(sa.input_bytes, sb.input_bytes, "{name}");
                assert_eq!(sa.opcount, sb.opcount, "{name}");
                assert_eq!(sa.cost.regions(), sb.cost.regions(), "{name}");
            }
            assert!(a.arrival >= last, "{name}: arrivals regressed");
            last = a.arrival;
            a.validate().unwrap();
        }

        // Schedule-level parity: byte-identical SimReports, all policies.
        for policy in PolicyKind::ALL {
            let mat = sim::simulate(cfg(policy), collected.jobs.clone());
            let streamed = sim::simulate_stream(cfg(policy), spec.build(seed).unwrap().stream);
            assert_eq!(
                fingerprint(&mat),
                fingerprint(&streamed),
                "{name}: streamed run diverged from collected under {}",
                policy.name()
            );
            assert_eq!(mat.completed.len(), collected.jobs.len(), "{name}: lost jobs");
        }
    }
}

#[test]
fn user_classes_stable_across_builds() {
    // The classification a scenario reports must be deterministic and
    // cover every user that actually submits jobs (scale is the
    // documented exception: no behaviour classes).
    let seed = 5;
    for name in Registry::global().names() {
        let spec = test_spec(name);
        let a = spec.build(seed).unwrap().user_class;
        let w = spec.workload(seed).unwrap();
        assert_eq!(a, w.user_class, "{name}: class map unstable");
        if name != "scale" {
            for j in &w.jobs {
                assert!(
                    w.user_class.contains_key(&j.user),
                    "{name}: user {} unclassified",
                    j.user
                );
            }
        }
    }
}

#[test]
fn workload_adapter_roundtrip() {
    // Workload::into_stream is the thin materialized adapter: streaming
    // it is identical to handing the vector to `simulate`.
    let w = test_spec("scenario2").workload(1).unwrap();
    let mat = sim::simulate(cfg(PolicyKind::Uwfq), w.jobs.clone());
    let streamed = sim::simulate_stream(
        cfg(PolicyKind::Uwfq),
        uwfq::workload::stream::VecStream::new(w.jobs),
    );
    assert_eq!(fingerprint(&mat), fingerprint(&streamed));
}
