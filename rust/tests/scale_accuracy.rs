//! Streaming-metrics accuracy on a real ~50k-job workload — the CI
//! contract behind the documented tolerances in
//! `metrics/streaming.rs` / `bench/scale.rs`.
//!
//! The heavy tests run a full simulation and are release-only
//! (`cfg_attr(debug_assertions, ignore)`): debug builds cross-check
//! every incremental selection against the O(active) reference scan,
//! which would make a 50k-job congested run take minutes. The CI
//! `scale-smoke` job runs them via `cargo test --release --test
//! scale_accuracy`; fast sample-level accuracy tests live in
//! `metrics/streaming.rs`.

use std::collections::HashMap;

use uwfq::bench::scale::{
    run_scale, ECDF_QUANTILE_RTOL, ECDF_SUP_TOL, P2_P99_RTOL, P2_QUANTILE_RTOL,
};
use uwfq::config::Config;
use uwfq::core::dag::CompletedJob;
use uwfq::core::SchedCore;
use uwfq::metrics::streaming::StreamingRunMetrics;
use uwfq::sim::{self, CompletionSink};
use uwfq::workload::gtrace::{gtrace, GtraceParams};
use uwfq::workload::stream::ScaleParams;

/// Tees each completion into the streaming sink while retaining the bare
/// response times — one run yields both the estimate and its ground
/// truth.
struct Tee {
    streaming: StreamingRunMetrics,
    rts: Vec<f64>,
}

impl CompletionSink for Tee {
    fn job_completed(&mut self, c: CompletedJob) {
        self.rts.push(c.response_time());
        self.streaming.job_completed(c);
    }
}

/// A gtrace-shaped workload grown to ≈50k jobs: more users over a longer
/// window, same §5.3 shaping pipeline (heavy-user rebalance, runtime
/// filter, utilization rescale).
fn big_gtrace_params() -> GtraceParams {
    GtraceParams {
        window_s: 5_000.0,
        users: 500,
        heavy_users: 100,
        cores: 64,
        ..GtraceParams::default()
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: 50k-job simulation (CI scale-smoke)")]
fn streaming_quantiles_within_tolerance_on_50k_gtrace() {
    let p = big_gtrace_params();
    let stream = gtrace(97, &p);
    // gtrace names are per-job unique, so slowdowns are skipped (empty
    // idle map → slowdown 1.0); this test is about RT quantiles.
    let mut tee = Tee {
        streaming: StreamingRunMetrics::new("gtrace-50k", HashMap::new()),
        rts: Vec::new(),
    };
    let cfg = Config::default().with_cores(p.cores);
    let mut core = SchedCore::from_config(cfg);
    let summary = sim::simulate_stream_into(&mut core, stream, &mut tee);
    assert!(
        tee.rts.len() >= 30_000,
        "workload too small for the accuracy contract: {} jobs",
        tee.rts.len()
    );
    assert_eq!(summary.jobs_completed as usize, tee.rts.len());
    assert!(summary.peak_in_flight_jobs < tee.rts.len() / 4, "backlog unbounded");

    let mut sorted = tee.rts.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (p, pct) in [(0.50, 50.0), (0.95, 95.0), (0.99, 99.0)] {
        let exact = uwfq::util::stats::percentile_sorted(&sorted, pct);
        assert!(exact > 0.0);
        let ecdf = tee.streaming.rt_quantile_ecdf(p);
        let rel_ecdf = (ecdf - exact).abs() / exact;
        assert!(
            rel_ecdf <= ECDF_QUANTILE_RTOL,
            "ECDF p{pct}: {ecdf} vs exact {exact} (rel {rel_ecdf})"
        );
        let p2 = tee.streaming.rt_quantile_p2(p);
        let tol = if pct == 99.0 { P2_P99_RTOL } else { P2_QUANTILE_RTOL };
        let rel_p2 = (p2 - exact).abs() / exact;
        assert!(
            rel_p2 <= tol,
            "P² p{pct}: {p2} vs exact {exact} (rel {rel_p2})"
        );
    }

    // ECDF vs exact empirical CDF at the streaming bins' edges.
    let exact_at =
        |v: f64| -> f64 { sorted.partition_point(|&s| s <= v) as f64 / sorted.len() as f64 };
    let mut sup = 0.0f64;
    for b in 0..tee.streaming.rt_ecdf.bins() {
        let edge = tee.streaming.rt_ecdf.upper_edge(b);
        sup = sup.max((tee.streaming.rt_ecdf.cdf_at(edge) - exact_at(edge)).abs());
    }
    assert!(sup <= ECDF_SUP_TOL, "ECDF sup error at edges {sup}");
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-only: 50k-job scale run (CI scale-smoke)")]
fn scale_harness_verifies_at_50k() {
    // The `uwfq scale --quick` shape end to end through the harness:
    // bounded backlog, full slowdown pipeline (template idle map), and
    // the tolerance check that CI enforces.
    let params = ScaleParams {
        users: 1_000,
        jobs: 50_000,
        cores: 64,
        target_utilization: 0.85,
        seed: 42,
    };
    let cfg = Config::default().with_cores(64);
    let o = run_scale(&params, &cfg, true);
    assert_eq!(o.jobs, 50_000);
    assert_eq!(o.user_count, 1_000);
    assert!(
        o.peak_in_flight_jobs < 5_000,
        "peak backlog {} — resident state must stay O(in-flight), far below 50k",
        o.peak_in_flight_jobs
    );
    assert!(o.arena_job_slots <= o.peak_in_flight_jobs + 1);
    o.verify.as_ref().unwrap().check().unwrap();
}

/// Cheap smoke so `cargo test -q` (debug tier-1) still exercises this
/// file: miniature versions of both paths.
#[test]
fn miniature_accuracy_smoke() {
    let p = GtraceParams {
        window_s: 60.0,
        users: 6,
        heavy_users: 2,
        cores: 8,
        ..GtraceParams::default()
    };
    let mut tee = Tee {
        streaming: StreamingRunMetrics::new("mini", HashMap::new()),
        rts: Vec::new(),
    };
    let mut core = SchedCore::from_config(Config::default().with_cores(8));
    sim::simulate_stream_into(&mut core, gtrace(3, &p), &mut tee);
    assert!(!tee.rts.is_empty());
    // With few samples the P² estimate is exact or near-exact; just pin
    // basic sanity: quantiles ordered and inside the observed range.
    let q50 = tee.streaming.rt_quantile_ecdf(0.50);
    let q99 = tee.streaming.rt_quantile_ecdf(0.99);
    let max = tee.rts.iter().cloned().fold(0.0, f64::max);
    assert!(q50 <= q99 * (1.0 + 1e-9));
    assert!(q99 <= max * 1.1 + 1.0);
}
