//! Integration: the full three-layer stack composes — workload → UWFQ
//! scheduling → real thread-per-core executors running the AOT-compiled
//! Pallas analytics kernel via PJRT → aggregated results.
//!
//! Requires `make artifacts` (skips if missing).

use std::path::Path;

use uwfq::config::Config;
use uwfq::exec::run_real;
use uwfq::sched::PolicyKind;
use uwfq::workload::scenarios::micro_job;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = uwfq::runtime::ArtifactStore::default_dir();
    if !Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(dir)
}

#[test]
fn real_backend_runs_multi_user_workload() {
    let Some(dir) = artifacts() else { return };
    let cfg = Config {
        cores: 2,
        policy: PolicyKind::Uwfq,
        ..Config::default()
    };
    // Two users, three jobs, compressed timeline.
    let jobs = vec![
        micro_job(1, "tiny", 0.0, None),
        micro_job(2, "tiny", 0.1, None),
        micro_job(1, "short", 0.2, None),
    ];
    let report = run_real(cfg, jobs, &dir, 0.02).expect("real run succeeds");
    assert_eq!(report.completed.len(), 3);
    assert!(report.makespan_s > 0.0);
    // Every job produced a final [mean; var] result with finite values.
    assert_eq!(report.results.len(), 3);
    for (job, out) in &report.results {
        assert_eq!(out.len(), 16, "job {job} output shape");
        assert!(out.iter().all(|v| v.is_finite()), "job {job} finite");
        // Variance row non-negative.
        assert!(out[8..].iter().all(|&v| v >= -1e-3), "job {job} var >= 0");
    }
    // Task wall times were measured for at least one variant.
    assert!(!report.task_wall.is_empty());
}

#[test]
fn real_backend_respects_policy_ordering() {
    let Some(dir) = artifacts() else { return };
    // FIFO: first submitted job must finish first when both arrive
    // together on a single core (no preemption, strict order).
    let cfg = Config {
        cores: 1,
        policy: PolicyKind::Fifo,
        ..Config::default()
    };
    let jobs = vec![
        micro_job(1, "tiny", 0.0, None),
        micro_job(2, "tiny", 0.001, None),
    ];
    let report = run_real(cfg, jobs, &dir, 0.01).expect("real run succeeds");
    let first = report.completed.iter().find(|c| c.user == 1).unwrap();
    let second = report.completed.iter().find(|c| c.user == 2).unwrap();
    assert!(
        first.finish <= second.finish,
        "FIFO must finish user 1 first"
    );
}

#[test]
fn real_backend_errors_on_missing_artifacts() {
    let cfg = Config {
        cores: 1,
        ..Config::default()
    };
    let jobs = vec![micro_job(1, "tiny", 0.0, None)];
    let err = run_real(cfg, jobs, Path::new("/nonexistent/artifacts"), 1.0);
    assert!(err.is_err());
}
