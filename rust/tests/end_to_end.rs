//! Integration: the full three-layer stack composes — workload → UWFQ
//! scheduling → real thread-per-core executors running the AOT-compiled
//! Pallas analytics kernel via PJRT → aggregated results (requires
//! `make artifacts`; skips if missing) — plus CLI end-to-end coverage of
//! the trace-replay pipeline (`uwfq tracegen` → `uwfq replay`), which
//! needs no artifacts: the binary itself is the fixture.

use std::path::Path;
use std::process::Command;

use uwfq::config::Config;
use uwfq::exec::run_real;
use uwfq::sched::PolicyKind;
use uwfq::workload::scenarios::micro_job;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = uwfq::runtime::ArtifactStore::default_dir();
    if !Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(dir)
}

#[test]
fn real_backend_runs_multi_user_workload() {
    let Some(dir) = artifacts() else { return };
    let cfg = Config {
        cores: 2,
        policy: PolicyKind::Uwfq,
        ..Config::default()
    };
    // Two users, three jobs, compressed timeline.
    let jobs = vec![
        micro_job(1, "tiny", 0.0, None),
        micro_job(2, "tiny", 0.1, None),
        micro_job(1, "short", 0.2, None),
    ];
    let report = run_real(cfg, jobs, &dir, 0.02).expect("real run succeeds");
    assert_eq!(report.completed.len(), 3);
    assert!(report.makespan_s > 0.0);
    // Every job produced a final [mean; var] result with finite values.
    assert_eq!(report.results.len(), 3);
    for (job, out) in &report.results {
        assert_eq!(out.len(), 16, "job {job} output shape");
        assert!(out.iter().all(|v| v.is_finite()), "job {job} finite");
        // Variance row non-negative.
        assert!(out[8..].iter().all(|&v| v >= -1e-3), "job {job} var >= 0");
    }
    // Task wall times were measured for at least one variant.
    assert!(!report.task_wall.is_empty());
}

#[test]
fn real_backend_respects_policy_ordering() {
    let Some(dir) = artifacts() else { return };
    // FIFO: first submitted job must finish first when both arrive
    // together on a single core (no preemption, strict order).
    let cfg = Config {
        cores: 1,
        policy: PolicyKind::Fifo,
        ..Config::default()
    };
    let jobs = vec![
        micro_job(1, "tiny", 0.0, None),
        micro_job(2, "tiny", 0.001, None),
    ];
    let report = run_real(cfg, jobs, &dir, 0.01).expect("real run succeeds");
    let first = report.completed.iter().find(|c| c.user == 1).unwrap();
    let second = report.completed.iter().find(|c| c.user == 2).unwrap();
    assert!(
        first.finish <= second.finish,
        "FIFO must finish user 1 first"
    );
}

#[test]
fn real_backend_errors_on_missing_artifacts() {
    let cfg = Config {
        cores: 1,
        ..Config::default()
    };
    let jobs = vec![micro_job(1, "tiny", 0.0, None)];
    let err = run_real(cfg, jobs, Path::new("/nonexistent/artifacts"), 1.0);
    assert!(err.is_err());
}

// ---------------------------------------------------------------------------
// Trace replay CLI (uwfq tracegen → uwfq replay)
// ---------------------------------------------------------------------------

fn uwfq_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_uwfq"))
}

fn temp_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("uwfq_e2e_replay_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn replay_cli_end_to_end() {
    let dir = temp_dir();
    let trace = dir.join("synth.csv");
    let out = uwfq_bin()
        .args(["tracegen", trace.to_str().unwrap(), "--jobs", "400", "--seed", "11"])
        .output()
        .expect("spawn uwfq tracegen");
    assert!(
        out.status.success(),
        "tracegen failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(trace.exists());

    let out = uwfq_bin()
        .args([
            "replay",
            "--trace",
            trace.to_str().unwrap(),
            "--quick",
            "--cores",
            "8",
            "--out",
            dir.to_str().unwrap(),
        ])
        .output()
        .expect("spawn uwfq replay");
    assert!(
        out.status.success(),
        "replay failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("trace replay"), "{stdout}");
    let json = std::fs::read_to_string(dir.join("BENCH_replay.json")).unwrap();
    for key in [
        "replay/jobs",
        "replay/jobs_per_s",
        "replay/peak_in_flight_jobs",
        "replay/max_buffered_rows",
        "replay/rt_p95_ecdf_s",
    ] {
        assert!(json.contains(key), "BENCH_replay.json missing {key}: {json}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replay_cli_errors_name_the_problem() {
    // No trace given: the usage hint names the flag.
    let out = uwfq_bin().arg("replay").output().expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--trace"), "{err}");

    // Missing file: the error names the path.
    let out = uwfq_bin()
        .args(["replay", "--trace", "/nonexistent/trace.csv"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("/nonexistent/trace.csv"), "{err}");

    // Malformed row: the error names the offending line and lists the
    // format's valid columns.
    let dir = temp_dir();
    let bad = dir.join("bad.csv");
    std::fs::write(
        &bad,
        "job,user,arrival_s,slot_s,stages,heavy\ng0,1,0.0,5.0,1,0\ng1,1,1.0,oops,1,0\n",
    )
    .unwrap();
    let out = uwfq_bin()
        .args(["replay", "--trace", bad.to_str().unwrap()])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("line 3"), "{err}");
    assert!(err.contains("slot_s") && err.contains("columns"), "{err}");

    // Unknown format value: the error lists the valid ones.
    let out = uwfq_bin()
        .args(["replay", "--trace", bad.to_str().unwrap(), "--format", "tsv"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("gcluster"), "{err}");

    // Non-positive gcluster runtime: rejected with the line, the field
    // and the format's columns — not silently mapped to a zero-work job.
    let bad_rt = dir.join("bad_runtime.csv");
    std::fs::write(
        &bad_rt,
        "timestamp,job_id,user,scheduling_class,runtime_s,cpu_request\n\
         0.5,900,7,3,20.0,2.0\n\
         1.5,901,8,0,-4.0,0.5\n",
    )
    .unwrap();
    let out = uwfq_bin()
        .args(["replay", "--trace", bad_rt.to_str().unwrap(), "--format", "gcluster"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("line 3"), "{err}");
    assert!(err.contains("runtime_s must be a positive finite number"), "{err}");
    assert!(err.contains("cpu_request"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bad_input_errors_name_the_problem() {
    // Every malformed-input path must exit nonzero with a message naming
    // what was wrong — never a panic, never a silent zero exit.

    // Malformed numeric flag value: the error names the key AND the value.
    let out = uwfq_bin()
        .args(["run", "--cores", "abc"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("cores") && err.contains("abc"), "{err}");

    // Out-of-range fault knob: the error names the knob.
    let out = uwfq_bin()
        .args(["run", "--fault.task_fail_prob", "1.5"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("task_fail_prob"), "{err}");

    // Unknown fault knob: the error names it and lists the valid keys.
    let out = uwfq_bin()
        .args(["run", "--fault.bogus_knob", "1"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fault.bogus_knob"), "{err}");
    assert!(err.contains("task_fail_prob"), "{err}");

    // Unknown reproduce target: named, with the valid list.
    let out = uwfq_bin()
        .args(["reproduce", "bogus"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bogus") && err.contains("table1"), "{err}");

    // `uwfq fault` sweeps its own arms: pre-set fault flags are rejected
    // with a pointer to the single-run alternative.
    let out = uwfq_bin()
        .args(["fault", "--quick", "--fault.task_fail_prob", "0.1"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fault") && err.contains("uwfq run"), "{err}");
}
