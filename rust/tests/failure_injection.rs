//! Failure/perturbation injection: estimator error (§6.4 robustness),
//! grace-period dynamics (§4.2), degenerate workloads, and hostile
//! configurations. The system must stay correct (all jobs complete, no
//! panics) and the paper's robustness claim must hold in shape.

use uwfq::config::Config;
use uwfq::core::job::{CostProfile, JobSpec};
use uwfq::partition::SchemeKind;
use uwfq::sched::PolicyKind;
use uwfq::sim;
use uwfq::util::propkit;
use uwfq::workload::{ScenarioSpec, Workload};

/// Scaled-down scenario 1 via the registry (the only workload entry
/// point since the twin-function refactor).
fn small_scenario1(seed: u64, duration_s: f64, burst: u32, gap_s: f64) -> Workload {
    ScenarioSpec::new("scenario1")
        .with("duration_s", &duration_s.to_string())
        .with("burst", &burst.to_string())
        .with("poisson_gap_s", &gap_s.to_string())
        .workload(seed)
        .unwrap()
}

#[test]
fn uwfq_robust_to_estimator_error() {
    // §6.4: virtual-time scheduling is robust to inaccurate runtime
    // predictions. With σ=0.5 lognormal error (≈ ±65% typical), mean RT
    // should degrade by at most ~50% vs the perfect oracle.
    let w = small_scenario1(7, 120.0, 4, 30.0);
    let mut exact = Config::default().with_policy(PolicyKind::Uwfq);
    exact.seed = 7;
    let mut noisy = exact.clone();
    noisy.estimator_sigma = 0.5;

    let m_exact = uwfq::bench::run_one(&exact, &w);
    let m_noisy = uwfq::bench::run_one(&noisy, &w);
    assert_eq!(m_exact.outcomes.len(), m_noisy.outcomes.len());
    assert!(
        m_noisy.mean_rt() < m_exact.mean_rt() * 1.5,
        "noisy {} vs exact {}",
        m_noisy.mean_rt(),
        m_exact.mean_rt()
    );
}

#[test]
fn runtime_partitioning_robust_to_estimator_error() {
    // Partition counts come from estimates; error changes granularity but
    // must not break completion or blow up response times.
    let w = ScenarioSpec::new("scenario2")
        .with("jobs_per_user", "8")
        .with("stagger_s", "1.0")
        .workload(1)
        .unwrap();
    for sigma in [0.0, 0.3, 0.8] {
        let mut cfg = Config::default()
            .with_policy(PolicyKind::Uwfq)
            .with_scheme(SchemeKind::Runtime);
        cfg.estimator_sigma = sigma;
        let m = uwfq::bench::run_one(&cfg, &w);
        assert_eq!(m.outcomes.len(), 32, "sigma={sigma}");
        assert!(m.mean_rt().is_finite());
    }
}

#[test]
fn grace_period_extremes_are_safe() {
    // Zero grace (users always re-enter fresh) and huge grace (users are
    // always revived) must both complete every job.
    let w = small_scenario1(11, 90.0, 3, 20.0);
    for grace in [0.0, 2.0, 1e6] {
        let mut cfg = Config::default().with_policy(PolicyKind::Uwfq);
        cfg.grace_rsec = grace;
        let m = uwfq::bench::run_one(&cfg, &w);
        assert_eq!(m.outcomes.len(), w.jobs.len(), "grace={grace}");
    }
}

#[test]
fn degenerate_workloads() {
    let cfg = Config::default().with_cores(4);
    // Single zero-ish work job.
    let tiny = JobSpec::three_phase(1, "z", 0, 1e-6, 1, 1, None);
    let rep = sim::simulate(cfg.clone(), vec![tiny]);
    assert_eq!(rep.completed.len(), 1);

    // Extreme skew: 99% of cost in 1% of data.
    let skew = CostProfile::skewed(0.01, 10_000.0);
    let j = JobSpec::three_phase(1, "s", 0, 10.0, 256 << 20, 4, Some(skew));
    for scheme in [SchemeKind::Size, SchemeKind::Runtime] {
        let rep = sim::simulate(cfg.clone().with_scheme(scheme), vec![j.clone()]);
        assert_eq!(rep.completed.len(), 1);
    }

    // Many users, one job each, simultaneous arrival.
    let jobs: Vec<JobSpec> = (0..50)
        .map(|i| JobSpec::three_phase(i, &format!("u{i}"), 0, 1.0, 64 << 20, 4, None))
        .collect();
    for policy in PolicyKind::ALL {
        let rep = sim::simulate(cfg.clone().with_policy(policy), jobs.clone());
        assert_eq!(rep.completed.len(), 50, "{}", policy.name());
    }
}

#[test]
fn single_core_cluster() {
    let cfg = Config::default().with_cores(1);
    let jobs: Vec<JobSpec> = (0..5)
        .map(|i| {
            let arrival = i as u64 * 100_000;
            JobSpec::three_phase(1 + i % 2, &format!("j{i}"), arrival, 0.5, 32 << 20, 4, None)
        })
        .collect();
    for policy in PolicyKind::ALL {
        let rep = sim::simulate(cfg.clone().with_policy(policy), jobs.clone());
        assert_eq!(rep.completed.len(), 5, "{}", policy.name());
    }
}

#[test]
fn hostile_atr_values() {
    // Very small ATR explodes task counts (bounded by overhead economics,
    // but must not hang); very large ATR degenerates to one partition.
    let j = JobSpec::three_phase(1, "j", 0, 5.0, 256 << 20, 4, None);
    for atr in [0.001, 0.05, 100.0] {
        let mut cfg = Config::default()
            .with_cores(4)
            .with_scheme(SchemeKind::Runtime);
        cfg.atr = atr;
        let rep = sim::simulate(cfg, vec![j.clone()]);
        assert_eq!(rep.completed.len(), 1, "atr={atr}");
    }
}

#[test]
fn adversarial_arrival_patterns_complete() {
    propkit::check("adversarial arrivals", 0xFA11, 8, |r| {
        let mut cfg = Config::default().with_cores(4);
        cfg.task_overhead = 0.001;
        let mut jobs = Vec::new();
        // Clustered arrivals with duplicate timestamps and random users.
        for i in 0..25 {
            let t = (r.below(5) * 1_000_000) as u64; // 0..5s, many ties
            jobs.push(JobSpec::three_phase(
                r.below(6) as u32,
                &format!("a{i}"),
                t,
                0.1 + r.f64() * 2.0,
                (1 + r.below(512)) << 20,
                4,
                None,
            ));
        }
        for policy in PolicyKind::ALL {
            let rep = sim::simulate(cfg.clone().with_policy(policy), jobs.clone());
            if rep.completed.len() != 25 {
                return Err(format!("{} lost jobs", policy.name()));
            }
        }
        Ok(())
    });
}
