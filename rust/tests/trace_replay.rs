//! Trace-replay subsystem tests:
//!
//! * **Golden fixtures** — two small writer-format traces are checked
//!   into `tests/data/`. The raw (unshaped) streaming replay must be
//!   **byte-identical** to the legacy in-memory `tracefile` loader —
//!   per-field job parity and bit-exact `SimReport`s under **all five**
//!   policies (two independent parser+builder implementations agreeing
//!   is the golden contract; a parsing regression in either breaks it
//!   without any toolchain-local blessing step). Fixture A's parsed rows
//!   are additionally pinned value-by-value, and the one-pass shaping
//!   factors over it are pinned against the documented formulas.
//! * **Differential** — the one-pass streaming shaper vs the exact
//!   two-pass gtrace oracle on a writer-generated trace: job count
//!   within 2 %, identical `UserClass` maps, and response-time
//!   quantiles within the documented scale tolerances
//!   (`bench::scale::P2_QUANTILE_RTOL` / `P2_P99_RTOL`) for all five
//!   policies.
//! * **Bounded state** — a writer-generated 1M-row trace replays through
//!   the `trace` registry path with peak in-flight jobs and peak
//!   buffered rows orders of magnitude below the trace length
//!   (release-only; debug builds run the same check at 50k rows via the
//!   differential sizes above).

use uwfq::bench::scale::{P2_P99_RTOL, P2_QUANTILE_RTOL};
use uwfq::config::Config;
use uwfq::core::dag::CompletedJob;
use uwfq::core::SchedCore;
use uwfq::sched::PolicyKind;
use uwfq::sim::{self, CompletionSink, SimReport};
use uwfq::util::stats;
use uwfq::workload::gtrace::GtraceParams;
use uwfq::workload::registry;
use uwfq::workload::traceio::{self, writer, ShapeParams, TraceParams};
use uwfq::workload::{tracefile, ScenarioSpec};

mod common;
use common::fingerprint;

fn fixture(name: &str) -> String {
    format!("{}/tests/data/{name}", env!("CARGO_MANIFEST_DIR"))
}

fn temp(name: &str) -> String {
    let dir = std::env::temp_dir().join(format!("uwfq_trace_replay_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

fn cfg(policy: PolicyKind) -> Config {
    Config::default().with_cores(8).with_policy(policy)
}

// ---------------------------------------------------------------------------
// Golden fixtures
// ---------------------------------------------------------------------------

#[test]
fn golden_fixture_a_parses_value_by_value() {
    let mut rd = traceio::RowReader::open(&fixture("trace_small_a.csv"), None).unwrap();
    // (name, user, arrival_s, slot_s, stages, heavy) — pinned to the
    // checked-in bytes; any reader regression shifts a field.
    let expect = [
        ("a0", 1u32, 0.0, 24.0, 1usize, true),
        ("a1", 2, 1.5, 6.0, 1, false),
        ("a2", 1, 2.0, 30.0, 2, true),
        ("a3", 3, 2.0, 4.0, 1, false),
        ("a4", 2, 3.25, 10.0, 1, false),
        ("a5", 1, 5.0, 36.0, 2, true),
        ("a6", 3, 6.5, 8.0, 1, false),
        ("a7", 2, 8.0, 12.0, 1, false),
        ("a8", 1, 9.75, 28.0, 2, true),
        ("a9", 3, 11.0, 5.0, 1, false),
        ("a10", 2, 12.5, 9.0, 1, false),
        ("a11", 1, 14.0, 32.0, 2, true),
    ];
    for (i, e) in expect.iter().enumerate() {
        let row = rd.next_row().unwrap().unwrap_or_else(|| panic!("row {i} missing"));
        assert_eq!(row.index, i as u64);
        assert_eq!(row.name, e.0);
        assert_eq!(row.user, e.1);
        let (arrival, slot): (f64, f64) = (e.2, e.3);
        assert_eq!(row.arrival_s.to_bits(), arrival.to_bits());
        assert_eq!(row.slot_s.to_bits(), slot.to_bits());
        assert_eq!(row.stages, e.4);
        assert_eq!(row.heavy, e.5);
    }
    assert!(rd.next_row().unwrap().is_none());
}

#[test]
fn golden_raw_replay_matches_tracefile_loader_byte_exactly() {
    for name in ["trace_small_a.csv", "trace_small_b.csv"] {
        let path = fixture(name);
        let loaded = tracefile::load_csv_file(&path).unwrap();
        let spec = ScenarioSpec::new("trace")
            .with("path", &path)
            .with("shape", "false");
        // The streamed and the in-memory loader must classify users
        // identically...
        let inst = spec.build(1).unwrap();
        assert_eq!(inst.user_class, loaded.user_class, "{name}");
        // ...and produce bit-identical schedules under every policy.
        for policy in PolicyKind::ALL {
            let streamed = sim::simulate_stream(cfg(policy), spec.build(1).unwrap().stream);
            let in_memory = sim::simulate(cfg(policy), loaded.jobs.clone());
            assert_eq!(
                fingerprint(&streamed),
                fingerprint(&in_memory),
                "{name}: streaming parser diverged from the legacy loader under {}",
                policy.name()
            );
            assert_eq!(streamed.completed.len(), loaded.jobs.len(), "{name}: lost jobs");
        }
    }
}

#[test]
fn golden_shaping_factors_match_documented_formulas() {
    // Fixture A by hand: heavy work 24+30+36+28+32 = 150, light work
    // 6+4+10+8+12+5+9 = 54, span 14 s, and every slot far below 10× the
    // median (no filtering). With warmup > rows the one-pass shaper
    // freezes over the whole file, so its factors must equal the exact
    // formulas on those sums.
    let tp = TraceParams {
        path: fixture("trace_small_a.csv"),
        shaping: ShapeParams {
            warmup: 100,
            filter_median_mult: 10.0,
            heavy_work_fraction: 0.9,
            target_utilization: 0.8,
            cores: 16,
        },
        skew_fraction: 0.0,
        ..TraceParams::default()
    };
    let mut s = traceio::open_trace(&tp).unwrap();
    let jobs = uwfq::workload::stream::materialize(&mut s);
    assert_eq!(jobs.len(), 12, "no fixture row may be filtered");
    let st = s.shape_stats();
    assert_eq!(st.rows_dropped, 0);
    let heavy_scale = 0.9 / 0.1 * 54.0 / 150.0;
    let rate = (150.0 * heavy_scale + 54.0) / 14.0;
    let util_scale = 0.8 * 16.0 / rate;
    assert!((st.heavy_scale - heavy_scale).abs() < 1e-12, "{st:?}");
    assert!((st.util_scale - util_scale).abs() < 1e-12, "{st:?}");
    // Each job's total slot time is the shaped row value (stage fractions
    // sum to 1; tolerate only fp summation noise).
    let raw = [24.0, 6.0, 30.0, 4.0, 10.0, 36.0, 8.0, 12.0, 28.0, 5.0, 9.0, 32.0];
    let heavy = [1, 0, 1, 0, 0, 1, 0, 0, 1, 0, 0, 1].map(|h| h == 1);
    for ((j, slot), is_heavy) in jobs.iter().zip(raw).zip(heavy) {
        let expect = slot * if is_heavy { heavy_scale } else { 1.0 } * util_scale;
        let got = j.slot_time();
        assert!(
            (got - expect).abs() / expect < 1e-9,
            "{}: shaped slot {got} vs {expect}",
            j.name
        );
    }
}

// ---------------------------------------------------------------------------
// One-pass vs exact two-pass differential
// ---------------------------------------------------------------------------

fn rts_of(rep: &SimReport) -> Vec<f64> {
    rep.completed.iter().map(|c| c.response_time()).collect()
}

#[test]
fn one_pass_shaping_matches_two_pass_oracle_within_documented_tolerances() {
    let seed = 20260730;
    // Sub-critical target utilization: RT quantiles stay stable under
    // the few-percent factor drift the warmup-window estimate is
    // allowed. ~6 000 rows with a 2 048-row warmup keeps the window's
    // per-class work-rate sampling error at a few percent — well inside
    // the 15 % / 25 % tolerances.
    let gp = writer::params_for_jobs(
        6_000,
        &GtraceParams {
            cores: 8,
            target_utilization: 0.7,
            ..GtraceParams::default()
        },
    );
    let path = temp("differential.csv");
    let rows = writer::write_synthetic(&path, seed, &gp).unwrap();
    assert!(rows > 4000, "differential trace too small: {rows} rows");

    // Streamed one-pass replay of the written raw rows.
    let spec = ScenarioSpec::new("trace")
        .with("path", &path)
        .with("warmup", "2048")
        .with("cores", "8")
        .with("target_utilization", "0.7");
    // Exact two-pass oracle: the in-memory generator over the same raw
    // tuples (same seed and params as the writer; shortest round-trip
    // float formatting makes the window parameter exact).
    let oracle_spec = ScenarioSpec::new("gtrace")
        .with("window_s", &format!("{}", gp.window_s))
        .with("cores", "8")
        .with("target_utilization", "0.7");

    let streamed_w = spec.workload(seed).unwrap();
    let oracle_w = oracle_spec.workload(seed).unwrap();

    // Job count within 2 % (running-median filter vs global median).
    let (a, b) = (streamed_w.jobs.len() as f64, oracle_w.jobs.len() as f64);
    assert!(
        (a - b).abs() / b < 0.02,
        "job count drift: streamed {a} vs oracle {b}"
    );
    // Identical user classification.
    assert_eq!(streamed_w.user_class, oracle_w.user_class);

    // Response-time quantiles within the documented scale tolerances,
    // per policy (p50/p95 at the P² tolerance, p99 at the looser one).
    for policy in PolicyKind::ALL {
        let sr = sim::simulate(cfg(policy), streamed_w.jobs.clone());
        let or = sim::simulate(cfg(policy), oracle_w.jobs.clone());
        let (s_rts, o_rts) = (rts_of(&sr), rts_of(&or));
        let mean_s = stats::mean(&s_rts);
        let mean_o = stats::mean(&o_rts);
        assert!(
            (mean_s - mean_o).abs() / mean_o < P2_QUANTILE_RTOL,
            "{}: mean RT {mean_s} vs oracle {mean_o}",
            policy.name()
        );
        let tols = [(50.0, P2_QUANTILE_RTOL), (95.0, P2_QUANTILE_RTOL), (99.0, P2_P99_RTOL)];
        for (pct, tol) in tols {
            let qs = stats::percentile(&s_rts, pct);
            let qo = stats::percentile(&o_rts, pct);
            assert!(
                (qs - qo).abs() / qo < tol,
                "{}: p{pct} {qs} vs oracle {qo} (tol {tol})",
                policy.name()
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Bounded resident state
// ---------------------------------------------------------------------------

/// Counts completions without retaining them — the O(1) sink.
#[derive(Default)]
struct CountSink {
    jobs: u64,
}

impl CompletionSink for CountSink {
    fn job_completed(&mut self, _job: CompletedJob) {
        self.jobs += 1;
    }
}

/// Replay a writer-generated `rows`-row trace and assert the resident
/// workload state stays O(warmup + in-flight): the peak in-flight job
/// counter and the shaper's peak buffer are both orders of magnitude
/// below the trace length, i.e. the streaming path never materializes
/// the trace.
fn assert_bounded_replay(rows_target: u64) {
    let warmup = 4096usize.min(rows_target as usize / 4).max(16);
    let gp = writer::params_for_jobs(
        rows_target,
        &GtraceParams {
            cores: 8,
            target_utilization: 0.6,
            ..GtraceParams::default()
        },
    );
    let path = temp(&format!("bounded_{rows_target}.csv"));
    let rows = writer::write_synthetic(&path, 7, &gp).unwrap();
    assert!(
        (rows as f64 - rows_target as f64).abs() / rows_target as f64 < 0.15,
        "writer produced {rows} rows for a {rows_target} target"
    );

    // Through the registry path (what `uwfq replay` and the `trace`
    // entry run), but keeping hold of the stream for its counters.
    let spec = ScenarioSpec::new("trace")
        .with("path", &path)
        .with("warmup", &warmup.to_string())
        .with("cores", "8")
        .with("target_utilization", "0.6");
    let tp = registry::trace_params(&spec, 7).unwrap();
    let (classes, scanned) = traceio::scan_user_classes(&tp.path, tp.format).unwrap();
    assert_eq!(scanned, rows);
    assert_eq!(classes.len(), 25);

    let mut stream = traceio::open_trace(&tp).unwrap();
    let mut sink = CountSink::default();
    let mut core = SchedCore::from_config(cfg(PolicyKind::Uwfq));
    let summary = sim::simulate_stream_into(&mut core, &mut stream, &mut sink);

    let stats = stream.shape_stats();
    assert_eq!(stats.rows_in, rows);
    assert_eq!(sink.jobs, summary.jobs_completed);
    assert_eq!(sink.jobs + stats.rows_dropped, rows, "jobs lost in the pipeline");
    assert!(
        stats.rows_dropped as f64 <= rows as f64 * 0.10,
        "filter dropped {} of {rows}",
        stats.rows_dropped
    );
    // The bounded-state contract.
    assert!(
        stream.max_buffered() <= warmup,
        "shaper buffered {} rows, above the {warmup}-row warmup bound",
        stream.max_buffered()
    );
    assert!(
        summary.peak_in_flight_jobs as u64 <= (rows / 20).max(64),
        "peak in-flight {} is not O(active) for a {rows}-row trace",
        summary.peak_in_flight_jobs
    );
    assert!(summary.makespan_s > 0.0 && core.is_idle());
    std::fs::remove_file(&path).ok();
}

#[test]
fn bounded_replay_smoke_50k() {
    // Debug-profile tier-1 version of the million-row contract.
    assert_bounded_replay(50_000);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "1M-row replay is a release-profile test (CI)")]
fn million_row_replay_holds_bounded_state() {
    let rows: u64 = std::env::var("UWFQ_REPLAY_ROWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    assert_bounded_replay(rows);
}
