//! Differential tests for the deterministic fault subsystem:
//!
//! 1. **Zero-rate inertness** — a `FaultConfig` with every rate at zero
//!    (whatever its seed/budget/backoff knobs say) is byte-identical to
//!    the default config under every policy: the fault machinery has no
//!    observable footprint until a rate is armed.
//! 2. **Ledger == log** — the goodput/waste core-time ledger (total and
//!    per-user) is exactly the span sum of the task log, split by
//!    outcome: virtual time and goodput are charged once per successful
//!    attempt, never for retries, killed racers or crash-lost attempts.
//! 3. **Goodput invariance** — with failures only (no stragglers, no
//!    crashes), per-user *goodput* equals the clean run's per-user busy
//!    time: re-execution adds waste, never goodput.
//! 4. **Reset-vs-fresh with faults** — a `SimCtx` recycled across faulty
//!    runs (the sweep-worker path, `SchedCore::reset` under the hood)
//!    reproduces a fresh context bit for bit, fault ledger included.

use std::collections::BTreeMap;

use uwfq::config::Config;
use uwfq::core::task::Outcome;
use uwfq::fault::FaultConfig;
use uwfq::sched::PolicyKind;
use uwfq::sim::{self, SimCtx};
use uwfq::workload::ScenarioSpec;

mod common;
use common::fingerprint;

/// The standard faulty differential workload: multi-user, bursty, big
/// enough that every fault class actually fires at the test rates.
fn workload(seed: u64) -> Vec<uwfq::core::job::JobSpec> {
    ScenarioSpec::new("scenario2")
        .with("jobs_per_user", "8")
        .with("stagger_s", "0.8")
        .workload(seed)
        .unwrap()
        .jobs
}

/// A config arming all three fault classes at rates that fire on the
/// small test workload.
fn all_faults() -> FaultConfig {
    FaultConfig {
        task_fail_prob: 0.15,
        retry_backoff_s: 0.05,
        straggler_prob: 0.1,
        straggler_mult: 5.0,
        spec_mult: 2.0,
        crash_mttf_s: 3.0,
        crash_recover_s: 0.5,
        seed: 42,
        ..Default::default()
    }
}

#[test]
fn zero_rate_fault_config_is_byte_identical_to_default() {
    let jobs = workload(3);
    for policy in PolicyKind::ALL {
        let base = Config::default().with_cores(8).with_policy(policy);
        let mut zeroed = base.clone();
        // Rates all zero ⇒ inert, no matter what the inactive knobs say.
        zeroed.fault = FaultConfig {
            max_failures: 7,
            retry_backoff_s: 123.0,
            straggler_mult: 9.0,
            spec_mult: 3.0,
            crash_recover_s: 99.0,
            seed: 0xDEAD_BEEF,
            ..Default::default()
        };
        assert!(!zeroed.fault.enabled());
        let a = sim::simulate(base, jobs.clone());
        let b = sim::simulate(zeroed, jobs.clone());
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "zero-rate fault config perturbed the schedule under {}",
            policy.name()
        );
    }
}

#[test]
fn goodput_ledger_matches_task_log_exactly() {
    let jobs = workload(7);
    let mut cfg = Config::default().with_cores(8).with_policy(PolicyKind::Uwfq);
    cfg.log_tasks = true;
    cfg.fault = all_faults();
    let rep = sim::simulate(cfg, jobs.clone());
    assert_eq!(rep.completed.len(), jobs.len());
    let f = &rep.fault;
    assert!(
        f.failures > 0 && f.spec_launched > 0 && f.crashes > 0,
        "test workload must exercise all three fault classes: {f:?}"
    );

    // Total and per-user ledger == span sums split by outcome: goodput
    // is charged exactly once per successful attempt, waste for every
    // failed, killed or crash-lost attempt.
    let mut good: u128 = 0;
    let mut waste: u128 = 0;
    let mut per_user: BTreeMap<u32, (u128, u128)> = BTreeMap::new();
    let mut winners: BTreeMap<(u64, u64, u64), u32> = BTreeMap::new();
    for t in &rep.task_log {
        let span = (t.finished - t.started) as u128;
        let e = per_user.entry(t.user).or_default();
        if t.outcome == Outcome::Success {
            good += span;
            e.0 += span;
            *winners.entry((t.job, t.stage, t.task)).or_default() += 1;
        } else {
            waste += span;
            e.1 += span;
        }
    }
    assert_eq!(f.good_us, good, "goodput ledger diverged from task log");
    assert_eq!(f.wasted_us, waste, "waste ledger diverged from task log");
    assert_eq!(f.per_user, per_user, "per-user ledger diverged from task log");

    // Exactly one successful attempt per (job, stage, task).
    assert!(
        winners.values().all(|&n| n == 1),
        "a task completed more than once"
    );
}

#[test]
fn retries_add_waste_never_goodput() {
    // Failures only: every successful attempt runs its clean duration,
    // so per-user goodput must equal the clean run's per-user busy time
    // while the failed attempts pile up in the waste column.
    let jobs = workload(11);
    let mut clean = Config::default().with_cores(8).with_policy(PolicyKind::Uwfq);
    clean.log_tasks = true;
    let mut faulty = clean.clone();
    faulty.fault = FaultConfig {
        task_fail_prob: 0.25,
        retry_backoff_s: 0.05,
        seed: 9,
        ..Default::default()
    };
    let a = sim::simulate(clean, jobs.clone());
    let b = sim::simulate(faulty, jobs.clone());
    assert_eq!(b.completed.len(), jobs.len());
    assert!(b.fault.failures > 0, "no failures fired");

    let mut clean_busy: BTreeMap<u32, u128> = BTreeMap::new();
    for t in &a.task_log {
        *clean_busy.entry(t.user).or_default() += (t.finished - t.started) as u128;
    }
    let faulty_good: BTreeMap<u32, u128> =
        b.fault.per_user.iter().map(|(&u, &(g, _))| (u, g)).collect();
    assert_eq!(
        clean_busy, faulty_good,
        "re-execution changed per-user goodput"
    );
    assert!(b.fault.wasted_us > 0);
}

#[test]
fn simctx_reuse_with_faults_matches_fresh_context() {
    // The sweep-worker path: one context recycled across faulty cells
    // (SchedCore::reset under the hood) must reproduce a fresh context
    // bit for bit — no fault state (blacklists, retry ledgers, crash
    // cursors, stats) leaks between cells.
    let jobs = workload(5);
    let mut cfg = Config::default().with_cores(8).with_policy(PolicyKind::Uwfq);
    cfg.log_tasks = true;
    cfg.fault = all_faults();

    let mut fresh_ctx = SimCtx::new();
    let fresh = fresh_ctx.simulate(&cfg, jobs.clone());
    assert!(fresh.fault.failures > 0 && fresh.fault.crashes > 0);

    let mut reused = SimCtx::new();
    // Dirty the context with two different faulty cells first.
    let mut other = cfg.clone().with_policy(PolicyKind::Fair);
    other.fault.seed = 1234;
    reused.simulate(&other, jobs.clone());
    let mut crashy = cfg.clone();
    crashy.fault.crash_mttf_s = 1.0;
    crashy.fault.crash_recover_s = 0.25;
    reused.simulate(&crashy, jobs.clone());

    let replay = reused.simulate(&cfg, jobs.clone());
    assert_eq!(
        fingerprint(&fresh),
        fingerprint(&replay),
        "recycled context diverged from fresh under faults"
    );
    // Task logs (attempts, outcomes, core placement) agree too.
    assert_eq!(fresh.task_log.len(), replay.task_log.len());
    for (x, y) in fresh.task_log.iter().zip(&replay.task_log) {
        assert_eq!(
            (x.task, x.stage, x.job, x.core, x.started, x.finished, x.attempt, x.outcome),
            (y.task, y.stage, y.job, y.core, y.started, y.finished, y.attempt, y.outcome),
        );
    }
}
