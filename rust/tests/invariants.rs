//! Property-based invariant harness: randomized registry scenarios ×
//! all five policies, driven by the in-repo propkit (seeded `Rng`, no
//! external crates — failures report a case seed that reproduces the
//! input exactly).
//!
//! Invariants checked on random workloads:
//!
//! 1. **Completions == arrivals** — no job is lost or duplicated, under
//!    every policy.
//! 2. **Byte-identical reports** — the same seed yields bit-for-bit the
//!    same `SimReport` (floats compared by bit pattern) on repeated runs.
//! 3. **Work conservation** — while an arrived job has not launched its
//!    first task, every core is busy (the engine re-offers freed cores
//!    immediately; a leaf stage is runnable from its arrival instant, so
//!    an idle core + a waiting leaf is a scheduling bug).
//! 4. **Non-decreasing virtual time** — the 2-level virtual-time system
//!    (`sched::vtime::TwoLevelVtime`) never moves `V_global` backwards
//!    under random arrival/update interleavings.
//! 5. **Bounded fairness gap** — Theorem A.4 generalized from the fixed
//!    fixtures of `scheduler_bounds.rs` to random registry workloads:
//!    every job finishes under UWFQ within `L_max/R + 2·l_max` (plus
//!    discretization slack) of its UJF finish time. Restricted to the
//!    uniform-cost micro scenarios, matching the theorem's assumptions
//!    (the skewed-cost macro generators violate them by design).
//! 6. **Fault arm** — the same completions/determinism/work-conservation
//!    invariants with a random fault config active (task failures,
//!    stragglers + speculation, core crashes): retries never lose or
//!    duplicate a job, a fixed fault seed repeats byte-identically under
//!    every policy, and work conservation generalizes to "a core may only
//!    idle while a leaf stage waits if it sits inside one of its own
//!    crash/blacklist windows".
//! 7. **Event-core differential** — the calendar-queue + batched event
//!    core reproduces the binary-heap per-event reference schedule
//!    byte-for-byte (completions, utilization bits, fault ledger) on
//!    random registry scenarios × every policy × random fault mixes.
//! 8. **Sharded arm** — `sim::run_sharded` at random shard counts:
//!    merged completions equal arrivals, each shard serves exactly its
//!    hash partition, and work is conserved per shard (a shard's
//!    busy-core ledger never exceeds its core count × its makespan, and
//!    a shard with work is actually busy).
//! 9. **Sharded rebalance arm** — cross-shard core lending on random
//!    Zipf-skewed streams: under every policy, completions still equal
//!    arrivals, the cluster-wide busy ledger never exceeds the (lending-
//!    invariant) total core count × makespan, drift respects the same
//!    provable bound, and repeats are bit-for-bit identical.
//! 10. **Multi-resource arm** — resource-vector accounting across all
//!    seven policies (DRF and BoPF included): completions equal
//!    arrivals, the per-dimension busy ledgers (u128 milli-demand-µs)
//!    never exceed cores × makespan in either dimension, unit-demand
//!    workloads keep both ledgers identical, and repeats — ledgers
//!    included — are byte-identical. (Unit-vector work conservation for
//!    DRF/BoPF rides invariant 3, which already iterates all seven.)

use std::collections::HashMap;

use uwfq::config::Config;
use uwfq::fault::FaultConfig;
use uwfq::sched::vtime::TwoLevelVtime;
use uwfq::sched::PolicyKind;
use uwfq::sim;
use uwfq::sim::{EventBackend, SimOpts};
use uwfq::util::{propkit, Rng};
use uwfq::workload::stress::{skewed, SkewedParams};
use uwfq::workload::ScenarioSpec;
use uwfq::TimeUs;

mod common;
use common::fingerprint;

/// A random small registry scenario: name + schema-valid random params.
/// Sizes are kept small so a debug-profile property run stays fast.
fn random_spec(r: &mut Rng) -> ScenarioSpec {
    match r.below(6) {
        0 => ScenarioSpec::new("scenario1")
            .with("duration_s", &format!("{}", 40 + r.below(50)))
            .with("burst", &format!("{}", 2 + r.below(2)))
            .with("poisson_gap_s", &format!("{}", 20 + r.below(20))),
        1 => ScenarioSpec::new("scenario2")
            .with("jobs_per_user", &format!("{}", 3 + r.below(5)))
            .with("stagger_s", &format!("{:.2}", r.range_f64(0.0, 2.0))),
        2 => ScenarioSpec::new("bursty")
            .with("users", &format!("{}", 2 + r.below(3)))
            .with("steady_users", &format!("{}", 1 + r.below(2)))
            .with("duration_s", &format!("{}", 60 + r.below(60)))
            .with("cycle_s", "30")
            .with("burst_ratio", &format!("{:.2}", r.range_f64(0.1, 0.35)))
            .with("rate", &format!("{:.2}", r.range_f64(0.8, 2.0))),
        3 => ScenarioSpec::new("heavytail")
            .with("users", &format!("{}", 2 + r.below(3)))
            .with("jobs_per_user", &format!("{}", 6 + r.below(7)))
            .with("alpha", &format!("{:.2}", r.range_f64(1.2, 2.5)))
            .with("mean_gap_s", &format!("{:.1}", r.range_f64(2.0, 6.0))),
        4 => ScenarioSpec::new("diurnal")
            .with("users", &format!("{}", 2 + r.below(4)))
            .with("duration_s", &format!("{}", 120 + r.below(120)))
            .with("mean_rate", &format!("{:.3}", r.range_f64(0.04, 0.1))),
        _ => ScenarioSpec::new("gtrace")
            .with("window_s", &format!("{}", 60 + r.below(40)))
            .with("users", &format!("{}", 5 + r.below(4)))
            .with("heavy_users", "2")
            .with("cores", "8"),
    }
}

/// Uniform-cost micro-job scenarios only — the bounded-gap theorem's
/// assumptions (no skewed cost profiles, strict chains).
fn random_micro_spec(r: &mut Rng) -> ScenarioSpec {
    let mut spec = random_spec(r);
    while !matches!(spec.name.as_str(), "scenario1" | "scenario2" | "bursty" | "diurnal") {
        spec = random_spec(r);
    }
    spec
}

#[test]
fn completions_match_arrivals_and_reports_are_byte_identical() {
    propkit::check("completions + determinism", 0x1A7E5, 6, |r| {
        let spec = random_spec(r);
        let seed = r.next_u64();
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        if w.jobs.is_empty() {
            return Err(format!("{spec:?}: degenerate empty workload"));
        }
        for policy in PolicyKind::ALL {
            let cfg = Config::default().with_cores(8).with_policy(policy);
            let a = sim::simulate(cfg.clone(), w.jobs.clone());
            if a.completed.len() != w.jobs.len() {
                return Err(format!(
                    "{}: {} of {} jobs completed ({spec:?})",
                    policy.name(),
                    a.completed.len(),
                    w.jobs.len()
                ));
            }
            let b = sim::simulate(cfg, w.jobs.clone());
            if fingerprint(&a) != fingerprint(&b) {
                return Err(format!(
                    "{}: repeated run not byte-identical ({spec:?})",
                    policy.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn no_idle_core_while_a_leaf_stage_waits() {
    propkit::check("work conservation", 0xC0A5E2, 5, |r| {
        let spec = random_spec(r);
        let seed = r.next_u64();
        let policy = PolicyKind::ALL[r.below(PolicyKind::ALL.len() as u64) as usize];
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        let mut cfg = Config::default().with_cores(8).with_policy(policy);
        cfg.log_tasks = true;
        let rep = sim::simulate(cfg.clone(), w.jobs.clone());

        // Busy intervals per core (the engine never overlaps tasks on a
        // core; keep them sorted by start).
        let mut by_core: HashMap<usize, Vec<(TimeUs, TimeUs)>> = HashMap::new();
        for t in &rep.task_log {
            by_core.entry(t.core).or_default().push((t.started, t.finished));
        }
        for spans in by_core.values_mut() {
            spans.sort_unstable();
        }
        // First task start per job.
        let mut first_start: HashMap<u64, TimeUs> = HashMap::new();
        for t in &rep.task_log {
            let e = first_start.entry(t.job).or_insert(t.started);
            *e = (*e).min(t.started);
        }

        // A core is busy throughout [lo, hi) iff its sorted spans cover
        // the window without a positive-length gap.
        let covers = |spans: &[(TimeUs, TimeUs)], lo: TimeUs, hi: TimeUs| -> bool {
            let mut at = lo;
            for &(s, f) in spans {
                if f <= at {
                    continue;
                }
                if s > at {
                    return false; // gap [at, s) inside the window
                }
                at = f;
                if at >= hi {
                    return true;
                }
            }
            at >= hi
        };
        for c in &rep.completed {
            let s = *first_start
                .get(&c.job)
                .ok_or_else(|| format!("job {} has no tasks", c.job))?;
            if s <= c.submit {
                continue; // launched at arrival — nothing to check
            }
            for core in 0..cfg.cores as usize {
                let empty = Vec::new();
                let spans = by_core.get(&core).unwrap_or(&empty);
                if !covers(spans, c.submit, s) {
                    return Err(format!(
                        "{}: core {core} idle in [{}, {}) while job {} waited \
                         for its first launch ({spec:?})",
                        policy.name(),
                        c.submit,
                        s,
                        c.job
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn two_level_virtual_time_never_regresses() {
    propkit::check("vtime monotone", 0x57EAD, 8, |r| {
        let r_total = (2 + r.below(31)) as f64;
        let grace = r.range_f64(0.0, 4.0);
        let mut vt = TwoLevelVtime::new(r_total);
        let mut t = 0.0f64;
        let mut last_v = vt.v_global;
        for job in 0..(10 + r.below(20)) {
            t += r.exp(1.0);
            let user = 1 + r.below(4) as u32;
            if r.f64() < 0.4 {
                // Interleave plain updates between arrivals.
                vt.update_virtual_time(t);
                if vt.v_global < last_v {
                    return Err(format!("update moved V_global back at t={t}"));
                }
                last_v = vt.v_global;
                t += r.exp(2.0);
            }
            vt.job_arrival(t, user, job, 0.2 + r.f64() * 5.0, 1.0, grace);
            if vt.v_global < last_v {
                return Err(format!("arrival moved V_global back at t={t}"));
            }
            last_v = vt.v_global;
        }
        // Long quiet drain: virtual time keeps advancing monotonically.
        for _ in 0..10 {
            t += r.exp(0.2);
            vt.update_virtual_time(t);
            if vt.v_global < last_v {
                return Err(format!("drain moved V_global back at t={t}"));
            }
            last_v = vt.v_global;
        }
        Ok(())
    });
}

#[test]
fn uwfq_within_bounded_gap_of_ujf_on_random_workloads() {
    // Theorem A.4 (`scheduler_bounds.rs`) generalized to random registry
    // workloads: F_i − f_i ≤ L_max/R + 2·l_max, with the same slack the
    // fixed-fixture test uses for the practical-UJF approximation.
    propkit::check("UWFQ bounded by UJF (registry)", 0xB0B5, 5, |r| {
        let spec = random_micro_spec(r);
        let seed = r.next_u64();
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        let cores = 8u32;
        let mut cfg = Config::default().with_cores(cores);
        cfg.task_overhead = 0.0;
        cfg.log_tasks = true;
        let uwfq = sim::simulate(cfg.clone().with_policy(PolicyKind::Uwfq), w.jobs.clone());
        let ujf = sim::simulate(cfg.clone().with_policy(PolicyKind::Ujf), w.jobs.clone());

        let l_max_job: f64 = w.jobs.iter().map(|j| j.slot_time()).fold(0.0, f64::max);
        let task_max: f64 = uwfq
            .task_log
            .iter()
            .map(|t| uwfq::us_to_s(t.finished - t.started))
            .fold(0.0, f64::max)
            .max(l_max_job / cores as f64);
        let bound = l_max_job / cores as f64 + 2.0 * task_max;

        for cu in &uwfq.completed {
            let cj = ujf
                .completed
                .iter()
                .find(|c| c.job == cu.job)
                .ok_or_else(|| format!("job {} missing under UJF", cu.job))?;
            let delay = cu.response_time() - cj.response_time();
            if delay > bound * 1.5 + 1.0 {
                return Err(format!(
                    "job {} delayed {delay:.2}s past UJF, bound {bound:.2}s ({spec:?})",
                    cu.job
                ));
            }
        }
        Ok(())
    });
}

/// A random fault config mixing the three failure classes, each armed
/// independently (so single-class and combined regimes both get
/// exercised). Rates are kept high enough to actually fire on the small
/// property workloads.
fn random_fault(r: &mut Rng) -> FaultConfig {
    let mut f = FaultConfig::default();
    if r.f64() < 0.7 {
        f.task_fail_prob = r.range_f64(0.05, 0.35);
        f.retry_backoff_s = r.range_f64(0.01, 0.5);
        f.max_failures = 1 + r.below(4) as u32;
    }
    if r.f64() < 0.5 {
        f.straggler_prob = r.range_f64(0.05, 0.25);
        f.straggler_mult = r.range_f64(3.0, 8.0);
        f.spec_mult = r.range_f64(1.5, 3.0);
    }
    if r.f64() < 0.4 {
        f.crash_mttf_s = r.range_f64(15.0, 90.0);
        f.crash_recover_s = r.range_f64(0.5, 10.0);
    }
    f.seed = r.next_u64();
    f
}

#[test]
fn faults_lose_no_jobs_and_repeat_byte_identically() {
    // Invariant 6a/6b: with a random fault mix active, every policy still
    // completes exactly the arrived jobs (retry budgets are finite, so a
    // task that exhausts its failures succeeds on the final attempt), and
    // a fixed fault seed reproduces the full report — completed jobs AND
    // the fault ledger — bit for bit.
    propkit::check("fault completions + determinism", 0xFA17B, 5, |r| {
        let spec = random_spec(r);
        let seed = r.next_u64();
        let fault = random_fault(r);
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        if w.jobs.is_empty() {
            return Err(format!("{spec:?}: degenerate empty workload"));
        }
        for policy in PolicyKind::ALL {
            let mut cfg = Config::default().with_cores(8).with_policy(policy);
            cfg.fault = fault.clone();
            let a = sim::simulate(cfg.clone(), w.jobs.clone());
            if a.completed.len() != w.jobs.len() {
                return Err(format!(
                    "{}: {} of {} jobs completed under faults ({spec:?}, {fault:?})",
                    policy.name(),
                    a.completed.len(),
                    w.jobs.len()
                ));
            }
            if a.fault.retries != a.fault.failures {
                return Err(format!(
                    "{}: {} retries for {} failures — a failed attempt was \
                     dropped or double-requeued ({spec:?}, {fault:?})",
                    policy.name(),
                    a.fault.retries,
                    a.fault.failures
                ));
            }
            let b = sim::simulate(cfg, w.jobs.clone());
            if fingerprint(&a) != fingerprint(&b) {
                return Err(format!(
                    "{}: repeated faulty run not byte-identical ({spec:?}, {fault:?})",
                    policy.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn event_core_backends_produce_byte_identical_schedules() {
    // Invariant 7: the optimized event core (calendar queue + same-t
    // batching) is schedule-preserving. For random registry scenarios,
    // every policy, fault-free and under a random fault mix, every cell
    // of the (backend × batching) matrix must fingerprint identically to
    // the binary-heap per-event reference — completion order and times,
    // utilization bit pattern, and the full fault ledger.
    propkit::check("event-core differential", 0xE5C0DE, 5, |r| {
        let spec = random_spec(r);
        let seed = r.next_u64();
        let faulty = r.f64() < 0.6;
        let fault = if faulty { random_fault(r) } else { FaultConfig::default() };
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        if w.jobs.is_empty() {
            return Err(format!("{spec:?}: degenerate empty workload"));
        }
        let cells = [
            (EventBackend::Heap, true),
            (EventBackend::Wheel, false),
            (EventBackend::Wheel, true),
        ];
        for policy in PolicyKind::ALL {
            let mut cfg = Config::default().with_cores(8).with_policy(policy);
            cfg.log_tasks = true;
            cfg.fault = fault.clone();
            let reference = sim::simulate_opts(
                cfg.clone(),
                w.jobs.clone(),
                SimOpts { backend: EventBackend::Heap, batch: false },
            );
            let want = fingerprint(&reference);
            for (backend, batch) in cells {
                let got =
                    sim::simulate_opts(cfg.clone(), w.jobs.clone(), SimOpts { backend, batch });
                if fingerprint(&got) != want {
                    return Err(format!(
                        "{}: {backend:?} batch={batch} diverged from heap per-event \
                         reference ({spec:?}, faulty={faulty})",
                        policy.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_runs_lose_no_jobs_and_conserve_work_per_shard() {
    // Invariant 8: the sharded engine at random shard counts. The merged
    // run completes exactly the arrived jobs under every policy; each
    // shard's completions all hash to that shard; and per-shard work
    // conservation holds in ledger form — busy core-time never exceeds
    // the shard's cores × its own makespan (utilization ≤ 1), and a
    // shard that completed jobs accumulated busy time.
    propkit::check("sharded completions + per-shard conservation", 0x5A4DE, 5, |r| {
        let spec = random_spec(r);
        let seed = r.next_u64();
        let policy = PolicyKind::ALL[r.below(PolicyKind::ALL.len() as u64) as usize];
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        if w.jobs.is_empty() {
            return Err(format!("{spec:?}: degenerate empty workload"));
        }
        let shards = 2 + r.below(3) as u32; // 2..=4
        let mut cfg = Config::default().with_cores(8).with_policy(policy);
        cfg.shards = shards;
        cfg.shard_epoch_s = r.range_f64(1.0, 4.0);
        if r.f64() < 0.3 {
            cfg.fault = random_fault(r);
        }
        let run = sim::run_sharded(
            &cfg,
            SimOpts::default(),
            |_| w.to_stream(),
            |_| sim::CollectSink::default(),
        );
        if run.summary.jobs_completed as usize != w.jobs.len() {
            return Err(format!(
                "{}: {} of {} jobs completed at S={shards} ({spec:?})",
                policy.name(),
                run.summary.jobs_completed,
                w.jobs.len()
            ));
        }
        let per_shard_total: u64 = run
            .per_shard
            .iter()
            .map(|p| p.summary.jobs_completed)
            .sum();
        if per_shard_total != run.summary.jobs_completed {
            return Err(format!(
                "{}: per-shard counts sum to {per_shard_total}, merged says {} ({spec:?})",
                policy.name(),
                run.summary.jobs_completed
            ));
        }
        for (s, p) in run.per_shard.iter().enumerate() {
            // Ledger-form work conservation: a shard cannot be busier
            // than cores × wall time (1 µs slack per core for the final
            // event's rounding).
            let cap = p.cores as u128 * uwfq::s_to_us(p.summary.makespan_s) as u128
                + p.cores as u128;
            if p.summary.busy_core_us > cap {
                return Err(format!(
                    "{}: shard {s} busy {} µs exceeds {} cores × makespan ({spec:?})",
                    policy.name(),
                    p.summary.busy_core_us,
                    p.cores
                ));
            }
            if p.summary.jobs_completed > 0 && p.summary.busy_core_us == 0 {
                return Err(format!(
                    "{}: shard {s} completed {} jobs with zero busy time ({spec:?})",
                    policy.name(),
                    p.summary.jobs_completed
                ));
            }
            for c in &run.sinks[s].completed {
                let want = sim::shard_of(c.user, shards);
                if want != s as u32 {
                    return Err(format!(
                        "{}: user {} completed in shard {s}, hashes to {want} ({spec:?})",
                        policy.name(),
                        c.user
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_rebalance_conserves_jobs_and_cores_on_skewed_streams() {
    // Invariant 9: deterministic cross-shard core lending. Lending moves
    // integer cores between shards at epoch barriers but conserves their
    // total, so the cluster-wide busy ledger stays under cores ×
    // makespan, the drift bound is the same `cores × shard_epoch_s`, no
    // job is lost, and a repeat run is bit-for-bit identical.
    propkit::check("sharded rebalance conservation", 0x1E4D5, 5, |r| {
        let p = SkewedParams {
            users: 20 + r.below(40) as u32,
            jobs: 300 + r.below(500),
            zipf_s: r.range_f64(1.0, 1.8),
            hot_users: 3 + r.below(6) as u32,
            cores: 8,
            target_utilization: r.range_f64(0.5, 0.9),
            skew_fraction: 0.2,
        };
        let seed = r.next_u64();
        let shards = 2 + r.below(3) as u32; // 2..=4
        let epoch_s = r.range_f64(0.5, 3.0);
        // Floor × shards must fit in the cluster (8 cores, ≤ 4 shards).
        let min_cores = 1 + r.below(2) as u32; // 1..=2
        let cap = 1 + r.below(3) as u32; // 1..=3
        let sink_fp = |sinks: &[sim::CollectSink]| -> Vec<(u64, u32, u64, u64, u64)> {
            sinks
                .iter()
                .flat_map(|s| {
                    s.completed
                        .iter()
                        .map(|c| (c.job, c.user, c.submit, c.finish, c.slot_time.to_bits()))
                })
                .collect()
        };
        for policy in PolicyKind::ALL {
            let mut cfg = Config::default().with_cores(8).with_policy(policy);
            cfg.shards = shards;
            cfg.shard_epoch_s = epoch_s;
            cfg.shard_rebalance = true;
            cfg.rebalance_min_cores = min_cores;
            cfg.rebalance_cap = cap;
            let go = || {
                sim::run_sharded(
                    &cfg,
                    SimOpts::default(),
                    |_| skewed(seed, &p).expect("skewed property params are valid"),
                    |_| sim::CollectSink::default(),
                )
            };
            let (a, b) = (go(), go());
            if a.summary.jobs_completed != p.jobs {
                return Err(format!(
                    "{}: {} of {} jobs completed with lending at S={shards} ({p:?})",
                    policy.name(),
                    a.summary.jobs_completed,
                    p.jobs
                ));
            }
            if a.sync.max_drift_rsec > a.sync.bound_rsec + 1e-9 {
                return Err(format!(
                    "{}: drift {} exceeds bound {} with lending at S={shards}, \
                     epoch {epoch_s} ({p:?})",
                    policy.name(),
                    a.sync.max_drift_rsec,
                    a.sync.bound_rsec
                ));
            }
            // Core conservation in ledger form: lending never mints
            // cores, so total busy time fits under the cluster envelope
            // (1 µs rounding slack per core).
            let envelope = cfg.cores as u128 * uwfq::s_to_us(a.summary.makespan_s) as u128
                + cfg.cores as u128;
            if a.summary.busy_core_us > envelope {
                return Err(format!(
                    "{}: busy {} µs exceeds {} cores × makespan with lending \
                     at S={shards} ({p:?})",
                    policy.name(),
                    a.summary.busy_core_us,
                    cfg.cores
                ));
            }
            if a.sync.lend_events != b.sync.lend_events
                || a.summary.makespan_s.to_bits() != b.summary.makespan_s.to_bits()
                || sink_fp(&a.sinks) != sink_fp(&b.sinks)
            {
                return Err(format!(
                    "{}: lending repeat not byte-identical at S={shards} ({p:?})",
                    policy.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn multi_resource_ledgers_bounded_and_deterministic() {
    // Invariant 10: resource-vector accounting. A random registry
    // scenario — with a random memory fraction layered onto `bursty`,
    // the demand-capable stress entry — runs under all seven policies on
    // an engine whose per-dimension ledgers stay readable afterwards.
    propkit::check("multi-resource ledgers", 0xD4F5, 5, |r| {
        let mut spec = random_spec(r);
        if spec.name == "bursty" && r.f64() < 0.7 {
            spec = spec.with("mem_frac", &format!("{:.2}", r.range_f64(0.2, 0.9)));
        }
        let seed = r.next_u64();
        let burst_rsec = r.range_f64(0.5, 30.0);
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        if w.jobs.is_empty() {
            return Err(format!("{spec:?}: degenerate empty workload"));
        }
        let unit = w
            .jobs
            .iter()
            .all(|j| j.stages.iter().all(|s| s.demand.is_unit()));
        for policy in PolicyKind::ALL {
            let mut cfg = Config::default().with_cores(8).with_policy(policy);
            cfg.bopf_burst_rsec = burst_rsec;
            let mut core = uwfq::core::SchedCore::from_config(cfg.clone());
            let a = sim::simulate_into(&mut core, w.jobs.clone());
            if a.completed.len() != w.jobs.len() {
                return Err(format!(
                    "{}: {} of {} jobs completed ({spec:?})",
                    policy.name(),
                    a.completed.len(),
                    w.jobs.len()
                ));
            }
            // No over-commit in either dimension: a unit core-slot
            // carries at most 1000 milli-demand per µs, so each ledger
            // is bounded by cores × 1000 × makespan (1 µs slack per core
            // for the final event's rounding).
            let busy = core.resource_busy_mmus();
            let cap = cfg.cores as u128 * 1000 * uwfq::s_to_us(a.makespan_s) as u128
                + cfg.cores as u128 * 1000;
            for (dim, &b) in busy.iter().enumerate() {
                if b > cap {
                    return Err(format!(
                        "{}: dimension {dim} busy {b} mmus exceeds cores × makespan \
                         {cap} ({spec:?})",
                        policy.name()
                    ));
                }
            }
            if unit && busy[0] != busy[1] {
                return Err(format!(
                    "{}: unit-demand workload split the ledgers ({} vs {} mmus, \
                     {spec:?})",
                    policy.name(),
                    busy[0],
                    busy[1]
                ));
            }
            if !unit && busy[0] == 0 && busy[1] == 0 {
                return Err(format!("{}: no work ledgered ({spec:?})", policy.name()));
            }
            let mut core2 = uwfq::core::SchedCore::from_config(cfg.clone());
            let b2 = sim::simulate_into(&mut core2, w.jobs.clone());
            if fingerprint(&a) != fingerprint(&b2)
                || busy != core2.resource_busy_mmus()
                || core.resource_good_mmus() != core2.resource_good_mmus()
            {
                return Err(format!(
                    "{}: repeated run (ledgers included) not byte-identical ({spec:?})",
                    policy.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn faulty_work_conservation_modulo_blacklist_windows() {
    // Invariant 6c: work conservation under faults. While a job waits for
    // its first launch its leaf stage holds never-launched tasks (virgin,
    // so never in retry backoff) — any core that is free and *in service*
    // must take one. A core is excused exactly for its recorded
    // crash/blacklist windows; the task log (which includes failed,
    // killed and crash-lost attempts) must cover the rest.
    propkit::check("fault work conservation", 0xFA17C, 5, |r| {
        let spec = random_spec(r);
        let seed = r.next_u64();
        let fault = random_fault(r);
        let policy = PolicyKind::ALL[r.below(PolicyKind::ALL.len() as u64) as usize];
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        let mut cfg = Config::default().with_cores(8).with_policy(policy);
        cfg.log_tasks = true;
        cfg.fault = fault.clone();
        let rep = sim::simulate(cfg.clone(), w.jobs.clone());

        // Busy intervals per core: every attempt's span plus the core's
        // blacklist windows (during which it is excused from service).
        let mut by_core: HashMap<usize, Vec<(TimeUs, TimeUs)>> = HashMap::new();
        for t in &rep.task_log {
            by_core.entry(t.core).or_default().push((t.started, t.finished));
        }
        for &(core, down, up) in &rep.fault.crash_windows {
            by_core.entry(core).or_default().push((down, up));
        }
        for spans in by_core.values_mut() {
            spans.sort_unstable();
        }
        let mut first_start: HashMap<u64, TimeUs> = HashMap::new();
        for t in &rep.task_log {
            let e = first_start.entry(t.job).or_insert(t.started);
            *e = (*e).min(t.started);
        }
        let covers = |spans: &[(TimeUs, TimeUs)], lo: TimeUs, hi: TimeUs| -> bool {
            let mut at = lo;
            for &(s, f) in spans {
                if f <= at {
                    continue;
                }
                if s > at {
                    return false;
                }
                at = f;
                if at >= hi {
                    return true;
                }
            }
            at >= hi
        };
        for c in &rep.completed {
            let s = *first_start
                .get(&c.job)
                .ok_or_else(|| format!("job {} has no tasks", c.job))?;
            if s <= c.submit {
                continue;
            }
            for core in 0..cfg.cores as usize {
                let empty = Vec::new();
                let spans = by_core.get(&core).unwrap_or(&empty);
                if !covers(spans, c.submit, s) {
                    return Err(format!(
                        "{}: core {core} idle and in service in [{}, {}) while \
                         job {} waited for its first launch ({spec:?}, {fault:?})",
                        policy.name(),
                        c.submit,
                        s,
                        c.job
                    ));
                }
            }
        }
        Ok(())
    });
}
