//! Reproduction shape assertions: the qualitative claims of the paper's
//! evaluation must hold in our reproduction (absolute numbers are
//! testbed-dependent; orderings and rough factors are not).
//!
//! Claims checked (DESIGN.md §4 "Expected result shape"):
//!  1. Scenario 1: UWFQ best avg RT; UWFQ/UJF give infrequent users far
//!     lower RT than Fair; UWFQ fewest violations.
//!  2. Scenario 2: UWFQ best avg RT; CFQ worst (stage interleaving).
//!  3. Macro: -P cuts small-job RT massively for CFQ/UWFQ; UWFQ DVR < CFQ
//!     (default partitioning).
//!  4. Figs. 3/4: runtime partitioning fixes skew and priority inversion.

use uwfq::bench::{figures, tables};
use uwfq::config::Config;
use uwfq::sweep::Sweep;
use uwfq::workload::ScenarioSpec;

fn base() -> Config {
    Config::default() // 32 cores, paper testbed
}

fn row<'a>(rows: &'a [tables::Table1Row], label: &str) -> &'a tables::Table1Row {
    rows.iter().find(|r| r.label == label).unwrap()
}

#[test]
fn scenario1_shape_claims() {
    let (s1, _) = tables::table1(42, &base(), &Sweep::seq());
    let fair = row(&s1.rows, "Fair");
    let ujf = row(&s1.rows, "UJF");
    let cfq = row(&s1.rows, "CFQ");
    let uwfq = row(&s1.rows, "UWFQ");

    // UWFQ has the best average response time.
    for other in [fair, ujf, cfq] {
        assert!(
            uwfq.rt_avg <= other.rt_avg * 1.02,
            "UWFQ avg RT {} vs {} {}",
            uwfq.rt_avg,
            other.label,
            other.rt_avg
        );
    }
    // User context: infrequent users do far better under UWFQ/UJF than
    // under Fair (paper: −89% UWFQ vs Fair).
    let infreq = |r: &tables::Table1Row| r.class_rt.unwrap().1;
    assert!(
        infreq(uwfq) < 0.5 * infreq(fair),
        "UWFQ infreq {} vs Fair {}",
        infreq(uwfq),
        infreq(fair)
    );
    assert!(infreq(ujf) < 0.5 * infreq(fair));
    // CFQ (no user context) is clearly worse than UWFQ for infrequent
    // users (paper: >7×; we require ≥1.5×).
    assert!(infreq(cfq) > 1.5 * infreq(uwfq));
    // UWFQ has the fewest deadline violations.
    let viol = |r: &tables::Table1Row| r.fairness.as_ref().unwrap().violations;
    assert!(viol(uwfq) <= viol(fair));
    assert!(viol(uwfq) <= viol(cfq));
}

#[test]
fn scenario2_shape_claims() {
    let (_, s2) = tables::table1(42, &base(), &Sweep::seq());
    let fair = row(&s2.rows, "Fair");
    let ujf = row(&s2.rows, "UJF");
    let cfq = row(&s2.rows, "CFQ");
    let uwfq = row(&s2.rows, "UWFQ");

    // UWFQ best; CFQ worst (job-context claim, §5.2.2).
    for other in [fair, ujf, cfq] {
        assert!(uwfq.rt_avg < other.rt_avg, "UWFQ not best");
    }
    for other in [fair, ujf, uwfq] {
        assert!(cfq.rt_avg > other.rt_avg * 0.99, "CFQ not worst");
    }
    // First-arriving user beats last under UWFQ (and UJF), as in Table 1.
    let (first, last) = uwfq.first_last_rt.unwrap();
    assert!(first < last);
}

#[test]
fn macro_shape_claims() {
    // A reduced macro workload keeps this test fast while preserving the
    // heavy-user / ≥100% utilization structure.
    let w = ScenarioSpec::new("gtrace")
        .with("window_s", "150")
        .with("users", "12")
        .with("heavy_users", "3")
        .workload(42)
        .unwrap();
    let t2 = tables::table2(&w, &base(), &Sweep::seq());
    let get = |label: &str| t2.rows.iter().find(|r| r.label == label).unwrap();

    // Runtime partitioning massively improves small-job RT for the
    // deadline schedulers (paper: −74% UWFQ-P vs UJF-P on 0-80%).
    let uwfq_p = get("UWFQ-P");
    let ujf_p = get("UJF-P");
    assert!(
        uwfq_p.rt_0_80 < 0.6 * ujf_p.rt_0_80,
        "UWFQ-P 0-80% {} vs UJF-P {}",
        uwfq_p.rt_0_80,
        ujf_p.rt_0_80
    );
    // CFQ/UWFQ beat Fair/UJF on average RT with -P.
    assert!(uwfq_p.rt_avg < get("Fair-P").rt_avg);
    assert!(get("CFQ-P").rt_avg < get("Fair-P").rt_avg);
    // Long jobs (95-100%) do not improve as much as small jobs under the
    // deadline schedulers — the paper's long-tail trade-off.
    let small_gain = ujf_p.rt_0_80 / uwfq_p.rt_0_80;
    let tail_gain = ujf_p.rt_95_100 / uwfq_p.rt_95_100.max(1e-9);
    assert!(small_gain > tail_gain, "small {small_gain} vs tail {tail_gain}");
}

#[test]
fn fig3_fig4_partitioning_claims() {
    let f3 = figures::fig3(&base(), &Sweep::seq());
    assert!(
        f3.runs[1].1 < 0.6 * f3.runs[0].1,
        "runtime partitioning must cut the skewed job's completion: {} vs {}",
        f3.runs[1].1,
        f3.runs[0].1
    );
    let f4 = figures::fig4(&base(), &Sweep::seq());
    let (default_hi, runtime_hi) = (f4.runs[0].1, f4.runs[1].1);
    assert!(
        runtime_hi < 0.7 * default_hi,
        "runtime partitioning must fix the inversion: {runtime_hi} vs {default_hi}"
    );
}

#[test]
fn fig5_fig6_cdf_claims() {
    // Fig. 5: UWFQ's infrequent-user CDF dominates Fair's (more mass at
    // low response times).
    let series = figures::fig5(42, &base(), &Sweep::seq());
    let get = |name: &str| series.iter().find(|s| s.label == name).unwrap();
    let (uwfq, fair) = (get("UWFQ"), get("Fair"));
    let probe = fair.points[fair.points.len() / 2].0; // Fair's median RT
    assert!(
        uwfq.at(probe) >= fair.at(probe),
        "UWFQ CDF must dominate Fair at Fair's median"
    );

    // Fig. 6: UWFQ completes jobs gradually; CFQ finishes late (at 60% of
    // CFQ's final completion time, UWFQ has finished more jobs).
    let series6 = figures::fig6(42, &base(), &Sweep::seq());
    let get6 = |name: &str| series6.iter().find(|s| s.label == name).unwrap();
    let (uwfq6, cfq6) = (get6("UWFQ"), get6("CFQ"));
    let t60 = cfq6.points.last().unwrap().0 * 0.6;
    assert!(
        uwfq6.at(t60) > cfq6.at(t60),
        "UWFQ {} vs CFQ {} completed by t={t60:.1}",
        uwfq6.at(t60),
        cfq6.at(t60)
    );
}
