//! Grid-level differential test: a full Table-1 + Table-2 + Fig-5/6/7
//! sweep at `--threads N` (default 4, `UWFQ_SWEEP_THREADS` overrides —
//! CI runs a {1, 4} matrix) must produce **byte-identical** rendered
//! tables and CSV files to the sequential (1-thread) reference.
//!
//! This extends PR 1's incremental-vs-scan equivalence discipline from
//! the single-simulation level to the grid level: the sweep engine may
//! reorder cell *execution* arbitrarily across workers, but never cell
//! *results*.

use std::path::PathBuf;

use uwfq::bench::{figures, tables};
use uwfq::config::Config;
use uwfq::sweep::Sweep;
use uwfq::workload::{ScenarioSpec, Workload};

fn par_sweep() -> Sweep {
    let threads = std::env::var("UWFQ_SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(4);
    Sweep::new(threads)
}

fn base() -> Config {
    Config::default().with_cores(8)
}

/// A scaled-down (but structurally complete) macro workload so the full
/// 16-cell Table-2 + Fig-7 grid stays test-fast.
fn macro_workload() -> Workload {
    ScenarioSpec::new("gtrace")
        .with("window_s", "90")
        .with("users", "8")
        .with("heavy_users", "2")
        .with("cores", "8")
        .workload(11)
        .unwrap()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("uwfq_sweep_diff_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn read(dir: &PathBuf, name: &str) -> Vec<u8> {
    std::fs::read(dir.join(name)).unwrap_or_else(|e| panic!("{name}: {e}"))
}

#[test]
fn table1_sweep_is_byte_identical() {
    let seq = table_outputs(&Sweep::seq(), "t1_seq");
    let par = table_outputs(&par_sweep(), "t1_par");
    assert_eq!(seq, par, "Table 1 parallel output diverged from sequential");
}

fn table_outputs(sweep: &Sweep, tag: &str) -> (String, String, Vec<u8>, Vec<u8>) {
    let (s1, s2) = tables::table1(3, &base(), sweep);
    let dir = tmp_dir(tag);
    tables::write_table1_csv(dir.join("t1s1.csv").to_str().unwrap(), &s1).unwrap();
    tables::write_table1_csv(dir.join("t1s2.csv").to_str().unwrap(), &s2).unwrap();
    let out = (
        tables::render_table1(&s1),
        tables::render_table1(&s2),
        read(&dir, "t1s1.csv"),
        read(&dir, "t1s2.csv"),
    );
    std::fs::remove_dir_all(dir).ok();
    out
}

#[test]
fn table2_and_fig7_sweep_is_byte_identical() {
    let w = macro_workload();
    let run = |sweep: &Sweep, tag: &str| -> (String, Vec<u8>, Vec<u8>) {
        let t2 = tables::table2(&w, &base(), sweep);
        let f7 = figures::fig7(&w, &base(), sweep);
        let dir = tmp_dir(tag);
        tables::write_table2_csv(dir.join("t2.csv").to_str().unwrap(), &t2).unwrap();
        figures::write_fig7_csv(dir.to_str().unwrap(), &f7).unwrap();
        let out = (
            tables::render_table2(&t2),
            read(&dir, "t2.csv"),
            read(&dir, "fig7_user_violations.csv"),
        );
        std::fs::remove_dir_all(dir).ok();
        out
    };
    let seq = run(&Sweep::seq(), "t2_seq");
    let par = run(&par_sweep(), "t2_par");
    assert_eq!(
        seq, par,
        "Table 2 / Fig 7 parallel output diverged from sequential"
    );
}

#[test]
fn cdf_figures_sweep_is_byte_identical() {
    let run = |sweep: &Sweep, tag: &str| -> (Vec<u8>, Vec<u8>) {
        let f5 = figures::fig5(3, &base(), sweep);
        let f6 = figures::fig6(3, &base(), sweep);
        let dir = tmp_dir(tag);
        figures::write_fig5_csv(dir.to_str().unwrap(), &f5).unwrap();
        figures::write_fig6_csv(dir.to_str().unwrap(), &f6).unwrap();
        let out = (
            read(&dir, "fig5_infrequent_cdf.csv"),
            read(&dir, "fig6_completion_cdf.csv"),
        );
        std::fs::remove_dir_all(dir).ok();
        out
    };
    let seq = run(&Sweep::seq(), "cdf_seq");
    let par = run(&par_sweep(), "cdf_par");
    assert_eq!(seq, par, "Fig 5/6 parallel output diverged from sequential");
}
