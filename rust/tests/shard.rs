//! Differential + property suite for the sharded engine
//! (`sim::run_sharded` — federated virtual time over hash-partitioned
//! users):
//!
//! 1. **S=1 byte-identity** — one shard is the unsharded engine: for
//!    every policy, fault-free and with a fault mix armed, the sharded
//!    runner's completions (every field, floats by bit pattern),
//!    makespan/utilization bits, and the full fault ledger match
//!    `simulate_stream_into_opts` exactly.
//! 2. **Deterministic repeats at S=4** — multi-shard runs are not equal
//!    to the unsharded schedule (disjoint user sets on disjoint cores,
//!    shard-local arrival sequences), but they must repeat bit-for-bit.
//! 3. **Drift bound (property)** — on randomized registry scenarios the
//!    observed pre-sync virtual-time spread never exceeds the provable
//!    `cores × shard_epoch_s` resource-seconds, no job is lost, and the
//!    hash partition is respected.
//! 4. **Rebalance-off identity** — with `shard_rebalance` off the
//!    lending knobs are inert: schedules, fault ledger and drift match
//!    the static split byte for byte for every policy.
//! 5. **Lending on a skewed stream** — cross-shard core lending loses no
//!    jobs, stays within the same drift bound, and repeats bit-for-bit.

use uwfq::config::Config;
use uwfq::core::SchedCore;
use uwfq::fault::FaultConfig;
use uwfq::sched::PolicyKind;
use uwfq::sim::{run_sharded, shard_cores, simulate_stream_into_opts, CollectSink, SimOpts};
use uwfq::util::{propkit, Rng};
use uwfq::workload::stress::{skewed, SkewedParams};
use uwfq::workload::{ScenarioSpec, Workload};

/// The fixture workload: multi-user, bursty enough that shards interleave.
fn fixture_workload(seed: u64) -> Workload {
    ScenarioSpec::new("gtrace")
        .with("window_s", "80")
        .with("users", "8")
        .with("heavy_users", "2")
        .with("cores", "8")
        .workload(seed)
        .expect("gtrace fixture")
}

fn fault_mix(seed: u64) -> FaultConfig {
    let mut f = FaultConfig::default();
    f.task_fail_prob = 0.1;
    f.retry_backoff_s = 0.05;
    f.max_failures = 3;
    f.straggler_prob = 0.1;
    f.straggler_mult = 4.0;
    f.spec_mult = 2.0;
    f.seed = seed;
    f
}

/// Byte-level completion fingerprint of a `CollectSink`.
fn sink_fingerprint(sink: &CollectSink) -> Vec<(u64, u32, String, u64, u64, u64)> {
    sink.completed
        .iter()
        .map(|c| {
            (
                c.job,
                c.user,
                c.name.to_string(),
                c.submit,
                c.finish,
                c.slot_time.to_bits(),
            )
        })
        .collect()
}

#[test]
fn one_shard_is_byte_identical_to_the_unsharded_engine_for_every_policy() {
    let w = fixture_workload(21);
    for faulty in [false, true] {
        for policy in PolicyKind::ALL {
            let mut cfg = Config::default().with_cores(8).with_policy(policy);
            if faulty {
                cfg.fault = fault_mix(77);
            }
            let mut core = SchedCore::from_config(cfg.clone());
            let mut want_sink = CollectSink::default();
            let want = simulate_stream_into_opts(
                &mut core,
                w.to_stream(),
                &mut want_sink,
                SimOpts::default(),
            );
            let run = run_sharded(
                &cfg,
                SimOpts::default(),
                |_| w.to_stream(),
                |_| CollectSink::default(),
            );
            let tag = format!("{} faulty={faulty}", policy.name());
            assert_eq!(run.per_shard.len(), 1, "{tag}");
            assert_eq!(run.sync.epochs, 0, "{tag}: S=1 must never sync");
            assert_eq!(run.summary.jobs_completed, want.jobs_completed, "{tag}");
            assert_eq!(run.summary.task_events, want.task_events, "{tag}");
            assert_eq!(
                run.summary.peak_in_flight_jobs, want.peak_in_flight_jobs,
                "{tag}"
            );
            assert_eq!(
                run.summary.makespan_s.to_bits(),
                want.makespan_s.to_bits(),
                "{tag}"
            );
            assert_eq!(
                run.summary.utilization.to_bits(),
                want.utilization.to_bits(),
                "{tag}"
            );
            assert_eq!(run.summary.busy_core_us, want.busy_core_us, "{tag}");
            assert_eq!(run.summary.fault, want.fault, "{tag}: fault ledger diverged");
            assert_eq!(
                sink_fingerprint(&run.sinks[0]),
                sink_fingerprint(&want_sink),
                "{tag}: completion schedule diverged"
            );
        }
    }
}

#[test]
fn four_shard_runs_repeat_bit_for_bit() {
    let w = fixture_workload(33);
    for faulty in [false, true] {
        for policy in PolicyKind::ALL {
            let mut cfg = Config::default().with_cores(8).with_policy(policy);
            cfg.shards = 4;
            cfg.shard_epoch_s = 1.0;
            if faulty {
                cfg.fault = fault_mix(5);
            }
            let go = || {
                run_sharded(
                    &cfg,
                    SimOpts::default(),
                    |_| w.to_stream(),
                    |_| CollectSink::default(),
                )
            };
            let (a, b) = (go(), go());
            let tag = format!("{} faulty={faulty}", policy.name());
            assert_eq!(
                a.summary.jobs_completed as usize,
                w.jobs.len(),
                "{tag}: jobs lost"
            );
            assert_eq!(a.summary.jobs_completed, b.summary.jobs_completed, "{tag}");
            assert_eq!(
                a.summary.makespan_s.to_bits(),
                b.summary.makespan_s.to_bits(),
                "{tag}"
            );
            assert_eq!(
                a.summary.utilization.to_bits(),
                b.summary.utilization.to_bits(),
                "{tag}"
            );
            assert_eq!(a.summary.fault, b.summary.fault, "{tag}: fault ledger");
            assert_eq!(a.sync.epochs, b.sync.epochs, "{tag}");
            assert_eq!(
                a.sync.max_drift_rsec.to_bits(),
                b.sync.max_drift_rsec.to_bits(),
                "{tag}"
            );
            for (s, (sa, sb)) in a.sinks.iter().zip(b.sinks.iter()).enumerate() {
                assert_eq!(
                    sink_fingerprint(sa),
                    sink_fingerprint(sb),
                    "{tag}: shard {s} schedule diverged between repeats"
                );
            }
        }
    }
}

#[test]
fn rebalance_off_leaves_the_static_split_byte_identical() {
    // The lending knobs must be inert while `shard_rebalance` is off:
    // the static-split schedule (completions, fault ledger, makespan
    // bits) from before lending existed cannot move, whatever values
    // `rebalance_min_cores` / `rebalance_cap` hold.
    let w = fixture_workload(47);
    for faulty in [false, true] {
        for policy in PolicyKind::ALL {
            let mut base = Config::default().with_cores(8).with_policy(policy);
            base.shards = 4;
            base.shard_epoch_s = 1.0;
            if faulty {
                base.fault = fault_mix(11);
            }
            let mut knobs = base.clone();
            knobs.shard_rebalance = false; // explicit off
            knobs.rebalance_min_cores = 2;
            knobs.rebalance_cap = 7;
            let go = |cfg: &Config| {
                run_sharded(
                    cfg,
                    SimOpts::default(),
                    |_| w.to_stream(),
                    |_| CollectSink::default(),
                )
            };
            let (a, b) = (go(&base), go(&knobs));
            let tag = format!("{} faulty={faulty}", policy.name());
            assert_eq!(a.sync.lend_events, 0, "{tag}: lending fired while off");
            assert_eq!(b.sync.lend_events, 0, "{tag}: lending fired while off");
            assert_eq!(a.summary.fault, b.summary.fault, "{tag}: fault ledger moved");
            assert_eq!(
                a.summary.makespan_s.to_bits(),
                b.summary.makespan_s.to_bits(),
                "{tag}"
            );
            assert_eq!(
                a.sync.max_drift_rsec.to_bits(),
                b.sync.max_drift_rsec.to_bits(),
                "{tag}"
            );
            for (s, (sa, sb)) in a.sinks.iter().zip(b.sinks.iter()).enumerate() {
                assert_eq!(
                    sink_fingerprint(sa),
                    sink_fingerprint(sb),
                    "{tag}: shard {s} schedule moved with lending knobs set"
                );
            }
        }
    }
}

#[test]
fn lending_on_a_skewed_stream_completes_within_bound_and_repeats() {
    // A hot Zipf head pins a subset of shards; with lending on, every
    // job still completes, the drift bound is the same provable
    // `cores × shard_epoch_s`, and repeats are bit-for-bit.
    let p = SkewedParams {
        users: 40,
        jobs: 800,
        hot_users: 8,
        cores: 8,
        ..SkewedParams::default()
    };
    for policy in PolicyKind::ALL {
        let mut cfg = Config::default().with_cores(8).with_policy(policy);
        cfg.shards = 4;
        cfg.shard_epoch_s = 1.0;
        cfg.shard_rebalance = true;
        cfg.rebalance_min_cores = 1;
        cfg.rebalance_cap = 2;
        let go = || {
            run_sharded(
                &cfg,
                SimOpts::default(),
                |_| skewed(13, &p).expect("skewed fixture"),
                |_| CollectSink::default(),
            )
        };
        let (a, b) = (go(), go());
        let tag = policy.name();
        assert_eq!(
            a.summary.jobs_completed, p.jobs,
            "{tag}: jobs lost under lending"
        );
        assert!(
            a.sync.max_drift_rsec <= a.sync.bound_rsec + 1e-9,
            "{tag}: drift {} exceeds bound {} with lending on",
            a.sync.max_drift_rsec,
            a.sync.bound_rsec
        );
        assert_eq!(a.sync.lend_events, b.sync.lend_events, "{tag}: lend events");
        assert_eq!(
            a.sync.max_drift_rsec.to_bits(),
            b.sync.max_drift_rsec.to_bits(),
            "{tag}"
        );
        assert_eq!(a.summary.fault, b.summary.fault, "{tag}: fault ledger");
        for (s, (sa, sb)) in a.sinks.iter().zip(b.sinks.iter()).enumerate() {
            assert_eq!(
                sink_fingerprint(sa),
                sink_fingerprint(sb),
                "{tag}: shard {s} diverged between lending repeats"
            );
        }
    }
}

/// A random small registry scenario (kept small so the debug-profile
/// property run stays fast; mirrors the invariant harness's generator).
fn random_spec(r: &mut Rng) -> ScenarioSpec {
    match r.below(4) {
        0 => ScenarioSpec::new("scenario2")
            .with("jobs_per_user", &format!("{}", 3 + r.below(5)))
            .with("stagger_s", &format!("{:.2}", r.range_f64(0.0, 2.0))),
        1 => ScenarioSpec::new("bursty")
            .with("users", &format!("{}", 3 + r.below(3)))
            .with("steady_users", &format!("{}", 1 + r.below(2)))
            .with("duration_s", &format!("{}", 60 + r.below(60)))
            .with("cycle_s", "30")
            .with("burst_ratio", &format!("{:.2}", r.range_f64(0.1, 0.35)))
            .with("rate", &format!("{:.2}", r.range_f64(0.8, 2.0))),
        2 => ScenarioSpec::new("heavytail")
            .with("users", &format!("{}", 3 + r.below(3)))
            .with("jobs_per_user", &format!("{}", 6 + r.below(7)))
            .with("alpha", &format!("{:.2}", r.range_f64(1.2, 2.5)))
            .with("mean_gap_s", &format!("{:.1}", r.range_f64(2.0, 6.0))),
        _ => ScenarioSpec::new("gtrace")
            .with("window_s", &format!("{}", 60 + r.below(40)))
            .with("users", &format!("{}", 5 + r.below(4)))
            .with("heavy_users", "2")
            .with("cores", "8"),
    }
}

#[test]
fn drift_stays_within_the_provable_bound_on_random_registry_specs() {
    propkit::check("shard drift bound", 0x5AA8D, 6, |r| {
        let spec = random_spec(r);
        let seed = r.next_u64();
        let w = spec.workload(seed).map_err(|e| format!("{spec:?}: {e}"))?;
        if w.jobs.is_empty() {
            return Err(format!("{spec:?}: degenerate empty workload"));
        }
        let shards = 2 + r.below(3) as u32; // 2..=4
        let mut cfg = Config::default().with_cores(8).with_policy(PolicyKind::Uwfq);
        cfg.shards = shards;
        cfg.shard_epoch_s = r.range_f64(0.5, 4.0);
        if r.f64() < 0.4 {
            let mut f = fault_mix(r.next_u64());
            f.straggler_prob = 0.0; // keep property runs fast
            cfg.fault = f;
        }
        let run = run_sharded(
            &cfg,
            SimOpts::default(),
            |_| w.to_stream(),
            |_| CollectSink::default(),
        );
        if run.summary.jobs_completed as usize != w.jobs.len() {
            return Err(format!(
                "{} of {} jobs completed at S={shards} ({spec:?})",
                run.summary.jobs_completed,
                w.jobs.len()
            ));
        }
        if run.sync.max_drift_rsec > run.sync.bound_rsec + 1e-9 {
            return Err(format!(
                "drift {} exceeds bound {} at S={shards}, epoch {} ({spec:?})",
                run.sync.max_drift_rsec, run.sync.bound_rsec, cfg.shard_epoch_s
            ));
        }
        // Hash partition respected: every completion sits in the shard
        // its user hashes to, and the core split covers the cluster.
        let cores = shard_cores(cfg.cores, shards);
        if cores.iter().sum::<u32>() != cfg.cores {
            return Err("shard core split does not partition the cluster".into());
        }
        for (s, sink) in run.sinks.iter().enumerate() {
            for c in &sink.completed {
                let want = uwfq::sim::shard_of(c.user, shards);
                if want != s as u32 {
                    return Err(format!(
                        "user {} completed in shard {s}, hashes to {want} ({spec:?})",
                        c.user
                    ));
                }
            }
        }
        Ok(())
    });
}
