//! Helpers shared by the differential/property test suites (included
//! via `mod common;` — not a test binary of its own).

use uwfq::fault::FaultStats;
use uwfq::sim::SimReport;

/// Full byte-level fingerprint of a report: every completed-job field
/// (floats by bit pattern), the aggregate columns, and the complete
/// fault ledger (counters, goodput/waste integers, per-user split). One
/// definition of "byte-identical" for all differential suites — extend
/// it here when `SimReport` grows identity-bearing fields.
pub fn fingerprint(
    rep: &SimReport,
) -> (Vec<(u64, u32, String, u64, u64, u64)>, u64, u64, FaultStats) {
    (
        rep.completed
            .iter()
            .map(|c| {
                (
                    c.job,
                    c.user,
                    c.name.to_string(),
                    c.submit,
                    c.finish,
                    c.slot_time.to_bits(),
                )
            })
            .collect(),
        rep.makespan_s.to_bits(),
        rep.utilization.to_bits(),
        rep.fault.clone(),
    )
}
