//! Integration: AOT artifacts load, compile and execute on the PJRT CPU
//! client, and the numerics match the pure-Rust reference computation of
//! the same analytics (which itself mirrors python's ref.py oracle).
//!
//! Requires `make artifacts` (skips with a message if missing).

use std::path::Path;

use uwfq::data::{TripTable, BLOCK_COLS, BLOCK_ROWS};
use uwfq::runtime::ArtifactStore;

fn store() -> Option<ArtifactStore> {
    let dir = ArtifactStore::default_dir();
    if !Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ArtifactStore::load(&dir).expect("artifact store loads"))
}

/// Rust-side mirror of python/compile/kernels/ref.py (normalize + k-op
/// chain + [sum; sumsq]).
fn ref_compute(block: &[f32], k: u32) -> Vec<f32> {
    let (rows, cols) = (BLOCK_ROWS, BLOCK_COLS);
    // normalize per column
    let mut mean = vec![0f64; cols];
    let mut std = vec![0f64; cols];
    for r in 0..rows {
        for c in 0..cols {
            mean[c] += block[r * cols + c] as f64;
        }
    }
    mean.iter_mut().for_each(|m| *m /= rows as f64);
    for r in 0..rows {
        for c in 0..cols {
            let d = block[r * cols + c] as f64 - mean[c];
            std[c] += d * d;
        }
    }
    std.iter_mut().for_each(|s| *s = (*s / rows as f64).sqrt());
    // chain + aggregate
    let mut out = vec![0f64; 2 * cols];
    for r in 0..rows {
        for c in 0..cols {
            let c1 = 0.75 + 0.05 * c as f64;
            let c0 = 0.01 * (c as f64 - cols as f64 / 2.0);
            let mut y = (block[r * cols + c] as f64 - mean[c]) / (std[c] + 1e-6);
            for _ in 0..k {
                y = (y * c1 + c0).tanh();
            }
            out[c] += y;
            out[cols + c] += y * y;
        }
    }
    out.into_iter().map(|v| v as f32).collect()
}

#[test]
fn compute_artifact_matches_reference() {
    let Some(store) = store() else { return };
    let table = TripTable::new(123, 2);
    let block = table.block(0);
    for k in store.variants() {
        let got = store.run_compute_block(k, &block).unwrap();
        let want = ref_compute(&block, k);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let tol = 1e-2_f32.max(w.abs() * 1e-3);
            assert!(
                (g - w).abs() < tol,
                "k={k} idx={i}: got {g}, want {w}"
            );
        }
    }
}

#[test]
fn aggregate_artifact_folds_partials() {
    let Some(store) = store() else { return };
    let table = TripTable::new(7, 3);
    let cols = store.manifest.cols;
    let mut partials = Vec::new();
    let mut sum = vec![0f64; 2 * cols];
    for b in 0..3u64 {
        let p = store.run_compute_block(4, &table.block(b)).unwrap();
        for (i, v) in p.iter().enumerate() {
            sum[i] += *v as f64;
        }
        partials.push((p, BLOCK_ROWS as f32));
    }
    let out = store.run_aggregate(&partials).unwrap();
    let total = 3.0 * BLOCK_ROWS as f64;
    for c in 0..cols {
        let mean = sum[c] / total;
        let var = sum[cols + c] / total - mean * mean;
        assert!((out[c] as f64 - mean).abs() < 1e-3, "mean col {c}");
        assert!((out[cols + c] as f64 - var).abs() < 1e-3, "var col {c}");
    }
}

#[test]
fn aggregate_chunks_beyond_fanin() {
    let Some(store) = store() else { return };
    let cols = store.manifest.cols;
    let n = store.manifest.agg_fanin + 5; // forces chunked folding
    let partials: Vec<(Vec<f32>, f32)> = (0..n)
        .map(|i| {
            let mut p = vec![0f32; 2 * cols];
            for c in 0..cols {
                p[c] = (i + 1) as f32; // sum
                p[cols + c] = (i + 1) as f32 * 2.0; // sumsq
            }
            (p, 10.0)
        })
        .collect();
    let out = store.run_aggregate(&partials).unwrap();
    let total = 10.0 * n as f64;
    let sum: f64 = (1..=n).map(|i| i as f64).sum();
    let sumsq: f64 = 2.0 * sum;
    let mean = sum / total;
    let var = sumsq / total - mean * mean;
    for c in 0..cols {
        assert!((out[c] as f64 - mean).abs() < 1e-4, "mean col {c}: {}", out[c]);
        assert!(
            (out[cols + c] as f64 - var).abs() < 1e-3,
            "var col {c}: {}",
            out[cols + c]
        );
    }
}

#[test]
fn variants_match_manifest() {
    let Some(store) = store() else { return };
    assert_eq!(store.variants(), vec![1, 4, 16, 64]);
    assert_eq!(store.manifest.block_rows, BLOCK_ROWS);
    assert_eq!(store.manifest.cols, BLOCK_COLS);
    assert!(store.compute(3).is_err()); // only compiled variants
    assert_eq!(store.platform(), "cpu");
}

#[test]
fn rejects_wrong_block_size() {
    let Some(store) = store() else { return };
    assert!(store.run_compute_block(4, &[0.0; 8]).is_err());
    assert!(store.run_aggregate(&[]).is_err());
}
