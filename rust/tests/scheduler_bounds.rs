//! Property tests on the paper's theoretical guarantees (Appendix A).
//!
//! * Theorem A.3: jobs in 2-level virtual time finish no later than under
//!   the user-job fair (GPS) schedule — checked by comparing virtual
//!   deadlines against a brute-force fluid UJF simulation.
//! * Theorem A.4 / bounded UJF: in the discrete engine, every job's
//!   finish time under UWFQ is within `L_max/R + 2·l_max` (+ overheads)
//!   of its finish time under the practical UJF scheduler.
//! * Virtual-time invariants: monotonicity, deadline ordering == fluid
//!   GPS finish ordering.

use uwfq::config::Config;
use uwfq::core::job::JobSpec;
use uwfq::partition::SchemeKind;
use uwfq::sched::vtime::TwoLevelVtime;
use uwfq::sched::PolicyKind;
use uwfq::sim;
use uwfq::util::{propkit, Rng};

/// Brute-force fluid simulation of the user-job fair (UJF/GPS) system:
/// equal share per user, equal share per job within a user, infinitesimal
/// quanta. Returns per-job finish times.
fn fluid_ujf(r_total: f64, jobs: &[(u32, f64, f64)]) -> Vec<f64> {
    // jobs: (user, arrival, slot)
    let n = jobs.len();
    let mut remaining: Vec<f64> = jobs.iter().map(|j| j.2).collect();
    let mut finish = vec![f64::NAN; n];
    let mut t = 0.0;
    let dt = 1e-3;
    let mut done = 0;
    let mut guard = 0u64;
    while done < n {
        // active jobs per user
        let active: Vec<usize> = (0..n)
            .filter(|&i| jobs[i].1 <= t && finish[i].is_nan())
            .collect();
        if active.is_empty() {
            // jump to next arrival
            let next = jobs
                .iter()
                .enumerate()
                .filter(|(i, j)| finish[*i].is_nan() && j.1 > t)
                .map(|(_, j)| j.1)
                .fold(f64::INFINITY, f64::min);
            t = next;
            continue;
        }
        let mut users: Vec<u32> = active.iter().map(|&i| jobs[i].0).collect();
        users.sort();
        users.dedup();
        let r_user = r_total / users.len() as f64;
        for &i in &active {
            let n_jobs = active.iter().filter(|&&a| jobs[a].0 == jobs[i].0).count();
            let rate = r_user / n_jobs as f64;
            remaining[i] -= rate * dt;
            if remaining[i] <= 0.0 && finish[i].is_nan() {
                finish[i] = t + dt;
                done += 1;
            }
        }
        t += dt;
        guard += 1;
        assert!(guard < 40_000_000, "fluid sim diverged");
    }
    finish
}

/// Step a 2-level virtual-time system forward and record the real time at
/// which each job leaves the virtual system (its 2LV finish time `f_i`).
/// All arrivals must already be in `vt`... so instead we re-drive arrivals
/// interleaved with fine-grained updates.
fn two_level_finish_times(r_total: f64, jobs: &[(u32, f64, f64)]) -> Vec<f64> {
    let mut vt = TwoLevelVtime::new(r_total);
    let mut finish = vec![f64::NAN; jobs.len()];
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| jobs[a].1.partial_cmp(&jobs[b].1).unwrap());
    let horizon = jobs.iter().map(|j| j.1).fold(0.0, f64::max)
        + jobs.iter().map(|j| j.2).sum::<f64>() + 1.0;
    let mut active: std::collections::HashSet<u64> = std::collections::HashSet::new();
    let dt = 0.005;
    let mut t = 0.0;
    let mut next = 0;
    while t < horizon {
        while next < order.len() && jobs[order[next]].1 <= t {
            let i = order[next];
            vt.job_arrival(jobs[i].1, jobs[i].0, i as u64, jobs[i].2, 1.0, 0.0);
            active.insert(i as u64);
            next += 1;
        }
        vt.update_virtual_time(t);
        // Jobs no longer in any user's virtual job set have finished.
        let still: std::collections::HashSet<u64> = vt
            .users
            .values()
            .flat_map(|u| u.jobs.values().map(|j| j.job))
            .collect();
        active.retain(|&j| {
            if !still.contains(&j) {
                finish[j as usize] = t;
                false
            } else {
                true
            }
        });
        t += dt;
    }
    for (i, f) in finish.iter_mut().enumerate() {
        if f.is_nan() {
            // Should not happen within the horizon.
            *f = f64::INFINITY;
            let _ = i;
        }
    }
    finish
}

#[test]
fn theorem_a3_two_level_no_later_than_fluid_ujf() {
    // Theorem A.3: f_i ≤ f̂_i — every job finishes in the 2-level virtual
    // schedule no later than under user-job fair GPS.
    propkit::check("2LV ≤ fluid UJF", 0xA11CE, 20, |r| {
        let r_total = (1 + r.below(8)) as f64;
        let n_jobs = 2 + r.below(8) as usize;
        let mut jobs = Vec::new();
        let mut t = 0.0;
        for _ in 0..n_jobs {
            t += r.exp(1.0);
            jobs.push((r.below(3) as u32, t, 0.2 + r.f64() * 4.0));
        }
        let f2lv = two_level_finish_times(r_total, &jobs);
        let fluid = fluid_ujf(r_total, &jobs);
        for i in 0..n_jobs {
            // Discretization slack: fluid dt 1e-3, 2LV step 5e-3.
            if f2lv[i] > fluid[i] + 0.05 {
                return Err(format!(
                    "job {i} finishes at {} in 2LV but {} in fluid UJF \
                     (jobs {jobs:?}, R={r_total})",
                    f2lv[i], fluid[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn uwfq_bounded_by_ujf_in_discrete_engine() {
    // Theorem A.4: F_i − f_i ≤ L_max/R + 2·l_max. Our discrete engine adds
    // per-task overhead; we check the bound with overhead slack.
    propkit::check("UWFQ bounded by UJF", 0xB0B, 15, |r| {
        let cores = 4 + 4 * r.below(3) as u32; // 4, 8 or 12
        let mut cfg = Config::default()
            .with_cores(cores)
            .with_scheme(SchemeKind::Size);
        cfg.task_overhead = 0.0;
        let n_users = 1 + r.below(4) as u32;
        let mut jobs = Vec::new();
        let mut t = 0.0;
        for i in 0..(3 + r.below(10)) {
            t += r.exp(0.5);
            let user = 1 + r.below(n_users as u64) as u32;
            let compute = 1.0 + r.f64() * 30.0;
            jobs.push(JobSpec::three_phase(
                user,
                &format!("j{i}"),
                uwfq::s_to_us(t),
                compute,
                256 << 20,
                4,
                None,
            ));
        }
        let uwfq = sim::simulate(cfg.clone().with_policy(PolicyKind::Uwfq), jobs.clone());
        let ujf = sim::simulate(cfg.clone().with_policy(PolicyKind::Ujf), jobs.clone());

        // l_max: longest single task in the workload under this
        // partitioning; L_max: largest job slot time.
        let l_max_job: f64 = jobs.iter().map(|j| j.slot_time()).fold(0.0, f64::max);
        let task_max: f64 = uwfq
            .task_log
            .iter()
            .map(|t| uwfq::us_to_s(t.finished - t.started))
            .fold(0.0, f64::max)
            .max(
                jobs.iter()
                    .flat_map(|j| j.stages.iter())
                    .map(|s| s.slot_time / cores as f64)
                    .fold(0.0, f64::max),
            );
        let bound = l_max_job / cores as f64 + 2.0 * task_max.max(l_max_job / cores as f64);

        for cu in &uwfq.completed {
            let cj = ujf
                .completed
                .iter()
                .find(|c| c.job == cu.job)
                .expect("same jobs in both runs");
            let delay = cu.response_time() - cj.response_time();
            // Practical-UJF is itself an approximation of GPS; allow 50%
            // slack on the theoretical bound.
            if delay > bound * 1.5 + 0.5 {
                return Err(format!(
                    "job {} delayed {delay:.2}s past UJF, bound {bound:.2}s \
                     (cores={cores}, jobs={})",
                    cu.job,
                    jobs.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn all_policies_complete_all_jobs_no_starvation() {
    propkit::check("no starvation", 0x5EED, 10, |r| {
        let mut cfg = Config::default().with_cores(8);
        cfg.task_overhead = 0.005;
        let mut jobs = Vec::new();
        let mut t = 0.0;
        for i in 0..20 {
            t += r.exp(2.0);
            jobs.push(JobSpec::three_phase(
                1 + r.below(5) as u32,
                &format!("j{i}"),
                uwfq::s_to_us(t),
                0.5 + r.f64() * 8.0,
                128 << 20,
                4,
                None,
            ));
        }
        for policy in PolicyKind::ALL {
            let rep = sim::simulate(cfg.clone().with_policy(policy), jobs.clone());
            if rep.completed.len() != jobs.len() {
                return Err(format!(
                    "{}: {} of {} jobs completed",
                    policy.name(),
                    rep.completed.len(),
                    jobs.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn work_conservation_under_load() {
    // While any task is pending, no core sits idle (the engine re-offers
    // freed cores immediately) → utilization ≈ 1 during the busy period.
    propkit::check("work conservation", 0xC0DE, 10, |r| {
        let mut cfg = Config::default().with_cores(8);
        cfg.task_overhead = 0.0;
        cfg.log_tasks = true;
        // Burst of jobs at t=0 keeps the queue non-empty.
        let jobs: Vec<JobSpec> = (0..10)
            .map(|i| {
                JobSpec::three_phase(
                    1 + (i % 3),
                    &format!("j{i}"),
                    0,
                    2.0 + r.f64() * 4.0,
                    256 << 20,
                    4,
                    None,
                )
            })
            .collect();
        let rep = sim::simulate(cfg.clone().with_policy(PolicyKind::Uwfq), jobs);
        if rep.utilization < 0.85 {
            return Err(format!("utilization {:.3} too low", rep.utilization));
        }
        Ok(())
    });
}
