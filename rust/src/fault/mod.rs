//! Deterministic fault injection: task failures, stragglers, core
//! crashes — the robustness layer under the fairness claims.
//!
//! Everything here is a **pure function of the fault seed and stable
//! task coordinates** — there is no RNG stream to advance, so injecting
//! faults cannot perturb any other random draw in the run and a fixed
//! `fault.seed` reproduces the exact same failure schedule no matter
//! how the simulation interleaves events. With every rate at zero the
//! plan decides `Clean` for every attempt and schedules no crashes, so
//! a zero-fault run is byte-identical to a build without this module.
//!
//! The three injected fault classes (knobs in [`FaultConfig`]):
//!
//! * **Task failures** — an attempt fails partway through its runtime
//!   (a deterministic fraction in `[0.05, 0.95]`), is charged one
//!   failure, and is resubmitted to its stage after an
//!   exponential-backoff delay. The injector itself stops failing an
//!   attempt once `max_failures` is reached, so every task eventually
//!   succeeds and `completions == arrivals` still holds under faults.
//! * **Stragglers** — an attempt runs `straggler_mult ×` its clean
//!   runtime. When speculation is on (`spec_mult > 0`) the engine
//!   launches a clean clone once the original exceeds `spec_mult ×`
//!   the estimate; first finisher wins, the loser is killed and its
//!   core freed.
//! * **Core crashes** — per-core exponential inter-crash gaps with mean
//!   `crash_mttf_s`; a crash kills the in-flight attempt (requeued at
//!   once, not charged as a failure) and blacklists the core for
//!   `crash_recover_s`.
//!
//! Accounting rule (the fairness invariant): virtual time is charged
//! once per task at job arrival (deadlines never move under retries),
//! and **goodput** counts only the winning attempt of each task;
//! every other core-second lands in `wasted_us`. [`FaultStats`]
//! surfaces both, per run and per user.

use std::collections::BTreeMap;

use crate::{s_to_us, TimeUs, UserId};

/// Knobs for the deterministic fault model. All rates default to zero
/// (faults disabled); see module docs for semantics.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Per-attempt failure probability in `[0, 1]`.
    pub task_fail_prob: f64,
    /// Failure budget per task: the injector never fails an attempt at
    /// or beyond this count, bounding retries per task.
    pub max_failures: u32,
    /// Base resubmission delay after a failure; attempt `k` waits
    /// `retry_backoff_s · 2^(k-1)` seconds before re-entering its stage.
    pub retry_backoff_s: f64,
    /// Per-attempt straggler probability in `[0, 1]`.
    pub straggler_prob: f64,
    /// Runtime multiplier applied to straggler attempts (> 1).
    pub straggler_mult: f64,
    /// Speculation threshold: a running attempt becomes a speculation
    /// candidate once it exceeds `spec_mult ×` its clean runtime
    /// estimate. `0` disables speculative clones.
    pub spec_mult: f64,
    /// Mean time between crashes per core, seconds. `0` disables
    /// crashes.
    pub crash_mttf_s: f64,
    /// Blacklist window after a crash before the core re-enters
    /// service.
    pub crash_recover_s: f64,
    /// Fault-schedule seed, independent of the workload seed.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            task_fail_prob: 0.0,
            max_failures: 3,
            retry_backoff_s: 1.0,
            straggler_prob: 0.0,
            straggler_mult: 4.0,
            spec_mult: 2.0,
            crash_mttf_s: 0.0,
            crash_recover_s: 30.0,
            seed: 0,
        }
    }
}

impl FaultConfig {
    /// True iff any fault class can fire. The engine skips every fault
    /// branch when this is false, which is what makes the zero-rate
    /// differential exact.
    pub fn enabled(&self) -> bool {
        self.task_fail_prob > 0.0 || self.straggler_prob > 0.0 || self.crash_mttf_s > 0.0
    }

    /// Validate ranges; errors name the offending `fault.*` key.
    pub fn validate(&self) -> Result<(), String> {
        for (key, v) in [
            ("fault.task_fail_prob", self.task_fail_prob),
            ("fault.straggler_prob", self.straggler_prob),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{key} must be in [0, 1], got {v}"));
            }
        }
        for (key, v) in [
            ("fault.retry_backoff_s", self.retry_backoff_s),
            ("fault.straggler_mult", self.straggler_mult),
            ("fault.spec_mult", self.spec_mult),
            ("fault.crash_mttf_s", self.crash_mttf_s),
            ("fault.crash_recover_s", self.crash_recover_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("{key} must be finite and >= 0, got {v}"));
            }
        }
        if self.straggler_prob > 0.0 && self.straggler_mult < 1.0 {
            return Err(format!(
                "fault.straggler_mult must be >= 1 when stragglers are on, got {}",
                self.straggler_mult
            ));
        }
        Ok(())
    }
}

/// The decided fate of one task attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fate {
    /// Runs to completion at its clean runtime.
    Clean,
    /// Fails after `frac ∈ [0.05, 0.95]` of its clean runtime.
    Fail { frac: f64 },
    /// Completes, but at `mult ×` its clean runtime.
    Straggle { mult: f64 },
}

/// splitmix64 finalizer — same mixing constants as `util::rng`, kept
/// local so the fault schedule is a closed function of its inputs.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fold a key tuple into one well-mixed 64-bit hash.
fn fold(seed: u64, parts: &[u64]) -> u64 {
    let mut h = mix(seed);
    for &p in parts {
        h = mix(h ^ p);
    }
    h
}

/// Map a hash onto `[0, 1)` with 53 bits of precision (the same
/// conversion `util::rng` uses).
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Domain-separation salts so the three draw families never alias.
const SALT_FATE: u64 = 0xFA7E;
const SALT_FRAC: u64 = 0xF2AC;
const SALT_CRASH: u64 = 0xC2A5;

/// The per-run fault schedule: a stateless oracle keyed on stable task
/// coordinates `(arrival_seq, stage_idx, task_idx, attempt)` and, for
/// crashes, `(core, crash_idx)`. Stateless is the point — fates are
/// reproducible under any event interleaving and under engine reset.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    cfg: FaultConfig,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> Self {
        FaultPlan { cfg }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Decide the fate of attempt `attempt` of a task. Attempts past
    /// the failure budget can still straggle but never fail, so a
    /// task's retry chain always terminates in a completion.
    pub fn fate(&self, arrival_seq: u64, stage_idx: usize, task_idx: u32, attempt: u32) -> Fate {
        let pf = self.cfg.task_fail_prob;
        let ps = self.cfg.straggler_prob;
        if pf <= 0.0 && ps <= 0.0 {
            return Fate::Clean;
        }
        let key = [
            SALT_FATE,
            arrival_seq,
            stage_idx as u64,
            task_idx as u64,
            attempt as u64,
        ];
        let u = unit(fold(self.cfg.seed, &key));
        if u < pf && attempt < self.cfg.max_failures {
            let key = [
                SALT_FRAC,
                arrival_seq,
                stage_idx as u64,
                task_idx as u64,
                attempt as u64,
            ];
            let f = unit(fold(self.cfg.seed, &key));
            Fate::Fail { frac: 0.05 + 0.90 * f }
        } else if u < pf + ps {
            Fate::Straggle { mult: self.cfg.straggler_mult }
        } else {
            Fate::Clean
        }
    }

    /// The `idx`-th inter-crash gap on `core` (exponential with mean
    /// `crash_mttf_s`, clamped to ≥ 1 µs so a pathological draw cannot
    /// produce a zero-width crash loop). `None` when crashes are off.
    pub fn crash_gap_us(&self, core: usize, idx: u64) -> Option<TimeUs> {
        if self.cfg.crash_mttf_s <= 0.0 {
            return None;
        }
        let u = unit(fold(self.cfg.seed, &[SALT_CRASH, core as u64, idx]));
        let gap_s = -self.cfg.crash_mttf_s * (1.0 - u).ln();
        Some(s_to_us(gap_s).max(1))
    }

    /// Backoff before resubmitting a task after its `failures`-th
    /// failure (1-based): `retry_backoff_s · 2^(failures-1)`, exponent
    /// capped so the shift cannot overflow.
    pub fn retry_delay_us(&self, failures: u32) -> TimeUs {
        let exp = failures.saturating_sub(1).min(20);
        s_to_us(self.cfg.retry_backoff_s * (1u64 << exp) as f64)
    }
}

/// Fault/recovery counters for one run, surfaced on `SimReport` and
/// `StreamSummary`. `good_us`/`wasted_us` split every core-µs the run
/// consumed: the winning attempt of each task is goodput, every other
/// attempt (failed, killed speculation loser, lost to a crash) is
/// waste. Per-user totals use a `BTreeMap` so iteration order — and
/// therefore any derived rendering — is deterministic.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    /// Injected task failures (each consumes one retry).
    pub failures: u64,
    /// Resubmissions that re-entered a stage after backoff.
    pub retries: u64,
    /// Speculative clones launched.
    pub spec_launched: u64,
    /// Speculations where the clone finished first.
    pub spec_wins: u64,
    /// Speculations where the original finished first (clone killed).
    pub spec_losses: u64,
    /// Speculation candidates skipped because no free core existed.
    pub spec_skipped: u64,
    /// Core crashes.
    pub crashes: u64,
    /// In-flight attempts killed by a crash.
    pub tasks_lost_to_crash: u64,
    /// Core-µs spent on winning attempts.
    pub good_us: u128,
    /// Core-µs spent on failed / killed / crash-lost attempts.
    pub wasted_us: u128,
    /// Per-user `(good_us, wasted_us)` — the goodput ledger behind the
    /// fairness-under-failure claim. Only populated when faults are on.
    pub per_user: BTreeMap<UserId, (u128, u128)>,
    /// Crash windows `(core, crashed_at, recovered_at)`; recorded only
    /// when task logging is on (same gate as the task log).
    pub crash_windows: Vec<(usize, TimeUs, TimeUs)>,
}

impl FaultStats {
    pub fn good_core_s(&self) -> f64 {
        self.good_us as f64 / 1e6
    }

    pub fn wasted_core_s(&self) -> f64 {
        self.wasted_us as f64 / 1e6
    }

    /// Fold another ledger (one shard's) into this one — the exact
    /// reduction behind the sharded engine's merged summary. Counters
    /// and core-time sums add; per-user entries add (shards serve
    /// disjoint users, so entries never actually collide); crash windows
    /// concatenate with the shard's cores renumbered into the cluster
    /// index space via `core_offset` (the sum of earlier shards' core
    /// counts). Merging into a default-initialized ledger with offset 0
    /// is the identity.
    pub fn merge(&mut self, other: &FaultStats, core_offset: usize) {
        self.failures += other.failures;
        self.retries += other.retries;
        self.spec_launched += other.spec_launched;
        self.spec_wins += other.spec_wins;
        self.spec_losses += other.spec_losses;
        self.spec_skipped += other.spec_skipped;
        self.crashes += other.crashes;
        self.tasks_lost_to_crash += other.tasks_lost_to_crash;
        self.good_us += other.good_us;
        self.wasted_us += other.wasted_us;
        for (&user, &(good, wasted)) in &other.per_user {
            let e = self.per_user.entry(user).or_insert((0, 0));
            e.0 += good;
            e.1 += wasted;
        }
        self.crash_windows.extend(
            other
                .crash_windows
                .iter()
                .map(|&(core, down, up)| (core + core_offset, down, up)),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(f: impl FnOnce(&mut FaultConfig)) -> FaultPlan {
        let mut cfg = FaultConfig::default();
        f(&mut cfg);
        FaultPlan::new(cfg)
    }

    #[test]
    fn zero_rates_are_always_clean() {
        let p = plan(|_| {});
        for seq in 0..50 {
            for attempt in 0..4 {
                assert_eq!(p.fate(seq, 0, 0, attempt), Fate::Clean);
            }
        }
        assert_eq!(p.crash_gap_us(0, 0), None);
    }

    #[test]
    fn stats_merge_sums_and_offsets_cores() {
        let a = FaultStats {
            failures: 2,
            retries: 2,
            good_us: 100,
            wasted_us: 10,
            per_user: [(1u32, (50u128, 5u128))].into_iter().collect(),
            crash_windows: vec![(0, 10, 20)],
            ..Default::default()
        };
        let b = FaultStats {
            failures: 3,
            retries: 3,
            crashes: 1,
            good_us: 40,
            wasted_us: 4,
            per_user: [(7u32, (40u128, 4u128))].into_iter().collect(),
            crash_windows: vec![(1, 30, 40)],
            ..Default::default()
        };
        // Identity: merging into a default ledger at offset 0.
        let mut m = FaultStats::default();
        m.merge(&a, 0);
        assert_eq!(m, a);
        // Second shard's cores renumber past the first shard's 4 cores.
        m.merge(&b, 4);
        assert_eq!(m.failures, 5);
        assert_eq!(m.good_us, 140);
        assert_eq!(m.per_user[&1], (50, 5));
        assert_eq!(m.per_user[&7], (40, 4));
        assert_eq!(m.crash_windows, vec![(0, 10, 20), (5, 30, 40)]);
    }

    #[test]
    fn fates_are_deterministic_and_seed_sensitive() {
        let a = plan(|c| {
            c.task_fail_prob = 0.3;
            c.straggler_prob = 0.2;
            c.seed = 7;
        });
        let b = plan(|c| {
            c.task_fail_prob = 0.3;
            c.straggler_prob = 0.2;
            c.seed = 8;
        });
        let fates_a: Vec<Fate> = (0..200).map(|i| a.fate(i, 1, 2, 0)).collect();
        let again: Vec<Fate> = (0..200).map(|i| a.fate(i, 1, 2, 0)).collect();
        assert_eq!(fates_a, again, "same seed must reproduce fates");
        let fates_b: Vec<Fate> = (0..200).map(|i| b.fate(i, 1, 2, 0)).collect();
        assert_ne!(fates_a, fates_b, "different seeds must diverge");
    }

    #[test]
    fn fail_rate_roughly_matches_probability() {
        let p = plan(|c| {
            c.task_fail_prob = 0.25;
            c.seed = 42;
        });
        let fails = (0..4000)
            .filter(|&i| matches!(p.fate(i, 0, 0, 0), Fate::Fail { .. }))
            .count();
        let rate = fails as f64 / 4000.0;
        assert!((rate - 0.25).abs() < 0.03, "observed fail rate {rate}");
    }

    #[test]
    fn failure_budget_caps_fail_fate() {
        let p = plan(|c| {
            c.task_fail_prob = 1.0;
            c.max_failures = 2;
        });
        assert!(matches!(p.fate(0, 0, 0, 0), Fate::Fail { .. }));
        assert!(matches!(p.fate(0, 0, 0, 1), Fate::Fail { .. }));
        // At the budget the injector must stop failing this task.
        assert_eq!(p.fate(0, 0, 0, 2), Fate::Clean);
        assert_eq!(p.fate(0, 0, 0, 9), Fate::Clean);
    }

    #[test]
    fn fail_fraction_stays_in_band() {
        let p = plan(|c| {
            c.task_fail_prob = 1.0;
            c.seed = 3;
        });
        for i in 0..500 {
            if let Fate::Fail { frac } = p.fate(i, 0, 0, 0) {
                assert!((0.05..=0.95).contains(&frac), "frac {frac}");
            }
        }
    }

    #[test]
    fn crash_gaps_positive_and_mean_near_mttf() {
        let p = plan(|c| {
            c.crash_mttf_s = 10.0;
            c.seed = 9;
        });
        let gaps: Vec<TimeUs> = (0..2000).map(|i| p.crash_gap_us(0, i).unwrap()).collect();
        assert!(gaps.iter().all(|&g| g >= 1));
        let mean_s = gaps.iter().map(|&g| g as f64 / 1e6).sum::<f64>() / gaps.len() as f64;
        assert!((mean_s - 10.0).abs() < 1.0, "mean gap {mean_s}s vs mttf 10s");
    }

    #[test]
    fn retry_delay_doubles_and_saturates() {
        let p = plan(|c| c.retry_backoff_s = 1.0);
        assert_eq!(p.retry_delay_us(1), s_to_us(1.0));
        assert_eq!(p.retry_delay_us(2), s_to_us(2.0));
        assert_eq!(p.retry_delay_us(3), s_to_us(4.0));
        // Exponent capped — no shift overflow at absurd failure counts.
        assert_eq!(p.retry_delay_us(80), p.retry_delay_us(21));
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let mut c = FaultConfig::default();
        c.task_fail_prob = 1.5;
        assert!(c.validate().unwrap_err().contains("fault.task_fail_prob"));
        let mut c = FaultConfig::default();
        c.crash_mttf_s = -1.0;
        assert!(c.validate().unwrap_err().contains("fault.crash_mttf_s"));
        let mut c = FaultConfig::default();
        c.straggler_prob = 0.1;
        c.straggler_mult = 0.5;
        assert!(c.validate().unwrap_err().contains("fault.straggler_mult"));
        assert!(FaultConfig::default().validate().is_ok());
    }
}
