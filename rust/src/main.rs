//! `uwfq` — launcher binary: reproduce the paper's tables/figures, run
//! ad-hoc workloads through the simulator, or serve a workload on the
//! real PJRT execution backend.

use std::path::Path;
use std::process::ExitCode;
use std::time::Instant;

use uwfq::bench::{figures, tables};
use uwfq::cli::{Cli, USAGE};
use uwfq::config::Config;
use uwfq::metrics::fairness::{fairness_vs_ujf, DvrDenominator};
use uwfq::sweep::Sweep;
use uwfq::util::benchkit::JsonSink;
use uwfq::workload::{scenarios, Registry, ScenarioSpec, Workload};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match Cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cli.command.as_str() {
        "reproduce" => reproduce(&cli),
        "sweep" => sweep_cmd(&cli),
        "fault" => fault_cmd(&cli),
        "drf" => drf_cmd(&cli),
        "hotpath" => hotpath_cmd(&cli),
        "scale" => scale_cmd(&cli),
        "shard" => shard_cmd(&cli),
        "benchsummary" => benchsummary_cmd(&cli),
        "replay" => replay_cmd(&cli),
        "tracegen" => tracegen_cmd(&cli),
        "run" => run(&cli),
        "scenarios" => scenarios_cmd(),
        "serve" => serve(&cli),
        "ablation" => ablation(&cli),
        "analyze" => analyze(&cli),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Spec for registry entry `name`, with its quick overrides applied when
/// `quick` (the scenario's own idea of a fast smoke shape).
fn spec_with_quick(name: &str, quick: bool) -> Result<ScenarioSpec, String> {
    let sc = Registry::global().get(name)?;
    let mut spec = ScenarioSpec::new(name);
    if quick {
        for &(k, v) in sc.quick_overrides() {
            spec = spec.with(k, v);
        }
    }
    Ok(spec)
}

/// The Table-2 / Fig-7 macro workload, shrunk under `--quick`.
fn macro_workload(quick: bool, seed: u64, base: &Config) -> Result<Workload, String> {
    if quick {
        let mut spec = spec_with_quick("gtrace", true)?;
        spec = spec.with("cores", &base.cores.to_string());
        spec.workload(seed)
    } else {
        Ok(figures::default_macro_workload(seed))
    }
}

/// Targets `uwfq reproduce` accepts (checked up front — a typo must be a
/// hard error, not a silent no-op run).
const REPRODUCE_TARGETS: [&str; 8] = [
    "table1", "table2", "fig3", "fig4", "fig5", "fig6", "fig7", "all",
];

fn reproduce(cli: &Cli) -> Result<(), String> {
    let what = cli
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    if !REPRODUCE_TARGETS.contains(&what) {
        return Err(format!(
            "unknown reproduce target '{what}' (valid: {})",
            REPRODUCE_TARGETS.join(", ")
        ));
    }
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let mut base = cli.config()?;
    let quick = cli.quick();
    if quick {
        base.cores = 8;
    }
    let seed = base.seed;
    // Grids route through the sweep engine; `--threads 1` (the default
    // here) is the sequential reference, more workers give byte-identical
    // output faster.
    let swp = Sweep::new(cli.threads(1)?);
    let io = |e: std::io::Error| e.to_string();

    if matches!(what, "table1" | "all") {
        let (s1, s2) = tables::table1(seed, &base, &swp);
        println!("{}", tables::render_table1(&s1));
        println!("{}", tables::render_table1(&s2));
        tables::write_table1_csv(&format!("{out}/table1_scenario1.csv"), &s1).map_err(io)?;
        tables::write_table1_csv(&format!("{out}/table1_scenario2.csv"), &s2).map_err(io)?;
    }
    if matches!(what, "table2" | "all") {
        let w = macro_workload(quick, seed, &base)?;
        let t2 = tables::table2(&w, &base, &swp);
        println!("{}", tables::render_table2(&t2));
        tables::write_table2_csv(&format!("{out}/table2_macro.csv"), &t2).map_err(io)?;
    }
    if matches!(what, "fig3" | "all") {
        let f = figures::fig3(&base, &swp);
        println!("== Fig 3 / task skew ==");
        for (label, rt, _) in &f.runs {
            println!("  {label:<10} completion {rt:.2} s");
        }
        figures::write_fig3_csv(&out, &f).map_err(io)?;
    }
    if matches!(what, "fig4" | "all") {
        let f = figures::fig4(&base, &swp);
        println!("== Fig 4 / priority inversion ==");
        for (label, hi, lo) in &f.runs {
            println!("  {label:<10} high-prio RT {hi:.2} s   low-prio RT {lo:.2} s");
        }
        figures::write_fig4_csv(&out, &f).map_err(io)?;
    }
    if matches!(what, "fig5" | "all") {
        let s = figures::fig5(seed, &base, &swp);
        figures::write_fig5_csv(&out, &s).map_err(io)?;
        println!("== Fig 5 → {out}/fig5_infrequent_cdf.csv ==");
    }
    if matches!(what, "fig6" | "all") {
        let s = figures::fig6(seed, &base, &swp);
        figures::write_fig6_csv(&out, &s).map_err(io)?;
        println!("== Fig 6 → {out}/fig6_completion_cdf.csv ==");
    }
    if matches!(what, "fig7" | "all") {
        let w = macro_workload(quick, seed, &base)?;
        let f = figures::fig7(&w, &base, &swp);
        figures::write_fig7_csv(&out, &f).map_err(io)?;
        println!("== Fig 7 → {out}/fig7_user_violations.csv ==");
    }
    println!("\nreproduce '{what}' done → {out}/");
    Ok(())
}

/// The stress scenarios `uwfq sweep` runs alongside the paper grids —
/// pure registry entries; this file only knows their names.
const STRESS_SCENARIOS: [&str; 3] = ["bursty", "heavytail", "diurnal"];

/// `--threads` and `--shards` compose: a sharded run already owns
/// `shards` OS threads, so `threads × shards` worker threads would
/// oversubscribe the machine. Trim the sweep workers (never below 1)
/// and say so loudly — silent thrash is worse than a warning.
fn cap_threads_for_shards(threads: usize, shards: u32) -> usize {
    let shards = shards.max(1) as usize;
    let avail = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if threads.saturating_mul(shards) > avail {
        let capped = (avail / shards).max(1);
        eprintln!(
            "warning: --threads {threads} x --shards {shards} oversubscribes the \
             {avail} available cores; capping --threads to {capped}"
        );
        capped
    } else {
        threads
    }
}

/// Run the generic policy × partitioner grid for one scenario spec and
/// write `sweep_<name>.csv`.
fn scenario_sweep(
    spec: &ScenarioSpec,
    base: &Config,
    par: &Sweep,
    out: &str,
) -> Result<(), String> {
    let g = tables::scenario_grid(spec, base, par)?;
    println!("{}", tables::render_scenario_grid(&g));
    let path = format!("{out}/sweep_{}.csv", spec.name);
    tables::write_scenario_grid_csv(&path, &g).map_err(|e| e.to_string())?;
    println!("scenario grid '{}' → {path}", spec.name);
    Ok(())
}

/// `uwfq sweep` — the whole evaluation grid on all cores: regenerates
/// every table and figure through the parallel sweep engine (output
/// byte-identical to `reproduce --threads 1`), runs the stress-scenario
/// grids, times the macro grid at 1 thread vs N, and records cells/s +
/// speedup in `BENCH_sweep.json`. With `--scenario NAME`, runs only that
/// scenario's generic grid.
fn sweep_cmd(cli: &Cli) -> Result<(), String> {
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let mut base = cli.config()?;
    let quick = cli.quick();
    if quick {
        base.cores = 8;
    }
    let seed = base.seed;
    let threads = cap_threads_for_shards(cli.threads(uwfq::sweep::auto_threads(None))?, base.shards);
    let par = Sweep::new(threads);
    let io = |e: std::io::Error| e.to_string();

    // `uwfq sweep --scenario NAME [--param k=v]`: just that scenario's
    // generic grid, straight off the registry.
    if let Some(name) = base.scenario.clone() {
        let mut spec = spec_with_quick(&name, quick)?;
        spec.params.extend(base.scenario_params.iter().cloned());
        return scenario_sweep(&spec, &base, &par, &out);
    }

    let w = macro_workload(quick, seed, &base)?;
    println!(
        "sweep: {} worker threads; macro workload {} jobs / {} users",
        par.threads(),
        w.jobs.len(),
        w.users().len()
    );

    // Prewarm the process-wide idle-response memo cache so the 1-thread
    // and N-thread probes below time identical work (slowdown
    // denominators would otherwise be computed once, by whichever probe
    // runs first).
    for scheme in [uwfq::partition::SchemeKind::Size, uwfq::partition::SchemeKind::Runtime] {
        uwfq::bench::idle_map(&base.clone().with_scheme(scheme), &w);
    }

    // Speedup probe: the macro grid (Table 2 + Fig 7), sequential first,
    // then — when more than one worker was requested — parallel. Cells/s
    // on this grid is the headline number tracked across PRs in
    // BENCH_sweep.json.
    let macro_cells = uwfq::bench::macro_grid_cell_count() as f64;
    let t0 = Instant::now();
    let mut t2 = tables::table2(&w, &base, &Sweep::seq());
    let mut f7 = figures::fig7(&w, &base, &Sweep::seq());
    let seq_s = t0.elapsed().as_secs_f64().max(1e-9);
    // None when threads == 1: a second probe would only duplicate the
    // sequential one and collide with its metric names.
    let mut par_s = None;
    if threads > 1 {
        let t0 = Instant::now();
        let t2_par = tables::table2(&w, &base, &par);
        let f7_par = figures::fig7(&w, &base, &par);
        par_s = Some(t0.elapsed().as_secs_f64().max(1e-9));
        // Determinism guard at the user-visible boundary (the
        // `sweep_differential` test covers every CSV byte).
        if tables::render_table2(&t2_par) != tables::render_table2(&t2) {
            return Err("parallel sweep diverged from sequential table output".into());
        }
        t2 = t2_par;
        f7 = f7_par;
    }

    // The rest of the evaluation, all parallel.
    let (s1, s2) = tables::table1(seed, &base, &par);
    let f5 = figures::fig5(seed, &base, &par);
    let f6 = figures::fig6(seed, &base, &par);
    let f3 = figures::fig3(&base, &par);
    let f4 = figures::fig4(&base, &par);

    println!("{}", tables::render_table1(&s1));
    println!("{}", tables::render_table1(&s2));
    println!("{}", tables::render_table2(&t2));
    tables::write_table1_csv(&format!("{out}/table1_scenario1.csv"), &s1).map_err(io)?;
    tables::write_table1_csv(&format!("{out}/table1_scenario2.csv"), &s2).map_err(io)?;
    tables::write_table2_csv(&format!("{out}/table2_macro.csv"), &t2).map_err(io)?;
    figures::write_fig3_csv(&out, &f3).map_err(io)?;
    figures::write_fig4_csv(&out, &f4).map_err(io)?;
    figures::write_fig5_csv(&out, &f5).map_err(io)?;
    figures::write_fig6_csv(&out, &f6).map_err(io)?;
    figures::write_fig7_csv(&out, &f7).map_err(io)?;

    // The stress scenarios ride along: each is a pure registry entry,
    // swept across every policy × partitioner with zero bench-layer code.
    for name in STRESS_SCENARIOS {
        scenario_sweep(&spec_with_quick(name, quick)?, &base, &par, &out)?;
    }

    let mut sink = JsonSink::new();
    sink.metric("sweep/threads", threads as f64);
    sink.metric("sweep/macro_grid_cells", macro_cells);
    sink.metric("sweep/macro_grid_seq_s", seq_s);
    sink.metric("sweep/cells_per_s_1t", macro_cells / seq_s);
    if let Some(ps) = par_s {
        sink.metric("sweep/macro_grid_par_s", ps);
        sink.metric(&format!("sweep/cells_per_s_{threads}t"), macro_cells / ps);
        sink.metric("sweep/speedup", seq_s / ps);
    }
    let (hits, misses, contended) = uwfq::sim::idle_cache_stats();
    sink.metric("sweep/idle_cache_hits", hits as f64);
    sink.metric("sweep/idle_cache_misses", misses as f64);
    sink.metric("sweep/idle_cache_contended", contended as f64);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_sweep.json"));
    sink.write(&bench_path).map_err(io)?;
    match par_s {
        Some(ps) => println!(
            "macro grid: {:.2} cells/s at 1 thread → {:.2} cells/s at {} threads ({:.2}×)",
            macro_cells / seq_s,
            macro_cells / ps,
            threads,
            seq_s / ps
        ),
        None => println!(
            "macro grid: {:.2} cells/s at 1 thread (single-worker run, no speedup probe)",
            macro_cells / seq_s
        ),
    }
    println!("sweep done → {out}/ (bench → {bench_path})");
    Ok(())
}

/// `uwfq fault` — fairness-under-failure degradation curves: UWFQ vs
/// Fair vs FIFO across increasing task-failure rates plus straggler/
/// speculation and crash/blacklist arms, through the sweep engine.
/// Emits `BENCH_fault.json` (the CI fault-smoke artifact).
fn fault_cmd(cli: &Cli) -> Result<(), String> {
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let mut base = cli.config()?;
    let quick = cli.quick();
    if cli.flag("cores").is_none() && cli.flag("config").is_none() {
        base.cores = if quick { 8 } else { 16 };
    }
    // The grid sets its own fault arms — a `--fault.*` flag here would be
    // silently overwritten per cell, so reject it loudly.
    if base.fault.enabled() {
        return Err(
            "uwfq fault sweeps its own fault arms; drop the --fault.* flags \
             (use `uwfq run --fault.task_fail_prob ...` for a single faulty run)"
                .into(),
        );
    }
    let par = Sweep::new(cli.threads(uwfq::sweep::auto_threads(None))?);
    let b = uwfq::bench::fault::run_fault(&base, quick, &par);
    print!("{}", uwfq::bench::fault::render(&b));
    let mut sink = JsonSink::new();
    uwfq::bench::fault::record_metrics(&b, &mut sink);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_fault.json"));
    sink.write(&bench_path).map_err(|e| e.to_string())?;
    println!("fault bench done → {bench_path}");
    Ok(())
}

/// `uwfq drf` — the multi-resource grids: all seven policies over a
/// mixed CPU/memory-demand workload (per-dimension goodput off the
/// engine's resource ledgers) plus the UWFQ-vs-BoPF burst-tolerance
/// ablation on the `bursty` scenario. Emits `BENCH_drf.json` (the CI
/// drf-smoke artifact).
fn drf_cmd(cli: &Cli) -> Result<(), String> {
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let mut base = cli.config()?;
    let quick = cli.quick();
    if cli.flag("cores").is_none() && cli.flag("config").is_none() {
        base.cores = if quick { 8 } else { 16 };
    }
    let par = Sweep::new(cli.threads(uwfq::sweep::auto_threads(None))?);
    let b = uwfq::bench::drf::run_drf(&base, quick, &par);
    print!("{}", uwfq::bench::drf::render(&b));
    let mut sink = JsonSink::new();
    uwfq::bench::drf::record_metrics(&b, &mut sink);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_drf.json"));
    sink.write(&bench_path).map_err(|e| e.to_string())?;
    println!("drf bench done → {bench_path}");
    Ok(())
}

/// `uwfq hotpath` — event-core throughput: the congested 50k-job /
/// 100-user / 64-core case per policy across the wheel-vs-heap and
/// batching-on/off ablation cells, plus the env-resolved default (so a
/// run under `UWFQ_EVENT_HEAP=1` benches the escape-hatch path). Emits
/// `BENCH_hotpath.json` (the CI hotpath-smoke artifact).
fn hotpath_cmd(cli: &Cli) -> Result<(), String> {
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let mut cfg = cli.config()?;
    // Bench default: the 64-core case — unless cores came via flag or
    // config file.
    if cli.flag("cores").is_none() && cli.flag("config").is_none() {
        cfg.cores = 64;
    }
    let quick = cli.quick();
    let outcome = uwfq::bench::hotpath::run_hotpath(&cfg, quick);
    print!("{}", uwfq::bench::hotpath::render(&outcome));
    let mut sink = JsonSink::new();
    uwfq::bench::hotpath::record_metrics(&outcome, &mut sink);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_hotpath.json"));
    sink.write(&bench_path).map_err(|e| e.to_string())?;
    println!("hotpath bench done → {bench_path}");
    Ok(())
}

/// `uwfq scale` — the streaming scale run: a million-job / ten-thousand-
/// user workload generated lazily (O(users) stream state), simulated with
/// completions drained into bounded-memory accumulators (O(in-flight +
/// users) resident metric state — no per-job outcome vector), and a
/// verify pass measuring the streaming estimators against exact
/// quantiles. Emits `BENCH_scale.json`; the accuracy tolerances are
/// *asserted* (non-zero exit on violation), which is what the CI
/// scale-smoke job runs.
fn scale_cmd(cli: &Cli) -> Result<(), String> {
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let mut cfg = cli.config()?;
    // Scale-run default: a bigger cluster than the paper testbed — but
    // only when the user set cores neither via flag nor config file.
    if cli.flag("cores").is_none() && cli.flag("config").is_none() {
        cfg.cores = 64;
    }
    let verify = cli.flag("verify") != Some("false");
    // Size resolution routes through the registry's `scale` entry — its
    // schema (and quick overrides) are the single source of the scale
    // defaults; `--jobs` / `--users` / `--param k=v` layer on top.
    let mut spec = spec_with_quick("scale", cli.quick())?;
    spec.params.extend(cfg.scenario_params.iter().cloned());
    if let Some(v) = cli.flag("jobs") {
        spec = spec.with("jobs", v);
    }
    if let Some(v) = cli.flag("users") {
        spec = spec.with("users", v);
    }
    spec = spec.with("cores", &cfg.cores.to_string());
    let params = uwfq::workload::registry::scale_params(&spec, cfg.seed)?;
    println!(
        "scale: {} jobs / {} users on {} cores (policy {}, streaming path{})",
        params.jobs,
        params.users,
        params.cores,
        cfg.policy.name(),
        if verify { " + exact verify pass" } else { "" }
    );
    let outcome = uwfq::bench::scale::run_scale(&params, &cfg, verify);
    print!("{}", uwfq::bench::scale::render(&outcome));

    let mut sink = JsonSink::new();
    uwfq::bench::scale::record_metrics(&outcome, &mut sink);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_scale.json"));
    sink.write(&bench_path).map_err(|e| e.to_string())?;
    println!("scale done → {bench_path}");

    if let Some(v) = &outcome.verify {
        v.check()
            .map_err(|e| format!("streaming accuracy outside documented tolerance: {e}"))?;
        println!("streaming estimators within documented tolerance");
    }
    Ok(())
}

/// `uwfq shard` — the sharded-engine bench: the scale workload run at
/// increasing shard counts (users hash-partitioned across parallel event
/// loops, federated virtual time re-coupled each `shard_epoch_s`), with
/// the 1-shard run as the in-process throughput baseline. Emits
/// `BENCH_shard.json` (jobs/s, speedup vs S=1, virtual-time drift vs its
/// provable bound per shard count); the CI shard-smoke job runs
/// `--quick` over a {1,2,4} matrix.
fn shard_cmd(cli: &Cli) -> Result<(), String> {
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let mut cfg = cli.config()?;
    if cli.flag("cores").is_none() && cli.flag("config").is_none() {
        cfg.cores = 64;
    }
    let quick = cli.quick();
    let counts = shard_count_sweep(cli, &cfg)?;
    if cli.flag("skew") == Some("true") {
        return shard_skew_cmd(cli, &cfg, &counts, &out, quick);
    }
    // Size resolution mirrors `uwfq scale` (registry `scale` entry, quick
    // overrides, --jobs/--users on top) — but the sharded headline shape
    // is wider: 1M jobs across 100k users, so hash partitioning has a
    // population to spread.
    let mut spec = spec_with_quick("scale", quick)?;
    spec.params.extend(cfg.scenario_params.iter().cloned());
    if !quick && cli.flag("users").is_none() {
        spec = spec.with("users", "100000");
    }
    if let Some(v) = cli.flag("jobs") {
        spec = spec.with("jobs", v);
    }
    if let Some(v) = cli.flag("users") {
        spec = spec.with("users", v);
    }
    spec = spec.with("cores", &cfg.cores.to_string());
    let params = uwfq::workload::registry::scale_params(&spec, cfg.seed)?;
    println!(
        "shard: {} jobs / {} users on {} cores, shard counts {:?} (policy {}, epoch {} s)",
        params.jobs,
        params.users,
        params.cores,
        counts,
        cfg.policy.name(),
        cfg.shard_epoch_s
    );
    let outcome = uwfq::bench::shard::run_shard(&params, &cfg, &counts);
    print!("{}", uwfq::bench::shard::render(&outcome));
    let mut sink = JsonSink::new();
    uwfq::bench::shard::record_metrics(&outcome, &mut sink);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_shard.json"));
    sink.write(&bench_path).map_err(|e| e.to_string())?;
    println!("shard bench done → {bench_path}");
    Ok(())
}

/// Shard counts for `uwfq shard`: `--shards N` benches {1, N}; the
/// default sweeps powers of two. Both are clamped by cores (a shard
/// needs a core); counts beyond the machine's parallelism still run
/// (the threads just time-slice) but are worth a loud note.
fn shard_count_sweep(cli: &Cli, cfg: &Config) -> Result<Vec<u32>, String> {
    let avail = std::thread::available_parallelism()
        .map(|n| n.get() as u32)
        .unwrap_or(1);
    let counts: Vec<u32> = if cli.flag("shards").is_some() {
        if cfg.shards > cfg.cores {
            return Err(format!(
                "--shards {} exceeds --cores {}: every shard needs a core",
                cfg.shards, cfg.cores
            ));
        }
        if cfg.shards == 1 {
            vec![1]
        } else {
            vec![1, cfg.shards]
        }
    } else {
        [1u32, 2, 4, 8]
            .into_iter()
            .filter(|&s| s <= cfg.cores && s <= avail.max(2))
            .collect()
    };
    if let Some(&max_s) = counts.iter().max() {
        if max_s > avail {
            eprintln!(
                "warning: {max_s} shards on {avail} available cores — shard threads \
                 will time-slice; speedups will understate the engine"
            );
        }
    }
    Ok(counts)
}

/// `uwfq shard --skew` — the cross-shard work-balancing ablation: the
/// Zipfian `skewed` stream at each shard count, static core split vs
/// deterministic core lending (`speedup_vs_static` per count). An
/// explicit `--shard_rebalance false` keeps only the static arm.
fn shard_skew_cmd(
    cli: &Cli,
    cfg: &Config,
    counts: &[u32],
    out: &str,
    quick: bool,
) -> Result<(), String> {
    // Size resolution routes through the registry's `skewed` entry;
    // the non-quick default is the 1M-job headline shape the lending
    // speedup is tracked on.
    let mut spec = spec_with_quick("skewed", quick)?;
    spec.params.extend(cfg.scenario_params.iter().cloned());
    if !quick && cli.flag("jobs").is_none() {
        spec = spec.with("jobs", "1000000");
    }
    if let Some(v) = cli.flag("jobs") {
        spec = spec.with("jobs", v);
    }
    if let Some(v) = cli.flag("users") {
        spec = spec.with("users", v);
    }
    spec = spec.with("cores", &cfg.cores.to_string());
    let params = uwfq::workload::registry::skewed_params(&spec)?;
    // The ablation runs both arms by default; only an explicit
    // `--shard_rebalance false` drops the lending arm (the config key's
    // default is off, so absence means "compare", not "skip").
    let lending = cli.flag("shard_rebalance") != Some("false");
    println!(
        "shard --skew: {} jobs / {} users ({} hot, zipf_s {}) on {} cores, \
         shard counts {:?}, lending {} (policy {}, epoch {} s)",
        params.jobs,
        params.users,
        params.hot_users,
        params.zipf_s,
        params.cores,
        counts,
        if lending { "on" } else { "off" },
        cfg.policy.name(),
        cfg.shard_epoch_s
    );
    let outcome = uwfq::bench::shard::run_shard_skew(cfg.seed, &params, cfg, counts, lending);
    print!("{}", uwfq::bench::shard::render_skew(&outcome));
    let mut sink = JsonSink::new();
    uwfq::bench::shard::record_skew_metrics(&outcome, &mut sink);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_shard.json"));
    sink.write(&bench_path).map_err(|e| e.to_string())?;
    println!("shard skew bench done → {bench_path}");
    Ok(())
}

/// `uwfq benchsummary` — merge every `BENCH_*.json` artifact found in
/// the given directories (default: `out/` then `.`) into one markdown
/// perf-trajectory table on stdout; `--out FILE` also writes the file.
fn benchsummary_cmd(cli: &Cli) -> Result<(), String> {
    let dirs: Vec<String> = if cli.positional.is_empty() {
        vec!["out".to_string(), ".".to_string()]
    } else {
        cli.positional.clone()
    };
    let md = uwfq::bench::summary::summarize(&dirs)?;
    print!("{md}");
    if let Some(path) = cli.flag("out") {
        std::fs::write(path, &md).map_err(|e| format!("{path}: {e}"))?;
        println!("\nbench summary → {path}");
    }
    Ok(())
}

/// `uwfq replay` — streaming trace replay: the file is read in chunks,
/// shaped in one pass (running P² median filter, warmup-window
/// rebalance/rescale) and simulated with completions drained into
/// bounded-memory accumulators — O(warmup + in-flight) resident state
/// regardless of trace length. Emits `BENCH_replay.json`; `--grid` also
/// runs the generic policies × partitioners grid over the trace (the
/// materialized path, like `uwfq sweep --scenario trace`).
fn replay_cmd(cli: &Cli) -> Result<(), String> {
    let out = cli.flag_or("out", "out");
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;
    let cfg = cli.config()?;
    // Spec resolution mirrors `scale`: registry schema defaults ← quick
    // overrides ← config-file param.* ← --param flags ← --trace/--format
    // sugar; the simulated cluster size doubles as the shaping target.
    let mut spec = spec_with_quick("trace", cli.quick())?;
    spec.params.extend(cfg.scenario_params.iter().cloned());
    if let Some(path) = cli.flag("trace") {
        spec = spec.with("path", path);
    }
    if let Some(fmt) = cli.flag("format") {
        spec = spec.with("format", fmt);
    }
    // The simulated cluster size doubles as the shaping target — unless
    // the user pinned the shaping's cores param explicitly (later
    // overrides win, so appending here would clobber it).
    if !spec.params.iter().any(|(k, _)| k == "cores") {
        spec = spec.with("cores", &cfg.cores.to_string());
    }
    let params = uwfq::workload::registry::trace_params(&spec, cfg.seed)
        .map_err(|e| format!("{e}\n(usage: uwfq replay --trace FILE)"))?;
    println!(
        "replay: {} ({} shaping, warmup {} rows) on {} cores (policy {})",
        params.path,
        if params.shape { "one-pass §5.3" } else { "no" },
        params.shaping.warmup,
        cfg.cores,
        cfg.policy.name()
    );
    let outcome = uwfq::bench::replay::run_replay(&params, &cfg)?;
    print!("{}", uwfq::bench::replay::render(&outcome));

    let mut sink = JsonSink::new();
    uwfq::bench::replay::record_metrics(&outcome, &mut sink);
    let bench_path = cli.flag_or("bench-json", &format!("{out}/BENCH_replay.json"));
    sink.write(&bench_path).map_err(|e| e.to_string())?;
    println!("replay done → {bench_path}");

    if cli.flag("grid") == Some("true") {
        let par = Sweep::new(cli.threads(uwfq::sweep::auto_threads(None))?);
        scenario_sweep(&spec, &cfg, &par, &out)?;
    }
    Ok(())
}

/// `uwfq tracegen` — write a seeded synthetic trace (the gtrace
/// generator's raw unshaped tuples, native CSV, sorted by arrival) for
/// replay benches, CI smoke runs and fixtures. `--jobs N` solves the
/// window for a target row count; `--param k=v` overrides the gtrace
/// schema.
fn tracegen_cmd(cli: &Cli) -> Result<(), String> {
    let path = cli
        .positional
        .first()
        .ok_or("usage: uwfq tracegen FILE [--jobs N] [--param k=v ...]")?;
    let cfg = cli.config()?;
    let mut spec = ScenarioSpec::new("gtrace");
    if cli.quick() {
        spec = spec_with_quick("gtrace", true)?;
    }
    spec.params.extend(cfg.scenario_params.iter().cloned());
    let mut gp = uwfq::workload::registry::gtrace_params(&spec)?;
    if let Some(jobs) = cli.flag("jobs") {
        let jobs: u64 = jobs.parse().map_err(|_| format!("bad --jobs '{jobs}'"))?;
        gp = uwfq::workload::traceio::writer::params_for_jobs(jobs, &gp);
    }
    let rows = uwfq::workload::traceio::writer::write_synthetic(path, cfg.seed, &gp)?;
    println!(
        "tracegen: {rows} rows over {:.0} s ({} users, {} heavy) → {path}",
        gp.window_s, gp.users, gp.heavy_users
    );
    Ok(())
}

/// Resolve the scenario `uwfq run` should build: `--scenario NAME` (or a
/// config file's `scenario =` line) via [`Config::scenario`], the legacy
/// `--workload NAME` / `--workload trace:FILE` spelling, or the default
/// `scenario1`. Parameter overrides layer `defaults ← --quick ←
/// config-file param.* ← --param flags`.
fn scenario_spec(cli: &Cli, cfg: &Config) -> Result<ScenarioSpec, String> {
    let mut name = cfg.scenario.clone();
    let mut extra: Vec<(String, String)> = Vec::new();
    if let Some(wl) = cli.flag("workload") {
        if name.is_some() {
            return Err("use either --scenario or the legacy --workload, not both".into());
        }
        if let Some(path) = wl.strip_prefix("trace:") {
            name = Some("tracefile".to_string());
            extra.push(("path".to_string(), path.to_string()));
        } else {
            name = Some(wl.to_string());
        }
    }
    let name = name.unwrap_or_else(|| "scenario1".to_string());
    let mut spec = spec_with_quick(&name, cli.quick())?;
    spec.params.extend(cfg.scenario_params.iter().cloned());
    spec.params.extend(extra);
    Ok(spec)
}

/// `uwfq scenarios` — list every registry entry with its parameter
/// schema, defaults and quick-run overrides.
fn scenarios_cmd() -> Result<(), String> {
    let reg = Registry::global();
    println!("registered scenarios ({}):", reg.names().len());
    for sc in reg.iter() {
        println!("\n  {:<10} {}", sc.name(), sc.doc());
        for p in sc.schema() {
            println!(
                "      --param {}={}  [{}] {}",
                p.name,
                p.default,
                p.default.type_name(),
                p.doc
            );
        }
        if !sc.quick_overrides().is_empty() {
            let q: Vec<String> = sc
                .quick_overrides()
                .iter()
                .map(|&(k, v)| format!("{k}={v}"))
                .collect();
            println!("      --quick → {}", q.join(" "));
        }
    }
    println!("\nrun one:    uwfq run --scenario NAME --param k=v");
    println!("sweep one:  uwfq sweep --scenario NAME   (policies × partitioners)");
    Ok(())
}

fn analyze(cli: &Cli) -> Result<(), String> {
    // Post-hoc analysis of a JSON-lines event log (paper §5.1's trace
    // pipeline): `uwfq run --eventlog trace.jsonl` then `uwfq analyze
    // trace.jsonl`.
    let path = cli
        .positional
        .first()
        .ok_or("usage: uwfq analyze <trace.jsonl>")?;
    let events = uwfq::core::eventlog::read(path).map_err(|e| format!("{e:#}"))?;
    let s = uwfq::core::eventlog::analyze(&events).map_err(|e| format!("{e:#}"))?;
    println!("trace {path}: {} events", events.len());
    println!("  jobs {}   tasks {}", s.jobs, s.tasks);
    println!("  RT avg {:.2} s   worst-10% {:.2} s", s.mean_rt, s.worst10_rt);
    println!("  makespan {:.1} s   utilization {:.2}", s.makespan_s, s.utilization);
    for (user, rt) in &s.per_user_mean_rt {
        println!("  user {user:>3}: mean RT {rt:.2} s");
    }
    Ok(())
}

fn run(cli: &Cli) -> Result<(), String> {
    let mut cfg = cli.config()?;
    let eventlog = cli.flag("eventlog").map(|s| s.to_string());
    if eventlog.is_some() {
        cfg.log_tasks = true;
    }
    let spec = scenario_spec(cli, &cfg)?;
    let w = spec.workload(cfg.seed)?;
    println!(
        "scenario {}: {} jobs, {} users, {:.0} core-s of work",
        spec.name,
        w.jobs.len(),
        w.users().len(),
        w.total_slot_time()
    );
    let m = uwfq::bench::run_one(&cfg, &w);
    let ujf = uwfq::bench::run_ujf_reference(&cfg, &w);
    let f = fairness_vs_ujf(&m, &ujf, DvrDenominator::GreaterThanZero);
    println!("scheduler {}:", m.label);
    println!(
        "  makespan     {:.1} s   utilization {:.2}",
        m.makespan_s, m.utilization
    );
    println!(
        "  RT   avg {:.2} s   worst-10% {:.2} s",
        m.mean_rt(),
        m.worst10_rt()
    );
    println!(
        "  SL   avg {:.2}     worst-10% {:.2}",
        m.mean_slowdown(),
        m.worst10_slowdown()
    );
    println!(
        "  fairness vs UJF: DVR {:.2} ({} violations)  DSR {:.2} ({} slacks)",
        f.dvr, f.violations, f.dsr, f.slacks
    );
    if let Some(path) = eventlog {
        let rep = uwfq::sim::simulate(cfg.clone(), w.jobs.clone());
        let events = uwfq::core::eventlog::events_of_run(&w, &rep);
        uwfq::core::eventlog::write(&path, &events).map_err(|e| format!("{e:#}"))?;
        println!("  event log → {path} ({} events)", events.len());
    }
    Ok(())
}

fn serve(cli: &Cli) -> Result<(), String> {
    let mut cfg = cli.config()?;
    if cli.flag("cores").is_none() {
        cfg.cores = 4; // sensible real-backend default
    }
    let time_scale: f64 = cli
        .flag_or("time-scale", "0.05")
        .parse()
        .map_err(|_| "bad --time-scale".to_string())?;
    let default_dir = uwfq::runtime::ArtifactStore::default_dir();
    let artifacts = match cli.flag("artifacts") {
        Some(a) => a.to_string(),
        None => default_dir
            .to_str()
            .ok_or_else(|| {
                format!(
                    "default artifact dir {} is not valid UTF-8; pass --artifacts DIR",
                    default_dir.display()
                )
            })?
            .to_string(),
    };
    // A small two-user interactive-style workload.
    let mut jobs = Vec::new();
    for i in 0..4 {
        jobs.push(scenarios::micro_job(1, "tiny", i as f64 * 2.0, None));
    }
    jobs.push(scenarios::micro_job(2, "short", 1.0, None));
    println!(
        "serving {} jobs on {} real executor cores (policy {}, artifacts {artifacts})",
        jobs.len(),
        cfg.cores,
        cfg.policy.name()
    );
    let report = uwfq::exec::run_real(cfg, jobs, Path::new(&artifacts), time_scale)
        .map_err(|e| format!("{e:#}"))?;
    println!(
        "completed {} jobs in {:.2} s",
        report.completed.len(),
        report.makespan_s
    );
    for c in &report.completed {
        let out = report.results.get(&c.job);
        println!(
            "  job {} ({} / user {}): RT {:.2} s, result[mean0] = {}",
            c.job,
            c.name,
            c.user,
            c.response_time(),
            out.map(|o| format!("{:.4}", o[0]))
                .unwrap_or_else(|| "-".into())
        );
    }
    for (k, (mean_s, n)) in &report.task_wall {
        println!("  task wall time k={k}: {:.1} ms × {n}", mean_s * 1e3);
    }
    Ok(())
}

fn ablation(cli: &Cli) -> Result<(), String> {
    // Design-choice ablations (DESIGN.md §5): user-context vs job-context
    // vs both, and ATR sensitivity. Both grids route through the sweep
    // engine (`--threads N` parallelizes them, output unchanged).
    let base = cli.config()?;
    let seed = base.seed;
    let swp = Sweep::new(cli.threads(1)?);
    println!("== ablation: scheduler context (scenario 1) ==");
    println!("  CFQ   = job deadlines, no user context");
    println!("  UJF   = user fairness, no deadlines");
    println!("  UWFQ  = both (the paper's point)\n");
    let (s1, _) = tables::table1(seed, &base, &swp);
    println!("{}", tables::render_table1(&s1));

    println!("== ablation: ATR sensitivity (macro, UWFQ-P) ==");
    let wm = macro_workload(true, seed, &base)?;
    let atrs = [0.1, 0.25, 0.5, 1.0, 2.0, 5.0];
    let cells: Vec<Config> = atrs
        .iter()
        .map(|&atr| {
            let mut cfg = base
                .clone()
                .with_policy(uwfq::sched::PolicyKind::Uwfq)
                .with_scheme(uwfq::partition::SchemeKind::Runtime);
            cfg.atr = atr;
            cfg
        })
        .collect();
    let metrics = swp.run(&cells, |ctx, cfg| uwfq::bench::run_one_in(ctx, cfg, &wm));
    for (atr, m) in atrs.iter().zip(&metrics) {
        println!(
            "  ATR {atr:>5.2} s → RT avg {:.2} s, makespan {:.1} s",
            m.mean_rt(),
            m.makespan_s
        );
    }
    Ok(())
}
