//! Hand-rolled CLI (clap is not available offline): positional
//! subcommand + `--key value` flags, mapped onto [`Config`] keys plus a
//! few harness options.

use std::collections::BTreeMap;

use crate::config::Config;

#[derive(Clone, Debug)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const USAGE: &str = "\
uwfq — User Weighted Fair Queuing for multi-user Spark-like analytics
(reproduction of Kažemaks et al., 2025)

USAGE:
  uwfq reproduce <table1|table2|fig3|fig4|fig5|fig6|fig7|all> [--out DIR] [--seed N] [--quick true] [--threads N]
  uwfq sweep [--threads N] [--out DIR] [--seed N] [--quick true]  # full evaluation grid, all cores
  uwfq scale [--jobs N] [--users N] [--quick true] [--verify false] [--out DIR]
             # streaming million-job run: O(in-flight + users) memory,
             # emits BENCH_scale.json (defaults 1M jobs / 10k users;
             # --quick: 50k / 1k)
  uwfq run --workload <scenario1|scenario2|gtrace|trace:FILE> [--policy P] [--scheme S]
  uwfq serve [--cores N] [--time-scale F] [--artifacts DIR]   # real PJRT backend demo
  uwfq ablation [--seed N] [--threads N]                      # design-choice ablations
  uwfq run --workload scenario2 --eventlog trace.jsonl        # emit event log
  uwfq analyze trace.jsonl                                    # post-hoc trace analysis
  uwfq help

FLAGS (config keys, see config.rs):
  --cores N --atr S --grace_rsec S --task_overhead S --seed N
  --policy fifo|fair|ujf|cfq|uwfq --scheme default|runtime
  --estimator_sigma S --config FILE

  --threads N routes the experiment grid through the parallel sweep
  engine (N worker threads; 0 = all cores). Output is byte-identical to
  --threads 1; `reproduce` defaults to 1, `sweep` defaults to 0.
";

impl Cli {
    pub fn parse(args: &[String]) -> Result<Cli, String> {
        let mut it = args.iter();
        let command = it.next().cloned().unwrap_or_else(|| "help".to_string());
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let rest: Vec<&String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = rest[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = rest
                    .get(i + 1)
                    .ok_or_else(|| format!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.to_string());
                i += 2;
            } else {
                positional.push(a.to_string());
                i += 1;
            }
        }
        Ok(Cli {
            command,
            positional,
            flags,
        })
    }

    /// Build the engine config from `--config FILE` plus flag overrides.
    pub fn config(&self) -> Result<Config, String> {
        let mut cfg = match self.flags.get("config") {
            Some(path) => Config::from_file(path)?,
            None => Config::default(),
        };
        for (k, v) in &self.flags {
            match k.as_str() {
                // harness-only flags, not config keys
                "config" | "out" | "quick" | "workload" | "time-scale" | "artifacts"
                | "eventlog" | "threads" | "bench-json" | "jobs" | "users" | "verify" => {}
                _ => cfg.set(k, v)?,
            }
        }
        Ok(cfg)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    /// Resolve `--threads` into a worker count: absent → `default`
    /// (clamped ≥ 1 by [`crate::sweep::auto_threads`] semantics), `0` →
    /// all available cores, `N` → N.
    pub fn threads(&self, default: usize) -> Result<usize, String> {
        match self.flag("threads") {
            None => Ok(default.max(1)),
            Some(v) => {
                let n: usize = v.parse().map_err(|_| format!("bad --threads '{v}'"))?;
                Ok(crate::sweep::auto_threads(Some(n)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::SchemeKind;
    use crate::sched::PolicyKind;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = Cli::parse(&args("reproduce table1 --out results --seed 7")).unwrap();
        assert_eq!(c.command, "reproduce");
        assert_eq!(c.positional, vec!["table1"]);
        assert_eq!(c.flag("out"), Some("results"));
        let cfg = c.config().unwrap();
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn flags_override_config() {
        let c = Cli::parse(&args("run --policy cfq --scheme runtime --cores 8")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.policy, PolicyKind::Cfq);
        assert_eq!(cfg.scheme, SchemeKind::Runtime);
        assert_eq!(cfg.cores, 8);
    }

    #[test]
    fn missing_flag_value_errors() {
        assert!(Cli::parse(&args("run --policy")).is_err());
    }

    #[test]
    fn unknown_config_key_errors() {
        let c = Cli::parse(&args("run --bogus 1")).unwrap();
        assert!(c.config().is_err());
    }

    #[test]
    fn empty_args_give_help() {
        let c = Cli::parse(&[]).unwrap();
        assert_eq!(c.command, "help");
    }

    #[test]
    fn threads_flag_is_harness_only() {
        let c = Cli::parse(&args("sweep --threads 4 --cores 8")).unwrap();
        // Not a config key: config parses cleanly with --threads present.
        let cfg = c.config().unwrap();
        assert_eq!(cfg.cores, 8);
        assert_eq!(c.threads(1).unwrap(), 4);
        // Absent → default; 0 → all cores (≥ 1).
        let d = Cli::parse(&args("reproduce all")).unwrap();
        assert_eq!(d.threads(1).unwrap(), 1);
        let z = Cli::parse(&args("sweep --threads 0")).unwrap();
        assert!(z.threads(1).unwrap() >= 1);
        assert!(Cli::parse(&args("sweep --threads x")).unwrap().threads(1).is_err());
    }
}
