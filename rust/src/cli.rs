//! Hand-rolled CLI (clap is not available offline): positional
//! subcommand + `--key value` flags, mapped onto [`Config`] keys plus a
//! few harness options. `--param k=v` may repeat (scenario parameter
//! overrides, applied in order); the switch flags in [`SWITCH_FLAGS`]
//! may appear bare (`--quick` ≡ `--quick true`), every other flag
//! requires a value.

use std::collections::BTreeMap;

use crate::config::Config;

#[derive(Clone, Debug)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    /// Repeated `--param k=v` scenario overrides, in order of appearance.
    pub params: Vec<(String, String)>,
}

pub const USAGE: &str = "\
uwfq — User Weighted Fair Queuing for multi-user Spark-like analytics
(reproduction of Kažemaks et al., 2025)

USAGE:
  uwfq scenarios                               # list registered scenarios + params
  uwfq run --scenario NAME [--param k=v ...] [--quick] [--policy P] [--scheme S]
  uwfq reproduce <table1|table2|fig3|fig4|fig5|fig6|fig7|all> [--out DIR] [--seed N] [--quick] [--threads N]
  uwfq sweep [--scenario NAME] [--threads N] [--out DIR] [--seed N] [--quick]
             # full evaluation grid on all cores; with --scenario NAME,
             # the generic policy × partitioner grid for that scenario
  uwfq scale [--jobs N] [--users N] [--quick] [--verify false] [--out DIR]
             # streaming million-job run: O(in-flight + users) memory,
             # emits BENCH_scale.json (defaults 1M jobs / 10k users;
             # --quick: 50k / 1k)
  uwfq replay --trace FILE [--format native|gcluster] [--quick] [--grid] [--out DIR]
             # streaming trace replay with one-pass §5.3 shaping:
             # O(warmup + in-flight) memory, emits BENCH_replay.json;
             # --grid also sweeps the trace across policies × partitioners
  uwfq tracegen FILE [--jobs N] [--seed N] [--param k=v ...]
             # write a seeded synthetic trace (gtrace raw tuples, native
             # CSV) for replay benches and fixtures
  uwfq fault [--quick] [--threads N] [--out DIR] [--seed N]
             # fairness-under-failure degradation curves: UWFQ/Fair/FIFO
             # across failure rates + straggler + crash arms, emits
             # BENCH_fault.json
  uwfq drf [--quick] [--threads N] [--out DIR] [--seed N]
             # multi-resource grids: all seven policies over a mixed
             # CPU/memory-demand workload, plus the UWFQ-vs-BoPF
             # burst-tolerance ablation on the bursty scenario, emits
             # BENCH_drf.json
  uwfq hotpath [--quick] [--out DIR] [--cores N]
             # event-core throughput: wheel vs heap event queues plus a
             # batching on/off ablation per policy, emits
             # BENCH_hotpath.json (UWFQ_EVENT_HEAP=1 benches the
             # escape-hatch default)
  uwfq shard [--quick] [--shards N] [--jobs N] [--users N] [--out DIR] [--skew]
             # sharded engine bench: federated virtual time over
             # hash-partitioned users, one event loop per shard; sweeps
             # shard counts (or just --shards N), reports jobs/s and
             # speedup vs the 1-shard baseline plus the observed
             # virtual-time drift, emits BENCH_shard.json. --skew runs
             # the Zipfian `skewed` scenario instead and ablates
             # cross-shard core lending on/off per shard count
             # (`speedup_vs_static`); `--shard_rebalance false` keeps
             # only the static arm
  uwfq benchsummary [DIR ...] [--out FILE]
             # merge every BENCH_*.json found in the given dirs (default:
             # out/ then .) into one markdown perf-trajectory table
  uwfq serve [--cores N] [--time-scale F] [--artifacts DIR]   # real PJRT backend demo
  uwfq ablation [--seed N] [--threads N]                      # design-choice ablations
  uwfq run --scenario scenario2 --eventlog trace.jsonl        # emit event log
  uwfq analyze trace.jsonl                                    # post-hoc trace analysis
  uwfq help

FLAGS (config keys, see config.rs):
  --cores N --atr S --grace_rsec S --bopf_burst_rsec S --task_overhead S --seed N
  --policy fifo|fair|ujf|cfq|uwfq|drf|bopf --scheme default|runtime|-P
  --estimator_sigma S --config FILE
  --scenario NAME --param k=v   (repeatable; `uwfq scenarios` lists them;
  config files spell these `scenario = NAME` and `param.k = v`)
  --fault.task_fail_prob P --fault.max_failures N --fault.retry_backoff_s S
  --fault.straggler_prob P --fault.straggler_mult M --fault.spec_mult M
  --fault.crash_mttf_s S --fault.crash_recover_s S --fault.seed N
             (deterministic fault injection; all rates default to 0 = off)

  --threads N routes the experiment grid through the parallel sweep
  engine (N worker threads; 0 = all cores). Output is byte-identical to
  --threads 1; `reproduce` defaults to 1, `sweep` defaults to 0.

  --shards N splits one run into N parallel event loops over
  hash-partitioned users (config key `shards`; `shard_epoch_s` sets the
  virtual-time sync epoch). --shards 1 is byte-identical to the
  unsharded engine. threads x shards is capped at the machine's
  available parallelism — the harness trims --threads (with a warning)
  rather than oversubscribe.

  --shard_rebalance true|false turns on deterministic cross-shard core
  lending at each shard epoch barrier (default false = byte-identical
  static split); --rebalance_min_cores N keeps a per-shard floor and
  --rebalance_cap N caps cores migrated per epoch.
";

/// Flags that are boolean switches: bare `--quick` reads as
/// `--quick true`. Every other flag still requires an explicit value, so
/// a forgotten value (`--out` at the end of the line) stays a hard error
/// instead of silently becoming the string "true".
const SWITCH_FLAGS: [&str; 4] = ["quick", "verify", "grid", "skew"];

impl Cli {
    pub fn parse(args: &[String]) -> Result<Cli, String> {
        let mut it = args.iter();
        let command = it.next().cloned().unwrap_or_else(|| "help".to_string());
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut params = Vec::new();
        let rest: Vec<&String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = rest[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = if SWITCH_FLAGS.contains(&key) {
                    // Switch flags consume a value only when it is an
                    // explicit true/false — `--quick table2` must leave
                    // `table2` as a positional, not swallow it.
                    match rest.get(i + 1).map(|v| v.as_str()) {
                        Some(v) if v == "true" || v == "false" => {
                            i += 2;
                            v.to_string()
                        }
                        _ => {
                            i += 1;
                            "true".to_string()
                        }
                    }
                } else {
                    match rest.get(i + 1) {
                        Some(v) if !v.starts_with("--") => {
                            i += 2;
                            v.to_string()
                        }
                        _ => return Err(format!("flag --{key} needs a value")),
                    }
                };
                if key == "param" {
                    let (k, v) = val
                        .split_once('=')
                        .ok_or_else(|| format!("--param expects k=v, got '{val}'"))?;
                    params.push((k.trim().to_string(), v.trim().to_string()));
                } else {
                    flags.insert(key.to_string(), val);
                }
            } else {
                positional.push(a.to_string());
                i += 1;
            }
        }
        Ok(Cli {
            command,
            positional,
            flags,
            params,
        })
    }

    /// Build the engine config from `--config FILE` plus flag overrides;
    /// `--param` overrides append after any config-file `param.*` lines
    /// (later wins when the scenario's schema is applied).
    pub fn config(&self) -> Result<Config, String> {
        let mut cfg = match self.flags.get("config") {
            Some(path) => Config::from_file(path)?,
            None => Config::default(),
        };
        for (k, v) in &self.flags {
            match k.as_str() {
                // harness-only flags, not config keys ("workload" is the
                // legacy spelling of --scenario, resolved in main::run)
                "config" | "out" | "quick" | "workload" | "time-scale" | "artifacts"
                | "eventlog" | "threads" | "bench-json" | "jobs" | "users" | "verify"
                | "trace" | "format" | "grid" | "skew" => {}
                _ => cfg.set(k, v)?,
            }
        }
        cfg.scenario_params.extend(self.params.iter().cloned());
        Ok(cfg)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    /// True when `--quick` (or `--quick true`) was passed.
    pub fn quick(&self) -> bool {
        self.flag("quick") == Some("true")
    }

    /// Resolve `--threads` into a worker count: absent → `default`
    /// (clamped ≥ 1 by [`crate::sweep::auto_threads`] semantics), `0` →
    /// all available cores, `N` → N.
    pub fn threads(&self, default: usize) -> Result<usize, String> {
        match self.flag("threads") {
            None => Ok(default.max(1)),
            Some(v) => {
                let n: usize = v.parse().map_err(|_| format!("bad --threads '{v}'"))?;
                Ok(crate::sweep::auto_threads(Some(n)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::SchemeKind;
    use crate::sched::PolicyKind;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = Cli::parse(&args("reproduce table1 --out results --seed 7")).unwrap();
        assert_eq!(c.command, "reproduce");
        assert_eq!(c.positional, vec!["table1"]);
        assert_eq!(c.flag("out"), Some("results"));
        let cfg = c.config().unwrap();
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn flags_override_config() {
        let c = Cli::parse(&args("run --policy cfq --scheme runtime --cores 8")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.policy, PolicyKind::Cfq);
        assert_eq!(cfg.scheme, SchemeKind::Runtime);
        assert_eq!(cfg.cores, 8);
    }

    #[test]
    fn switch_flags_and_missing_values() {
        // Value-taking flags still hard-error when the value is missing.
        let err = Cli::parse(&args("run --policy")).unwrap_err();
        assert!(err.contains("--policy needs a value"), "{err}");
        assert!(Cli::parse(&args("reproduce all --out")).is_err());
        // Switch flags work bare, trailing or mid-line.
        let c = Cli::parse(&args("run --quick --seed 3")).unwrap();
        assert!(c.quick());
        assert_eq!(c.config().unwrap().seed, 3);
        let c = Cli::parse(&args("reproduce table2 --quick")).unwrap();
        assert!(c.quick());
        // A bare switch before a positional must not swallow it.
        let c = Cli::parse(&args("reproduce --quick table2")).unwrap();
        assert!(c.quick());
        assert_eq!(c.positional, vec!["table2"]);
        // Explicit values still accepted.
        assert!(Cli::parse(&args("scale --verify false")).unwrap().flag("verify")
            == Some("false"));
    }

    #[test]
    fn fault_flags_route_to_config() {
        let c = Cli::parse(&args("run --fault.task_fail_prob 0.05 --fault.seed 9")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.fault.task_fail_prob, 0.05);
        assert_eq!(cfg.fault.seed, 9);
        assert!(cfg.fault.enabled());
        // Out-of-range values error with the knob named.
        let c = Cli::parse(&args("run --fault.task_fail_prob 1.5")).unwrap();
        let err = c.config().unwrap_err();
        assert!(err.contains("task_fail_prob"), "{err}");
    }

    #[test]
    fn shards_flag_routes_to_config() {
        let c = Cli::parse(&args("shard --shards 4 --shard_epoch_s 2.0 --cores 8")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.shard_epoch_s, 2.0);
        // Invalid shard counts surface the config error (naming threads).
        let c = Cli::parse(&args("shard --shards 0")).unwrap();
        let err = c.config().unwrap_err();
        assert!(err.contains("shards") && err.contains("threads"), "{err}");
    }

    #[test]
    fn skew_flag_is_a_harness_switch() {
        let c = Cli::parse(&args("shard --skew --shards 8 --cores 8")).unwrap();
        assert_eq!(c.flag("skew"), Some("true"));
        // Harness-only: config still parses, shards routed normally.
        assert_eq!(c.config().unwrap().shards, 8);
        // Bare --skew before a positional must not swallow it.
        let c = Cli::parse(&args("shard --skew extra")).unwrap();
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn unknown_config_key_errors() {
        let c = Cli::parse(&args("run --bogus 1")).unwrap();
        assert!(c.config().is_err());
    }

    #[test]
    fn empty_args_give_help() {
        let c = Cli::parse(&[]).unwrap();
        assert_eq!(c.command, "help");
    }

    #[test]
    fn scenario_and_repeated_params() {
        let c = Cli::parse(&args(
            "run --scenario bursty --param rate=4 --param burst_ratio=0.25 --cores 8",
        ))
        .unwrap();
        assert_eq!(
            c.params,
            vec![
                ("rate".to_string(), "4".to_string()),
                ("burst_ratio".to_string(), "0.25".to_string()),
            ]
        );
        let cfg = c.config().unwrap();
        assert_eq!(cfg.scenario.as_deref(), Some("bursty"));
        assert_eq!(cfg.scenario_params, c.params);
        assert_eq!(cfg.cores, 8);
        // Malformed --param errors at parse time.
        assert!(Cli::parse(&args("run --param notkv")).is_err());
    }

    #[test]
    fn replay_flags_are_harness_only() {
        let c = Cli::parse(&args("replay --trace t.csv --format native --grid --cores 8"))
            .unwrap();
        assert_eq!(c.flag("trace"), Some("t.csv"));
        assert_eq!(c.flag("format"), Some("native"));
        assert_eq!(c.flag("grid"), Some("true"));
        // None of them are config keys.
        assert_eq!(c.config().unwrap().cores, 8);
        // --trace still requires a value.
        assert!(Cli::parse(&args("replay --trace")).is_err());
        // Bare --grid before a positional must not swallow it.
        let c = Cli::parse(&args("replay --grid x.csv")).unwrap();
        assert_eq!(c.positional, vec!["x.csv"]);
    }

    #[test]
    fn threads_flag_is_harness_only() {
        let c = Cli::parse(&args("sweep --threads 4 --cores 8")).unwrap();
        // Not a config key: config parses cleanly with --threads present.
        let cfg = c.config().unwrap();
        assert_eq!(cfg.cores, 8);
        assert_eq!(c.threads(1).unwrap(), 4);
        // Absent → default; 0 → all cores (≥ 1).
        let d = Cli::parse(&args("reproduce all")).unwrap();
        assert_eq!(d.threads(1).unwrap(), 1);
        let z = Cli::parse(&args("sweep --threads 0")).unwrap();
        assert!(z.threads(1).unwrap() >= 1);
        assert!(Cli::parse(&args("sweep --threads x")).unwrap().threads(1).is_err());
    }
}
