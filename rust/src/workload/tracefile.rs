//! CSV trace loader — drop-in path for a real WTA (Workflow Trace
//! Archive) export so the macro benchmark can run on the actual Google
//! trace instead of the shaped generator.
//!
//! Format (header required):
//! ```text
//! job,user,arrival_s,slot_s,stages,heavy
//! g0,3,12.5,140.0,2,1
//! ```
//! `stages` ∈ 1..=8 builds a linear chain; `heavy` ∈ {0,1} sets the user
//! class.

use super::{UserClass, Workload};
use crate::core::job::{CostProfile, JobSpec, StagePhase, StageSpec};
use crate::s_to_us;
use std::collections::HashMap;

/// Deterministic trace job: an `nstages`-long linear chain splitting
/// `slot` evenly, uniform cost, no RNG. Shared by this loader and the
/// raw (unshaped) replay path of [`crate::workload::traceio`], which is
/// what lets the golden-fixture test demand byte-identical `SimReport`s
/// between the two parsers.
pub(crate) fn flat_job(
    user: u32,
    name: &str,
    arrival_s: f64,
    slot: f64,
    nstages: usize,
) -> JobSpec {
    let per = slot / nstages as f64;
    let bytes = (((slot * 8.0) as u64) << 20).max(32 << 20);
    let stages: Vec<StageSpec> = (0..nstages)
        .map(|i| StageSpec {
            phase: StagePhase::Generic,
            parents: if i == 0 { vec![] } else { vec![i - 1] },
            is_leaf_input: i == 0,
            input_bytes: bytes,
            slot_time: per,
            cost: CostProfile::uniform(),
            max_parallelism: None,
            opcount: 4,
            demand: crate::core::task::ResourceVec::UNIT,
        })
        .collect();
    JobSpec {
        user,
        name: name.into(),
        arrival: s_to_us(arrival_s),
        weight: 1.0,
        stages,
    }
}

pub fn load_csv(text: &str) -> Result<Workload, String> {
    let mut lines = text.lines().enumerate();
    let (_, header) = lines.next().ok_or("empty trace")?;
    let cols: Vec<&str> = header.trim().split(',').map(|c| c.trim()).collect();
    let expect = ["job", "user", "arrival_s", "slot_s", "stages", "heavy"];
    if cols != expect {
        return Err(format!("bad header {cols:?}, expected {expect:?}"));
    }

    let mut jobs = Vec::new();
    let mut user_class = HashMap::new();
    for (ln, raw) in lines {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
        if f.len() != 6 {
            return Err(format!("line {}: expected 6 fields", ln + 1));
        }
        let name = f[0].to_string();
        let user: u32 = f[1].parse().map_err(|_| format!("line {}: bad user", ln + 1))?;
        let arrival: f64 = f[2]
            .parse()
            .map_err(|_| format!("line {}: bad arrival_s", ln + 1))?;
        let slot: f64 = f[3]
            .parse()
            .map_err(|_| format!("line {}: bad slot_s", ln + 1))?;
        let nstages: usize = f[4]
            .parse()
            .map_err(|_| format!("line {}: bad stages", ln + 1))?;
        let heavy = f[5] == "1";
        if !(1..=8).contains(&nstages) {
            return Err(format!("line {}: stages out of range", ln + 1));
        }
        if slot <= 0.0 || arrival < 0.0 {
            return Err(format!("line {}: nonpositive slot or negative arrival", ln + 1));
        }
        user_class.insert(
            user,
            if heavy { UserClass::Heavy } else { UserClass::Light },
        );
        jobs.push(flat_job(user, &name, arrival, slot, nstages));
    }
    if jobs.is_empty() {
        return Err("trace has no jobs".into());
    }
    Ok(Workload {
        name: "tracefile".into(),
        jobs,
        user_class,
    })
}

pub fn load_csv_file(path: &str) -> Result<Workload, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    load_csv(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
job,user,arrival_s,slot_s,stages,heavy
g0,1,0.0,100.0,2,1
g1,2,5.5,10.0,1,0
# comment line
g2,1,9.0,40.0,3,1
";

    #[test]
    fn parses_sample() {
        let w = load_csv(SAMPLE).unwrap();
        assert_eq!(w.jobs.len(), 3);
        assert_eq!(w.user_class[&1], UserClass::Heavy);
        assert_eq!(w.user_class[&2], UserClass::Light);
        assert_eq!(w.jobs[2].stages.len(), 3);
        assert!((w.jobs[0].slot_time() - 100.0).abs() < 1e-9);
        w.jobs.iter().for_each(|j| j.validate().unwrap());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(load_csv("").is_err());
        assert!(load_csv("x,y\n").is_err());
        assert!(load_csv("job,user,arrival_s,slot_s,stages,heavy\n").is_err());
        assert!(load_csv("job,user,arrival_s,slot_s,stages,heavy\na,1,0,0,1,0\n").is_err());
        assert!(load_csv("job,user,arrival_s,slot_s,stages,heavy\na,1,0,5,9,0\n").is_err());
        assert!(load_csv("job,user,arrival_s,slot_s,stages,heavy\na,x,0,5,1,0\n").is_err());
    }

    #[test]
    fn stream_yields_sorted_sample() {
        // The streamed form is `Workload::into_stream` (what the registry
        // entry hands out): sorted by arrival.
        use crate::workload::stream::JobStream;
        let mut s = load_csv(SAMPLE).unwrap().into_stream();
        assert_eq!(s.size_hint(), Some(3));
        let mut last = 0;
        while let Some(j) = s.next_job() {
            assert!(j.arrival >= last);
            last = j.arrival;
        }
    }

    #[test]
    fn roundtrip_via_file() {
        let dir = std::env::temp_dir().join("uwfq_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        std::fs::write(&p, SAMPLE).unwrap();
        let w = load_csv_file(p.to_str().unwrap()).unwrap();
        assert_eq!(w.jobs.len(), 3);
        std::fs::remove_dir_all(dir).ok();
    }
}
