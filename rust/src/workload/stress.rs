//! Stress scenarios beyond the paper's evaluation — pure registry
//! entries (`bursty`, `heavytail`, `diurnal`) that exist to probe where
//! fair schedulers actually break:
//!
//! * [`bursty`] — BoPF-style on/off users (Le et al., *BoPF: Mitigating
//!   the Burstiness-Fairness Tradeoff in Multi-Resource Clusters*):
//!   synchronized burst windows with a configurable burst ratio, over a
//!   background of steady Poisson users.
//! * [`heavytail`] — Pareto job sizes with tunable shape `alpha`; the
//!   smaller `alpha`, the more a handful of elephants dominates, which is
//!   where size-oblivious fairness policies starve small jobs.
//! * [`diurnal`] — sinusoidal-rate Poisson arrivals (thinning method):
//!   the load swings between trough and peak every period, exercising
//!   schedulers across utilization regimes inside a single run.
//! * [`skewed`] — Zipfian per-user submission rates: a small head of
//!   `hot_users` carries almost all jobs while a long tail idles. Under
//!   the sharded engine this is the adversarial partition — the heavy
//!   users hash onto few shards and pin them while siblings starve —
//!   which is exactly what cross-shard core lending
//!   ([`crate::sim::rebalance_cores`]) exists to fix.
//!
//! Each is defined once as per-user lazy generators k-way merged in
//! arrival order ([`MergeStream`]) — O(users) resident state — and is
//! immediately sweepable across every policy × partitioner through the
//! registry with zero bench-layer code.

use super::gtrace::trace_job;
use super::scenarios::micro_job;
use super::stream::{from_fn, JobStream, MergeStream};
use super::UserClass;
use crate::util::Rng;
use crate::UserId;
use std::collections::HashMap;

// ---------------------------------------------------------------------------
// bursty — on/off users with a configurable burst ratio
// ---------------------------------------------------------------------------

/// Parameters of the [`bursty`] scenario.
#[derive(Clone, Debug)]
pub struct BurstyParams {
    /// On/off (bursty) users; class `Frequent`.
    pub users: u32,
    /// Steady background Poisson users; class `Infrequent`.
    pub steady_users: u32,
    pub duration_s: f64,
    /// On/off cycle length.
    pub cycle_s: f64,
    /// Fraction of each cycle the bursty users are ON, in (0, 1].
    pub burst_ratio: f64,
    /// Poisson submission rate (jobs/s per user) while ON.
    pub rate: f64,
    /// Mean submission gap of the steady users (seconds).
    pub steady_gap_s: f64,
    /// Memory demand fraction of the bursty users' tasks, in (0, 1].
    /// `1.0` (the default) keeps every job on the legacy unit vector;
    /// lower values make the bursts memory-light so DRF/BoPF can pack
    /// them differently from the unit-demand background.
    pub mem_frac: f64,
}

impl Default for BurstyParams {
    fn default() -> Self {
        BurstyParams {
            users: 4,
            steady_users: 2,
            duration_s: 300.0,
            cycle_s: 60.0,
            burst_ratio: 0.1,
            rate: 2.0,
            steady_gap_s: 40.0,
            mem_frac: 1.0,
        }
    }
}

/// **Bursty** — `users` on/off users submit short jobs at `rate` jobs/s
/// during the first `burst_ratio` of every `cycle_s` window (bursts are
/// synchronized across users, the adversarial case for fair queuing),
/// while `steady_users` background users trickle tiny jobs the whole
/// time.
pub fn bursty(seed: u64, p: &BurstyParams) -> Result<MergeStream, String> {
    if p.users == 0 {
        return Err("bursty: users must be >= 1".into());
    }
    if !(p.burst_ratio > 0.0 && p.burst_ratio <= 1.0) {
        return Err(format!("bursty: burst_ratio {} outside (0, 1]", p.burst_ratio));
    }
    if p.cycle_s <= 0.0 || p.rate <= 0.0 || p.steady_gap_s <= 0.0 || p.duration_s <= 0.0 {
        return Err(
            "bursty: duration_s, cycle_s, rate and steady_gap_s must be positive".into(),
        );
    }
    if !(p.mem_frac > 0.0 && p.mem_frac <= 1.0) {
        return Err(format!("bursty: mem_frac {} outside (0, 1]", p.mem_frac));
    }
    let mut rng = Rng::new(seed);
    let mut streams: Vec<Box<dyn JobStream + Send>> = Vec::new();

    let on_len = p.cycle_s * p.burst_ratio;
    for user in 1..=p.users {
        let mut r = rng.fork(user as u64);
        let (duration_s, cycle_s) = (p.duration_s, p.cycle_s);
        let (rate, mem_frac) = (p.rate, p.mem_frac);
        let mut cycle_start = 0.0;
        let mut t = r.exp(rate);
        streams.push(Box::new(from_fn(move || loop {
            if cycle_start >= duration_s {
                return None;
            }
            // Yield only inside the ON window; arrivals that overshoot it
            // are discarded and the generator jumps to the next cycle, so
            // yields are strictly nondecreasing (on_len <= cycle_s).
            if t < cycle_start + on_len && t < duration_s {
                let mut job = micro_job(user, "short", t, None);
                if mem_frac < 1.0 {
                    // Bursty users' tasks are memory-light; the unit
                    // default leaves the legacy byte-identical path.
                    job = job
                        .with_demand(crate::core::task::ResourceVec::new(1.0, mem_frac));
                }
                t += r.exp(rate);
                return Some(job);
            }
            cycle_start += cycle_s;
            t = cycle_start + r.exp(rate);
        })));
    }

    for i in 0..p.steady_users {
        let user = p.users + 1 + i;
        let mut r = rng.fork(0x57EAD ^ user as u64);
        let (duration_s, gap) = (p.duration_s, p.steady_gap_s);
        let mut t = r.exp(1.0 / gap);
        streams.push(Box::new(from_fn(move || {
            if t >= duration_s {
                return None;
            }
            let job = micro_job(user, "tiny", t, None);
            t += r.exp(1.0 / gap);
            Some(job)
        })));
    }

    Ok(MergeStream::new(streams))
}

/// [`bursty`]'s user classification: bursty users `Frequent`, steady
/// background users `Infrequent`.
pub fn bursty_classes(p: &BurstyParams) -> HashMap<UserId, UserClass> {
    let mut m = HashMap::new();
    for u in 1..=p.users {
        m.insert(u, UserClass::Frequent);
    }
    for i in 0..p.steady_users {
        m.insert(p.users + 1 + i, UserClass::Infrequent);
    }
    m
}

// ---------------------------------------------------------------------------
// heavytail — Pareto job sizes, tunable alpha
// ---------------------------------------------------------------------------

/// Parameters of the [`heavytail`] scenario.
#[derive(Clone, Debug)]
pub struct HeavytailParams {
    pub users: u32,
    pub jobs_per_user: u32,
    /// Mean Poisson submission gap per user (seconds).
    pub mean_gap_s: f64,
    /// Pareto shape; smaller = heavier tail (alpha <= 1 has infinite
    /// mean, hence the cap).
    pub alpha: f64,
    /// Pareto scale — the minimum job size (core-seconds).
    pub min_slot: f64,
    /// Size cap (core-seconds), so pathological draws stay simulable.
    pub cap_slot: f64,
    /// Fraction of stages given a skewed cost profile (as in gtrace).
    pub skew_fraction: f64,
}

impl Default for HeavytailParams {
    fn default() -> Self {
        HeavytailParams {
            users: 8,
            jobs_per_user: 50,
            mean_gap_s: 5.0,
            alpha: 1.5,
            min_slot: 2.0,
            cap_slot: 3600.0,
            skew_fraction: 0.2,
        }
    }
}

/// **Heavytail** — every user submits Poisson-spaced jobs whose sizes are
/// Pareto(`alpha`, `min_slot`) core-seconds (capped at `cap_slot`). Jobs
/// reuse the gtrace stage-chain shape (1–3 linear stages, size-scaled
/// inputs), so the partitioners see the same structure the paper's macro
/// workload has — only the size law changes.
pub fn heavytail(seed: u64, p: &HeavytailParams) -> Result<MergeStream, String> {
    if p.users == 0 {
        return Err("heavytail: users must be >= 1".into());
    }
    if p.alpha <= 0.0 || p.min_slot <= 0.0 || p.mean_gap_s <= 0.0 {
        return Err("heavytail: alpha, min_slot and mean_gap_s must be positive".into());
    }
    if p.cap_slot < p.min_slot {
        return Err(format!(
            "heavytail: cap_slot {} below min_slot {}",
            p.cap_slot, p.min_slot
        ));
    }
    let mut rng = Rng::new(seed);
    let streams: Vec<Box<dyn JobStream + Send>> = (1..=p.users)
        .map(|user| {
            let mut r = rng.fork(user as u64);
            let p = p.clone();
            let mut t = r.exp(1.0 / p.mean_gap_s);
            let mut i = 0u32;
            Box::new(from_fn(move || {
                if i >= p.jobs_per_user {
                    return None;
                }
                let slot = r.pareto(p.alpha, p.min_slot).min(p.cap_slot);
                let name = format!("ht{user}-{i}");
                let job = trace_job(user, &name, t, slot, &mut r, p.skew_fraction);
                t += r.exp(1.0 / p.mean_gap_s);
                i += 1;
                Some(job)
            })) as Box<dyn JobStream + Send>
        })
        .collect();
    Ok(MergeStream::new(streams))
}

/// [`heavytail`]'s classification: every user draws from the same
/// heavy-tailed law, so all are `Heavy`.
pub fn heavytail_classes(p: &HeavytailParams) -> HashMap<UserId, UserClass> {
    (1..=p.users).map(|u| (u, UserClass::Heavy)).collect()
}

// ---------------------------------------------------------------------------
// diurnal — sinusoidal-rate Poisson arrivals
// ---------------------------------------------------------------------------

/// Parameters of the [`diurnal`] scenario.
#[derive(Clone, Debug)]
pub struct DiurnalParams {
    pub users: u32,
    pub duration_s: f64,
    /// Sinusoid period (one "day").
    pub period_s: f64,
    /// Rate swing in [0, 1): rate(t) = mean_rate · (1 + amplitude·sin).
    pub amplitude: f64,
    /// Mean submission rate per user (jobs/s), averaged over a period.
    pub mean_rate: f64,
    /// Fraction of tiny (vs short) jobs.
    pub tiny_fraction: f64,
}

impl Default for DiurnalParams {
    fn default() -> Self {
        DiurnalParams {
            users: 6,
            duration_s: 600.0,
            period_s: 240.0,
            amplitude: 0.8,
            mean_rate: 0.05,
            tiny_fraction: 0.7,
        }
    }
}

/// **Diurnal** — each user is a non-homogeneous Poisson process with rate
/// `mean_rate · (1 + amplitude · sin(2π·t/period))`, sampled by the
/// thinning method: propose at the peak rate, accept with probability
/// `rate(t)/rate_max`. All users share the phase (everyone's day peaks
/// together), so the cluster swings between near-idle troughs and
/// oversubscribed peaks within one run.
pub fn diurnal(seed: u64, p: &DiurnalParams) -> Result<MergeStream, String> {
    if p.users == 0 {
        return Err("diurnal: users must be >= 1".into());
    }
    if !(0.0..1.0).contains(&p.amplitude) {
        return Err(format!("diurnal: amplitude {} outside [0, 1)", p.amplitude));
    }
    if p.mean_rate <= 0.0 || p.period_s <= 0.0 || p.duration_s <= 0.0 {
        return Err("diurnal: duration_s, mean_rate and period_s must be positive".into());
    }
    if !(0.0..=1.0).contains(&p.tiny_fraction) {
        return Err(format!("diurnal: tiny_fraction {} outside [0, 1]", p.tiny_fraction));
    }
    let rate_max = p.mean_rate * (1.0 + p.amplitude);
    let mut rng = Rng::new(seed);
    let streams: Vec<Box<dyn JobStream + Send>> = (1..=p.users)
        .map(|user| {
            let mut r = rng.fork(user as u64);
            let p = p.clone();
            let mut t = 0.0f64;
            Box::new(from_fn(move || loop {
                t += r.exp(rate_max);
                if t >= p.duration_s {
                    return None;
                }
                let phase = 2.0 * std::f64::consts::PI * t / p.period_s;
                let rate = p.mean_rate * (1.0 + p.amplitude * phase.sin());
                if r.f64() * rate_max < rate {
                    let kind = if r.f64() < p.tiny_fraction { "tiny" } else { "short" };
                    return Some(micro_job(user, kind, t, None));
                }
            })) as Box<dyn JobStream + Send>
        })
        .collect();
    Ok(MergeStream::new(streams))
}

/// [`diurnal`]'s classification: every user submits around the clock —
/// all `Frequent`.
pub fn diurnal_classes(p: &DiurnalParams) -> HashMap<UserId, UserClass> {
    (1..=p.users).map(|u| (u, UserClass::Frequent)).collect()
}

// ---------------------------------------------------------------------------
// skewed — Zipfian per-user rates, tunable head size and exponent
// ---------------------------------------------------------------------------

/// Per-job slot-time draw for [`skewed`]: uniform over this range, so the
/// mean `(min + max) / 2` is analytically known and the window sizing
/// below hits `target_utilization` in expectation.
const SKEWED_SLOT_MIN_S: f64 = 0.5;
const SKEWED_SLOT_MAX_S: f64 = 6.5;

/// Parameters of the [`skewed`] scenario.
#[derive(Clone, Debug)]
pub struct SkewedParams {
    /// Total user population (hot head + cold tail).
    pub users: u32,
    /// Total jobs across all users (apportioned by the Zipf law).
    pub jobs: u64,
    /// Zipf exponent of the head: user `k` (1-based, `k <= hot_users`)
    /// gets weight `k^-zipf_s`. Larger = steeper skew.
    pub zipf_s: f64,
    /// Head size: users `1..=hot_users` follow the Zipf law; the entire
    /// tail *shares* the next rank's weight `(hot_users+1)^-zipf_s`, so
    /// the head dominates regardless of tail size.
    pub hot_users: u32,
    /// Cluster cores the window is sized for.
    pub cores: u32,
    /// Offered load as a fraction of `cores` capacity, in (0, 1].
    pub target_utilization: f64,
    /// Fraction of stages given a skewed cost profile (as in gtrace).
    pub skew_fraction: f64,
}

impl Default for SkewedParams {
    fn default() -> Self {
        SkewedParams {
            users: 400,
            jobs: 20_000,
            zipf_s: 1.2,
            hot_users: 16,
            cores: 8,
            target_utilization: 0.7,
            skew_fraction: 0.2,
        }
    }
}

/// Zipf-head weights: `k^-zipf_s` for the head, one extra rank's weight
/// split evenly across the whole tail.
fn zipf_weights(p: &SkewedParams) -> Vec<f64> {
    let n = p.users as usize;
    let h = (p.hot_users as usize).min(n);
    let mut w: Vec<f64> = (1..=h).map(|k| (k as f64).powf(-p.zipf_s)).collect();
    if n > h {
        let each = ((h + 1) as f64).powf(-p.zipf_s) / (n - h) as f64;
        w.resize(n, each);
    }
    w
}

/// Largest-remainder apportionment of `total` jobs over `weights`:
/// floors first, then the largest fractional parts (ties → lower index)
/// absorb the remainder, so counts always sum to exactly `total`.
fn apportion_jobs(total: u64, weights: &[f64]) -> Vec<u64> {
    let sum: f64 = weights.iter().sum();
    let mut counts: Vec<u64> = Vec::with_capacity(weights.len());
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(weights.len());
    let mut assigned = 0u64;
    for (i, &w) in weights.iter().enumerate() {
        let quota = total as f64 * w / sum;
        let base = quota.floor() as u64;
        counts.push(base);
        assigned += base;
        fracs.push((quota - base as f64, i));
    }
    fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let mut left = total.saturating_sub(assigned);
    let mut i = 0usize;
    while left > 0 {
        counts[fracs[i % fracs.len()].1] += 1;
        left -= 1;
        i += 1;
    }
    counts
}

/// **Skewed** — `users` Poisson users whose per-user job counts follow a
/// Zipf law over a `hot_users`-sized head (exponent `zipf_s`); the tail
/// shares a single rank's weight, so the head carries ~all of the work.
/// Every user's jobs reuse the gtrace stage-chain shape with slot-times
/// uniform in `[0.5, 6.5]` s; the submission window is sized so the whole
/// stream offers `target_utilization` of `cores`. Determinism: per-user
/// forked RNG streams, k-way merged in arrival order.
pub fn skewed(seed: u64, p: &SkewedParams) -> Result<MergeStream, String> {
    if p.users == 0 {
        return Err("skewed: users must be >= 1".into());
    }
    if p.hot_users == 0 || p.hot_users > p.users {
        return Err(format!(
            "skewed: hot_users {} outside 1..=users ({})",
            p.hot_users, p.users
        ));
    }
    if p.jobs == 0 {
        return Err("skewed: jobs must be >= 1".into());
    }
    if !(p.zipf_s >= 0.0 && p.zipf_s.is_finite()) {
        return Err(format!("skewed: zipf_s {} must be finite and >= 0", p.zipf_s));
    }
    if p.cores == 0 {
        return Err("skewed: cores must be >= 1".into());
    }
    if !(p.target_utilization > 0.0 && p.target_utilization <= 1.0) {
        return Err(format!(
            "skewed: target_utilization {} outside (0, 1]",
            p.target_utilization
        ));
    }
    if !(0.0..=1.0).contains(&p.skew_fraction) {
        return Err(format!(
            "skewed: skew_fraction {} outside [0, 1]",
            p.skew_fraction
        ));
    }
    let counts = apportion_jobs(p.jobs, &zipf_weights(p));
    let mean_slot = (SKEWED_SLOT_MIN_S + SKEWED_SLOT_MAX_S) / 2.0;
    let window_s = p.jobs as f64 * mean_slot / (p.cores as f64 * p.target_utilization);
    let mut rng = Rng::new(seed);
    let mut streams: Vec<Box<dyn JobStream + Send>> = Vec::new();
    for (i, &count) in counts.iter().enumerate() {
        let user = (i + 1) as u32;
        let mut r = rng.fork(user as u64);
        if count == 0 {
            continue;
        }
        let gap = window_s / count as f64;
        let skew_fraction = p.skew_fraction;
        let mut t = r.exp(1.0 / gap);
        let mut i_job = 0u64;
        streams.push(Box::new(from_fn(move || {
            if i_job >= count {
                return None;
            }
            let slot = r.range_f64(SKEWED_SLOT_MIN_S, SKEWED_SLOT_MAX_S);
            let name = format!("zf{user}-{i_job}");
            let job = trace_job(user, &name, t, slot, &mut r, skew_fraction);
            t += r.exp(1.0 / gap);
            i_job += 1;
            Some(job)
        })));
    }
    Ok(MergeStream::new(streams))
}

/// [`skewed`]'s classification: the Zipf head is `Heavy`, the tail
/// `Infrequent`.
pub fn skewed_classes(p: &SkewedParams) -> HashMap<UserId, UserClass> {
    (1..=p.users)
        .map(|u| {
            let class = if u <= p.hot_users {
                UserClass::Heavy
            } else {
                UserClass::Infrequent
            };
            (u, class)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::stream::materialize;
    use crate::TimeUs;

    fn sorted_nondecreasing(jobs: &[crate::core::job::JobSpec]) -> bool {
        jobs.windows(2).all(|w| w[0].arrival <= w[1].arrival)
    }

    #[test]
    fn bursty_respects_windows() {
        let p = BurstyParams {
            users: 3,
            steady_users: 1,
            duration_s: 120.0,
            cycle_s: 30.0,
            burst_ratio: 0.2,
            rate: 3.0,
            steady_gap_s: 20.0,
            mem_frac: 1.0,
        };
        let jobs = materialize(bursty(5, &p).unwrap());
        assert!(!jobs.is_empty());
        assert!(sorted_nondecreasing(&jobs));
        let classes = bursty_classes(&p);
        for j in &jobs {
            j.validate().unwrap();
            let arr = j.arrival as f64 / 1e6;
            assert!(arr < p.duration_s);
            if classes[&j.user] == UserClass::Frequent {
                // Bursty submissions land inside an ON window.
                let phase = arr % p.cycle_s;
                assert!(
                    phase <= p.cycle_s * p.burst_ratio + 1e-6,
                    "user {} job at phase {phase}",
                    j.user
                );
                assert_eq!(&*j.name, "short");
            } else {
                assert_eq!(&*j.name, "tiny");
            }
        }
        // Both populations produced jobs.
        assert!(jobs.iter().any(|j| classes[&j.user] == UserClass::Frequent));
        assert!(jobs.iter().any(|j| classes[&j.user] == UserClass::Infrequent));
    }

    #[test]
    fn bursty_rejects_bad_params() {
        let mut p = BurstyParams::default();
        p.burst_ratio = 0.0;
        assert!(bursty(1, &p).is_err());
        p = BurstyParams::default();
        p.users = 0;
        assert!(bursty(1, &p).is_err());
        for bad in [0.0, -0.5, 1.5] {
            p = BurstyParams::default();
            p.mem_frac = bad;
            let err = bursty(1, &p).unwrap_err();
            assert!(err.contains("mem_frac"), "{err}");
        }
    }

    #[test]
    fn bursty_mem_frac_marks_only_burst_users() {
        use crate::core::task::ResourceVec;
        let mut p = BurstyParams::default();
        p.duration_s = 60.0;
        p.mem_frac = 0.25;
        let jobs = materialize(bursty(5, &p).unwrap());
        let classes = bursty_classes(&p);
        assert!(jobs.iter().any(|j| classes[&j.user] == UserClass::Frequent));
        assert!(jobs.iter().any(|j| classes[&j.user] == UserClass::Infrequent));
        for j in &jobs {
            j.validate().unwrap();
            let want = if classes[&j.user] == UserClass::Frequent {
                ResourceVec::new(1.0, 0.25)
            } else {
                ResourceVec::UNIT
            };
            assert!(j.stages.iter().all(|s| s.demand == want), "user {}", j.user);
        }
        // The unit default leaves everything on the legacy vector.
        let jobs = materialize(bursty(5, &BurstyParams::default()).unwrap());
        assert!(jobs.iter().all(|j| j.stages.iter().all(|s| s.demand.is_unit())));
    }

    #[test]
    fn heavytail_sizes_follow_pareto_bounds() {
        let p = HeavytailParams {
            users: 4,
            jobs_per_user: 25,
            mean_gap_s: 2.0,
            alpha: 1.2,
            min_slot: 3.0,
            cap_slot: 500.0,
            skew_fraction: 0.3,
        };
        let jobs = materialize(heavytail(9, &p).unwrap());
        assert_eq!(jobs.len(), 100);
        assert!(sorted_nondecreasing(&jobs));
        let mut max = 0.0f64;
        for j in &jobs {
            j.validate().unwrap();
            let slot = j.slot_time();
            assert!(slot >= p.min_slot * 0.999, "slot {slot}");
            assert!(slot <= p.cap_slot * 1.001, "slot {slot}");
            max = max.max(slot);
        }
        // A heavy tail actually shows up.
        assert!(max > 10.0 * p.min_slot, "max {max}");
        assert_eq!(heavytail_classes(&p).len(), 4);
    }

    #[test]
    fn heavytail_rejects_bad_params() {
        let mut p = HeavytailParams::default();
        p.cap_slot = 0.5; // below min_slot
        assert!(heavytail(1, &p).is_err());
        p = HeavytailParams::default();
        p.alpha = 0.0;
        assert!(heavytail(1, &p).is_err());
    }

    #[test]
    fn diurnal_rate_swings_with_the_sinusoid() {
        let p = DiurnalParams {
            users: 20,
            duration_s: 480.0,
            period_s: 240.0,
            amplitude: 0.9,
            mean_rate: 0.2,
            tiny_fraction: 0.7,
        };
        let jobs = materialize(diurnal(3, &p).unwrap());
        assert!(sorted_nondecreasing(&jobs));
        // Count arrivals in peak vs trough quarters of the sinusoid:
        // sin > 0 on the first half of each period (peak), < 0 on the
        // second (trough).
        let (mut peak, mut trough) = (0u32, 0u32);
        for j in &jobs {
            let t = j.arrival as f64 / 1e6;
            if (t % p.period_s) < p.period_s / 2.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "peak {peak} vs trough {trough}"
        );
        assert_eq!(diurnal_classes(&p).len(), 20);
    }

    #[test]
    fn skewed_head_dominates_and_job_count_is_exact() {
        let p = SkewedParams {
            users: 50,
            jobs: 2_000,
            zipf_s: 1.2,
            hot_users: 8,
            cores: 8,
            target_utilization: 0.7,
            skew_fraction: 0.2,
        };
        let jobs = materialize(skewed(11, &p).unwrap());
        // Largest-remainder apportionment: the total is exact.
        assert_eq!(jobs.len(), 2_000);
        assert!(sorted_nondecreasing(&jobs));
        let mut per_user = HashMap::new();
        for j in &jobs {
            j.validate().unwrap();
            assert!(j.user >= 1 && j.user <= p.users);
            *per_user.entry(j.user).or_insert(0u64) += 1;
        }
        // The Zipf head carries ~all of the work (the tail shares one
        // rank's weight), and rank 1 beats rank `hot_users`.
        let head: u64 = (1..=p.hot_users).map(|u| per_user.get(&u).copied().unwrap_or(0)).sum();
        assert!(head as f64 > 0.9 * jobs.len() as f64, "head {head}");
        assert!(per_user[&1] > per_user[&p.hot_users] * 2, "not Zipf-steep");
        let classes = skewed_classes(&p);
        assert_eq!(classes.len(), 50);
        assert_eq!(classes[&1], UserClass::Heavy);
        assert_eq!(classes[&50], UserClass::Infrequent);
        // Deterministic per seed.
        let key = |seed: u64| -> Vec<(u32, TimeUs)> {
            materialize(skewed(seed, &p).unwrap())
                .iter()
                .map(|j| (j.user, j.arrival))
                .collect()
        };
        assert_eq!(key(11), key(11));
        assert_ne!(key(11), key(12));
    }

    #[test]
    fn skewed_rejects_bad_params() {
        let check = |f: fn(&mut SkewedParams), frag: &str| {
            let mut p = SkewedParams::default();
            f(&mut p);
            let err = skewed(1, &p).unwrap_err();
            assert!(err.contains(frag), "{err}");
        };
        check(|p| p.users = 0, "users");
        check(|p| p.hot_users = 0, "hot_users");
        check(|p| p.hot_users = p.users + 1, "hot_users");
        check(|p| p.jobs = 0, "jobs");
        check(|p| p.zipf_s = -1.0, "zipf_s");
        check(|p| p.target_utilization = 0.0, "target_utilization");
        check(|p| p.skew_fraction = 1.5, "skew_fraction");
    }

    #[test]
    fn deterministic_per_seed() {
        let key = |seed: u64| -> Vec<(u32, TimeUs)> {
            materialize(bursty(seed, &BurstyParams::default()).unwrap())
                .iter()
                .map(|j| (j.user, j.arrival))
                .collect()
        };
        assert_eq!(key(4), key(4));
        assert_ne!(key(4), key(5));
    }
}
