//! Workloads — one definition each, registered in the [`registry`].
//!
//! * [`registry`] — **the scenario registry**: every workload is a named
//!   [`registry::Scenario`] with a typed parameter schema and a single
//!   constructor returning a lazy [`stream::JobStream`]; the materialized
//!   [`Workload`] form is the generic `collect()` adapter. Grids, the CLI
//!   (`uwfq scenarios`, `uwfq run --scenario NAME --param k=v`) and
//!   config files all reference scenarios by name + params.
//! * [`scenarios`] — the paper's two micro-benchmark generators (§5.2.1):
//!   (1) infrequent + frequent users, (2) multiple frequent users.
//! * [`gtrace`] — the Google-trace-shaped macro generator (§5.3: 25
//!   users, 5 heavy users >90 % of work, ≥100 % utilization over a 500 s
//!   window). Deliberately keeps the paper's **exact two-pass**
//!   filter/rebalance/rescale pipeline: it is the differential oracle
//!   for the streaming shaper.
//! * [`traceio`] — **streaming trace replay** (registry entry `trace`,
//!   `uwfq replay`): a chunked line reader over real trace files (native
//!   CSV + a Google-cluster-trace column mapping), a one-pass §5.3
//!   shaping stage (running P² median filter, warmup-window
//!   rebalance/rescale), and a seeded synthetic trace writer — resident
//!   state O(warmup + in-flight) regardless of trace length.
//! * [`stress`] — stress generators beyond the paper: `bursty` (BoPF-style
//!   on/off users), `heavytail` (Pareto sizes), `diurnal` (sinusoidal-rate
//!   Poisson).
//! * [`tracefile`] — the simple in-memory CSV trace loader (registry
//!   entry `tracefile`, `--param path=FILE`); the streaming raw-replay
//!   path reuses its job builder byte-for-byte.
//! * [`stream`] — the lazy job-timeline substrate ([`stream::JobStream`]):
//!   per-user generators k-way merged in arrival order, plus the
//!   `uwfq scale` million-job workload. Every materialized workload
//!   doubles as a stream via [`Workload::into_stream`].

pub mod gtrace;
pub mod registry;
pub mod scenarios;
pub mod stream;
pub mod stress;
pub mod tracefile;
pub mod traceio;

pub use registry::{Registry, Scenario, ScenarioSpec};
pub use stream::JobStream;

use std::collections::HashMap;

use crate::core::job::JobSpec;
use crate::UserId;

/// User behaviour class, used by the metrics layer to split the paper's
/// table columns (Freq./Infreq. in scenario 1; heavy/light in the macro).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UserClass {
    Frequent,
    Infrequent,
    Heavy,
    Light,
}

/// A named job timeline plus per-user classification.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub jobs: Vec<JobSpec>,
    pub user_class: HashMap<UserId, UserClass>,
}

impl Workload {
    /// Total sequential work (core-seconds).
    pub fn total_slot_time(&self) -> f64 {
        self.jobs.iter().map(|j| j.slot_time()).sum()
    }

    /// Timeline span in seconds (last arrival).
    pub fn span_s(&self) -> f64 {
        crate::us_to_s(self.jobs.iter().map(|j| j.arrival).max().unwrap_or(0))
    }

    /// Theoretical utilization: work / (cores × window).
    pub fn utilization(&self, cores: u32, window_s: f64) -> f64 {
        self.total_slot_time() / (cores as f64 * window_s)
    }

    pub fn users(&self) -> Vec<UserId> {
        let mut u: Vec<UserId> = self.user_class.keys().copied().collect();
        u.sort();
        u
    }

    /// Consume the workload as a [`stream::JobStream`] (the thin
    /// materialized adapter: stable-sorted by arrival, exactly the order
    /// the simulator replays).
    pub fn into_stream(self) -> stream::VecStream {
        stream::VecStream::new(self.jobs)
    }

    /// Stream a borrowed workload (clones the job vector).
    pub fn to_stream(&self) -> stream::VecStream {
        stream::VecStream::new(self.jobs.clone())
    }
}

/// The micro-benchmark job sizes (§5.2): idle-system response times of
/// 0.90 s (tiny) and 2.25 s (short) on the 32-core testbed correspond to
/// these sequential slot-times.
pub const TINY_COMPUTE_SLOT: f64 = 24.0;
pub const SHORT_COMPUTE_SLOT: f64 = 64.0;

/// Paper dataset size (752 MB) — drives size-based partitioning.
pub const DATASET_BYTES: u64 = 752 << 20;

/// Test fixture shared by unit tests across the crate: the scenario2
/// micro workload at a custom size, built through the registry (one
/// place tracks the schema's param names).
#[cfg(test)]
pub(crate) fn test_scenario2(seed: u64, jobs_per_user: u32, stagger_s: f64) -> Workload {
    registry::ScenarioSpec::new("scenario2")
        .with("jobs_per_user", &jobs_per_user.to_string())
        .with("stagger_s", &stagger_s.to_string())
        .workload(seed)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobSpec;

    #[test]
    fn workload_aggregates() {
        let w = Workload {
            name: "t".into(),
            jobs: vec![
                JobSpec::three_phase(1, "a", 0, 1.0, 1 << 20, 4, None),
                JobSpec::three_phase(2, "b", 2_000_000, 2.0, 1 << 20, 4, None),
            ],
            user_class: [(1, UserClass::Frequent), (2, UserClass::Infrequent)]
                .into_iter()
                .collect(),
        };
        assert!(w.total_slot_time() > 3.0);
        assert_eq!(w.span_s(), 2.0);
        assert_eq!(w.users(), vec![1, 2]);
        assert!(w.utilization(32, 10.0) > 0.0);
    }
}
