//! Google-trace-shaped macro workload (paper §5.3).
//!
//! The paper uses the 2014 Google cluster trace (WTA format), selects a
//! 500 s slice, filters out jobs whose runtime exceeds 10× the median,
//! and scales the rest to ≥100 % theoretical utilization of the 32-core
//! cluster. The final workload has 25 users of which 5 heavy users submit
//! >90 % of the total work.
//!
//! We reproduce the *statistical shape* with a seeded generator (the trace
//! itself is a 300 MB external download): heavy-tailed lognormal job
//! sizes, Poisson user arrivals, 1–3-stage linear jobs, and the same
//! filter + rescale pipeline. Real trace files are replayed through
//! [`crate::workload::traceio`] instead (registry entry `trace`).
//!
//! The workload is defined **once**, as the [`GtraceStream`] constructor
//! [`gtrace`]; the materialized form is the registry's generic collect
//! adapter (registry entry `gtrace`). This generator deliberately keeps
//! the **exact two-pass** §5.3 shaping ([`raw_rows`] → [`shape_exact`]):
//! it is the in-memory differential *oracle* that the one-pass streaming
//! shaper ([`crate::workload::traceio::shaping`]) is measured against
//! (`tests/trace_replay.rs`), and the synthetic trace writer
//! ([`crate::workload::traceio::writer`]) emits exactly the [`raw_rows`]
//! tuples so the two pipelines shape the same raw input.

use super::stream::JobStream;
use super::UserClass;
use crate::core::job::{CostProfile, JobSpec, StagePhase, StageSpec};
use crate::util::{stats, Rng};
use crate::{s_to_us, UserId};
use std::collections::HashMap;

/// Generator parameters; defaults reproduce §5.3.
#[derive(Clone, Debug)]
pub struct GtraceParams {
    pub window_s: f64,
    pub users: u32,
    pub heavy_users: u32,
    /// Fraction of total work submitted by heavy users.
    pub heavy_work_fraction: f64,
    /// Target theoretical utilization (work / cores / window).
    pub target_utilization: f64,
    pub cores: u32,
    /// Fraction of jobs given a skewed cost profile (exercises the paper's
    /// runtime-partitioning gains on "homogeneous workloads").
    pub skew_fraction: f64,
    /// Runtime filter threshold (× median), per §5.3.
    pub filter_median_mult: f64,
}

impl Default for GtraceParams {
    fn default() -> Self {
        GtraceParams {
            window_s: 500.0,
            users: 25,
            heavy_users: 5,
            heavy_work_fraction: 0.92,
            target_utilization: 1.05,
            cores: 32,
            skew_fraction: 0.3,
            filter_median_mult: 10.0,
        }
    }
}

/// One raw generated trace tuple prior to §5.3 shaping — the common
/// currency of the exact pipeline, the synthetic trace writer and the
/// one-pass streaming shaper's differential test.
#[derive(Clone, Copy, Debug)]
pub struct RawTuple {
    pub user: u32,
    pub arrival_s: f64,
    /// Total sequential work (core-seconds), unshaped.
    pub slot_s: f64,
    pub class: UserClass,
}

/// Mean submission gaps of the raw generators (seconds per job per
/// user) — shared with the trace writer's row-count solver
/// ([`crate::workload::traceio::writer::params_for_jobs`]), which would
/// otherwise drift when these are tuned.
pub(crate) const HEAVY_GAP_S: f64 = 25.0;
pub(crate) const LIGHT_GAP_S: f64 = 70.0;

/// Generate the raw (unshaped) §5.3 tuples in generation order, plus the
/// root RNG in the exact state the per-job materialization forks from.
pub fn raw_rows(seed: u64, p: &GtraceParams) -> (Vec<RawTuple>, Rng) {
    let mut rng = Rng::new(seed);
    let mut raw: Vec<RawTuple> = Vec::new();

    // Heavy users: moderately frequent, heavy-tailed big jobs.
    for user in 1..=p.heavy_users {
        let mut r = rng.fork(user as u64);
        let mut t = r.range_f64(0.0, 20.0);
        while t < p.window_s {
            // Lognormal core-seconds; median e^4.5 ≈ 90, heavy tail.
            let slot = r.lognormal(4.5, 1.1);
            raw.push(RawTuple {
                user,
                arrival_s: t,
                slot_s: slot,
                class: UserClass::Heavy,
            });
            t += r.exp(1.0 / HEAVY_GAP_S); // a job every ~25 s per heavy user
        }
    }
    // Light users: infrequent small jobs.
    for user in (p.heavy_users + 1)..=p.users {
        let mut r = rng.fork(1000 + user as u64);
        let mut t = r.range_f64(0.0, 60.0);
        while t < p.window_s {
            let slot = r.lognormal(2.6, 0.8); // median ≈ 13 core-s
            raw.push(RawTuple {
                user,
                arrival_s: t,
                slot_s: slot,
                class: UserClass::Light,
            });
            t += r.exp(1.0 / LIGHT_GAP_S); // a job every ~70 s per light user
        }
    }
    (raw, rng)
}

/// The **exact two-pass** §5.3 shaping pipeline: drop the runtime tail
/// against the global median, rebalance heavy users to
/// `heavy_work_fraction` of the work, rescale everything to the target
/// utilization over the window. This is the differential oracle the
/// one-pass streaming shaper is measured against.
pub fn shape_exact(raw: &mut Vec<RawTuple>, p: &GtraceParams) {
    // §5.3 filter: drop jobs with runtime > filter_median_mult × median.
    let slots: Vec<f64> = raw.iter().map(|j| j.slot_s).collect();
    let med = stats::median(&slots);
    raw.retain(|j| j.slot_s <= p.filter_median_mult * med);

    // Rebalance so heavy users produce `heavy_work_fraction` of the work,
    // then rescale everything to the target utilization.
    let heavy_work: f64 = raw
        .iter()
        .filter(|j| j.class == UserClass::Heavy)
        .map(|j| j.slot_s)
        .sum();
    let light_work: f64 = raw
        .iter()
        .filter(|j| j.class == UserClass::Light)
        .map(|j| j.slot_s)
        .sum();
    let heavy_scale =
        p.heavy_work_fraction / (1.0 - p.heavy_work_fraction) * light_work / heavy_work;
    for j in raw.iter_mut() {
        if j.class == UserClass::Heavy {
            j.slot_s *= heavy_scale;
        }
    }
    let total: f64 = raw.iter().map(|j| j.slot_s).sum();
    let target = p.target_utilization * p.cores as f64 * p.window_s;
    let scale = target / total;
    for j in raw.iter_mut() {
        j.slot_s *= scale;
    }
}

/// Stage-chain length for a job of `slot` core-seconds (bigger jobs get
/// more stages) — shared by [`trace_job`] and the trace writer's
/// `stages` column.
pub(crate) fn stage_count(slot: f64) -> usize {
    if slot < 30.0 {
        1
    } else if slot < 200.0 {
        2
    } else {
        3
    }
}

/// One trace job: a linear chain of 1–3 stages whose slot-times partition
/// the job's total, leaf stage first; bigger jobs get more stages. Shared
/// with the `heavytail` stress scenario (Pareto sizes, same chain shape)
/// and the `trace` replay entry (shaped real-trace rows).
pub(crate) fn trace_job(
    user: u32,
    name: &str,
    arrival_s: f64,
    slot: f64,
    r: &mut Rng,
    skew_fraction: f64,
) -> JobSpec {
    let nstages = stage_count(slot);
    // Split slot across stages (dominant middle stage for 3-stage jobs).
    let fractions: Vec<f64> = match nstages {
        1 => vec![1.0],
        2 => vec![0.25, 0.75],
        _ => vec![0.15, 0.7, 0.15],
    };
    // Input scaled with job size: ~8 MB per core-second, min 32 MB.
    let bytes = (((slot * 8.0) as u64) << 20).max(32 << 20);
    // Shuffle stages consume *aggregated* intermediate output — much
    // smaller than the scan input (8–64× shrink). This is what makes
    // default AQE coalesce them to very few partitions and create the
    // long-running tasks the paper's runtime partitioning fixes (§4.1.2).
    let shuffle_shrink = 8u64 << r.below(4); // 8, 16, 32 or 64
    let stages: Vec<StageSpec> = fractions
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let skewed = r.f64() < skew_fraction;
            StageSpec {
                phase: StagePhase::Generic,
                parents: if i == 0 { vec![] } else { vec![i - 1] },
                is_leaf_input: i == 0,
                input_bytes: if i == 0 { bytes } else { (bytes / shuffle_shrink).max(1 << 20) },
                slot_time: slot * f,
                cost: if skewed {
                    CostProfile::skewed(0.05, r.range_f64(4.0, 8.0))
                } else {
                    CostProfile::uniform()
                },
                max_parallelism: None,
                opcount: [1u32, 4, 16, 64][(r.below(4)) as usize],
                demand: crate::core::task::ResourceVec::UNIT,
            }
        })
        .collect();
    JobSpec {
        user,
        name: name.into(),
        arrival: s_to_us(arrival_s),
        weight: 1.0,
        stages,
    }
}

/// One shaped trace job awaiting lazy materialization: the compact tuple
/// plus its pre-forked RNG (forked in generation order, so the root RNG
/// advances exactly as the shaping pipeline prescribes).
struct RawTraceJob {
    user: u32,
    idx: usize,
    arrival_s: f64,
    slot: f64,
    rng: Rng,
}

/// The macro workload as a stream — the single definition behind the
/// `gtrace` registry entry. The stream holds compact shaped tuples (the
/// deliberate cost of the exact two-pass oracle pipeline); fully
/// streaming trace replay lives in [`crate::workload::traceio`].
pub struct GtraceStream {
    raw: std::vec::IntoIter<RawTraceJob>,
    skew_fraction: f64,
    /// Per-user behaviour class (O(users); known before any job yields).
    pub user_class: HashMap<UserId, UserClass>,
}

/// Build the macro workload stream for the given seed/params.
pub fn gtrace(seed: u64, p: &GtraceParams) -> GtraceStream {
    let (mut raw, mut rng) = raw_rows(seed, p);
    shape_exact(&mut raw, p);
    let mut user_class = HashMap::new();
    let mut items: Vec<RawTraceJob> = raw
        .iter()
        .enumerate()
        .map(|(i, j)| {
            user_class.insert(j.user, j.class);
            RawTraceJob {
                user: j.user,
                idx: i,
                arrival_s: j.arrival_s,
                slot: j.slot_s,
                // Forked in generation order — the root RNG advances
                // identically no matter what order jobs later yield in.
                rng: rng.fork(0xB0B ^ i as u64),
            }
        })
        .collect();
    // Arrival order with the stable tie-break (generation index), i.e.
    // exactly the order the simulator's sorted cursor replays.
    items.sort_by_key(|r| (s_to_us(r.arrival_s), r.idx));
    GtraceStream {
        raw: items.into_iter(),
        skew_fraction: p.skew_fraction,
        user_class,
    }
}

impl JobStream for GtraceStream {
    fn next_job(&mut self) -> Option<JobSpec> {
        let mut r = self.raw.next()?;
        Some(trace_job(
            r.user,
            &format!("g{}", r.idx),
            r.arrival_s,
            r.slot,
            &mut r.rng,
            self.skew_fraction,
        ))
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.raw.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::stream::materialize;
    use crate::workload::Workload;

    /// Collect the stream into a materialized workload (what the registry
    /// entry's generic collect adapter does).
    fn wl(seed: u64, p: &GtraceParams) -> Workload {
        let s = gtrace(seed, p);
        let user_class = s.user_class.clone();
        Workload {
            name: "gtrace".into(),
            jobs: materialize(s),
            user_class,
        }
    }

    #[test]
    fn matches_paper_shape() {
        let p = GtraceParams::default();
        let w = wl(42, &p);
        // 25 users, 5 heavy.
        assert_eq!(w.users().len() as u32, p.users);
        let heavy: Vec<_> = w
            .user_class
            .iter()
            .filter(|(_, c)| **c == UserClass::Heavy)
            .collect();
        assert_eq!(heavy.len() as u32, p.heavy_users);
        // Heavy users >90% of work.
        let heavy_work: f64 = w
            .jobs
            .iter()
            .filter(|j| w.user_class[&j.user] == UserClass::Heavy)
            .map(|j| j.slot_time())
            .sum();
        let frac = heavy_work / w.total_slot_time();
        assert!(frac > 0.9, "heavy fraction {frac}");
        // Utilization ≈ target.
        let util = w.utilization(p.cores, p.window_s);
        assert!((util - p.target_utilization).abs() < 0.02, "util {util}");
        // Majority of users submit only infrequent small jobs.
        let light_jobs = w
            .jobs
            .iter()
            .filter(|j| w.user_class[&j.user] == UserClass::Light)
            .count();
        assert!(light_jobs >= 20);
    }

    #[test]
    fn filter_removes_tail() {
        let mut p = GtraceParams::default();
        p.filter_median_mult = 10.0;
        let w = wl(7, &p);
        let slots: Vec<f64> = w.jobs.iter().map(|j| j.slot_time()).collect();
        let med = crate::util::stats::median(&slots);
        // After rescaling the ratio max/median can exceed the filter
        // slightly (heavy rebalancing), but the extreme tail is gone.
        let max = slots.iter().cloned().fold(0.0, f64::max);
        assert!(max / med < 120.0, "max/med {}", max / med);
    }

    #[test]
    fn deterministic_and_sorted() {
        let p = GtraceParams::default();
        let key = |seed: u64| {
            materialize(gtrace(seed, &p))
                .iter()
                .map(|j| (j.user, j.arrival, j.stages.len()))
                .collect::<Vec<_>>()
        };
        let a = key(9);
        assert_eq!(a, key(9));
        assert_ne!(a, key(10));
        // The stream contract: nondecreasing arrivals.
        assert!(a.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn stage_chains_valid() {
        let w = wl(3, &GtraceParams::default());
        for j in &w.jobs {
            j.validate().unwrap();
            assert!(j.stages[0].is_leaf_input);
            assert!((1..=3).contains(&j.stages.len()));
        }
    }
}
