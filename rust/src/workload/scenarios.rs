//! The micro-benchmark scenarios (§5.2.1), constructed with the paper's
//! parameters on the 32-core testbed.
//!
//! Job sizes follow §5.2: *tiny* and *short* jobs with idle-system
//! response times of ≈0.90 s and ≈2.25 s respectively; each analytics job
//! is a 3-phase load → compute → collect chain over its own copy of the
//! dataset.

use super::stream::{from_fn, JobStream, MergeStream};
use super::{UserClass, Workload, DATASET_BYTES, SHORT_COMPUTE_SLOT, TINY_COMPUTE_SLOT};
use crate::core::job::{CostProfile, JobSpec};
use crate::s_to_us;
use crate::util::Rng;
use std::collections::HashMap;

/// Make one micro-benchmark job. `kind` ∈ {"tiny", "short"}.
pub fn micro_job(user: u32, kind: &str, arrival_s: f64, skew: Option<CostProfile>) -> JobSpec {
    let (slot, opcount) = match kind {
        "tiny" => (TINY_COMPUTE_SLOT, 4),
        "short" => (SHORT_COMPUTE_SLOT, 16),
        other => panic!("unknown micro job kind '{other}'"),
    };
    JobSpec::three_phase(user, kind, s_to_us(arrival_s), slot, DATASET_BYTES, opcount, skew)
}

/// **Scenario 1 — infrequent and frequent users** (§5.2.1).
///
/// Users 1–2 are *infrequent*: Poisson job submissions (mean gap
/// `poisson_gap_s`), 70 % tiny / 30 % short. Users 3–4 are *frequent*:
/// every 30 s each submits a burst of `burst` short jobs, which together
/// oversubscribe the 32-core cluster and build a backlog.
pub fn scenario1(seed: u64, duration_s: f64, burst: usize, poisson_gap_s: f64) -> Workload {
    let mut rng = Rng::new(seed);
    let mut jobs = Vec::new();
    let mut user_class = HashMap::new();

    // Infrequent users (Poisson arrivals, like the paper).
    for user in 1..=2u32 {
        user_class.insert(user, UserClass::Infrequent);
        let mut r = rng.fork(user as u64);
        let mut t = r.exp(1.0 / poisson_gap_s);
        while t < duration_s {
            let kind = if r.f64() < 0.7 { "tiny" } else { "short" };
            jobs.push(micro_job(user, kind, t, None));
            t += r.exp(1.0 / poisson_gap_s);
        }
    }

    // Frequent users (synchronized 30 s burst cycles; tiny start offsets
    // keep arrival order deterministic but overlapping, as in §5.2.1).
    for user in 3..=4u32 {
        user_class.insert(user, UserClass::Frequent);
        let offset = (user - 3) as f64 * 0.050;
        let mut cycle = 0.0;
        while cycle < duration_s {
            for b in 0..burst {
                jobs.push(micro_job(user, "short", cycle + offset + b as f64 * 0.010, None));
            }
            cycle += 30.0;
        }
    }

    Workload {
        name: "scenario1".into(),
        jobs,
        user_class,
    }
}

/// Scenario 1 with the paper's defaults: 300 s, bursts of 6 short jobs,
/// infrequent users averaging one job per 40 s.
pub fn scenario1_default(seed: u64) -> Workload {
    scenario1(seed, 300.0, 6, 40.0)
}

/// **Scenario 1 as a lazy stream** — per-user generators (same seeded RNG
/// forks, same arithmetic as [`scenario1`]) k-way merged in arrival
/// order. Simulating this stream is byte-identical to simulating the
/// materialized workload: user streams are indexed in construction order
/// (users 1–4), so merge ties reproduce the stable sort's tie-break.
pub fn scenario1_stream(seed: u64, duration_s: f64, burst: usize, poisson_gap_s: f64) -> MergeStream {
    let mut rng = Rng::new(seed);
    let mut streams: Vec<Box<dyn JobStream + Send>> = Vec::new();

    for user in 1..=2u32 {
        let mut r = rng.fork(user as u64);
        let mut t = r.exp(1.0 / poisson_gap_s);
        streams.push(Box::new(from_fn(move || {
            if t >= duration_s {
                return None;
            }
            let kind = if r.f64() < 0.7 { "tiny" } else { "short" };
            let job = micro_job(user, kind, t, None);
            t += r.exp(1.0 / poisson_gap_s);
            Some(job)
        })));
    }

    for user in 3..=4u32 {
        let offset = (user - 3) as f64 * 0.050;
        let mut cycle = 0.0;
        let mut b = 0usize;
        streams.push(Box::new(from_fn(move || {
            if burst == 0 || cycle >= duration_s {
                return None;
            }
            let job = micro_job(user, "short", cycle + offset + b as f64 * 0.010, None);
            b += 1;
            if b == burst {
                b = 0;
                cycle += 30.0;
            }
            Some(job)
        })));
    }

    MergeStream::new(streams)
}

/// [`scenario1_stream`] with the paper's defaults.
pub fn scenario1_default_stream(seed: u64) -> MergeStream {
    scenario1_stream(seed, 300.0, 6, 40.0)
}

/// **Scenario 2 — multiple frequent users** (§5.2.1).
///
/// Four users each submit `jobs_per_user` tiny jobs at once, with
/// deterministic per-user start delays (`stagger_s` apart) so the user
/// arrival order is consistent across runs.
pub fn scenario2(seed: u64, jobs_per_user: usize, stagger_s: f64) -> Workload {
    let _ = seed; // fully deterministic; seed kept for API symmetry
    let mut jobs = Vec::new();
    let mut user_class = HashMap::new();
    for user in 1..=4u32 {
        user_class.insert(user, UserClass::Frequent);
        let start = (user - 1) as f64 * stagger_s;
        for b in 0..jobs_per_user {
            // sub-ms stagger within the burst keeps submission order
            // deterministic without affecting the scenario.
            jobs.push(micro_job(user, "tiny", start + b as f64 * 0.001, None));
        }
    }
    Workload {
        name: "scenario2".into(),
        jobs,
        user_class,
    }
}

/// Scenario 2 with the paper-scale burst: 20 tiny jobs/user (≈60 s of
/// work on 32 cores), users staggered 5 s apart.
pub fn scenario2_default(seed: u64) -> Workload {
    scenario2(seed, 20, 5.0)
}

/// **Scenario 2 as a lazy stream** — fully deterministic per-user
/// generators merged in arrival order (byte-identical to the
/// materialized [`scenario2`] under simulation).
pub fn scenario2_stream(seed: u64, jobs_per_user: usize, stagger_s: f64) -> MergeStream {
    let _ = seed; // fully deterministic; seed kept for API symmetry
    let streams: Vec<Box<dyn JobStream + Send>> = (1..=4u32)
        .map(|user| {
            let start = (user - 1) as f64 * stagger_s;
            let mut b = 0usize;
            Box::new(from_fn(move || {
                if b >= jobs_per_user {
                    return None;
                }
                let job = micro_job(user, "tiny", start + b as f64 * 0.001, None);
                b += 1;
                Some(job)
            })) as Box<dyn JobStream + Send>
        })
        .collect();
    MergeStream::new(streams)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario1_shape() {
        let w = scenario1_default(42);
        // 2 infrequent + 2 frequent users.
        assert_eq!(w.users().len(), 4);
        let freq: Vec<_> = w
            .user_class
            .iter()
            .filter(|(_, c)| **c == UserClass::Frequent)
            .collect();
        assert_eq!(freq.len(), 2);
        // Frequent users dominate the workload.
        let freq_work: f64 = w
            .jobs
            .iter()
            .filter(|j| w.user_class[&j.user] == UserClass::Frequent)
            .map(|j| j.slot_time())
            .sum();
        assert!(freq_work / w.total_slot_time() > 0.8);
        // Oversubscribed: >100% of 32 cores over 300 s + drain time.
        assert!(w.utilization(32, 330.0) > 0.7, "util {}", w.utilization(32, 330.0));
        // 10 burst cycles × 2 users × 6 jobs = 120 short jobs minimum.
        assert!(w.jobs.len() >= 120);
    }

    #[test]
    fn scenario1_deterministic_per_seed() {
        let a = scenario1_default(7);
        let b = scenario1_default(7);
        let c = scenario1_default(8);
        let key = |w: &Workload| {
            w.jobs
                .iter()
                .map(|j| (j.user, j.arrival, j.name.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&a), key(&b));
        assert_ne!(key(&a), key(&c));
    }

    #[test]
    fn scenario2_shape() {
        let w = scenario2_default(1);
        assert_eq!(w.jobs.len(), 80);
        assert_eq!(w.users().len(), 4);
        // Start delays order the users.
        let first_arrival = |u: u32| {
            w.jobs
                .iter()
                .filter(|j| j.user == u)
                .map(|j| j.arrival)
                .min()
                .unwrap()
        };
        assert!(first_arrival(1) < first_arrival(2));
        assert!(first_arrival(3) < first_arrival(4));
        // All tiny.
        assert!(w.jobs.iter().all(|j| &*j.name == "tiny"));
    }

    #[test]
    fn scenario_streams_match_materialized_sorted_order() {
        // The streamed scenarios must yield exactly the jobs of the
        // materialized builders, in the stable sort-by-arrival order the
        // simulator replays — job-level parity here, schedule-level
        // parity in tests/stream_differential.rs.
        use crate::workload::stream::materialize;
        let key = |jobs: &[JobSpec]| -> Vec<(u32, crate::TimeUs, String)> {
            jobs.iter()
                .map(|j| (j.user, j.arrival, j.name.to_string()))
                .collect()
        };
        let mat1 = scenario1(7, 120.0, 3, 30.0).into_stream();
        let streamed1 = materialize(scenario1_stream(7, 120.0, 3, 30.0));
        assert_eq!(key(&materialize(mat1)), key(&streamed1));

        let mat2 = scenario2(1, 5, 0.5).into_stream();
        let streamed2 = materialize(scenario2_stream(1, 5, 0.5));
        assert_eq!(key(&materialize(mat2)), key(&streamed2));
    }

    #[test]
    fn micro_job_idle_rts_calibrated() {
        // Validate the §5.2 calibration: tiny ≈ 0.90 s, short ≈ 2.25 s on
        // the idle 32-core cluster with default partitioning.
        let cfg = crate::config::Config::default();
        let tiny = crate::sim::idle_response_time(&cfg, &micro_job(1, "tiny", 0.0, None));
        let short = crate::sim::idle_response_time(&cfg, &micro_job(1, "short", 0.0, None));
        assert!((tiny - 0.90).abs() < 0.15, "tiny idle RT {tiny}");
        assert!((short - 2.25).abs() < 0.30, "short idle RT {short}");
    }

    #[test]
    #[should_panic(expected = "unknown micro job kind")]
    fn micro_job_rejects_unknown_kind() {
        micro_job(1, "huge", 0.0, None);
    }
}
