//! The micro-benchmark scenarios (§5.2.1), constructed with the paper's
//! parameters on the 32-core testbed.
//!
//! Job sizes follow §5.2: *tiny* and *short* jobs with idle-system
//! response times of ≈0.90 s and ≈2.25 s respectively; each analytics job
//! is a 3-phase load → compute → collect chain over its own copy of the
//! dataset.
//!
//! Each scenario is defined **once**, as a lazy [`JobStream`] constructor
//! (per-user generators k-way merged in arrival order); the materialized
//! `Workload` form is the registry's generic collect adapter
//! ([`crate::workload::registry`], entries `scenario1` / `scenario2`).

use super::stream::{from_fn, JobStream, MergeStream};
use super::{UserClass, DATASET_BYTES, SHORT_COMPUTE_SLOT, TINY_COMPUTE_SLOT};
use crate::core::job::{CostProfile, JobSpec};
use crate::s_to_us;
use crate::util::Rng;
use crate::UserId;
use std::collections::HashMap;

/// Make one micro-benchmark job. `kind` ∈ {"tiny", "short"}.
pub fn micro_job(user: u32, kind: &str, arrival_s: f64, skew: Option<CostProfile>) -> JobSpec {
    let (slot, opcount) = match kind {
        "tiny" => (TINY_COMPUTE_SLOT, 4),
        "short" => (SHORT_COMPUTE_SLOT, 16),
        other => panic!("unknown micro job kind '{other}'"),
    };
    JobSpec::three_phase(user, kind, s_to_us(arrival_s), slot, DATASET_BYTES, opcount, skew)
}

/// **Scenario 1 — infrequent and frequent users** (§5.2.1), as a lazy
/// stream of per-user generators merged in arrival order.
///
/// Users 1–2 are *infrequent*: Poisson job submissions (mean gap
/// `poisson_gap_s`), 70 % tiny / 30 % short. Users 3–4 are *frequent*:
/// every 30 s each submits a burst of `burst` short jobs, which together
/// oversubscribe the 32-core cluster and build a backlog.
///
/// User streams are indexed in construction order (users 1–4), so merge
/// ties reproduce a stable sort-by-arrival of the per-user timelines —
/// the exact order the simulator replays.
pub fn scenario1(seed: u64, duration_s: f64, burst: usize, poisson_gap_s: f64) -> MergeStream {
    let mut rng = Rng::new(seed);
    let mut streams: Vec<Box<dyn JobStream + Send>> = Vec::new();

    // Infrequent users (Poisson arrivals, like the paper).
    for user in 1..=2u32 {
        let mut r = rng.fork(user as u64);
        let mut t = r.exp(1.0 / poisson_gap_s);
        streams.push(Box::new(from_fn(move || {
            if t >= duration_s {
                return None;
            }
            let kind = if r.f64() < 0.7 { "tiny" } else { "short" };
            let job = micro_job(user, kind, t, None);
            t += r.exp(1.0 / poisson_gap_s);
            Some(job)
        })));
    }

    // Frequent users (synchronized 30 s burst cycles; tiny start offsets
    // keep arrival order deterministic but overlapping, as in §5.2.1).
    for user in 3..=4u32 {
        let offset = (user - 3) as f64 * 0.050;
        let mut cycle = 0.0;
        let mut b = 0usize;
        streams.push(Box::new(from_fn(move || {
            if burst == 0 || cycle >= duration_s {
                return None;
            }
            let job = micro_job(user, "short", cycle + offset + b as f64 * 0.010, None);
            b += 1;
            if b == burst {
                b = 0;
                cycle += 30.0;
            }
            Some(job)
        })));
    }

    MergeStream::new(streams)
}

/// Scenario 1's fixed user classification: users 1–2 infrequent, 3–4
/// frequent (known before any job yields — O(users) like the stream).
pub fn scenario1_classes() -> HashMap<UserId, UserClass> {
    [
        (1, UserClass::Infrequent),
        (2, UserClass::Infrequent),
        (3, UserClass::Frequent),
        (4, UserClass::Frequent),
    ]
    .into_iter()
    .collect()
}

/// **Scenario 2 — multiple frequent users** (§5.2.1), as a lazy stream.
///
/// Four users each submit `jobs_per_user` tiny jobs at once, with
/// deterministic per-user start delays (`stagger_s` apart) so the user
/// arrival order is consistent across runs. Fully deterministic; `seed`
/// is kept for constructor symmetry.
pub fn scenario2(seed: u64, jobs_per_user: usize, stagger_s: f64) -> MergeStream {
    let _ = seed; // fully deterministic; seed kept for API symmetry
    let streams: Vec<Box<dyn JobStream + Send>> = (1..=4u32)
        .map(|user| {
            let start = (user - 1) as f64 * stagger_s;
            let mut b = 0usize;
            Box::new(from_fn(move || {
                if b >= jobs_per_user {
                    return None;
                }
                // sub-ms stagger within the burst keeps submission order
                // deterministic without affecting the scenario.
                let job = micro_job(user, "tiny", start + b as f64 * 0.001, None);
                b += 1;
                Some(job)
            })) as Box<dyn JobStream + Send>
        })
        .collect();
    MergeStream::new(streams)
}

/// Scenario 2's fixed user classification: all four users frequent.
pub fn scenario2_classes() -> HashMap<UserId, UserClass> {
    (1..=4).map(|u| (u, UserClass::Frequent)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::registry::builtin_workload;
    use crate::workload::stream::materialize;
    use crate::workload::Workload;

    #[test]
    fn scenario1_shape() {
        // The registry's collect adapter over the paper-default stream.
        let w = builtin_workload("scenario1", 42);
        // 2 infrequent + 2 frequent users.
        assert_eq!(w.users().len(), 4);
        let freq: Vec<_> = w
            .user_class
            .iter()
            .filter(|(_, c)| **c == UserClass::Frequent)
            .collect();
        assert_eq!(freq.len(), 2);
        // Frequent users dominate the workload.
        let freq_work: f64 = w
            .jobs
            .iter()
            .filter(|j| w.user_class[&j.user] == UserClass::Frequent)
            .map(|j| j.slot_time())
            .sum();
        assert!(freq_work / w.total_slot_time() > 0.8);
        // Oversubscribed: >100% of 32 cores over 300 s + drain time.
        assert!(w.utilization(32, 330.0) > 0.7, "util {}", w.utilization(32, 330.0));
        // 10 burst cycles × 2 users × 6 jobs = 120 short jobs minimum.
        assert!(w.jobs.len() >= 120);
    }

    #[test]
    fn scenario1_deterministic_per_seed() {
        let key = |seed: u64| {
            materialize(scenario1(seed, 300.0, 6, 40.0))
                .iter()
                .map(|j| (j.user, j.arrival, j.name.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(7), key(7));
        assert_ne!(key(7), key(8));
    }

    #[test]
    fn scenario1_yields_sorted_arrivals() {
        let jobs = materialize(scenario1(7, 120.0, 3, 30.0));
        assert!(jobs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        for j in &jobs {
            j.validate().unwrap();
        }
    }

    #[test]
    fn scenario2_shape() {
        let w: Workload = builtin_workload("scenario2", 1);
        assert_eq!(w.jobs.len(), 80);
        assert_eq!(w.users().len(), 4);
        // Start delays order the users.
        let first_arrival = |u: u32| {
            w.jobs
                .iter()
                .filter(|j| j.user == u)
                .map(|j| j.arrival)
                .min()
                .unwrap()
        };
        assert!(first_arrival(1) < first_arrival(2));
        assert!(first_arrival(3) < first_arrival(4));
        // All tiny.
        assert!(w.jobs.iter().all(|j| &*j.name == "tiny"));
    }

    #[test]
    fn micro_job_idle_rts_calibrated() {
        // Validate the §5.2 calibration: tiny ≈ 0.90 s, short ≈ 2.25 s on
        // the idle 32-core cluster with default partitioning.
        let cfg = crate::config::Config::default();
        let tiny = crate::sim::idle_response_time(&cfg, &micro_job(1, "tiny", 0.0, None));
        let short = crate::sim::idle_response_time(&cfg, &micro_job(1, "short", 0.0, None));
        assert!((tiny - 0.90).abs() < 0.15, "tiny idle RT {tiny}");
        assert!((short - 2.25).abs() < 0.30, "short idle RT {short}");
    }

    #[test]
    #[should_panic(expected = "unknown micro job kind")]
    fn micro_job_rejects_unknown_kind() {
        micro_job(1, "huge", 0.0, None);
    }
}
