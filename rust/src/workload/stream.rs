//! Streaming workloads — lazy job timelines for million-job runs.
//!
//! The materialized [`super::Workload`] builds the full `Vec<JobSpec>` up
//! front, so peak memory is O(total jobs). Trace-driven scheduler studies
//! at larger scales (Pastorelli et al., *Practical Size-based Scheduling
//! for MapReduce Workloads*; Le et al., *BoPF*) only work because their
//! pipelines *stream* the trace instead. This module provides the same
//! for the simulator:
//!
//! * [`JobStream`] — the lazy job-source contract: `next_job` yields
//!   `JobSpec`s in nondecreasing arrival order.
//! * [`MergeStream`] — a k-way merge of per-user (or per-source) streams
//!   by a small binary heap: O(streams) resident state, O(log streams)
//!   per job. Ties break by stream index, which reproduces the stable
//!   sort-by-arrival order of the materialized path when streams are
//!   created in workload-construction order.
//! * [`VecStream`] — the thin materialized adapter: any `Workload` (or
//!   bare job vector) is also a stream, stable-sorted exactly like
//!   [`crate::sim::simulate`] sorts it.
//! * [`scale_stream`] — the million-job / ten-thousand-user workload
//!   behind `uwfq scale` and `benches/scale.rs`: per-user seeded Poisson
//!   generators over a small set of interned job templates, k-way merged.
//!   Resident state is O(users), independent of total job count.
//!
//! Every workload in the repo is *defined* as a stream and registered in
//! [`super::registry`]; the materialized [`super::Workload`] form is the
//! registry's generic `collect()` adapter over the stream. The generic
//! differential test (`tests/stream_differential`) asserts, for every
//! registry entry, that simulating the stream is byte-identical to
//! simulating its collected form across all five policies.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::core::job::{CostProfile, JobSpec, StagePhase, StageSpec};
use crate::s_to_us;
use crate::util::Rng;
use crate::TimeUs;

/// A lazy job timeline: yields `JobSpec`s in **nondecreasing arrival
/// order** (debug-asserted by the simulator). Implementations should hold
/// O(1)–O(users) state, not O(total jobs).
pub trait JobStream {
    fn next_job(&mut self) -> Option<JobSpec>;

    /// Jobs still to come, when known (sizing hints only).
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

/// Boxed streams are streams too — what lets the scenario registry hand
/// out `Box<dyn JobStream + Send>` that plugs into every generic driver
/// (`materialize`, `simulate_stream`, `MergeStream` sources).
impl JobStream for Box<dyn JobStream + Send> {
    fn next_job(&mut self) -> Option<JobSpec> {
        (**self).next_job()
    }

    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}

/// Mutable borrows are streams too: drive a stream you still own through
/// a by-value consumer (`materialize`, `simulate_stream_into`) and read
/// its counters afterwards — how the trace-replay tests assert the
/// bounded-state contract after a run.
impl<S: JobStream> JobStream for &mut S {
    fn next_job(&mut self) -> Option<JobSpec> {
        (**self).next_job()
    }

    fn size_hint(&self) -> Option<usize> {
        (**self).size_hint()
    }
}

// ---------------------------------------------------------------------------
// Materialized adapter
// ---------------------------------------------------------------------------

/// The materialized adapter: wraps an owned job vector, stable-sorted by
/// arrival — the exact order [`crate::sim::simulate`] feeds the engine.
pub struct VecStream {
    jobs: std::vec::IntoIter<JobSpec>,
}

impl VecStream {
    pub fn new(mut jobs: Vec<JobSpec>) -> VecStream {
        // Stable: same-instant arrivals keep vector order, matching the
        // simulator's tie-break contract.
        jobs.sort_by_key(|j| j.arrival);
        VecStream {
            jobs: jobs.into_iter(),
        }
    }
}

impl JobStream for VecStream {
    fn next_job(&mut self) -> Option<JobSpec> {
        self.jobs.next()
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.jobs.len())
    }
}

// ---------------------------------------------------------------------------
// Shard splitter
// ---------------------------------------------------------------------------

/// Hash-stable shard assignment of a user: a splitmix64 finalizer over
/// the user id, reduced mod `shards`. Stable across runs, shard counts
/// are free to vary (changing S reassigns users, same S never does), and
/// `shards <= 1` degenerates to shard 0.
pub fn shard_of(user: crate::UserId, shards: u32) -> u32 {
    if shards <= 1 {
        return 0;
    }
    let mut x = (user as u64) ^ 0x9E37_79B9_7F4A_7C15;
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x % shards as u64) as u32
}

/// One shard's view of a full workload: passes through exactly the jobs
/// whose user hashes to `shard`, in stream order. Because it filters the
/// *same* underlying timeline each shard regenerates independently,
/// per-user arrival order (and every job's content) is preserved
/// verbatim with O(1) extra state — the union over shards is a
/// partition of the original stream.
pub struct ShardStream<S> {
    inner: S,
    shard: u32,
    shards: u32,
}

impl<S: JobStream> ShardStream<S> {
    pub fn new(inner: S, shard: u32, shards: u32) -> ShardStream<S> {
        assert!(shard < shards.max(1), "shard index out of range");
        ShardStream {
            inner,
            shard,
            shards,
        }
    }
}

impl<S: JobStream> JobStream for ShardStream<S> {
    fn next_job(&mut self) -> Option<JobSpec> {
        loop {
            let job = self.inner.next_job()?;
            if shard_of(job.user, self.shards) == self.shard {
                return Some(job);
            }
        }
    }

    fn size_hint(&self) -> Option<usize> {
        // Upper bound only: the inner hint counts all shards' jobs.
        self.inner.size_hint()
    }
}

/// A stream from a plain closure (per-user generators without bespoke
/// structs). The closure must yield nondecreasing arrivals.
pub struct GenStream<F: FnMut() -> Option<JobSpec>> {
    f: F,
}

/// Wrap a generator closure as a [`JobStream`].
pub fn from_fn<F: FnMut() -> Option<JobSpec>>(f: F) -> GenStream<F> {
    GenStream { f }
}

impl<F: FnMut() -> Option<JobSpec>> JobStream for GenStream<F> {
    fn next_job(&mut self) -> Option<JobSpec> {
        (self.f)()
    }
}

/// Drain a stream into a vector (tests and the materialized round-trip).
pub fn materialize(mut stream: impl JobStream) -> Vec<JobSpec> {
    let mut jobs = Vec::with_capacity(stream.size_hint().unwrap_or(0));
    while let Some(j) = stream.next_job() {
        jobs.push(j);
    }
    jobs
}

// ---------------------------------------------------------------------------
// K-way merge
// ---------------------------------------------------------------------------

/// K-way merge of per-source streams by a small min-heap keyed on
/// `(arrival, stream index)`. Each source must itself be nondecreasing;
/// the merged output then is too. Equal arrivals pop in stream-index
/// order, so indexing streams in workload-construction order reproduces
/// the materialized stable sort exactly.
pub struct MergeStream {
    streams: Vec<Box<dyn JobStream + Send>>,
    /// One look-ahead job per live stream (the heap stores only the key).
    buffered: Vec<Option<JobSpec>>,
    heap: BinaryHeap<Reverse<(TimeUs, usize)>>,
}

impl MergeStream {
    pub fn new(mut streams: Vec<Box<dyn JobStream + Send>>) -> MergeStream {
        let mut buffered: Vec<Option<JobSpec>> = Vec::with_capacity(streams.len());
        let mut heap = BinaryHeap::with_capacity(streams.len());
        for (i, s) in streams.iter_mut().enumerate() {
            match s.next_job() {
                Some(j) => {
                    heap.push(Reverse((j.arrival, i)));
                    buffered.push(Some(j));
                }
                None => buffered.push(None),
            }
        }
        MergeStream {
            streams,
            buffered,
            heap,
        }
    }
}

impl JobStream for MergeStream {
    fn next_job(&mut self) -> Option<JobSpec> {
        let Reverse((_, i)) = self.heap.pop()?;
        let job = self.buffered[i].take().expect("heap entry without buffered job");
        if let Some(next) = self.streams[i].next_job() {
            debug_assert!(
                next.arrival >= job.arrival,
                "per-source stream must yield nondecreasing arrivals"
            );
            self.heap.push(Reverse((next.arrival, i)));
            self.buffered[i] = Some(next);
        }
        Some(job)
    }

    fn size_hint(&self) -> Option<usize> {
        let buffered = self.buffered.iter().filter(|b| b.is_some()).count();
        let mut total = buffered;
        for s in &self.streams {
            total += s.size_hint()?;
        }
        Some(total)
    }
}

// ---------------------------------------------------------------------------
// The scale workload (million jobs, ten thousand users)
// ---------------------------------------------------------------------------

/// Parameters of the streaming scale workload.
#[derive(Clone, Debug)]
pub struct ScaleParams {
    pub users: u32,
    pub jobs: u64,
    /// Cores of the target cluster — with `target_utilization` this sets
    /// the workload window, which keeps the backlog (and therefore the
    /// engine's resident state) statistically bounded.
    pub cores: u32,
    pub target_utilization: f64,
    pub seed: u64,
}

impl Default for ScaleParams {
    fn default() -> Self {
        ScaleParams {
            users: 10_000,
            jobs: 1_000_000,
            cores: 64,
            target_utilization: 0.85,
            seed: 42,
        }
    }
}

/// One interned job template of the scale workload.
struct ScaleTemplate {
    name: Arc<str>,
    /// Probability weight (normalized over the template set).
    weight: f64,
    /// Total sequential work (core-seconds).
    slot: f64,
    /// Parallelism (tasks per stage, capped via `max_parallelism`).
    tasks: u32,
}

/// The template mix: mostly interactive-sized jobs with a heavy-ish tail,
/// echoing the paper's micro/macro size spread. Mean work ≈ 3.55 core-s.
fn scale_templates() -> Vec<ScaleTemplate> {
    vec![
        ScaleTemplate { name: Arc::from("sc-tiny"), weight: 0.50, slot: 0.5, tasks: 4 },
        ScaleTemplate { name: Arc::from("sc-small"), weight: 0.30, slot: 2.0, tasks: 8 },
        ScaleTemplate { name: Arc::from("sc-medium"), weight: 0.15, slot: 8.0, tasks: 16 },
        ScaleTemplate { name: Arc::from("sc-large"), weight: 0.05, slot: 30.0, tasks: 32 },
    ]
}

/// Build one scale job from a template. A two-stage load → compute chain;
/// `max_parallelism` pins the task count so per-job work is independent
/// of the cluster size (leaf stages otherwise split one-per-core).
fn scale_job(user: u32, arrival: TimeUs, tpl: &ScaleTemplate) -> JobSpec {
    let bytes = tpl.tasks as u64 * (24 << 20);
    let load = StageSpec {
        phase: StagePhase::Load,
        parents: vec![],
        is_leaf_input: true,
        input_bytes: bytes,
        slot_time: tpl.slot * 0.25,
        cost: CostProfile::uniform(),
        max_parallelism: Some(tpl.tasks),
        opcount: 1,
        demand: crate::core::task::ResourceVec::UNIT,
    };
    let compute = StageSpec {
        phase: StagePhase::Compute,
        parents: vec![0],
        is_leaf_input: false,
        input_bytes: bytes,
        slot_time: tpl.slot * 0.75,
        cost: CostProfile::uniform(),
        max_parallelism: Some(tpl.tasks),
        opcount: 4,
        demand: crate::core::task::ResourceVec::UNIT,
    };
    JobSpec {
        user,
        name: tpl.name.clone(),
        arrival,
        weight: 1.0,
        stages: vec![load, compute],
    }
}

/// One job per distinct scale template (arrival 0) — the input for the
/// idle-response map that turns streaming RTs into slowdowns. O(templates)
/// regardless of run size.
pub fn scale_template_jobs() -> Vec<JobSpec> {
    scale_templates()
        .iter()
        .map(|t| scale_job(0, 0, t))
        .collect()
}

/// One user's lazy Poisson job source.
struct ScaleUser {
    user: u32,
    rng: Rng,
    templates: Arc<Vec<ScaleTemplate>>,
    /// Next arrival (seconds on the workload timeline).
    t: f64,
    mean_gap_s: f64,
    remaining: u64,
}

impl JobStream for ScaleUser {
    fn next_job(&mut self) -> Option<JobSpec> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        // Template choice by cumulative weight.
        let x = self.rng.f64();
        let total: f64 = self.templates.iter().map(|t| t.weight).sum();
        let mut acc = 0.0;
        let mut pick = self.templates.len() - 1;
        for (i, t) in self.templates.iter().enumerate() {
            acc += t.weight / total;
            if x < acc {
                pick = i;
                break;
            }
        }
        let job = scale_job(self.user, s_to_us(self.t), &self.templates[pick]);
        self.t += self.rng.exp(1.0 / self.mean_gap_s);
        Some(job)
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.remaining as usize)
    }
}

/// The streaming scale workload: `jobs` jobs spread over `users` seeded
/// Poisson users, k-way merged in arrival order. Resident state is
/// O(users) — one RNG, one look-ahead job and one heap slot per user —
/// so a million-job run never materializes its timeline.
pub fn scale_stream(p: &ScaleParams) -> MergeStream {
    assert!(p.users > 0 && p.cores > 0 && p.target_utilization > 0.0);
    let templates = Arc::new(scale_templates());
    let total_weight: f64 = templates.iter().map(|t| t.weight).sum();
    let mean_slot: f64 = templates.iter().map(|t| t.weight * t.slot).sum::<f64>() / total_weight;
    // Window sized so expected offered load matches the utilization
    // target: keeps the in-flight backlog (engine arenas) statistically
    // bounded instead of growing with the job count.
    let window_s =
        (p.jobs as f64 * mean_slot / (p.cores as f64 * p.target_utilization)).max(1.0);

    let mut root = Rng::new(p.seed);
    let per_user = p.jobs / p.users as u64;
    let extra = p.jobs % p.users as u64;
    let mut streams: Vec<Box<dyn JobStream + Send>> = Vec::with_capacity(p.users as usize);
    for u in 0..p.users {
        let n = per_user + u64::from((u as u64) < extra);
        let mut rng = root.fork(u as u64 + 1);
        let mean_gap_s = window_s / n.max(1) as f64;
        let t0 = rng.range_f64(0.0, mean_gap_s);
        streams.push(Box::new(ScaleUser {
            user: u + 1,
            rng,
            templates: Arc::clone(&templates),
            t: t0,
            mean_gap_s,
            remaining: n,
        }));
    }
    MergeStream::new(streams)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chirp(user: u32, arrivals: &[f64]) -> Box<dyn JobStream + Send> {
        let jobs: Vec<JobSpec> = arrivals
            .iter()
            .map(|&t| JobSpec::three_phase(user, "c", s_to_us(t), 0.1, 1 << 20, 1, None))
            .collect();
        let mut it = jobs.into_iter();
        Box::new(from_fn(move || it.next()))
    }

    #[test]
    fn merge_yields_global_arrival_order_with_stable_ties() {
        let m = MergeStream::new(vec![
            chirp(1, &[0.0, 2.0, 2.0, 5.0]),
            chirp(2, &[1.0, 2.0, 4.0]),
            chirp(3, &[2.0]),
        ]);
        let jobs = materialize(m);
        let key: Vec<(TimeUs, u32)> = jobs.iter().map(|j| (j.arrival, j.user)).collect();
        // Sorted by arrival; at t=2.0 the tie breaks by stream index and
        // both of stream 1's t=2 jobs precede streams 2 and 3.
        let expect = vec![
            (s_to_us(0.0), 1),
            (s_to_us(1.0), 2),
            (s_to_us(2.0), 1),
            (s_to_us(2.0), 1),
            (s_to_us(2.0), 2),
            (s_to_us(2.0), 3),
            (s_to_us(4.0), 2),
            (s_to_us(5.0), 1),
        ];
        assert_eq!(key, expect);
    }

    #[test]
    fn merge_matches_vec_stream_stable_sort() {
        // The merged order must equal VecStream's stable sort of the
        // concatenation in stream order — the parity contract the
        // scenario streams rely on.
        let streams = [
            (1u32, vec![0.5, 1.0, 1.0, 3.0]),
            (2u32, vec![1.0, 2.0]),
            (3u32, vec![0.5, 1.0, 9.0]),
        ];
        let mut concat = Vec::new();
        for (u, ts) in &streams {
            for &t in ts {
                concat.push(JobSpec::three_phase(*u, "c", s_to_us(t), 0.1, 1 << 20, 1, None));
            }
        }
        let sorted = materialize(VecStream::new(concat));
        let merged = materialize(MergeStream::new(
            streams
                .iter()
                .map(|(u, ts)| chirp(*u, ts))
                .collect(),
        ));
        let key = |jobs: &[JobSpec]| -> Vec<(TimeUs, u32)> {
            jobs.iter().map(|j| (j.arrival, j.user)).collect()
        };
        assert_eq!(key(&sorted), key(&merged));
    }

    #[test]
    fn vec_stream_sorts_and_reports_size() {
        let jobs = vec![
            JobSpec::three_phase(1, "a", 5_000_000, 0.1, 1 << 20, 1, None),
            JobSpec::three_phase(2, "b", 1_000_000, 0.1, 1 << 20, 1, None),
        ];
        let mut s = VecStream::new(jobs);
        assert_eq!(s.size_hint(), Some(2));
        assert_eq!(s.next_job().unwrap().user, 2);
        assert_eq!(s.next_job().unwrap().user, 1);
        assert!(s.next_job().is_none());
    }

    #[test]
    fn scale_stream_counts_and_order() {
        let p = ScaleParams {
            users: 7,
            jobs: 100,
            cores: 8,
            target_utilization: 0.8,
            seed: 3,
        };
        let mut s = scale_stream(&p);
        assert_eq!(s.size_hint(), Some(100));
        let mut last: TimeUs = 0;
        let mut count = 0u64;
        let mut users = std::collections::HashSet::new();
        while let Some(j) = s.next_job() {
            assert!(j.arrival >= last, "arrivals must be nondecreasing");
            last = j.arrival;
            users.insert(j.user);
            j.validate().unwrap();
            count += 1;
        }
        assert_eq!(count, 100);
        assert_eq!(users.len(), 7);
    }

    #[test]
    fn scale_stream_is_deterministic_and_seed_sensitive() {
        let p = ScaleParams {
            users: 5,
            jobs: 60,
            cores: 8,
            target_utilization: 0.8,
            seed: 11,
        };
        let key = |p: &ScaleParams| -> Vec<(u32, TimeUs, Arc<str>)> {
            materialize(scale_stream(p))
                .into_iter()
                .map(|j| (j.user, j.arrival, j.name))
                .collect()
        };
        assert_eq!(key(&p), key(&p));
        let mut p2 = p.clone();
        p2.seed = 12;
        assert_ne!(key(&p), key(&p2));
    }

    #[test]
    fn scale_jobs_share_interned_template_names() {
        let p = ScaleParams {
            users: 3,
            jobs: 40,
            cores: 8,
            target_utilization: 0.8,
            seed: 1,
        };
        let jobs = materialize(scale_stream(&p));
        let distinct: std::collections::HashSet<&str> =
            jobs.iter().map(|j| &*j.name).collect();
        assert!(distinct.len() <= scale_templates().len());
        // Interning: two jobs of the same template share the allocation.
        let a = jobs.iter().find(|j| &*j.name == "sc-tiny");
        let b = jobs.iter().rfind(|j| &*j.name == "sc-tiny");
        if let (Some(a), Some(b)) = (a, b) {
            assert!(Arc::ptr_eq(&a.name, &b.name));
        }
    }

    #[test]
    fn scale_template_jobs_cover_the_mix() {
        let tpls = scale_template_jobs();
        assert_eq!(tpls.len(), 4);
        for t in &tpls {
            t.validate().unwrap();
            assert_eq!(t.stages.len(), 2);
        }
    }

    #[test]
    fn shard_of_is_stable_and_degenerate_at_one() {
        for u in 0..500u32 {
            assert_eq!(shard_of(u, 1), 0);
            assert_eq!(shard_of(u, 0), 0);
            for s in [2u32, 4, 7] {
                let a = shard_of(u, s);
                assert!(a < s);
                assert_eq!(a, shard_of(u, s), "assignment must be pure");
            }
        }
        // The finalizer actually spreads users (not all in one shard).
        let counts = (0..1000u32).fold([0usize; 4], |mut acc, u| {
            acc[shard_of(u, 4) as usize] += 1;
            acc
        });
        for (s, &c) in counts.iter().enumerate() {
            assert!(c > 100, "shard {s} starved: {counts:?}");
        }
    }

    #[test]
    fn shard_streams_partition_the_timeline() {
        // The union of the 3 shard views is exactly the full stream, each
        // user lands in exactly one shard, and per-user order (the whole
        // job sequence, arrival-for-arrival) is preserved verbatim.
        let p = ScaleParams {
            users: 23,
            jobs: 200,
            cores: 8,
            target_utilization: 0.8,
            seed: 5,
        };
        let full = materialize(scale_stream(&p));
        let shards = 3u32;
        let mut union: Vec<Vec<JobSpec>> = Vec::new();
        for s in 0..shards {
            let part = materialize(ShardStream::new(scale_stream(&p), s, shards));
            for j in &part {
                assert_eq!(shard_of(j.user, shards), s);
            }
            union.push(part);
        }
        assert_eq!(
            union.iter().map(Vec::len).sum::<usize>(),
            full.len(),
            "shards must partition the stream"
        );
        let per_user = |jobs: &[JobSpec]| {
            let mut m: std::collections::HashMap<u32, Vec<(TimeUs, Arc<str>)>> =
                std::collections::HashMap::new();
            for j in jobs {
                m.entry(j.user).or_default().push((j.arrival, j.name.clone()));
            }
            m
        };
        let want = per_user(&full);
        let mut got: std::collections::HashMap<u32, Vec<(TimeUs, Arc<str>)>> =
            std::collections::HashMap::new();
        for part in &union {
            for (u, seq) in per_user(part) {
                assert!(got.insert(u, seq).is_none(), "user split across shards");
            }
        }
        assert_eq!(got, want, "per-user sequences must survive sharding");
    }
}
