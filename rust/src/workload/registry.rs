//! The scenario registry — every workload in the repo, defined **once**.
//!
//! A [`Scenario`] is a name, a typed parameter schema (key/value params
//! with defaults, overridable from `--param k=v` flags and `param.k = v`
//! config-file lines), and a single constructor returning a lazy
//! [`JobStream`] plus the per-user classification. The materialized
//! [`Workload`] form is the generic [`ScenarioInstance::collect`] adapter
//! over the stream — there are no hand-wired materialized/streamed twin
//! functions anywhere.
//!
//! Grids reference scenarios as *data* ([`ScenarioSpec`]: name + raw
//! overrides), so adding a workload is one registration here: it is
//! immediately listable (`uwfq scenarios`), runnable
//! (`uwfq run --scenario NAME --param k=v`), and sweepable across every
//! policy × partitioner (`uwfq sweep --scenario NAME`) with zero
//! bench-layer code. The generic differential test
//! (`tests/stream_differential.rs`) asserts for **every** entry that
//! simulating the stream is byte-identical to simulating its collected
//! form under all five policies.

use std::collections::HashMap;
use std::fmt;
use std::sync::OnceLock;

use super::gtrace::{self, GtraceParams};
use super::scenarios;
use super::stream::{self, materialize, JobStream, ScaleParams};
use super::stress::{self, BurstyParams, DiurnalParams, HeavytailParams, SkewedParams};
use super::traceio::{self, ShapeParams, TraceFormat, TraceParams};
use super::tracefile;
use super::{UserClass, Workload};
use crate::UserId;

// ---------------------------------------------------------------------------
// Typed parameters
// ---------------------------------------------------------------------------

/// A typed scenario parameter value. The schema default fixes the type;
/// overrides are parsed as that type.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValue {
    U64(u64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl ParamValue {
    pub fn type_name(&self) -> &'static str {
        match self {
            ParamValue::U64(_) => "int",
            ParamValue::F64(_) => "float",
            ParamValue::Bool(_) => "bool",
            ParamValue::Str(_) => "string",
        }
    }

    /// Parse `raw` as this value's type.
    fn parse_as(&self, raw: &str) -> Result<ParamValue, String> {
        match self {
            ParamValue::U64(_) => raw
                .parse()
                .map(ParamValue::U64)
                .map_err(|_| format!("expected int, got '{raw}'")),
            ParamValue::F64(_) => raw
                .parse::<f64>()
                .ok()
                .filter(|v| v.is_finite())
                .map(ParamValue::F64)
                .ok_or_else(|| format!("expected finite float, got '{raw}'")),
            ParamValue::Bool(_) => match raw {
                "true" | "1" => Ok(ParamValue::Bool(true)),
                "false" | "0" => Ok(ParamValue::Bool(false)),
                _ => Err(format!("expected bool, got '{raw}'")),
            },
            ParamValue::Str(_) => Ok(ParamValue::Str(raw.to_string())),
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::U64(v) => write!(f, "{v}"),
            ParamValue::F64(v) => write!(f, "{v}"),
            ParamValue::Bool(v) => write!(f, "{v}"),
            ParamValue::Str(v) => write!(f, "{v}"),
        }
    }
}

/// One entry of a scenario's parameter schema.
pub struct ParamSpec {
    pub name: &'static str,
    pub doc: &'static str,
    pub default: ParamValue,
}

/// Shorthand constructors for schema tables.
pub const fn p_u64(name: &'static str, default: u64, doc: &'static str) -> ParamSpec {
    ParamSpec { name, doc, default: ParamValue::U64(default) }
}
pub const fn p_f64(name: &'static str, default: f64, doc: &'static str) -> ParamSpec {
    ParamSpec { name, doc, default: ParamValue::F64(default) }
}
pub const fn p_bool(name: &'static str, default: bool, doc: &'static str) -> ParamSpec {
    ParamSpec { name, doc, default: ParamValue::Bool(default) }
}
/// String params default to empty in `const` tables (non-empty `String`
/// construction is not const); scenarios treat empty as "unset".
pub const fn p_str(name: &'static str, doc: &'static str) -> ParamSpec {
    ParamSpec { name, doc, default: ParamValue::Str(String::new()) }
}

/// A validated parameter bag: every schema entry present (defaults filled
/// in), every override type-checked against the schema. Later overrides
/// win, so layering is `defaults ← quick ← config file ← CLI flags`.
pub struct Params {
    values: Vec<(&'static str, ParamValue)>,
}

impl Params {
    pub fn from_schema(
        schema: &[ParamSpec],
        overrides: &[(String, String)],
    ) -> Result<Params, String> {
        let mut values: Vec<(&'static str, ParamValue)> = schema
            .iter()
            .map(|s| (s.name, s.default.clone()))
            .collect();
        for (k, raw) in overrides {
            let slot = values.iter_mut().find(|entry| entry.0 == k.as_str()).ok_or_else(|| {
                let valid: Vec<&str> = schema.iter().map(|s| s.name).collect();
                format!("unknown param '{k}' (valid params: {})", valid.join(", "))
            })?;
            slot.1 = slot.1.parse_as(raw).map_err(|e| format!("param '{k}': {e}"))?;
        }
        Ok(Params { values })
    }

    fn get(&self, name: &str) -> &ParamValue {
        self.values
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v)
            .unwrap_or_else(|| panic!("scenario read unschema'd param '{name}'"))
    }

    /// Typed accessors — panicking on a name/type mismatch, which is a
    /// registration bug (the schema and the constructor live side by
    /// side), not a user error. Narrowing accessors return `Err` instead:
    /// an out-of-range value is user input, not a registration bug.
    pub fn u64(&self, name: &str) -> u64 {
        match self.get(name) {
            ParamValue::U64(v) => *v,
            other => panic!("param '{name}' is {}, not int", other.type_name()),
        }
    }
    pub fn u32(&self, name: &str) -> Result<u32, String> {
        let v = self.u64(name);
        u32::try_from(v)
            .map_err(|_| format!("param '{name}': {v} out of range (max {})", u32::MAX))
    }
    pub fn usize(&self, name: &str) -> Result<usize, String> {
        let v = self.u64(name);
        usize::try_from(v).map_err(|_| format!("param '{name}': {v} out of range"))
    }
    pub fn f64(&self, name: &str) -> f64 {
        match self.get(name) {
            ParamValue::F64(v) => *v,
            other => panic!("param '{name}' is {}, not float", other.type_name()),
        }
    }
    pub fn bool(&self, name: &str) -> bool {
        match self.get(name) {
            ParamValue::Bool(v) => *v,
            other => panic!("param '{name}' is {}, not bool", other.type_name()),
        }
    }
    pub fn str(&self, name: &str) -> &str {
        match self.get(name) {
            ParamValue::Str(v) => v,
            other => panic!("param '{name}' is {}, not string", other.type_name()),
        }
    }
}

// ---------------------------------------------------------------------------
// The Scenario contract
// ---------------------------------------------------------------------------

/// A built scenario: the lazy job stream plus everything about the
/// workload that is known without draining it.
pub struct ScenarioInstance {
    pub name: &'static str,
    pub stream: Box<dyn JobStream + Send>,
    pub user_class: HashMap<UserId, UserClass>,
}

impl ScenarioInstance {
    /// The generic collect adapter — the materialized [`Workload`] form
    /// of any scenario. Streams yield in nondecreasing arrival order, so
    /// the collected job list is exactly the order the simulator replays;
    /// simulating it is byte-identical to simulating the stream (the
    /// generic differential test asserts this per entry).
    pub fn collect(self) -> Workload {
        Workload {
            name: self.name.to_string(),
            jobs: materialize(self.stream),
            user_class: self.user_class,
        }
    }
}

/// One registered workload: name, parameter schema, and the single
/// stream-returning constructor.
pub trait Scenario: Send + Sync {
    fn name(&self) -> &'static str;
    /// One-line description for `uwfq scenarios`.
    fn doc(&self) -> &'static str;
    fn schema(&self) -> &'static [ParamSpec];
    /// Overrides that shrink the scenario for smoke runs
    /// (`uwfq run --quick`, CI, the generic differential test).
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[]
    }
    /// Build the stream + classification from validated params.
    fn build(&self, seed: u64, params: &Params) -> Result<ScenarioInstance, String>;
}

// ---------------------------------------------------------------------------
// Scenarios as data
// ---------------------------------------------------------------------------

/// A scenario reference as *data*: name plus raw parameter overrides.
/// Grid cells, config files and CLI invocations all reduce to this.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub params: Vec<(String, String)>,
}

impl ScenarioSpec {
    pub fn new(name: &str) -> ScenarioSpec {
        ScenarioSpec {
            name: name.to_string(),
            params: Vec::new(),
        }
    }

    /// Builder-style override (later entries win).
    pub fn with(mut self, key: &str, val: &str) -> ScenarioSpec {
        self.params.push((key.to_string(), val.to_string()));
        self
    }

    /// Resolve against the global registry and build the stream.
    pub fn build(&self, seed: u64) -> Result<ScenarioInstance, String> {
        let sc = Registry::global().get(&self.name)?;
        let params = Params::from_schema(sc.schema(), &self.params)
            .map_err(|e| format!("scenario '{}': {e}", self.name))?;
        sc.build(seed, &params)
    }

    /// Build and collect — the materialized form.
    pub fn workload(&self, seed: u64) -> Result<Workload, String> {
        self.build(seed).map(ScenarioInstance::collect)
    }
}

/// Collect a built-in scenario with default params — for grids over
/// statically-known entries (panics on error, which would be a
/// registration bug).
pub fn builtin_workload(name: &str, seed: u64) -> Workload {
    ScenarioSpec::new(name)
        .workload(seed)
        .unwrap_or_else(|e| panic!("built-in scenario '{name}': {e}"))
}

/// Resolve a `scale` spec into the [`ScaleParams`] the scale harness
/// (`uwfq scale`, `bench::scale::run_scale`) consumes. The registry's
/// `scale` schema is the single source for the scale defaults — the
/// harness and `uwfq run --scenario scale` cannot drift.
pub fn scale_params(spec: &ScenarioSpec, seed: u64) -> Result<ScaleParams, String> {
    if spec.name != "scale" {
        return Err(format!("scale_params: spec names '{}', not 'scale'", spec.name));
    }
    let sc = Registry::global().get("scale")?;
    let p = Params::from_schema(sc.schema(), &spec.params)
        .map_err(|e| format!("scenario 'scale': {e}"))?;
    let params = ScaleParams {
        users: p.u32("users")?,
        jobs: p.u64("jobs"),
        cores: p.u32("cores")?,
        target_utilization: p.f64("target_utilization"),
        seed,
    };
    validate_scale(&params)?;
    Ok(params)
}

/// Shared `scale` validation — clean errors instead of `scale_stream`'s
/// internal assert, for both `uwfq scale` and the registry entry.
fn validate_scale(p: &ScaleParams) -> Result<(), String> {
    if p.users == 0 || p.cores == 0 || p.target_utilization <= 0.0 {
        return Err("scale: users, cores and target_utilization must be positive".into());
    }
    Ok(())
}

/// Resolve a `gtrace` spec into [`GtraceParams`] — the registry schema is
/// the single source for the §5.3 generator defaults, shared by the
/// `gtrace` entry, `uwfq tracegen` and the trace writer.
pub fn gtrace_params(spec: &ScenarioSpec) -> Result<GtraceParams, String> {
    if spec.name != "gtrace" {
        return Err(format!("gtrace_params: spec names '{}', not 'gtrace'", spec.name));
    }
    let sc = Registry::global().get("gtrace")?;
    let p = Params::from_schema(sc.schema(), &spec.params)
        .map_err(|e| format!("scenario 'gtrace': {e}"))?;
    gtrace_params_from(&p)
}

fn gtrace_params_from(p: &Params) -> Result<GtraceParams, String> {
    let gp = GtraceParams {
        window_s: p.f64("window_s"),
        users: p.u32("users")?,
        heavy_users: p.u32("heavy_users")?,
        heavy_work_fraction: p.f64("heavy_work_fraction"),
        target_utilization: p.f64("target_utilization"),
        cores: p.u32("cores")?,
        skew_fraction: p.f64("skew_fraction"),
        filter_median_mult: p.f64("filter_median_mult"),
    };
    if gp.heavy_users == 0 || gp.heavy_users >= gp.users {
        return Err(format!(
            "gtrace: need 1 <= heavy_users < users (got {} / {})",
            gp.heavy_users, gp.users
        ));
    }
    if !(gp.heavy_work_fraction > 0.0 && gp.heavy_work_fraction < 1.0) {
        return Err("gtrace: heavy_work_fraction must be in (0, 1)".into());
    }
    if gp.window_s <= 0.0 || gp.cores == 0 {
        return Err("gtrace: window_s and cores must be positive".into());
    }
    Ok(gp)
}

/// Resolve a `trace` spec into the [`TraceParams`] the replay harness
/// (`uwfq replay`, `bench::replay`) consumes — one schema for the CLI,
/// config files and the registry entry.
pub fn trace_params(spec: &ScenarioSpec, seed: u64) -> Result<TraceParams, String> {
    if spec.name != "trace" {
        return Err(format!("trace_params: spec names '{}', not 'trace'", spec.name));
    }
    let sc = Registry::global().get("trace")?;
    let p = Params::from_schema(sc.schema(), &spec.params)
        .map_err(|e| format!("scenario 'trace': {e}"))?;
    trace_params_from(&p, seed)
}

fn trace_params_from(p: &Params, seed: u64) -> Result<TraceParams, String> {
    let path = p.str("path");
    if path.is_empty() {
        return Err("trace: requires --param path=FILE".into());
    }
    let format = TraceFormat::parse(p.str("format")).map_err(|e| format!("trace: {e}"))?;
    let shaping = ShapeParams {
        warmup: p.usize("warmup")?,
        filter_median_mult: p.f64("filter_median_mult"),
        heavy_work_fraction: p.f64("heavy_work_fraction"),
        target_utilization: p.f64("target_utilization"),
        cores: p.u32("cores")?,
    };
    if shaping.warmup == 0 {
        return Err("trace: warmup must be >= 1".into());
    }
    if !(shaping.heavy_work_fraction > 0.0 && shaping.heavy_work_fraction < 1.0) {
        return Err("trace: heavy_work_fraction must be in (0, 1)".into());
    }
    if shaping.filter_median_mult <= 0.0
        || shaping.target_utilization <= 0.0
        || shaping.cores == 0
    {
        return Err(
            "trace: filter_median_mult, target_utilization and cores must be positive".into(),
        );
    }
    let mem_frac = p.f64("mem_frac");
    if !(mem_frac > 0.0 && mem_frac <= 1.0) {
        return Err("trace: mem_frac must be in (0, 1]".into());
    }
    Ok(TraceParams {
        path: path.to_string(),
        format,
        shape: p.bool("shape"),
        shaping,
        skew_fraction: p.f64("skew_fraction"),
        mem_frac,
        seed,
    })
}

/// Resolve a `skewed` spec into [`SkewedParams`] — the registry schema is
/// the single source for the Zipf defaults, shared by the `skewed` entry
/// and the `uwfq shard --skew` bench harness.
pub fn skewed_params(spec: &ScenarioSpec) -> Result<SkewedParams, String> {
    if spec.name != "skewed" {
        return Err(format!("skewed_params: spec names '{}', not 'skewed'", spec.name));
    }
    let sc = Registry::global().get("skewed")?;
    let p = Params::from_schema(sc.schema(), &spec.params)
        .map_err(|e| format!("scenario 'skewed': {e}"))?;
    skewed_params_from(&p)
}

/// Range validation lives in `stress::skewed` — the entry's `build` and
/// every harness caller hit the same checks when constructing the stream.
fn skewed_params_from(p: &Params) -> Result<SkewedParams, String> {
    Ok(SkewedParams {
        users: p.u32("users")?,
        jobs: p.u64("jobs"),
        zipf_s: p.f64("zipf_s"),
        hot_users: p.u32("hot_users")?,
        cores: p.u32("cores")?,
        target_utilization: p.f64("target_utilization"),
        skew_fraction: p.f64("skew_fraction"),
    })
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

pub struct Registry {
    entries: Vec<Box<dyn Scenario>>,
}

impl Registry {
    /// The standard registry: the paper's workloads plus the stress
    /// scenarios. Adding a workload = adding one entry here.
    pub fn standard() -> Registry {
        Registry {
            entries: vec![
                Box::new(Scenario1),
                Box::new(Scenario2),
                Box::new(Gtrace),
                Box::new(Tracefile),
                Box::new(Trace),
                Box::new(Scale),
                Box::new(Bursty),
                Box::new(Heavytail),
                Box::new(Diurnal),
                Box::new(Skewed),
            ],
        }
    }

    /// The process-wide registry instance.
    pub fn global() -> &'static Registry {
        static REG: OnceLock<Registry> = OnceLock::new();
        REG.get_or_init(Registry::standard)
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Scenario> {
        self.entries.iter().map(|e| e.as_ref())
    }

    pub fn get(&self, name: &str) -> Result<&dyn Scenario, String> {
        self.entries
            .iter()
            .find(|e| e.name() == name)
            .map(|e| e.as_ref())
            .ok_or_else(|| {
                format!(
                    "unknown scenario '{name}' (valid scenarios: {})",
                    self.names().join(", ")
                )
            })
    }
}

// ---------------------------------------------------------------------------
// Entries
// ---------------------------------------------------------------------------

struct Scenario1;

const SCENARIO1_SCHEMA: &[ParamSpec] = &[
    p_f64("duration_s", 300.0, "workload window (seconds)"),
    p_u64("burst", 6, "short jobs per frequent-user burst"),
    p_f64("poisson_gap_s", 40.0, "mean submission gap of infrequent users"),
];

impl Scenario for Scenario1 {
    fn name(&self) -> &'static str {
        "scenario1"
    }
    fn doc(&self) -> &'static str {
        "§5.2.1 micro: 2 infrequent Poisson users + 2 frequent burst users"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        SCENARIO1_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("duration_s", "90"), ("burst", "3")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let gap = p.f64("poisson_gap_s");
        if gap <= 0.0 || p.f64("duration_s") <= 0.0 {
            return Err("scenario1: duration_s and poisson_gap_s must be positive".into());
        }
        Ok(ScenarioInstance {
            name: "scenario1",
            stream: Box::new(scenarios::scenario1(
                seed,
                p.f64("duration_s"),
                p.usize("burst")?,
                gap,
            )),
            user_class: scenarios::scenario1_classes(),
        })
    }
}

struct Scenario2;

const SCENARIO2_SCHEMA: &[ParamSpec] = &[
    p_u64("jobs_per_user", 20, "tiny jobs each of the 4 users submits at once"),
    p_f64("stagger_s", 5.0, "per-user start delay"),
];

impl Scenario for Scenario2 {
    fn name(&self) -> &'static str {
        "scenario2"
    }
    fn doc(&self) -> &'static str {
        "§5.2.1 micro: 4 frequent users flood tiny jobs, staggered starts"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        SCENARIO2_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("jobs_per_user", "6")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        Ok(ScenarioInstance {
            name: "scenario2",
            stream: Box::new(scenarios::scenario2(
                seed,
                p.usize("jobs_per_user")?,
                p.f64("stagger_s"),
            )),
            user_class: scenarios::scenario2_classes(),
        })
    }
}

struct Gtrace;

const GTRACE_SCHEMA: &[ParamSpec] = &[
    p_f64("window_s", 500.0, "trace window (seconds)"),
    p_u64("users", 25, "total users"),
    p_u64("heavy_users", 5, "users submitting most of the work"),
    p_f64("heavy_work_fraction", 0.92, "fraction of work from heavy users"),
    p_f64("target_utilization", 1.05, "work / (cores × window)"),
    p_u64("cores", 32, "cluster size the workload is shaped for"),
    p_f64("skew_fraction", 0.3, "fraction of stages with skewed cost"),
    p_f64("filter_median_mult", 10.0, "§5.3 runtime filter (× median)"),
];

impl Scenario for Gtrace {
    fn name(&self) -> &'static str {
        "gtrace"
    }
    fn doc(&self) -> &'static str {
        "§5.3 macro: Google-trace-shaped, 5 heavy users >90% of work"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        GTRACE_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("window_s", "120"), ("users", "10"), ("heavy_users", "3"), ("cores", "8")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let gp = gtrace_params_from(p)?;
        let s = gtrace::gtrace(seed, &gp);
        let user_class = s.user_class.clone();
        Ok(ScenarioInstance {
            name: "gtrace",
            stream: Box::new(s),
            user_class,
        })
    }
}

struct Tracefile;

const TRACEFILE_SCHEMA: &[ParamSpec] =
    &[p_str("path", "CSV trace file (job,user,arrival_s,slot_s,stages,heavy)")];

impl Scenario for Tracefile {
    fn name(&self) -> &'static str {
        "tracefile"
    }
    fn doc(&self) -> &'static str {
        "CSV trace loader — run a real WTA export (--param path=FILE)"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        TRACEFILE_SCHEMA
    }
    fn build(&self, _seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let path = p.str("path");
        if path.is_empty() {
            return Err("tracefile: requires --param path=FILE".into());
        }
        let w = tracefile::load_csv_file(path)?;
        let user_class = w.user_class.clone();
        Ok(ScenarioInstance {
            name: "tracefile",
            stream: Box::new(w.into_stream()),
            user_class,
        })
    }
}

struct Trace;

const TRACE_SCHEMA: &[ParamSpec] = &[
    p_str("path", "trace file (native tracefile CSV or Google-cluster mapping)"),
    p_str("format", "trace format: native | gcluster (empty = detect from header)"),
    p_bool("shape", true, "apply the one-pass §5.3 shaping (false = replay verbatim)"),
    p_u64("warmup", 4096, "rows buffered to freeze the rebalance/rescale factors"),
    p_f64("filter_median_mult", 10.0, "runtime filter (× running P² median)"),
    p_f64("heavy_work_fraction", 0.92, "rebalance target for heavy-user work"),
    p_f64("target_utilization", 1.05, "rescale target: work rate / cores"),
    p_u64("cores", 32, "cluster size the shaping targets"),
    p_f64("skew_fraction", 0.3, "fraction of shaped stages with skewed cost"),
    p_f64("mem_frac", 1.0, "per-task memory demand fraction in (0, 1]"),
];

impl Scenario for Trace {
    fn name(&self) -> &'static str {
        "trace"
    }
    fn doc(&self) -> &'static str {
        "streaming trace replay: one-pass §5.3 shaping, O(warmup) state"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        TRACE_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("warmup", "256")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let tp = trace_params_from(p, seed)?;
        // One validating pass: collects the per-user classification the
        // instance needs up front and surfaces malformed rows as clean
        // errors (the stream itself has no error channel).
        let (user_class, _rows) = traceio::scan_user_classes(&tp.path, tp.format)?;
        Ok(ScenarioInstance {
            name: "trace",
            stream: Box::new(traceio::open_trace(&tp)?),
            user_class,
        })
    }
}

struct Scale;

const SCALE_SCHEMA: &[ParamSpec] = &[
    p_u64("users", 10_000, "Poisson users"),
    p_u64("jobs", 1_000_000, "total jobs across all users"),
    p_u64("cores", 64, "cluster size the window is shaped for"),
    p_f64("target_utilization", 0.85, "offered load vs cluster capacity"),
];

impl Scenario for Scale {
    fn name(&self) -> &'static str {
        "scale"
    }
    fn doc(&self) -> &'static str {
        "streaming million-job / 10k-user workload (`uwfq scale`)"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        SCALE_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("jobs", "50000"), ("users", "1000")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let params = ScaleParams {
            users: p.u32("users")?,
            jobs: p.u64("jobs"),
            cores: p.u32("cores")?,
            target_utilization: p.f64("target_utilization"),
            seed,
        };
        validate_scale(&params)?;
        Ok(ScenarioInstance {
            name: "scale",
            stream: Box::new(stream::scale_stream(&params)),
            // The scale workload has no behaviour classes — every user
            // draws from the same template mix.
            user_class: HashMap::new(),
        })
    }
}

struct Bursty;

const BURSTY_SCHEMA: &[ParamSpec] = &[
    p_u64("users", 4, "on/off bursty users"),
    p_u64("steady_users", 2, "steady background Poisson users"),
    p_f64("duration_s", 300.0, "workload window (seconds)"),
    p_f64("cycle_s", 60.0, "on/off cycle length"),
    p_f64("burst_ratio", 0.1, "fraction of each cycle the users are ON"),
    p_f64("rate", 2.0, "jobs/s per bursty user while ON"),
    p_f64("steady_gap_s", 40.0, "mean gap of the steady users"),
    p_f64("mem_frac", 1.0, "memory demand fraction of the bursty users' tasks, (0, 1]"),
];

impl Scenario for Bursty {
    fn name(&self) -> &'static str {
        "bursty"
    }
    fn doc(&self) -> &'static str {
        "BoPF-style on/off users: synchronized bursts, tunable burst ratio"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        BURSTY_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("duration_s", "60"), ("cycle_s", "30")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let bp = BurstyParams {
            users: p.u32("users")?,
            steady_users: p.u32("steady_users")?,
            duration_s: p.f64("duration_s"),
            cycle_s: p.f64("cycle_s"),
            burst_ratio: p.f64("burst_ratio"),
            rate: p.f64("rate"),
            steady_gap_s: p.f64("steady_gap_s"),
            mem_frac: p.f64("mem_frac"),
        };
        Ok(ScenarioInstance {
            name: "bursty",
            stream: Box::new(stress::bursty(seed, &bp)?),
            user_class: stress::bursty_classes(&bp),
        })
    }
}

struct Heavytail;

const HEAVYTAIL_SCHEMA: &[ParamSpec] = &[
    p_u64("users", 8, "users"),
    p_u64("jobs_per_user", 50, "jobs each user submits"),
    p_f64("mean_gap_s", 5.0, "mean Poisson submission gap per user"),
    p_f64("alpha", 1.5, "Pareto shape (smaller = heavier tail)"),
    p_f64("min_slot", 2.0, "minimum job size (core-seconds)"),
    p_f64("cap_slot", 3600.0, "job size cap (core-seconds)"),
    p_f64("skew_fraction", 0.2, "fraction of stages with skewed cost"),
];

impl Scenario for Heavytail {
    fn name(&self) -> &'static str {
        "heavytail"
    }
    fn doc(&self) -> &'static str {
        "Pareto job sizes with tunable alpha — elephants vs mice"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        HEAVYTAIL_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("users", "4"), ("jobs_per_user", "15")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let hp = HeavytailParams {
            users: p.u32("users")?,
            jobs_per_user: p.u32("jobs_per_user")?,
            mean_gap_s: p.f64("mean_gap_s"),
            alpha: p.f64("alpha"),
            min_slot: p.f64("min_slot"),
            cap_slot: p.f64("cap_slot"),
            skew_fraction: p.f64("skew_fraction"),
        };
        Ok(ScenarioInstance {
            name: "heavytail",
            stream: Box::new(stress::heavytail(seed, &hp)?),
            user_class: stress::heavytail_classes(&hp),
        })
    }
}

struct Diurnal;

const DIURNAL_SCHEMA: &[ParamSpec] = &[
    p_u64("users", 6, "users (shared sinusoid phase)"),
    p_f64("duration_s", 600.0, "workload window (seconds)"),
    p_f64("period_s", 240.0, "sinusoid period (one 'day')"),
    p_f64("amplitude", 0.8, "rate swing in [0, 1)"),
    p_f64("mean_rate", 0.05, "mean jobs/s per user over a period"),
    p_f64("tiny_fraction", 0.7, "fraction of tiny (vs short) jobs"),
];

impl Scenario for Diurnal {
    fn name(&self) -> &'static str {
        "diurnal"
    }
    fn doc(&self) -> &'static str {
        "sinusoidal-rate Poisson arrivals — trough-to-peak load swings"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        DIURNAL_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("duration_s", "240")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let dp = DiurnalParams {
            users: p.u32("users")?,
            duration_s: p.f64("duration_s"),
            period_s: p.f64("period_s"),
            amplitude: p.f64("amplitude"),
            mean_rate: p.f64("mean_rate"),
            tiny_fraction: p.f64("tiny_fraction"),
        };
        Ok(ScenarioInstance {
            name: "diurnal",
            stream: Box::new(stress::diurnal(seed, &dp)?),
            user_class: stress::diurnal_classes(&dp),
        })
    }
}

struct Skewed;

const SKEWED_SCHEMA: &[ParamSpec] = &[
    p_u64("users", 400, "total user population (hot head + cold tail)"),
    p_u64("jobs", 20_000, "total jobs across all users"),
    p_f64("zipf_s", 1.2, "Zipf exponent of the hot head"),
    p_u64("hot_users", 16, "head size following the Zipf law"),
    p_u64("cores", 8, "cluster size the window is shaped for"),
    p_f64("target_utilization", 0.7, "offered load vs cluster capacity"),
    p_f64("skew_fraction", 0.2, "fraction of stages with skewed cost"),
];

impl Scenario for Skewed {
    fn name(&self) -> &'static str {
        "skewed"
    }
    fn doc(&self) -> &'static str {
        "Zipfian per-user rates: a hot head pins shards, the tail idles"
    }
    fn schema(&self) -> &'static [ParamSpec] {
        SKEWED_SCHEMA
    }
    fn quick_overrides(&self) -> &'static [(&'static str, &'static str)] {
        &[("jobs", "1200"), ("users", "40"), ("hot_users", "8")]
    }
    fn build(&self, seed: u64, p: &Params) -> Result<ScenarioInstance, String> {
        let sp = skewed_params_from(p)?;
        Ok(ScenarioInstance {
            name: "skewed",
            stream: Box::new(stress::skewed(seed, &sp)?),
            user_class: stress::skewed_classes(&sp),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lists_all_entries() {
        let names = Registry::global().names();
        assert!(names.len() >= 7, "registry too small: {names:?}");
        for expect in [
            "scenario1",
            "scenario2",
            "gtrace",
            "tracefile",
            "trace",
            "scale",
            "bursty",
            "heavytail",
            "diurnal",
            "skewed",
        ] {
            assert!(names.contains(&expect), "missing '{expect}' in {names:?}");
        }
    }

    #[test]
    fn unknown_scenario_error_lists_names() {
        let err = Registry::global().get("nope").unwrap_err();
        assert!(err.contains("unknown scenario 'nope'"), "{err}");
        assert!(err.contains("bursty") && err.contains("scenario1"), "{err}");
    }

    #[test]
    fn params_layer_and_typecheck() {
        let schema = SCENARIO1_SCHEMA;
        // Defaults.
        let p = Params::from_schema(schema, &[]).unwrap();
        assert_eq!(p.f64("duration_s"), 300.0);
        assert_eq!(p.usize("burst").unwrap(), 6);
        // Later overrides win.
        let ov = vec![
            ("burst".to_string(), "3".to_string()),
            ("burst".to_string(), "9".to_string()),
        ];
        assert_eq!(Params::from_schema(schema, &ov).unwrap().u64("burst"), 9);
        // Type errors name the param.
        let bad = vec![("burst".to_string(), "x".to_string())];
        let err = Params::from_schema(schema, &bad).unwrap_err();
        assert!(err.contains("param 'burst'") && err.contains("int"), "{err}");
        // Unknown params list the valid ones.
        let unk = vec![("bogus".to_string(), "1".to_string())];
        let err = Params::from_schema(schema, &unk).unwrap_err();
        assert!(err.contains("unknown param 'bogus'"), "{err}");
        assert!(err.contains("duration_s"), "{err}");
        // Non-finite floats are rejected at parse time (a NaN duration
        // would make on/off generators spin forever).
        for bad in ["nan", "inf", "-inf"] {
            let ov = vec![("duration_s".to_string(), bad.to_string())];
            let err = Params::from_schema(schema, &ov).unwrap_err();
            assert!(err.contains("finite"), "{bad}: {err}");
        }
        // Narrowing accessors reject out-of-range values as user errors.
        let ov = vec![("burst".to_string(), "4294967297".to_string())];
        let p = Params::from_schema(schema, &ov).unwrap();
        assert!(p.u32("burst").unwrap_err().contains("out of range"));
    }

    #[test]
    fn scale_params_resolve_through_the_schema() {
        // The scale harness's sizes come from the registry schema — one
        // source of truth for defaults and overrides.
        let p = scale_params(&ScenarioSpec::new("scale"), 7).unwrap();
        assert_eq!((p.jobs, p.users, p.cores), (1_000_000, 10_000, 64));
        assert_eq!(p.seed, 7);
        let q = scale_params(
            &ScenarioSpec::new("scale").with("jobs", "500").with("users", "5"),
            1,
        )
        .unwrap();
        assert_eq!((q.jobs, q.users), (500, 5));
        assert!(scale_params(&ScenarioSpec::new("bursty"), 1).is_err());
    }

    #[test]
    fn every_quick_override_is_schema_valid() {
        // Registration-rot guard: each entry's quick overrides must parse
        // against its own schema.
        for sc in Registry::global().iter() {
            let ov: Vec<(String, String)> = sc
                .quick_overrides()
                .iter()
                .map(|&(k, v)| (k.to_string(), v.to_string()))
                .collect();
            Params::from_schema(sc.schema(), &ov)
                .unwrap_or_else(|e| panic!("{}: bad quick overrides: {e}", sc.name()));
        }
    }

    #[test]
    fn spec_builds_and_collects() {
        let w = ScenarioSpec::new("bursty")
            .with("duration_s", "60")
            .with("users", "2")
            .workload(7)
            .unwrap();
        assert_eq!(w.name, "bursty");
        assert!(!w.jobs.is_empty());
        assert!(!w.user_class.is_empty());
        // Invalid *values* surface the scenario's own validation.
        let err = ScenarioSpec::new("bursty")
            .with("burst_ratio", "2.0")
            .build(7)
            .unwrap_err();
        assert!(err.contains("burst_ratio"), "{err}");
    }

    #[test]
    fn tracefile_requires_path() {
        let err = ScenarioSpec::new("tracefile").build(1).unwrap_err();
        assert!(err.contains("path"), "{err}");
    }

    #[test]
    fn trace_entry_validates_params() {
        // Path is mandatory; a missing file surfaces the path.
        let err = ScenarioSpec::new("trace").build(1).unwrap_err();
        assert!(err.contains("path"), "{err}");
        let err = ScenarioSpec::new("trace")
            .with("path", "/nonexistent/t.csv")
            .build(1)
            .unwrap_err();
        assert!(err.contains("/nonexistent/t.csv"), "{err}");
        // Bad format / bad shaping params error before any file I/O.
        let err = trace_params(
            &ScenarioSpec::new("trace").with("path", "x.csv").with("format", "tsv"),
            1,
        )
        .unwrap_err();
        assert!(err.contains("gcluster"), "{err}");
        let err = trace_params(
            &ScenarioSpec::new("trace").with("path", "x.csv").with("warmup", "0"),
            1,
        )
        .unwrap_err();
        assert!(err.contains("warmup"), "{err}");
        // Valid specs resolve through the schema with layered overrides.
        let tp = trace_params(
            &ScenarioSpec::new("trace")
                .with("path", "x.csv")
                .with("warmup", "64")
                .with("shape", "false")
                .with("cores", "8"),
            7,
        )
        .unwrap();
        assert_eq!(tp.shaping.warmup, 64);
        assert!(!tp.shape);
        assert_eq!(tp.shaping.cores, 8);
        assert_eq!(tp.seed, 7);
        assert!(trace_params(&ScenarioSpec::new("scale"), 1).is_err());
    }

    #[test]
    fn gtrace_params_resolve_through_the_schema() {
        let gp = gtrace_params(&ScenarioSpec::new("gtrace")).unwrap();
        assert_eq!((gp.users, gp.heavy_users, gp.cores), (25, 5, 32));
        let gp = gtrace_params(
            &ScenarioSpec::new("gtrace").with("users", "8").with("heavy_users", "2"),
        )
        .unwrap();
        assert_eq!((gp.users, gp.heavy_users), (8, 2));
        assert!(gtrace_params(&ScenarioSpec::new("gtrace").with("users", "1")).is_err());
        assert!(gtrace_params(&ScenarioSpec::new("scale")).is_err());
    }

    #[test]
    fn skewed_params_resolve_through_the_schema() {
        let sp = skewed_params(&ScenarioSpec::new("skewed")).unwrap();
        assert_eq!((sp.users, sp.jobs, sp.hot_users), (400, 20_000, 16));
        assert_eq!(sp.zipf_s, 1.2);
        let sp = skewed_params(
            &ScenarioSpec::new("skewed").with("jobs", "500").with("hot_users", "4"),
        )
        .unwrap();
        assert_eq!((sp.jobs, sp.hot_users), (500, 4));
        assert!(skewed_params(&ScenarioSpec::new("scale")).is_err());
        // Range errors surface when the stream is built.
        let err = ScenarioSpec::new("skewed").with("hot_users", "0").build(1).unwrap_err();
        assert!(err.contains("hot_users"), "{err}");
    }

    #[test]
    fn collect_matches_direct_stream() {
        // The adapter adds nothing: collecting == materializing the
        // stream the same constructor returns.
        let spec = ScenarioSpec::new("heavytail")
            .with("users", "3")
            .with("jobs_per_user", "10");
        let collected = spec.workload(11).unwrap();
        let streamed = materialize(spec.build(11).unwrap().stream);
        assert_eq!(collected.jobs.len(), streamed.len());
        for (a, b) in collected.jobs.iter().zip(&streamed) {
            assert_eq!((a.user, a.arrival, &a.name), (b.user, b.arrival, &b.name));
        }
    }
}
