//! Synthetic trace writer — seeded, parameterized, native-format output.
//!
//! Emits the **raw unshaped** §5.3 tuples of the gtrace generator
//! ([`crate::workload::gtrace::raw_rows`]) as a native trace CSV, sorted
//! by arrival. Because the rows are written *before* shaping, the file
//! is a faithful stand-in for a real trace export: replaying it through
//! the one-pass streaming shaper and running the in-memory generator
//! (exact two-pass shaping) shape the *same* raw input — which is what
//! the differential test and the replay bench feed on.
//!
//! Floats are written with Rust's shortest round-trip formatting, so
//! parsing the file back reproduces every value bit-for-bit.

use std::io::{BufWriter, Write};

use crate::s_to_us;
use crate::workload::gtrace::{self, GtraceParams};
use crate::workload::UserClass;

use super::reader::NATIVE_COLUMNS;

/// Write the synthetic raw trace for `(seed, params)`; returns the row
/// count. Rows are sorted by `(arrival, generation index)` — the order
/// the replay stream (and the simulator's cursor) consumes.
pub fn write_synthetic(path: &str, seed: u64, p: &GtraceParams) -> Result<u64, String> {
    let (raw, _rng) = gtrace::raw_rows(seed, p);
    let mut rows: Vec<(usize, gtrace::RawTuple)> = raw.into_iter().enumerate().collect();
    rows.sort_by_key(|(i, r)| (s_to_us(r.arrival_s), *i));

    let f = std::fs::File::create(path).map_err(|e| format!("{path}: {e}"))?;
    let mut w = BufWriter::new(f);
    let io = |e: std::io::Error| format!("{path}: {e}");
    writeln!(w, "{NATIVE_COLUMNS}").map_err(io)?;
    for (i, r) in &rows {
        writeln!(
            w,
            "g{i},{},{},{},{},{}",
            r.user,
            r.arrival_s,
            r.slot_s,
            gtrace::stage_count(r.slot_s),
            u8::from(r.class == UserClass::Heavy),
        )
        .map_err(io)?;
    }
    w.flush().map_err(io)?;
    Ok(rows.len() as u64)
}

/// Gtrace params whose generators produce roughly `jobs` raw rows: the
/// per-user submission rates are fixed, so the window is solved from the
/// target count. Used by the 1M-row replay test and the bench.
pub fn params_for_jobs(jobs: u64, base: &GtraceParams) -> GtraceParams {
    let heavy = base.heavy_users as f64;
    let light = (base.users - base.heavy_users) as f64;
    // Raw generation rates (jobs/s), from the generator's own gap
    // constants so a tuning there cannot silently skew the solver.
    let rate = heavy / gtrace::HEAVY_GAP_S + light / gtrace::LIGHT_GAP_S;
    let mut p = base.clone();
    p.window_s = (jobs as f64 / rate.max(1e-9)).max(1.0);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traceio::reader::RowReader;
    use crate::TimeUs;

    fn temp(name: &str) -> String {
        let dir = std::env::temp_dir().join(format!("uwfq_writer_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    #[test]
    fn written_trace_parses_back_bit_exactly() {
        let p = GtraceParams {
            window_s: 60.0,
            users: 6,
            heavy_users: 2,
            cores: 8,
            ..GtraceParams::default()
        };
        let path = temp("roundtrip.csv");
        let n = write_synthetic(&path, 11, &p).unwrap();
        assert!(n > 10, "tiny trace: {n} rows");

        // Parse back and compare against the generator's raw tuples.
        let (raw, _) = gtrace::raw_rows(11, &p);
        assert_eq!(raw.len() as u64, n);
        let mut expect: Vec<(usize, gtrace::RawTuple)> =
            raw.into_iter().enumerate().collect();
        expect.sort_by_key(|(i, r)| (s_to_us(r.arrival_s), *i));

        let mut rd = RowReader::open(&path, None).unwrap();
        let mut count = 0usize;
        let mut last: TimeUs = 0;
        while let Some(row) = rd.next_row().unwrap() {
            let (gen_idx, exp) = &expect[count];
            assert_eq!(row.name, format!("g{gen_idx}"));
            assert_eq!(row.user, exp.user);
            // Shortest round-trip formatting: bit-exact floats.
            assert_eq!(row.arrival_s.to_bits(), exp.arrival_s.to_bits());
            assert_eq!(row.slot_s.to_bits(), exp.slot_s.to_bits());
            assert_eq!(row.heavy, exp.class == UserClass::Heavy);
            assert_eq!(row.stages, gtrace::stage_count(exp.slot_s));
            assert!(s_to_us(row.arrival_s) >= last);
            last = s_to_us(row.arrival_s);
            count += 1;
        }
        assert_eq!(count as u64, n);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn writer_is_seed_sensitive() {
        let p = GtraceParams {
            window_s: 40.0,
            users: 4,
            heavy_users: 1,
            ..GtraceParams::default()
        };
        let (a, b) = (temp("seed_a.csv"), temp("seed_b.csv"));
        write_synthetic(&a, 1, &p).unwrap();
        write_synthetic(&b, 2, &p).unwrap();
        assert_ne!(
            std::fs::read_to_string(&a).unwrap(),
            std::fs::read_to_string(&b).unwrap()
        );
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn params_for_jobs_hits_target_roughly() {
        let p = params_for_jobs(5_000, &GtraceParams::default());
        let (raw, _) = gtrace::raw_rows(3, &p);
        let n = raw.len() as f64;
        assert!(
            (n - 5_000.0).abs() / 5_000.0 < 0.15,
            "generated {n} rows for a 5k target"
        );
    }
}
