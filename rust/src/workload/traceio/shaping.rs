//! One-pass §5.3 shaping over a sorted raw-row stream.
//!
//! The paper's macro pipeline (filter jobs above 10× the median runtime,
//! rebalance heavy users to >90 % of the work, rescale to the target
//! utilization) is inherently two-pass: the median and the work totals
//! are global statistics. The in-memory synthetic generator keeps that
//! exact pipeline ([`crate::workload::gtrace::shape_exact`], the
//! differential oracle). Real trace files are shaped here in **one
//! pass** with streaming statistics instead:
//!
//! * the runtime-tail **filter** tests each row against the *running* P²
//!   median estimate ([`crate::metrics::streaming::P2Quantile`], O(1)
//!   state) rather than the global median;
//! * the **rebalance** and **rescale** factors are frozen from a bounded
//!   warmup window — the first `warmup` rows are buffered, per-class
//!   work accumulators and the window's time span yield the heavy-user
//!   scale and the utilization scale, then the buffer is flushed and
//!   every later row is shaped in O(1).
//!
//! Resident state is O(warmup) during the window and O(1) after — never
//! O(trace length). Accuracy versus the exact two-pass oracle is bounded
//! by the differential test (`tests/trace_replay.rs`): job count within
//! 2 %, response-time quantiles within the documented P² tolerances
//! ([`crate::bench::scale::P2_QUANTILE_RTOL`] /
//! [`crate::bench::scale::P2_P99_RTOL`]).

use std::collections::VecDeque;

use super::reader::RawRow;
use crate::metrics::streaming::P2Quantile;

/// Shaping knobs (defaults mirror the gtrace §5.3 parameters).
#[derive(Clone, Debug)]
pub struct ShapeParams {
    /// Rows buffered before the rebalance/rescale factors freeze.
    pub warmup: usize,
    /// Runtime filter threshold (× running P² median).
    pub filter_median_mult: f64,
    /// Target fraction of total work from heavy users.
    pub heavy_work_fraction: f64,
    /// Target theoretical utilization: work / (cores × span).
    pub target_utilization: f64,
    /// Cluster size the shaping targets.
    pub cores: u32,
}

impl Default for ShapeParams {
    fn default() -> Self {
        ShapeParams {
            warmup: 4096,
            filter_median_mult: 10.0,
            heavy_work_fraction: 0.92,
            target_utilization: 1.05,
            cores: 32,
        }
    }
}

/// One shaped row, ready for job materialization. The trace's `stages`
/// column is deliberately absent: shaping rescales the job size, and the
/// §5.3 builder re-synthesizes the stage chain from the *shaped* size
/// (only the raw replay path honors the column).
#[derive(Clone, Debug)]
pub struct ShapedRow {
    pub index: u64,
    pub name: String,
    pub user: u32,
    pub arrival_s: f64,
    /// Shaped total sequential work (core-seconds).
    pub slot_s: f64,
    pub heavy: bool,
    /// Per-task CPU demand fraction, passed through from the raw row —
    /// shaping rescales work, never the demand vector.
    pub cpu_demand: f64,
}

/// Counters exposed for observability and the bounded-state assertions.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShapeStats {
    pub rows_in: u64,
    /// Rows dropped by the runtime-tail filter.
    pub rows_dropped: u64,
    /// Peak warmup-buffer occupancy (≤ warmup by construction).
    pub max_buffered: usize,
    /// Heavy-user rebalance factor (1.0 until frozen).
    pub heavy_scale: f64,
    /// Utilization rescale factor (1.0 until frozen).
    pub util_scale: f64,
}

/// Frozen rebalance/rescale factors.
#[derive(Clone, Copy, Debug)]
struct Factors {
    heavy_scale: f64,
    util_scale: f64,
}

/// The one-pass shaper: push raw rows (sorted by arrival), pop shaped
/// rows. `finish()` must be called at end of input so a shorter-than-
/// warmup trace still flushes (degenerating to a near-exact shaping of
/// the whole file).
pub struct OnePassShaper {
    p: ShapeParams,
    median: P2Quantile,
    buf: VecDeque<RawRow>,
    out: VecDeque<ShapedRow>,
    factors: Option<Factors>,
    stats: ShapeStats,
}

impl OnePassShaper {
    pub fn new(p: ShapeParams) -> OnePassShaper {
        assert!(p.warmup > 0, "warmup must be >= 1");
        OnePassShaper {
            p,
            median: P2Quantile::median(),
            buf: VecDeque::new(),
            out: VecDeque::new(),
            factors: None,
            stats: ShapeStats {
                heavy_scale: 1.0,
                util_scale: 1.0,
                ..ShapeStats::default()
            },
        }
    }

    pub fn stats(&self) -> ShapeStats {
        self.stats
    }

    /// Observe one raw row. Rows must arrive sorted (the reader enforces
    /// it); shaped output preserves that order.
    pub fn push(&mut self, row: RawRow) {
        self.stats.rows_in += 1;
        self.median.observe(row.slot_s);
        if self.factors.is_some() {
            self.emit(row);
            return;
        }
        self.buf.push_back(row);
        self.stats.max_buffered = self.stats.max_buffered.max(self.buf.len());
        if self.buf.len() >= self.p.warmup {
            self.freeze();
        }
    }

    /// Signal end of input: freezes factors from whatever was buffered.
    pub fn finish(&mut self) {
        if self.factors.is_none() {
            self.freeze();
        }
    }

    /// Shaped rows ready so far, in arrival order.
    pub fn pop(&mut self) -> Option<ShapedRow> {
        self.out.pop_front()
    }

    /// Compute the rebalance/rescale factors from the warmup window and
    /// flush the buffer through the filter.
    fn freeze(&mut self) {
        let med = self.median.value();
        let threshold = self.p.filter_median_mult * med;
        let mut heavy_work = 0.0f64;
        let mut light_work = 0.0f64;
        for r in &self.buf {
            if med > 0.0 && r.slot_s > threshold {
                continue; // filtered rows don't count toward the factors
            }
            if r.heavy {
                heavy_work += r.slot_s;
            } else {
                light_work += r.slot_s;
            }
        }
        // Rebalance so heavy users produce `heavy_work_fraction` of the
        // work — the exact pipeline's formula over the window's sums.
        let f = self.p.heavy_work_fraction;
        let heavy_scale = if heavy_work > 0.0 && light_work > 0.0 {
            f / (1.0 - f) * light_work / heavy_work
        } else {
            1.0
        };
        // Rescale the offered-load *rate* (work per second of trace time)
        // to the utilization target; the window span estimates the rate.
        let span = match (self.buf.front(), self.buf.back()) {
            (Some(a), Some(b)) => b.arrival_s - a.arrival_s,
            _ => 0.0,
        };
        let rate = if span > 0.0 {
            (heavy_work * heavy_scale + light_work) / span
        } else {
            0.0
        };
        let util_scale = if rate > 0.0 {
            self.p.target_utilization * self.p.cores as f64 / rate
        } else {
            1.0
        };
        self.factors = Some(Factors {
            heavy_scale,
            util_scale,
        });
        self.stats.heavy_scale = heavy_scale;
        self.stats.util_scale = util_scale;
        while let Some(row) = self.buf.pop_front() {
            self.emit(row);
        }
    }

    fn emit(&mut self, row: RawRow) {
        let med = self.median.value();
        if med > 0.0 && row.slot_s > self.p.filter_median_mult * med {
            self.stats.rows_dropped += 1;
            return;
        }
        let fx = self.factors.expect("emit before freeze");
        let class_scale = if row.heavy { fx.heavy_scale } else { 1.0 };
        self.out.push_back(ShapedRow {
            index: row.index,
            slot_s: row.slot_s * class_scale * fx.util_scale,
            name: row.name,
            user: row.user,
            arrival_s: row.arrival_s,
            heavy: row.heavy,
            cpu_demand: row.cpu_demand,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(index: u64, user: u32, arrival_s: f64, slot_s: f64, heavy: bool) -> RawRow {
        RawRow {
            index,
            line: index + 2,
            name: format!("g{index}"),
            user,
            arrival_s,
            slot_s,
            stages: 1,
            heavy,
            cpu_demand: 1.0,
        }
    }

    fn drain(s: &mut OnePassShaper) -> Vec<ShapedRow> {
        let mut out = Vec::new();
        while let Some(r) = s.pop() {
            out.push(r);
        }
        out
    }

    #[test]
    fn warmup_buffers_then_flushes_in_order() {
        let mut s = OnePassShaper::new(ShapeParams {
            warmup: 4,
            ..ShapeParams::default()
        });
        for i in 0..3u64 {
            s.push(row(i, 1, i as f64, 10.0, i == 0));
            assert!(s.pop().is_none(), "nothing may emit during warmup");
        }
        s.push(row(3, 2, 3.0, 10.0, false));
        let out = drain(&mut s);
        assert_eq!(out.len(), 4);
        assert!(out.windows(2).all(|w| w[0].index < w[1].index));
        assert_eq!(s.stats().max_buffered, 4);
        // Post-freeze rows stream through in O(1).
        s.push(row(4, 1, 4.0, 10.0, true));
        assert_eq!(drain(&mut s).len(), 1);
    }

    #[test]
    fn short_trace_finish_flushes_everything() {
        let mut s = OnePassShaper::new(ShapeParams {
            warmup: 1000,
            ..ShapeParams::default()
        });
        for i in 0..5u64 {
            s.push(row(i, 1 + (i % 2) as u32, i as f64, 4.0 + i as f64, i % 2 == 0));
        }
        assert!(s.pop().is_none());
        s.finish();
        assert_eq!(drain(&mut s).len(), 5);
    }

    #[test]
    fn filter_drops_running_median_tail() {
        let mut s = OnePassShaper::new(ShapeParams {
            warmup: 8,
            filter_median_mult: 10.0,
            ..ShapeParams::default()
        });
        // Median ≈ 10; a 500-core-s elephant is > 10× the median.
        for i in 0..8u64 {
            s.push(row(i, 1, i as f64, 10.0, false));
        }
        s.push(row(8, 1, 8.0, 500.0, false));
        s.push(row(9, 1, 9.0, 12.0, false));
        s.finish();
        let out = drain(&mut s);
        assert_eq!(s.stats().rows_dropped, 1);
        assert!(out.iter().all(|r| r.index != 8));
        assert_eq!(out.len(), 9);
    }

    #[test]
    fn factors_reproduce_exact_formulas_on_the_window() {
        // Warmup covers the whole input: the frozen factors must equal
        // the exact pipeline's formulas computed over all rows.
        let rows = [
            row(0, 1, 0.0, 30.0, true),
            row(1, 2, 2.0, 6.0, false),
            row(2, 1, 5.0, 20.0, true),
            row(3, 3, 8.0, 4.0, false),
        ];
        let p = ShapeParams {
            warmup: 100,
            filter_median_mult: 10.0,
            heavy_work_fraction: 0.9,
            target_utilization: 0.8,
            cores: 16,
        };
        let mut s = OnePassShaper::new(p);
        for r in rows {
            s.push(r);
        }
        s.finish();
        let st = s.stats();
        let (heavy, light, span) = (50.0, 10.0, 8.0);
        let heavy_scale = 0.9 / 0.1 * light / heavy;
        let rate = (heavy * heavy_scale + light) / span;
        let util_scale = 0.8 * 16.0 / rate;
        assert!((st.heavy_scale - heavy_scale).abs() < 1e-12, "{st:?}");
        assert!((st.util_scale - util_scale).abs() < 1e-12, "{st:?}");
        let out = drain(&mut s);
        assert!((out[0].slot_s - 30.0 * heavy_scale * util_scale).abs() < 1e-12);
        assert!((out[1].slot_s - 6.0 * util_scale).abs() < 1e-12);
    }

    #[test]
    fn degenerate_windows_fall_back_to_unit_scales() {
        // Same-instant window (span 0) and single-class windows must not
        // divide by zero — scales fall back to 1.
        let mut s = OnePassShaper::new(ShapeParams {
            warmup: 2,
            ..ShapeParams::default()
        });
        s.push(row(0, 1, 1.0, 5.0, true));
        s.push(row(1, 2, 1.0, 7.0, true));
        s.finish();
        let st = s.stats();
        assert_eq!(st.heavy_scale, 1.0);
        assert_eq!(st.util_scale, 1.0);
        assert_eq!(drain(&mut s).len(), 2);
    }
}
