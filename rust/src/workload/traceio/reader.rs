//! Chunked trace-file reading: fixed-size block reads, line-at-a-time
//! parsing, two column mappings.
//!
//! [`ChunkedLines`] reads the underlying source in fixed-size chunks and
//! yields one line at a time from a reused buffer, so resident reader
//! state is O(chunk + longest line) regardless of file length — a 40 MB
//! million-row trace is never loaded whole.
//!
//! [`RowReader`] layers the column mappings on top:
//!
//! * `native` — the repo's tracefile CSV
//!   (`job,user,arrival_s,slot_s,stages,heavy`).
//! * `gcluster` — a pragmatic Google-cluster-trace mapping
//!   (`timestamp,job_id,user,scheduling_class,runtime_s,cpu_request`):
//!   `slot_s = runtime_s × cpu_request` core-seconds, `heavy` =
//!   scheduling class ≥ 2 (the trace's "production" tiers), stage chain
//!   derived from the job size (§5.3 shape).
//!
//! Every parse error names the offending line and lists the format's
//! valid columns; rows must be sorted by arrival (checked, named line on
//! regression).

use std::fs::File;
use std::io::Read;

use crate::{s_to_us, TimeUs};

/// Default read-chunk size (bytes).
pub const DEFAULT_CHUNK: usize = 64 * 1024;

/// The native tracefile column set.
pub const NATIVE_COLUMNS: &str = "job,user,arrival_s,slot_s,stages,heavy";
/// The Google-cluster-trace column mapping.
pub const GCLUSTER_COLUMNS: &str =
    "timestamp,job_id,user,scheduling_class,runtime_s,cpu_request";

/// A trace column mapping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    Native,
    GCluster,
}

impl TraceFormat {
    /// Parse a format override: empty (or "auto") means detect from the
    /// header.
    pub fn parse(s: &str) -> Result<Option<TraceFormat>, String> {
        match s {
            "" | "auto" => Ok(None),
            "native" => Ok(Some(TraceFormat::Native)),
            "gcluster" => Ok(Some(TraceFormat::GCluster)),
            other => Err(format!(
                "unknown trace format '{other}' (valid: auto, native, gcluster)"
            )),
        }
    }

    /// The format's column list (error messages, docs).
    pub fn columns(&self) -> &'static str {
        match self {
            TraceFormat::Native => NATIVE_COLUMNS,
            TraceFormat::GCluster => GCLUSTER_COLUMNS,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TraceFormat::Native => "native",
            TraceFormat::GCluster => "gcluster",
        }
    }

    /// Detect the format from a header line.
    fn detect(header: &str) -> Result<TraceFormat, String> {
        let norm: Vec<&str> = header.split(',').map(|c| c.trim()).collect();
        for fmt in [TraceFormat::Native, TraceFormat::GCluster] {
            let cols: Vec<&str> = fmt.columns().split(',').collect();
            if norm == cols {
                return Ok(fmt);
            }
        }
        Err(format!(
            "unrecognized trace header '{header}' (expected '{NATIVE_COLUMNS}' \
             or '{GCLUSTER_COLUMNS}')"
        ))
    }
}

/// One parsed raw trace row, prior to any shaping.
#[derive(Clone, Debug, PartialEq)]
pub struct RawRow {
    /// 0-based data-row ordinal (per-row RNG forks).
    pub index: u64,
    /// 1-based file line (error reporting).
    pub line: u64,
    /// Job name (`job` column; the `job_id` token under `gcluster`).
    pub name: String,
    pub user: u32,
    pub arrival_s: f64,
    /// Total sequential work (core-seconds), unshaped.
    pub slot_s: f64,
    /// Stage-chain length from the trace (0 = derive from the job size).
    pub stages: usize,
    pub heavy: bool,
    /// Per-task CPU demand as a fraction of one core-slot, in (0, 1].
    /// Native rows are whole-slot (1.0); `gcluster` maps `cpu_request`
    /// here, clamped to a slot (requests above one core keep `slot_s =
    /// runtime_s × cpu_request` but can't demand more than the slot).
    pub cpu_demand: f64,
}

// ---------------------------------------------------------------------------
// Chunked line reader
// ---------------------------------------------------------------------------

/// Line iterator over a byte source read in fixed-size chunks. The line
/// buffer is reused across calls (no per-line allocation); resident state
/// is the chunk plus the longest line seen.
pub struct ChunkedLines<R: Read> {
    src: R,
    chunk: usize,
    /// Unconsumed bytes: `buf[start..]` is pending input.
    buf: Vec<u8>,
    start: usize,
    /// Scan cursor: `buf[start..searched)` is known newline-free, so a
    /// line spanning many chunks is searched in O(line) total rather
    /// than rescanned from `start` after every fill.
    searched: usize,
    eof: bool,
    /// Last returned line number (1-based after the first call).
    line_no: u64,
    line: String,
}

impl<R: Read> ChunkedLines<R> {
    pub fn new(src: R, chunk: usize) -> ChunkedLines<R> {
        assert!(chunk > 0);
        ChunkedLines {
            src,
            chunk,
            buf: Vec::with_capacity(chunk),
            start: 0,
            searched: 0,
            eof: false,
            line_no: 0,
            line: String::new(),
        }
    }

    /// Read the next chunk from the source into the pending buffer.
    fn fill(&mut self) -> std::io::Result<()> {
        // Compact the consumed prefix before growing.
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.searched -= self.start;
            self.start = 0;
        }
        let old = self.buf.len();
        self.buf.resize(old + self.chunk, 0);
        let n = self.src.read(&mut self.buf[old..])?;
        self.buf.truncate(old + n);
        if n == 0 {
            self.eof = true;
        }
        Ok(())
    }

    /// Next line (trailing `\n`/`\r` stripped) with its 1-based number;
    /// `None` at end of input. The returned borrow ends at the next call.
    pub fn next_line(&mut self) -> std::io::Result<Option<(u64, &str)>> {
        let nl = loop {
            debug_assert!(self.start <= self.searched && self.searched <= self.buf.len());
            if let Some(pos) = self.buf[self.searched..].iter().position(|&b| b == b'\n') {
                break Some(self.searched + pos);
            }
            self.searched = self.buf.len();
            if self.eof {
                break None;
            }
            self.fill()?;
        };
        let (lo, hi, consumed) = match nl {
            Some(pos) => (self.start, pos, pos + 1),
            None if self.start < self.buf.len() => {
                (self.start, self.buf.len(), self.buf.len())
            }
            None => return Ok(None),
        };
        let mut bytes = &self.buf[lo..hi];
        if bytes.last() == Some(&b'\r') {
            bytes = &bytes[..bytes.len() - 1];
        }
        // Hard UTF-8 rejection, matching the in-memory loader's
        // `read_to_string` behavior — corrupted input must surface, not
        // be replayed with replacement characters.
        let text = std::str::from_utf8(bytes).map_err(|_| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {}: invalid UTF-8", self.line_no + 1),
            )
        })?;
        self.line.clear();
        self.line.push_str(text);
        self.start = consumed;
        self.searched = consumed;
        self.line_no += 1;
        Ok(Some((self.line_no, &self.line)))
    }
}

// ---------------------------------------------------------------------------
// Row reader
// ---------------------------------------------------------------------------

/// Trace rows from a chunked line source: header detection, per-format
/// field parsing, arrival-order enforcement. Errors name the offending
/// line and list the format's valid columns.
pub struct RowReader<R: Read> {
    lines: ChunkedLines<R>,
    pub format: TraceFormat,
    /// Label used in error messages (normally the file path).
    label: String,
    index: u64,
    last_arrival: TimeUs,
}

impl RowReader<File> {
    /// Open a trace file; `forced` pins the format, `None` detects it
    /// from the header.
    pub fn open(path: &str, forced: Option<TraceFormat>) -> Result<RowReader<File>, String> {
        let f = File::open(path).map_err(|e| format!("{path}: {e}"))?;
        RowReader::new(f, path, forced, DEFAULT_CHUNK)
    }
}

impl<R: Read> RowReader<R> {
    pub fn new(
        src: R,
        label: &str,
        forced: Option<TraceFormat>,
        chunk: usize,
    ) -> Result<RowReader<R>, String> {
        let mut lines = ChunkedLines::new(src, chunk);
        let header = match lines.next_line().map_err(|e| format!("{label}: {e}"))? {
            Some((_, h)) => h.to_string(),
            None => return Err(format!("{label}: empty trace (missing header)")),
        };
        let detected = TraceFormat::detect(&header).map_err(|e| format!("{label}: {e}"))?;
        let format = match forced {
            Some(f) if f != detected => {
                // A forced format must still see its own header — silently
                // consuming a headerless file's first data row as "the
                // header" would lose a job.
                return Err(format!(
                    "{label}: forced format '{}' but the header is '{}' (columns: {})",
                    f.name(),
                    detected.name(),
                    f.columns()
                ));
            }
            Some(f) => f,
            None => detected,
        };
        Ok(RowReader {
            lines,
            format,
            label: label.to_string(),
            index: 0,
            last_arrival: 0,
        })
    }

    fn err(&self, line: u64, what: &str) -> String {
        format!(
            "{} line {line}: {what} (columns: {})",
            self.label,
            self.format.columns()
        )
    }

    /// Next data row; blank lines and `#` comments are skipped. `None` at
    /// end of file. Parsing works off the reader's reused line buffer —
    /// the only per-row allocation is the owned job name.
    pub fn next_row(&mut self) -> Result<Option<RawRow>, String> {
        loop {
            let (format, index) = (self.format, self.index);
            let row = {
                let label = &self.label;
                let (line_no, line) = match self
                    .lines
                    .next_line()
                    .map_err(|e| format!("{label}: {e}"))?
                {
                    Some(l) => l,
                    None => return Ok(None),
                };
                let text = line.trim();
                if text.is_empty() || text.starts_with('#') {
                    continue;
                }
                // Fixed-size field buffer: both formats have ≤ MAX_FIELDS
                // columns, so splitting allocates nothing.
                let mut fields = [""; MAX_FIELDS];
                let mut got = 0usize;
                for tok in text.split(',') {
                    if got < MAX_FIELDS {
                        fields[got] = tok.trim();
                    }
                    got += 1;
                }
                parse_fields(format, label, index, line_no, &fields[..got.min(MAX_FIELDS)], got)?
            };
            if row.arrival_s < 0.0 || !row.arrival_s.is_finite() {
                return Err(self.err(row.line, "negative or non-finite arrival"));
            }
            if row.slot_s <= 0.0 || !row.slot_s.is_finite() {
                return Err(self.err(row.line, "job size must be a positive finite number"));
            }
            let arrival_us = s_to_us(row.arrival_s);
            if arrival_us < self.last_arrival {
                return Err(self.err(
                    row.line,
                    "arrivals regressed — the trace must be sorted by arrival time",
                ));
            }
            self.last_arrival = arrival_us;
            self.index += 1;
            return Ok(Some(row));
        }
    }
}

/// Upper bound on columns across the supported formats (both currently
/// have 6) — sizes the allocation-free field buffer.
const MAX_FIELDS: usize = 8;

/// Parse one split data line (`got` = the true field count, which may
/// exceed `f.len()` when the line had more than [`MAX_FIELDS`] commas).
/// A free function (not a method) so it can run while the reused line
/// buffer is still borrowed from the reader.
fn parse_fields(
    format: TraceFormat,
    label: &str,
    index: u64,
    line_no: u64,
    f: &[&str],
    got: usize,
) -> Result<RawRow, String> {
    let err = |what: String| -> String {
        format!("{label} line {line_no}: {what} (columns: {})", format.columns())
    };
    let want = format.columns().split(',').count();
    if got != want {
        return Err(err(format!("expected {want} fields, got {got}")));
    }
    let num = |col: &str, tok: &str| -> Result<f64, String> {
        tok.parse::<f64>().map_err(|_| err(format!("bad {col} '{tok}'")))
    };
    let int = |col: &str, tok: &str| -> Result<u64, String> {
        tok.parse::<u64>().map_err(|_| err(format!("bad {col} '{tok}'")))
    };
    match format {
        TraceFormat::Native => {
            let user = int("user", f[1])?;
            let user = u32::try_from(user)
                .map_err(|_| err(format!("user {user} out of range")))?;
            let arrival_s = num("arrival_s", f[2])?;
            let slot_s = num("slot_s", f[3])?;
            let stages = int("stages", f[4])? as usize;
            if !(1..=8).contains(&stages) {
                return Err(err("stages out of range (1..=8)".into()));
            }
            let heavy = match f[5] {
                "1" => true,
                "0" => false,
                tok => return Err(err(format!("bad heavy '{tok}'"))),
            };
            Ok(RawRow {
                index,
                line: line_no,
                name: f[0].to_string(),
                user,
                arrival_s,
                slot_s,
                stages,
                heavy,
                cpu_demand: 1.0,
            })
        }
        TraceFormat::GCluster => {
            let arrival_s = num("timestamp", f[0])?;
            let user = int("user", f[2])?;
            let user = u32::try_from(user)
                .map_err(|_| err(format!("user {user} out of range")))?;
            let sclass = int("scheduling_class", f[3])?;
            let runtime_s = num("runtime_s", f[4])?;
            if runtime_s <= 0.0 || !runtime_s.is_finite() {
                return Err(err("runtime_s must be a positive finite number".into()));
            }
            let cpus = num("cpu_request", f[5])?;
            if cpus <= 0.0 || !cpus.is_finite() {
                return Err(err("cpu_request must be positive".into()));
            }
            Ok(RawRow {
                index,
                line: line_no,
                name: f[1].to_string(),
                user,
                arrival_s,
                slot_s: runtime_s * cpus,
                stages: 0, // the shaped replay derives the chain
                heavy: sclass >= 2,
                cpu_demand: cpus.min(1.0),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows_of(text: &str, forced: Option<TraceFormat>) -> Result<Vec<RawRow>, String> {
        let mut r = RowReader::new(text.as_bytes(), "mem", forced, 16)?;
        let mut out = Vec::new();
        while let Some(row) = r.next_row()? {
            out.push(row);
        }
        Ok(out)
    }

    #[test]
    fn chunked_lines_reassemble_across_chunk_boundaries() {
        // Tiny 4-byte chunks force every line to span chunk boundaries.
        let text = "alpha\nbeta\n\ngamma delta epsilon\nlast";
        let mut cl = ChunkedLines::new(text.as_bytes(), 4);
        let mut got = Vec::new();
        while let Some((n, l)) = cl.next_line().unwrap() {
            got.push((n, l.to_string()));
        }
        assert_eq!(
            got,
            vec![
                (1, "alpha".to_string()),
                (2, "beta".to_string()),
                (3, String::new()),
                (4, "gamma delta epsilon".to_string()),
                (5, "last".to_string()),
            ]
        );
    }

    #[test]
    fn chunked_lines_strip_crlf() {
        let mut cl = ChunkedLines::new("a\r\nb\r\n".as_bytes(), 3);
        assert_eq!(cl.next_line().unwrap(), Some((1, "a")));
        assert_eq!(cl.next_line().unwrap(), Some((2, "b")));
        assert_eq!(cl.next_line().unwrap(), None);
    }

    #[test]
    fn chunked_lines_reject_invalid_utf8_naming_the_line() {
        // Matches the in-memory loader's read_to_string behavior:
        // corrupted bytes error instead of becoming U+FFFD job names.
        let bytes: &[u8] = b"ok line\nbad \xFF byte\n";
        let mut cl = ChunkedLines::new(bytes, 4);
        assert_eq!(cl.next_line().unwrap(), Some((1, "ok line")));
        let err = cl.next_line().unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        assert!(err.to_string().contains("UTF-8"), "{err}");
    }

    const NATIVE: &str = "\
job,user,arrival_s,slot_s,stages,heavy
g0,1,0.0,100.0,2,1
# comment
g1,2,5.5,10.0,1,0
";

    #[test]
    fn native_rows_parse_with_detection() {
        let rows = rows_of(NATIVE, None).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].user, 1);
        assert!(rows[0].heavy);
        assert_eq!(rows[0].stages, 2);
        assert_eq!(rows[1].index, 1);
        assert_eq!(rows[1].line, 4); // comment counted in line numbers
        assert!(!rows[1].heavy);
    }

    #[test]
    fn gcluster_rows_map_columns() {
        let text = "\
timestamp,job_id,user,scheduling_class,runtime_s,cpu_request
0.5,900,7,3,20.0,2.0
3.25,901,8,0,4.0,0.5
";
        let rows = rows_of(text, None).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].heavy); // class 3 => production tier
        assert_eq!(rows[0].slot_s, 40.0); // 20 s × 2 cores
        assert_eq!(rows[0].stages, 0); // derived later
        assert_eq!(rows[0].cpu_demand, 1.0); // 2-core request clamps to a slot
        assert!(!rows[1].heavy);
        assert_eq!(rows[1].slot_s, 2.0);
        assert_eq!(rows[1].cpu_demand, 0.5); // sub-core request = real demand
    }

    #[test]
    fn native_rows_have_unit_demand() {
        let rows = rows_of(NATIVE, None).unwrap();
        assert!(rows.iter().all(|r| r.cpu_demand == 1.0));
    }

    #[test]
    fn gcluster_rejects_nonpositive_runtime() {
        for (row, what) in [
            ("0.5,900,7,3,0.0,2.0", "zero runtime"),
            ("0.5,900,7,3,-4.0,2.0", "negative runtime"),
            ("0.5,900,7,3,inf,2.0", "non-finite runtime"),
            ("0.5,900,7,3,nan,2.0", "NaN runtime"),
        ] {
            let text = format!("{GCLUSTER_COLUMNS}\n{row}\n");
            let err = rows_of(&text, None).unwrap_err();
            assert!(err.contains("line 2"), "{what}: {err}");
            assert!(err.contains("runtime_s must be a positive finite number"), "{what}: {err}");
            assert!(err.contains(GCLUSTER_COLUMNS), "{what}: {err}");
        }
    }

    #[test]
    fn errors_name_line_and_list_columns() {
        let bad_slot = "\
job,user,arrival_s,slot_s,stages,heavy
g0,1,0.0,xyz,2,1
";
        let err = rows_of(bad_slot, None).unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("bad slot_s 'xyz'"), "{err}");
        assert!(err.contains(NATIVE_COLUMNS), "{err}");

        let bad_fields = "\
job,user,arrival_s,slot_s,stages,heavy
g0,1,0.0
";
        let err = rows_of(bad_fields, None).unwrap_err();
        assert!(err.contains("line 2") && err.contains("expected 6 fields"), "{err}");

        let unsorted = "\
job,user,arrival_s,slot_s,stages,heavy
g0,1,5.0,1.0,1,0
g1,1,4.0,1.0,1,0
";
        let err = rows_of(unsorted, None).unwrap_err();
        assert!(err.contains("line 3") && err.contains("sorted"), "{err}");

        let err = rows_of("nope,header\n", None).unwrap_err();
        assert!(err.contains(NATIVE_COLUMNS) && err.contains(GCLUSTER_COLUMNS), "{err}");

        let err = rows_of("", None).unwrap_err();
        assert!(err.contains("empty trace"), "{err}");
    }

    #[test]
    fn validation_rejects_bad_values() {
        for row in [
            "g0,1,-1.0,5.0,1,0",  // negative arrival
            "g0,1,0.0,0.0,1,0",   // zero slot
            "g0,1,0.0,5.0,9,0",   // stages out of range
            "g0,1,0.0,5.0,1,yes", // bad heavy
        ] {
            let text = format!("{NATIVE_COLUMNS}\n{row}\n");
            assert!(rows_of(&text, None).is_err(), "{row}");
        }
    }

    #[test]
    fn format_parse_and_forcing() {
        assert_eq!(TraceFormat::parse("").unwrap(), None);
        assert_eq!(TraceFormat::parse("auto").unwrap(), None);
        assert_eq!(TraceFormat::parse("native").unwrap(), Some(TraceFormat::Native));
        assert_eq!(TraceFormat::parse("gcluster").unwrap(), Some(TraceFormat::GCluster));
        assert!(TraceFormat::parse("csv").unwrap_err().contains("gcluster"));
        // Forcing a format asserts it against the header.
        let rows = rows_of(NATIVE, Some(TraceFormat::Native)).unwrap();
        assert_eq!(rows.len(), 2);
        // A mismatched (or missing) header under a forced format is a
        // loud error, never a silently-consumed first data row.
        let err = rows_of(NATIVE, Some(TraceFormat::GCluster)).unwrap_err();
        assert!(err.contains("forced format 'gcluster'"), "{err}");
        let headerless = "g0,1,0.0,5.0,1,0\n";
        let err = rows_of(headerless, Some(TraceFormat::Native)).unwrap_err();
        assert!(err.contains("unrecognized trace header"), "{err}");
    }
}
