//! Streaming trace ingestion — replay real trace files row-by-row with
//! bounded memory (registry entry `trace`, CLI `uwfq replay`).
//!
//! The pipeline, end to end:
//!
//! 1. [`reader`] — a chunked line reader over the trace file (fixed-size
//!    block reads, reused line buffer) with two column mappings: the
//!    native tracefile CSV and a Google-cluster-trace mapping. Rows must
//!    be sorted by arrival; every parse error names the offending line
//!    and lists the valid columns.
//! 2. [`shaping`] — the **one-pass** §5.3 shaping stage: the runtime
//!    tail is filtered against a running P² median
//!    ([`crate::metrics::streaming::P2Quantile`]) and the heavy-user
//!    rebalance / utilization rescale factors are frozen from a bounded
//!    warmup window. The in-memory `gtrace` generator keeps the exact
//!    two-pass pipeline as the differential oracle
//!    (`tests/trace_replay.rs`).
//! 3. [`TraceStream`] — the [`JobStream`] over the shaped rows: resident
//!    state is O(warmup + in-flight), independent of trace length. With
//!    `shape = false` rows are replayed verbatim through the
//!    deterministic tracefile job builder (byte-identical to the
//!    in-memory [`crate::workload::tracefile`] loader — the golden
//!    cross-parser contract).
//! 4. [`writer`] — a seeded synthetic trace writer emitting the raw
//!    (unshaped) gtrace tuples, used by benches and test fixtures.
//!
//! Because [`JobStream::next_job`] cannot return errors, the registry
//! entry validates the whole file up front via [`scan_user_classes`]
//! (one streaming pass, O(users) state) — it both collects the per-user
//! classification the `ScenarioInstance` needs before any job yields and
//! surfaces every malformed-row error as a clean `Result`. A file that
//! changes between the scan and the replay panics with the parse error
//! (TOCTOU, not a user error).

pub mod reader;
pub mod shaping;
pub mod writer;

use std::collections::HashMap;
use std::fs::File;

use crate::core::job::JobSpec;
use crate::util::Rng;
use crate::workload::stream::JobStream;
use crate::workload::{gtrace, tracefile, UserClass};
use crate::UserId;

pub use reader::{ChunkedLines, RawRow, RowReader, TraceFormat};
pub use shaping::{OnePassShaper, ShapeParams, ShapeStats};

/// Everything the `trace` registry entry resolves from its schema.
#[derive(Clone, Debug)]
pub struct TraceParams {
    pub path: String,
    /// `None` = detect from the header.
    pub format: Option<TraceFormat>,
    /// Apply the one-pass §5.3 shaping (false = verbatim replay).
    pub shape: bool,
    pub shaping: ShapeParams,
    /// Fraction of shaped stages given a skewed cost profile.
    pub skew_fraction: f64,
    /// Per-task memory demand fraction in (0, 1] applied to every
    /// replayed job (the trace carries no memory column; 1.0 = the
    /// legacy unit vector).
    pub mem_frac: f64,
    pub seed: u64,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            path: String::new(),
            format: None,
            shape: true,
            shaping: ShapeParams::default(),
            skew_fraction: 0.3,
            mem_frac: 1.0,
            seed: 42,
        }
    }
}

/// One full streaming pass over the trace: validates every row and
/// returns the per-user classification plus the data-row count.
/// O(users) resident state. A user's class comes from their **last**
/// row's heavy flag — the same rule as the in-memory
/// [`crate::workload::tracefile`] loader, so the two entries classify
/// every file identically (the golden cross-parser contract).
pub fn scan_user_classes(
    path: &str,
    format: Option<TraceFormat>,
) -> Result<(HashMap<UserId, UserClass>, u64), String> {
    let mut rd = RowReader::open(path, format)?;
    let mut classes: HashMap<UserId, UserClass> = HashMap::new();
    let mut rows = 0u64;
    while let Some(row) = rd.next_row()? {
        rows += 1;
        let class = if row.heavy { UserClass::Heavy } else { UserClass::Light };
        classes.insert(row.user, class);
    }
    if rows == 0 {
        return Err(format!("{path}: trace has no jobs"));
    }
    Ok((classes, rows))
}

/// The streaming trace replay: chunked reads → (optional) one-pass
/// shaping → lazy job materialization. Resident state is the reader's
/// chunk, the shaper's warmup buffer (drained after freezing) and one
/// row of lookahead — O(warmup + in-flight), never O(trace length).
pub struct TraceStream {
    rd: RowReader<File>,
    /// `None` = raw replay (deterministic tracefile job builder).
    shaper: Option<OnePassShaper>,
    rng: Rng,
    skew_fraction: f64,
    mem_frac: f64,
    eof: bool,
    jobs_out: u64,
}

/// Open a trace for streaming replay. Callers that need clean errors for
/// malformed rows should [`scan_user_classes`] first (the registry entry
/// does) — mid-stream parse errors panic, because [`JobStream`] has no
/// error channel.
pub fn open_trace(p: &TraceParams) -> Result<TraceStream, String> {
    let rd = RowReader::open(&p.path, p.format)?;
    Ok(TraceStream {
        rd,
        shaper: p.shape.then(|| OnePassShaper::new(p.shaping.clone())),
        rng: Rng::new(p.seed),
        skew_fraction: p.skew_fraction,
        mem_frac: p.mem_frac,
        eof: false,
        jobs_out: 0,
    })
}

impl TraceStream {
    /// Shaper counters (zeroed stats when replaying raw).
    pub fn shape_stats(&self) -> ShapeStats {
        self.shaper.as_ref().map(|s| s.stats()).unwrap_or_default()
    }

    /// Peak buffered row count — the bounded-state assertion hook
    /// (≤ warmup by construction; 0 on the raw path, which buffers
    /// nothing beyond the reader's chunk).
    pub fn max_buffered(&self) -> usize {
        self.shape_stats().max_buffered
    }

    pub fn jobs_out(&self) -> u64 {
        self.jobs_out
    }

    /// The per-task demand vector of a replayed row: the row's CPU
    /// request (unit on native traces) × the configured memory fraction.
    /// Unit vectors skip the builder entirely, keeping legacy replays
    /// byte-identical to the pre-vector loader.
    fn demand_of(&self, cpu_demand: f64, job: JobSpec) -> JobSpec {
        if cpu_demand == 1.0 && self.mem_frac == 1.0 {
            return job;
        }
        job.with_demand(crate::core::task::ResourceVec::new(cpu_demand, self.mem_frac))
    }

    /// Materialize one shaped row: the §5.3 stage-chain builder with a
    /// per-row forked RNG (skew profiles, shuffle shrink).
    fn shaped_job(&mut self, r: shaping::ShapedRow) -> JobSpec {
        let mut jr = self.rng.fork(r.index);
        let job =
            gtrace::trace_job(r.user, &r.name, r.arrival_s, r.slot_s, &mut jr, self.skew_fraction);
        self.demand_of(r.cpu_demand, job)
    }

    /// Materialize one raw row: the deterministic flat builder shared
    /// with the in-memory tracefile loader.
    fn raw_job(&self, r: &RawRow) -> JobSpec {
        let stages = if r.stages > 0 {
            r.stages
        } else {
            gtrace::stage_count(r.slot_s)
        };
        let job = tracefile::flat_job(r.user, &r.name, r.arrival_s, r.slot_s, stages);
        self.demand_of(r.cpu_demand, job)
    }
}

impl JobStream for TraceStream {
    fn next_job(&mut self) -> Option<JobSpec> {
        loop {
            if let Some(row) = self.shaper.as_mut().and_then(|s| s.pop()) {
                self.jobs_out += 1;
                return Some(self.shaped_job(row));
            }
            if self.eof {
                return None;
            }
            match self.rd.next_row() {
                Ok(Some(row)) => match &mut self.shaper {
                    Some(sh) => sh.push(row),
                    None => {
                        self.jobs_out += 1;
                        return Some(self.raw_job(&row));
                    }
                },
                Ok(None) => {
                    self.eof = true;
                    if let Some(sh) = &mut self.shaper {
                        sh.finish();
                    }
                }
                // No error channel on the stream contract; the registry
                // entry pre-validates with `scan_user_classes`.
                Err(e) => panic!("trace replay: {e}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gtrace::GtraceParams;
    use crate::workload::stream::materialize;

    fn temp(name: &str) -> String {
        let dir = std::env::temp_dir().join(format!("uwfq_traceio_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    fn small_params(path: &str) -> (TraceParams, u64) {
        let gp = GtraceParams {
            window_s: 80.0,
            users: 6,
            heavy_users: 2,
            cores: 8,
            ..GtraceParams::default()
        };
        let rows = writer::write_synthetic(path, 5, &gp).unwrap();
        let tp = TraceParams {
            path: path.to_string(),
            shaping: ShapeParams {
                warmup: 16,
                cores: 8,
                ..ShapeParams::default()
            },
            ..TraceParams::default()
        };
        (tp, rows)
    }

    #[test]
    fn shaped_replay_streams_sorted_valid_jobs() {
        let path = temp("shaped.csv");
        let (tp, rows) = small_params(&path);
        let mut s = open_trace(&tp).unwrap();
        let jobs = materialize(&mut s);
        // The runtime filter may drop a few tail rows, nothing else.
        assert!(jobs.len() as u64 <= rows);
        assert!(jobs.len() as u64 >= rows * 9 / 10, "{} of {rows}", jobs.len());
        let mut last = 0;
        for j in &jobs {
            j.validate().unwrap();
            assert!(j.arrival >= last);
            last = j.arrival;
        }
        assert!(s.max_buffered() <= 16);
        assert_eq!(s.jobs_out(), jobs.len() as u64);
        assert_eq!(s.shape_stats().rows_in, rows);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_is_deterministic_per_seed() {
        let path = temp("determ.csv");
        let (tp, _) = small_params(&path);
        let key = |tp: &TraceParams| {
            materialize(open_trace(tp).unwrap())
                .iter()
                .map(|j| (j.user, j.arrival, j.stages.len(), j.slot_time().to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&tp), key(&tp));
        let mut tp2 = tp.clone();
        tp2.seed = 99; // different skew draws, same rows
        let (a, b) = (key(&tp), key(&tp2));
        assert_eq!(a.len(), b.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn raw_replay_matches_tracefile_loader() {
        let path = temp("raw.csv");
        let (mut tp, rows) = small_params(&path);
        tp.shape = false;
        let streamed = materialize(open_trace(&tp).unwrap());
        assert_eq!(streamed.len() as u64, rows);
        let loaded = tracefile::load_csv_file(&path).unwrap();
        let mut jobs = loaded.jobs;
        jobs.sort_by_key(|j| j.arrival); // stable: file order preserved
        for (a, b) in streamed.iter().zip(&jobs) {
            assert_eq!((a.user, a.arrival, &*a.name), (b.user, b.arrival, &*b.name));
            assert_eq!(a.stages.len(), b.stages.len());
            assert_eq!(
                a.slot_time().to_bits(),
                b.slot_time().to_bits(),
                "raw replay must reuse the tracefile builder bit-for-bit"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scan_collects_classes_and_errors_cleanly() {
        let path = temp("scan.csv");
        let (tp, _) = small_params(&path);
        let (classes, rows) = scan_user_classes(&path, tp.format).unwrap();
        assert_eq!(classes.len(), 6);
        assert_eq!(classes.values().filter(|c| **c == UserClass::Heavy).count(), 2);
        assert!(rows > 0);
        // Missing file: the error names the path.
        let err = scan_user_classes("/nonexistent/trace.csv", None).unwrap_err();
        assert!(err.contains("/nonexistent/trace.csv"), "{err}");
        // Malformed rows surface from the scan, naming the line.
        let bad = temp("bad.csv");
        std::fs::write(&bad, "job,user,arrival_s,slot_s,stages,heavy\na,1,0,oops,1,0\n")
            .unwrap();
        let err = scan_user_classes(&bad, None).unwrap_err();
        assert!(err.contains("line 2") && err.contains("slot_s"), "{err}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bad).ok();
    }

    #[test]
    fn gcluster_rows_carry_real_demand_vectors() {
        use crate::core::task::ResourceVec;
        let path = temp("demand.csv");
        let text = "timestamp,job_id,user,scheduling_class,runtime_s,cpu_request\n\
                    0.0,900,1,3,20.0,0.25\n1.0,901,2,0,4.0,2.0\n";
        std::fs::write(&path, text).unwrap();
        let tp = TraceParams {
            path: path.clone(),
            shape: false,
            mem_frac: 0.5,
            ..TraceParams::default()
        };
        let jobs = materialize(open_trace(&tp).unwrap());
        assert_eq!(jobs.len(), 2);
        // Sub-core request becomes the cpu demand; mem_frac rides along.
        assert!(jobs[0].stages.iter().all(|s| s.demand == ResourceVec::new(0.25, 0.5)));
        // Multi-core requests clamp to one slot's cpu capacity.
        assert!(jobs[1].stages.iter().all(|s| s.demand == ResourceVec::new(1.0, 0.5)));
        for j in &jobs {
            j.validate().unwrap();
        }
        // Default params leave every stage on the unit vector (legacy
        // byte-identity path).
        let unit = TraceParams { path: path.clone(), shape: false, ..TraceParams::default() };
        let jobs = materialize(open_trace(&unit).unwrap());
        assert!(jobs[1].stages.iter().all(|s| s.demand.is_unit()));
        assert!(!jobs[0].stages[0].demand.is_unit(), "cpu_request 0.25 is a real demand");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scan_classes_last_row_wins_like_the_tracefile_loader() {
        // A user whose heavy flag flips mid-trace: both parsers must
        // agree (tracefile's insert semantics = last row wins).
        let flip = temp("flip.csv");
        let text = "job,user,arrival_s,slot_s,stages,heavy\n\
                    f0,7,0.0,5.0,1,1\nf1,7,1.0,5.0,1,0\nf2,8,2.0,5.0,1,1\n";
        std::fs::write(&flip, text).unwrap();
        let (classes, _) = scan_user_classes(&flip, None).unwrap();
        let loaded = tracefile::load_csv(text).unwrap();
        assert_eq!(classes, loaded.user_class);
        assert_eq!(classes[&7], UserClass::Light);
        assert_eq!(classes[&8], UserClass::Heavy);
        std::fs::remove_file(&flip).ok();
    }
}
