//! The practical User-Job Fairness (UJF) baseline (paper §5.1.2).
//!
//! Dynamically creates a fairness pool per user as they arrive; the root
//! Fair policy picks the user with the fewest running tasks
//! (`P_k = N^k_active_task_amount`), and the user's internal Fair policy
//! picks among their stages. This is the paper's fairness reference
//! scheduler — the baseline the DVR/DSR metrics compare against.

use super::{Policy, StageMeta, StageView};
use crate::core::pool::{Pool, PoolPolicy};
use crate::StageId;
use std::collections::HashMap;

pub struct Ujf {
    root: Pool,
}

impl Ujf {
    pub fn new() -> Self {
        Ujf {
            root: Pool::new("root", PoolPolicy::Fair),
        }
    }
}

impl Default for Ujf {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for Ujf {
    fn name(&self) -> &'static str {
        "UJF"
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        // Dynamic per-user pool (created on first stage of that user).
        self.root
            .child(&format!("user-{}", meta.user), PoolPolicy::Fair)
            .add_stage(meta.stage);
    }

    fn on_stage_finish(&mut self, stage: StageId) {
        self.root.remove_stage(stage);
        self.root.prune_empty();
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Fast path equivalent to walking the two-level pool tree
        // (root Fair over per-user pools, Fair within a pool) — verified
        // against `Pool::select` in `fast_path_matches_pool_tree`.
        // 1. Per-user totals over ALL active stages.
        let mut users: HashMap<u32, (u32, u64, usize, bool)> = HashMap::with_capacity(8);
        for v in views {
            let e = users.entry(v.user).or_insert((0, u64::MAX, usize::MAX, false));
            e.0 += v.running;
            e.1 = e.1.min(v.arrival_seq);
            e.2 = e.2.min(v.stage_idx);
            e.3 |= v.pending > 0;
        }
        // 2. Root Fair: user with fewest running tasks (among users with
        //    pending work); FIFO/stage-idx/user-name tiebreaks, matching
        //    the pool tree's comparator + name-ordered children.
        let (&best_user, _) = users
            .iter()
            .filter(|(_, e)| e.3)
            .min_by_key(|(&u, e)| (e.0, e.1, e.2, u))?;
        // 3. Pool Fair: that user's stage with fewest running tasks.
        views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.user == best_user && v.pending > 0)
            .min_by_key(|(_, v)| (v.running, v.arrival_seq, v.stage_idx, v.stage))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::JobMeta;

    fn submit(p: &mut Ujf, stage: u64, user: u32) {
        p.on_stage_submit(
            0.0,
            &StageMeta {
                stage,
                job: stage,
                user,
                est_slot_time: 1.0,
            },
        );
    }

    fn v(stage: u64, user: u32, running: u32, pending: u32, seq: u64) -> StageView {
        StageView {
            stage,
            job: stage,
            user,
            stage_idx: 0,
            running,
            pending,
            arrival_seq: seq,
        }
    }

    #[test]
    fn user_with_fewest_running_tasks_wins() {
        let mut p = Ujf::new();
        submit(&mut p, 1, 1);
        submit(&mut p, 2, 1);
        submit(&mut p, 3, 2);
        // user 1 runs 4 tasks over two stages; user 2 runs 1.
        let views = vec![
            v(1, 1, 1, 5, 0),
            v(2, 1, 3, 5, 1),
            v(3, 2, 1, 5, 2),
        ];
        assert_eq!(p.select(0.0, &views), Some(2));
    }

    #[test]
    fn equal_share_across_users_over_launches() {
        let mut p = Ujf::new();
        submit(&mut p, 1, 1);
        submit(&mut p, 2, 2);
        submit(&mut p, 3, 3);
        let mut running = [0u32; 3];
        for _ in 0..12 {
            let views: Vec<StageView> = (0..3)
                .map(|i| v(i as u64 + 1, i as u32 + 1, running[i], 10, i as u64))
                .collect();
            let picked = p.select(0.0, &views).unwrap();
            running[picked] += 1;
        }
        assert_eq!(running, [4, 4, 4]);
    }

    #[test]
    fn flooding_user_does_not_starve_infrequent_user() {
        // user 1 has 10 stages, user 2 has one: per-launch alternation
        // keeps the running-task totals of both users balanced.
        let mut p = Ujf::new();
        for s in 1..=10 {
            submit(&mut p, s, 1);
        }
        submit(&mut p, 11, 2);
        let mut u1 = 0u32;
        let mut u2 = 0u32;
        for _ in 0..8 {
            let mut views: Vec<StageView> = (1..=10)
                .map(|s| v(s, 1, if s == 1 { u1 } else { 0 }, 10, s))
                .collect();
            // put all of user 1's running tasks on stage 1's count for
            // simplicity of the test harness
            views.push(v(11, 2, u2, 10, 11));
            let picked = p.select(0.0, &views).unwrap();
            if views[picked].user == 1 {
                u1 += 1;
            } else {
                u2 += 1;
            }
        }
        assert_eq!(u1, 4);
        assert_eq!(u2, 4);
    }

    #[test]
    fn stage_finish_prunes_pool() {
        let mut p = Ujf::new();
        submit(&mut p, 1, 1);
        p.on_stage_finish(1);
        // No runnable views → None.
        assert_eq!(p.select(0.0, &[]), None);
        let exhausted = vec![v(2, 2, 1, 0, 0)];
        assert_eq!(p.select(0.0, &exhausted), None);
    }

    #[test]
    fn fast_path_matches_pool_tree() {
        // The O(S) select must agree with walking the two-level Pool tree.
        use crate::core::pool::{Pool, PoolPolicy};
        use crate::util::propkit;
        propkit::check("ujf fast path == pool tree", 0xFA57, 200, |r| {
            let n = 1 + r.below(12) as usize;
            let views: Vec<StageView> = (0..n)
                .map(|i| StageView {
                    stage: i as u64 + 1,
                    job: i as u64 + 1,
                    user: r.below(4) as u32,
                    stage_idx: r.below(3) as usize,
                    running: r.below(5) as u32,
                    pending: r.below(3) as u32,
                    arrival_seq: r.below(6),
                })
                .collect();
            let mut pool = Pool::new("root", PoolPolicy::Fair);
            let mut p = Ujf::new();
            for v in &views {
                pool.child(&format!("user-{:08}", v.user), PoolPolicy::Fair)
                    .add_stage(v.stage);
                p.on_stage_submit(
                    0.0,
                    &StageMeta {
                        stage: v.stage,
                        job: v.job,
                        user: v.user,
                        est_slot_time: 1.0,
                    },
                );
            }
            let map: std::collections::HashMap<StageId, &StageView> =
                views.iter().map(|v| (v.stage, v)).collect();
            let tree = pool.select(&map);
            let fast = p.select(0.0, &views).map(|i| views[i].stage);
            if tree != fast {
                return Err(format!("tree {tree:?} != fast {fast:?} views {views:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn ignores_job_arrival_hook() {
        let mut p = Ujf::new();
        p.on_job_arrival(
            0.0,
            &JobMeta {
                job: 1,
                user: 1,
                weight: 1.0,
                est_slot_time: 1.0,
                arrival_seq: 0,
            },
        );
        assert_eq!(p.job_deadline(1), None);
    }
}
