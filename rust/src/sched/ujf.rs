//! The practical User-Job Fairness (UJF) baseline (paper §5.1.2).
//!
//! Dynamically creates a fairness pool per user as they arrive; the root
//! Fair policy picks the user with the fewest running tasks
//! (`P_k = N^k_active_task_amount`), and the user's internal Fair policy
//! picks among their stages. This is the paper's fairness reference
//! scheduler — the baseline the DVR/DSR metrics compare against.
//!
//! Incremental index: a two-level mirror of the pool tree. Per user we
//! keep aggregate counters (Σ running, Σ pending) plus ordered multisets
//! of the user's stage arrival-seqs / stage-idxs (the root Fair
//! tiebreaks), and an inner Fair [`MapIndex`] over the user's pending
//! stages (map-backed: one index per user, so dense slot columns would
//! cost users × slots). The root level is a lazy min-heap over users
//! with the same invalidation rules as the stage indexes: fresh entry
//! on every key decrease, stale fix-up at pop time. Selection is
//! O(log users + log stages-of-user) per launch. Per-stage records live
//! in a dense slot column ([`SlotCol`]).

use super::index::MapIndex;
use super::{Policy, StageMeta, StageView};
use crate::core::arena::SlotCol;
use crate::{StageId, UserId};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

/// Root-level priority of a user: (Σ running, min arrival_seq,
/// min stage_idx, user id) — identical to the scan-path aggregate.
type UserKey = (u32, u64, usize, UserId);

#[derive(Default)]
struct UserState {
    /// Σ running over the user's active (submitted, unfinished) stages.
    running: u32,
    /// Σ pending over the user's active stages.
    pending: u32,
    /// Multiset of `arrival_seq` over active stages (min = root tiebreak).
    seqs: BTreeMap<u64, u32>,
    /// Multiset of `stage_idx` over active stages.
    idxs: BTreeMap<usize, u32>,
    /// Inner Fair index over the user's pending stages:
    /// (running, arrival_seq, stage_idx) with stage-id tiebreak.
    stages: MapIndex<(u32, u64, usize)>,
}

impl UserState {
    fn key(&self, user: UserId) -> UserKey {
        debug_assert!(!self.seqs.is_empty(), "keyed user has no active stages");
        let min_seq = *self.seqs.keys().next().unwrap();
        let min_idx = *self.idxs.keys().next().unwrap();
        (self.running, min_seq, min_idx, user)
    }
}

/// Static per-stage facts the notifications need.
struct StageRec {
    user: UserId,
    seq: u64,
    idx: usize,
}

#[derive(Default)]
pub struct Ujf {
    users: HashMap<UserId, UserState>,
    /// Lazy min-heap over users with pending work.
    root: BinaryHeap<Reverse<UserKey>>,
    /// Stage slot → static record.
    stage_rec: SlotCol<StageRec>,
}

impl Ujf {
    pub fn new() -> Self {
        Ujf::default()
    }

    /// Valid root minimum: the highest-priority user with pending work.
    fn peek_user(&mut self) -> Option<UserId> {
        while let Some(&Reverse((run, seq, idx, uid))) = self.root.peek() {
            match self.users.get(&uid) {
                Some(u) if u.pending > 0 => {
                    let cur = u.key(uid);
                    if cur == (run, seq, idx, uid) {
                        return Some(uid);
                    }
                    // Stale: re-key so the user stays represented.
                    self.root.pop();
                    self.root.push(Reverse(cur));
                }
                // Departed, or nothing launchable: reclaim. The user is
                // re-pushed on the next pending 0→>0 transition (stage
                // submit), so dropping here is safe.
                _ => {
                    self.root.pop();
                }
            }
        }
        None
    }
}

fn multiset_remove<K: Ord + Copy>(set: &mut BTreeMap<K, u32>, k: K) {
    match set.get_mut(&k) {
        Some(n) if *n > 1 => *n -= 1,
        Some(_) => {
            set.remove(&k);
        }
        None => debug_assert!(false, "multiset underflow"),
    }
}

impl Policy for Ujf {
    fn name(&self) -> &'static str {
        "UJF"
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        let u = self.users.entry(meta.user).or_default();
        *u.seqs.entry(meta.arrival_seq).or_insert(0) += 1;
        *u.idxs.entry(meta.stage_idx).or_insert(0) += 1;
        u.pending += meta.pending;
        u.stages.insert(
            meta.stage,
            meta.slot,
            (0, meta.arrival_seq, meta.stage_idx),
            meta.pending,
        );
        // Key may have decreased (new mins) and pending may have left 0.
        let key = u.key(meta.user);
        self.root.push(Reverse(key));
        self.stage_rec.set(
            meta.slot,
            StageRec {
                user: meta.user,
                seq: meta.arrival_seq,
                idx: meta.stage_idx,
            },
        );
    }

    fn on_task_launched(&mut self, stage: StageId, slot: u32) {
        let Some(rec) = self.stage_rec.get(slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("launch for absent user");
        debug_assert!(u.pending > 0);
        u.pending -= 1;
        u.running += 1;
        u.stages.task_launched(stage);
        if let Some((running, seq, idx)) = u.stages.key_of(stage) {
            u.stages.update_key(stage, (running + 1, seq, idx));
        }
        // Root key increased — existing entries go stale-smaller and are
        // fixed up at the next peek; no push needed.
    }

    fn on_task_finished(&mut self, stage: StageId, slot: u32) {
        let Some(rec) = self.stage_rec.get(slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("finish for absent user");
        debug_assert!(u.running > 0);
        u.running -= 1;
        if let Some((running, seq, idx)) = u.stages.key_of(stage) {
            debug_assert!(running > 0);
            u.stages.update_key(stage, (running - 1, seq, idx));
        }
        // Root key decreased: push fresh so the user can't surface late.
        if u.pending > 0 {
            let key = u.key(rec.user);
            self.root.push(Reverse(key));
        }
    }

    fn on_tasks_finished(&mut self, batch: &[(StageId, u32)]) {
        // Coalesce runs of consecutive same-stage finishes: one net
        // counter update and one root push per run instead of one per
        // finish. Equivalent to the per-event replay — the skipped
        // intermediate root/inner entries are lazy entries the peek
        // loops would have re-keyed away.
        let mut i = 0;
        while i < batch.len() {
            let (stage, slot) = batch[i];
            let mut n: u32 = 1;
            while i + (n as usize) < batch.len() && batch[i + n as usize] == (stage, slot) {
                n += 1;
            }
            if let Some(rec) = self.stage_rec.get(slot) {
                let u = self.users.get_mut(&rec.user).expect("finish for absent user");
                debug_assert!(u.running >= n);
                u.running -= n;
                if let Some((running, seq, idx)) = u.stages.key_of(stage) {
                    debug_assert!(running >= n);
                    u.stages.update_key(stage, (running - n, seq, idx));
                }
                if u.pending > 0 {
                    let key = u.key(rec.user);
                    self.root.push(Reverse(key));
                }
            }
            i += n as usize;
        }
    }

    fn on_task_requeued(&mut self, _now_s: f64, view: &StageView) {
        let Some(rec) = self.stage_rec.get(view.slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("requeue for absent user");
        u.pending += 1;
        // Inner Fair index: the stage may have left on exhaustion; its
        // re-entry key uses the engine's current running count (the
        // failed task is already off the core), as the scan path would.
        u.stages
            .task_requeued(view.stage, view.slot, (view.running, rec.seq, rec.idx));
        // Pending may have left 0 — push a fresh root key so the user is
        // representable again (same rule as stage submit).
        let key = u.key(rec.user);
        self.root.push(Reverse(key));
    }

    fn on_stage_finish(&mut self, stage: StageId, slot: u32) {
        let Some(rec) = self.stage_rec.take(slot) else {
            return;
        };
        let Some(u) = self.users.get_mut(&rec.user) else {
            return;
        };
        multiset_remove(&mut u.seqs, rec.seq);
        multiset_remove(&mut u.idxs, rec.idx);
        u.stages.remove(stage);
        if u.seqs.is_empty() {
            // Last active stage gone: the user leaves the pool tree
            // (equivalent of `prune_empty`).
            self.users.remove(&rec.user);
        }
    }

    fn select_next(&mut self, _now_s: f64) -> Option<(StageId, u32)> {
        let uid = self.peek_user()?;
        let u = self.users.get_mut(&uid).expect("peeked user exists");
        let picked = u.stages.peek();
        debug_assert!(picked.is_some(), "pending user has no launchable stage");
        picked
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Reference scan equivalent to walking the two-level pool tree
        // (root Fair over per-user pools, Fair within a pool) — verified
        // against `Pool::select` in `fast_path_matches_pool_tree`.
        // 1. Per-user totals over ALL active stages.
        let mut users: HashMap<u32, (u32, u64, usize, bool)> = HashMap::with_capacity(8);
        for v in views {
            let e = users.entry(v.user).or_insert((0, u64::MAX, usize::MAX, false));
            e.0 += v.running;
            e.1 = e.1.min(v.arrival_seq);
            e.2 = e.2.min(v.stage_idx);
            e.3 |= v.pending > 0;
        }
        // 2. Root Fair: user with fewest running tasks (among users with
        //    pending work); FIFO/stage-idx/user-name tiebreaks, matching
        //    the pool tree's comparator + name-ordered children.
        let (&best_user, _) = users
            .iter()
            .filter(|(_, e)| e.3)
            .min_by_key(|(&u, e)| (e.0, e.1, e.2, u))?;
        // 3. Pool Fair: that user's stage with fewest running tasks.
        views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.user == best_user && v.pending > 0)
            .min_by_key(|(_, v)| (v.running, v.arrival_seq, v.stage_idx, v.stage))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::JobMeta;

    fn submit(p: &mut Ujf, stage: u64, user: u32) {
        submit_n(p, stage, user, 10);
    }

    fn submit_n(p: &mut Ujf, stage: u64, user: u32, pending: u32) {
        p.on_stage_submit(
            0.0,
            &StageMeta {
                stage,
                slot: stage as u32,
                job: stage,
                user,
                est_slot_time: 1.0,
                stage_idx: 0,
                arrival_seq: stage,
                pending,
                demand: crate::core::task::ResourceVec::UNIT,
            },
        );
    }

    fn v(stage: u64, user: u32, running: u32, pending: u32, seq: u64) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job: stage,
            user,
            stage_idx: 0,
            running,
            pending,
            arrival_seq: seq,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    #[test]
    fn user_with_fewest_running_tasks_wins() {
        let mut p = Ujf::new();
        submit(&mut p, 1, 1);
        submit(&mut p, 2, 1);
        submit(&mut p, 3, 2);
        // user 1 runs 4 tasks over two stages; user 2 runs 1.
        let views = vec![
            v(1, 1, 1, 5, 0),
            v(2, 1, 3, 5, 1),
            v(3, 2, 1, 5, 2),
        ];
        assert_eq!(p.select(0.0, &views), Some(2));
    }

    #[test]
    fn equal_share_across_users_over_launches() {
        let mut p = Ujf::new();
        submit(&mut p, 1, 1);
        submit(&mut p, 2, 2);
        submit(&mut p, 3, 3);
        let mut running = [0u32; 3];
        for _ in 0..12 {
            let views: Vec<StageView> = (0..3)
                .map(|i| v(i as u64 + 1, i as u32 + 1, running[i], 10, i as u64))
                .collect();
            let picked = p.select(0.0, &views).unwrap();
            running[picked] += 1;
        }
        assert_eq!(running, [4, 4, 4]);
    }

    #[test]
    fn incremental_equal_share_across_users() {
        let mut p = Ujf::new();
        submit(&mut p, 1, 1);
        submit(&mut p, 2, 2);
        submit(&mut p, 3, 3);
        let mut launched = std::collections::HashMap::new();
        for _ in 0..12 {
            let (s, slot) = p.select_next(0.0).unwrap();
            *launched.entry(s).or_insert(0u32) += 1;
            p.on_task_launched(s, slot);
        }
        assert_eq!(launched[&1], 4);
        assert_eq!(launched[&2], 4);
        assert_eq!(launched[&3], 4);
    }

    #[test]
    fn incremental_flooder_shares_with_infrequent_user() {
        // user 1 floods 10 stages, user 2 has one: per-launch alternation
        // keeps the users' running totals balanced.
        let mut p = Ujf::new();
        for s in 1..=10 {
            submit(&mut p, s, 1);
        }
        submit(&mut p, 11, 2);
        let mut per_user = [0u32; 2];
        for _ in 0..8 {
            let (s, slot) = p.select_next(0.0).unwrap();
            per_user[if s == 11 { 1 } else { 0 }] += 1;
            p.on_task_launched(s, slot);
        }
        assert_eq!(per_user, [4, 4]);
    }

    #[test]
    fn incremental_finish_rebalances() {
        let mut p = Ujf::new();
        submit_n(&mut p, 1, 1, 4);
        submit_n(&mut p, 2, 2, 4);
        // u1 launches twice, u2 once → u2 preferred next.
        assert_eq!(p.select_next(0.0), Some((1, 1)));
        p.on_task_launched(1, 1);
        assert_eq!(p.select_next(0.0), Some((2, 2)));
        p.on_task_launched(2, 2);
        assert_eq!(p.select_next(0.0), Some((1, 1)));
        p.on_task_launched(1, 1);
        assert_eq!(p.select_next(0.0), Some((2, 2)));
        // One of u1's tasks finishes → tie at 1 running each → user id
        // breaks the tie? No: min arrival_seq breaks first (u1's stage 1).
        p.on_task_finished(1, 1);
        assert_eq!(p.select_next(0.0), Some((1, 1)));
    }

    #[test]
    fn batched_finish_matches_per_event_replay() {
        let mut a = Ujf::new();
        let mut b = Ujf::new();
        for p in [&mut a, &mut b] {
            submit_n(p, 1, 1, 6);
            submit_n(p, 2, 2, 6);
            for _ in 0..3 {
                p.on_task_launched(1, 1);
            }
            p.on_task_launched(2, 2);
        }
        let batch = [(1u64, 1u32), (1, 1), (2, 2)];
        a.on_tasks_finished(&batch);
        for &(s, slot) in &batch {
            b.on_task_finished(s, slot);
        }
        for _ in 0..6 {
            let x = a.select_next(0.0);
            assert_eq!(x, b.select_next(0.0));
            if let Some((s, slot)) = x {
                a.on_task_launched(s, slot);
                b.on_task_launched(s, slot);
            }
        }
    }

    #[test]
    fn flooding_user_does_not_starve_infrequent_user() {
        // user 1 has 10 stages, user 2 has one: per-launch alternation
        // keeps the running-task totals of both users balanced.
        let mut p = Ujf::new();
        for s in 1..=10 {
            submit(&mut p, s, 1);
        }
        submit(&mut p, 11, 2);
        let mut u1 = 0u32;
        let mut u2 = 0u32;
        for _ in 0..8 {
            let mut views: Vec<StageView> = (1..=10)
                .map(|s| v(s, 1, if s == 1 { u1 } else { 0 }, 10, s))
                .collect();
            // put all of user 1's running tasks on stage 1's count for
            // simplicity of the test harness
            views.push(v(11, 2, u2, 10, 11));
            let picked = p.select(0.0, &views).unwrap();
            if views[picked].user == 1 {
                u1 += 1;
            } else {
                u2 += 1;
            }
        }
        assert_eq!(u1, 4);
        assert_eq!(u2, 4);
    }

    #[test]
    fn stage_finish_prunes_pool() {
        let mut p = Ujf::new();
        submit(&mut p, 1, 1);
        p.on_stage_finish(1, 1);
        assert!(p.users.is_empty(), "user pruned with last stage");
        // No runnable views → None.
        assert_eq!(p.select(0.0, &[]), None);
        assert_eq!(p.select_next(0.0), None);
        let exhausted = vec![v(2, 2, 1, 0, 0)];
        assert_eq!(p.select(0.0, &exhausted), None);
    }

    #[test]
    fn fast_path_matches_pool_tree() {
        // The O(S) select must agree with walking the two-level Pool tree.
        use crate::core::pool::{Pool, PoolPolicy};
        use crate::util::propkit;
        use crate::StageId;
        propkit::check("ujf fast path == pool tree", 0xFA57, 200, |r| {
            let n = 1 + r.below(12) as usize;
            let views: Vec<StageView> = (0..n)
                .map(|i| StageView {
                    stage: i as u64 + 1,
                    slot: i as u32 + 1,
                    job: i as u64 + 1,
                    user: r.below(4) as u32,
                    stage_idx: r.below(3) as usize,
                    running: r.below(5) as u32,
                    pending: r.below(3) as u32,
                    arrival_seq: r.below(6),
                    demand: crate::core::task::ResourceVec::UNIT,
                })
                .collect();
            let mut pool = Pool::new("root", PoolPolicy::Fair);
            let mut p = Ujf::new();
            for v in &views {
                pool.child(&format!("user-{:08}", v.user), PoolPolicy::Fair)
                    .add_stage(v.stage);
                p.on_stage_submit(
                    0.0,
                    &StageMeta {
                        stage: v.stage,
                        slot: v.slot,
                        job: v.job,
                        user: v.user,
                        est_slot_time: 1.0,
                        stage_idx: v.stage_idx,
                        arrival_seq: v.arrival_seq,
                        pending: v.pending.max(1),
                        demand: crate::core::task::ResourceVec::UNIT,
                    },
                );
            }
            let map: std::collections::HashMap<StageId, &StageView> =
                views.iter().map(|v| (v.stage, v)).collect();
            let tree = pool.select(&map);
            let fast = p.select(0.0, &views).map(|i| views[i].stage);
            if tree != fast {
                return Err(format!("tree {tree:?} != fast {fast:?} views {views:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn ignores_job_arrival_hook() {
        let mut p = Ujf::new();
        p.on_job_arrival(
            0.0,
            &JobMeta {
                job: 1,
                user: 1,
                weight: 1.0,
                est_slot_time: 1.0,
                arrival_seq: 0,
            },
        );
        assert_eq!(p.job_deadline(1), None);
    }
}
