//! Spark's built-in FIFO scheduler (paper §2.1.3): jobs in arrival order,
//! stages of the same job in stage-index order.

use super::{select_min_by_key, Policy, StageView};

#[derive(Default)]
pub struct Fifo;

impl Fifo {
    pub fn new() -> Self {
        Fifo
    }
}

impl Policy for Fifo {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        select_min_by_key(views, |v| (v.arrival_seq, v.stage_idx, v.stage))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(stage: u64, seq: u64, idx: usize, pending: u32) -> StageView {
        StageView {
            stage,
            job: seq,
            user: 0,
            stage_idx: idx,
            running: 0,
            pending,
            arrival_seq: seq,
        }
    }

    #[test]
    fn picks_earliest_job_then_stage() {
        let mut p = Fifo::new();
        let views = vec![v(10, 2, 0, 1), v(11, 1, 1, 1), v(12, 1, 0, 1)];
        assert_eq!(p.select(0.0, &views), Some(2));
    }

    #[test]
    fn skips_exhausted_stages() {
        let mut p = Fifo::new();
        let views = vec![v(10, 1, 0, 0), v(11, 2, 0, 3)];
        assert_eq!(p.select(0.0, &views), Some(1));
        assert_eq!(p.select(0.0, &[]), None);
    }
}
