//! Spark's built-in FIFO scheduler (paper §2.1.3): jobs in arrival order,
//! stages of the same job in stage-index order.
//!
//! Incremental index: keys are static per stage, so a plain lazy min-heap
//! ([`StageIndex`]) gives O(log n) selection with no invalidation traffic.

use super::index::StageIndex;
use super::{select_min_by_key, Policy, StageMeta, StageView};
use crate::StageId;

#[derive(Default)]
pub struct Fifo {
    index: StageIndex<(u64, usize)>,
}

impl Fifo {
    pub fn new() -> Self {
        Fifo {
            index: StageIndex::new(),
        }
    }
}

impl Policy for Fifo {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        self.index
            .insert(meta.stage, (meta.arrival_seq, meta.stage_idx), meta.pending);
    }

    fn on_task_launched(&mut self, stage: StageId) {
        self.index.task_launched(stage);
    }

    fn on_task_requeued(&mut self, _now_s: f64, v: &StageView) {
        self.index
            .task_requeued(v.stage, (v.arrival_seq, v.stage_idx));
    }

    fn on_stage_finish(&mut self, stage: StageId) {
        self.index.remove(stage);
    }

    fn select_next(&mut self, _now_s: f64) -> Option<StageId> {
        self.index.peek()
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        select_min_by_key(views, |v| (v.arrival_seq, v.stage_idx, v.stage))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(stage: u64, seq: u64, idx: usize, pending: u32) -> StageView {
        StageView {
            stage,
            job: seq,
            user: 0,
            stage_idx: idx,
            running: 0,
            pending,
            arrival_seq: seq,
        }
    }

    fn submit(p: &mut Fifo, stage: u64, seq: u64, idx: usize, pending: u32) {
        p.on_stage_submit(
            0.0,
            &StageMeta {
                stage,
                job: seq,
                user: 0,
                est_slot_time: 1.0,
                stage_idx: idx,
                arrival_seq: seq,
                pending,
            },
        );
    }

    #[test]
    fn picks_earliest_job_then_stage() {
        let mut p = Fifo::new();
        let views = vec![v(10, 2, 0, 1), v(11, 1, 1, 1), v(12, 1, 0, 1)];
        assert_eq!(p.select(0.0, &views), Some(2));
    }

    #[test]
    fn skips_exhausted_stages() {
        let mut p = Fifo::new();
        let views = vec![v(10, 1, 0, 0), v(11, 2, 0, 3)];
        assert_eq!(p.select(0.0, &views), Some(1));
        assert_eq!(p.select(0.0, &[]), None);
    }

    #[test]
    fn incremental_matches_scan() {
        let mut p = Fifo::new();
        submit(&mut p, 10, 2, 0, 1);
        submit(&mut p, 11, 1, 1, 1);
        submit(&mut p, 12, 1, 0, 2);
        assert_eq!(p.select_next(0.0), Some(12));
        p.on_task_launched(12);
        assert_eq!(p.select_next(0.0), Some(12));
        p.on_task_launched(12); // exhausted
        assert_eq!(p.select_next(0.0), Some(11));
        p.on_stage_finish(11);
        assert_eq!(p.select_next(0.0), Some(10));
        p.on_task_launched(10);
        assert_eq!(p.select_next(0.0), None);
    }
}
