//! Spark's built-in FIFO scheduler (paper §2.1.3): jobs in arrival order,
//! stages of the same job in stage-index order.
//!
//! Incremental index: keys are static per stage, so a plain lazy min-heap
//! ([`StageIndex`]) gives O(log n) selection with no invalidation traffic
//! — and `static_keys` lets the batched event core merge same-timestamp
//! offers and launch multi-task quanta without re-selecting.

use super::index::StageIndex;
use super::{select_min_by_key, Policy, StageMeta, StageView};
use crate::StageId;

#[derive(Default)]
pub struct Fifo {
    index: StageIndex<(u64, usize)>,
}

impl Fifo {
    pub fn new() -> Self {
        Fifo {
            index: StageIndex::new(),
        }
    }
}

impl Policy for Fifo {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        self.index.insert(
            meta.stage,
            meta.slot,
            (meta.arrival_seq, meta.stage_idx),
            meta.pending,
        );
    }

    fn on_task_launched(&mut self, stage: StageId, slot: u32) {
        self.index.task_launched(stage, slot);
    }

    fn on_tasks_launched(&mut self, stage: StageId, slot: u32, n: u32) {
        self.index.task_launched_n(stage, slot, n);
    }

    fn on_tasks_finished(&mut self, _batch: &[(StageId, u32)]) {
        // Keys are static and carry no running count: a batch of plain
        // finishes changes nothing in the index.
    }

    fn on_task_requeued(&mut self, _now_s: f64, v: &StageView) {
        self.index
            .task_requeued(v.stage, v.slot, (v.arrival_seq, v.stage_idx));
    }

    fn on_stage_finish(&mut self, stage: StageId, slot: u32) {
        self.index.remove(stage, slot);
    }

    fn static_keys(&self) -> bool {
        true
    }

    fn select_next(&mut self, _now_s: f64) -> Option<(StageId, u32)> {
        self.index.peek()
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        select_min_by_key(views, |v| (v.arrival_seq, v.stage_idx, v.stage))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(stage: u64, seq: u64, idx: usize, pending: u32) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job: seq,
            user: 0,
            stage_idx: idx,
            running: 0,
            pending,
            arrival_seq: seq,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    fn submit(p: &mut Fifo, stage: u64, seq: u64, idx: usize, pending: u32) {
        p.on_stage_submit(
            0.0,
            &StageMeta {
                stage,
                slot: stage as u32,
                job: seq,
                user: 0,
                est_slot_time: 1.0,
                stage_idx: idx,
                arrival_seq: seq,
                pending,
                demand: crate::core::task::ResourceVec::UNIT,
            },
        );
    }

    #[test]
    fn picks_earliest_job_then_stage() {
        let mut p = Fifo::new();
        let views = vec![v(10, 2, 0, 1), v(11, 1, 1, 1), v(12, 1, 0, 1)];
        assert_eq!(p.select(0.0, &views), Some(2));
    }

    #[test]
    fn skips_exhausted_stages() {
        let mut p = Fifo::new();
        let views = vec![v(10, 1, 0, 0), v(11, 2, 0, 3)];
        assert_eq!(p.select(0.0, &views), Some(1));
        assert_eq!(p.select(0.0, &[]), None);
    }

    #[test]
    fn incremental_matches_scan() {
        let mut p = Fifo::new();
        submit(&mut p, 10, 2, 0, 1);
        submit(&mut p, 11, 1, 1, 1);
        submit(&mut p, 12, 1, 0, 2);
        assert_eq!(p.select_next(0.0), Some((12, 12)));
        p.on_task_launched(12, 12);
        assert_eq!(p.select_next(0.0), Some((12, 12)));
        p.on_task_launched(12, 12); // exhausted
        assert_eq!(p.select_next(0.0), Some((11, 11)));
        p.on_stage_finish(11, 11);
        assert_eq!(p.select_next(0.0), Some((10, 10)));
        p.on_task_launched(10, 10);
        assert_eq!(p.select_next(0.0), None);
    }

    #[test]
    fn batched_hooks_match_singles() {
        let mut a = Fifo::new();
        let mut b = Fifo::new();
        for p in [&mut a, &mut b] {
            submit(p, 10, 1, 0, 3);
            submit(p, 11, 2, 0, 1);
        }
        a.on_tasks_launched(10, 10, 2);
        b.on_task_launched(10, 10);
        b.on_task_launched(10, 10);
        assert_eq!(a.select_next(0.0), b.select_next(0.0));
        // Plain-finish batches are a no-op for static keys.
        a.on_tasks_finished(&[(10, 10), (10, 10)]);
        assert_eq!(a.select_next(0.0), Some((10, 10)));
    }
}
