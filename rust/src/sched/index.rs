//! Incremental priority indexes for O(log n) per-event selection.
//!
//! Every policy keeps a [`StageIndex`] (or, for UJF's pool tree, one
//! [`MapIndex`] per user) so that `select_next` is a heap peek instead
//! of a scan over all active stages. Both use **lazy invalidation**:
//! key changes push a fresh entry instead of rewriting the heap, and
//! stale entries are discarded (or re-keyed) when they surface at the
//! top.
//!
//! [`StageIndex`] stores its per-stage state (current key, pending
//! count, occupying stage id) in **dense slot-indexed columns** — SoA,
//! addressed by the engine's arena slot that every policy hook now
//! carries — so validation at the heap top is three array reads with
//! no hashing. [`MapIndex`] is the HashMap-backed variant with the
//! same API and invariants, for the many-small-indexes case (UJF keeps
//! one per user; dense columns there would multiply the slot space by
//! the user count).
//!
//! ## Invariants (the lazy-invalidation contract)
//!
//! 1. A stage with pending tasks always has at least one heap entry whose
//!    stored key is **≤** its true key: every key *decrease* (and every
//!    consumption of the top entry) pushes a fresh entry, while key
//!    *increases* are left stale and fixed up at pop time.
//! 2. An entry is *valid* iff its stored key equals the stage's current
//!    key. A stale-smaller entry surfaces early, is re-pushed with the
//!    current key, and therefore can never cause a late selection.
//! 3. Stages whose pending count reaches zero are dropped from the
//!    index. A fault-injected retry can make a stage selectable again
//!    ([`StageIndex::task_requeued`]): a dropped stage is re-inserted
//!    with the caller's key, a live one just gains pending count. On
//!    the fault-free path pending never increases and the drop is
//!    permanent.
//!
//! Slot recycling is safe: the engine retires a stage (and its index
//! entry) before its arena slot is reused, stage ids are never reused,
//! and heap entries carry `(key, stage, slot)` — an entry whose slot
//! now holds a different stage id fails the occupancy check and is
//! reclaimed like any other dead entry.
//!
//! Amortized cost: every engine event (submit / launch / task-finish)
//! pushes O(1) entries, so total heap traffic is O(events · log n).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::StageId;

/// Total-ordered f64 for heap keys (virtual deadlines are always finite
/// or +∞, never NaN; `total_cmp` matches `PartialOrd` on that domain).
#[derive(Clone, Copy, Debug, Default)]
pub struct F64Key(pub f64);

impl PartialEq for F64Key {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == std::cmp::Ordering::Equal
    }
}
impl Eq for F64Key {}
impl PartialOrd for F64Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Sentinel for an unoccupied slot column entry: the engine's stage ids
/// start at 1, so 0 never names a live stage.
const EMPTY: StageId = 0;

/// Min-index over stages with pending work, SoA storage. `K` is the
/// policy's priority key; ties beyond `K` break on `StageId` (matching
/// the scan-path comparators, which all end in the stage id — the slot
/// rides behind the id and never decides an ordering).
#[derive(Debug)]
pub struct StageIndex<K: Ord + Copy + Default> {
    heap: BinaryHeap<Reverse<(K, StageId, u32)>>,
    /// Dense columns indexed by arena slot. `id[slot] == EMPTY` means
    /// the slot is not selectable; otherwise `key`/`pending` hold the
    /// occupying stage's current key and pending count (always > 0).
    id: Vec<StageId>,
    key: Vec<K>,
    pending: Vec<u32>,
    /// Selectable stages (occupied slots).
    live: usize,
}

impl<K: Ord + Copy + Default> Default for StageIndex<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Ord + Copy + Default> StageIndex<K> {
    pub fn new() -> Self {
        StageIndex {
            heap: BinaryHeap::new(),
            id: Vec::new(),
            key: Vec::new(),
            pending: Vec::new(),
            live: 0,
        }
    }

    /// Number of selectable (pending > 0) stages.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    #[inline]
    fn occupied(&self, stage: StageId, slot: u32) -> bool {
        (slot as usize) < self.id.len() && self.id[slot as usize] == stage
    }

    /// Current key of a selectable stage.
    pub fn key_of(&self, stage: StageId, slot: u32) -> Option<K> {
        if self.occupied(stage, slot) {
            Some(self.key[slot as usize])
        } else {
            None
        }
    }

    /// Register a newly-submitted stage under its arena slot.
    pub fn insert(&mut self, stage: StageId, slot: u32, key: K, pending: u32) {
        debug_assert!(pending > 0, "stage submitted with no tasks");
        debug_assert_ne!(stage, EMPTY, "stage ids start at 1");
        let i = slot as usize;
        if i >= self.id.len() {
            self.id.resize(i + 1, EMPTY);
            self.key.resize_with(i + 1, K::default);
            self.pending.resize(i + 1, 0);
        }
        debug_assert_eq!(self.id[i], EMPTY, "slot already occupied");
        self.id[i] = stage;
        self.key[i] = key;
        self.pending[i] = pending;
        self.live += 1;
        self.heap.push(Reverse((key, stage, slot)));
    }

    /// Drop a stage (completion). Heap entries are reclaimed lazily.
    pub fn remove(&mut self, stage: StageId, slot: u32) {
        if self.occupied(stage, slot) {
            self.id[slot as usize] = EMPTY;
            self.live -= 1;
        }
    }

    /// Change a stage's priority key. Pushes a fresh entry so the new
    /// position is discoverable; the old entry goes stale.
    pub fn update_key(&mut self, stage: StageId, slot: u32, key: K) {
        if self.occupied(stage, slot) && self.key[slot as usize] != key {
            self.key[slot as usize] = key;
            self.heap.push(Reverse((key, stage, slot)));
        }
    }

    /// One task of `stage` launched: decrement pending, dropping the
    /// stage from the index when it has nothing left to launch.
    pub fn task_launched(&mut self, stage: StageId, slot: u32) {
        self.task_launched_n(stage, slot, 1);
    }

    /// `n` tasks of `stage` launched back-to-back (the batched core's
    /// multi-launch quantum): one decrement instead of `n`.
    pub fn task_launched_n(&mut self, stage: StageId, slot: u32, n: u32) {
        if self.occupied(stage, slot) {
            let i = slot as usize;
            debug_assert!(self.pending[i] >= n);
            self.pending[i] -= n;
            if self.pending[i] == 0 {
                self.id[i] = EMPTY;
                self.live -= 1;
            }
        }
    }

    /// One task of `stage` re-entered its queue after a fault-injected
    /// retry: re-increment pending. A stage that had been dropped on
    /// exhaustion is re-inserted under `key`; a still-live stage keeps
    /// its current key (the retry does not change its priority).
    pub fn task_requeued(&mut self, stage: StageId, slot: u32, key: K) {
        if self.occupied(stage, slot) {
            self.pending[slot as usize] += 1;
        } else {
            self.insert(stage, slot, key, 1);
        }
    }

    /// The minimum-key selectable stage (with its slot), or `None`.
    /// Does not consume the entry — callers follow up with
    /// [`Self::task_launched`] (via the policy's `on_task_launched`)
    /// once the launch actually happens.
    pub fn peek(&mut self) -> Option<(StageId, u32)> {
        while let Some(&Reverse((k, stage, slot))) = self.heap.peek() {
            if self.occupied(stage, slot) {
                let cur = self.key[slot as usize];
                if cur == k {
                    // Valid: stored key is the current key.
                    debug_assert!(self.pending[slot as usize] > 0);
                    return Some((stage, slot));
                }
                // Stale: re-key so the stage keeps its representation.
                self.heap.pop();
                self.heap.push(Reverse((cur, stage, slot)));
            } else {
                // Dead (finished, exhausted, or recycled slot): reclaim.
                self.heap.pop();
            }
        }
        None
    }
}

/// HashMap-backed index with the same API, lazy-invalidation contract,
/// and `(key, stage)` selection order as [`StageIndex`]. Used where
/// many small indexes coexist (UJF's per-user pools) and per-index
/// dense slot columns would cost `users × slots` memory.
#[derive(Debug)]
pub struct MapIndex<K: Ord + Copy> {
    heap: BinaryHeap<Reverse<(K, StageId, u32)>>,
    /// stage → (current key, pending tasks, arena slot).
    live: HashMap<StageId, (K, u32, u32)>,
}

impl<K: Ord + Copy> Default for MapIndex<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Ord + Copy> MapIndex<K> {
    pub fn new() -> Self {
        MapIndex {
            heap: BinaryHeap::new(),
            live: HashMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    pub fn key_of(&self, stage: StageId) -> Option<K> {
        self.live.get(&stage).map(|&(k, _, _)| k)
    }

    pub fn insert(&mut self, stage: StageId, slot: u32, key: K, pending: u32) {
        debug_assert!(pending > 0, "stage submitted with no tasks");
        self.live.insert(stage, (key, pending, slot));
        self.heap.push(Reverse((key, stage, slot)));
    }

    pub fn remove(&mut self, stage: StageId) {
        self.live.remove(&stage);
    }

    pub fn update_key(&mut self, stage: StageId, key: K) {
        if let Some(e) = self.live.get_mut(&stage) {
            if e.0 != key {
                e.0 = key;
                self.heap.push(Reverse((key, stage, e.2)));
            }
        }
    }

    pub fn task_launched(&mut self, stage: StageId) {
        self.task_launched_n(stage, 1);
    }

    pub fn task_launched_n(&mut self, stage: StageId, n: u32) {
        if let Some(e) = self.live.get_mut(&stage) {
            debug_assert!(e.1 >= n);
            e.1 -= n;
            if e.1 == 0 {
                self.live.remove(&stage);
            }
        }
    }

    pub fn task_requeued(&mut self, stage: StageId, slot: u32, key: K) {
        match self.live.get_mut(&stage) {
            Some(e) => e.1 += 1,
            None => self.insert(stage, slot, key, 1),
        }
    }

    pub fn peek(&mut self) -> Option<(StageId, u32)> {
        while let Some(&Reverse((k, stage, slot))) = self.heap.peek() {
            match self.live.get(&stage) {
                Some(&(cur, _, s)) if cur == k && s == slot => return Some((stage, slot)),
                Some(&(cur, _, s)) => {
                    self.heap.pop();
                    self.heap.push(Reverse((cur, stage, s)));
                }
                None => {
                    self.heap.pop();
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_key_wins_with_stage_tiebreak() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(5, 0, 2, 1);
        ix.insert(3, 1, 1, 1);
        ix.insert(4, 2, 1, 1);
        assert_eq!(ix.peek(), Some((3, 1)), "equal keys break on stage id");
    }

    #[test]
    fn pending_exhaustion_drops_stage() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(1, 0, 0, 2);
        ix.insert(2, 1, 5, 1);
        assert_eq!(ix.peek(), Some((1, 0)));
        ix.task_launched(1, 0);
        assert_eq!(ix.peek(), Some((1, 0)));
        ix.task_launched(1, 0);
        assert_eq!(ix.peek(), Some((2, 1)), "exhausted stage is dropped");
        assert_eq!(ix.len(), 1);
    }

    #[test]
    fn batched_launch_matches_singles() {
        let mut a: StageIndex<u64> = StageIndex::new();
        let mut b: StageIndex<u64> = StageIndex::new();
        a.insert(1, 0, 3, 5);
        b.insert(1, 0, 3, 5);
        a.task_launched_n(1, 0, 3);
        for _ in 0..3 {
            b.task_launched(1, 0);
        }
        assert_eq!(a.peek(), b.peek());
        a.task_launched_n(1, 0, 2);
        b.task_launched_n(1, 0, 2);
        assert_eq!(a.peek(), None, "exhaustion via batch drops the stage");
        assert_eq!(b.peek(), None);
    }

    #[test]
    fn key_increase_goes_stale_then_recovers() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(1, 0, 0, 5);
        ix.insert(2, 1, 1, 5);
        ix.update_key(1, 0, 3); // stage 1 demoted
        assert_eq!(ix.peek(), Some((2, 1)));
        ix.update_key(2, 1, 9); // stage 2 demoted past 1
        assert_eq!(ix.peek(), Some((1, 0)));
    }

    #[test]
    fn removal_reclaims_lazily() {
        let mut ix: StageIndex<(u32, u64)> = StageIndex::new();
        ix.insert(1, 0, (0, 0), 1);
        ix.insert(2, 1, (0, 1), 1);
        ix.remove(1, 0);
        assert_eq!(ix.peek(), Some((2, 1)));
        ix.remove(2, 1);
        assert_eq!(ix.peek(), None);
        assert!(ix.is_empty());
    }

    #[test]
    fn requeue_revives_exhausted_stage() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(1, 0, 4, 1);
        ix.insert(2, 1, 7, 1);
        ix.task_launched(1, 0);
        assert_eq!(ix.peek(), Some((2, 1)), "stage 1 exhausted");
        // Retry re-inserts the dropped stage with the caller's key.
        ix.task_requeued(1, 0, 4);
        assert_eq!(ix.peek(), Some((1, 0)));
        assert_eq!(ix.key_of(1, 0), Some(4));
        // Requeue on a live stage only bumps pending.
        ix.task_requeued(2, 1, 99);
        assert_eq!(ix.key_of(2, 1), Some(7), "live stage keeps its key");
        ix.task_launched(1, 0);
        ix.task_launched(2, 1);
        assert_eq!(ix.peek(), Some((2, 1)), "second pending task still there");
    }

    #[test]
    fn recycled_slot_rejects_dead_heap_entries() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(1, 0, 0, 1); // best key, slot 0
        ix.insert(2, 1, 5, 1);
        ix.remove(1, 0);
        // Slot 0 recycled by a new stage with a worse key: the stale
        // heap entry (0, stage 1, slot 0) must not select stage 3.
        ix.insert(3, 0, 9, 1);
        assert_eq!(ix.peek(), Some((2, 1)));
        ix.task_launched(2, 1);
        assert_eq!(ix.peek(), Some((3, 0)));
    }

    #[test]
    fn f64key_total_order() {
        assert!(F64Key(1.0) < F64Key(2.0));
        assert!(F64Key(f64::INFINITY) > F64Key(1e300));
        assert_eq!(F64Key(3.5), F64Key(3.5));
    }

    #[test]
    fn churn_preserves_argmin_vs_scan() {
        // Randomized differential check against a linear scan, with the
        // slot space deliberately recycled (slot = stage % 7) so the
        // occupancy check is exercised under aliasing. Only one live
        // stage per slot at a time, as in the engine.
        use crate::util::Rng;
        let mut rng = Rng::new(0x1DE);
        let mut ix: StageIndex<(u32, u64)> = StageIndex::new();
        let mut model: HashMap<StageId, ((u32, u64), u32, u32)> = HashMap::new();
        let mut slot_used = [false; 7];
        let mut next_stage: StageId = 1;
        for _ in 0..2000 {
            match rng.below(4) {
                0 => {
                    let slot = (next_stage % 7) as u32;
                    if !slot_used[slot as usize] {
                        let key = (rng.below(4) as u32, rng.below(100));
                        let pending = 1 + rng.below(3) as u32;
                        ix.insert(next_stage, slot, key, pending);
                        model.insert(next_stage, (key, pending, slot));
                        slot_used[slot as usize] = true;
                    }
                    next_stage += 1;
                }
                1 => {
                    if let Some(&s) = model.keys().min() {
                        let (_, _, slot) = model.remove(&s).unwrap();
                        ix.remove(s, slot);
                        slot_used[slot as usize] = false;
                    }
                }
                2 => {
                    if let Some(&s) = model.keys().max() {
                        let key = (rng.below(4) as u32, rng.below(100));
                        let e = model.get_mut(&s).unwrap();
                        ix.update_key(s, e.2, key);
                        e.0 = key;
                    }
                }
                _ => {
                    if let Some((s, slot)) = ix.peek() {
                        ix.task_launched(s, slot);
                        let e = model.get_mut(&s).unwrap();
                        e.1 -= 1;
                        if e.1 == 0 {
                            model.remove(&s);
                            slot_used[slot as usize] = false;
                        }
                    }
                }
            }
            let expect = model
                .iter()
                .map(|(&s, &(k, _, slot))| (k, s, slot))
                .min()
                .map(|(_, s, slot)| (s, slot));
            assert_eq!(ix.peek(), expect);
        }
    }

    #[test]
    fn map_index_mirrors_soa_behavior() {
        let mut ix: MapIndex<u64> = MapIndex::new();
        ix.insert(5, 0, 2, 1);
        ix.insert(3, 1, 1, 2);
        ix.insert(4, 2, 1, 1);
        assert_eq!(ix.peek(), Some((3, 1)), "equal keys break on stage id");
        ix.task_launched(3);
        ix.task_launched(3);
        assert_eq!(ix.peek(), Some((4, 2)), "exhausted stage dropped");
        ix.update_key(4, 9);
        assert_eq!(ix.peek(), Some((5, 0)));
        ix.remove(5);
        ix.task_requeued(3, 1, 0);
        assert_eq!(ix.peek(), Some((3, 1)), "requeue revives with new key");
        ix.task_launched_n(3, 1);
        assert_eq!(ix.peek(), Some((4, 2)));
    }
}
