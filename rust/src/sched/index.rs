//! Incremental priority indexes for O(log n) per-event selection.
//!
//! Every policy keeps a [`StageIndex`] (or two, for UJF's pool tree) so
//! that `select_next` is a heap peek instead of a scan over all active
//! stages. The index uses **lazy invalidation**: key changes push a fresh
//! entry instead of rewriting the heap, and stale entries are discarded
//! (or re-keyed) when they surface at the top.
//!
//! ## Invariants (the lazy-invalidation contract)
//!
//! 1. A stage with pending tasks always has at least one heap entry whose
//!    stored key is **≤** its true key: every key *decrease* (and every
//!    consumption of the top entry) pushes a fresh entry, while key
//!    *increases* are left stale and fixed up at pop time.
//! 2. An entry is *valid* iff its stored key equals the stage's current
//!    key. A stale-smaller entry surfaces early, is re-pushed with the
//!    current key, and therefore can never cause a late selection.
//! 3. Stages whose pending count reaches zero are dropped from the
//!    index. A fault-injected retry can make a stage selectable again
//!    ([`StageIndex::task_requeued`]): a dropped stage is re-inserted
//!    with the caller's key, a live one just gains pending count. On
//!    the fault-free path pending never increases and the drop is
//!    permanent.
//!
//! Amortized cost: every engine event (submit / launch / task-finish)
//! pushes O(1) entries, so total heap traffic is O(events · log n).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::StageId;

/// Total-ordered f64 for heap keys (virtual deadlines are always finite
/// or +∞, never NaN; `total_cmp` matches `PartialOrd` on that domain).
#[derive(Clone, Copy, Debug)]
pub struct F64Key(pub f64);

impl PartialEq for F64Key {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == std::cmp::Ordering::Equal
    }
}
impl Eq for F64Key {}
impl PartialOrd for F64Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Min-index over stages with pending work. `K` is the policy's priority
/// key; ties beyond `K` break on `StageId` (matching the scan-path
/// comparators, which all end in the stage id).
#[derive(Debug)]
pub struct StageIndex<K: Ord + Copy> {
    heap: BinaryHeap<Reverse<(K, StageId)>>,
    /// stage → (current key, pending tasks). Stages leave at pending 0 or
    /// on removal; heap entries for absent stages are dropped lazily.
    live: HashMap<StageId, (K, u32)>,
}

impl<K: Ord + Copy> Default for StageIndex<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Ord + Copy> StageIndex<K> {
    pub fn new() -> Self {
        StageIndex {
            heap: BinaryHeap::new(),
            live: HashMap::new(),
        }
    }

    /// Number of selectable (pending > 0) stages.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Current key of a selectable stage.
    pub fn key_of(&self, stage: StageId) -> Option<K> {
        self.live.get(&stage).map(|&(k, _)| k)
    }

    /// Register a newly-submitted stage.
    pub fn insert(&mut self, stage: StageId, key: K, pending: u32) {
        debug_assert!(pending > 0, "stage submitted with no tasks");
        self.live.insert(stage, (key, pending));
        self.heap.push(Reverse((key, stage)));
    }

    /// Drop a stage (completion). Heap entries are reclaimed lazily.
    pub fn remove(&mut self, stage: StageId) {
        self.live.remove(&stage);
    }

    /// Change a stage's priority key. Pushes a fresh entry so the new
    /// position is discoverable; the old entry goes stale.
    pub fn update_key(&mut self, stage: StageId, key: K) {
        if let Some(e) = self.live.get_mut(&stage) {
            if e.0 != key {
                e.0 = key;
                self.heap.push(Reverse((key, stage)));
            }
        }
    }

    /// One task of `stage` launched: decrement pending, dropping the
    /// stage from the index when it has nothing left to launch.
    pub fn task_launched(&mut self, stage: StageId) {
        if let Some(e) = self.live.get_mut(&stage) {
            debug_assert!(e.1 > 0);
            e.1 -= 1;
            if e.1 == 0 {
                self.live.remove(&stage);
            }
        }
    }

    /// One task of `stage` re-entered its queue after a fault-injected
    /// retry: re-increment pending. A stage that had been dropped on
    /// exhaustion is re-inserted under `key`; a still-live stage keeps
    /// its current key (the retry does not change its priority).
    pub fn task_requeued(&mut self, stage: StageId, key: K) {
        match self.live.get_mut(&stage) {
            Some(e) => e.1 += 1,
            None => self.insert(stage, key, 1),
        }
    }

    /// The minimum-key selectable stage, or `None`. Does not consume the
    /// entry — callers follow up with [`Self::task_launched`] (via the
    /// policy's `on_task_launched`) once the launch actually happens.
    pub fn peek(&mut self) -> Option<StageId> {
        while let Some(&Reverse((k, stage))) = self.heap.peek() {
            match self.live.get(&stage) {
                // Valid: stored key is the current key.
                Some(&(cur, _)) if cur == k => return Some(stage),
                // Stale: re-key so the stage keeps its representation.
                Some(&(cur, _)) => {
                    self.heap.pop();
                    self.heap.push(Reverse((cur, stage)));
                }
                // Dead (finished or exhausted): reclaim.
                None => {
                    self.heap.pop();
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_key_wins_with_stage_tiebreak() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(5, 2, 1);
        ix.insert(3, 1, 1);
        ix.insert(4, 1, 1);
        assert_eq!(ix.peek(), Some(3), "equal keys break on stage id");
    }

    #[test]
    fn pending_exhaustion_drops_stage() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(1, 0, 2);
        ix.insert(2, 5, 1);
        assert_eq!(ix.peek(), Some(1));
        ix.task_launched(1);
        assert_eq!(ix.peek(), Some(1));
        ix.task_launched(1);
        assert_eq!(ix.peek(), Some(2), "exhausted stage is dropped");
        assert_eq!(ix.len(), 1);
    }

    #[test]
    fn key_increase_goes_stale_then_recovers() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(1, 0, 5);
        ix.insert(2, 1, 5);
        ix.update_key(1, 3); // stage 1 demoted
        assert_eq!(ix.peek(), Some(2));
        ix.update_key(2, 9); // stage 2 demoted past 1
        assert_eq!(ix.peek(), Some(1));
    }

    #[test]
    fn removal_reclaims_lazily() {
        let mut ix: StageIndex<(u32, u64)> = StageIndex::new();
        ix.insert(1, (0, 0), 1);
        ix.insert(2, (0, 1), 1);
        ix.remove(1);
        assert_eq!(ix.peek(), Some(2));
        ix.remove(2);
        assert_eq!(ix.peek(), None);
        assert!(ix.is_empty());
    }

    #[test]
    fn requeue_revives_exhausted_stage() {
        let mut ix: StageIndex<u64> = StageIndex::new();
        ix.insert(1, 4, 1);
        ix.insert(2, 7, 1);
        ix.task_launched(1);
        assert_eq!(ix.peek(), Some(2), "stage 1 exhausted");
        // Retry re-inserts the dropped stage with the caller's key.
        ix.task_requeued(1, 4);
        assert_eq!(ix.peek(), Some(1));
        assert_eq!(ix.key_of(1), Some(4));
        // Requeue on a live stage only bumps pending.
        ix.task_requeued(2, 99);
        assert_eq!(ix.key_of(2), Some(7), "live stage keeps its key");
        ix.task_launched(1);
        ix.task_launched(2);
        assert_eq!(ix.peek(), Some(2), "second pending task still there");
    }

    #[test]
    fn f64key_total_order() {
        assert!(F64Key(1.0) < F64Key(2.0));
        assert!(F64Key(f64::INFINITY) > F64Key(1e300));
        assert_eq!(F64Key(3.5), F64Key(3.5));
    }

    #[test]
    fn churn_preserves_argmin_vs_scan() {
        // Randomized differential check against a linear scan.
        use crate::util::Rng;
        let mut rng = Rng::new(0x1DE);
        let mut ix: StageIndex<(u32, u64)> = StageIndex::new();
        let mut model: std::collections::HashMap<StageId, ((u32, u64), u32)> =
            std::collections::HashMap::new();
        let mut next_stage: StageId = 1;
        for _ in 0..2000 {
            match rng.below(4) {
                0 => {
                    let key = (rng.below(4) as u32, rng.below(100));
                    let pending = 1 + rng.below(3) as u32;
                    ix.insert(next_stage, key, pending);
                    model.insert(next_stage, (key, pending));
                    next_stage += 1;
                }
                1 => {
                    if let Some(&s) = model.keys().min() {
                        ix.remove(s);
                        model.remove(&s);
                    }
                }
                2 => {
                    if let Some(&s) = model.keys().max() {
                        let key = (rng.below(4) as u32, rng.below(100));
                        ix.update_key(s, key);
                        model.get_mut(&s).unwrap().0 = key;
                    }
                }
                _ => {
                    if let Some(s) = ix.peek() {
                        ix.task_launched(s);
                        let e = model.get_mut(&s).unwrap();
                        e.1 -= 1;
                        if e.1 == 0 {
                            model.remove(&s);
                        }
                    }
                }
            }
            let expect = model
                .iter()
                .map(|(&s, &(k, _))| (k, s))
                .min()
                .map(|(_, s)| s);
            assert_eq!(ix.peek(), expect);
        }
    }
}
