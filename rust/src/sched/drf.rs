//! Dominant Resource Fairness (DRF) — progressive filling over resource
//! vectors (the Mesos fair-allocation study, arXiv:1803.00922).
//!
//! Each user's *dominant share* is the larger of their (CPU, memory)
//! allocation fractions across all running tasks. Every launch
//! opportunity goes to the user with the smallest dominant share —
//! progressive filling — with FIFO tie-breaks (min arrival-seq, min
//! stage-idx, user id) so unit-vector workloads reduce to a
//! deterministic, work-conserving schedule. Weights are deliberately
//! ignored (unweighted DRF, as in the original allocation study).
//!
//! All share accounting is **exact integer arithmetic** in milli-demand
//! units: a launch adds the stage's `(cpu, mem)` demand in milli-units
//! to the user's allocation, a finish subtracts it, and the dominant
//! share is `max(cpu_milli, mem_milli)` — identical cluster capacity per
//! dimension makes the fraction comparison equivalent to comparing raw
//! milli totals, with no float drift between the incremental index and
//! the reference scan.
//!
//! Incremental index: the same two-level lazy structure as UJF — a root
//! min-heap over users keyed `(dominant_milli, min_seq, min_idx, user)`
//! with fresh entries pushed on every key decrease and stale entries
//! re-keyed at pop time, plus one FIFO [`MapIndex`] per user over their
//! pending stages. Selection is O(log users + log stages-of-user).

use super::index::MapIndex;
use super::{Policy, StageMeta, StageView};
use crate::core::arena::SlotCol;
use crate::{StageId, UserId};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

/// Root priority: (dominant allocation in milli-units, min arrival_seq,
/// min stage_idx, user id).
type UserKey = (u64, u64, usize, UserId);

#[derive(Default)]
struct UserState {
    /// Σ cpu demand (milli-units) over the user's running tasks.
    alloc_cpu: u64,
    /// Σ mem demand (milli-units) over the user's running tasks.
    alloc_mem: u64,
    /// Σ pending over the user's active stages.
    pending: u32,
    /// Multiset of `arrival_seq` over active stages (min = tiebreak).
    seqs: BTreeMap<u64, u32>,
    /// Multiset of `stage_idx` over active stages.
    idxs: BTreeMap<usize, u32>,
    /// FIFO index over the user's pending stages:
    /// (arrival_seq, stage_idx) with stage-id tiebreak.
    stages: MapIndex<(u64, usize)>,
}

impl UserState {
    fn dominant(&self) -> u64 {
        self.alloc_cpu.max(self.alloc_mem)
    }

    fn key(&self, user: UserId) -> UserKey {
        debug_assert!(!self.seqs.is_empty(), "keyed user has no active stages");
        let min_seq = *self.seqs.keys().next().unwrap();
        let min_idx = *self.idxs.keys().next().unwrap();
        (self.dominant(), min_seq, min_idx, user)
    }
}

/// Static per-stage facts the notifications need.
struct StageRec {
    user: UserId,
    seq: u64,
    idx: usize,
    /// Stage demand in milli-units (cpu, mem).
    dm: (u64, u64),
}

#[derive(Default)]
pub struct Drf {
    users: HashMap<UserId, UserState>,
    /// Lazy min-heap over users with pending work.
    root: BinaryHeap<Reverse<UserKey>>,
    /// Stage slot → static record.
    stage_rec: SlotCol<StageRec>,
}

impl Drf {
    pub fn new() -> Self {
        Drf::default()
    }

    /// Valid root minimum: the lowest-dominant-share user with pending
    /// work (same lazy re-key loop as UJF's root).
    fn peek_user(&mut self) -> Option<UserId> {
        while let Some(&Reverse((dom, seq, idx, uid))) = self.root.peek() {
            match self.users.get(&uid) {
                Some(u) if u.pending > 0 => {
                    let cur = u.key(uid);
                    if cur == (dom, seq, idx, uid) {
                        return Some(uid);
                    }
                    self.root.pop();
                    self.root.push(Reverse(cur));
                }
                _ => {
                    self.root.pop();
                }
            }
        }
        None
    }
}

fn multiset_remove<K: Ord + Copy>(set: &mut BTreeMap<K, u32>, k: K) {
    match set.get_mut(&k) {
        Some(n) if *n > 1 => *n -= 1,
        Some(_) => {
            set.remove(&k);
        }
        None => debug_assert!(false, "multiset underflow"),
    }
}

impl Policy for Drf {
    fn name(&self) -> &'static str {
        "DRF"
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        let (dc, dmem) = meta.demand.milli();
        let u = self.users.entry(meta.user).or_default();
        *u.seqs.entry(meta.arrival_seq).or_insert(0) += 1;
        *u.idxs.entry(meta.stage_idx).or_insert(0) += 1;
        u.pending += meta.pending;
        u.stages.insert(
            meta.stage,
            meta.slot,
            (meta.arrival_seq, meta.stage_idx),
            meta.pending,
        );
        // Key may have decreased (new mins) and pending may have left 0.
        let key = u.key(meta.user);
        self.root.push(Reverse(key));
        self.stage_rec.set(
            meta.slot,
            StageRec {
                user: meta.user,
                seq: meta.arrival_seq,
                idx: meta.stage_idx,
                dm: (dc as u64, dmem as u64),
            },
        );
    }

    fn on_task_launched(&mut self, stage: StageId, slot: u32) {
        let Some(rec) = self.stage_rec.get(slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("launch for absent user");
        debug_assert!(u.pending > 0);
        u.pending -= 1;
        u.alloc_cpu += rec.dm.0;
        u.alloc_mem += rec.dm.1;
        u.stages.task_launched(stage);
        // Dominant share increased — existing root entries go
        // stale-smaller and are re-keyed at the next peek; no push.
    }

    fn on_task_finished(&mut self, stage: StageId, slot: u32) {
        let _ = stage;
        let Some(rec) = self.stage_rec.get(slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("finish for absent user");
        debug_assert!(u.alloc_cpu >= rec.dm.0 && u.alloc_mem >= rec.dm.1);
        u.alloc_cpu -= rec.dm.0;
        u.alloc_mem -= rec.dm.1;
        // Dominant share decreased: push fresh so the user can't surface
        // late.
        if u.pending > 0 {
            let key = u.key(rec.user);
            self.root.push(Reverse(key));
        }
    }

    fn on_task_requeued(&mut self, _now_s: f64, view: &StageView) {
        let Some(rec) = self.stage_rec.get(view.slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("requeue for absent user");
        u.pending += 1;
        // The stage may have left the index on exhaustion; its FIFO key
        // is static, so re-entry uses the recorded key.
        u.stages
            .task_requeued(view.stage, view.slot, (rec.seq, rec.idx));
        // Pending may have left 0 — push a fresh root key so the user is
        // representable again.
        let key = u.key(rec.user);
        self.root.push(Reverse(key));
    }

    fn on_stage_finish(&mut self, stage: StageId, slot: u32) {
        let Some(rec) = self.stage_rec.take(slot) else {
            return;
        };
        let Some(u) = self.users.get_mut(&rec.user) else {
            return;
        };
        multiset_remove(&mut u.seqs, rec.seq);
        multiset_remove(&mut u.idxs, rec.idx);
        u.stages.remove(stage);
        if u.seqs.is_empty() {
            debug_assert_eq!(
                (u.alloc_cpu, u.alloc_mem),
                (0, 0),
                "departing user still holds allocation"
            );
            self.users.remove(&rec.user);
        }
    }

    fn select_next(&mut self, _now_s: f64) -> Option<(StageId, u32)> {
        let uid = self.peek_user()?;
        let u = self.users.get_mut(&uid).expect("peeked user exists");
        let picked = u.stages.peek();
        debug_assert!(picked.is_some(), "pending user has no launchable stage");
        picked
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Reference scan: recompute every user's allocation from the
        // engine's running counts — Σ running × demand (milli) per
        // dimension, exactly the integers the incremental path maintains.
        let mut users: HashMap<u32, (u64, u64, u64, usize, bool)> = HashMap::with_capacity(8);
        for v in views {
            let (dc, dm) = v.demand.milli();
            let e = users
                .entry(v.user)
                .or_insert((0, 0, u64::MAX, usize::MAX, false));
            e.0 += v.running as u64 * dc as u64;
            e.1 += v.running as u64 * dm as u64;
            e.2 = e.2.min(v.arrival_seq);
            e.3 = e.3.min(v.stage_idx);
            e.4 |= v.pending > 0;
        }
        // Progressive filling: smallest dominant share wins; FIFO and
        // user-id tiebreaks.
        let (&best_user, _) = users
            .iter()
            .filter(|(_, e)| e.4)
            .min_by_key(|(&u, e)| (e.0.max(e.1), e.2, e.3, u))?;
        // Within the user: FIFO over pending stages.
        views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.user == best_user && v.pending > 0)
            .min_by_key(|(_, v)| (v.arrival_seq, v.stage_idx, v.stage))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::task::ResourceVec;

    fn submit(p: &mut Drf, stage: u64, user: u32, demand: ResourceVec) {
        p.on_stage_submit(
            0.0,
            &StageMeta {
                stage,
                slot: stage as u32,
                job: stage,
                user,
                est_slot_time: 1.0,
                stage_idx: 0,
                arrival_seq: stage,
                pending: 10,
                demand,
            },
        );
    }

    fn v(stage: u64, user: u32, running: u32, pending: u32, demand: ResourceVec) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job: stage,
            user,
            stage_idx: 0,
            running,
            pending,
            arrival_seq: stage,
            demand,
        }
    }

    #[test]
    fn lowest_dominant_share_wins() {
        let mut p = Drf::new();
        // user 1: cpu-heavy tasks; user 2: mem-heavy tasks.
        let cpu = ResourceVec::new(1.0, 0.2);
        let mem = ResourceVec::new(0.2, 1.0);
        submit(&mut p, 1, 1, cpu);
        submit(&mut p, 2, 2, mem);
        // user 1 runs 2 tasks (dominant 2000), user 2 runs 1 (1000).
        let views = vec![v(1, 1, 2, 5, cpu), v(2, 2, 1, 5, mem)];
        assert_eq!(p.select(0.0, &views), Some(1));
    }

    #[test]
    fn incremental_progressive_filling_equalizes_dominant_shares() {
        // Classic DRF example: user 1 demands (1.0, 0.25), user 2
        // (0.25, 1.0). Progressive filling alternates launches, keeping
        // dominant shares equal — each user ends with the same number of
        // running tasks despite asymmetric vectors.
        let mut p = Drf::new();
        submit(&mut p, 1, 1, ResourceVec::new(1.0, 0.25));
        submit(&mut p, 2, 2, ResourceVec::new(0.25, 1.0));
        let mut per_user = [0u32; 2];
        for _ in 0..8 {
            let (s, slot) = p.select_next(0.0).unwrap();
            per_user[(s - 1) as usize] += 1;
            p.on_task_launched(s, slot);
        }
        assert_eq!(per_user, [4, 4]);
    }

    #[test]
    fn asymmetric_demands_skew_allocation_toward_light_user() {
        // user 1's dominant demand is 1.0, user 2's is 0.25: equalizing
        // dominant shares gives user 2 ~4× the task count.
        let mut p = Drf::new();
        submit(&mut p, 1, 1, ResourceVec::UNIT);
        submit(&mut p, 2, 2, ResourceVec::new(0.25, 0.25));
        let mut per_user = [0u32; 2];
        for _ in 0..10 {
            let (s, slot) = p.select_next(0.0).unwrap();
            per_user[(s - 1) as usize] += 1;
            p.on_task_launched(s, slot);
        }
        // 2 launches for user 1 (dominant 2000 milli) vs 8 for user 2
        // (dominant 2000 milli): shares equalized.
        assert_eq!(per_user, [2, 8]);
    }

    #[test]
    fn unit_vectors_reduce_to_fewest_running_tasks() {
        // With unit demands the dominant share is 1000 × running tasks,
        // so DRF degenerates to fair sharing by running count.
        let mut p = Drf::new();
        for s in 1..=3 {
            submit(&mut p, s, s as u32, ResourceVec::UNIT);
        }
        let mut launched = std::collections::HashMap::new();
        for _ in 0..12 {
            let (s, slot) = p.select_next(0.0).unwrap();
            *launched.entry(s).or_insert(0u32) += 1;
            p.on_task_launched(s, slot);
        }
        assert_eq!(launched[&1], 4);
        assert_eq!(launched[&2], 4);
        assert_eq!(launched[&3], 4);
    }

    #[test]
    fn finish_rebalances_and_scan_agrees() {
        let mut p = Drf::new();
        let d1 = ResourceVec::new(0.5, 1.0);
        let d2 = ResourceVec::new(1.0, 0.5);
        submit(&mut p, 1, 1, d1);
        submit(&mut p, 2, 2, d2);
        let mut running = [0u32; 2];
        for _ in 0..6 {
            let views = vec![
                v(1, 1, running[0], 10, d1),
                v(2, 2, running[1], 10, d2),
            ];
            let scan = p.select(0.0, &views).map(|i| views[i].stage);
            let inc = p.select_next(0.0).map(|(s, _)| s);
            assert_eq!(scan, inc);
            let (s, slot) = p.select_next(0.0).unwrap();
            running[(s - 1) as usize] += 1;
            p.on_task_launched(s, slot);
        }
        assert_eq!(running, [3, 3]);
        // Finish two of user 1's tasks: user 1 drops to dominant 1000,
        // below user 2's 3000 — user 1 must be picked next.
        p.on_task_finished(1, 1);
        p.on_task_finished(1, 1);
        assert_eq!(p.select_next(0.0), Some((1, 1)));
    }

    #[test]
    fn stage_finish_prunes_user() {
        let mut p = Drf::new();
        submit(&mut p, 1, 1, ResourceVec::UNIT);
        p.on_stage_finish(1, 1);
        assert!(p.users.is_empty(), "user pruned with last stage");
        assert_eq!(p.select_next(0.0), None);
        assert_eq!(p.select(0.0, &[]), None);
    }
}
