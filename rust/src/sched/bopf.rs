//! BoPF — Bounded Priority Fairness: burst-tolerant two-class scheduling.
//!
//! Interactive users submit in short bursts separated by think time; a
//! long-term fair scheduler makes each burst queue behind everything the
//! user "saved up" during their idle period. BoPF bounds that effect:
//! every user holds a *burst budget* of estimated resource-seconds,
//! refreshed whenever they go active after an idle period. While budget
//! remains the user is in the **burst class** and is served ahead of all
//! exhausted users, ordered by burst start (earlier burst first — FIFO
//! across bursts keeps the class starvation-free and deterministic).
//! Once the budget is spent the user falls back to the **fair class**,
//! ordered by DRF-style dominant share of their current allocation — a
//! sustained heavy user cannot ride the priority lane by re-submitting.
//!
//! Each launch charges the user's budget with the task's estimated
//! resource-seconds: `(stage est-slot-time / initial task count) ×
//! dominant demand fraction`. Charges use the runtime estimator's
//! per-stage value captured at submit, so the policy is deterministic and
//! estimator-consistent across repeats.
//!
//! Incremental index: the UJF/DRF two-level lazy structure — root
//! min-heap over users keyed `(class, burst-seq | dominant-milli,
//! min_seq, min_idx, user)`, one FIFO [`MapIndex`] per user.

use super::index::MapIndex;
use super::{Policy, StageMeta, StageView};
use crate::core::arena::SlotCol;
use crate::{StageId, UserId};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

/// Root priority: (class [0 = burst, 1 = fair], burst-seq or dominant
/// alloc milli, min arrival_seq, min stage_idx, user id).
type UserKey = (u8, u64, u64, usize, UserId);

#[derive(Default)]
struct UserState {
    /// Remaining burst budget, estimated resource-seconds. Strictly
    /// positive ⇒ burst class.
    credit_rsec: f64,
    /// Global sequence of the user's current burst (FIFO across bursts).
    burst_seq: u64,
    /// Σ cpu / mem demand (milli-units) over the user's running tasks.
    alloc_cpu: u64,
    alloc_mem: u64,
    /// Σ pending over the user's active stages.
    pending: u32,
    /// Multisets over active stages (min = FIFO tiebreak).
    seqs: BTreeMap<u64, u32>,
    idxs: BTreeMap<usize, u32>,
    /// FIFO index over the user's pending stages.
    stages: MapIndex<(u64, usize)>,
}

impl UserState {
    fn dominant(&self) -> u64 {
        self.alloc_cpu.max(self.alloc_mem)
    }

    fn key(&self, user: UserId) -> UserKey {
        debug_assert!(!self.seqs.is_empty(), "keyed user has no active stages");
        let min_seq = *self.seqs.keys().next().unwrap();
        let min_idx = *self.idxs.keys().next().unwrap();
        let (class, a) = if self.credit_rsec > 0.0 {
            (0, self.burst_seq)
        } else {
            (1, self.dominant())
        };
        (class, a, min_seq, min_idx, user)
    }
}

/// Static per-stage facts the notifications need.
struct StageRec {
    user: UserId,
    seq: u64,
    idx: usize,
    /// Stage demand in milli-units (cpu, mem).
    dm: (u64, u64),
    /// Budget charge per launched task, estimated resource-seconds.
    charge_rsec: f64,
}

pub struct Bopf {
    /// Burst budget granted per burst, estimated resource-seconds.
    burst_rsec: f64,
    users: HashMap<UserId, UserState>,
    /// Lazy min-heap over users with pending work.
    root: BinaryHeap<Reverse<UserKey>>,
    /// Stage slot → static record.
    stage_rec: SlotCol<StageRec>,
    /// Next burst sequence number (global, monotone).
    next_burst: u64,
}

impl Bopf {
    pub fn new(burst_rsec: f64) -> Self {
        assert!(burst_rsec > 0.0 && burst_rsec.is_finite());
        Bopf {
            burst_rsec,
            users: HashMap::new(),
            root: BinaryHeap::new(),
            stage_rec: SlotCol::default(),
            next_burst: 0,
        }
    }

    /// Valid root minimum: same lazy re-key loop as UJF/DRF.
    fn peek_user(&mut self) -> Option<UserId> {
        while let Some(&Reverse((c, a, seq, idx, uid))) = self.root.peek() {
            match self.users.get(&uid) {
                Some(u) if u.pending > 0 => {
                    let cur = u.key(uid);
                    if cur == (c, a, seq, idx, uid) {
                        return Some(uid);
                    }
                    self.root.pop();
                    self.root.push(Reverse(cur));
                }
                _ => {
                    self.root.pop();
                }
            }
        }
        None
    }
}

fn multiset_remove<K: Ord + Copy>(set: &mut BTreeMap<K, u32>, k: K) {
    match set.get_mut(&k) {
        Some(n) if *n > 1 => *n -= 1,
        Some(_) => {
            set.remove(&k);
        }
        None => debug_assert!(false, "multiset underflow"),
    }
}

impl Policy for Bopf {
    fn name(&self) -> &'static str {
        "BoPF"
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        let (dc, dmem) = meta.demand.milli();
        let u = self.users.entry(meta.user).or_default();
        if u.seqs.is_empty() {
            // User goes active after an idle period: a new burst starts
            // with a fresh budget, queued FIFO behind earlier bursts.
            u.credit_rsec = self.burst_rsec;
            u.burst_seq = self.next_burst;
            self.next_burst += 1;
        }
        *u.seqs.entry(meta.arrival_seq).or_insert(0) += 1;
        *u.idxs.entry(meta.stage_idx).or_insert(0) += 1;
        u.pending += meta.pending;
        u.stages.insert(
            meta.stage,
            meta.slot,
            (meta.arrival_seq, meta.stage_idx),
            meta.pending,
        );
        let key = u.key(meta.user);
        self.root.push(Reverse(key));
        self.stage_rec.set(
            meta.slot,
            StageRec {
                user: meta.user,
                seq: meta.arrival_seq,
                idx: meta.stage_idx,
                dm: (dc as u64, dmem as u64),
                charge_rsec: meta.est_slot_time / meta.pending.max(1) as f64
                    * meta.demand.dominant(),
            },
        );
    }

    fn on_task_launched(&mut self, stage: StageId, slot: u32) {
        let Some(rec) = self.stage_rec.get(slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("launch for absent user");
        debug_assert!(u.pending > 0);
        u.pending -= 1;
        u.alloc_cpu += rec.dm.0;
        u.alloc_mem += rec.dm.1;
        u.credit_rsec -= rec.charge_rsec;
        u.stages.task_launched(stage);
        // Key can only increase here (budget drain / class flip / higher
        // dominant share): existing root entries go stale-smaller and
        // are re-keyed at the next peek.
    }

    fn on_task_finished(&mut self, stage: StageId, slot: u32) {
        let _ = stage;
        let Some(rec) = self.stage_rec.get(slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("finish for absent user");
        debug_assert!(u.alloc_cpu >= rec.dm.0 && u.alloc_mem >= rec.dm.1);
        u.alloc_cpu -= rec.dm.0;
        u.alloc_mem -= rec.dm.1;
        // Fair-class key decreased with the dominant share: push fresh.
        if u.pending > 0 {
            let key = u.key(rec.user);
            self.root.push(Reverse(key));
        }
    }

    fn on_task_requeued(&mut self, _now_s: f64, view: &StageView) {
        let Some(rec) = self.stage_rec.get(view.slot) else {
            return;
        };
        let u = self.users.get_mut(&rec.user).expect("requeue for absent user");
        u.pending += 1;
        u.stages
            .task_requeued(view.stage, view.slot, (rec.seq, rec.idx));
        let key = u.key(rec.user);
        self.root.push(Reverse(key));
    }

    fn on_stage_finish(&mut self, stage: StageId, slot: u32) {
        let Some(rec) = self.stage_rec.take(slot) else {
            return;
        };
        let Some(u) = self.users.get_mut(&rec.user) else {
            return;
        };
        multiset_remove(&mut u.seqs, rec.seq);
        multiset_remove(&mut u.idxs, rec.idx);
        u.stages.remove(stage);
        if u.seqs.is_empty() {
            debug_assert_eq!(
                (u.alloc_cpu, u.alloc_mem),
                (0, 0),
                "departing user still holds allocation"
            );
            // Unspent credit does not carry over: the next activity
            // starts a fresh burst.
            self.users.remove(&rec.user);
        }
    }

    fn select_next(&mut self, _now_s: f64) -> Option<(StageId, u32)> {
        let uid = self.peek_user()?;
        let u = self.users.get_mut(&uid).expect("peeked user exists");
        let picked = u.stages.peek();
        debug_assert!(picked.is_some(), "pending user has no launchable stage");
        picked
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Reference scan: allocation and FIFO mins recomputed from the
        // engine's views; budget state (credit, burst seq) read from the
        // same per-user records the incremental path maintains — both
        // are driven by identical launch/finish notifications.
        let mut agg: HashMap<u32, (u64, u64, u64, usize, bool)> = HashMap::with_capacity(8);
        for v in views {
            let (dc, dm) = v.demand.milli();
            let e = agg
                .entry(v.user)
                .or_insert((0, 0, u64::MAX, usize::MAX, false));
            e.0 += v.running as u64 * dc as u64;
            e.1 += v.running as u64 * dm as u64;
            e.2 = e.2.min(v.arrival_seq);
            e.3 = e.3.min(v.stage_idx);
            e.4 |= v.pending > 0;
        }
        let (&best_user, _) = agg
            .iter()
            .filter(|(_, e)| e.4)
            .min_by_key(|(&uid, e)| {
                let u = self.users.get(&uid).expect("viewed user is tracked");
                let (class, a) = if u.credit_rsec > 0.0 {
                    (0u8, u.burst_seq)
                } else {
                    (1u8, e.0.max(e.1))
                };
                (class, a, e.2, e.3, uid)
            })?;
        views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.user == best_user && v.pending > 0)
            .min_by_key(|(_, v)| (v.arrival_seq, v.stage_idx, v.stage))
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::task::ResourceVec;

    fn submit(p: &mut Bopf, stage: u64, user: u32, est: f64, pending: u32) {
        p.on_stage_submit(
            0.0,
            &StageMeta {
                stage,
                slot: stage as u32,
                job: stage,
                user,
                est_slot_time: est,
                stage_idx: 0,
                arrival_seq: stage,
                pending,
                demand: ResourceVec::UNIT,
            },
        );
    }

    fn v(stage: u64, user: u32, running: u32, pending: u32) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job: stage,
            user,
            stage_idx: 0,
            running,
            pending,
            arrival_seq: stage,
            demand: ResourceVec::UNIT,
        }
    }

    #[test]
    fn burst_class_preempts_exhausted_user() {
        // Budget 2 rsec; user 1's tasks cost 1 rsec each: after two
        // launches the budget hits zero and user 1 drops to the fair
        // class, so freshly-bursting user 2 takes over.
        let mut p = Bopf::new(2.0);
        submit(&mut p, 1, 1, 10.0, 10);
        for _ in 0..2 {
            let (s, slot) = p.select_next(0.0).unwrap();
            assert_eq!(s, 1);
            p.on_task_launched(s, slot);
        }
        submit(&mut p, 2, 2, 10.0, 10);
        let views = vec![v(1, 1, 2, 8), v(2, 2, 0, 10)];
        assert_eq!(p.select(0.0, &views), Some(1), "burst user wins the scan");
        assert_eq!(p.select_next(0.0).unwrap().0, 2, "burst user wins the index");
    }

    #[test]
    fn earlier_burst_wins_within_class() {
        let mut p = Bopf::new(100.0);
        submit(&mut p, 1, 1, 1.0, 5);
        submit(&mut p, 2, 2, 1.0, 5);
        // Both users hold credit; user 1's burst started first.
        for _ in 0..5 {
            let (s, slot) = p.select_next(0.0).unwrap();
            assert_eq!(s, 1);
            p.on_task_launched(s, slot);
        }
        assert_eq!(p.select_next(0.0).unwrap().0, 2);
    }

    #[test]
    fn fair_class_orders_by_dominant_share() {
        // Budget so small the first launch exhausts it: both users land
        // in the fair class immediately and alternate like DRF.
        let mut p = Bopf::new(1e-9);
        submit(&mut p, 1, 1, 10.0, 10);
        submit(&mut p, 2, 2, 10.0, 10);
        let mut per_user = [0u32; 2];
        for _ in 0..6 {
            let (s, slot) = p.select_next(0.0).unwrap();
            per_user[(s - 1) as usize] += 1;
            p.on_task_launched(s, slot);
        }
        assert_eq!(per_user, [3, 3], "exhausted users share fairly");
    }

    #[test]
    fn scan_matches_incremental_through_burst_exhaustion() {
        let mut p = Bopf::new(3.0);
        submit(&mut p, 1, 1, 10.0, 10); // 1 rsec per task
        submit(&mut p, 2, 2, 5.0, 10); // 0.5 rsec per task
        let mut running = [0u32; 2];
        for _ in 0..12 {
            let views = vec![
                v(1, 1, running[0], 10 - running[0]),
                v(2, 2, running[1], 10 - running[1]),
            ];
            let scan = p.select(0.0, &views).map(|i| views[i].stage);
            let inc = p.select_next(0.0).map(|(s, _)| s);
            assert_eq!(scan, inc);
            let (s, slot) = p.select_next(0.0).unwrap();
            running[(s - 1) as usize] += 1;
            p.on_task_launched(s, slot);
        }
    }

    #[test]
    fn idle_user_gets_fresh_budget_on_return() {
        let mut p = Bopf::new(1.0);
        submit(&mut p, 1, 1, 10.0, 10);
        let (s, slot) = p.select_next(0.0).unwrap();
        p.on_task_launched(s, slot); // budget spent
        p.on_task_finished(1, 1);
        p.on_stage_finish(1, 1); // user departs
        assert!(p.users.is_empty());
        // Re-arrival: a fresh burst with fresh credit and a later seq.
        submit(&mut p, 3, 1, 10.0, 10);
        let u = &p.users[&1];
        assert_eq!(u.credit_rsec, 1.0);
        assert_eq!(u.burst_seq, 1);
    }

    #[test]
    fn finish_rebalances_fair_class() {
        let mut p = Bopf::new(1e-9);
        submit(&mut p, 1, 1, 10.0, 10);
        submit(&mut p, 2, 2, 10.0, 10);
        // Drive user 1 to 3 running, user 2 to 1.
        for want in [1u64, 2, 1, 2, 1] {
            let (s, slot) = p.select_next(0.0).unwrap();
            let _ = want;
            p.on_task_launched(s, slot);
        }
        // user 1: 3 running (first pick by user-id tiebreak), user 2: 2.
        p.on_task_finished(1, 1);
        p.on_task_finished(1, 1);
        p.on_task_finished(1, 1);
        // user 1 now at 0 running: must be picked next.
        assert_eq!(p.select_next(0.0).unwrap().0, 1);
    }
}
