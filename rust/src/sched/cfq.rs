//! Cluster Fair Queuing (CFQ) baseline — Chen et al., INFOCOM'17 (paper
//! §5.1.2, ref [8]).
//!
//! CFQ assigns each *stage* a virtual deadline from traditional 1-level
//! virtual time (`P_s = D_s`), omitting user and job context. Stages of
//! the same analytics job are therefore independent flows: a job's next
//! stage re-enters the virtual queue when submitted, which makes CFQ
//! interleave jobs stage-by-stage (the behaviour the paper highlights in
//! scenario 2, where CFQ finishes everything "only at the very end").
//!
//! Incremental index: a stage's deadline is fixed at submission, so the
//! [`StageIndex`] key `(deadline, arrival_seq)` is static and selection
//! is a pure O(log n) heap peek — and `static_keys` lets the batched
//! event core merge offers. Per-stage deadlines live in a dense
//! slot-indexed column ([`SlotCol`]), not a hash map.

use super::index::{F64Key, StageIndex};
use super::vtime::SingleVtime;
use super::{select_min_by_key, Policy, StageMeta, StageView};
use crate::core::arena::SlotCol;
use crate::{JobId, StageId};
use std::collections::HashMap;

pub struct Cfq {
    vt: SingleVtime,
    /// Stage slot → assigned virtual deadline.
    deadlines: SlotCol<f64>,
    /// Best (earliest) stage deadline seen per job — only for diagnostics.
    job_deadlines: HashMap<JobId, f64>,
    /// (deadline, arrival_seq) — stage id breaks final ties.
    index: StageIndex<(F64Key, u64)>,
}

impl Cfq {
    pub fn new(r_total: f64) -> Self {
        Cfq {
            vt: SingleVtime::new(r_total),
            deadlines: SlotCol::new(),
            job_deadlines: HashMap::new(),
            index: StageIndex::new(),
        }
    }
}

impl Policy for Cfq {
    fn name(&self) -> &'static str {
        "CFQ"
    }

    fn on_stage_submit(&mut self, now_s: f64, meta: &StageMeta) {
        let d = self.vt.arrive(now_s, meta.stage, meta.est_slot_time);
        self.deadlines.set(meta.slot, d);
        self.index.insert(
            meta.stage,
            meta.slot,
            (F64Key(d), meta.arrival_seq),
            meta.pending,
        );
        let e = self
            .job_deadlines
            .entry(meta.job)
            .or_insert(f64::INFINITY);
        *e = e.min(d);
    }

    fn on_task_launched(&mut self, stage: StageId, slot: u32) {
        self.index.task_launched(stage, slot);
    }

    fn on_tasks_launched(&mut self, stage: StageId, slot: u32, n: u32) {
        self.index.task_launched_n(stage, slot, n);
    }

    fn on_tasks_finished(&mut self, _batch: &[(StageId, u32)]) {
        // Deadlines are fixed at submission: a batch of plain finishes
        // changes nothing in the index.
    }

    fn on_task_requeued(&mut self, _now_s: f64, v: &StageView) {
        // The stage's deadline was fixed at submission; a retry re-enters
        // under the same deadline (no extra virtual-time charge).
        let d = self
            .deadlines
            .get(v.slot)
            .copied()
            .unwrap_or(f64::INFINITY);
        self.index
            .task_requeued(v.stage, v.slot, (F64Key(d), v.arrival_seq));
    }

    fn on_stage_finish(&mut self, stage: StageId, slot: u32) {
        self.deadlines.take(slot);
        self.index.remove(stage, slot);
    }

    fn static_keys(&self) -> bool {
        true
    }

    fn select_next(&mut self, _now_s: f64) -> Option<(StageId, u32)> {
        self.index.peek()
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        select_min_by_key(views, |v| {
            (
                self.deadlines
                    .get(v.slot)
                    .copied()
                    .unwrap_or(f64::INFINITY),
                v.arrival_seq,
                v.stage,
            )
        })
    }

    fn job_deadline(&self, job: JobId) -> Option<f64> {
        self.job_deadlines.get(&job).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(stage: u64, job: u64, slot_time: f64) -> StageMeta {
        StageMeta {
            stage,
            slot: stage as u32,
            job,
            user: 0,
            est_slot_time: slot_time,
            stage_idx: 0,
            arrival_seq: stage,
            pending: 1,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    fn v(stage: u64, seq: u64) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job: stage,
            user: 0,
            stage_idx: 0,
            running: 0,
            pending: 1,
            arrival_seq: seq,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    #[test]
    fn shorter_stage_gets_earlier_deadline() {
        let mut p = Cfq::new(4.0);
        p.on_stage_submit(0.0, &meta(1, 1, 10.0));
        p.on_stage_submit(0.0, &meta(2, 2, 1.0));
        let views = vec![v(1, 0), v(2, 1)];
        assert_eq!(p.select(0.0, &views), Some(1));
        assert_eq!(p.select_next(0.0), Some((2, 2)));
    }

    #[test]
    fn later_submission_pays_virtual_time() {
        // Stage A (L=2) at t=0, stage B (L=2) at t=1 (R=1, one active →
        // V(1)=1): D_A=2, D_B=3 → A first.
        let mut p = Cfq::new(1.0);
        p.on_stage_submit(0.0, &meta(1, 1, 2.0));
        p.on_stage_submit(1.0, &meta(2, 2, 2.0));
        let views = vec![v(2, 1), v(1, 0)];
        assert_eq!(p.select(1.0, &views), Some(1));
        assert_eq!(p.select_next(1.0), Some((1, 1)));
    }

    #[test]
    fn no_user_context_flooder_wins_share() {
        // One user submits 4 stages, another submits 1, all L=1 at t=0:
        // deadlines are all equal → CFQ serves them in FIFO-ish order,
        // giving the flooding user 4/5 of the service. (Contrast with the
        // UJF/UWFQ tests.)
        let mut p = Cfq::new(1.0);
        for s in 1..=4 {
            p.on_stage_submit(0.0, &meta(s, s, 1.0));
        }
        p.on_stage_submit(0.0, &meta(5, 5, 1.0));
        let views: Vec<StageView> = (1..=5).map(|s| v(s, s)).collect();
        // all deadlines equal → ties break by arrival: the flooder's first
        // stage is selected, not the single-job user's.
        assert_eq!(p.select(0.0, &views), Some(0));
        assert_eq!(p.select_next(0.0), Some((1, 1)));
    }

    #[test]
    fn stage_finish_retires_entity() {
        let mut p = Cfq::new(1.0);
        p.on_stage_submit(0.0, &meta(1, 1, 1.0));
        p.on_stage_finish(1, 1);
        let views = vec![v(1, 0)];
        // Unknown stages sort last but are still selectable (defensive).
        assert_eq!(p.select(0.0, &views), Some(0));
        // The incremental index, by contrast, no longer knows the stage.
        assert_eq!(p.select_next(0.0), None);
    }

    #[test]
    fn job_deadline_tracks_min_stage_deadline() {
        let mut p = Cfq::new(1.0);
        p.on_stage_submit(0.0, &meta(1, 7, 3.0));
        p.on_stage_submit(0.0, &meta(2, 7, 1.0));
        assert!(p.job_deadline(7).unwrap() <= 3.0);
    }

    #[test]
    fn launches_drain_pending() {
        let mut p = Cfq::new(2.0);
        let mut m = meta(1, 1, 1.0);
        m.pending = 2;
        p.on_stage_submit(0.0, &m);
        p.on_stage_submit(0.0, &meta(2, 2, 5.0));
        assert_eq!(p.select_next(0.0), Some((1, 1)));
        p.on_task_launched(1, 1);
        assert_eq!(p.select_next(0.0), Some((1, 1)));
        p.on_task_launched(1, 1);
        assert_eq!(p.select_next(0.0), Some((2, 2)));
    }

    #[test]
    fn batched_launch_drains_like_singles() {
        let mut p = Cfq::new(2.0);
        let mut m = meta(1, 1, 1.0);
        m.pending = 3;
        p.on_stage_submit(0.0, &m);
        p.on_stage_submit(0.0, &meta(2, 2, 5.0));
        p.on_tasks_launched(1, 1, 3);
        assert_eq!(p.select_next(0.0), Some((2, 2)));
    }
}
