//! Virtual-time machinery (paper §3.3, §6.1).
//!
//! * [`SingleVtime`] — classic 1-level virtual time over independent
//!   entities (WFQ/CFQ): `V(t) = ∫ R/N(t) dt`, deadline `D = V(arrival) +
//!   L`. Used by the CFQ baseline, where the entities are *stages*.
//! * [`TwoLevelVtime`] — the paper's 2-level virtual time: a *global*
//!   virtual time progressing at the per-user share rate `R/N_users`, and
//!   per-user virtual times progressing at the per-job share rate
//!   `R_user/N_jobs^k`. Implements Algorithm 1 (job deadline assignment),
//!   Algorithm 2 (`updateVirtualTime` / `getUserFinishTime` /
//!   `progressVirtualTime`) and Algorithm 3 (`updateUserVirtualTime`),
//!   plus the §4.2 grace-period user revival.
//!
//! All times are in seconds; virtual quantities are in resource-seconds
//! (core-seconds), so a job with slot-time `L` finishes in the virtual
//! schedule when its owner has received `L` core-seconds of service.
//!
//! Data structures are heap/tree-backed so every operation the paper
//! bounds to O(log N) actually is: [`SingleVtime`] retires entities from
//! a binary min-heap (the seed used a sorted `Vec` with O(n) head
//! removal), each user's virtual job set is an ordered map keyed by
//! `(D_user, job)` (O(log n) insert / pop-min / suffix iteration), and
//! the earliest-finishing user is found through a lazily-invalidated
//! min-heap over latest deadlines instead of a full scan.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

use super::index::F64Key;
use crate::{JobId, UserId};

const EPS: f64 = 1e-9;

// ---------------------------------------------------------------------------
// 1-level virtual time (WFQ / CFQ)
// ---------------------------------------------------------------------------

/// Classic virtual-time tracker for a flat set of entities with equal
/// weights (CFQ over stages).
#[derive(Debug)]
pub struct SingleVtime {
    /// Total system resources R (cores).
    pub r_total: f64,
    /// Current virtual time V(t).
    pub v: f64,
    t_prev: f64,
    /// Active entities in the *virtual* (GPS) system, as a min-heap of
    /// (deadline, id): only the earliest deadline is ever inspected.
    active: BinaryHeap<Reverse<(F64Key, u64)>>,
}

impl SingleVtime {
    pub fn new(r_total: f64) -> Self {
        assert!(r_total > 0.0);
        SingleVtime {
            r_total,
            v: 0.0,
            t_prev: 0.0,
            active: BinaryHeap::new(),
        }
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Advance V(t) to `t`, retiring entities whose deadlines pass.
    /// Piecewise integration: the rate R/N changes at each retirement.
    pub fn progress(&mut self, t: f64) {
        debug_assert!(t >= self.t_prev - EPS, "time went backwards");
        while let Some(&Reverse((F64Key(next_d), _))) = self.active.peek() {
            let n = self.active.len() as f64;
            let rate = self.r_total / n;
            // Real time at which the earliest entity retires.
            let t_reach = self.t_prev + (next_d - self.v).max(0.0) / rate;
            if t_reach > t + EPS {
                self.v += (t - self.t_prev) * rate;
                self.t_prev = t;
                return;
            }
            self.v = next_d;
            self.t_prev = t_reach;
            self.active.pop();
        }
        self.t_prev = t;
    }

    /// Entity arrival: assign and record its virtual deadline
    /// `D = V(t) + L` (unit weight).
    pub fn arrive(&mut self, t: f64, id: u64, slot: f64) -> f64 {
        self.progress(t);
        let d = self.v + slot.max(0.0);
        self.active.push(Reverse((F64Key(d), id)));
        d
    }
}

// ---------------------------------------------------------------------------
// 2-level virtual time (UWFQ)
// ---------------------------------------------------------------------------

/// A job inside a user's virtual job set `S_jobs^k`.
#[derive(Clone, Copy, Debug)]
pub struct VJob {
    pub job: JobId,
    /// Slot-time `L_i` (estimated, seconds of sequential work).
    pub slot: f64,
    /// User-level virtual deadline `D_user^i`.
    pub d_user: f64,
    /// Global virtual deadline `D_global^i` (reassigned on each arrival of
    /// the same user, Algorithm 1 phase 3).
    pub d_global: f64,
}

/// Per-user state `U_k` in the virtual fair system.
#[derive(Clone, Debug, Default)]
pub struct VUser {
    /// User virtual time `V_user^k`.
    pub v_user: f64,
    /// Virtual arrival time `V_arrival^k` (global virtual-time units),
    /// advanced by `L_i·U_w` as jobs virtually finish (Alg. 3 l.16–17).
    pub v_arrival: f64,
    /// `U_w` — user weight (1 = equal priority).
    pub weight: f64,
    /// `S_jobs^k`, ordered by `(d_user, job)`. `d_global` is monotone
    /// non-decreasing in this order (deadlines telescope from
    /// `V_arrival^k`), so the last entry carries the latest deadline.
    pub jobs: BTreeMap<(F64Key, JobId), VJob>,
}

impl VUser {
    /// `getLatestDeadline` — the user's last job's global deadline.
    /// O(log n): d_global is monotone in the job-set order.
    fn latest_deadline(&self) -> f64 {
        self.jobs
            .values()
            .next_back()
            .map(|j| j.d_global)
            .unwrap_or(f64::NEG_INFINITY)
    }
}

/// Exit record for the §4.2 grace period: a user who left the virtual
/// system can be revived "with their original arrival time" if
/// `V_global < V_global_end^k + T_grace · R`.
#[derive(Clone, Copy, Debug)]
pub struct ExitRecord {
    pub v_arrival: f64,
    pub v_user: f64,
    /// Global virtual end time `V^k_{global,end}` (their last deadline).
    pub v_global_end: f64,
}

#[derive(Debug)]
pub struct TwoLevelVtime {
    /// Total system resources `R` (cores).
    pub r_total: f64,
    /// Global virtual time `V_global`.
    pub v_global: f64,
    /// Previous update time `T_previous` (real seconds).
    pub t_previous: f64,
    /// Active users `S_users`.
    pub users: HashMap<UserId, VUser>,
    /// Grace-period graveyard (§4.2).
    pub exited: HashMap<UserId, ExitRecord>,
    /// Assigned global deadlines per job — persists after virtual finish,
    /// because stage priority `P_s = D_global^i` is fixed (§4.1.1).
    pub deadlines: HashMap<JobId, f64>,
    /// Jobs whose `D_global` was (re)written by the most recent
    /// [`TwoLevelVtime::job_arrival`] — Algorithm 1 phase 3 rewrites a
    /// suffix of the user's job set, and incremental schedulers (UWFQ's
    /// stage index) re-key exactly these. Includes the arriving job.
    pub last_changed: Vec<(JobId, f64)>,
    /// Lazy min-heap over users by latest global deadline — Algorithm 2's
    /// earliest-finishing-user query without a full user scan. A fresh
    /// entry is pushed on every key *decrease* (job-set drained to empty →
    /// `NEG_INFINITY`) and on arrival; stale entries are re-keyed when
    /// they surface (the same invalidation contract as
    /// [`crate::sched::index::StageIndex`]).
    user_heap: BinaryHeap<Reverse<(F64Key, UserId)>>,
    /// Reusable scratch for `progress_virtual_time`'s drained-user pass
    /// (it runs on every Algorithm-1 call — no per-call allocation).
    drained_buf: Vec<UserId>,
}

impl TwoLevelVtime {
    pub fn new(r_total: f64) -> Self {
        assert!(r_total > 0.0);
        TwoLevelVtime {
            r_total,
            v_global: 0.0,
            t_previous: 0.0,
            users: HashMap::new(),
            exited: HashMap::new(),
            deadlines: HashMap::new(),
            last_changed: Vec::new(),
            user_heap: BinaryHeap::new(),
            drained_buf: Vec::new(),
        }
    }

    /// **Algorithm 1** — job deadline assignment under UWFQ.
    ///
    /// Returns the job's global virtual deadline `D_global^i`.
    /// `grace_rsec` is the §4.2 grace period in resource-seconds.
    pub fn job_arrival(
        &mut self,
        t_current: f64,
        user: UserId,
        job: JobId,
        slot: f64,
        weight: f64,
        grace_rsec: f64,
    ) -> f64 {
        // Phase 1: update system.
        self.update_virtual_time(t_current);
        if !self.users.contains_key(&user) {
            // §4.2: revive a recently exited user with their original
            // (progressed) virtual arrival time, else admit fresh.
            let revived = self.exited.get(&user).copied().filter(|ex| {
                self.v_global < ex.v_global_end + grace_rsec * self.r_total
            });
            let st = match revived {
                Some(ex) => VUser {
                    v_user: ex.v_user,
                    v_arrival: ex.v_arrival,
                    weight,
                    jobs: BTreeMap::new(),
                },
                None => VUser {
                    v_user: 0.0,
                    v_arrival: self.v_global,
                    weight,
                    jobs: BTreeMap::new(),
                },
            };
            self.exited.remove(&user);
            self.users.insert(user, st);
        }

        // Phase 2: user deadline; insert into S_jobs^k (ordered by
        // (d_user, job) — unique, jobs never re-arrive).
        let u = self.users.get_mut(&user).unwrap();
        u.weight = weight;
        let d_user = u.v_user + slot * u.weight;
        let key = (F64Key(d_user), job);
        u.jobs.insert(
            key,
            VJob {
                job,
                slot,
                d_user,
                d_global: 0.0,
            },
        );

        // Phase 3: (re)assign global virtual deadlines for the user's
        // active jobs, sequentially from V_arrival^k. Jobs *before* the
        // insertion point telescope to the same deadlines as before, so
        // only the suffix starting at the new job needs rewriting —
        // O(log n) for in-order arrivals instead of O(jobs/user) (hot
        // path; equivalent to the paper's full phase-3 loop).
        let mut d_prev = u
            .jobs
            .range(..key)
            .next_back()
            .map(|(_, j)| j.d_global)
            .unwrap_or(u.v_arrival);
        let weight = u.weight;
        let mut out = 0.0;
        self.last_changed.clear();
        for (_, j) in u.jobs.range_mut(key..) {
            d_prev += j.slot * weight;
            j.d_global = d_prev;
            self.deadlines.insert(j.job, d_prev);
            self.last_changed.push((j.job, d_prev));
            if j.job == job {
                out = d_prev;
            }
        }
        // The user's latest deadline moved — (re)key the user heap.
        self.user_heap.push(Reverse((F64Key(d_prev), user)));
        out
    }

    /// `getJobDeadline` — assigned priority of a job (`P_s = D_global^i`).
    pub fn job_deadline(&self, job: JobId) -> Option<f64> {
        self.deadlines.get(&job).copied()
    }

    /// Valid minimum of the user heap: the earliest-finishing user and
    /// its latest global deadline.
    fn earliest_finishing_user(&mut self) -> Option<(UserId, f64)> {
        while let Some(&Reverse((F64Key(d), uid))) = self.user_heap.peek() {
            match self.users.get(&uid) {
                None => {
                    self.user_heap.pop();
                }
                Some(u) => {
                    let cur = u.latest_deadline();
                    if F64Key(cur) == F64Key(d) {
                        return Some((uid, d));
                    }
                    self.user_heap.pop();
                    self.user_heap.push(Reverse((F64Key(cur), uid)));
                }
            }
        }
        None
    }

    /// **Algorithm 2** — `updateVirtualTime(T_current)`.
    pub fn update_virtual_time(&mut self, t_current: f64) {
        // Users leave in the order of their latest global deadlines.
        loop {
            if self.users.is_empty() {
                self.t_previous = self.t_previous.max(t_current);
                return;
            }
            let r_user = self.r_total / self.users.len() as f64;
            let (uid, v_global_end) = self
                .earliest_finishing_user()
                .expect("non-empty user set has a heap entry");
            let t_finish = self.user_finish_time(uid, r_user);
            if t_finish > t_current + EPS {
                break;
            }
            // The user leaves: progress everyone to its finish time at the
            // pre-departure share, then remove it and recompute shares.
            self.progress_virtual_time(t_finish, r_user);
            let left = self.users.remove(&uid).unwrap();
            self.exited.insert(
                uid,
                ExitRecord {
                    v_arrival: left.v_arrival,
                    v_user: left.v_user,
                    v_global_end,
                },
            );
        }
        let r_user = self.r_total / self.users.len() as f64;
        self.progress_virtual_time(t_current, r_user);
    }

    /// `getUserFinishTime(U, R_user)` — real time at which the user's last
    /// job finishes under the current share.
    fn user_finish_time(&self, user: UserId, r_user: f64) -> f64 {
        let d_latest = self.users[&user].latest_deadline();
        let t_spent = (d_latest - self.v_global) / r_user;
        self.t_previous + t_spent
    }

    /// `progressVirtualTime(T, R_user)`.
    fn progress_virtual_time(&mut self, t: f64, r_user: f64) {
        let t_passed = (t - self.t_previous).max(0.0);
        self.v_global += t_passed * r_user;
        let t_previous = self.t_previous;
        let mut drained = std::mem::take(&mut self.drained_buf);
        drained.clear();
        for (&uid, u) in self.users.iter_mut() {
            if update_user_virtual_time(u, t_previous, r_user, t) {
                drained.push(uid);
            }
        }
        // A drained job set drops the user's latest deadline to
        // `NEG_INFINITY` — a key *decrease*, which the lazy heap must see
        // as a fresh entry or `earliest_finishing_user` could surface a
        // non-minimal user (leaving the drained user as a ghost inflating
        // the share denominator).
        for &uid in &drained {
            self.user_heap
                .push(Reverse((F64Key(f64::NEG_INFINITY), uid)));
        }
        self.drained_buf = drained;
        self.t_previous = self.t_previous.max(t);
    }
}

/// **Algorithm 3** — `updateUserVirtualTime(U_k, R_user, T_current)`.
/// Free function (not a method) so `progressVirtualTime` can iterate the
/// user map mutably without collecting keys — this is on the Algorithm-1
/// hot path. Returns `true` when this update drained the user's job set
/// (its latest deadline just dropped to `NEG_INFINITY` — the caller must
/// refresh the lazy user heap).
fn update_user_virtual_time(u: &mut VUser, t_previous: f64, r_user: f64, t_current: f64) -> bool {
    let mut t_prev_user = t_previous;
    let mut v_user = u.v_user;
    let mut retired_any = false;

    // Retire jobs whose user-level deadlines pass, in d_user order
    // (= job-set order): each retirement is a pop-min.
    while let Some(head) = u.jobs.values().next().copied() {
        let r_job = r_user / u.jobs.len() as f64;
        let t_passed = (t_current - t_prev_user).max(0.0);
        let v_test = v_user + t_passed * r_job;
        if head.d_user > v_test + EPS {
            break;
        }
        let v_spent = (head.d_user - v_user).max(0.0);
        let t_spent = v_spent / r_job;
        v_user += v_spent;
        t_prev_user += t_spent;
        // Progress virtual arrival so future global deadlines account
        // for virtually finished jobs (Alg. 3 l.16–17).
        u.v_arrival += head.slot * u.weight;
        u.jobs.pop_first();
        retired_any = true;
    }
    // Catch the user's virtual time up to T_current.
    if !u.jobs.is_empty() {
        let r_job = r_user / u.jobs.len() as f64;
        let t_spent = (t_current - t_prev_user).max(0.0);
        v_user += t_spent * r_job;
    }
    u.v_user = v_user;
    retired_any && u.jobs.is_empty()
}

impl TwoLevelVtime {
    /// Number of users active in the *virtual* system.
    pub fn active_users(&self) -> usize {
        self.users.len()
    }

    /// Jobs active in the virtual system across all users.
    pub fn active_jobs(&self) -> usize {
        self.users.values().map(|u| u.jobs.len()).sum()
    }

    // -----------------------------------------------------------------
    // Federated sharding (sync-barrier protocol)
    // -----------------------------------------------------------------

    /// Advance the virtual system to the sync-barrier instant `t_bar_s`
    /// and report `(active_users, v_global)` — one shard's contribution
    /// to the population-wide reference. Safe to call at any instant the
    /// driver has fully processed (it is Algorithm 2, the same update a
    /// job arrival at `t_bar_s` would perform first).
    pub fn sync_snapshot(&mut self, t_bar_s: f64) -> (usize, f64) {
        self.update_virtual_time(t_bar_s);
        (self.users.len(), self.v_global)
    }

    /// Re-couple this shard to the population at a sync barrier:
    /// level-set `v_global` to the user-count-weighted population
    /// reference `v_ref` and re-derive the shard's share of the cluster
    /// rate (`r_total = R_cluster · n_shard / n_population`). Call only
    /// right after [`TwoLevelVtime::sync_snapshot`] at the same barrier
    /// instant, so every pending departure up to the barrier has been
    /// applied under the *old* rate first.
    ///
    /// Level-setting every epoch is what bounds cross-shard drift
    /// without accumulation: each epoch restarts from the common
    /// `v_ref`, and within one epoch a shard advances `v_global` by at
    /// most `r_total · epoch ≤ R_cluster · epoch` resource-seconds, so
    /// the pre-sync spread never exceeds one epoch of service at the
    /// cluster rate. Nothing downstream assumes `v_global` is monotone
    /// across barriers: deadlines telescope from per-user state
    /// (`v_arrival`/`d_global` chains), and `t_previous` is real-time
    /// based and untouched.
    ///
    /// A shard with no active users keeps its previous `r_total` (any
    /// positive rate ≤ R_cluster preserves the bound; the rate only
    /// matters again once a user arrives, and the next barrier re-derives
    /// it).
    pub fn recouple(&mut self, v_ref: f64, r_cluster: f64, n_shard: usize, n_population: usize) {
        debug_assert!(r_cluster > 0.0 && n_population > 0);
        self.v_global = v_ref;
        if n_shard > 0 {
            let r = r_cluster * n_shard as f64 / n_population as f64;
            assert!(r > 0.0, "recoupled rate must stay positive");
            self.r_total = r;
        }
    }

    /// Re-couple to an explicit shard rate — the core-lending variant of
    /// [`TwoLevelVtime::recouple`]. Under cross-shard lending the shard's
    /// capacity is its *lent* core allocation, not the population-share
    /// rescale, so the caller passes the allocation directly. Same
    /// level-set semantics and the same empty-shard guard: a
    /// non-positive rate keeps the previous one (the rate only matters
    /// again once a user arrives, and the next barrier re-derives it).
    /// The drift bound survives because the rebalancer conserves the
    /// total: Σ r_shard = R_cluster, so within one epoch the population
    /// still advances by at most `R_cluster · epoch` resource-seconds.
    pub fn recouple_to_rate(&mut self, v_ref: f64, r_shard: f64) {
        self.v_global = v_ref;
        if r_shard > 0.0 {
            self.r_total = r_shard;
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6
    }

    // ---- SingleVtime ----

    #[test]
    fn single_vtime_rate_scales_with_entities() {
        let mut v = SingleVtime::new(4.0);
        v.arrive(0.0, 1, 100.0); // far deadline, stays active
        v.progress(1.0);
        assert!(close(v.v, 4.0)); // one entity: rate 4
        v.arrive(1.0, 2, 100.0);
        v.progress(2.0);
        assert!(close(v.v, 6.0)); // two entities: rate 2
    }

    #[test]
    fn single_vtime_deadline_is_gps_finish() {
        // R=2, entity A (L=2) at t=0; B (L=2) at t=0. Each gets rate 1 →
        // both finish at t=2; deadlines equal V(0)+2 = 2.
        let mut v = SingleVtime::new(2.0);
        let da = v.arrive(0.0, 1, 2.0);
        let db = v.arrive(0.0, 2, 2.0);
        assert!(close(da, 2.0) && close(db, 2.0));
        v.progress(2.0);
        assert_eq!(v.active_len(), 0); // both retired exactly at t=2
        assert!(close(v.v, 2.0));
    }

    #[test]
    fn single_vtime_retirement_changes_rate() {
        // R=2: A (L=1) and B (L=3) at t=0 → shares 1 each.
        // A retires at t=1 (V=1); then B alone at rate 2 reaches D_B=3 at
        // t=2.
        let mut v = SingleVtime::new(2.0);
        v.arrive(0.0, 1, 1.0);
        v.arrive(0.0, 2, 3.0);
        v.progress(1.5);
        assert_eq!(v.active_len(), 1);
        assert!(close(v.v, 2.0)); // V(1)=1 then rate 2 for 0.5s
        v.progress(2.0);
        assert_eq!(v.active_len(), 0);
        assert!(close(v.v, 3.0));
    }

    #[test]
    fn single_vtime_idle_freezes() {
        let mut v = SingleVtime::new(4.0);
        v.arrive(0.0, 1, 1.0);
        v.progress(10.0); // retires at t=0.25, V frozen at 1 after
        assert!(close(v.v, 1.0));
        let d = v.arrive(20.0, 2, 2.0);
        assert!(close(d, 3.0));
    }

    #[test]
    fn single_vtime_heap_retires_in_deadline_order() {
        // Out-of-order deadline arrivals retire earliest-first, exercising
        // the heap (the seed kept a sorted Vec).
        let mut v = SingleVtime::new(1.0);
        v.arrive(0.0, 1, 5.0);
        v.arrive(0.0, 2, 1.0);
        v.arrive(0.0, 3, 3.0);
        // Rates: 3 entities → 1/3 each. Entity 2 (D=1) retires first.
        v.progress(3.0); // V(3) = 1 exactly → entity 2 retires
        assert_eq!(v.active_len(), 2);
        v.progress(100.0);
        assert_eq!(v.active_len(), 0);
        assert!(close(v.v, 5.0));
    }

    // ---- TwoLevelVtime: the worked examples from the design notes ----

    #[test]
    fn alg1_deadlines_match_ujf_gps() {
        // R=4. u1 submits j1 (L=8) at t=0 → D_global=8.
        // u2 submits j2 (L=4) at t=1 → V_global(1)=4, D_global=8.
        // Under user-level GPS both finish at t=3 — equal deadlines.
        let mut vt = TwoLevelVtime::new(4.0);
        let d1 = vt.job_arrival(0.0, 1, 101, 8.0, 1.0, 0.0);
        assert!(close(d1, 8.0));
        let d2 = vt.job_arrival(1.0, 2, 201, 4.0, 1.0, 0.0);
        assert!(close(vt.v_global, 4.0));
        assert!(close(d2, 8.0));
        // u1's user virtual time progressed at the full user share (4).
        assert!(close(vt.users[&1].v_user, 4.0));
    }

    #[test]
    fn alg2_user_departure_redistributes_share() {
        // R=4. u1: j1 L=4; u2: j2 L=8, both at t=0.
        // D_global: j1=4, j2=8. GPS: u1 done t=2, then u2 alone till t=3.
        let mut vt = TwoLevelVtime::new(4.0);
        let d1 = vt.job_arrival(0.0, 1, 1, 4.0, 1.0, 0.0);
        let d2 = vt.job_arrival(0.0, 2, 2, 8.0, 1.0, 0.0);
        assert!(close(d1, 4.0) && close(d2, 8.0));
        // At t=3 both users should have left the virtual system.
        vt.update_virtual_time(3.0);
        assert_eq!(vt.active_users(), 0);
        assert!(close(vt.v_global, 8.0));
        // Deadlines persist for scheduling.
        assert!(close(vt.job_deadline(1).unwrap(), 4.0));
        assert!(close(vt.job_deadline(2).unwrap(), 8.0));
    }

    #[test]
    fn within_user_jobs_sequential_in_global_deadlines() {
        // One user, two jobs L=2 and L=6 at t=0 → D_global 2 and 8:
        // the user's jobs are sequenced, not interleaved (§3.3).
        let mut vt = TwoLevelVtime::new(2.0);
        let da = vt.job_arrival(0.0, 1, 1, 2.0, 1.0, 0.0);
        let db = vt.job_arrival(0.0, 1, 2, 6.0, 1.0, 0.0);
        assert!(close(da, 2.0));
        assert!(close(db, 8.0));
    }

    #[test]
    fn shorter_later_job_can_overtake_within_user() {
        // u1 submits jA L=10 at t=0 (D_user=10). At t=1 (R=2, single user:
        // v_user rate = 2) v_user=2; jB L=2 → D_user=4 < 10 → B sequences
        // first in the user's virtual order, so B gets the earlier global
        // deadline and A's global deadline is pushed back.
        let mut vt = TwoLevelVtime::new(2.0);
        let da0 = vt.job_arrival(0.0, 1, 1, 10.0, 1.0, 0.0);
        assert!(close(da0, 10.0));
        let db = vt.job_arrival(1.0, 1, 2, 2.0, 1.0, 0.0);
        let da1 = vt.job_deadline(1).unwrap();
        assert!(db < da1, "short job must overtake: {db} vs {da1}");
        assert!(close(db, 2.0)); // v_arrival(0) + 2
        assert!(close(da1, 12.0)); // pushed behind B
        // Phase 3 reported both rewritten deadlines (overtaken suffix).
        assert_eq!(vt.last_changed.len(), 2);
        assert_eq!(vt.last_changed[0].0, 2);
        assert_eq!(vt.last_changed[1].0, 1);
    }

    #[test]
    fn alg3_retires_jobs_and_advances_arrival() {
        // Single user, R=1. j1 L=1 at t=0. By t=2 it has virtually
        // finished; a new job j2 L=1 then gets D_global measured after j1.
        let mut vt = TwoLevelVtime::new(1.0);
        vt.job_arrival(0.0, 1, 1, 1.0, 1.0, 0.0);
        vt.update_virtual_time(2.0);
        // user left at t=1 (finished all jobs)
        assert_eq!(vt.active_users(), 0);
        // revive within grace: arrival should be the *progressed* one (1.0)
        let d2 = vt.job_arrival(2.0, 1, 2, 1.0, 1.0, 10.0);
        assert!(close(d2, 2.0), "v_arrival advanced by L1: D=1+1, got {d2}");
    }

    #[test]
    fn grace_period_expired_user_rejoins_fresh() {
        let mut vt = TwoLevelVtime::new(1.0);
        vt.job_arrival(0.0, 1, 1, 1.0, 1.0, 0.0);
        // Another user keeps virtual time moving far past u1's end.
        vt.job_arrival(0.0, 2, 2, 100.0, 1.0, 0.0);
        vt.update_virtual_time(50.0);
        // u1 ended at v_global_end=1; grace 2 rsec · R=1 → revive only if
        // v_global < 3. v_global(50) is way past — fresh arrival.
        let d = vt.job_arrival(50.0, 1, 3, 1.0, 1.0, 2.0);
        let fresh_expected = vt.v_global; // arrival pinned at current v_global
        assert!(close(d, fresh_expected + 1.0 - 1.0 + 1.0) || d > 3.0);
        assert!(d > 3.0, "must not keep the stale early deadline: {d}");
    }

    #[test]
    fn grace_period_revives_recent_user() {
        // R=2. u1 finishes early, comes back quickly: revived with
        // progressed arrival → keeps continuity instead of jumping to
        // v_global.
        let mut vt = TwoLevelVtime::new(2.0);
        vt.job_arrival(0.0, 1, 1, 1.0, 1.0, 2.0);
        vt.job_arrival(0.0, 2, 2, 8.0, 1.0, 2.0);
        // u1 virtually done at t=1 (share 1); revive at t=1.5 within grace
        // (v_global_end=1, grace 2·2=4 → revive while v_global < 5).
        let d = vt.job_arrival(1.5, 1, 3, 1.0, 1.0, 2.0);
        // v_arrival progressed to 1 → D = 1 + 1 = 2, NOT v_global(1.5)+1.
        assert!(close(d, 2.0), "revived deadline should be 2, got {d}");
    }

    #[test]
    fn vtime_monotone_under_random_arrivals() {
        use crate::util::Rng;
        let mut rng = Rng::new(99);
        let mut vt = TwoLevelVtime::new(8.0);
        let mut t = 0.0;
        let mut last_v = 0.0;
        for i in 0..500 {
            t += rng.exp(2.0);
            let user = rng.below(6) as UserId;
            let slot = 0.1 + rng.f64() * 5.0;
            vt.job_arrival(t, user, i, slot, 1.0, 2.0);
            assert!(vt.v_global >= last_v - 1e-9, "v_global regressed");
            assert!(vt.t_previous <= t + 1e-9);
            last_v = vt.v_global;
            // Per-user jobs stay ordered by d_user, and d_global is
            // monotone along that order (latest_deadline's invariant).
            for u in vt.users.values() {
                let jobs: Vec<&VJob> = u.jobs.values().collect();
                for w in jobs.windows(2) {
                    assert!(w[0].d_user <= w[1].d_user + 1e-9);
                    assert!(w[0].d_global <= w[1].d_global + 1e-9);
                }
            }
        }
    }

    #[test]
    fn sync_snapshot_matches_plain_update() {
        // The barrier snapshot is Algorithm 2 verbatim: same v_global and
        // user count as calling update_virtual_time directly.
        let mut a = TwoLevelVtime::new(4.0);
        let mut b = TwoLevelVtime::new(4.0);
        for vt in [&mut a, &mut b] {
            vt.job_arrival(0.0, 1, 1, 8.0, 1.0, 0.0);
            vt.job_arrival(0.5, 2, 2, 3.0, 1.0, 0.0);
        }
        let (n, v) = a.sync_snapshot(1.25);
        b.update_virtual_time(1.25);
        assert_eq!(n, b.active_users());
        assert_eq!(v.to_bits(), b.v_global.to_bits());
    }

    #[test]
    fn recouple_levels_vglobal_and_rescales_rate() {
        let mut vt = TwoLevelVtime::new(8.0);
        vt.job_arrival(0.0, 1, 1, 4.0, 1.0, 0.0);
        vt.job_arrival(0.0, 2, 2, 4.0, 1.0, 0.0);
        let (n, _v) = vt.sync_snapshot(0.5);
        assert_eq!(n, 2);
        // Population of 8 users across all shards, cluster rate 16: this
        // shard's share is 16·2/8 = 4.
        vt.recouple(3.0, 16.0, n, 8);
        assert_eq!(vt.v_global.to_bits(), 3.0f64.to_bits());
        assert!(close(vt.r_total, 4.0));
        // Deadline assignment keeps working after a backward level-set: a
        // fresh user anchors at the recoupled v_global.
        let d = vt.job_arrival(0.5, 3, 3, 2.0, 1.0, 0.0);
        assert!(d >= 3.0, "deadline telescopes from recoupled v_ref: {d}");
    }

    #[test]
    fn recouple_empty_shard_keeps_positive_rate() {
        let mut vt = TwoLevelVtime::new(4.0);
        vt.job_arrival(0.0, 1, 1, 0.5, 1.0, 0.0);
        // By t=1 the user has left the virtual system.
        let (n, _v) = vt.sync_snapshot(1.0);
        assert_eq!(n, 0);
        vt.recouple(7.0, 16.0, n, 5);
        assert_eq!(vt.v_global.to_bits(), 7.0f64.to_bits());
        assert!(vt.r_total > 0.0, "empty shard keeps its previous rate");
        assert!(close(vt.r_total, 4.0));
        // And it can admit users again afterwards.
        let d = vt.job_arrival(1.5, 9, 9, 1.0, 1.0, 0.0);
        assert!(d > 7.0);
    }

    #[test]
    fn recouple_to_rate_sets_lent_allocation() {
        let mut vt = TwoLevelVtime::new(8.0);
        vt.job_arrival(0.0, 1, 1, 4.0, 1.0, 0.0);
        let (_n, _v) = vt.sync_snapshot(0.5);
        // The shard was lent 12 of the cluster's cores.
        vt.recouple_to_rate(5.0, 12.0);
        assert_eq!(vt.v_global.to_bits(), 5.0f64.to_bits());
        assert!(close(vt.r_total, 12.0));
        // Non-positive rates keep the previous allocation.
        vt.recouple_to_rate(6.0, 0.0);
        assert_eq!(vt.v_global.to_bits(), 6.0f64.to_bits());
        assert!(close(vt.r_total, 12.0));
    }

    #[test]
    fn deadlines_respect_user_share_not_job_count() {
        // User A floods 10 jobs of L=1 at t=0; user B submits one L=1 job
        // at t=0. B's deadline must be comparable to A's FIRST job, not
        // queued behind all ten (the paper's core fairness claim).
        let mut vt = TwoLevelVtime::new(2.0);
        for j in 0..10 {
            vt.job_arrival(0.0, 1, j, 1.0, 1.0, 0.0);
        }
        let db = vt.job_arrival(0.0, 2, 100, 1.0, 1.0, 0.0);
        let da_first = vt.job_deadline(0).unwrap();
        let da_last = vt.job_deadline(9).unwrap();
        assert!(close(db, da_first), "{db} vs {da_first}");
        assert!(da_last > 9.0 * db - 1e-6);
    }
}
