//! Scheduling policies.
//!
//! A [`Policy`] observes job/stage lifecycle events and, at every task
//! launch opportunity, selects which runnable stage gets the freed core —
//! the equivalent of Spark sorting the Root Pool on each resource offer
//! (§2.1.1 step 5). Stages carry their analytics-job context (§3.1) so
//! policies can schedule at job/user granularity.
//!
//! Selection is **incremental**: lifecycle notifications
//! ([`Policy::on_stage_submit`], [`Policy::on_task_launched`],
//! [`Policy::on_task_finished`], [`Policy::on_stage_finish`]) let each
//! policy maintain its own priority index (see [`index`]), and
//! [`Policy::select_next`] answers in O(log n). The snapshot-scan
//! [`Policy::select`] is retained as the reference semantics: the engine
//! cross-checks both paths under `debug_assertions`, and the differential
//! test in [`crate::sim`] asserts schedule equivalence end to end.
//!
//! Every lifecycle hook carries the stage's **arena slot** next to its
//! id: policy side state lives in dense slot-indexed columns
//! ([`crate::core::arena::SlotCol`], [`index::StageIndex`]) rather than
//! hash maps, so the hot path never hashes. The batched event core adds
//! two coalesced hooks — [`Policy::on_tasks_finished`] (one call for a
//! same-timestamp batch of clean finishes) and
//! [`Policy::on_tasks_launched`] (one call for a multi-launch run on
//! one stage) — whose defaults replay the per-event hooks in order, so
//! per-event and batched notification are observationally identical by
//! construction. Policies whose selection keys ignore running counts
//! declare [`Policy::static_keys`] so the engine can additionally merge
//! same-timestamp launch offers.

pub mod bopf;
pub mod cfq;
pub mod drf;
pub mod fair;
pub mod fifo;
pub mod index;
pub mod ujf;
pub mod uwfq;
pub mod vtime;

use crate::core::task::ResourceVec;
use crate::{JobId, StageId, UserId};

/// Job-level metadata given to the policy when an analytics job arrives.
#[derive(Clone, Debug)]
pub struct JobMeta {
    pub job: JobId,
    pub user: UserId,
    /// UWFQ user weight `U_w`.
    pub weight: f64,
    /// Estimated job slot-time `L_i` in seconds (total across stages) —
    /// from the runtime estimator, perfect under the oracle.
    pub est_slot_time: f64,
    /// Monotone submission sequence number.
    pub arrival_seq: u64,
}

/// Stage-level metadata on stage submission: deadline assignment inputs
/// (CFQ) plus everything a policy needs to key its priority index without
/// ever consulting engine state again.
#[derive(Clone, Debug)]
pub struct StageMeta {
    pub stage: StageId,
    /// Engine arena slot of the stage — the dense address policies key
    /// their side columns on (valid until `on_stage_finish`).
    pub slot: u32,
    pub job: JobId,
    pub user: UserId,
    pub est_slot_time: f64,
    /// Index of this stage within its job's stage list (FIFO tiebreak).
    pub stage_idx: usize,
    /// Arrival sequence of the owning job (FIFO tiebreak).
    pub arrival_seq: u64,
    /// Launchable tasks at submission time (initial pending count).
    pub pending: u32,
    /// Per-task resource demand (unit on every legacy workload) —
    /// multi-resource policies (DRF/BoPF) key shares on this.
    pub demand: ResourceVec,
}

/// Snapshot of a live stage at selection time.
#[derive(Clone, Debug)]
pub struct StageView {
    pub stage: StageId,
    /// Engine arena slot of the stage (see [`StageMeta::slot`]).
    pub slot: u32,
    pub job: JobId,
    pub user: UserId,
    pub stage_idx: usize,
    pub running: u32,
    pub pending: u32,
    /// Arrival sequence of the owning job.
    pub arrival_seq: u64,
    /// Per-task resource demand (see [`StageMeta::demand`]).
    pub demand: ResourceVec,
}

/// A scheduling policy. All engine times are seconds (f64).
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// An analytics job arrived (all of its stages are known; deadline
    /// assignment for UWFQ happens here, per §4.1.1).
    fn on_job_arrival(&mut self, _now_s: f64, _meta: &JobMeta) {}

    /// A stage of an already-arrived job was submitted to the task
    /// scheduler (its dependencies finished).
    fn on_stage_submit(&mut self, _now_s: f64, _meta: &StageMeta) {}

    /// One task of `stage` was launched (running += 1, pending −= 1).
    /// Fired by the engine immediately after every launch so the policy's
    /// index tracks counts without snapshots.
    fn on_task_launched(&mut self, _stage: StageId, _slot: u32) {}

    /// `n` tasks of `stage` were launched back-to-back in one offer (the
    /// batched core's multi-launch quantum for [`Policy::static_keys`]
    /// policies). The default replays [`Policy::on_task_launched`] `n`
    /// times — the executable spec of the coalesced form.
    fn on_tasks_launched(&mut self, stage: StageId, slot: u32, n: u32) {
        for _ in 0..n {
            self.on_task_launched(stage, slot);
        }
    }

    /// One running task of `stage` finished (running −= 1). Fired before
    /// `on_stage_finish` when it was the stage's last task.
    fn on_task_finished(&mut self, _stage: StageId, _slot: u32) {}

    /// A same-timestamp batch of plain (non-completing) task finishes,
    /// in event order. The batched event core defers per-finish
    /// notifications and delivers them in one call right before the
    /// next policy interaction; the default replays
    /// [`Policy::on_task_finished`] in order — the executable spec —
    /// and policies override it to coalesce (one re-key per run of
    /// same-stage finishes) or to skip it entirely when their keys
    /// don't depend on running counts.
    fn on_tasks_finished(&mut self, batch: &[(StageId, u32)]) {
        for &(stage, slot) in batch {
            self.on_task_finished(stage, slot);
        }
    }

    /// One running task of `stage` failed (fault injection): running −= 1
    /// but the stage is **not** complete — the task will be requeued
    /// after its retry backoff. For every policy in this crate the index
    /// bookkeeping is identical to a task finishing on a stage with work
    /// left, so the default delegates; a policy whose `on_task_finished`
    /// ever does completion-specific work must override this.
    fn on_task_failed(&mut self, stage: StageId, slot: u32) {
        self.on_task_finished(stage, slot);
    }

    /// A failed task re-entered its stage's queue after backoff
    /// (pending += 1). The stage may have left the policy's index when
    /// it exhausted its pending tasks, so the view carries everything
    /// needed to re-key it.
    fn on_task_requeued(&mut self, _now_s: f64, _view: &StageView) {}

    /// A stage completed all of its tasks (pool-tree maintenance). The
    /// slot is about to be recycled — policies must drop their
    /// slot-keyed side state here.
    fn on_stage_finish(&mut self, _stage: StageId, _slot: u32) {}

    /// All stages of a job finished.
    fn on_job_finish(&mut self, _now_s: f64, _job: JobId) {}

    /// True when this policy's selection keys never change while a
    /// stage sits in the index (no running-count or load terms — FIFO,
    /// CFQ, UWFQ). The batched event core uses this to merge
    /// same-timestamp launch offers and run multi-launch quanta; the
    /// per-event differential validates the claim end to end.
    fn static_keys(&self) -> bool {
        false
    }

    /// Incremental selection: the highest-priority stage with pending
    /// tasks according to the policy's own index, in O(log n), returned
    /// with its arena slot so the engine skips the id→slot map on the
    /// launch path. Must agree with [`Policy::select`] over the
    /// engine's live stages — the engine asserts this under
    /// `debug_assertions`.
    fn select_next(&mut self, now_s: f64) -> Option<(StageId, u32)>;

    /// Reference snapshot-scan selection: pick the stage (index into
    /// `views`) to launch one task from. Must return a view with
    /// `pending > 0`, or `None`. O(views) — kept as the executable
    /// specification for `select_next` (debug cross-check + differential
    /// tests), not used on the release hot path.
    fn select(&mut self, now_s: f64, views: &[StageView]) -> Option<usize>;

    /// The job's assigned global virtual deadline, if this policy uses
    /// deadlines (diagnostics + ablation benches).
    fn job_deadline(&self, _job: JobId) -> Option<f64> {
        None
    }

    /// Mutable access to the policy's 2-level virtual system, when it
    /// has one (UWFQ). The sharded engine re-couples each shard's
    /// `v_global`/`r_total` to the population-wide reference at sync
    /// barriers through this hook; policies without virtual-time state
    /// return `None` and shards run fully decoupled.
    fn vtime_mut(&mut self) -> Option<&mut vtime::TwoLevelVtime> {
        None
    }
}

/// Select the view minimizing `key` among views with pending work —
/// shared helper for deadline/counter-based policies.
pub fn select_min_by_key<K: PartialOrd>(
    views: &[StageView],
    mut key: impl FnMut(&StageView) -> K,
) -> Option<usize> {
    let mut best: Option<(usize, K)> = None;
    for (i, v) in views.iter().enumerate() {
        if v.pending == 0 {
            continue;
        }
        let k = key(v);
        match &best {
            None => best = Some((i, k)),
            Some((_, bk)) if k < *bk => best = Some((i, k)),
            _ => {}
        }
    }
    best.map(|(i, _)| i)
}

/// Construct a policy by name — the config-system entry point.
pub fn make_policy(
    kind: PolicyKind,
    cores: u32,
    grace_rsec: f64,
    bopf_burst_rsec: f64,
) -> Box<dyn Policy> {
    match kind {
        PolicyKind::Fifo => Box::new(fifo::Fifo::new()),
        PolicyKind::Fair => Box::new(fair::Fair::new()),
        PolicyKind::Ujf => Box::new(ujf::Ujf::new()),
        PolicyKind::Cfq => Box::new(cfq::Cfq::new(cores as f64)),
        PolicyKind::Uwfq => Box::new(uwfq::Uwfq::new(cores as f64, grace_rsec)),
        PolicyKind::Drf => Box::new(drf::Drf::new()),
        PolicyKind::Bopf => Box::new(bopf::Bopf::new(bopf_burst_rsec)),
    }
}

/// The schedulers evaluated in the paper (§5.1.2) plus Spark FIFO and
/// the multi-resource pair (DRF, BoPF).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Fifo,
    Fair,
    Ujf,
    Cfq,
    Uwfq,
    Drf,
    Bopf,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 7] = [
        PolicyKind::Fifo,
        PolicyKind::Fair,
        PolicyKind::Ujf,
        PolicyKind::Cfq,
        PolicyKind::Uwfq,
        PolicyKind::Drf,
        PolicyKind::Bopf,
    ];

    /// The four schedulers compared in the paper's tables.
    pub const PAPER: [PolicyKind; 4] = [
        PolicyKind::Fair,
        PolicyKind::Ujf,
        PolicyKind::Cfq,
        PolicyKind::Uwfq,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "FIFO",
            PolicyKind::Fair => "Fair",
            PolicyKind::Ujf => "UJF",
            PolicyKind::Cfq => "CFQ",
            PolicyKind::Uwfq => "UWFQ",
            PolicyKind::Drf => "DRF",
            PolicyKind::Bopf => "BoPF",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(PolicyKind::Fifo),
            "fair" => Some(PolicyKind::Fair),
            "ujf" => Some(PolicyKind::Ujf),
            "cfq" => Some(PolicyKind::Cfq),
            "uwfq" => Some(PolicyKind::Uwfq),
            "drf" => Some(PolicyKind::Drf),
            "bopf" => Some(PolicyKind::Bopf),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_min_skips_pending_zero() {
        let views = vec![
            StageView {
                stage: 1,
                slot: 0,
                job: 1,
                user: 0,
                stage_idx: 0,
                running: 0,
                pending: 0,
                arrival_seq: 0,
                demand: ResourceVec::UNIT,
            },
            StageView {
                stage: 2,
                slot: 1,
                job: 2,
                user: 0,
                stage_idx: 0,
                running: 0,
                pending: 1,
                arrival_seq: 1,
                demand: ResourceVec::UNIT,
            },
        ];
        assert_eq!(select_min_by_key(&views, |v| v.arrival_seq), Some(1));
    }

    #[test]
    fn policy_kind_parse_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
            assert_eq!(PolicyKind::parse(&k.name().to_lowercase()), Some(k));
        }
        assert_eq!(PolicyKind::parse("nope"), None);
    }
}
