//! Spark's built-in Fair scheduler (paper §5.1.2): the stage with the
//! fewest running tasks has the highest priority,
//! `P_s = N^s_active_task_amount`. Job-level only — no user context, which
//! is exactly the weakness the paper demonstrates (users with more active
//! stages receive more resources).

use super::{select_min_by_key, Policy, StageView};

#[derive(Default)]
pub struct Fair;

impl Fair {
    pub fn new() -> Self {
        Fair
    }
}

impl Policy for Fair {
    fn name(&self) -> &'static str {
        "Fair"
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Fewest running tasks; FIFO tiebreak (Spark's comparator with
        // minShare=0, weight=1).
        select_min_by_key(views, |v| (v.running, v.arrival_seq, v.stage_idx, v.stage))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(stage: u64, running: u32, pending: u32, seq: u64) -> StageView {
        StageView {
            stage,
            job: stage,
            user: 0,
            stage_idx: 0,
            running,
            pending,
            arrival_seq: seq,
        }
    }

    #[test]
    fn fewest_running_wins() {
        let mut p = Fair::new();
        let views = vec![v(1, 5, 4, 0), v(2, 2, 4, 1), v(3, 3, 4, 2)];
        assert_eq!(p.select(0.0, &views), Some(1));
    }

    #[test]
    fn equalizes_over_successive_launches() {
        // Simulate counts updating as tasks launch: selection must rotate.
        let mut p = Fair::new();
        let mut running = [0u32; 3];
        for _ in 0..9 {
            let views: Vec<StageView> = (0..3).map(|i| v(i as u64 + 1, running[i], 10, i as u64)).collect();
            let picked = p.select(0.0, &views).unwrap();
            running[picked] += 1;
        }
        assert_eq!(running, [3, 3, 3]);
    }

    #[test]
    fn fifo_tiebreak() {
        let mut p = Fair::new();
        let views = vec![v(1, 1, 1, 5), v(2, 1, 1, 3)];
        assert_eq!(p.select(0.0, &views), Some(1));
    }
}
