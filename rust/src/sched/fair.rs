//! Spark's built-in Fair scheduler (paper §5.1.2): the stage with the
//! fewest running tasks has the highest priority,
//! `P_s = N^s_active_task_amount`. Job-level only — no user context, which
//! is exactly the weakness the paper demonstrates (users with more active
//! stages receive more resources).
//!
//! Incremental index: key `(running, arrival_seq, stage_idx)` changes on
//! every launch/finish of the stage; the [`StageIndex`] lazy-invalidation
//! rules (fresh entry on decrease, stale fix-up on increase) keep
//! selection at O(log n) amortized per event. Keys depend on running
//! counts, so Fair is **not** `static_keys` — the batched core still
//! offers per event, but delivers deferred finish notifications through
//! the coalescing [`Policy::on_tasks_finished`] below.

use super::index::StageIndex;
use super::{select_min_by_key, Policy, StageMeta, StageView};
use crate::StageId;

#[derive(Default)]
pub struct Fair {
    /// (running, arrival_seq, stage_idx) — stage id breaks final ties.
    index: StageIndex<(u32, u64, usize)>,
}

impl Fair {
    pub fn new() -> Self {
        Fair {
            index: StageIndex::new(),
        }
    }
}

impl Policy for Fair {
    fn name(&self) -> &'static str {
        "Fair"
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        self.index.insert(
            meta.stage,
            meta.slot,
            (0, meta.arrival_seq, meta.stage_idx),
            meta.pending,
        );
    }

    fn on_task_launched(&mut self, stage: StageId, slot: u32) {
        self.index.task_launched(stage, slot);
        if let Some((running, seq, idx)) = self.index.key_of(stage, slot) {
            self.index.update_key(stage, slot, (running + 1, seq, idx));
        }
    }

    fn on_task_finished(&mut self, stage: StageId, slot: u32) {
        // Only stages still holding pending work live in the index; for
        // them a finish lowers the priority key, which must push a fresh
        // entry (invariant 1 in the index docs).
        if let Some((running, seq, idx)) = self.index.key_of(stage, slot) {
            debug_assert!(running > 0);
            self.index.update_key(stage, slot, (running - 1, seq, idx));
        }
    }

    fn on_tasks_finished(&mut self, batch: &[(StageId, u32)]) {
        // Coalesce runs of consecutive same-stage finishes into one net
        // key update. Equivalent to the per-event replay: intermediate
        // keys would only add stale heap entries that the lazy peek
        // re-keys away — the surviving current key is identical.
        let mut i = 0;
        while i < batch.len() {
            let (stage, slot) = batch[i];
            let mut n: u32 = 1;
            while i + (n as usize) < batch.len() && batch[i + n as usize] == (stage, slot) {
                n += 1;
            }
            if let Some((running, seq, idx)) = self.index.key_of(stage, slot) {
                debug_assert!(running >= n);
                self.index.update_key(stage, slot, (running - n, seq, idx));
            }
            i += n as usize;
        }
    }

    fn on_task_requeued(&mut self, _now_s: f64, v: &StageView) {
        // `v.running` is the engine's current count (the failed task is
        // already off the core), matching the scan comparator exactly.
        self.index
            .task_requeued(v.stage, v.slot, (v.running, v.arrival_seq, v.stage_idx));
    }

    fn on_stage_finish(&mut self, stage: StageId, slot: u32) {
        self.index.remove(stage, slot);
    }

    fn select_next(&mut self, _now_s: f64) -> Option<(StageId, u32)> {
        self.index.peek()
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Fewest running tasks; FIFO tiebreak (Spark's comparator with
        // minShare=0, weight=1).
        select_min_by_key(views, |v| (v.running, v.arrival_seq, v.stage_idx, v.stage))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(stage: u64, running: u32, pending: u32, seq: u64) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job: stage,
            user: 0,
            stage_idx: 0,
            running,
            pending,
            arrival_seq: seq,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    fn submit(p: &mut Fair, stage: u64, seq: u64, pending: u32) {
        p.on_stage_submit(
            0.0,
            &StageMeta {
                stage,
                slot: stage as u32,
                job: stage,
                user: 0,
                est_slot_time: 1.0,
                stage_idx: 0,
                arrival_seq: seq,
                pending,
                demand: crate::core::task::ResourceVec::UNIT,
            },
        );
    }

    #[test]
    fn fewest_running_wins() {
        let mut p = Fair::new();
        let views = vec![v(1, 5, 4, 0), v(2, 2, 4, 1), v(3, 3, 4, 2)];
        assert_eq!(p.select(0.0, &views), Some(1));
    }

    #[test]
    fn equalizes_over_successive_launches() {
        // Simulate counts updating as tasks launch: selection must rotate.
        let mut p = Fair::new();
        let mut running = [0u32; 3];
        for _ in 0..9 {
            let views: Vec<StageView> = (0..3).map(|i| v(i as u64 + 1, running[i], 10, i as u64)).collect();
            let picked = p.select(0.0, &views).unwrap();
            running[picked] += 1;
        }
        assert_eq!(running, [3, 3, 3]);
    }

    #[test]
    fn fifo_tiebreak() {
        let mut p = Fair::new();
        let views = vec![v(1, 1, 1, 5), v(2, 1, 1, 3)];
        assert_eq!(p.select(0.0, &views), Some(1));
    }

    #[test]
    fn incremental_rotates_like_scan() {
        let mut p = Fair::new();
        for s in 1..=3u64 {
            submit(&mut p, s, s, 10);
        }
        let mut launched = [0u32; 3];
        for _ in 0..9 {
            let (s, slot) = p.select_next(0.0).unwrap();
            launched[(s - 1) as usize] += 1;
            p.on_task_launched(s, slot);
        }
        assert_eq!(launched, [3, 3, 3]);
    }

    #[test]
    fn finish_restores_priority() {
        let mut p = Fair::new();
        submit(&mut p, 1, 1, 10);
        submit(&mut p, 2, 2, 10);
        // Stage 1 launches twice → stage 2 preferred.
        p.on_task_launched(1, 1);
        p.on_task_launched(1, 1);
        assert_eq!(p.select_next(0.0), Some((2, 2)));
        p.on_task_launched(2, 2);
        // A stage-1 task finishes: both at running 1 → FIFO tiebreak.
        p.on_task_finished(1, 1);
        assert_eq!(p.select_next(0.0), Some((1, 1)));
    }

    #[test]
    fn batched_finish_matches_per_event_replay() {
        let mut a = Fair::new();
        let mut b = Fair::new();
        for p in [&mut a, &mut b] {
            submit(p, 1, 1, 10);
            submit(p, 2, 2, 10);
            for _ in 0..3 {
                p.on_task_launched(1, 1);
            }
            p.on_task_launched(2, 2);
        }
        let batch = [(1u64, 1u32), (1, 1), (2, 2)];
        a.on_tasks_finished(&batch);
        for &(s, slot) in &batch {
            b.on_task_finished(s, slot);
        }
        for _ in 0..4 {
            let x = a.select_next(0.0);
            assert_eq!(x, b.select_next(0.0));
            if let Some((s, slot)) = x {
                a.on_task_launched(s, slot);
                b.on_task_launched(s, slot);
            }
        }
    }
}
