//! **UWFQ — User Weighted Fair Queuing** (paper §3.3, §4.1): the paper's
//! contribution.
//!
//! On analytics-job arrival, Algorithm 1 simulates a virtual user-job fair
//! (UJF/GPS) system via 2-level virtual time and assigns the job a global
//! virtual deadline — the virtual time at which it would finish if every
//! user received an equal share and each user's jobs ran sequentially in
//! user-deadline order. Every stage of the job inherits this deadline
//! (`P_s = D_global^i`, §4.1.1), so jobs run to completion instead of
//! interleaving, while remaining bounded by user-job fairness
//! (Appendix A: `F_i − f_i ≤ L_max/R + 2·l_max`).
//!
//! The §4.2 grace period revives recently departed users with their
//! progressed virtual arrival time so stage stragglers of inaccurately
//! estimated jobs don't gain spurious priority.
//!
//! Incremental index: stages are keyed by `(D_global, arrival_seq,
//! stage_idx)`. Algorithm 1 can *reassign* the deadlines of a user's
//! queued jobs when a shorter job overtakes them; [`TwoLevelVtime`]
//! reports the rewritten suffix in `last_changed` and the affected
//! stages are re-keyed (lazy invalidation — the stale heap entries are
//! discarded when they surface). Keys only change on job arrivals —
//! never on launches or finishes — so UWFQ is `static_keys` for the
//! batched event core (arrivals always flush pending batches first).

use super::index::{F64Key, StageIndex};
use super::vtime::TwoLevelVtime;
use super::{select_min_by_key, JobMeta, Policy, StageMeta, StageView};
use crate::core::arena::SlotCol;
use crate::{JobId, StageId};
use std::collections::HashMap;

pub struct Uwfq {
    vt: TwoLevelVtime,
    /// Grace period in resource-seconds (paper default: 2).
    pub grace_rsec: f64,
    /// (D_global, arrival_seq, stage_idx) — stage id breaks final ties.
    index: StageIndex<(F64Key, u64, usize)>,
    /// Active (submitted, unfinished) stages per job as `(stage, slot)`,
    /// for deadline re-keying; plus each stage's static tiebreak key
    /// parts in a dense slot column.
    job_stages: HashMap<JobId, Vec<(StageId, u32)>>,
    stage_static: SlotCol<(JobId, u64, usize)>,
}

impl Uwfq {
    pub fn new(r_total: f64, grace_rsec: f64) -> Self {
        Uwfq {
            vt: TwoLevelVtime::new(r_total),
            grace_rsec,
            index: StageIndex::new(),
            job_stages: HashMap::new(),
            stage_static: SlotCol::new(),
        }
    }

    /// Read-only access to the virtual system (diagnostics, benches).
    pub fn vtime(&self) -> &TwoLevelVtime {
        &self.vt
    }
}

impl Policy for Uwfq {
    fn name(&self) -> &'static str {
        "UWFQ"
    }

    fn on_job_arrival(&mut self, now_s: f64, meta: &JobMeta) {
        self.vt.job_arrival(
            now_s,
            meta.user,
            meta.job,
            meta.est_slot_time,
            meta.weight,
            self.grace_rsec,
        );
        // Algorithm 1 phase 3 may have pushed back the deadlines of the
        // user's queued jobs — re-key their live stages.
        for i in 0..self.vt.last_changed.len() {
            let (job, d) = self.vt.last_changed[i];
            let Some(stages) = self.job_stages.get(&job) else {
                continue;
            };
            for &(s, slot) in stages {
                if let Some(&(_, seq, idx)) = self.stage_static.get(slot) {
                    self.index.update_key(s, slot, (F64Key(d), seq, idx));
                }
            }
        }
    }

    fn on_stage_submit(&mut self, _now_s: f64, meta: &StageMeta) {
        let d = self.vt.job_deadline(meta.job).unwrap_or(f64::INFINITY);
        self.index.insert(
            meta.stage,
            meta.slot,
            (F64Key(d), meta.arrival_seq, meta.stage_idx),
            meta.pending,
        );
        self.job_stages
            .entry(meta.job)
            .or_default()
            .push((meta.stage, meta.slot));
        self.stage_static
            .set(meta.slot, (meta.job, meta.arrival_seq, meta.stage_idx));
    }

    fn on_task_launched(&mut self, stage: StageId, slot: u32) {
        self.index.task_launched(stage, slot);
    }

    fn on_tasks_launched(&mut self, stage: StageId, slot: u32, n: u32) {
        self.index.task_launched_n(stage, slot, n);
    }

    fn on_tasks_finished(&mut self, _batch: &[(StageId, u32)]) {
        // Deadlines never move on finishes: a batch of plain finishes
        // changes nothing in the index.
    }

    fn on_task_requeued(&mut self, _now_s: f64, v: &StageView) {
        // A retry re-enters under the job's *current* global deadline —
        // virtual time was charged once at arrival and never again, so
        // re-execution cannot move the job in the virtual order.
        let d = self.vt.job_deadline(v.job).unwrap_or(f64::INFINITY);
        self.index
            .task_requeued(v.stage, v.slot, (F64Key(d), v.arrival_seq, v.stage_idx));
    }

    fn on_stage_finish(&mut self, stage: StageId, slot: u32) {
        self.index.remove(stage, slot);
        if let Some((job, _, _)) = self.stage_static.take(slot) {
            if let Some(stages) = self.job_stages.get_mut(&job) {
                stages.retain(|&(s, _)| s != stage);
                if stages.is_empty() {
                    self.job_stages.remove(&job);
                }
            }
        }
    }

    fn static_keys(&self) -> bool {
        true
    }

    fn select_next(&mut self, _now_s: f64) -> Option<(StageId, u32)> {
        self.index.peek()
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Highest priority = lowest global virtual deadline; stages of the
        // same job execute in stage order (earlier stages are parents).
        select_min_by_key(views, |v| {
            (
                self.vt
                    .job_deadline(v.job)
                    .unwrap_or(f64::INFINITY),
                v.arrival_seq,
                v.stage_idx,
                v.stage,
            )
        })
    }

    fn on_job_finish(&mut self, _now_s: f64, job: JobId) {
        // Deadlines of finished jobs are no longer needed for scheduling;
        // keep the map from growing over a long-running application.
        self.vt.deadlines.remove(&job);
        self.job_stages.remove(&job);
    }

    fn job_deadline(&self, job: JobId) -> Option<f64> {
        self.vt.job_deadline(job)
    }

    fn vtime_mut(&mut self) -> Option<&mut TwoLevelVtime> {
        Some(&mut self.vt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(job: u64, user: u32, slot: f64, seq: u64) -> JobMeta {
        JobMeta {
            job,
            user,
            weight: 1.0,
            est_slot_time: slot,
            arrival_seq: seq,
        }
    }

    fn smeta(stage: u64, job: u64, idx: usize, seq: u64) -> StageMeta {
        StageMeta {
            stage,
            slot: stage as u32,
            job,
            user: 1,
            est_slot_time: 1.0,
            stage_idx: idx,
            arrival_seq: seq,
            pending: 1,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    fn v(stage: u64, job: u64, user: u32, idx: usize) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job,
            user,
            stage_idx: idx,
            running: 0,
            pending: 1,
            arrival_seq: job,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    #[test]
    fn infrequent_user_overtakes_flooder() {
        // User 1 floods 5 jobs (L=4); user 2 submits one small job (L=1).
        // UWFQ must schedule user 2's job before user 1's queued jobs.
        let mut p = Uwfq::new(4.0, 2.0);
        for j in 1..=5 {
            p.on_job_arrival(0.0, &meta(j, 1, 4.0, j));
        }
        p.on_job_arrival(0.1, &meta(6, 2, 1.0, 6));
        let views: Vec<StageView> = (1..=6).map(|j| v(j, j, if j == 6 { 2 } else { 1 }, 0)).collect();
        // Flooder's first job has D=4; the small job's deadline is ~1+ε —
        // user 2's job wins over jobs 2..5 and over job 1 too.
        let picked = p.select(0.1, &views).unwrap();
        assert_eq!(views[picked].job, 6);
    }

    #[test]
    fn job_context_runs_jobs_to_completion() {
        // Two jobs of the same user: all stages of the earlier-deadline
        // job sort before any stage of the later one (no interleaving).
        let mut p = Uwfq::new(4.0, 2.0);
        p.on_job_arrival(0.0, &meta(1, 1, 2.0, 1));
        p.on_job_arrival(0.0, &meta(2, 1, 2.0, 2));
        let views = vec![v(10, 2, 1, 0), v(11, 1, 1, 1), v(12, 1, 1, 0)];
        // job 1 has the earlier deadline; its stage_idx=0 goes first.
        let picked = p.select(0.0, &views).unwrap();
        assert_eq!(views[picked].stage, 12);
    }

    #[test]
    fn stage_inherits_job_deadline() {
        let mut p = Uwfq::new(4.0, 2.0);
        p.on_job_arrival(0.0, &meta(1, 1, 8.0, 1));
        let d = p.job_deadline(1).unwrap();
        assert!((d - 8.0).abs() < 1e-9);
        // Both stages of job 1 carry the same priority — selection among
        // them falls back to stage order.
        let views = vec![v(20, 1, 1, 1), v(21, 1, 1, 0)];
        assert_eq!(p.select(0.0, &views), Some(1));
    }

    #[test]
    fn job_finish_cleans_deadline_map() {
        let mut p = Uwfq::new(4.0, 2.0);
        p.on_job_arrival(0.0, &meta(1, 1, 1.0, 1));
        assert!(p.job_deadline(1).is_some());
        p.on_job_finish(1.0, 1);
        assert!(p.job_deadline(1).is_none());
    }

    #[test]
    fn weights_shift_deadlines() {
        // User 2 with weight 0.5 (favored: deadlines grow half as fast).
        let mut p = Uwfq::new(2.0, 2.0);
        p.on_job_arrival(
            0.0,
            &JobMeta {
                job: 1,
                user: 1,
                weight: 1.0,
                est_slot_time: 4.0,
                arrival_seq: 1,
            },
        );
        p.on_job_arrival(
            0.0,
            &JobMeta {
                job: 2,
                user: 2,
                weight: 0.5,
                est_slot_time: 4.0,
                arrival_seq: 2,
            },
        );
        let d1 = p.job_deadline(1).unwrap();
        let d2 = p.job_deadline(2).unwrap();
        assert!(d2 < d1, "favored user must get earlier deadline");
    }

    #[test]
    fn reassigned_deadline_rekeys_live_stages() {
        // u1 queues a long job (stage live), then a short job of the same
        // user overtakes it in the user's virtual order: the long job's
        // deadline is pushed back, and the incremental index must prefer
        // the short job's stage afterwards.
        let mut p = Uwfq::new(2.0, 2.0);
        p.on_job_arrival(0.0, &meta(1, 1, 10.0, 1));
        p.on_stage_submit(0.0, &smeta(100, 1, 0, 1));
        assert_eq!(p.select_next(0.0), Some((100, 100)));
        p.on_job_arrival(1.0, &meta(2, 1, 2.0, 2));
        p.on_stage_submit(1.0, &smeta(200, 2, 0, 2));
        let d1 = p.job_deadline(1).unwrap();
        let d2 = p.job_deadline(2).unwrap();
        assert!(d2 < d1, "short job overtakes: {d2} vs {d1}");
        assert_eq!(p.select_next(1.0), Some((200, 200)));
        // The scan path agrees.
        let views = vec![v(100, 1, 1, 0), v(200, 2, 1, 0)];
        assert_eq!(p.select(1.0, &views), Some(1));
        // Finish the short job: the long job's stage surfaces again.
        p.on_task_launched(200, 200);
        p.on_stage_finish(200, 200);
        p.on_job_finish(2.0, 2);
        assert_eq!(p.select_next(2.0), Some((100, 100)));
    }
}
