//! **UWFQ — User Weighted Fair Queuing** (paper §3.3, §4.1): the paper's
//! contribution.
//!
//! On analytics-job arrival, Algorithm 1 simulates a virtual user-job fair
//! (UJF/GPS) system via 2-level virtual time and assigns the job a global
//! virtual deadline — the virtual time at which it would finish if every
//! user received an equal share and each user's jobs ran sequentially in
//! user-deadline order. Every stage of the job inherits this deadline
//! (`P_s = D_global^i`, §4.1.1), so jobs run to completion instead of
//! interleaving, while remaining bounded by user-job fairness
//! (Appendix A: `F_i − f_i ≤ L_max/R + 2·l_max`).
//!
//! The §4.2 grace period revives recently departed users with their
//! progressed virtual arrival time so stage stragglers of inaccurately
//! estimated jobs don't gain spurious priority.

use super::vtime::TwoLevelVtime;
use super::{select_min_by_key, JobMeta, Policy, StageView};
use crate::JobId;

pub struct Uwfq {
    vt: TwoLevelVtime,
    /// Grace period in resource-seconds (paper default: 2).
    pub grace_rsec: f64,
}

impl Uwfq {
    pub fn new(r_total: f64, grace_rsec: f64) -> Self {
        Uwfq {
            vt: TwoLevelVtime::new(r_total),
            grace_rsec,
        }
    }

    /// Read-only access to the virtual system (diagnostics, benches).
    pub fn vtime(&self) -> &TwoLevelVtime {
        &self.vt
    }
}

impl Policy for Uwfq {
    fn name(&self) -> &'static str {
        "UWFQ"
    }

    fn on_job_arrival(&mut self, now_s: f64, meta: &JobMeta) {
        self.vt.job_arrival(
            now_s,
            meta.user,
            meta.job,
            meta.est_slot_time,
            meta.weight,
            self.grace_rsec,
        );
    }

    fn select(&mut self, _now_s: f64, views: &[StageView]) -> Option<usize> {
        // Highest priority = lowest global virtual deadline; stages of the
        // same job execute in stage order (earlier stages are parents).
        select_min_by_key(views, |v| {
            (
                self.vt
                    .job_deadline(v.job)
                    .unwrap_or(f64::INFINITY),
                v.arrival_seq,
                v.stage_idx,
                v.stage,
            )
        })
    }

    fn on_job_finish(&mut self, _now_s: f64, job: JobId) {
        // Deadlines of finished jobs are no longer needed for scheduling;
        // keep the map from growing over a long-running application.
        self.vt.deadlines.remove(&job);
    }

    fn job_deadline(&self, job: JobId) -> Option<f64> {
        self.vt.job_deadline(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(job: u64, user: u32, slot: f64, seq: u64) -> JobMeta {
        JobMeta {
            job,
            user,
            weight: 1.0,
            est_slot_time: slot,
            arrival_seq: seq,
        }
    }

    fn v(stage: u64, job: u64, user: u32, idx: usize) -> StageView {
        StageView {
            stage,
            job,
            user,
            stage_idx: idx,
            running: 0,
            pending: 1,
            arrival_seq: job,
        }
    }

    #[test]
    fn infrequent_user_overtakes_flooder() {
        // User 1 floods 5 jobs (L=4); user 2 submits one small job (L=1).
        // UWFQ must schedule user 2's job before user 1's queued jobs.
        let mut p = Uwfq::new(4.0, 2.0);
        for j in 1..=5 {
            p.on_job_arrival(0.0, &meta(j, 1, 4.0, j));
        }
        p.on_job_arrival(0.1, &meta(6, 2, 1.0, 6));
        let views: Vec<StageView> = (1..=6).map(|j| v(j, j, if j == 6 { 2 } else { 1 }, 0)).collect();
        // Flooder's first job has D=4; the small job's deadline is ~1+ε —
        // user 2's job wins over jobs 2..5 and over job 1 too.
        let picked = p.select(0.1, &views).unwrap();
        assert_eq!(views[picked].job, 6);
    }

    #[test]
    fn job_context_runs_jobs_to_completion() {
        // Two jobs of the same user: all stages of the earlier-deadline
        // job sort before any stage of the later one (no interleaving).
        let mut p = Uwfq::new(4.0, 2.0);
        p.on_job_arrival(0.0, &meta(1, 1, 2.0, 1));
        p.on_job_arrival(0.0, &meta(2, 1, 2.0, 2));
        let views = vec![v(10, 2, 1, 0), v(11, 1, 1, 1), v(12, 1, 1, 0)];
        // job 1 has the earlier deadline; its stage_idx=0 goes first.
        let picked = p.select(0.0, &views).unwrap();
        assert_eq!(views[picked].stage, 12);
    }

    #[test]
    fn stage_inherits_job_deadline() {
        let mut p = Uwfq::new(4.0, 2.0);
        p.on_job_arrival(0.0, &meta(1, 1, 8.0, 1));
        let d = p.job_deadline(1).unwrap();
        assert!((d - 8.0).abs() < 1e-9);
        // Both stages of job 1 carry the same priority — selection among
        // them falls back to stage order.
        let views = vec![v(20, 1, 1, 1), v(21, 1, 1, 0)];
        assert_eq!(p.select(0.0, &views), Some(1));
    }

    #[test]
    fn job_finish_cleans_deadline_map() {
        let mut p = Uwfq::new(4.0, 2.0);
        p.on_job_arrival(0.0, &meta(1, 1, 1.0, 1));
        assert!(p.job_deadline(1).is_some());
        p.on_job_finish(1.0, 1);
        assert!(p.job_deadline(1).is_none());
    }

    #[test]
    fn weights_shift_deadlines() {
        // User 2 with weight 0.5 (favored: deadlines grow half as fast).
        let mut p = Uwfq::new(2.0, 2.0);
        p.on_job_arrival(
            0.0,
            &JobMeta {
                job: 1,
                user: 1,
                weight: 1.0,
                est_slot_time: 4.0,
                arrival_seq: 1,
            },
        );
        p.on_job_arrival(
            0.0,
            &JobMeta {
                job: 2,
                user: 2,
                weight: 0.5,
                est_slot_time: 4.0,
                arrival_seq: 2,
            },
        );
        let d1 = p.job_deadline(1).unwrap();
        let d2 = p.job_deadline(2).unwrap();
        assert!(d2 < d1, "favored user must get earlier deadline");
    }
}
