//! Stage/job runtime estimation (paper §4.1.3, §6.4).
//!
//! UWFQ and runtime partitioning both consume *estimated* runtimes. The
//! paper assumes a perfect predictor (§5.1) and argues virtual-time
//! scheduling is robust to error (§6.4); we provide both the perfect
//! [`Oracle`] and a seeded multiplicative-error [`Noisy`] estimator for the
//! robustness ablation.

use crate::core::job::{JobSpec, StageSpec};
use crate::util::Rng;
use crate::JobId;

/// A class-loaded "performance estimator" in the paper's terms: returns
/// estimated sequential runtimes (slot-times) of work units.
///
/// Estimates are keyed by stage *identity* `(job, stage_idx)`: querying
/// the same stage twice — in any order, interleaved with anything —
/// returns the same value. This is what keeps runs byte-identical
/// regardless of how often a policy or the idle-response memo consults
/// the estimator.
pub trait RuntimeEstimator: Send {
    fn name(&self) -> &'static str;

    /// Estimated sequential runtime of stage `stage_idx` of `job`, seconds.
    fn stage_slot_time(&self, job: JobId, stage_idx: usize, stage: &StageSpec) -> f64;

    /// Estimated job slot-time `L_i` = Σ stage estimates.
    fn job_slot_time(&self, job: JobId, spec: &JobSpec) -> f64 {
        spec.stages
            .iter()
            .enumerate()
            .map(|(i, s)| self.stage_slot_time(job, i, s))
            .sum()
    }
}

/// Perfect runtime prediction (the paper's experimental assumption).
#[derive(Default)]
pub struct Oracle;

impl Oracle {
    pub fn new() -> Self {
        Oracle
    }
}

impl RuntimeEstimator for Oracle {
    fn name(&self) -> &'static str {
        "oracle"
    }
    fn stage_slot_time(&self, _job: JobId, _stage_idx: usize, stage: &StageSpec) -> f64 {
        stage.slot_time
    }
}

/// Multiplicative lognormal error: estimate = truth · exp(σ·N(0,1)).
/// σ = 0 reduces to the oracle. The error is a pure function of
/// (seed, job, stage index): stable per stage identity, independent
/// across stages — a predictor that is *consistently* wrong per stage,
/// never flip-flopping between queries.
pub struct Noisy {
    sigma: f64,
    seed: u64,
}

impl Noisy {
    pub fn new(sigma: f64, seed: u64) -> Self {
        assert!(sigma >= 0.0);
        Noisy { sigma, seed }
    }

    /// SplitMix64-style mix of the stage identity into an RNG seed.
    fn stage_seed(&self, job: JobId, stage_idx: usize) -> u64 {
        let mut h = self.seed ^ 0x9E37_79B9_7F4A_7C15;
        for v in [job as u64, stage_idx as u64] {
            h ^= v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h = h.rotate_left(31).wrapping_mul(0x94D0_49BB_1331_11EB);
        }
        h
    }
}

impl RuntimeEstimator for Noisy {
    fn name(&self) -> &'static str {
        "noisy"
    }
    fn stage_slot_time(&self, job: JobId, stage_idx: usize, stage: &StageSpec) -> f64 {
        if self.sigma == 0.0 {
            return stage.slot_time;
        }
        let mut rng = Rng::new(self.stage_seed(job, stage_idx));
        stage.slot_time * rng.lognormal(0.0, self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobSpec;

    #[test]
    fn oracle_is_exact() {
        let j = JobSpec::three_phase(1, "j", 0, 2.0, 1 << 20, 4, None);
        let o = Oracle::new();
        assert_eq!(o.job_slot_time(1, &j), j.slot_time());
        assert_eq!(o.stage_slot_time(1, 1, &j.stages[1]), 1.0);
    }

    #[test]
    fn noisy_zero_sigma_is_exact() {
        let j = JobSpec::three_phase(1, "j", 0, 2.0, 1 << 20, 4, None);
        let n = Noisy::new(0.0, 7);
        assert!((n.job_slot_time(3, &j) - j.slot_time()).abs() < 1e-12);
    }

    #[test]
    fn noisy_errors_are_positive_and_centered() {
        // 2000 distinct stage identities: errors are independent across
        // identities, positive, and the log-error mean is ~0.
        let j = JobSpec::three_phase(1, "j", 0, 2.0, 1 << 20, 4, None);
        let n = Noisy::new(0.5, 11);
        let mut ratios = Vec::new();
        for job in 0..2000 {
            let e = n.stage_slot_time(job, 1, &j.stages[1]);
            assert!(e > 0.0);
            ratios.push((e / 1.0).ln());
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(mean.abs() < 0.05, "log-error mean {mean}");
    }

    #[test]
    fn noisy_is_stable_per_stage_identity() {
        // The regression this trait shape exists for: re-querying a stage
        // (any number of times, interleaved with other queries) returns
        // the identical estimate — repeat runs cannot diverge on query
        // order.
        let j = JobSpec::three_phase(1, "j", 0, 2.0, 1 << 20, 4, None);
        let n = Noisy::new(0.5, 11);
        let first = n.stage_slot_time(42, 1, &j.stages[1]);
        let other = n.stage_slot_time(42, 2, &j.stages[2]);
        for _ in 0..3 {
            assert_eq!(n.stage_slot_time(42, 1, &j.stages[1]).to_bits(), first.to_bits());
            assert_eq!(n.stage_slot_time(42, 2, &j.stages[2]).to_bits(), other.to_bits());
        }
        assert_ne!(first.to_bits(), other.to_bits(), "distinct identities draw distinct errors");
        // A fresh estimator with the same seed reproduces the values.
        let m = Noisy::new(0.5, 11);
        assert_eq!(m.stage_slot_time(42, 1, &j.stages[1]).to_bits(), first.to_bits());
        // A different seed draws a different error.
        let k = Noisy::new(0.5, 12);
        assert_ne!(k.stage_slot_time(42, 1, &j.stages[1]).to_bits(), first.to_bits());
    }
}
