//! Stage/job runtime estimation (paper §4.1.3, §6.4).
//!
//! UWFQ and runtime partitioning both consume *estimated* runtimes. The
//! paper assumes a perfect predictor (§5.1) and argues virtual-time
//! scheduling is robust to error (§6.4); we provide both the perfect
//! [`Oracle`] and a seeded multiplicative-error [`Noisy`] estimator for the
//! robustness ablation.

use crate::core::job::{JobSpec, StageSpec};
use crate::util::Rng;
use std::cell::RefCell;

/// A class-loaded "performance estimator" in the paper's terms: returns
/// estimated sequential runtimes (slot-times) of work units.
pub trait RuntimeEstimator: Send {
    fn name(&self) -> &'static str;

    /// Estimated sequential runtime of one stage, seconds.
    fn stage_slot_time(&self, stage: &StageSpec) -> f64;

    /// Estimated job slot-time `L_i` = Σ stage estimates.
    fn job_slot_time(&self, job: &JobSpec) -> f64 {
        job.stages.iter().map(|s| self.stage_slot_time(s)).sum()
    }
}

/// Perfect runtime prediction (the paper's experimental assumption).
#[derive(Default)]
pub struct Oracle;

impl Oracle {
    pub fn new() -> Self {
        Oracle
    }
}

impl RuntimeEstimator for Oracle {
    fn name(&self) -> &'static str {
        "oracle"
    }
    fn stage_slot_time(&self, stage: &StageSpec) -> f64 {
        stage.slot_time
    }
}

/// Multiplicative lognormal error: estimate = truth · exp(σ·N(0,1)).
/// σ = 0 reduces to the oracle. Deterministic per seed, but *not* per
/// stage identity — successive queries draw fresh errors, modelling a
/// predictor that is inconsistent across stages.
pub struct Noisy {
    sigma: f64,
    rng: RefCell<Rng>,
}

impl Noisy {
    pub fn new(sigma: f64, seed: u64) -> Self {
        assert!(sigma >= 0.0);
        Noisy {
            sigma,
            rng: RefCell::new(Rng::new(seed)),
        }
    }
}

impl RuntimeEstimator for Noisy {
    fn name(&self) -> &'static str {
        "noisy"
    }
    fn stage_slot_time(&self, stage: &StageSpec) -> f64 {
        let e = self.rng.borrow_mut().lognormal(0.0, self.sigma);
        stage.slot_time * e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobSpec;

    #[test]
    fn oracle_is_exact() {
        let j = JobSpec::three_phase(1, "j", 0, 2.0, 1 << 20, 4, None);
        let o = Oracle::new();
        assert_eq!(o.job_slot_time(&j), j.slot_time());
        assert_eq!(o.stage_slot_time(&j.stages[1]), 1.0);
    }

    #[test]
    fn noisy_zero_sigma_is_exact() {
        let j = JobSpec::three_phase(1, "j", 0, 2.0, 1 << 20, 4, None);
        let n = Noisy::new(0.0, 7);
        assert!((n.job_slot_time(&j) - j.slot_time()).abs() < 1e-12);
    }

    #[test]
    fn noisy_errors_are_positive_and_centered() {
        let j = JobSpec::three_phase(1, "j", 0, 2.0, 1 << 20, 4, None);
        let n = Noisy::new(0.5, 11);
        let mut ratios = Vec::new();
        for _ in 0..2000 {
            let e = n.stage_slot_time(&j.stages[1]);
            assert!(e > 0.0);
            ratios.push((e / 1.0).ln());
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(mean.abs() < 0.05, "log-error mean {mean}");
    }
}
