//! Spark-style schedulable pool tree (paper §2.1.3).
//!
//! The Task Scheduler keeps a Root Pool containing stages and/or nested
//! pools. At every resource offer the tree is sorted by the pool's
//! scheduling policy and the highest-priority runnable stage is selected.
//! The built-in Fair scheduler is a flat Fair root pool over stages; the
//! practical UJF baseline (§5.1.2) is a Fair root pool over dynamically
//! created per-user pools, each a Fair pool over that user's stages.

use std::collections::{BTreeMap, HashMap};

use crate::sched::StageView;
use crate::StageId;

/// Scheduling policy of a single pool level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolPolicy {
    /// Order by job arrival then stage index (Spark FIFO).
    Fifo,
    /// Spark's FairSchedulingAlgorithm with minShare=0, weight=1, which
    /// reduces to "fewest running tasks first" — the paper's
    /// `P_s = N^s_active_task_amount`.
    Fair,
}

/// Aggregated scheduling metrics of a subtree.
#[derive(Clone, Copy, Debug, Default)]
struct Agg {
    running: u32,
    pending: u32,
    min_arrival: u64,
    min_stage_idx: usize,
}

/// A selection candidate: subtree metrics plus the schedulable entity's
/// own weight / minShare (Spark's FairSchedulingAlgorithm inputs).
#[derive(Clone, Copy, Debug)]
struct Candidate {
    agg: Agg,
    weight: f64,
    min_share: u32,
}

impl Candidate {
    fn needy(&self) -> bool {
        self.agg.running < self.min_share
    }
    fn min_share_ratio(&self) -> f64 {
        self.agg.running as f64 / self.min_share.max(1) as f64
    }
    fn task_to_weight_ratio(&self) -> f64 {
        self.agg.running as f64 / self.weight.max(1e-9)
    }
}

#[derive(Debug)]
pub struct Pool {
    pub name: String,
    pub policy: PoolPolicy,
    pub weight: f64,
    pub min_share: u32,
    children: BTreeMap<String, Pool>,
    stages: Vec<StageId>,
}

/// Compare a primary f64 criterion, falling back to FIFO order on ties.
fn cmp_then_fifo(ka: f64, kb: f64, a: &Candidate, b: &Candidate) -> bool {
    if (ka - kb).abs() > 1e-12 {
        return ka < kb;
    }
    (a.agg.min_arrival, a.agg.min_stage_idx) < (b.agg.min_arrival, b.agg.min_stage_idx)
}

impl Pool {
    pub fn new(name: &str, policy: PoolPolicy) -> Pool {
        Pool {
            name: name.to_string(),
            policy,
            weight: 1.0,
            min_share: 0,
            children: BTreeMap::new(),
            stages: Vec::new(),
        }
    }

    /// Get or create a child pool (dynamic per-user pools, §5.1.2).
    pub fn child(&mut self, name: &str, policy: PoolPolicy) -> &mut Pool {
        self.children
            .entry(name.to_string())
            .or_insert_with(|| Pool::new(name, policy))
    }

    pub fn add_stage(&mut self, stage: StageId) {
        self.stages.push(stage);
    }

    /// Drop a stage from this subtree (on completion). Returns true if found.
    pub fn remove_stage(&mut self, stage: StageId) -> bool {
        if let Some(pos) = self.stages.iter().position(|&s| s == stage) {
            self.stages.remove(pos);
            return true;
        }
        for c in self.children.values_mut() {
            if c.remove_stage(stage) {
                return true;
            }
        }
        false
    }

    /// Remove empty child pools (users whose stages all finished).
    pub fn prune_empty(&mut self) {
        self.children.retain(|_, c| {
            c.prune_empty();
            !c.stages.is_empty() || !c.children.is_empty()
        });
    }

    fn aggregate(&self, views: &HashMap<StageId, &StageView>) -> Option<Agg> {
        let mut agg: Option<Agg> = None;
        let mut fold = |a: Agg| {
            agg = Some(match agg {
                None => a,
                Some(b) => Agg {
                    running: a.running + b.running,
                    pending: a.pending + b.pending,
                    min_arrival: a.min_arrival.min(b.min_arrival),
                    min_stage_idx: a.min_stage_idx.min(b.min_stage_idx),
                },
            });
        };
        for s in &self.stages {
            if let Some(v) = views.get(s) {
                fold(Agg {
                    running: v.running,
                    pending: v.pending,
                    min_arrival: v.arrival_seq,
                    min_stage_idx: v.stage_idx,
                });
            }
        }
        for c in self.children.values() {
            if let Some(a) = c.aggregate(views) {
                fold(a);
            }
        }
        agg
    }

    /// Select the highest-priority stage with pending tasks, walking the
    /// tree with this pool's policy at each level (paper §2.1.3: root
    /// policy picks the pool, pool policy picks the stage).
    pub fn select(&self, views: &HashMap<StageId, &StageView>) -> Option<StageId> {
        // Candidate leaf stages at this level (weight 1, minShare 0 —
        // stages inherit scheduling attributes from their pool in Spark).
        let mut best_stage: Option<(Candidate, StageId)> = None;
        for s in &self.stages {
            if let Some(v) = views.get(s) {
                if v.pending == 0 {
                    continue;
                }
                let a = Candidate {
                    agg: Agg {
                        running: v.running,
                        pending: v.pending,
                        min_arrival: v.arrival_seq,
                        min_stage_idx: v.stage_idx,
                    },
                    weight: 1.0,
                    min_share: 0,
                };
                if best_stage.is_none()
                    || self.better(&a, &best_stage.as_ref().unwrap().0)
                {
                    best_stage = Some((a, *s));
                }
            }
        }
        // Candidate child pools (only those with pending work anywhere),
        // carrying their own weight/minShare.
        let mut best_child: Option<(Candidate, &Pool)> = None;
        for c in self.children.values() {
            if let Some(agg) = c.aggregate(views) {
                if agg.pending == 0 {
                    continue;
                }
                let a = Candidate {
                    agg,
                    weight: c.weight,
                    min_share: c.min_share,
                };
                if best_child.is_none()
                    || self.better(&a, &best_child.as_ref().unwrap().0)
                {
                    best_child = Some((a, c));
                }
            }
        }
        match (best_stage, best_child) {
            (None, None) => None,
            (Some((_, s)), None) => Some(s),
            (None, Some((_, c))) => c.select(views),
            (Some((sa, s)), Some((ca, c))) => {
                if self.better(&sa, &ca) {
                    Some(s)
                } else {
                    c.select(views)
                }
            }
        }
    }

    /// Is `a` strictly higher priority than `b` under this pool's policy?
    ///
    /// Fair is Spark's full `FairSchedulingAlgorithm`: entities running
    /// below their minShare ("needy") come first (ordered by
    /// minShareRatio); otherwise order by runningTasks/weight; FIFO
    /// (arrival, stage index) tiebreak. With the defaults minShare=0,
    /// weight=1 this reduces to the paper's `P_s = N^s_running`.
    fn better(&self, a: &Candidate, b: &Candidate) -> bool {
        match self.policy {
            PoolPolicy::Fifo => {
                (a.agg.min_arrival, a.agg.min_stage_idx)
                    < (b.agg.min_arrival, b.agg.min_stage_idx)
            }
            PoolPolicy::Fair => match (a.needy(), b.needy()) {
                (true, false) => true,
                (false, true) => false,
                (true, true) => cmp_then_fifo(a.min_share_ratio(), b.min_share_ratio(), a, b),
                (false, false) => {
                    cmp_then_fifo(a.task_to_weight_ratio(), b.task_to_weight_ratio(), a, b)
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::StageView;

    fn view(stage: StageId, user: u32, running: u32, pending: u32, seq: u64) -> StageView {
        StageView {
            stage,
            slot: stage as u32,
            job: stage,
            user,
            stage_idx: 0,
            running,
            pending,
            arrival_seq: seq,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    fn views(vs: &[StageView]) -> HashMap<StageId, &StageView> {
        vs.iter().map(|v| (v.stage, v)).collect()
    }

    #[test]
    fn fair_picks_fewest_running() {
        let mut p = Pool::new("root", PoolPolicy::Fair);
        p.add_stage(1);
        p.add_stage(2);
        let vs = [view(1, 0, 3, 5, 0), view(2, 0, 1, 5, 1)];
        assert_eq!(p.select(&views(&vs)), Some(2));
    }

    #[test]
    fn fair_skips_no_pending() {
        let mut p = Pool::new("root", PoolPolicy::Fair);
        p.add_stage(1);
        p.add_stage(2);
        let vs = [view(1, 0, 0, 0, 0), view(2, 0, 9, 2, 1)];
        assert_eq!(p.select(&views(&vs)), Some(2));
    }

    #[test]
    fn fifo_picks_earliest_arrival() {
        let mut p = Pool::new("root", PoolPolicy::Fifo);
        p.add_stage(1);
        p.add_stage(2);
        let vs = [view(1, 0, 0, 5, 7), view(2, 0, 0, 5, 3)];
        assert_eq!(p.select(&views(&vs)), Some(2));
    }

    #[test]
    fn two_level_user_fairness() {
        // User A has 2 stages with 4 running total; user B has 1 stage with
        // 1 running. Root Fair must pick user B even though A's individual
        // stages have fewer running tasks than B's.
        let mut root = Pool::new("root", PoolPolicy::Fair);
        root.child("userA", PoolPolicy::Fair).add_stage(1);
        root.child("userA", PoolPolicy::Fair).add_stage(2);
        root.child("userB", PoolPolicy::Fair).add_stage(3);
        let vs = [
            view(1, 0, 0, 5, 0),
            view(2, 0, 4, 5, 1),
            view(3, 1, 1, 5, 2),
        ];
        assert_eq!(root.select(&views(&vs)), Some(3));
    }

    #[test]
    fn within_user_fair() {
        let mut root = Pool::new("root", PoolPolicy::Fair);
        root.child("userA", PoolPolicy::Fair).add_stage(1);
        root.child("userA", PoolPolicy::Fair).add_stage(2);
        let vs = [view(1, 0, 2, 5, 0), view(2, 0, 1, 5, 1)];
        assert_eq!(root.select(&views(&vs)), Some(2));
    }

    #[test]
    fn remove_and_prune() {
        let mut root = Pool::new("root", PoolPolicy::Fair);
        root.child("u1", PoolPolicy::Fair).add_stage(1);
        assert!(root.remove_stage(1));
        assert!(!root.remove_stage(1));
        root.prune_empty();
        let vs: [StageView; 0] = [];
        assert_eq!(root.select(&views(&vs)), None);
    }

    #[test]
    fn weighted_pool_gets_proportional_share() {
        // user A weight 3, user B weight 1 → A should win until its
        // running/weight ratio exceeds B's: with A running 2 and B
        // running 1, A's ratio (0.67) < B's (1.0) → A wins again.
        let mut root = Pool::new("root", PoolPolicy::Fair);
        root.child("a", PoolPolicy::Fair).weight = 3.0;
        root.child("a", PoolPolicy::Fair).add_stage(1);
        root.child("b", PoolPolicy::Fair).add_stage(2);
        let vs = [view(1, 0, 2, 5, 0), view(2, 1, 1, 5, 1)];
        assert_eq!(root.select(&views(&vs)), Some(1));
        // Over repeated launches the split converges to ~3:1.
        let mut running = [0u32; 2];
        for _ in 0..16 {
            let vs = [
                view(1, 0, running[0], 5, 0),
                view(2, 1, running[1], 5, 1),
            ];
            match root.select(&views(&vs)) {
                Some(1) => running[0] += 1,
                Some(2) => running[1] += 1,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(running, [12, 4]);
    }

    #[test]
    fn needy_pool_preempts_weighted() {
        // Pool B has minShare 4 and only 1 running → needy, wins over
        // pool A even though A has fewer running tasks per weight.
        let mut root = Pool::new("root", PoolPolicy::Fair);
        root.child("a", PoolPolicy::Fair).weight = 10.0;
        root.child("a", PoolPolicy::Fair).add_stage(1);
        root.child("b", PoolPolicy::Fair).min_share = 4;
        root.child("b", PoolPolicy::Fair).add_stage(2);
        let vs = [view(1, 0, 0, 5, 0), view(2, 1, 1, 5, 1)];
        assert_eq!(root.select(&views(&vs)), Some(2));
    }

    #[test]
    fn empty_pool_selects_none() {
        let p = Pool::new("root", PoolPolicy::Fair);
        let vs: [StageView; 0] = [];
        assert_eq!(p.select(&views(&vs)), None);
    }
}
