//! Dense slab arena — the engine's job/stage storage.
//!
//! External ids (`JobId`, `StageId`) stay monotone for the lifetime of the
//! application (records, event logs and policies key on them), while the
//! engine addresses live state through recycled **slot** indices: O(1)
//! direct indexing with no hashing on the hot path, and memory bounded by
//! the peak number of concurrently live entities rather than the total
//! ever created.

/// A slab of `T` with free-slot recycling. Slots are `u32` indices into a
/// dense vector; removed slots are pushed on a free list and reused by the
/// next insert (LIFO, so recently-touched memory is reused first).
#[derive(Debug)]
pub struct Slab<T> {
    slots: Vec<Option<T>>,
    free: Vec<u32>,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab {
            slots: Vec::new(),
            free: Vec::new(),
        }
    }

    pub fn with_capacity(n: usize) -> Self {
        Slab {
            slots: Vec::with_capacity(n),
            free: Vec::new(),
        }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Total slots ever allocated (live + free) — grows only with *peak*
    /// concurrency thanks to free-list recycling.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a value, returning its slot.
    pub fn insert(&mut self, value: T) -> u32 {
        match self.free.pop() {
            Some(slot) => {
                debug_assert!(self.slots[slot as usize].is_none());
                self.slots[slot as usize] = Some(value);
                slot
            }
            None => {
                self.slots.push(Some(value));
                (self.slots.len() - 1) as u32
            }
        }
    }

    /// Remove and return the value at `slot`. Panics on an empty slot —
    /// the engine never double-frees.
    pub fn remove(&mut self, slot: u32) -> T {
        let v = self.slots[slot as usize]
            .take()
            .expect("slab: remove of empty slot");
        self.free.push(slot);
        v
    }

    pub fn get(&self, slot: u32) -> &T {
        self.slots[slot as usize]
            .as_ref()
            .expect("slab: read of empty slot")
    }

    pub fn get_mut(&mut self, slot: u32) -> &mut T {
        self.slots[slot as usize]
            .as_mut()
            .expect("slab: write of empty slot")
    }

    /// Drop every live entry and reset the free list, retaining the slot
    /// vector's allocation. After `clear` the slab is observationally
    /// identical to a fresh one (inserts fill slots 0, 1, ... again) — the
    /// sweep engine's per-worker core reuse depends on this equivalence.
    pub fn clear(&mut self) {
        self.slots.clear();
        self.free.clear();
    }

    /// Live entries with their slots (diagnostics / cold paths only).
    pub fn iter(&self) -> impl Iterator<Item = (u32, &T)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i as u32, v)))
    }
}

/// A dense **side column** keyed by slab slot: policies and indexes
/// attach per-stage state to the engine's recycled slot numbers without
/// hashing. Structurally a `Vec<Option<T>>` that grows on demand —
/// reads of never-set or cleared slots return `None`, so callers don't
/// coordinate growth with the owning slab. This is the SoA counterpart
/// to [`Slab`]: the slab owns the entity, columns own one hot field
/// each, and all of them share the slot address space.
#[derive(Debug, Default)]
pub struct SlotCol<T> {
    col: Vec<Option<T>>,
}

impl<T> SlotCol<T> {
    pub fn new() -> Self {
        SlotCol { col: Vec::new() }
    }

    /// Set `slot`'s value, growing the column as needed.
    pub fn set(&mut self, slot: u32, value: T) {
        let i = slot as usize;
        if i >= self.col.len() {
            self.col.resize_with(i + 1, || None);
        }
        self.col[i] = Some(value);
    }

    pub fn get(&self, slot: u32) -> Option<&T> {
        self.col.get(slot as usize).and_then(|v| v.as_ref())
    }

    pub fn get_mut(&mut self, slot: u32) -> Option<&mut T> {
        self.col.get_mut(slot as usize).and_then(|v| v.as_mut())
    }

    /// Clear and return `slot`'s value (slot-recycling handoff).
    pub fn take(&mut self, slot: u32) -> Option<T> {
        self.col.get_mut(slot as usize).and_then(|v| v.take())
    }

    /// Drop all values, retaining the allocation (reset-for-reuse).
    pub fn clear(&mut self) {
        self.col.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<&'static str> = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(*s.get(a), "a");
        assert_eq!(*s.get(b), "b");
        assert_eq!(s.remove(a), "a");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn slots_are_recycled() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        let _b = s.insert(2);
        s.remove(a);
        let c = s.insert(3);
        assert_eq!(c, a, "freed slot is reused");
        assert_eq!(*s.get(c), 3);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn iter_skips_holes() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(10);
        let b = s.insert(20);
        let c = s.insert(30);
        s.remove(b);
        let live: Vec<(u32, u64)> = s.iter().map(|(i, &v)| (i, v)).collect();
        assert_eq!(live, vec![(a, 10), (c, 30)]);
    }

    #[test]
    fn clear_behaves_like_fresh() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        let _ = s.insert(2);
        s.remove(a);
        s.clear();
        assert_eq!(s.len(), 0);
        assert!(s.is_empty());
        // Slot numbering restarts exactly like a brand-new slab.
        assert_eq!(s.insert(7), 0);
        assert_eq!(s.insert(8), 1);
        assert_eq!(*s.get(0), 7);
    }

    #[test]
    #[should_panic(expected = "empty slot")]
    fn double_remove_panics() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        s.remove(a);
        s.remove(a);
    }

    #[test]
    fn slot_col_sparse_set_get_take() {
        let mut c: SlotCol<f64> = SlotCol::new();
        assert_eq!(c.get(3), None, "unset slot reads None");
        c.set(3, 1.5);
        c.set(0, 0.5);
        assert_eq!(c.get(3), Some(&1.5));
        assert_eq!(c.get(1), None, "hole between set slots");
        assert_eq!(c.take(3), Some(1.5));
        assert_eq!(c.get(3), None, "take clears the slot");
        assert_eq!(c.take(99), None, "take beyond the column is None");
        *c.get_mut(0).unwrap() = 2.0;
        assert_eq!(c.get(0), Some(&2.0));
    }

    #[test]
    fn slot_col_clear_resets() {
        let mut c: SlotCol<u32> = SlotCol::new();
        c.set(2, 7);
        c.clear();
        assert_eq!(c.get(2), None);
        c.set(2, 9); // regrows transparently after clear
        assert_eq!(c.get(2), Some(&9));
    }
}
