//! The Spark-like execution substrate (paper §2.1, Fig. 1): analytics jobs
//! are decomposed into a DAG of stages, stage inputs are partitioned into
//! tasks, and a task scheduler launches tasks onto executor cores in
//! priority order under a pluggable scheduling policy.
//!
//! The substrate is backend-agnostic: the same [`engine::SchedCore`] is
//! driven by the discrete-event simulator ([`crate::sim`]) and by the real
//! PJRT execution backend ([`crate::exec`]).

pub mod arena;
pub mod dag;
pub mod engine;
pub mod eventlog;
pub mod job;
pub mod pool;
pub mod stage;
pub mod task;

pub use engine::{Launch, SchedCore, TaskEvent, TaskEventClass};
pub use job::{CostProfile, JobSpec, StagePhase, StageSpec};
pub use stage::StageState;
pub use task::{Outcome, TaskSpec};
