//! Per-job DAG tracking — the DAG Scheduler role (paper §2.1.1): stages
//! are submitted to the task scheduler once all their parents finished,
//! and the job completes when its last stage does.

use std::sync::Arc;

use super::job::JobSpec;
use crate::{JobId, StageId, TimeUs, UserId};

#[derive(Clone, Debug)]
pub struct JobState {
    pub id: JobId,
    pub spec: JobSpec,
    pub arrival_seq: u64,
    /// Time the job was submitted to the engine.
    pub submit_time: TimeUs,
    /// StageId of each spec stage once submitted to the task scheduler.
    pub stage_ids: Vec<Option<StageId>>,
    pub stage_done: Vec<bool>,
    pub finish_time: Option<TimeUs>,
}

impl JobState {
    pub fn new(id: JobId, arrival_seq: u64, submit_time: TimeUs, spec: JobSpec) -> Self {
        let n = spec.stages.len();
        JobState {
            id,
            spec,
            arrival_seq,
            submit_time,
            stage_ids: vec![None; n],
            stage_done: vec![false; n],
            finish_time: None,
        }
    }

    /// Spec indices of stages that are ready (all parents done) but not
    /// yet submitted.
    pub fn ready_stages(&self) -> Vec<usize> {
        (0..self.spec.stages.len())
            .filter(|&i| {
                self.stage_ids[i].is_none()
                    && self.spec.stages[i]
                        .parents
                        .iter()
                        .all(|&p| self.stage_done[p])
            })
            .collect()
    }

    pub fn mark_submitted(&mut self, idx: usize, stage: StageId) {
        debug_assert!(self.stage_ids[idx].is_none());
        self.stage_ids[idx] = Some(stage);
    }

    /// Mark a stage finished; returns newly-ready spec indices.
    pub fn mark_done(&mut self, idx: usize) -> Vec<usize> {
        debug_assert!(!self.stage_done[idx]);
        self.stage_done[idx] = true;
        self.ready_stages()
    }

    pub fn is_complete(&self) -> bool {
        self.stage_done.iter().all(|&d| d)
    }
}

/// Record of a finished analytics job, consumed by the metrics layer.
#[derive(Clone, Debug)]
pub struct CompletedJob {
    pub job: JobId,
    pub user: UserId,
    /// Interned job-kind name (shared with the spec — no per-completion
    /// allocation).
    pub name: Arc<str>,
    /// Submission (arrival) time — `min(T_start)` in Eq. RT.
    pub submit: TimeUs,
    /// Completion of the last stage — `max(T_end)`.
    pub finish: TimeUs,
    /// Ground-truth job slot-time (seconds).
    pub slot_time: f64,
}

impl CompletedJob {
    /// Response time in seconds (§5.1.1).
    pub fn response_time(&self) -> f64 {
        crate::us_to_s(self.finish - self.submit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobSpec;

    fn chain_job() -> JobState {
        let spec = JobSpec::three_phase(1, "j", 0, 1.0, 1 << 20, 4, None);
        JobState::new(7, 0, 100, spec)
    }

    #[test]
    fn linear_chain_readiness() {
        let mut j = chain_job();
        assert_eq!(j.ready_stages(), vec![0]);
        j.mark_submitted(0, 100);
        assert_eq!(j.ready_stages(), Vec::<usize>::new());
        let ready = j.mark_done(0);
        assert_eq!(ready, vec![1]);
        j.mark_submitted(1, 101);
        let ready = j.mark_done(1);
        assert_eq!(ready, vec![2]);
        j.mark_submitted(2, 102);
        let ready = j.mark_done(2);
        assert_eq!(ready, vec![3]);
        j.mark_submitted(3, 103);
        assert!(!j.is_complete());
        assert!(j.mark_done(3).is_empty());
        assert!(j.is_complete());
    }

    #[test]
    fn diamond_dag_readiness() {
        // 0 → {1, 2} → 3
        let mut spec = JobSpec::three_phase(1, "d", 0, 1.0, 1 << 20, 4, None);
        spec.stages.truncate(4);
        spec.stages[1].parents = vec![0];
        spec.stages[2].parents = vec![0];
        spec.stages[3].parents = vec![1, 2];
        let mut j = JobState::new(1, 0, 0, spec);
        j.mark_submitted(0, 10);
        let r = j.mark_done(0);
        assert_eq!(r, vec![1, 2]);
        j.mark_submitted(1, 11);
        j.mark_submitted(2, 12);
        assert!(j.mark_done(1).is_empty()); // stage 3 still blocked on 2
        let r = j.mark_done(2);
        assert_eq!(r, vec![3]);
    }

    #[test]
    fn response_time_from_us() {
        let c = CompletedJob {
            job: 1,
            user: 1,
            name: "x".into(),
            submit: 1_000_000,
            finish: 3_500_000,
            slot_time: 1.0,
        };
        assert!((c.response_time() - 2.5).abs() < 1e-9);
    }
}
