//! Spark-style event logging (paper §5.1: "We enable event logging to
//! collect execution traces after the application has finished").
//!
//! A run emits a JSON-lines trace of job/task lifecycle events; the
//! `uwfq analyze` command (and external tooling) recomputes response
//! times and utilization from the trace alone — the same post-hoc
//! pipeline the paper uses to compute its metrics from Spark event logs.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::sim::SimReport;
use crate::util::jsonout::{self, Json};
use crate::workload::Workload;
use crate::{JobId, TimeUs};

/// One trace event (subset of Spark's SparkListenerEvent zoo, reduced to
/// what the paper's metrics need).
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    JobSubmitted {
        t: TimeUs,
        job: JobId,
        user: u32,
        name: String,
        slot_time: f64,
    },
    TaskStart {
        t: TimeUs,
        job: JobId,
        stage: u64,
        task: u64,
        core: usize,
    },
    TaskEnd {
        t: TimeUs,
        job: JobId,
        stage: u64,
        task: u64,
        core: usize,
    },
    JobCompleted {
        t: TimeUs,
        job: JobId,
    },
}

impl Event {
    pub fn time(&self) -> TimeUs {
        match self {
            Event::JobSubmitted { t, .. }
            | Event::TaskStart { t, .. }
            | Event::TaskEnd { t, .. }
            | Event::JobCompleted { t, .. } => *t,
        }
    }

    fn to_json(&self) -> Json {
        let (kind, mut fields): (&str, Vec<(&str, Json)>) = match self {
            Event::JobSubmitted {
                t,
                job,
                user,
                name,
                slot_time,
            } => (
                "JobSubmitted",
                vec![
                    ("t", jsonout::num(*t as f64)),
                    ("job", jsonout::num(*job as f64)),
                    ("user", jsonout::num(*user as f64)),
                    ("name", jsonout::s(name)),
                    ("slot_time", jsonout::num(*slot_time)),
                ],
            ),
            Event::TaskStart {
                t,
                job,
                stage,
                task,
                core,
            } => (
                "TaskStart",
                vec![
                    ("t", jsonout::num(*t as f64)),
                    ("job", jsonout::num(*job as f64)),
                    ("stage", jsonout::num(*stage as f64)),
                    ("task", jsonout::num(*task as f64)),
                    ("core", jsonout::num(*core as f64)),
                ],
            ),
            Event::TaskEnd {
                t,
                job,
                stage,
                task,
                core,
            } => (
                "TaskEnd",
                vec![
                    ("t", jsonout::num(*t as f64)),
                    ("job", jsonout::num(*job as f64)),
                    ("stage", jsonout::num(*stage as f64)),
                    ("task", jsonout::num(*task as f64)),
                    ("core", jsonout::num(*core as f64)),
                ],
            ),
            Event::JobCompleted { t, job } => (
                "JobCompleted",
                vec![
                    ("t", jsonout::num(*t as f64)),
                    ("job", jsonout::num(*job as f64)),
                ],
            ),
        };
        fields.push(("event", jsonout::s(kind)));
        jsonout::obj(fields)
    }

    fn from_json(v: &Json) -> Result<Event> {
        let kind = v
            .get("event")
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow!("event line missing 'event'"))?;
        let num = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| anyhow!("event missing '{k}'"))
        };
        Ok(match kind {
            "JobSubmitted" => Event::JobSubmitted {
                t: num("t")? as TimeUs,
                job: num("job")? as JobId,
                user: num("user")? as u32,
                name: v
                    .get("name")
                    .and_then(|x| x.as_str())
                    .unwrap_or("")
                    .to_string(),
                slot_time: num("slot_time")?,
            },
            "TaskStart" => Event::TaskStart {
                t: num("t")? as TimeUs,
                job: num("job")? as JobId,
                stage: num("stage")? as u64,
                task: num("task")? as u64,
                core: num("core")? as usize,
            },
            "TaskEnd" => Event::TaskEnd {
                t: num("t")? as TimeUs,
                job: num("job")? as JobId,
                stage: num("stage")? as u64,
                task: num("task")? as u64,
                core: num("core")? as usize,
            },
            "JobCompleted" => Event::JobCompleted {
                t: num("t")? as TimeUs,
                job: num("job")? as JobId,
            },
            other => return Err(anyhow!("unknown event kind '{other}'")),
        })
    }
}

/// Build the event stream of a finished simulation (requires the run to
/// have used `cfg.log_tasks = true` for task events).
pub fn events_of_run(workload: &Workload, report: &SimReport) -> Vec<Event> {
    let name_of: HashMap<JobId, (&str, u32, f64)> = report
        .completed
        .iter()
        .map(|c| (c.job, (&*c.name, c.user, c.slot_time)))
        .collect();
    let _ = workload;
    let mut events = Vec::new();
    for c in &report.completed {
        events.push(Event::JobSubmitted {
            t: c.submit,
            job: c.job,
            user: c.user,
            name: c.name.to_string(),
            slot_time: c.slot_time,
        });
        events.push(Event::JobCompleted {
            t: c.finish,
            job: c.job,
        });
    }
    for t in &report.task_log {
        let job = t.job;
        if name_of.contains_key(&job) {
            events.push(Event::TaskStart {
                t: t.started,
                job,
                stage: t.stage,
                task: t.task,
                core: t.core,
            });
            events.push(Event::TaskEnd {
                t: t.finished,
                job,
                stage: t.stage,
                task: t.task,
                core: t.core,
            });
        }
    }
    events.sort_by_key(|e| (e.time(), event_rank(e)));
    events
}

fn event_rank(e: &Event) -> u8 {
    match e {
        Event::JobSubmitted { .. } => 0,
        Event::TaskStart { .. } => 1,
        Event::TaskEnd { .. } => 2,
        Event::JobCompleted { .. } => 3,
    }
}

/// Write events as JSON lines.
pub fn write<P: AsRef<Path>>(path: P, events: &[Event]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(&path).with_context(|| format!("{:?}", path.as_ref()))?,
    );
    for e in events {
        let mut line = e.to_json().to_string_pretty();
        line.retain(|c| c != '\n');
        writeln!(f, "{line}")?;
    }
    f.flush()?;
    Ok(())
}

/// Read a JSON-lines event log.
pub fn read<P: AsRef<Path>>(path: P) -> Result<Vec<Event>> {
    let f = std::fs::File::open(&path).with_context(|| format!("{:?}", path.as_ref()))?;
    let mut events = Vec::new();
    for (i, line) in std::io::BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = jsonout::parse(&line).map_err(|e| anyhow!("line {}: {e}", i + 1))?;
        events.push(Event::from_json(&v)?);
    }
    Ok(events)
}

/// Post-hoc analysis of a trace — the §5.1.1 metrics recomputed from the
/// event log alone.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    pub jobs: usize,
    pub tasks: usize,
    pub mean_rt: f64,
    pub worst10_rt: f64,
    pub makespan_s: f64,
    pub utilization: f64,
    pub per_user_mean_rt: Vec<(u32, f64)>,
}

pub fn analyze(events: &[Event]) -> Result<TraceSummary> {
    let mut submit: HashMap<JobId, (TimeUs, u32)> = HashMap::new();
    let mut rts: Vec<f64> = Vec::new();
    let mut user_rts: HashMap<u32, Vec<f64>> = HashMap::new();
    let mut busy: u128 = 0;
    let mut tasks = 0usize;
    let mut cores_seen = 0usize;
    let mut t_max: TimeUs = 0;
    let mut task_start: HashMap<u64, TimeUs> = HashMap::new();

    for e in events {
        t_max = t_max.max(e.time());
        match e {
            Event::JobSubmitted { t, job, user, .. } => {
                submit.insert(*job, (*t, *user));
            }
            Event::JobCompleted { t, job } => {
                let (t0, user) = *submit
                    .get(job)
                    .ok_or_else(|| anyhow!("JobCompleted for unknown job {job}"))?;
                let rt = crate::us_to_s(t - t0);
                rts.push(rt);
                user_rts.entry(user).or_default().push(rt);
            }
            Event::TaskStart { t, task, core, .. } => {
                task_start.insert(*task, *t);
                cores_seen = cores_seen.max(core + 1);
            }
            Event::TaskEnd { t, task, .. } => {
                let t0 = task_start
                    .remove(task)
                    .ok_or_else(|| anyhow!("TaskEnd without TaskStart for {task}"))?;
                busy += (t - t0) as u128;
                tasks += 1;
            }
        }
    }
    let makespan_s = crate::us_to_s(t_max);
    let utilization = if makespan_s > 0.0 && cores_seen > 0 {
        busy as f64 / 1e6 / (cores_seen as f64 * makespan_s)
    } else {
        0.0
    };
    let mut per_user: Vec<(u32, f64)> = user_rts
        .into_iter()
        .map(|(u, rts)| (u, crate::util::stats::mean(&rts)))
        .collect();
    per_user.sort_by_key(|&(u, _)| u);
    Ok(TraceSummary {
        jobs: rts.len(),
        tasks,
        mean_rt: crate::util::stats::mean(&rts),
        worst10_rt: crate::util::stats::worst_frac_mean(&rts, 0.10),
        makespan_s,
        utilization,
        per_user_mean_rt: per_user,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::sched::PolicyKind;

    fn run_with_log() -> (Workload, SimReport) {
        let w = crate::workload::test_scenario2(1, 4, 0.5);
        let mut cfg = Config::default().with_cores(8).with_policy(PolicyKind::Uwfq);
        cfg.log_tasks = true;
        let rep = crate::sim::simulate(cfg, w.jobs.clone());
        (w, rep)
    }

    #[test]
    fn events_roundtrip_through_file() {
        let (w, rep) = run_with_log();
        let events = events_of_run(&w, &rep);
        assert!(!events.is_empty());
        let dir = std::env::temp_dir().join("uwfq_eventlog_test");
        let path = dir.join("trace.jsonl");
        write(&path, &events).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(events, back);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn analyze_matches_direct_metrics() {
        let (w, rep) = run_with_log();
        let events = events_of_run(&w, &rep);
        let sum = analyze(&events).unwrap();
        assert_eq!(sum.jobs, rep.completed.len());
        assert_eq!(sum.tasks, rep.task_log.len());
        let direct_mean = crate::util::stats::mean(
            &rep.completed
                .iter()
                .map(|c| c.response_time())
                .collect::<Vec<_>>(),
        );
        assert!((sum.mean_rt - direct_mean).abs() < 1e-9);
        assert!((sum.makespan_s - rep.makespan_s).abs() < 1e-9);
        assert!(sum.utilization > 0.5);
        assert_eq!(sum.per_user_mean_rt.len(), 4);
    }

    #[test]
    fn events_ordered_by_time() {
        let (w, rep) = run_with_log();
        let events = events_of_run(&w, &rep);
        for pair in events.windows(2) {
            assert!(pair[0].time() <= pair[1].time());
        }
    }

    #[test]
    fn read_rejects_garbage() {
        let dir = std::env::temp_dir().join("uwfq_eventlog_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"event\": \"Nope\", \"t\": 1}\n").unwrap();
        assert!(read(&path).is_err());
        std::fs::write(&path, "not json\n").unwrap();
        assert!(read(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn analyze_detects_inconsistent_trace() {
        let events = vec![Event::JobCompleted { t: 5, job: 1 }];
        assert!(analyze(&events).is_err());
    }
}
