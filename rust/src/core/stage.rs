//! Stage state — the equivalent of Spark's `TaskSetManager`: tracks the
//! task list, launch cursor, and running/finished counts for one stage.

use std::collections::VecDeque;

use super::task::{ResourceVec, TaskSpec};
use crate::{JobId, StageId, TimeUs, UserId};

#[derive(Clone, Debug)]
pub struct StageState {
    pub id: StageId,
    pub job: JobId,
    pub user: UserId,
    /// Index of this stage within its job's `stages` vector.
    pub idx: usize,
    pub tasks: Vec<TaskSpec>,
    /// Next task to launch (tasks are launched in partition order, like
    /// Spark's pending-task queue).
    pub next_task: usize,
    pub running: u32,
    pub finished: u32,
    pub submitted_at: TimeUs,
    /// Estimated sequential work of the whole stage, as given to the
    /// scheduler (perfect under the oracle estimator).
    pub est_slot_time: f64,
    /// Per-task resource demand (from the stage spec); unit on every
    /// legacy workload.
    pub demand: ResourceVec,
    /// Arrival sequence of the owning job (cached to keep the per-offer
    /// view construction free of job-map lookups — hot path).
    pub arrival_seq: u64,
    /// Arena slot of the owning job (engine-internal addressing — no
    /// id-map lookup on the completion path).
    pub job_slot: u32,
    /// Position of this stage in the engine's active list (swap-remove
    /// bookkeeping; maintained by the engine).
    pub active_pos: usize,
    /// Fault-injected tasks whose retry backoff elapsed, waiting for
    /// relaunch. Empty on the fault-free path.
    pub retry_queue: VecDeque<u32>,
    /// Sparse `(task_idx, failures)` ledger — failures are rare, so a
    /// linear scan beats a map. Empty on the fault-free path.
    pub fail_counts: Vec<(u32, u32)>,
}

impl StageState {
    pub fn pending(&self) -> u32 {
        (self.tasks.len() - self.next_task) as u32 + self.retry_queue.len() as u32
    }

    pub fn has_pending(&self) -> bool {
        self.next_task < self.tasks.len() || !self.retry_queue.is_empty()
    }

    pub fn is_complete(&self) -> bool {
        self.finished as usize == self.tasks.len()
    }

    /// Would one more clean finish complete the stage? The batched
    /// event core classifies completions *before* applying them, so it
    /// can tell "plain" finishes (deferrable notification) from
    /// stage-completing ones (must flush: they can retire stages and
    /// submit DAG children).
    pub fn completes_with_next_finish(&self) -> bool {
        self.finished as usize + 1 == self.tasks.len()
    }

    /// Launch the next pending task; returns its index. Ready retries go
    /// first (Spark relaunches failed tasks ahead of the virgin cursor).
    pub fn launch_next(&mut self) -> usize {
        debug_assert!(self.has_pending());
        let idx = match self.retry_queue.pop_front() {
            Some(t) => t as usize,
            None => {
                let i = self.next_task;
                self.next_task += 1;
                i
            }
        };
        self.running += 1;
        idx
    }

    pub fn task_finished(&mut self) {
        debug_assert!(self.running > 0);
        self.running -= 1;
        self.finished += 1;
    }

    /// A running task failed (fault injection): it leaves the core but is
    /// **not** finished — it re-enters via [`Self::requeue`] after its
    /// backoff.
    pub fn task_failed(&mut self) {
        debug_assert!(self.running > 0);
        self.running -= 1;
    }

    /// Re-enqueue a failed task once its retry backoff has elapsed.
    pub fn requeue(&mut self, task_idx: u32) {
        self.retry_queue.push_back(task_idx);
    }

    /// Failures recorded so far for `task_idx` — also the attempt number
    /// of the task's next launch.
    pub fn failures_of(&self, task_idx: u32) -> u32 {
        self.fail_counts
            .iter()
            .find(|&&(t, _)| t == task_idx)
            .map(|&(_, n)| n)
            .unwrap_or(0)
    }

    /// Charge one failure against `task_idx`; returns the new count.
    pub fn record_failure(&mut self, task_idx: u32) -> u32 {
        for e in &mut self.fail_counts {
            if e.0 == task_idx {
                e.1 += 1;
                return e.1;
            }
        }
        self.fail_counts.push((task_idx, 1));
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> StageState {
        StageState {
            id: 1,
            job: 1,
            user: 1,
            idx: 0,
            tasks: (0..n)
                .map(|i| TaskSpec {
                    range: (i as f64 / n as f64, (i + 1) as f64 / n as f64),
                    runtime_s: 0.1,
                    blocks: 1,
                    opcount: 1,
                })
                .collect(),
            next_task: 0,
            running: 0,
            finished: 0,
            submitted_at: 0,
            est_slot_time: 0.1 * n as f64,
            demand: ResourceVec::UNIT,
            arrival_seq: 0,
            job_slot: 0,
            active_pos: 0,
            retry_queue: VecDeque::new(),
            fail_counts: Vec::new(),
        }
    }

    #[test]
    fn lifecycle() {
        let mut s = mk(3);
        assert_eq!(s.pending(), 3);
        assert!(!s.is_complete());
        let a = s.launch_next();
        let b = s.launch_next();
        assert_eq!((a, b), (0, 1));
        assert_eq!(s.running, 2);
        assert_eq!(s.pending(), 1);
        s.task_finished();
        s.task_finished();
        assert_eq!(s.finished, 2);
        assert!(!s.is_complete());
        s.launch_next();
        assert!(s.completes_with_next_finish());
        s.task_finished();
        assert!(s.is_complete());
        assert!(!s.completes_with_next_finish());
        assert!(!s.has_pending());
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)] // debug_assert is compiled out in release
    fn launch_past_end_panics_in_debug() {
        let mut s = mk(1);
        s.launch_next();
        s.launch_next();
    }

    #[test]
    fn failure_requeue_lifecycle() {
        let mut s = mk(2);
        assert_eq!(s.launch_next(), 0);
        assert_eq!(s.launch_next(), 1);
        assert_eq!(s.pending(), 0);
        // Task 0 fails: off the core, not finished, not yet pending.
        s.task_failed();
        assert_eq!(s.record_failure(0), 1);
        assert_eq!(s.failures_of(0), 1);
        assert_eq!(s.pending(), 0);
        assert!(!s.has_pending());
        // Backoff elapses: the retry becomes pending and launches ahead
        // of the (exhausted) virgin cursor.
        s.requeue(0);
        assert_eq!(s.pending(), 1);
        assert!(s.has_pending());
        assert_eq!(s.launch_next(), 0);
        s.task_finished();
        s.task_finished();
        assert!(s.is_complete());
        assert_eq!(s.record_failure(0), 2, "ledger accumulates per task");
        assert_eq!(s.failures_of(1), 0);
    }
}
