//! Stage state — the equivalent of Spark's `TaskSetManager`: tracks the
//! task list, launch cursor, and running/finished counts for one stage.

use super::task::TaskSpec;
use crate::{JobId, StageId, TimeUs, UserId};

#[derive(Clone, Debug)]
pub struct StageState {
    pub id: StageId,
    pub job: JobId,
    pub user: UserId,
    /// Index of this stage within its job's `stages` vector.
    pub idx: usize,
    pub tasks: Vec<TaskSpec>,
    /// Next task to launch (tasks are launched in partition order, like
    /// Spark's pending-task queue).
    pub next_task: usize,
    pub running: u32,
    pub finished: u32,
    pub submitted_at: TimeUs,
    /// Estimated sequential work of the whole stage, as given to the
    /// scheduler (perfect under the oracle estimator).
    pub est_slot_time: f64,
    /// Arrival sequence of the owning job (cached to keep the per-offer
    /// view construction free of job-map lookups — hot path).
    pub arrival_seq: u64,
    /// Arena slot of the owning job (engine-internal addressing — no
    /// id-map lookup on the completion path).
    pub job_slot: u32,
    /// Position of this stage in the engine's active list (swap-remove
    /// bookkeeping; maintained by the engine).
    pub active_pos: usize,
}

impl StageState {
    pub fn pending(&self) -> u32 {
        (self.tasks.len() - self.next_task) as u32
    }

    pub fn has_pending(&self) -> bool {
        self.next_task < self.tasks.len()
    }

    pub fn is_complete(&self) -> bool {
        self.finished as usize == self.tasks.len()
    }

    /// Launch the next pending task; returns its index.
    pub fn launch_next(&mut self) -> usize {
        debug_assert!(self.has_pending());
        let idx = self.next_task;
        self.next_task += 1;
        self.running += 1;
        idx
    }

    pub fn task_finished(&mut self) {
        debug_assert!(self.running > 0);
        self.running -= 1;
        self.finished += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> StageState {
        StageState {
            id: 1,
            job: 1,
            user: 1,
            idx: 0,
            tasks: (0..n)
                .map(|i| TaskSpec {
                    range: (i as f64 / n as f64, (i + 1) as f64 / n as f64),
                    runtime_s: 0.1,
                    blocks: 1,
                    opcount: 1,
                })
                .collect(),
            next_task: 0,
            running: 0,
            finished: 0,
            submitted_at: 0,
            est_slot_time: 0.1 * n as f64,
            arrival_seq: 0,
            job_slot: 0,
            active_pos: 0,
        }
    }

    #[test]
    fn lifecycle() {
        let mut s = mk(3);
        assert_eq!(s.pending(), 3);
        assert!(!s.is_complete());
        let a = s.launch_next();
        let b = s.launch_next();
        assert_eq!((a, b), (0, 1));
        assert_eq!(s.running, 2);
        assert_eq!(s.pending(), 1);
        s.task_finished();
        s.task_finished();
        assert_eq!(s.finished, 2);
        assert!(!s.is_complete());
        s.launch_next();
        s.task_finished();
        assert!(s.is_complete());
        assert!(!s.has_pending());
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)] // debug_assert is compiled out in release
    fn launch_past_end_panics_in_debug() {
        let mut s = mk(1);
        s.launch_next();
        s.launch_next();
    }
}
