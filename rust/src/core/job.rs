//! Analytics-job and stage specifications.
//!
//! An *analytics job* (paper §3.1) is the highest abstraction level — the
//! unit users get utility from. It expands into one or more Spark stages
//! with dependencies; every stage inherits the job's user/job context so
//! the scheduler can enforce user-job fairness (§4.1.3).

use std::sync::Arc;

use super::task::ResourceVec;
use crate::{TimeUs, UserId};

/// Which of the paper's three micro-benchmark phases a stage implements.
/// `Generic` is used by trace-driven (macro) workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StagePhase {
    Load,
    Compute,
    Collect,
    Generic,
}

/// Piecewise-constant *cost density* over the stage's input `[0,1)`.
///
/// This is how task skew (§3.2, Fig. 3) is modeled: a stage's total
/// sequential work (`slot_time`) is distributed over its input data
/// non-uniformly; a partition covering fraction `[a,b)` of the input costs
/// `slot_time * integral(a,b)`. Splitting the input finer dilutes hot
/// regions across more tasks — exactly the mechanism by which runtime
/// partitioning fixes skew.
#[derive(Clone, Debug)]
pub struct CostProfile {
    /// (input fraction, relative cost weight); fractions sum to 1.
    regions: Vec<(f64, f64)>,
}

impl CostProfile {
    /// Uniform cost: every byte costs the same.
    pub fn uniform() -> Self {
        CostProfile {
            regions: vec![(1.0, 1.0)],
        }
    }

    /// A single hot region of `hot_frac` of the input whose per-byte cost is
    /// `multiplier`× the rest (Fig. 3's "one partition runs 5× longer" is
    /// `skewed(1/32, 5.0)` under 32-way default partitioning).
    pub fn skewed(hot_frac: f64, multiplier: f64) -> Self {
        assert!((0.0..1.0).contains(&hot_frac) && hot_frac > 0.0);
        assert!(multiplier > 0.0);
        CostProfile {
            regions: vec![(hot_frac, multiplier), (1.0 - hot_frac, 1.0)],
        }
    }

    /// Arbitrary piecewise profile; weights are relative, fractions must be
    /// positive and sum to ~1.
    pub fn from_regions(regions: Vec<(f64, f64)>) -> Self {
        assert!(!regions.is_empty());
        let total: f64 = regions.iter().map(|r| r.0).sum();
        assert!((total - 1.0).abs() < 1e-9, "fractions must sum to 1");
        assert!(regions.iter().all(|r| r.0 > 0.0 && r.1 >= 0.0));
        CostProfile { regions }
    }

    /// The raw `(fraction, weight)` regions — used by the idle-response
    /// memoization to derive a user-independent shape key.
    pub fn regions(&self) -> &[(f64, f64)] {
        &self.regions
    }

    /// Fraction of total stage cost falling in input range `[a, b)`.
    /// Normalized so that `integral(0, 1) == 1`.
    pub fn integral(&self, a: f64, b: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&a) && (0.0..=1.0).contains(&b) && a <= b);
        let norm: f64 = self.regions.iter().map(|(f, w)| f * w).sum();
        if norm == 0.0 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut lo = 0.0;
        for &(frac, w) in &self.regions {
            let hi = lo + frac;
            let ov_lo = a.max(lo);
            let ov_hi = b.min(hi);
            if ov_hi > ov_lo {
                acc += (ov_hi - ov_lo) * w;
            }
            lo = hi;
        }
        acc / norm
    }
}

/// One stage of an analytics job.
#[derive(Clone, Debug)]
pub struct StageSpec {
    pub phase: StagePhase,
    /// Indices (into `JobSpec::stages`) of parent stages that must finish
    /// before this stage can be submitted.
    pub parents: Vec<usize>,
    /// True for file-scan stages partitioned by the input partitioner;
    /// false for shuffle stages partitioned by AQE coalescing (§4.1.2).
    pub is_leaf_input: bool,
    /// Input size in bytes (drives size-based partitioning).
    pub input_bytes: u64,
    /// Total sequential work: time to execute the whole stage on one core
    /// (the paper's per-stage contribution to job slot-time `L_i`).
    pub slot_time: f64,
    /// Cost-density profile over the input (skew model).
    pub cost: CostProfile,
    /// Hard cap on partition count (e.g. 1 for result/collect stages).
    pub max_parallelism: Option<u32>,
    /// Op-chain length for the real execution backend (must be one of the
    /// AOT-compiled variants).
    pub opcount: u32,
    /// Per-task resource demand as a fraction of one core-slot's capacity
    /// per dimension. Unit = the paper's original one-task-per-slot model.
    pub demand: ResourceVec,
}

impl StageSpec {
    /// A simple stage with uniform cost.
    pub fn new(phase: StagePhase, parents: Vec<usize>, slot_time: f64, input_bytes: u64) -> Self {
        StageSpec {
            phase,
            parents,
            is_leaf_input: parents_is_leaf(&[]),
            input_bytes,
            slot_time,
            cost: CostProfile::uniform(),
            max_parallelism: None,
            opcount: 4,
            demand: ResourceVec::UNIT,
        }
    }
}

fn parents_is_leaf(parents: &[usize]) -> bool {
    parents.is_empty()
}

/// A user-submitted analytics job: user context + job context + stage DAG.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub user: UserId,
    /// Job-kind name ("tiny", "g42", ...). Interned (`Arc<str>`): jobs
    /// sharing a template share one allocation, and carrying the name
    /// into records (`CompletedJob`) is a refcount bump, not a clone —
    /// the per-completion `String` allocation was measurable on
    /// million-job streaming runs.
    pub name: Arc<str>,
    /// Absolute submission time in the workload timeline.
    pub arrival: TimeUs,
    /// UWFQ user weight `U_w` (1.0 = equal priority users).
    pub weight: f64,
    /// Stages in topological order (parents precede children).
    pub stages: Vec<StageSpec>,
}

impl JobSpec {
    /// Total job slot-time `L_i`: sequential single-core runtime across all
    /// stages (§3.3.1).
    pub fn slot_time(&self) -> f64 {
        self.stages.iter().map(|s| s.slot_time).sum()
    }

    /// The paper's micro-benchmark job shape (§5.2): a linear
    /// load → compute → collect chain where compute dominates. Each phase
    /// "has its own stages" (§5.2); the compute phase is two chained
    /// shuffle stages, which is what exposes stage-level schedulers (CFQ)
    /// to priority re-queueing between stages of the same job.
    ///
    /// `compute_time` is the compute-phase slot-time in seconds; load
    /// takes 8 % of compute and collect is a fixed small result stage.
    pub fn three_phase(
        user: UserId,
        name: &str,
        arrival: TimeUs,
        compute_time: f64,
        input_bytes: u64,
        opcount: u32,
        skew: Option<CostProfile>,
    ) -> Self {
        let load = StageSpec {
            phase: StagePhase::Load,
            parents: vec![],
            is_leaf_input: true,
            input_bytes,
            slot_time: compute_time * 0.08,
            cost: CostProfile::uniform(),
            max_parallelism: None,
            opcount: 1,
            demand: ResourceVec::UNIT,
        };
        let cost = skew.unwrap_or_else(CostProfile::uniform);
        let compute1 = StageSpec {
            phase: StagePhase::Compute,
            parents: vec![0],
            is_leaf_input: false,
            input_bytes,
            slot_time: compute_time * 0.5,
            cost: cost.clone(),
            max_parallelism: None,
            opcount,
            demand: ResourceVec::UNIT,
        };
        let compute2 = StageSpec {
            phase: StagePhase::Compute,
            parents: vec![1],
            is_leaf_input: false,
            input_bytes,
            slot_time: compute_time * 0.5,
            cost,
            max_parallelism: None,
            opcount,
            demand: ResourceVec::UNIT,
        };
        let collect = StageSpec {
            phase: StagePhase::Collect,
            parents: vec![2],
            is_leaf_input: false,
            input_bytes: 1024,
            slot_time: 0.004,
            cost: CostProfile::uniform(),
            max_parallelism: Some(1),
            opcount: 1,
            demand: ResourceVec::UNIT,
        };
        JobSpec {
            user,
            name: Arc::from(name),
            arrival,
            weight: 1.0,
            stages: vec![load, compute1, compute2, collect],
        }
    }

    /// Set every stage's per-task resource demand (builder style) — the
    /// workload layer's hook for trace/scenario-derived demand vectors.
    pub fn with_demand(mut self, demand: crate::core::task::ResourceVec) -> Self {
        for s in &mut self.stages {
            s.demand = demand;
        }
        self
    }

    /// Validate the DAG: topological parent order, no self-deps, and
    /// launchable resource demands.
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("job has no stages".into());
        }
        for (i, s) in self.stages.iter().enumerate() {
            for &p in &s.parents {
                if p >= i {
                    return Err(format!("stage {i} depends on later/self stage {p}"));
                }
            }
            if s.slot_time < 0.0 {
                return Err(format!("stage {i} has negative slot_time"));
            }
            if let Err(e) = s.demand.validate() {
                return Err(format!("stage {i}: {e}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_integral() {
        let c = CostProfile::uniform();
        assert!((c.integral(0.0, 1.0) - 1.0).abs() < 1e-12);
        assert!((c.integral(0.25, 0.75) - 0.5).abs() < 1e-12);
        assert_eq!(c.integral(0.3, 0.3), 0.0);
    }

    #[test]
    fn skewed_integral_matches_multiplier() {
        // hot 1/32 of data at 5x per-byte cost.
        let c = CostProfile::skewed(1.0 / 32.0, 5.0);
        let hot = c.integral(0.0, 1.0 / 32.0);
        let cold = c.integral(1.0 / 32.0, 2.0 / 32.0);
        assert!((hot / cold - 5.0).abs() < 1e-9);
        assert!((c.integral(0.0, 1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn integral_additive() {
        let c = CostProfile::skewed(0.1, 8.0);
        let whole = c.integral(0.0, 1.0);
        let parts = c.integral(0.0, 0.05) + c.integral(0.05, 0.4) + c.integral(0.4, 1.0);
        assert!((whole - parts).abs() < 1e-12);
    }

    #[test]
    fn from_regions_validates() {
        let c = CostProfile::from_regions(vec![(0.5, 2.0), (0.5, 1.0)]);
        assert!((c.integral(0.0, 0.5) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn from_regions_rejects_bad_fractions() {
        CostProfile::from_regions(vec![(0.5, 1.0), (0.4, 1.0)]);
    }

    #[test]
    fn three_phase_job_shape() {
        let j = JobSpec::three_phase(3, "short", 1_000_000, 2.25, 752 << 20, 4, None);
        assert_eq!(j.stages.len(), 4); // load, compute×2, collect
        assert!(j.validate().is_ok());
        assert_eq!(j.stages[1].parents, vec![0]);
        assert_eq!(j.stages[2].parents, vec![1]);
        assert_eq!(j.stages[3].parents, vec![2]);
        assert_eq!(j.stages[3].max_parallelism, Some(1));
        assert!(j.stages[0].is_leaf_input && !j.stages[1].is_leaf_input);
        // compute phase dominates
        let compute = j.stages[1].slot_time + j.stages[2].slot_time;
        assert!(compute > 0.8 * j.slot_time());
        assert_eq!(j.stages[1].slot_time, j.stages[2].slot_time);
    }

    #[test]
    fn validate_rejects_forward_deps() {
        let mut j = JobSpec::three_phase(1, "bad", 0, 1.0, 1024, 1, None);
        j.stages[0].parents = vec![2];
        assert!(j.validate().is_err());
    }

    #[test]
    fn validate_checks_stage_demands() {
        use crate::core::task::ResourceVec;
        let j = JobSpec::three_phase(1, "d", 0, 1.0, 1024, 1, None);
        assert!(j.stages.iter().all(|s| s.demand.is_unit()));
        let j = j.with_demand(ResourceVec::new(0.5, 0.25));
        assert!(j.stages.iter().all(|s| s.demand == ResourceVec::new(0.5, 0.25)));
        assert!(j.validate().is_ok());
        let bad = j.with_demand(ResourceVec::new(0.5, 1.5));
        let err = bad.validate().unwrap_err();
        assert!(err.contains("mem demand"), "{err}");
    }

    #[test]
    fn slot_time_sums_stages() {
        let j = JobSpec::three_phase(1, "j", 0, 1.0, 1024, 1, None);
        let expect: f64 = j.stages.iter().map(|s| s.slot_time).sum();
        assert_eq!(j.slot_time(), expect);
    }
}
