//! Task specifications: one task per input partition (paper §2.1.2).

use crate::{JobId, StageId, TaskId, TimeUs, UserId};

/// A schedulable task = the stage operation applied to one input partition.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// Input range as fractions `[lo, hi)` of the stage input.
    pub range: (f64, f64),
    /// Ground-truth runtime in seconds (simulation backend), derived from
    /// the stage cost profile + per-task overhead at partition time.
    pub runtime_s: f64,
    /// Number of data blocks this task covers (real execution backend).
    pub blocks: u32,
    /// Op-chain length (selects the AOT artifact variant).
    pub opcount: u32,
}

/// A task occupying an executor core. Tasks are **not preemptable** —
/// once launched they hold the core until completion (paper §3.2), which
/// is what makes priority inversion possible.
#[derive(Clone, Debug)]
pub struct RunningTask {
    pub task: TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: UserId,
    pub task_idx: usize,
    pub started: TimeUs,
    /// Simulated completion time (sim backend only; real backend completes
    /// via the worker pool).
    pub finish_at: TimeUs,
    /// Arena slot of the stage (engine-internal: O(1) completion path).
    pub stage_slot: u32,
}

/// Completed-task record for Gantt-style figures and utilization analysis.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub task: TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: UserId,
    pub core: usize,
    pub started: TimeUs,
    pub finished: TimeUs,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_spec_fields() {
        let t = TaskSpec {
            range: (0.0, 0.25),
            runtime_s: 1.5,
            blocks: 2,
            opcount: 4,
        };
        assert!(t.range.1 > t.range.0);
        assert_eq!(t.blocks, 2);
    }
}
