//! Task specifications: one task per input partition (paper §2.1.2).

use crate::{JobId, StageId, TaskId, TimeUs, UserId};

/// Per-task resource demand as a fraction of one core-slot's capacity in
/// each dimension (CPU, memory). The unit vector reproduces the paper's
/// original model — one task per identical slot — exactly; fractional
/// demands only influence multi-resource policies (DRF/BoPF) and the
/// per-dimension occupancy ledgers, never launch feasibility (demands are
/// validated into `(0, 1]`, so any task fits any free slot).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResourceVec {
    pub cpu: f64,
    pub mem: f64,
}

impl ResourceVec {
    /// Full-slot demand in both dimensions — the backward-compatible
    /// default everywhere a workload doesn't say otherwise.
    pub const UNIT: ResourceVec = ResourceVec { cpu: 1.0, mem: 1.0 };

    pub fn new(cpu: f64, mem: f64) -> Self {
        ResourceVec { cpu, mem }
    }

    /// Exactly the unit vector (the fast-path/back-compat predicate).
    pub fn is_unit(&self) -> bool {
        self.cpu == 1.0 && self.mem == 1.0
    }

    /// Does this demand fit within `capacity` on both dimensions?
    pub fn fits(&self, capacity: &ResourceVec) -> bool {
        self.cpu <= capacity.cpu && self.mem <= capacity.mem
    }

    /// The dominant (larger) component — DRF's scalarization.
    pub fn dominant(&self) -> f64 {
        self.cpu.max(self.mem)
    }

    /// Integer milli-units `(cpu, mem)` — the exact-arithmetic form used
    /// by the occupancy ledgers and the DRF share index (floats would
    /// drift between the incremental and reference-scan paths).
    pub fn milli(&self) -> (u32, u32) {
        (
            (self.cpu * 1000.0).round() as u32,
            (self.mem * 1000.0).round() as u32,
        )
    }

    /// Validate for use as a task demand: finite and in `(0, 1]` on both
    /// dimensions (a demand exceeding one slot could never launch).
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [("cpu", self.cpu), ("mem", self.mem)] {
            if !v.is_finite() || v <= 0.0 || v > 1.0 {
                return Err(format!("{name} demand must be finite and in (0, 1], got {v}"));
            }
        }
        Ok(())
    }
}

/// A schedulable task = the stage operation applied to one input partition.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// Input range as fractions `[lo, hi)` of the stage input.
    pub range: (f64, f64),
    /// Ground-truth runtime in seconds (simulation backend), derived from
    /// the stage cost profile + per-task overhead at partition time.
    pub runtime_s: f64,
    /// Number of data blocks this task covers (real execution backend).
    pub blocks: u32,
    /// Op-chain length (selects the AOT artifact variant).
    pub opcount: u32,
}

/// A task occupying an executor core. Tasks are **not preemptable** —
/// once launched they hold the core until completion (paper §3.2), which
/// is what makes priority inversion possible.
#[derive(Clone, Debug)]
pub struct RunningTask {
    pub task: TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: UserId,
    pub task_idx: usize,
    pub started: TimeUs,
    /// Simulated completion time (sim backend only; real backend completes
    /// via the worker pool).
    pub finish_at: TimeUs,
    /// Arena slot of the stage (engine-internal: O(1) completion path).
    pub stage_slot: u32,
    /// Monotone per-core launch sequence — stale timer events (spec
    /// wake-ups, completions of killed attempts) are dropped by sequence
    /// mismatch.
    pub seq: u64,
    /// Fault plan decided this attempt fails at `finish_at`.
    pub fails: bool,
    /// Attempt number (0 = first launch).
    pub attempt: u32,
    /// This occupancy is a speculative clone of a straggling attempt.
    pub is_clone: bool,
    /// Stage demand in milli-units `(cpu, mem)` — cached at launch so the
    /// completion-path occupancy charge needs no stage lookup.
    pub demand_milli: (u32, u32),
    /// Core of the competing attempt (original ↔ clone cross-link) while
    /// a speculation race is live.
    pub sibling: Option<usize>,
}

/// How a task attempt left its core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Completed; its runtime counts as goodput.
    Success,
    /// Fault-injected failure; retried after backoff.
    Failed,
    /// Speculation loser, killed when its sibling finished first.
    Killed,
    /// In-flight when its core crashed; requeued immediately.
    CrashLost,
}

/// Completed-task record for Gantt-style figures and utilization analysis.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub task: TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: UserId,
    pub core: usize,
    pub started: TimeUs,
    pub finished: TimeUs,
    /// Attempt number of this occupancy (0 on the fault-free path).
    pub attempt: u32,
    /// `Success` everywhere on the fault-free path.
    pub outcome: Outcome,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_spec_fields() {
        let t = TaskSpec {
            range: (0.0, 0.25),
            runtime_s: 1.5,
            blocks: 2,
            opcount: 4,
        };
        assert!(t.range.1 > t.range.0);
        assert_eq!(t.blocks, 2);
    }

    #[test]
    fn resource_vec_semantics() {
        let unit = ResourceVec::UNIT;
        assert!(unit.is_unit());
        assert_eq!(unit.milli(), (1000, 1000));
        assert_eq!(unit.dominant(), 1.0);
        assert!(unit.validate().is_ok());

        let d = ResourceVec::new(0.25, 0.5);
        assert!(!d.is_unit());
        assert!(d.fits(&unit));
        assert!(!unit.fits(&d));
        assert_eq!(d.dominant(), 0.5);
        assert_eq!(d.milli(), (250, 500));
        assert!(d.validate().is_ok());

        for bad in [
            ResourceVec::new(0.0, 0.5),
            ResourceVec::new(0.5, -0.1),
            ResourceVec::new(1.5, 0.5),
            ResourceVec::new(f64::NAN, 0.5),
            ResourceVec::new(0.5, f64::INFINITY),
        ] {
            assert!(bad.validate().is_err(), "{bad:?} must be rejected");
        }
    }
}
