//! Task specifications: one task per input partition (paper §2.1.2).

use crate::{JobId, StageId, TaskId, TimeUs, UserId};

/// A schedulable task = the stage operation applied to one input partition.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// Input range as fractions `[lo, hi)` of the stage input.
    pub range: (f64, f64),
    /// Ground-truth runtime in seconds (simulation backend), derived from
    /// the stage cost profile + per-task overhead at partition time.
    pub runtime_s: f64,
    /// Number of data blocks this task covers (real execution backend).
    pub blocks: u32,
    /// Op-chain length (selects the AOT artifact variant).
    pub opcount: u32,
}

/// A task occupying an executor core. Tasks are **not preemptable** —
/// once launched they hold the core until completion (paper §3.2), which
/// is what makes priority inversion possible.
#[derive(Clone, Debug)]
pub struct RunningTask {
    pub task: TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: UserId,
    pub task_idx: usize,
    pub started: TimeUs,
    /// Simulated completion time (sim backend only; real backend completes
    /// via the worker pool).
    pub finish_at: TimeUs,
    /// Arena slot of the stage (engine-internal: O(1) completion path).
    pub stage_slot: u32,
    /// Monotone per-core launch sequence — stale timer events (spec
    /// wake-ups, completions of killed attempts) are dropped by sequence
    /// mismatch.
    pub seq: u64,
    /// Fault plan decided this attempt fails at `finish_at`.
    pub fails: bool,
    /// Attempt number (0 = first launch).
    pub attempt: u32,
    /// This occupancy is a speculative clone of a straggling attempt.
    pub is_clone: bool,
    /// Core of the competing attempt (original ↔ clone cross-link) while
    /// a speculation race is live.
    pub sibling: Option<usize>,
}

/// How a task attempt left its core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Completed; its runtime counts as goodput.
    Success,
    /// Fault-injected failure; retried after backoff.
    Failed,
    /// Speculation loser, killed when its sibling finished first.
    Killed,
    /// In-flight when its core crashed; requeued immediately.
    CrashLost,
}

/// Completed-task record for Gantt-style figures and utilization analysis.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub task: TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: UserId,
    pub core: usize,
    pub started: TimeUs,
    pub finished: TimeUs,
    /// Attempt number of this occupancy (0 on the fault-free path).
    pub attempt: u32,
    /// `Success` everywhere on the fault-free path.
    pub outcome: Outcome,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_spec_fields() {
        let t = TaskSpec {
            range: (0.0, 0.25),
            runtime_s: 1.5,
            blocks: 2,
            opcount: 4,
        };
        assert!(t.range.1 > t.range.0);
        assert_eq!(t.blocks, 2);
    }
}
