//! `SchedCore` — the task scheduler + DAG scheduler of the long-running
//! analytics application (paper Fig. 1), independent of the execution
//! backend.
//!
//! The discrete-event simulator ([`crate::sim`]) and the real PJRT backend
//! ([`crate::exec`]) both drive this state machine with the same three
//! entry points: [`SchedCore::submit_job`], [`SchedCore::try_launch`] and
//! [`SchedCore::task_finished`].
//!
//! # Hot-path complexity contract
//!
//! Per-event cost is independent of the backlog (active-stage / in-flight
//! job count), matching the paper's O(log N) bound for UWFQ's virtual-time
//! machinery (§6.1) and extending it to the whole offer loop:
//!
//! * **State** lives in dense slab arenas ([`crate::core::arena::Slab`]):
//!   jobs and stages are addressed by recycled `u32` slots — O(1) direct
//!   indexing, no hashing, memory bounded by peak concurrency. External
//!   ids (`JobId`/`StageId`) stay monotone for records and policies; the
//!   only id→slot map consulted on the hot path is one `HashMap` lookup
//!   per *launch* (to resolve the policy's selected `StageId`).
//! * **Free cores** are a min-heap (lowest index first, preserving the
//!   seed's scan order): O(log cores) per launch/finish instead of a
//!   linear scan.
//! * **The active-stage list** removes by swap-remove with a position
//!   map (`StageState::active_pos`): O(1) per stage completion instead of
//!   `retain`'s O(active stages).
//! * **Selection** is incremental: the engine feeds the policy lifecycle
//!   notifications ([`crate::sched::Policy::on_task_launched`] /
//!   `on_task_finished` / `on_stage_finish`) and asks
//!   [`crate::sched::Policy::select_next`], which answers from the
//!   policy's own priority index — a lazily-invalidated binary heap
//!   (FIFO, Fair, CFQ, UWFQ) or a two-level heap (UJF). Per-event cost:
//!   FIFO/CFQ O(log S); Fair/UWFQ/UJF amortized O(log S) — each engine
//!   event pushes O(1) heap entries, stale entries are discarded or
//!   re-keyed when they surface (see [`crate::sched::index`] for the
//!   invalidation invariants).
//!
//! The snapshot-scan path (`StageView` slice + `Policy::select`) is
//! retained as the executable *specification*: under `debug_assertions`
//! every incremental pick is cross-checked against it, and
//! [`SchedCore::force_scan_select`] switches a core to pure scan
//! selection so differential tests can assert schedule equivalence
//! (ties included) in release builds too.
//!
//! # Batched mode
//!
//! [`SchedCore::set_batching`] arms the batched event core (used by the
//! simulator's calendar backend, see `crate::sim`): clean non-completing
//! finish notifications are *deferred* into one coalesced
//! [`crate::sched::Policy::on_tasks_finished`] call, flushed before any
//! other policy interaction (so every selection still sees exactly the
//! per-event state), and for `static_keys` policies
//! [`SchedCore::try_launch_into`] launches a whole quantum from the
//! selected stage before re-selecting — with static keys the per-launch
//! loop provably re-picks the same stage until it exhausts, so the
//! quantum reproduces the per-event schedule bit-for-bit.
//! [`SchedCore::classify_task_event`] tells the simulator which events
//! are batchable and [`SchedCore::can_launch`] makes the post-event
//! offer skippable when it provably cannot launch (no pending work or
//! no usable free core — the offer-loop postcondition).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::arena::Slab;
use super::dag::{CompletedJob, JobState};
use super::job::JobSpec;
use super::stage::StageState;
use super::task::{Outcome, ResourceVec, RunningTask, TaskRecord, TaskSpec};
use crate::config::Config;
use crate::estimate::RuntimeEstimator;
use crate::fault::{Fate, FaultPlan, FaultStats};
use crate::partition::PartitionScheme;
use crate::sched::{Policy, StageMeta, StageView};
use crate::{s_to_us, us_to_s, JobId, StageId, TimeUs, UserId};

/// Bytes of one data block — must match the AOT artifact geometry
/// (4096 rows × 8 cols × 4 bytes).
pub const BLOCK_BYTES: u64 = 4096 * 8 * 4;

/// A task-launch decision handed to the backend.
#[derive(Clone, Debug)]
pub struct Launch {
    pub core: usize,
    pub task: crate::TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: crate::UserId,
    pub task_idx: usize,
    /// Ground-truth runtime (simulation backend).
    pub runtime_s: f64,
    /// Work descriptor for the real backend.
    pub blocks: u32,
    pub opcount: u32,
    /// When this occupancy leaves the core: completion, or — when
    /// `fails` — the fault-injected failure instant. On the fault-free
    /// path this is exactly `now + s_to_us(runtime_s)`.
    pub finish_at: TimeUs,
    /// Fault plan decided this attempt fails at `finish_at`.
    pub fails: bool,
    /// Engine launch sequence for stale-event detection (simulator).
    pub seq: u64,
    /// When set, the simulator schedules a speculation check at this
    /// time (the attempt is a straggler past the `spec_mult` threshold).
    pub spec_wake_at: Option<TimeUs>,
}

/// Pre-classification of a scheduled task event
/// ([`SchedCore::classify_task_event`]) — read-only, so the simulator
/// can decide *before* applying the event whether it is batchable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskEventClass {
    /// Clean, unraced finish that leaves its stage incomplete: eligible
    /// for same-timestamp batching (its policy notification coalesces
    /// and its offer defers).
    Plain,
    /// Fault-injected failure — [`SchedCore::task_event`] will return
    /// [`TaskEvent::Failed`].
    Fail,
    /// A scheduling boundary: the finish completes its stage (DAG
    /// advances, new stages may submit) or resolves a speculation race
    /// (a second core frees). Handle per-event.
    Boundary,
}

/// What happened when a scheduled task event fired ([`SchedCore::task_event`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskEvent {
    /// The attempt completed (stage/DAG state advanced).
    Finished,
    /// The attempt failed; re-enqueue `task` on `stage` at `retry_at`.
    Failed {
        stage: StageId,
        task: u32,
        retry_at: TimeUs,
    },
}

pub struct SchedCore {
    pub cfg: Config,
    pub policy: Box<dyn Policy>,
    partitioner: Box<dyn PartitionScheme>,
    estimator: Box<dyn RuntimeEstimator>,
    /// Live jobs, slot-addressed (external `JobId`s stay monotone).
    jobs: Slab<JobState>,
    /// Live stages, slot-addressed.
    stages: Slab<StageState>,
    /// External stage id → arena slot (policy selections come back as
    /// external ids; one lookup per launch).
    stage_slots: HashMap<StageId, u32>,
    /// External job id → arena slot (diagnostics / backends address jobs
    /// by external id; off the per-event hot path).
    job_slots: HashMap<JobId, u32>,
    /// Submitted, not-yet-complete stage slots. Unordered — removal is
    /// swap-remove via `StageState::active_pos`.
    active: Vec<u32>,
    cores: Vec<Option<RunningTask>>,
    /// Idle core indices, lowest first (same pick order as the seed's
    /// linear scan).
    free_cores: BinaryHeap<Reverse<usize>>,
    next_job: JobId,
    next_stage: StageId,
    next_task: crate::TaskId,
    arrival_seq: u64,
    /// Finished analytics jobs, in completion order.
    pub completed: Vec<CompletedJob>,
    /// Per-task records (only when `cfg.log_tasks`).
    pub task_log: Vec<TaskRecord>,
    /// Scratch buffer for stage views (scan/debug path only).
    views_buf: Vec<StageView>,
    /// Use the snapshot-scan `Policy::select` path for every selection
    /// instead of the incremental index — the reference semantics for
    /// differential tests. Off (incremental) by default.
    pub force_scan_select: bool,
    /// Total pending (queued, unlaunched) tasks across all active
    /// stages — O(1) mirror of [`SchedCore::pending_task_count`] so the
    /// [`SchedCore::can_launch`] offer guard costs nothing per event.
    pending_total: u32,
    /// Batched mode ([`SchedCore::set_batching`]): defer plain finish
    /// notifications + launch multi-task quanta. Off by default — the
    /// per-event path stays byte-for-byte the executable specification.
    batch: bool,
    /// Deferred `(stage, slot)` finish notifications, delivered as one
    /// `Policy::on_tasks_finished` before the next policy interaction.
    finish_batch: Vec<(StageId, u32)>,
    // ---- fault machinery (inert when `fault_on` is false) ----------------
    /// The run's deterministic fault schedule (`None` ⇔ faults off).
    plan: Option<FaultPlan>,
    /// Cached `cfg.fault.enabled()` — every fault branch gates on this,
    /// which is what keeps the zero-rate path byte-identical.
    fault_on: bool,
    /// Crashed cores awaiting recovery (never offered work).
    blacklisted: Vec<bool>,
    /// Free-heap membership per core — fault paths can otherwise push a
    /// core that is already queued (e.g. recover racing a stale entry).
    in_heap: Vec<bool>,
    /// Per-core crash counter indexing the plan's crash-gap sequence.
    crash_counts: Vec<u64>,
    /// Slots retired by cross-shard core lending ([`SchedCore::set_cores`]):
    /// never offered work, reclaimed lazily from the free heap exactly
    /// like blacklisted cores. Physical slots only ever grow; shrink
    /// retires in place so every per-core vector keeps stable indices.
    retired: Vec<bool>,
    /// Monotone launch sequence: stale timer events (completions or spec
    /// wake-ups of attempts that died first) are dropped on mismatch.
    launch_seq: u64,
    /// Occupied cores (blacklisted idle cores are neither busy nor free).
    busy: usize,
    /// Retry/speculation/crash counters + the goodput-vs-waste ledger.
    pub fault_stats: FaultStats,
    /// Per-dimension goodput ledger in milli-demand-µs: each resolved
    /// occupancy's elapsed core-µs scaled by its (cpu, mem) demand in
    /// milli-units. Unit demands reduce each dimension to exactly
    /// `1000 × good_us` — the resource-vector twin of the scalar ledger.
    res_good_mmus: [u128; 2],
    /// Per-dimension waste ledger (kills, failures, crash losses) in
    /// milli-demand-µs.
    res_wasted_mmus: [u128; 2],
}

impl SchedCore {
    pub fn new(
        cfg: Config,
        policy: Box<dyn Policy>,
        partitioner: Box<dyn PartitionScheme>,
        estimator: Box<dyn RuntimeEstimator>,
    ) -> Self {
        let cores = cfg.cores as usize;
        let fault_on = cfg.fault.enabled();
        let plan = fault_on.then(|| FaultPlan::new(cfg.fault.clone()));
        SchedCore {
            cfg,
            policy,
            partitioner,
            estimator,
            jobs: Slab::new(),
            stages: Slab::new(),
            stage_slots: HashMap::new(),
            job_slots: HashMap::new(),
            active: Vec::new(),
            cores: vec![None; cores],
            free_cores: (0..cores).map(Reverse).collect(),
            next_job: 1,
            next_stage: 1,
            next_task: 1,
            arrival_seq: 0,
            completed: Vec::new(),
            task_log: Vec::new(),
            views_buf: Vec::new(),
            force_scan_select: false,
            pending_total: 0,
            batch: false,
            finish_batch: Vec::new(),
            plan,
            fault_on,
            blacklisted: vec![false; cores],
            in_heap: vec![true; cores],
            crash_counts: vec![0; cores],
            retired: vec![false; cores],
            launch_seq: 0,
            busy: 0,
            fault_stats: FaultStats::default(),
            res_good_mmus: [0; 2],
            res_wasted_mmus: [0; 2],
        }
    }

    /// Build the policy/partitioner/estimator triple a [`Config`]
    /// describes (shared by [`SchedCore::from_config`] and
    /// [`SchedCore::reset`] so both paths are constructed identically).
    #[allow(clippy::type_complexity)]
    fn parts_from_config(
        cfg: &Config,
    ) -> (
        Box<dyn Policy>,
        Box<dyn PartitionScheme>,
        Box<dyn RuntimeEstimator>,
    ) {
        let policy = crate::sched::make_policy(
            cfg.policy,
            cfg.cores,
            cfg.grace_rsec,
            cfg.bopf_burst_rsec,
        );
        let partitioner = crate::partition::make_scheme(
            cfg.scheme,
            cfg.cores,
            cfg.max_partition_bytes,
            cfg.advisory_partition_bytes,
            cfg.atr,
        );
        let estimator: Box<dyn RuntimeEstimator> = if cfg.estimator_sigma > 0.0 {
            Box::new(crate::estimate::Noisy::new(cfg.estimator_sigma, cfg.seed ^ 0xE57))
        } else {
            Box::new(crate::estimate::Oracle::new())
        };
        (policy, partitioner, estimator)
    }

    /// Build a core from a [`Config`] using its policy/scheme/estimator
    /// settings — the standard constructor for experiments.
    pub fn from_config(cfg: Config) -> Self {
        let (policy, partitioner, estimator) = SchedCore::parts_from_config(&cfg);
        SchedCore::new(cfg, policy, partitioner, estimator)
    }

    /// Re-arm the core for a fresh run under `cfg`, recycling every bulk
    /// allocation: slab arenas, id→slot maps, the active list, the core
    /// table, the free-core heap and the scan scratch buffer all keep
    /// their capacity. The policy, partitioner and estimator are rebuilt
    /// from the config (they are small and carry per-run state, including
    /// the noisy estimator's RNG), and all id counters restart — post-reset
    /// behaviour is observationally identical to
    /// `SchedCore::from_config(cfg)`, which is what lets the sweep
    /// engine's workers reuse one core across cells without perturbing
    /// results. `force_scan_select` is preserved.
    pub fn reset(&mut self, cfg: Config) {
        let (policy, partitioner, estimator) = SchedCore::parts_from_config(&cfg);
        let cores = cfg.cores as usize;
        self.cfg = cfg;
        self.policy = policy;
        self.partitioner = partitioner;
        self.estimator = estimator;
        self.jobs.clear();
        self.stages.clear();
        self.stage_slots.clear();
        self.job_slots.clear();
        self.active.clear();
        self.cores.clear();
        self.cores.resize(cores, None);
        self.free_cores.clear();
        for c in 0..cores {
            self.free_cores.push(Reverse(c));
        }
        self.next_job = 1;
        self.next_stage = 1;
        self.next_task = 1;
        self.arrival_seq = 0;
        self.completed.clear();
        self.task_log.clear();
        self.views_buf.clear();
        // `batch` is preserved like `force_scan_select` (both are
        // observationally neutral run-mode switches the driver re-arms).
        self.pending_total = 0;
        self.finish_batch.clear();
        // Fault machinery re-derives from the new config; every per-core
        // flag and counter starts over (reset-vs-fresh differential).
        self.fault_on = self.cfg.fault.enabled();
        self.plan = self
            .fault_on
            .then(|| FaultPlan::new(self.cfg.fault.clone()));
        self.blacklisted.clear();
        self.blacklisted.resize(cores, false);
        self.in_heap.clear();
        self.in_heap.resize(cores, true);
        self.crash_counts.clear();
        self.crash_counts.resize(cores, 0);
        self.retired.clear();
        self.retired.resize(cores, false);
        self.launch_seq = 0;
        self.busy = 0;
        self.fault_stats = FaultStats::default();
        self.res_good_mmus = [0; 2];
        self.res_wasted_mmus = [0; 2];
    }

    // ---- submission -----------------------------------------------------

    /// Submit an analytics job (paper §4.1.3: user context + job context
    /// arrive with the job). Returns its id.
    pub fn submit_job(&mut self, now: TimeUs, spec: JobSpec) -> anyhow::Result<JobId> {
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        let id = self.next_job;
        self.next_job += 1;
        let seq = self.arrival_seq;
        self.arrival_seq += 1;

        let est_slot = self.estimator.job_slot_time(id, &spec);
        self.flush_finish_batch();
        self.policy.on_job_arrival(
            us_to_s(now),
            &crate::sched::JobMeta {
                job: id,
                user: spec.user,
                weight: spec.weight,
                est_slot_time: est_slot,
                arrival_seq: seq,
            },
        );

        let job = JobState::new(id, seq, now, spec);
        let ready = job.ready_stages();
        let slot = self.jobs.insert(job);
        self.job_slots.insert(id, slot);
        for idx in ready {
            self.submit_stage(now, slot, idx);
        }
        Ok(id)
    }

    /// Partition one stage into tasks and hand it to the task scheduler.
    fn submit_stage(&mut self, now: TimeUs, job_slot: u32, idx: usize) {
        let job = self.jobs.get(job_slot);
        let job_id = job.id;
        let user = job.spec.user;
        let arrival_seq = job.arrival_seq;
        let spec = &job.spec.stages[idx];
        let demand = spec.demand;
        let est = self.estimator.stage_slot_time(job_id, idx, spec);

        let ranges = self.partitioner.partition(spec, est);
        let blocks_total = (spec.input_bytes.div_ceil(BLOCK_BYTES)).max(1);
        let tasks: Vec<TaskSpec> = ranges
            .iter()
            .map(|&(lo, hi)| TaskSpec {
                range: (lo, hi),
                runtime_s: spec.slot_time * spec.cost.integral(lo, hi) + self.cfg.task_overhead,
                blocks: (((hi - lo) * blocks_total as f64).round() as u32).max(1),
                opcount: spec.opcount,
            })
            .collect();

        let stage_id = self.next_stage;
        self.next_stage += 1;
        let pending = tasks.len() as u32;
        let stage = StageState {
            id: stage_id,
            job: job_id,
            user,
            idx,
            tasks,
            next_task: 0,
            running: 0,
            finished: 0,
            submitted_at: now,
            est_slot_time: est,
            demand,
            arrival_seq,
            job_slot,
            active_pos: self.active.len(),
            retry_queue: std::collections::VecDeque::new(),
            fail_counts: Vec::new(),
        };
        let slot = self.stages.insert(stage);
        self.active.push(slot);
        self.stage_slots.insert(stage_id, slot);
        self.jobs.get_mut(job_slot).mark_submitted(idx, stage_id);
        self.pending_total += pending;
        self.flush_finish_batch();
        self.policy.on_stage_submit(
            us_to_s(now),
            &StageMeta {
                stage: stage_id,
                slot,
                job: job_id,
                user,
                est_slot_time: est,
                stage_idx: idx,
                arrival_seq,
                pending,
                demand,
            },
        );
    }

    // ---- batched event core ----------------------------------------------

    /// Arm/disarm batched mode (see the module docs). The simulator's
    /// calendar backend turns this on; everything else runs per-event.
    pub fn set_batching(&mut self, on: bool) {
        debug_assert!(self.finish_batch.is_empty(), "toggled mid-batch");
        self.batch = on;
    }

    /// Deliver deferred finish notifications as one coalesced
    /// `Policy::on_tasks_finished`. Called before *every* policy
    /// interaction, so selections always see exactly the state the
    /// per-event path would have built.
    fn flush_finish_batch(&mut self) {
        if self.finish_batch.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.finish_batch);
        self.policy.on_tasks_finished(&batch);
        self.finish_batch = batch;
        self.finish_batch.clear();
    }

    /// True iff an offer could launch something: pending work exists and
    /// a usable (free, non-blacklisted) core is available. The offer
    /// loop's postcondition is exactly `!can_launch()`, so events that
    /// leave this false can skip their post-event offer without changing
    /// the schedule.
    pub fn can_launch(&mut self) -> bool {
        self.pending_total > 0 && self.peek_free().is_some()
    }

    // ---- free-core heap -------------------------------------------------

    /// Offer a core back to the scheduler. Deduplicated: fault paths
    /// (recover racing a stale idle entry) may offer a core that is
    /// already queued.
    fn push_free(&mut self, core: usize) {
        if !self.in_heap[core] {
            self.in_heap[core] = true;
            self.free_cores.push(Reverse(core));
        }
    }

    /// Lowest free usable core, without consuming it. Stale entries for
    /// blacklisted or retired cores are reclaimed lazily here.
    fn peek_free(&mut self) -> Option<usize> {
        while let Some(&Reverse(core)) = self.free_cores.peek() {
            if self.blacklisted[core] || self.retired[core] {
                self.free_cores.pop();
                self.in_heap[core] = false;
            } else {
                return Some(core);
            }
        }
        None
    }

    fn pop_free(&mut self) -> Option<usize> {
        let core = self.peek_free()?;
        self.free_cores.pop();
        self.in_heap[core] = false;
        Some(core)
    }

    /// Core-µs a finished/killed occupancy consumed, split into the
    /// goodput-vs-waste ledger (per-user detail only when faults are on —
    /// the aggregate feeds utilization on every run). `demand_milli`
    /// additionally scales the elapsed time into the per-dimension
    /// resource ledgers (exact integer arithmetic).
    fn charge(&mut self, user: UserId, elapsed: u128, good: bool, demand_milli: (u32, u32)) {
        let res = if good {
            self.fault_stats.good_us += elapsed;
            &mut self.res_good_mmus
        } else {
            self.fault_stats.wasted_us += elapsed;
            &mut self.res_wasted_mmus
        };
        res[0] += elapsed * demand_milli.0 as u128;
        res[1] += elapsed * demand_milli.1 as u128;
        if self.fault_on {
            let e = self.fault_stats.per_user.entry(user).or_insert((0, 0));
            if good {
                e.0 += elapsed;
            } else {
                e.1 += elapsed;
            }
        }
    }

    fn log_task(&mut self, rt: &RunningTask, core: usize, now: TimeUs, outcome: Outcome) {
        if self.cfg.log_tasks {
            self.task_log.push(TaskRecord {
                task: rt.task,
                stage: rt.stage,
                job: rt.job,
                user: rt.user,
                core,
                started: rt.started,
                finished: now,
                attempt: rt.attempt,
                outcome,
            });
        }
    }

    // ---- launching ------------------------------------------------------

    /// Snapshot-scan selection over the live stages (the reference
    /// semantics). O(active stages) — debug cross-check and
    /// `force_scan_select` only.
    fn scan_select(&mut self, now_s: f64) -> Option<StageId> {
        let mut views = std::mem::take(&mut self.views_buf);
        views.clear();
        for &slot in &self.active {
            let s = self.stages.get(slot);
            views.push(StageView {
                stage: s.id,
                slot,
                job: s.job,
                user: s.user,
                stage_idx: s.idx,
                running: s.running,
                pending: s.pending(),
                arrival_seq: s.arrival_seq,
                demand: s.demand,
            });
        }
        let picked = self.policy.select(now_s, &views).map(|i| {
            debug_assert!(views[i].pending > 0, "policy picked stage w/o pending");
            views[i].stage
        });
        self.views_buf = views;
        picked
    }

    /// One selection through the configured path, with the debug
    /// cross-check of incremental vs. reference-scan semantics. Returns
    /// the stage's external id *and* arena slot — the incremental path
    /// answers both from the policy index, dropping the id→slot hash
    /// lookup from the launch hot path.
    fn select_stage(&mut self, now_s: f64) -> Option<(StageId, u32)> {
        if self.force_scan_select {
            let sid = self.scan_select(now_s)?;
            let &slot = self
                .stage_slots
                .get(&sid)
                .expect("policy selected a live stage");
            return Some((sid, slot));
        }
        let picked = self.policy.select_next(now_s);
        #[cfg(debug_assertions)]
        {
            let reference = self.scan_select(now_s);
            debug_assert_eq!(
                picked.map(|(s, _)| s),
                reference,
                "incremental selection diverged from reference scan ({})",
                self.policy.name()
            );
            if let Some((sid, slot)) = picked {
                debug_assert_eq!(
                    self.stage_slots.get(&sid),
                    Some(&slot),
                    "policy index returned a stale slot"
                );
            }
        }
        picked
    }

    /// Fill free cores with the highest-priority pending tasks. Returns the
    /// launch list for the backend to execute.
    ///
    /// Allocates a fresh `Vec` per call — convenience wrapper for tests and
    /// cold paths; event loops should hold a reusable buffer and call
    /// [`SchedCore::try_launch_into`] instead.
    pub fn try_launch(&mut self, now: TimeUs) -> Vec<Launch> {
        let mut launches = Vec::new();
        self.try_launch_into(now, &mut launches);
        launches
    }

    /// [`SchedCore::try_launch`] into a caller-owned buffer (cleared
    /// first): the per-event `Vec<Launch>` allocation disappears from the
    /// hot path — simulators keep one buffer for the whole run.
    pub fn try_launch_into(&mut self, now: TimeUs, launches: &mut Vec<Launch>) {
        launches.clear();
        if self.active.is_empty() || self.free_cores.is_empty() {
            return; // nothing to do — keep the congested path free
        }
        self.flush_finish_batch();
        let now_s = us_to_s(now);
        // Static keys: the per-launch loop provably re-selects the same
        // stage until it exhausts (its key never changes and the id
        // tiebreak is fixed), so batched mode launches a whole quantum
        // per selection with one coalesced notification.
        let quantum = self.batch && !self.force_scan_select && self.policy.static_keys();
        while let Some(core) = self.peek_free() {
            let Some((sid, slot)) = self.select_stage(now_s) else {
                break;
            };
            self.pop_free();
            self.launch_one(now, sid, slot, core, launches);
            let mut n: u32 = 1;
            if quantum {
                while self.stages.get(slot).pending() > 0 {
                    let Some(c2) = self.peek_free() else {
                        break;
                    };
                    self.pop_free();
                    self.launch_one(now, sid, slot, c2, launches);
                    n += 1;
                }
            }
            if n == 1 {
                self.policy.on_task_launched(sid, slot);
            } else {
                self.policy.on_tasks_launched(sid, slot, n);
            }
        }
    }

    /// Launch one task of stage `sid` (arena `slot`) onto an
    /// already-popped free `core`. Engine state only — the policy launch
    /// notification is the caller's, so quanta can coalesce it.
    fn launch_one(
        &mut self,
        now: TimeUs,
        sid: StageId,
        slot: u32,
        core: usize,
        launches: &mut Vec<Launch>,
    ) {
        let stage = self.stages.get_mut(slot);
        // Core-slot capacity is the unit vector in both dimensions;
        // demands are validated into (0, 1] at submission, so every
        // pending task fits every free slot — the invariant is asserted
        // at the launch boundary, where an over-demand would over-commit.
        debug_assert!(
            stage.demand.fits(&ResourceVec::UNIT),
            "task demand exceeds core-slot capacity"
        );
        let demand_milli = stage.demand.milli();
        let task_idx = stage.launch_next();
        // Decide this attempt's fate from the deterministic plan.
        let attempt = if self.fault_on {
            stage.failures_of(task_idx as u32)
        } else {
            0
        };
        let t = &stage.tasks[task_idx];
        let mut fails = false;
        let mut dur_us = s_to_us(t.runtime_s);
        let mut spec_wake_at = None;
        if let Some(plan) = &self.plan {
            match plan.fate(stage.arrival_seq, stage.idx, task_idx as u32, attempt) {
                Fate::Clean => {}
                Fate::Fail { frac } => {
                    fails = true;
                    dur_us = s_to_us(frac * t.runtime_s).max(1);
                }
                Fate::Straggle { mult } => {
                    dur_us = s_to_us(mult * t.runtime_s);
                    let spec_mult = plan.config().spec_mult;
                    if spec_mult > 0.0 && mult > spec_mult {
                        spec_wake_at = Some(now + s_to_us(spec_mult * t.runtime_s).max(1));
                    }
                }
            }
        }
        let finish_at = now + dur_us;
        let task_id = self.next_task;
        self.next_task += 1;
        self.launch_seq += 1;
        let seq = self.launch_seq;
        let launch = Launch {
            core,
            task: task_id,
            stage: sid,
            job: stage.job,
            user: stage.user,
            task_idx,
            runtime_s: t.runtime_s,
            blocks: t.blocks,
            opcount: t.opcount,
            finish_at,
            fails,
            seq,
            spec_wake_at,
        };
        self.cores[core] = Some(RunningTask {
            task: task_id,
            stage: sid,
            job: stage.job,
            user: stage.user,
            task_idx,
            started: now,
            finish_at,
            stage_slot: slot,
            seq,
            fails,
            attempt,
            is_clone: false,
            sibling: None,
            demand_milli,
        });
        self.busy += 1;
        debug_assert!(self.pending_total > 0);
        self.pending_total -= 1;
        launches.push(launch);
    }

    // ---- completion -----------------------------------------------------

    /// A task finished on `core` (backend callback). Advances stage/job/DAG
    /// state; newly-ready stages are submitted. Call `try_launch` after.
    pub fn task_finished(&mut self, now: TimeUs, core: usize) {
        let rt = self.cores[core]
            .take()
            .expect("task_finished on idle core");
        self.busy -= 1;
        self.push_free(core);
        // Speculation race resolved: first finisher wins, the sibling is
        // killed and its core freed. Only the winner advances stage state.
        if let Some(sib) = rt.sibling {
            self.kill_sibling(now, sib, rt.is_clone);
        }
        self.charge(rt.user, (now - rt.started) as u128, true, rt.demand_milli);
        self.log_task(&rt, core, now, Outcome::Success);
        let stage = self.stages.get_mut(rt.stage_slot);
        stage.task_finished();
        let complete = stage.is_complete();
        let stage_idx = stage.idx;
        let job_slot = stage.job_slot;
        let active_pos = stage.active_pos;
        if !complete {
            if self.batch {
                // Deferred: coalesces into one `on_tasks_finished`
                // flushed before the next policy interaction.
                self.finish_batch.push((rt.stage, rt.stage_slot));
            } else {
                self.policy.on_task_finished(rt.stage, rt.stage_slot);
            }
            return;
        }
        self.flush_finish_batch();
        self.policy.on_task_finished(rt.stage, rt.stage_slot);
        // Stage complete: drop from active set (swap-remove + position
        // fix-up), advance the DAG (§2.1.1 step 7).
        self.active.swap_remove(active_pos);
        if let Some(&moved) = self.active.get(active_pos) {
            self.stages.get_mut(moved).active_pos = active_pos;
        }
        self.stage_slots.remove(&rt.stage);
        self.stages.remove(rt.stage_slot);
        self.policy.on_stage_finish(rt.stage, rt.stage_slot);

        let job = self.jobs.get_mut(job_slot);
        let newly_ready = job.mark_done(stage_idx);
        if job.is_complete() {
            job.finish_time = Some(now);
            let job_id = job.id;
            let rec = CompletedJob {
                job: job_id,
                user: job.spec.user,
                // Interned name: refcount bump, no string allocation.
                name: job.spec.name.clone(),
                submit: job.submit_time,
                finish: now,
                slot_time: job.spec.slot_time(),
            };
            self.jobs.remove(job_slot);
            self.job_slots.remove(&job_id);
            self.completed.push(rec);
            self.policy.on_job_finish(us_to_s(now), job_id);
        } else {
            for idx in newly_ready {
                self.submit_stage(now, job_slot, idx);
            }
        }
    }

    // ---- fault & recovery events ----------------------------------------

    /// Kill the losing attempt of a speculation race on `core` (the
    /// winner just finished elsewhere). The loser's runtime is waste; it
    /// touches no stage/policy counters — exactly one attempt of the
    /// pair (the winner) accounts for the task.
    fn kill_sibling(&mut self, now: TimeUs, core: usize, winner_is_clone: bool) {
        let rt = self.cores[core]
            .take()
            .expect("speculation race points at an idle core");
        self.busy -= 1;
        self.push_free(core);
        self.charge(rt.user, (now - rt.started) as u128, false, rt.demand_milli);
        if winner_is_clone {
            self.fault_stats.spec_wins += 1;
        } else {
            self.fault_stats.spec_losses += 1;
        }
        self.log_task(&rt, core, now, Outcome::Killed);
    }

    /// True iff the timer event tagged `seq` no longer refers to what is
    /// running on `core` (the attempt finished, failed, was killed, or
    /// was lost to a crash in the meantime).
    pub fn is_stale(&self, core: usize, seq: u64) -> bool {
        match self.cores[core].as_ref() {
            Some(rt) => rt.seq != seq,
            None => true,
        }
    }

    /// Classify the task event scheduled on `core` *without applying
    /// it* — the simulator's batching decision. Read-only: inspects the
    /// running attempt's fate flags and whether its finish would
    /// complete the stage.
    pub fn classify_task_event(&self, core: usize) -> TaskEventClass {
        let rt = self.cores[core]
            .as_ref()
            .expect("classify on idle core");
        if rt.fails {
            TaskEventClass::Fail
        } else if rt.sibling.is_some()
            || self.stages.get(rt.stage_slot).completes_with_next_finish()
        {
            TaskEventClass::Boundary
        } else {
            TaskEventClass::Plain
        }
    }

    /// A scheduled task event fired on `core`: completion on the clean
    /// path, or a fault-injected failure. On failure the attempt leaves
    /// the core, is charged one failure, and the caller re-enqueues it at
    /// the returned `retry_at` (exponential backoff) via
    /// [`SchedCore::retry_ready`].
    pub fn task_event(&mut self, now: TimeUs, core: usize) -> TaskEvent {
        let fails = self.cores[core]
            .as_ref()
            .expect("task_event on idle core")
            .fails;
        if !fails {
            self.task_finished(now, core);
            return TaskEvent::Finished;
        }
        let rt = self.cores[core].take().expect("checked above");
        self.busy -= 1;
        self.push_free(core);
        self.charge(rt.user, (now - rt.started) as u128, false, rt.demand_milli);
        self.fault_stats.failures += 1;
        self.log_task(&rt, core, now, Outcome::Failed);
        let stage = self.stages.get_mut(rt.stage_slot);
        stage.task_failed();
        let failures = stage.record_failure(rt.task_idx as u32);
        self.flush_finish_batch();
        self.policy.on_task_failed(rt.stage, rt.stage_slot);
        let backoff = self
            .plan
            .as_ref()
            .expect("failure without a fault plan")
            .retry_delay_us(failures)
            .max(1);
        TaskEvent::Failed {
            stage: rt.stage,
            task: rt.task_idx as u32,
            retry_at: now + backoff,
        }
    }

    /// A failed task's backoff elapsed: it re-enters its stage's queue
    /// and the policy is told the stage is selectable again. The stage is
    /// necessarily still live — a stage cannot complete while one of its
    /// tasks sits in retry limbo (`finished` never reached the task count).
    pub fn retry_ready(&mut self, now: TimeUs, stage: StageId, task: u32) {
        let &slot = self
            .stage_slots
            .get(&stage)
            .expect("retry for a departed stage");
        self.fault_stats.retries += 1;
        self.stages.get_mut(slot).requeue(task);
        self.pending_total += 1;
        self.notify_requeued(now, slot);
    }

    fn notify_requeued(&mut self, now: TimeUs, slot: u32) {
        self.flush_finish_batch();
        let s = self.stages.get(slot);
        let view = StageView {
            stage: s.id,
            slot,
            job: s.job,
            user: s.user,
            stage_idx: s.idx,
            running: s.running,
            pending: s.pending(),
            arrival_seq: s.arrival_seq,
            demand: s.demand,
        };
        self.policy.on_task_requeued(us_to_s(now), &view);
    }

    /// Speculation wake-up for the attempt tagged `seq` on `core`: if it
    /// is still running (not stale) and unraced, launch a clean clone on
    /// the lowest free non-blacklisted core. Returns the clone's
    /// `(finish_at, core, seq)` for the caller to schedule, or `None`
    /// (stale, already racing, or no core free — the latter counts as
    /// `spec_skipped`). Clones are engine-internal: no policy
    /// notifications and no stage-counter changes; the race winner's
    /// completion stands in for the task.
    pub fn spec_wake(&mut self, now: TimeUs, core: usize, seq: u64) -> Option<(TimeUs, usize, u64)> {
        {
            let Some(rt) = self.cores[core].as_ref() else {
                return None;
            };
            if rt.seq != seq || rt.sibling.is_some() {
                return None;
            }
        }
        let Some(clone_core) = self.pop_free() else {
            self.fault_stats.spec_skipped += 1;
            return None;
        };
        let (task, stage, job, user, task_idx, stage_slot, attempt, demand_milli) = {
            let rt = self.cores[core].as_ref().expect("checked above");
            (
                rt.task,
                rt.stage,
                rt.job,
                rt.user,
                rt.task_idx,
                rt.stage_slot,
                rt.attempt,
                rt.demand_milli,
            )
        };
        let base_s = self.stages.get(stage_slot).tasks[task_idx].runtime_s;
        let fin = now + s_to_us(base_s).max(1);
        self.launch_seq += 1;
        let clone_seq = self.launch_seq;
        self.cores[clone_core] = Some(RunningTask {
            task,
            stage,
            job,
            user,
            task_idx,
            started: now,
            finish_at: fin,
            stage_slot,
            seq: clone_seq,
            fails: false,
            attempt,
            is_clone: true,
            sibling: Some(core),
            demand_milli,
        });
        self.busy += 1;
        self.cores[core].as_mut().expect("checked above").sibling = Some(clone_core);
        self.fault_stats.spec_launched += 1;
        Some((fin, clone_core, clone_seq))
    }

    /// `core` crashes at `now`: its in-flight attempt (if any) is lost
    /// and the core blacklists until [`SchedCore::recover`]. A lost sole
    /// attempt is requeued immediately at the same attempt number — a
    /// crash is not the task's fault, so no failure charge and no
    /// backoff (and the stateless plan re-decides the same fate). A lost
    /// racer just leaves its sibling as the task's only attempt.
    pub fn crash(&mut self, now: TimeUs, core: usize) {
        debug_assert!(!self.blacklisted[core], "crash on blacklisted core");
        self.fault_stats.crashes += 1;
        self.blacklisted[core] = true;
        if self.cfg.log_tasks {
            self.fault_stats
                .crash_windows
                .push((core, now, now + self.recover_delay_us()));
        }
        let Some(rt) = self.cores[core].take() else {
            return; // idle core: its stale heap entry is skipped lazily
        };
        self.busy -= 1;
        self.charge(rt.user, (now - rt.started) as u128, false, rt.demand_milli);
        self.fault_stats.tasks_lost_to_crash += 1;
        self.log_task(&rt, core, now, Outcome::CrashLost);
        if let Some(sib) = rt.sibling {
            // The surviving racer becomes the task's sole attempt.
            if let Some(s) = self.cores[sib].as_mut() {
                s.sibling = None;
            }
        } else {
            let stage = self.stages.get_mut(rt.stage_slot);
            stage.task_failed();
            stage.requeue(rt.task_idx as u32);
            self.pending_total += 1;
            self.flush_finish_batch();
            self.policy.on_task_failed(rt.stage, rt.stage_slot);
            self.notify_requeued(now, rt.stage_slot);
        }
    }

    /// `core`'s recovery window elapsed: it re-enters service and is
    /// offered back to the scheduler.
    pub fn recover(&mut self, _now: TimeUs, core: usize) {
        debug_assert!(self.blacklisted[core], "recover on healthy core");
        self.blacklisted[core] = false;
        if self.cores[core].is_none() {
            self.push_free(core);
        }
    }

    /// Draw the next inter-crash gap for `core` from the plan's per-core
    /// sequence (advances the core's crash cursor). `None` ⇔ crashes off.
    pub fn next_crash_gap_us(&mut self, core: usize) -> Option<TimeUs> {
        let plan = self.plan.as_ref()?;
        let idx = self.crash_counts[core];
        let gap = plan.crash_gap_us(core, idx)?;
        self.crash_counts[core] += 1;
        Some(gap)
    }

    /// Blacklist window length after a crash.
    pub fn recover_delay_us(&self) -> TimeUs {
        s_to_us(self.cfg.fault.crash_recover_s).max(1)
    }

    /// Whether any fault class is live this run (simulator gate).
    pub fn faults_enabled(&self) -> bool {
        self.fault_on
    }

    pub fn is_blacklisted(&self, core: usize) -> bool {
        self.blacklisted[core]
    }

    /// Total core-µs consumed by completed occupancies (goodput + waste)
    /// — the utilization numerator, engine-side so re-execution, kills
    /// and crashes are all accounted at the instant they resolve.
    pub fn busy_core_us(&self) -> u128 {
        self.fault_stats.good_us + self.fault_stats.wasted_us
    }

    /// Per-dimension goodput ledger `[cpu, mem]` in milli-demand-µs —
    /// elapsed core-µs of each successful occupancy × its demand in
    /// milli-units. Unit demands give exactly `1000 × good_us` per
    /// dimension.
    pub fn resource_good_mmus(&self) -> [u128; 2] {
        self.res_good_mmus
    }

    /// Per-dimension waste ledger `[cpu, mem]` (kills/failures/crash
    /// losses) in milli-demand-µs.
    pub fn resource_wasted_mmus(&self) -> [u128; 2] {
        self.res_wasted_mmus
    }

    /// Per-dimension busy ledger `[cpu, mem]` (goodput + waste) in
    /// milli-demand-µs — the multi-resource utilization numerator. Since
    /// one core-slot offers 1000 milli-units per dimension, a run can
    /// never exceed `cores × 1000 × busy-window-µs` in either dimension
    /// (the invariant harness's over-commit bound).
    pub fn resource_busy_mmus(&self) -> [u128; 2] {
        [
            self.res_good_mmus[0] + self.res_wasted_mmus[0],
            self.res_good_mmus[1] + self.res_wasted_mmus[1],
        ]
    }

    // ---- dynamic capacity (cross-shard core lending) ---------------------

    /// Live (non-retired) core count — the capacity the scheduler may
    /// actually fill. Physical slots only ever grow; a lending shrink
    /// retires slots in place.
    pub fn live_cores(&self) -> u32 {
        self.retired.iter().filter(|&&r| !r).count() as u32
    }

    /// Free cores that could take work right now: idle, not blacklisted,
    /// not retired. Published into the shard barrier snapshot — the
    /// rebalancer never asks a shard to give up more than this, which is
    /// what lets [`SchedCore::set_cores`] retire only-when-free slots.
    pub fn free_usable_cores(&self) -> u32 {
        (0..self.cores.len())
            .filter(|&c| self.cores[c].is_none() && !self.blacklisted[c] && !self.retired[c])
            .count() as u32
    }

    /// Queued (unlaunched) work across all active stages in slot-seconds
    /// — the backlog metric each shard publishes at the sync barrier.
    /// O(pending tasks); called once per epoch, off the event hot path.
    pub fn queued_slot_s(&self) -> f64 {
        let mut acc = 0.0;
        for &slot in &self.active {
            let s = self.stages.get(slot);
            for t in &s.tasks[s.next_task..] {
                acc += t.runtime_s;
            }
            for &ti in &s.retry_queue {
                acc += s.tasks[ti as usize].runtime_s;
            }
        }
        acc
    }

    /// Distinct users with at least one active stage (barrier snapshot).
    pub fn active_user_count(&self) -> usize {
        let mut users: Vec<UserId> = self
            .active
            .iter()
            .map(|&slot| self.stages.get(slot).user)
            .collect();
        users.sort_unstable();
        users.dedup();
        users.len()
    }

    /// Resize the live core budget to `target` (cross-shard lending).
    ///
    /// *Shrink* retires the highest-indexed currently-free healthy slots
    /// in place, reusing the blacklist machinery's lazy free-heap
    /// reclaim — a retired slot is simply never offered again. The
    /// caller guarantees enough free cores exist (the rebalancer caps
    /// donations by the published [`SchedCore::free_usable_cores`], and
    /// the shard does not advance between publishing and applying); any
    /// slot that cannot be retired (busy or crashed) stays live and
    /// shows up in the returned count.
    ///
    /// *Grow* re-activates the lowest-indexed retired slots first, then
    /// appends fresh physical slots. Appended slots never crash: crash
    /// clocks are armed per-core at simulation start for the initial
    /// allocation only (see README "Work balancing").
    ///
    /// Returns the live core count after the call. `cfg.cores` keeps the
    /// shard's static allocation — the policy and partitioner are built
    /// from it once and keep the shard's nominal width.
    pub fn set_cores(&mut self, target: u32) -> u32 {
        let mut live = self.live_cores();
        while live > target {
            let victim = (0..self.cores.len()).rev().find(|&c| {
                !self.retired[c] && !self.blacklisted[c] && self.cores[c].is_none()
            });
            let Some(victim) = victim else {
                break; // nothing retirable left — report the shortfall
            };
            self.retired[victim] = true;
            live -= 1;
        }
        while live < target {
            if let Some(back) = (0..self.cores.len()).find(|&c| self.retired[c]) {
                self.retired[back] = false;
                if self.cores[back].is_none() && !self.blacklisted[back] {
                    self.push_free(back);
                }
            } else {
                let c = self.cores.len();
                self.cores.push(None);
                self.blacklisted.push(false);
                self.crash_counts.push(0);
                self.retired.push(false);
                self.in_heap.push(false);
                self.push_free(c);
            }
            live += 1;
        }
        live
    }

    // ---- introspection --------------------------------------------------

    pub fn busy_cores(&self) -> usize {
        self.busy
    }

    pub fn core_state(&self, core: usize) -> Option<&RunningTask> {
        self.cores[core].as_ref()
    }

    /// No queued work and no running tasks.
    pub fn is_idle(&self) -> bool {
        let idle = self.busy_cores() == 0 && self.active.is_empty();
        debug_assert!(
            !idle || self.pending_total == 0,
            "idle engine with non-zero pending_total mirror"
        );
        idle
    }

    pub fn active_stage_count(&self) -> usize {
        self.active.len()
    }

    pub fn pending_task_count(&self) -> u32 {
        self.active
            .iter()
            .map(|&slot| self.stages.get(slot).pending())
            .sum()
    }

    pub fn in_flight_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Tasks of one stage (testing / diagnostics).
    pub fn stage(&self, id: StageId) -> Option<&StageState> {
        let &slot = self.stage_slots.get(&id)?;
        Some(self.stages.get(slot))
    }

    pub fn stage_of_job(&self, job: JobId, idx: usize) -> Option<&StageState> {
        let &slot = self.job_slots.get(&job)?;
        let sid = (*self.jobs.get(slot).stage_ids.get(idx)?)?;
        self.stage(sid)
    }

    /// Arena footprints (slots allocated, live or free) — the memory the
    /// engine holds is bounded by *peak* concurrency, not total
    /// throughput. Exposed for the slot-recycling regression test.
    pub fn arena_capacities(&self) -> (usize, usize) {
        (self.jobs.capacity(), self.stages.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimate::Oracle;
    use crate::partition::SizeScheme;
    use crate::sched::fifo::Fifo;

    fn core(cores: u32) -> SchedCore {
        let cfg = Config {
            cores,
            task_overhead: 0.0,
            log_tasks: true,
            ..Config::default()
        };
        SchedCore::new(
            cfg,
            Box::new(Fifo::new()),
            Box::new(SizeScheme::new(24 << 20, 24 << 20, cores)),
            Box::new(Oracle::new()),
        )
    }

    fn job(user: u32, arrival: TimeUs, compute: f64) -> JobSpec {
        JobSpec::three_phase(user, "t", arrival, compute, 64 << 20, 4, None)
    }

    #[test]
    fn submit_creates_leaf_stage_only() {
        let mut c = core(4);
        let id = c.submit_job(0, job(1, 0, 1.0)).unwrap();
        assert_eq!(c.active_stage_count(), 1);
        let s = c.stage_of_job(id, 0).unwrap();
        // 64 MB / 24 MB = 3 partitions, but >= cores → 4
        assert_eq!(s.tasks.len(), 4);
    }

    #[test]
    fn launch_fills_all_cores() {
        let mut c = core(4);
        c.submit_job(0, job(1, 0, 1.0)).unwrap();
        let launches = c.try_launch(0);
        assert_eq!(launches.len(), 4);
        assert_eq!(c.busy_cores(), 4);
        assert!(c.try_launch(0).is_empty()); // no free cores
    }

    #[test]
    fn launches_take_lowest_free_core_first() {
        let mut c = core(4);
        c.submit_job(0, job(1, 0, 1.0)).unwrap();
        let launches = c.try_launch(0);
        let cores_used: Vec<usize> = launches.iter().map(|l| l.core).collect();
        assert_eq!(cores_used, vec![0, 1, 2, 3]);
        // Free a middle core: the next launch must land on it.
        c.submit_job(0, job(2, 0, 1.0)).unwrap();
        c.task_finished(1000, 2);
        let launches = c.try_launch(1000);
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].core, 2);
    }

    #[test]
    fn try_launch_into_reuses_buffer_and_matches_wrapper() {
        // A dirty reused buffer must be cleared and refilled with exactly
        // what the allocating wrapper would have returned.
        let mut a = core(4);
        let mut b = core(4);
        a.submit_job(0, job(1, 0, 1.0)).unwrap();
        b.submit_job(0, job(1, 0, 1.0)).unwrap();
        let wrapper = a.try_launch(0);
        let mut buf = vec![wrapper[0].clone()]; // pre-dirtied
        b.try_launch_into(0, &mut buf);
        assert_eq!(wrapper.len(), buf.len());
        for (x, y) in wrapper.iter().zip(&buf) {
            assert_eq!((x.core, x.stage, x.task_idx), (y.core, y.stage, y.task_idx));
            assert_eq!(x.runtime_s.to_bits(), y.runtime_s.to_bits());
        }
        // No free cores: the buffer comes back empty, not stale.
        b.try_launch_into(0, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn full_job_lifecycle_completes() {
        let mut c = core(2);
        c.submit_job(0, job(7, 0, 0.5)).unwrap();
        let mut now = 0;
        // Drive to completion by finishing whatever is running.
        let mut guard = 0;
        loop {
            let launches = c.try_launch(now);
            if launches.is_empty() && c.busy_cores() == 0 {
                break;
            }
            // Finish the earliest-finishing core.
            let (core_idx, fin) = (0..2)
                .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                .min_by_key(|&(_, f)| f)
                .unwrap();
            now = fin;
            c.task_finished(now, core_idx);
            guard += 1;
            assert!(guard < 1000, "no progress");
        }
        assert!(c.is_idle());
        assert_eq!(c.completed.len(), 1);
        let done = &c.completed[0];
        assert_eq!(done.user, 7);
        assert!(done.finish > 0);
        // Task log recorded every task.
        assert!(c.task_log.len() >= 3); // >=1 per stage
    }

    #[test]
    fn reset_is_observationally_fresh() {
        // Drive a run to completion, reset, re-run the same workload: ids,
        // schedules and records must be byte-identical to the first run,
        // and the arenas must keep their allocation.
        let cfg = Config {
            cores: 2,
            task_overhead: 0.0,
            log_tasks: true,
            policy: crate::sched::PolicyKind::Fifo,
            ..Config::default()
        };
        let run = |c: &mut SchedCore| -> (Vec<(u64, TimeUs)>, Vec<(crate::TaskId, usize)>) {
            c.submit_job(0, job(3, 0, 0.5)).unwrap();
            c.submit_job(0, job(4, 0, 0.5)).unwrap();
            let mut now = 0;
            let mut guard = 0;
            loop {
                let launches = c.try_launch(now);
                if launches.is_empty() && c.busy_cores() == 0 {
                    break;
                }
                let (core_idx, fin) = (0..2)
                    .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                    .min_by_key(|&(_, f)| f)
                    .unwrap();
                now = fin;
                c.task_finished(now, core_idx);
                guard += 1;
                assert!(guard < 1000, "no progress");
            }
            (
                c.completed.iter().map(|r| (r.job, r.finish)).collect(),
                c.task_log.iter().map(|t| (t.task, t.core)).collect(),
            )
        };
        let mut c = SchedCore::from_config(cfg.clone());
        let first = run(&mut c);
        let caps = c.arena_capacities();
        c.reset(cfg);
        assert!(c.is_idle());
        let second = run(&mut c);
        assert_eq!(first, second, "reset run diverged from fresh run");
        assert_eq!(c.arena_capacities(), caps, "reset dropped arena slots");
    }

    #[test]
    fn task_runtimes_conserve_slot_time() {
        let mut c = core(4);
        let id = c.submit_job(0, job(1, 0, 2.0)).unwrap();
        let s = c.stage_of_job(id, 0).unwrap();
        let total: f64 = s.tasks.iter().map(|t| t.runtime_s).sum();
        // overhead = 0 → sum of task runtimes == stage slot time.
        assert!((total - 2.0 * 0.08).abs() < 1e-9, "total={total}");
    }

    #[test]
    fn collect_stage_single_task() {
        let mut c = core(8);
        let id = c.submit_job(0, job(1, 0, 0.2)).unwrap();
        let mut now = 0;
        // run load + compute to get to collect
        for _ in 0..200 {
            c.try_launch(now);
            if let Some((i, f)) = (0..8)
                .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                .min_by_key(|&(_, f)| f)
            {
                now = f;
                c.task_finished(now, i);
            } else {
                break;
            }
            if let Some(s) = c.stage_of_job(id, 3) {
                assert_eq!(s.tasks.len(), 1);
                return; // collect submitted with exactly 1 task — done
            }
        }
        panic!("collect stage never submitted");
    }

    #[test]
    #[should_panic(expected = "task_finished on idle core")]
    fn finish_on_idle_core_panics() {
        let mut c = core(2);
        c.task_finished(0, 0);
    }

    #[test]
    fn rejects_invalid_job() {
        let mut c = core(2);
        let mut bad = job(1, 0, 1.0);
        bad.stages[0].parents = vec![1];
        assert!(c.submit_job(0, bad).is_err());
    }

    #[test]
    fn slots_recycle_across_job_churn() {
        // Run many sequential jobs through a tiny core: the arenas must
        // not grow with the total number of jobs ever submitted — slot
        // footprint after 20 rounds must equal the footprint after round
        // one (peak concurrency is identical every round).
        let mut c = core(2);
        let mut cap_after_first = None;
        for round in 0..20u64 {
            c.submit_job(round * 10_000_000, job(1, round * 10_000_000, 0.1))
                .unwrap();
            let mut now = round * 10_000_000;
            let mut guard = 0;
            while !c.is_idle() {
                c.try_launch(now);
                let (i, f) = (0..2)
                    .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                    .min_by_key(|&(_, f)| f)
                    .unwrap();
                now = f;
                c.task_finished(now, i);
                guard += 1;
                assert!(guard < 10_000, "no progress");
            }
            if cap_after_first.is_none() {
                cap_after_first = Some(c.arena_capacities());
            }
        }
        assert_eq!(c.completed.len(), 20);
        assert_eq!(c.in_flight_jobs(), 0);
        assert_eq!(c.active_stage_count(), 0);
        assert_eq!(
            Some(c.arena_capacities()),
            cap_after_first,
            "arena slots must be recycled, not leaked, across job churn"
        );
    }

    // ---- dynamic capacity -------------------------------------------------

    #[test]
    fn set_cores_shrinks_only_free_slots_and_grows_back() {
        let mut c = core(4);
        c.submit_job(0, job(1, 0, 1.0)).unwrap();
        let launches = c.try_launch(0);
        assert_eq!(launches.len(), 4);
        // All busy: nothing is retirable, the shortfall is reported.
        assert_eq!(c.set_cores(2), 4);
        // Free cores 2 and 3: shrink retires the highest-indexed slots.
        c.task_finished(1_000, 3);
        c.task_finished(1_000, 2);
        assert_eq!(c.set_cores(2), 2);
        assert_eq!(c.live_cores(), 2);
        assert_eq!(c.free_usable_cores(), 0);
        // Retired slots are never offered: new work cannot launch...
        c.submit_job(1_000, job(2, 1_000, 1.0)).unwrap();
        assert!(c.try_launch(1_000).is_empty());
        // ...until the budget grows back — re-activating slots 2 and 3
        // first, then appending fresh slots 4 and 5.
        assert_eq!(c.set_cores(6), 6);
        let relaunch = c.try_launch(1_000);
        let used: Vec<usize> = relaunch.iter().map(|l| l.core).collect();
        assert_eq!(used, vec![2, 3, 4, 5]);
    }

    #[test]
    fn backlog_metrics_track_unlaunched_work() {
        let mut c = core(2);
        assert_eq!(c.queued_slot_s(), 0.0);
        assert_eq!(c.active_user_count(), 0);
        c.submit_job(0, job(1, 0, 1.0)).unwrap();
        let q0 = c.queued_slot_s();
        assert!(q0 > 0.0);
        assert_eq!(c.active_user_count(), 1);
        // Launching moves work from queued to running: backlog shrinks.
        assert!(!c.try_launch(0).is_empty());
        assert!(c.queued_slot_s() < q0);
    }

    // ---- fault machinery -------------------------------------------------

    fn fault_core(cores: u32, fault: crate::fault::FaultConfig) -> SchedCore {
        let cfg = Config {
            cores,
            task_overhead: 0.0,
            log_tasks: true,
            policy: crate::sched::PolicyKind::Fifo,
            fault,
            ..Config::default()
        };
        SchedCore::from_config(cfg)
    }

    /// Minimal event loop over the engine's fault API (the simulator's
    /// heap, in miniature): task events, retry wake-ups, spec wake-ups.
    fn drive_faulty(c: &mut SchedCore) -> TimeUs {
        let mut heap: BinaryHeap<Reverse<(TimeUs, u8, u64, u64)>> = BinaryHeap::new();
        let mut now = 0;
        let mut guard = 0;
        loop {
            for l in c.try_launch(now) {
                heap.push(Reverse((l.finish_at, 0, l.core as u64, l.seq)));
                if let Some(w) = l.spec_wake_at {
                    heap.push(Reverse((w, 2, l.core as u64, l.seq)));
                }
            }
            let Some(Reverse((t, kind, a, b))) = heap.pop() else {
                break;
            };
            now = t;
            match kind {
                0 => {
                    if !c.is_stale(a as usize, b) {
                        if let TaskEvent::Failed { stage, task, retry_at } =
                            c.task_event(now, a as usize)
                        {
                            heap.push(Reverse((retry_at, 1, stage, task as u64)));
                        }
                    }
                }
                1 => c.retry_ready(now, a, b as u32),
                2 => {
                    if let Some((fin, core, seq)) = c.spec_wake(now, a as usize, b) {
                        heap.push(Reverse((fin, 0, core as u64, seq)));
                    }
                }
                _ => unreachable!(),
            }
            guard += 1;
            assert!(guard < 100_000, "no progress");
        }
        assert!(c.is_idle(), "driver drained but engine not idle");
        now
    }

    #[test]
    fn zero_fault_launches_are_clean() {
        // With all rates zero the fault fields are inert: no failure flag,
        // no spec wake-up, and finish_at is exactly now + runtime.
        let mut c = core(4);
        assert!(!c.faults_enabled());
        c.submit_job(0, job(1, 0, 1.0)).unwrap();
        let now = 5_000;
        for l in c.try_launch(now) {
            assert!(!l.fails);
            assert_eq!(l.spec_wake_at, None);
            assert_eq!(l.finish_at, now + s_to_us(l.runtime_s));
        }
    }

    #[test]
    fn failed_tasks_retry_until_budget_then_complete() {
        // fail_prob = 1 with a budget of 2: every task fails exactly
        // twice, then its third attempt is clean. Completions still
        // happen, and successful core-time matches the fault-free run.
        let fault = crate::fault::FaultConfig {
            task_fail_prob: 1.0,
            max_failures: 2,
            retry_backoff_s: 0.001,
            ..Default::default()
        };
        let mut clean = fault_core(2, crate::fault::FaultConfig::default());
        clean.submit_job(0, job(1, 0, 0.5)).unwrap();
        drive_faulty(&mut clean);
        let clean_tasks = clean.task_log.len();
        let clean_good = clean.fault_stats.good_us;
        assert!(clean_tasks > 0 && clean.completed.len() == 1);

        let mut c = fault_core(2, fault);
        c.submit_job(0, job(1, 0, 0.5)).unwrap();
        drive_faulty(&mut c);
        assert_eq!(c.completed.len(), 1);
        let successes = c
            .task_log
            .iter()
            .filter(|t| t.outcome == Outcome::Success)
            .count();
        let failures = c
            .task_log
            .iter()
            .filter(|t| t.outcome == Outcome::Failed)
            .count();
        assert_eq!(successes, clean_tasks, "each task succeeds exactly once");
        assert_eq!(failures, 2 * clean_tasks, "budget of 2 failures per task");
        assert_eq!(c.fault_stats.failures, failures as u64);
        assert_eq!(c.fault_stats.retries, failures as u64);
        // Goodput is charged once per successful task: identical to the
        // fault-free run (stragglers off, so runtimes are unchanged).
        assert_eq!(c.fault_stats.good_us, clean_good);
        assert!(c.fault_stats.wasted_us > 0, "failed attempts are waste");
        // Every success launched at attempt 2.
        for t in c.task_log.iter().filter(|t| t.outcome == Outcome::Success) {
            assert_eq!(t.attempt, 2);
        }
    }

    #[test]
    fn speculation_clone_wins_and_kills_straggler() {
        // Every task straggles at 8× with a 2× speculation threshold:
        // the clone (launched at 2×base, runs 1×base, done at 3×base)
        // always beats the straggler (done at 8×base).
        let fault = crate::fault::FaultConfig {
            straggler_prob: 1.0,
            straggler_mult: 8.0,
            spec_mult: 2.0,
            ..Default::default()
        };
        let mut c = fault_core(8, fault.clone());
        c.submit_job(0, job(1, 0, 0.5)).unwrap();
        drive_faulty(&mut c);
        assert_eq!(c.completed.len(), 1);
        assert!(c.fault_stats.spec_launched > 0);
        assert_eq!(c.fault_stats.spec_wins, c.fault_stats.spec_launched);
        assert_eq!(c.fault_stats.spec_losses, 0);
        assert!(c.fault_stats.wasted_us > 0, "killed stragglers are waste");
        let kills = c
            .task_log
            .iter()
            .filter(|t| t.outcome == Outcome::Killed)
            .count() as u64;
        assert_eq!(kills, c.fault_stats.spec_wins);

        // With every core occupied by stragglers there is never a free
        // core to clone onto: speculation is skipped, not deadlocked.
        let mut tight = fault_core(1, fault);
        tight.submit_job(0, job(1, 0, 0.5)).unwrap();
        drive_faulty(&mut tight);
        assert_eq!(tight.completed.len(), 1);
        assert_eq!(tight.fault_stats.spec_launched, 0);
        assert!(tight.fault_stats.spec_skipped > 0);
    }

    #[test]
    fn crash_blacklists_requeues_and_recovers() {
        // Crashes armed (plan exists) but driven manually here.
        let fault = crate::fault::FaultConfig {
            crash_mttf_s: 1000.0,
            crash_recover_s: 5.0,
            ..Default::default()
        };
        let mut c = fault_core(2, fault);
        c.submit_job(0, job(1, 0, 1.0)).unwrap();
        let launches = c.try_launch(0);
        assert_eq!(launches.len(), 2);
        let lost_task_idx = launches[0].task_idx;

        c.crash(1_000, 0);
        assert!(c.is_blacklisted(0));
        assert_eq!(c.fault_stats.crashes, 1);
        assert_eq!(c.fault_stats.tasks_lost_to_crash, 1);
        assert_eq!(c.busy_cores(), 1);
        // The lost attempt is pending again, but the blacklisted core
        // must not be offered (core 1 is still busy → nothing launches).
        assert!(c.pending_task_count() > 0);
        assert!(c.try_launch(2_000).is_empty());

        c.recover(6_000, 0);
        assert!(!c.is_blacklisted(0));
        let relaunch = c.try_launch(6_000);
        assert_eq!(relaunch.len(), 1);
        assert_eq!(relaunch[0].core, 0);
        // A crash is not the task's fault: the retry keeps attempt 0 and
        // charges no failure, no retry.
        assert_eq!(relaunch[0].task_idx, lost_task_idx);
        assert_eq!(c.core_state(0).unwrap().attempt, 0);
        assert_eq!(c.fault_stats.failures, 0);
        assert_eq!(c.fault_stats.retries, 0);

        // Crashing an idle core loses nothing and recovers cleanly.
        c.task_finished(7_000, 1);
        c.crash(7_500, 1);
        assert_eq!(c.fault_stats.tasks_lost_to_crash, 1);
        c.recover(8_000, 1);
        let more = c.try_launch(8_000);
        assert!(more.iter().any(|l| l.core == 1));
    }

    #[test]
    fn fixed_fault_seed_repeats_byte_identically() {
        let fault = crate::fault::FaultConfig {
            task_fail_prob: 0.3,
            straggler_prob: 0.2,
            straggler_mult: 6.0,
            spec_mult: 2.0,
            retry_backoff_s: 0.002,
            seed: 7,
            ..Default::default()
        };
        let run = || {
            let mut c = fault_core(4, fault.clone());
            for u in 0..3 {
                c.submit_job(0, job(u, 0, 0.4)).unwrap();
            }
            drive_faulty(&mut c);
            (
                c.completed.iter().map(|r| (r.job, r.finish)).collect::<Vec<_>>(),
                c.fault_stats.clone(),
            )
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    #[test]
    fn reset_clears_fault_state() {
        // A faulty run, then reset: the recycled core must replay the
        // same workload byte-identically (launch seq, fail ledgers,
        // blacklists and stats all re-derived from scratch).
        let fault = crate::fault::FaultConfig {
            task_fail_prob: 0.5,
            retry_backoff_s: 0.002,
            seed: 3,
            ..Default::default()
        };
        let cfg = Config {
            cores: 2,
            task_overhead: 0.0,
            log_tasks: true,
            policy: crate::sched::PolicyKind::Fifo,
            fault,
            ..Config::default()
        };
        let run = |c: &mut SchedCore| {
            c.submit_job(0, job(3, 0, 0.5)).unwrap();
            drive_faulty(c);
            (
                c.completed.iter().map(|r| (r.job, r.finish)).collect::<Vec<_>>(),
                c.task_log
                    .iter()
                    .map(|t| (t.task, t.core, t.attempt, t.outcome))
                    .collect::<Vec<_>>(),
                c.fault_stats.clone(),
            )
        };
        let mut c = SchedCore::from_config(cfg.clone());
        let first = run(&mut c);
        assert!(first.2.failures > 0, "test wants actual failures");
        c.reset(cfg);
        assert!(c.is_idle());
        assert_eq!(c.fault_stats, FaultStats::default());
        let second = run(&mut c);
        assert_eq!(first, second, "reset run diverged under faults");
    }

    #[test]
    fn batched_mode_matches_per_event_mode() {
        // Batching armed: deferred finish notifications, launch quanta
        // (FIFO is static_keys) and the offer guard must reproduce the
        // per-event schedule, task placement included.
        let drive = |batched: bool| -> (Vec<(u64, TimeUs)>, Vec<(crate::TaskId, usize)>) {
            let mut c = core(3);
            c.set_batching(batched);
            for u in 0..3 {
                c.submit_job(0, job(u, 0, 0.4)).unwrap();
            }
            let mut now = 0;
            let mut guard = 0;
            while !c.is_idle() {
                if c.can_launch() {
                    c.try_launch(now);
                }
                let (i, f) = (0..3)
                    .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                    .min_by_key(|&(_, f)| f)
                    .unwrap();
                now = f;
                c.task_finished(now, i);
                guard += 1;
                assert!(guard < 10_000, "no progress");
            }
            (
                c.completed.iter().map(|r| (r.job, r.finish)).collect(),
                c.task_log.iter().map(|t| (t.task, t.core)).collect(),
            )
        };
        assert_eq!(drive(false), drive(true));
    }

    #[test]
    fn scan_mode_matches_incremental_mode() {
        // Same workload through both selection paths → identical launches.
        let drive = |force_scan: bool| -> Vec<(u64, u64)> {
            let mut c = core(3);
            c.force_scan_select = force_scan;
            for u in 0..3 {
                c.submit_job(0, job(u, 0, 0.4)).unwrap();
            }
            let mut now = 0;
            let mut guard = 0;
            while !c.is_idle() {
                c.try_launch(now);
                let (i, f) = (0..3)
                    .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                    .min_by_key(|&(_, f)| f)
                    .unwrap();
                now = f;
                c.task_finished(now, i);
                guard += 1;
                assert!(guard < 10_000, "no progress");
            }
            c.completed.iter().map(|r| (r.job, r.finish)).collect()
        };
        assert_eq!(drive(false), drive(true));
    }
}
