//! `SchedCore` — the task scheduler + DAG scheduler of the long-running
//! analytics application (paper Fig. 1), independent of the execution
//! backend.
//!
//! The discrete-event simulator ([`crate::sim`]) and the real PJRT backend
//! ([`crate::exec`]) both drive this state machine with the same three
//! entry points: [`SchedCore::submit_job`], [`SchedCore::try_launch`] and
//! [`SchedCore::task_finished`].

use std::collections::HashMap;

use super::dag::{CompletedJob, JobState};
use super::job::JobSpec;
use super::stage::StageState;
use super::task::{RunningTask, TaskRecord, TaskSpec};
use crate::config::Config;
use crate::estimate::RuntimeEstimator;
use crate::partition::PartitionScheme;
use crate::sched::{Policy, StageMeta, StageView};
use crate::{s_to_us, us_to_s, JobId, StageId, TimeUs};

/// Bytes of one data block — must match the AOT artifact geometry
/// (4096 rows × 8 cols × 4 bytes).
pub const BLOCK_BYTES: u64 = 4096 * 8 * 4;

/// A task-launch decision handed to the backend.
#[derive(Clone, Debug)]
pub struct Launch {
    pub core: usize,
    pub task: crate::TaskId,
    pub stage: StageId,
    pub job: JobId,
    pub user: crate::UserId,
    pub task_idx: usize,
    /// Ground-truth runtime (simulation backend).
    pub runtime_s: f64,
    /// Work descriptor for the real backend.
    pub blocks: u32,
    pub opcount: u32,
}

pub struct SchedCore {
    pub cfg: Config,
    pub policy: Box<dyn Policy>,
    partitioner: Box<dyn PartitionScheme>,
    estimator: Box<dyn RuntimeEstimator>,
    jobs: HashMap<JobId, JobState>,
    stages: HashMap<StageId, StageState>,
    /// Submitted, not-yet-complete stages, in submission order.
    active_stages: Vec<StageId>,
    cores: Vec<Option<RunningTask>>,
    next_job: JobId,
    next_stage: StageId,
    next_task: crate::TaskId,
    arrival_seq: u64,
    /// Finished analytics jobs, in completion order.
    pub completed: Vec<CompletedJob>,
    /// Per-task records (only when `cfg.log_tasks`).
    pub task_log: Vec<TaskRecord>,
    /// Scratch buffer for stage views (reused across launches).
    views_buf: Vec<StageView>,
}

impl SchedCore {
    pub fn new(
        cfg: Config,
        policy: Box<dyn Policy>,
        partitioner: Box<dyn PartitionScheme>,
        estimator: Box<dyn RuntimeEstimator>,
    ) -> Self {
        let cores = cfg.cores as usize;
        SchedCore {
            cfg,
            policy,
            partitioner,
            estimator,
            jobs: HashMap::new(),
            stages: HashMap::new(),
            active_stages: Vec::new(),
            cores: vec![None; cores],
            next_job: 1,
            next_stage: 1,
            next_task: 1,
            arrival_seq: 0,
            completed: Vec::new(),
            task_log: Vec::new(),
            views_buf: Vec::new(),
        }
    }

    /// Build a core from a [`Config`] using its policy/scheme/estimator
    /// settings — the standard constructor for experiments.
    pub fn from_config(cfg: Config) -> Self {
        let policy = crate::sched::make_policy(cfg.policy, cfg.cores, cfg.grace_rsec);
        let partitioner = crate::partition::make_scheme(
            cfg.scheme,
            cfg.max_partition_bytes,
            cfg.advisory_partition_bytes,
            cfg.atr,
        );
        let estimator: Box<dyn RuntimeEstimator> = if cfg.estimator_sigma > 0.0 {
            Box::new(crate::estimate::Noisy::new(cfg.estimator_sigma, cfg.seed ^ 0xE57))
        } else {
            Box::new(crate::estimate::Oracle::new())
        };
        SchedCore::new(cfg, policy, partitioner, estimator)
    }

    // ---- submission -----------------------------------------------------

    /// Submit an analytics job (paper §4.1.3: user context + job context
    /// arrive with the job). Returns its id.
    pub fn submit_job(&mut self, now: TimeUs, spec: JobSpec) -> anyhow::Result<JobId> {
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        let id = self.next_job;
        self.next_job += 1;
        let seq = self.arrival_seq;
        self.arrival_seq += 1;

        let est_slot = self.estimator.job_slot_time(&spec);
        self.policy.on_job_arrival(
            us_to_s(now),
            &crate::sched::JobMeta {
                job: id,
                user: spec.user,
                weight: spec.weight,
                est_slot_time: est_slot,
                arrival_seq: seq,
            },
        );

        let job = JobState::new(id, seq, now, spec);
        let ready = job.ready_stages();
        self.jobs.insert(id, job);
        for idx in ready {
            self.submit_stage(now, id, idx);
        }
        Ok(id)
    }

    /// Partition one stage into tasks and hand it to the task scheduler.
    fn submit_stage(&mut self, now: TimeUs, job_id: JobId, idx: usize) {
        let job = &self.jobs[&job_id];
        let spec = job.spec.stages[idx].clone();
        let user = job.spec.user;
        let arrival_seq = job.arrival_seq;
        let est = self.estimator.stage_slot_time(&spec);

        let ranges = self.partitioner.partition(&spec, est, self.cfg.cores);
        let blocks_total = (spec.input_bytes.div_ceil(BLOCK_BYTES)).max(1);
        let tasks: Vec<TaskSpec> = ranges
            .iter()
            .map(|&(lo, hi)| TaskSpec {
                range: (lo, hi),
                runtime_s: spec.slot_time * spec.cost.integral(lo, hi) + self.cfg.task_overhead,
                blocks: (((hi - lo) * blocks_total as f64).round() as u32).max(1),
                opcount: spec.opcount,
            })
            .collect();

        let stage_id = self.next_stage;
        self.next_stage += 1;
        let stage = StageState {
            id: stage_id,
            job: job_id,
            user,
            idx,
            tasks,
            next_task: 0,
            running: 0,
            finished: 0,
            submitted_at: now,
            est_slot_time: est,
            arrival_seq,
        };
        self.stages.insert(stage_id, stage);
        self.active_stages.push(stage_id);
        self.jobs.get_mut(&job_id).unwrap().mark_submitted(idx, stage_id);
        self.policy.on_stage_submit(
            us_to_s(now),
            &StageMeta {
                stage: stage_id,
                job: job_id,
                user,
                est_slot_time: est,
            },
        );
    }

    // ---- launching ------------------------------------------------------

    /// Fill free cores with the highest-priority pending tasks. Returns the
    /// launch list for the backend to execute.
    pub fn try_launch(&mut self, now: TimeUs) -> Vec<Launch> {
        let mut launches = Vec::new();
        if self.active_stages.is_empty() || self.cores.iter().all(|c| c.is_some()) {
            return launches; // nothing to do — keep the congested path free
        }
        // Snapshot views of active stages ONCE per offer round; counts of
        // launched stages are updated in place (hot path: the snapshot is
        // O(active stages) and a round may fill many cores).
        let mut views = std::mem::take(&mut self.views_buf);
        views.clear();
        for &sid in &self.active_stages {
            let s = &self.stages[&sid];
            views.push(StageView {
                stage: sid,
                job: s.job,
                user: s.user,
                stage_idx: s.idx,
                running: s.running,
                pending: s.pending(),
                arrival_seq: s.arrival_seq,
            });
        }
        loop {
            let Some(core) = self.cores.iter().position(|c| c.is_none()) else {
                break;
            };
            let picked = self.policy.select(us_to_s(now), &views);
            let (sid, view_idx) = match picked {
                Some(i) => {
                    debug_assert!(views[i].pending > 0, "policy picked stage w/o pending");
                    (views[i].stage, i)
                }
                None => break,
            };
            views[view_idx].running += 1;
            views[view_idx].pending -= 1;

            let stage = self.stages.get_mut(&sid).unwrap();
            let task_idx = stage.launch_next();
            let t = &stage.tasks[task_idx];
            let task_id = self.next_task;
            self.next_task += 1;
            let launch = Launch {
                core,
                task: task_id,
                stage: sid,
                job: stage.job,
                user: stage.user,
                task_idx,
                runtime_s: t.runtime_s,
                blocks: t.blocks,
                opcount: t.opcount,
            };
            self.cores[core] = Some(RunningTask {
                task: task_id,
                stage: sid,
                job: stage.job,
                user: stage.user,
                task_idx,
                started: now,
                finish_at: now + s_to_us(t.runtime_s),
            });
            launches.push(launch);
        }
        self.views_buf = views;
        launches
    }

    // ---- completion -----------------------------------------------------

    /// A task finished on `core` (backend callback). Advances stage/job/DAG
    /// state; newly-ready stages are submitted. Call `try_launch` after.
    pub fn task_finished(&mut self, now: TimeUs, core: usize) {
        let rt = self.cores[core]
            .take()
            .expect("task_finished on idle core");
        if self.cfg.log_tasks {
            self.task_log.push(TaskRecord {
                task: rt.task,
                stage: rt.stage,
                job: rt.job,
                user: rt.user,
                core,
                started: rt.started,
                finished: now,
            });
        }
        let stage = self.stages.get_mut(&rt.stage).unwrap();
        stage.task_finished();
        if !stage.is_complete() {
            return;
        }
        // Stage complete: drop from active set, advance the DAG (§2.1.1
        // step 7).
        let stage_idx = stage.idx;
        let job_id = stage.job;
        self.active_stages.retain(|&s| s != rt.stage);
        self.stages.remove(&rt.stage);
        self.policy.on_stage_finish(rt.stage);

        let job = self.jobs.get_mut(&job_id).unwrap();
        let newly_ready = job.mark_done(stage_idx);
        if job.is_complete() {
            job.finish_time = Some(now);
            let rec = CompletedJob {
                job: job_id,
                user: job.spec.user,
                name: job.spec.name.clone(),
                submit: job.submit_time,
                finish: now,
                slot_time: job.spec.slot_time(),
            };
            self.jobs.remove(&job_id);
            self.completed.push(rec);
            self.policy.on_job_finish(us_to_s(now), job_id);
        } else {
            for idx in newly_ready {
                self.submit_stage(now, job_id, idx);
            }
        }
    }

    // ---- introspection --------------------------------------------------

    pub fn busy_cores(&self) -> usize {
        self.cores.iter().filter(|c| c.is_some()).count()
    }

    pub fn core_state(&self, core: usize) -> Option<&RunningTask> {
        self.cores[core].as_ref()
    }

    /// No queued work and no running tasks.
    pub fn is_idle(&self) -> bool {
        self.busy_cores() == 0 && self.active_stages.is_empty()
    }

    pub fn active_stage_count(&self) -> usize {
        self.active_stages.len()
    }

    pub fn pending_task_count(&self) -> u32 {
        self.active_stages
            .iter()
            .map(|s| self.stages[s].pending())
            .sum()
    }

    pub fn in_flight_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// Tasks of one stage (testing / diagnostics).
    pub fn stage(&self, id: StageId) -> Option<&StageState> {
        self.stages.get(&id)
    }

    pub fn stage_of_job(&self, job: JobId, idx: usize) -> Option<&StageState> {
        let sid = (*self.jobs.get(&job)?.stage_ids.get(idx)?)?;
        self.stages.get(&sid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimate::Oracle;
    use crate::partition::SizeScheme;
    use crate::sched::fifo::Fifo;

    fn core(cores: u32) -> SchedCore {
        let cfg = Config {
            cores,
            task_overhead: 0.0,
            log_tasks: true,
            ..Config::default()
        };
        SchedCore::new(
            cfg,
            Box::new(Fifo::new()),
            Box::new(SizeScheme::new(24 << 20, 24 << 20)),
            Box::new(Oracle::new()),
        )
    }

    fn job(user: u32, arrival: TimeUs, compute: f64) -> JobSpec {
        JobSpec::three_phase(user, "t", arrival, compute, 64 << 20, 4, None)
    }

    #[test]
    fn submit_creates_leaf_stage_only() {
        let mut c = core(4);
        let id = c.submit_job(0, job(1, 0, 1.0)).unwrap();
        assert_eq!(c.active_stage_count(), 1);
        let s = c.stage_of_job(id, 0).unwrap();
        // 64 MB / 24 MB = 3 partitions, but >= cores → 4
        assert_eq!(s.tasks.len(), 4);
    }

    #[test]
    fn launch_fills_all_cores() {
        let mut c = core(4);
        c.submit_job(0, job(1, 0, 1.0)).unwrap();
        let launches = c.try_launch(0);
        assert_eq!(launches.len(), 4);
        assert_eq!(c.busy_cores(), 4);
        assert!(c.try_launch(0).is_empty()); // no free cores
    }

    #[test]
    fn full_job_lifecycle_completes() {
        let mut c = core(2);
        c.submit_job(0, job(7, 0, 0.5)).unwrap();
        let mut now = 0;
        // Drive to completion by finishing whatever is running.
        let mut guard = 0;
        loop {
            let launches = c.try_launch(now);
            if launches.is_empty() && c.busy_cores() == 0 {
                break;
            }
            // Finish the earliest-finishing core.
            let (core_idx, fin) = (0..2)
                .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                .min_by_key(|&(_, f)| f)
                .unwrap();
            now = fin;
            c.task_finished(now, core_idx);
            guard += 1;
            assert!(guard < 1000, "no progress");
        }
        assert!(c.is_idle());
        assert_eq!(c.completed.len(), 1);
        let done = &c.completed[0];
        assert_eq!(done.user, 7);
        assert!(done.finish > 0);
        // Task log recorded every task.
        assert!(c.task_log.len() >= 3); // >=1 per stage
    }

    #[test]
    fn task_runtimes_conserve_slot_time() {
        let mut c = core(4);
        let id = c.submit_job(0, job(1, 0, 2.0)).unwrap();
        let s = c.stage_of_job(id, 0).unwrap();
        let total: f64 = s.tasks.iter().map(|t| t.runtime_s).sum();
        // overhead = 0 → sum of task runtimes == stage slot time.
        assert!((total - 2.0 * 0.08).abs() < 1e-9, "total={total}");
    }

    #[test]
    fn collect_stage_single_task() {
        let mut c = core(8);
        let id = c.submit_job(0, job(1, 0, 0.2)).unwrap();
        let mut now = 0;
        // run load + compute to get to collect
        for _ in 0..200 {
            c.try_launch(now);
            if let Some((i, f)) = (0..8)
                .filter_map(|i| c.core_state(i).map(|r| (i, r.finish_at)))
                .min_by_key(|&(_, f)| f)
            {
                now = f;
                c.task_finished(now, i);
            } else {
                break;
            }
            if let Some(s) = c.stage_of_job(id, 3) {
                assert_eq!(s.tasks.len(), 1);
                return; // collect submitted with exactly 1 task — done
            }
        }
        panic!("collect stage never submitted");
    }

    #[test]
    #[should_panic(expected = "task_finished on idle core")]
    fn finish_on_idle_core_panics() {
        let mut c = core(2);
        c.task_finished(0, 0);
    }

    #[test]
    fn rejects_invalid_job() {
        let mut c = core(2);
        let mut bad = job(1, 0, 1.0);
        bad.stages[0].parents = vec![1];
        assert!(c.submit_job(0, bad).is_err());
    }
}
