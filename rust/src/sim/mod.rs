//! Discrete-event cluster simulator — the testbed substitute for the
//! paper's DAS-5 deployment (§5.1).
//!
//! Drives a [`SchedCore`] with two event types: job arrivals (from the
//! workload timeline) and task completions (scheduled at launch time from
//! the task's ground-truth runtime). The event order reproduces Spark's
//! offer loop: every completion frees a core, which is immediately
//! re-offered to the highest-priority pending stage.
//!
//! Time is virtual (µs); a full 500 s macro benchmark over four schedulers
//! simulates in milliseconds, which is what makes the paper's parameter
//! grids reproducible on a laptop.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::core::dag::CompletedJob;
use crate::core::job::JobSpec;
use crate::core::task::TaskRecord;
use crate::core::SchedCore;
use crate::config::Config;
use crate::TimeUs;

/// Simulator events, ordered by time (then by kind for determinism:
/// completions before arrivals at the same instant, so freed cores are
/// visible to newly arriving jobs exactly like in the live system where
/// the completion handler runs first).
#[derive(Clone, Debug, PartialEq, Eq)]
enum Event {
    /// (time, core)
    TaskDone(TimeUs, usize),
    /// (time, index into the workload vector)
    JobArrival(TimeUs, usize),
}

impl Event {
    fn time(&self) -> TimeUs {
        match self {
            Event::TaskDone(t, _) | Event::JobArrival(t, _) => *t,
        }
    }

    /// (time, kind rank, payload) — completions before arrivals at equal
    /// times, payload as a deterministic final tiebreak.
    fn key(&self) -> (TimeUs, u8, usize) {
        match self {
            Event::TaskDone(t, c) => (*t, 0, *c),
            Event::JobArrival(t, i) => (*t, 1, *i),
        }
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Result of a completed simulation run.
pub struct SimReport {
    /// Scheduler/partitioner label ("UWFQ-P", ...).
    pub label: String,
    /// All finished analytics jobs.
    pub completed: Vec<CompletedJob>,
    /// Per-task records (when `cfg.log_tasks`).
    pub task_log: Vec<TaskRecord>,
    /// Virtual time at which the last job finished (the benchmark
    /// "Runtime" column of Table 2).
    pub makespan_s: f64,
    /// Total core-busy time / (cores × makespan).
    pub utilization: f64,
}

/// Simulate `jobs` (any order; sorted internally by arrival) to
/// completion under `cfg`.
pub fn simulate(cfg: Config, jobs: Vec<JobSpec>) -> SimReport {
    let core = SchedCore::from_config(cfg);
    simulate_with(core, jobs)
}

/// Simulate with a pre-built core (custom policy/estimator injections).
pub fn simulate_with(mut core: SchedCore, mut jobs: Vec<JobSpec>) -> SimReport {
    let label = core.cfg.label();
    jobs.sort_by_key(|j| j.arrival);

    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    for (i, j) in jobs.iter().enumerate() {
        heap.push(Reverse(Event::JobArrival(j.arrival, i)));
    }
    // Specs are moved (not cloned) into the engine on arrival — each slot
    // is consumed exactly once.
    let mut jobs: Vec<Option<JobSpec>> = jobs.into_iter().map(Some).collect();

    let mut now: TimeUs = 0;
    let mut busy_us: u128 = 0;
    while let Some(Reverse(ev)) = heap.pop() {
        debug_assert!(ev.time() >= now, "event time regressed");
        now = ev.time();
        match ev {
            Event::JobArrival(t, i) => {
                let spec = jobs[i].take().expect("arrival delivered twice");
                core.submit_job(t, spec)
                    .expect("workload produced invalid job");
            }
            Event::TaskDone(t, c) => {
                core.task_finished(t, c);
            }
        }
        // Drain any same-time events of the same kind cheaply? Not needed:
        // try_launch after every event keeps the offer semantics exact.
        for launch in core.try_launch(now) {
            let fin = now + crate::s_to_us(launch.runtime_s);
            busy_us += (fin - now) as u128;
            heap.push(Reverse(Event::TaskDone(fin, launch.core)));
        }
    }
    assert!(core.is_idle(), "simulation ended with stranded work");

    let makespan_s = crate::us_to_s(
        core.completed
            .iter()
            .map(|c| c.finish)
            .max()
            .unwrap_or(0),
    );
    let cores = core.cfg.cores as f64;
    let utilization = if makespan_s > 0.0 {
        busy_us as f64 / 1e6 / (cores * makespan_s)
    } else {
        0.0
    };
    SimReport {
        label,
        completed: core.completed,
        task_log: core.task_log,
        makespan_s,
        utilization,
    }
}

/// Response time of one job run **alone** on an idle cluster under `cfg`
/// (denominator of the slowdown metric, §5.1.1). Policy is irrelevant in
/// an idle system; partitioning is not.
pub fn idle_response_time(cfg: &Config, job: &JobSpec) -> f64 {
    let mut j = job.clone();
    j.arrival = 0;
    let report = simulate(cfg.clone(), vec![j]);
    report.completed[0].response_time()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobSpec;
    use crate::partition::SchemeKind;
    use crate::sched::PolicyKind;

    fn cfg(cores: u32, policy: PolicyKind) -> Config {
        Config {
            cores,
            task_overhead: 0.0,
            policy,
            log_tasks: true,
            ..Config::default()
        }
    }

    fn job(user: u32, arrival_s: f64, compute: f64) -> JobSpec {
        JobSpec::three_phase(
            user,
            "t",
            crate::s_to_us(arrival_s),
            compute,
            64 << 20,
            4,
            None,
        )
    }

    #[test]
    fn single_job_completes_with_expected_makespan() {
        // Load (leaf): 64 MB / 24 MB maxPartitionBytes = 3, raised to 4
        // cores → wall 0.256/4. Compute (shuffle, AQE): 64/24 → 3
        // partitions on 4 cores → wall 3.2/3. Collect: 1 task, 4 ms.
        let r = simulate(cfg(4, PolicyKind::Fifo), vec![job(1, 0.0, 3.2)]);
        assert_eq!(r.completed.len(), 1);
        let rt = r.completed[0].response_time();
        let expect = 3.2 * 0.08 / 4.0 + 3.2 / 3.0 + 0.004;
        assert!((rt - expect).abs() < 1e-6, "rt={rt} expect={expect}");
    }

    #[test]
    fn work_conservation_all_policies() {
        let jobs: Vec<JobSpec> = (0..6).map(|i| job(i % 3, i as f64 * 0.1, 1.0)).collect();
        for policy in PolicyKind::ALL {
            let r = simulate(cfg(4, policy), jobs.clone());
            assert_eq!(r.completed.len(), 6, "{}", policy.name());
            // With continuous pending work the cluster should be well
            // utilized until the tail.
            assert!(r.utilization > 0.5, "{} util={}", policy.name(), r.utilization);
        }
    }

    #[test]
    fn tasks_never_overlap_on_a_core() {
        let jobs: Vec<JobSpec> = (0..10).map(|i| job(i % 4, i as f64 * 0.05, 0.5)).collect();
        let r = simulate(cfg(4, PolicyKind::Uwfq), jobs);
        let mut by_core: std::collections::HashMap<usize, Vec<(u64, u64)>> =
            std::collections::HashMap::new();
        for t in &r.task_log {
            by_core.entry(t.core).or_default().push((t.started, t.finished));
        }
        for (_, mut spans) in by_core {
            spans.sort();
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0, "tasks overlap on core");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let jobs: Vec<JobSpec> = (0..8).map(|i| job(i % 2, i as f64 * 0.3, 0.7)).collect();
        let a = simulate(cfg(4, PolicyKind::Uwfq), jobs.clone());
        let b = simulate(cfg(4, PolicyKind::Uwfq), jobs);
        let fa: Vec<_> = a.completed.iter().map(|c| (c.job, c.finish)).collect();
        let fb: Vec<_> = b.completed.iter().map(|c| (c.job, c.finish)).collect();
        assert_eq!(fa, fb);
    }

    /// A 500-job mixed-user workload with bursts, duplicates arrival
    /// times (tie-breaking!) and varied sizes — the differential-test
    /// fixture for incremental vs. reference-scan selection.
    fn mixed_workload() -> Vec<JobSpec> {
        (0..500)
            .map(|i| {
                // 17 users with skewed activity; every 5th job arrives in
                // a same-instant burst to exercise tie-breaks.
                let user = ((i * 7) % 17) as u32;
                let arrival_s = if i % 5 == 0 {
                    (i / 5) as f64 * 0.25
                } else {
                    i as f64 * 0.04
                };
                let compute = 0.3 + ((i * 13) % 9) as f64 * 0.45;
                JobSpec::three_phase(
                    user,
                    &format!("m{i}"),
                    crate::s_to_us(arrival_s),
                    compute,
                    (32 + (i as u64 % 5) * 32) << 20,
                    4,
                    None,
                )
            })
            .collect()
    }

    #[test]
    fn incremental_selection_matches_reference_scan_all_policies() {
        // The incremental O(log n) indexes must reproduce the reference
        // snapshot-scan schedule *exactly* — same launches, same ties,
        // byte-identical (job, finish) completion orders — for every
        // policy. (Extends `deterministic_given_seed`: not merely
        // deterministic, but equivalent to the executable specification.)
        let jobs = mixed_workload();
        for policy in PolicyKind::ALL {
            let c = cfg(8, policy);
            let incremental = simulate(c.clone(), jobs.clone());
            let mut reference_core = SchedCore::from_config(c);
            reference_core.force_scan_select = true;
            let reference = simulate_with(reference_core, jobs.clone());
            let fi: Vec<_> = incremental
                .completed
                .iter()
                .map(|r| (r.job, r.finish))
                .collect();
            let fr: Vec<_> = reference
                .completed
                .iter()
                .map(|r| (r.job, r.finish))
                .collect();
            assert_eq!(fi.len(), jobs.len(), "{}", policy.name());
            assert_eq!(fi, fr, "{}: schedules diverged", policy.name());
        }
    }

    #[test]
    fn idle_rt_faster_with_runtime_partitioning_under_skew() {
        // One job, one 5× hot partition under 4-way default partitioning:
        // default RT suffers the straggler; ATR partitioning dilutes it
        // (Fig. 3).
        let skew = crate::core::job::CostProfile::skewed(0.25, 5.0);
        let mk = |scheme| {
            let mut c = cfg(4, PolicyKind::Fifo).with_scheme(scheme);
            c.atr = 0.1;
            c
        };
        let j = JobSpec::three_phase(1, "skewed", 0, 2.0, 64 << 20, 4, Some(skew));
        let rt_default = idle_response_time(&mk(SchemeKind::Size), &j);
        let rt_runtime = idle_response_time(&mk(SchemeKind::Runtime), &j);
        assert!(
            rt_runtime < rt_default * 0.75,
            "runtime partitioning should cut skewed RT: {rt_runtime} vs {rt_default}"
        );
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let r = simulate(
            cfg(2, PolicyKind::Fifo),
            vec![job(1, 0.0, 1.0), job(2, 0.01, 1.0)],
        );
        let first = r.completed.iter().find(|c| c.user == 1).unwrap();
        let second = r.completed.iter().find(|c| c.user == 2).unwrap();
        assert!(first.finish <= second.finish);
    }
}
