//! Discrete-event cluster simulator — the testbed substitute for the
//! paper's DAS-5 deployment (§5.1).
//!
//! Drives a [`SchedCore`] with job arrivals (from the workload timeline)
//! and task events (scheduled at launch time from the task's ground-truth
//! runtime — completion, or the fault-injected failure instant). The
//! event order reproduces Spark's offer loop: every completion frees a
//! core, which is immediately re-offered to the highest-priority pending
//! stage.
//!
//! When fault injection is armed ([`crate::fault::FaultConfig`]) the heap
//! carries three more event kinds: retry wake-ups (failed task's backoff
//! elapsed), speculation wake-ups (straggler passed the `spec_mult`
//! threshold — clone it), and core crash/recover pairs seeded per core
//! from the plan's deterministic gap sequence. All of it is inert at the
//! zero-rate defaults: the heap degenerates to `(time, core)` completions
//! and the schedule is byte-identical to a build without the subsystem.
//!
//! Time is virtual (µs); a full 500 s macro benchmark over four schedulers
//! simulates in milliseconds, which is what makes the paper's parameter
//! grids reproducible on a laptop.
//!
//! # Event core
//!
//! The inner machinery is swappable ([`SimOpts`]): completions and other
//! work events live in a calendar queue ([`calendar::CalendarQueue`],
//! O(1) amortized) with same-timestamp batching through the engine's
//! batched mode, or in the classic binary heap with strictly per-event
//! processing (`UWFQ_EVENT_HEAP=1` — the executable specification).
//! Both produce byte-identical schedules; `tests/invariants.rs` holds
//! the differential.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::config::Config;
use crate::core::dag::CompletedJob;
use crate::core::job::JobSpec;
use crate::core::task::TaskRecord;
use crate::core::{Launch, SchedCore, TaskEvent, TaskEventClass};
use crate::fault::FaultStats;
use crate::workload::stream::{JobStream, VecStream};
use crate::TimeUs;

pub mod calendar;
pub mod event;
pub mod shard;

pub use calendar::{CalendarQueue, EventBackend, EventQ};
pub use event::Ev;
pub use shard::{
    rebalance_cores, run_sharded, shard_cores, shard_of, ShardLoad, ShardRun, ShardSummary,
    SyncStats,
};
use event::{KIND_CRASH, KIND_RECOVER, KIND_RETRY, KIND_SPEC, KIND_TASK};

/// Event-core configuration for one simulation run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimOpts {
    /// Queue backend for completion/retry/spec-wake events.
    pub backend: EventBackend,
    /// Same-timestamp batching (one coalesced policy notification and
    /// one deferred offer per batch of plain finishes). Schedule-
    /// preserving; `false` runs the pristine per-event path.
    pub batch: bool,
}

impl Default for SimOpts {
    fn default() -> Self {
        SimOpts {
            backend: EventBackend::Wheel,
            batch: true,
        }
    }
}

static OPTS_FROM_ENV: OnceLock<SimOpts> = OnceLock::new();

impl SimOpts {
    /// The process-wide default, honoring the `UWFQ_EVENT_HEAP=1`
    /// escape hatch (binary heap + per-event processing — the reference
    /// semantics, and the rollback switch if the calendar path ever
    /// misbehaves in the field). Read once and cached.
    pub fn from_env() -> SimOpts {
        *OPTS_FROM_ENV.get_or_init(|| {
            let heap = std::env::var("UWFQ_EVENT_HEAP")
                .map(|v| v == "1")
                .unwrap_or(false);
            if heap {
                SimOpts {
                    backend: EventBackend::Heap,
                    batch: false,
                }
            } else {
                SimOpts::default()
            }
        })
    }
}

/// Result of a completed simulation run.
pub struct SimReport {
    /// Scheduler/partitioner label ("UWFQ-P", ...).
    pub label: String,
    /// All finished analytics jobs.
    pub completed: Vec<CompletedJob>,
    /// Per-task records (when `cfg.log_tasks`).
    pub task_log: Vec<TaskRecord>,
    /// Virtual time at which the last job finished (the benchmark
    /// "Runtime" column of Table 2).
    pub makespan_s: f64,
    /// Total core-busy time / (cores × makespan).
    pub utilization: f64,
    /// Fault-injection counters and the goodput-vs-waste ledger (all
    /// zeros on a fault-free run).
    pub fault: FaultStats,
}

/// Simulate `jobs` (any order; sorted internally by arrival) to
/// completion under `cfg`.
pub fn simulate(cfg: Config, jobs: Vec<JobSpec>) -> SimReport {
    let mut core = SchedCore::from_config(cfg);
    simulate_into(&mut core, jobs)
}

/// Simulate with a pre-built core (custom policy/estimator injections).
pub fn simulate_with(mut core: SchedCore, jobs: Vec<JobSpec>) -> SimReport {
    simulate_into(&mut core, jobs)
}

/// Simulate with an explicit event-core configuration. The differential
/// tests and the hotpath bench pin both sides of the wheel-vs-heap
/// comparison through this instead of racing on `UWFQ_EVENT_HEAP`.
pub fn simulate_opts(cfg: Config, jobs: Vec<JobSpec>, opts: SimOpts) -> SimReport {
    let mut core = SchedCore::from_config(cfg);
    let mut sink = CollectSink::default();
    let summary = simulate_stream_into_opts(&mut core, VecStream::new(jobs), &mut sink, opts);
    SimReport {
        label: summary.label,
        completed: sink.completed,
        task_log: std::mem::take(&mut core.task_log),
        makespan_s: summary.makespan_s,
        utilization: summary.utilization,
        fault: summary.fault,
    }
}

/// Simulate on a borrowed core — the sweep engine's reuse path: workers
/// recycle one core's allocations across grid cells via
/// [`SchedCore::reset`]. The core must be freshly built or reset; its
/// `task_log` is moved into the returned report.
///
/// This is the exact in-memory path (every [`CompletedJob`] retained in
/// the report), implemented on the streaming event loop
/// ([`simulate_stream_into`]) with a collecting sink — the two paths are
/// one loop, so they cannot drift.
pub fn simulate_into(core: &mut SchedCore, jobs: Vec<JobSpec>) -> SimReport {
    // VecStream stable-sorts by arrival: same-instant arrivals keep
    // workload order, matching the old heap's (time, kind, index)
    // tie-break.
    let mut sink = CollectSink::default();
    let summary = simulate_stream_into(core, VecStream::new(jobs), &mut sink);
    SimReport {
        label: summary.label,
        completed: sink.completed,
        task_log: std::mem::take(&mut core.task_log),
        makespan_s: summary.makespan_s,
        utilization: summary.utilization,
        fault: summary.fault,
    }
}

// ---------------------------------------------------------------------------
// Streaming simulation
// ---------------------------------------------------------------------------

/// Receives finished jobs as the simulation runs — the streaming
/// pipeline's output port. Bounded-memory sinks
/// ([`crate::metrics::streaming::StreamingRunMetrics`]) fold each job
/// into O(1) accumulator state; [`CollectSink`] retains everything (the
/// exact paper-table path).
pub trait CompletionSink {
    fn job_completed(&mut self, job: CompletedJob);
}

/// Retains every completed job — the exact in-memory reference sink.
#[derive(Default)]
pub struct CollectSink {
    pub completed: Vec<CompletedJob>,
}

impl CompletionSink for CollectSink {
    fn job_completed(&mut self, job: CompletedJob) {
        self.completed.push(job);
    }
}

/// Aggregate outcome of a streaming simulation (everything the metrics
/// sink cannot see itself).
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Scheduler/partitioner label ("UWFQ-P", ...).
    pub label: String,
    pub jobs_completed: u64,
    /// Task completions processed (the hot-path event count).
    pub task_events: u64,
    /// Peak number of concurrently in-flight jobs — the engine's resident
    /// state is O(this), not O(total jobs).
    pub peak_in_flight_jobs: usize,
    pub makespan_s: f64,
    pub utilization: f64,
    /// Total core-busy µs (goodput + waste) — the utilization numerator,
    /// carried so merged multi-shard summaries can recompute utilization
    /// exactly instead of un-dividing a float.
    pub busy_core_us: u128,
    /// Fault-injection counters and the goodput-vs-waste ledger (all
    /// zeros on a fault-free run).
    pub fault: FaultStats,
}

/// Drive a [`SchedCore`] from a lazy [`JobStream`], draining every
/// completed job into `sink` as it finishes. Resident state is the
/// engine's (O(in-flight jobs + active stages + cores)) plus whatever the
/// sink keeps — with a streaming sink, a million-job run never holds more
/// than the live backlog.
///
/// Event ordering (identical to [`simulate_into`], which shares this
/// loop): events fire in time order; at equal times heap events run
/// before arrivals (freed cores are visible to newly arriving jobs
/// exactly like in the live system, where the completion handler runs
/// first), same-time events fire lowest-kind-then-lowest-core first, and
/// same-time arrivals fire in stream order. Arrivals come from the
/// stream cursor rather than the heap. The stream must yield
/// nondecreasing arrivals (debug-asserted). Launches go through a
/// reusable buffer ([`SchedCore::try_launch_into`]) — zero per-event
/// allocations.
///
/// Heap entries are `(time, kind, a, b)`:
///
/// | kind | event           | `a`, `b`          | work? |
/// |------|-----------------|-------------------|-------|
/// | 0    | task event      | core, launch seq  | yes   |
/// | 1    | retry ready     | stage, task idx   | yes   |
/// | 2    | spec wake-up    | core, launch seq  | yes   |
/// | 3    | core recovers   | core, 0           | no    |
/// | 4    | core crashes    | core, 0           | no    |
///
/// "Work" events carry (or may spawn) task progress; environment events
/// (crash/recover) recur forever, so the loop ends when arrivals are
/// exhausted, no work events remain and the engine is idle — leftover
/// environment events are discarded. On the fault-free path only kind 0
/// exists and the tuple degenerates to the historical `(time, core)`
/// order, launch seqs never tie on one core.
///
/// Event-core options come from [`SimOpts::from_env`]
/// (`UWFQ_EVENT_HEAP=1` selects the binary-heap, per-event reference
/// path); use [`simulate_stream_into_opts`] to pin them explicitly.
pub fn simulate_stream_into<S: JobStream, K: CompletionSink>(
    core: &mut SchedCore,
    stream: S,
    sink: &mut K,
) -> StreamSummary {
    simulate_stream_into_opts(core, stream, sink, SimOpts::from_env())
}

/// Offer free cores to the policy and schedule the resulting launches:
/// one completion event each, plus a speculation wake-up for flagged
/// stragglers. The single point where work enters the queue.
fn offer(
    core: &mut SchedCore,
    q: &mut EventQ,
    launches: &mut Vec<Launch>,
    now: TimeUs,
    work_events: &mut u64,
) {
    core.try_launch_into(now, launches);
    for launch in launches.iter() {
        q.push(Ev::task(launch.finish_at, launch.core as u64, launch.seq));
        *work_events += 1;
        if let Some(wake) = launch.spec_wake_at {
            q.push(Ev::spec(wake, launch.core as u64, launch.seq));
            *work_events += 1;
        }
    }
}

/// [`simulate_stream_into`] with the event core pinned by the caller.
///
/// With `opts.batch` set, runs of same-timestamp *plain* finishes (clean,
/// unraced, stage stays incomplete — see
/// [`TaskEventClass`](crate::core::TaskEventClass)) are applied eagerly
/// while their policy notification coalesces into one
/// `on_tasks_finished` call and — for static-key policies — their
/// post-event offers merge into one deferred [`offer`] discharged at the
/// batch boundary (time advances, a non-plain event, an arrival, or
/// queue exhaustion). Cores free in ascending order within a batch and
/// static keys make selection independent of finish notifications, so
/// the merged offer reproduces the per-event (core, stage) pairing
/// bit-for-bit; dynamic-key policies keep per-event offers and only
/// coalesce notifications. Every per-event offer is guarded by
/// [`SchedCore::can_launch`] — exact, because an offer launches nothing
/// (and touches no policy state) unless a core is free *and* a task is
/// pending.
pub fn simulate_stream_into_opts<S: JobStream, K: CompletionSink>(
    core: &mut SchedCore,
    stream: S,
    sink: &mut K,
    opts: SimOpts,
) -> StreamSummary {
    let mut sim = StreamSim::new(core, stream, sink, opts);
    let done = sim.run_until(TimeUs::MAX);
    debug_assert!(done, "run_until(MAX) cannot pause");
    sim.finish()
}

/// A resumable streaming simulation: the one true event loop, pausable at
/// a virtual-time horizon. [`simulate_stream_into_opts`] is exactly
/// `new` → `run_until(TimeUs::MAX)` → `finish`; the sharded engine
/// ([`shard::run_sharded`]) drives the same loop epoch-by-epoch with a
/// sync barrier between `run_until` calls — one loop, so the sharded and
/// unsharded paths cannot drift.
///
/// Pausing is schedule-neutral: the driver stops *before* consuming the
/// first event or arrival past the horizon, so every state transition
/// happens at the same instant, in the same order, as an uninterrupted
/// run. A batch whose deferred offer is still pending at the horizon is
/// discharged at its own timestamp first (exactly what an event past the
/// horizon would have forced), then the pause decision is re-evaluated —
/// the discharge may schedule completions inside the horizon.
pub struct StreamSim<'a, S, K> {
    core: &'a mut SchedCore,
    stream: S,
    sink: &'a mut K,
    label: String,
    q: EventQ,
    launches: Vec<Launch>,
    next_arrival_spec: Option<JobSpec>,
    now: TimeUs,
    task_events: u64,
    work_events: u64,
    jobs_completed: u64,
    peak_in_flight: usize,
    max_finish: TimeUs,
    batch_offers: bool,
    offer_pending: bool,
}

impl<'a, S: JobStream, K: CompletionSink> StreamSim<'a, S, K> {
    pub fn new(core: &'a mut SchedCore, mut stream: S, sink: &'a mut K, opts: SimOpts) -> Self {
        let label = core.cfg.label();
        let mut q = EventQ::new(opts.backend);
        let next_arrival_spec = stream.next_job();

        core.set_batching(opts.batch);
        // Offer merging is only schedule-preserving when selection keys
        // are static (FIFO/CFQ/UWFQ); dynamic-key policies (Fair/UJF) get
        // coalesced notifications but per-event offers.
        let batch_offers = opts.batch && core.policy.static_keys();

        // Arm the crash clock of every core from the plan's per-core gap
        // sequence (no-op unless `fault.crash_mttf_s > 0`).
        if core.faults_enabled() {
            for c in 0..core.cfg.cores as usize {
                if let Some(gap) = core.next_crash_gap_us(c) {
                    q.push(Ev::crash(gap, c as u64));
                }
            }
        }
        StreamSim {
            core,
            stream,
            sink,
            label,
            q,
            launches: Vec::new(),
            next_arrival_spec,
            now: 0,
            task_events: 0,
            work_events: 0,
            jobs_completed: 0,
            peak_in_flight: 0,
            max_finish: 0,
            batch_offers,
            offer_pending: false,
        }
    }

    /// Advance until the simulation completes (`true`) or the next event
    /// or arrival lies strictly past `limit` (`false` — paused, resumable
    /// with a later horizon). `run_until(TimeUs::MAX)` never pauses.
    pub fn run_until(&mut self, limit: TimeUs) -> bool {
        loop {
            if self.next_arrival_spec.is_none() && self.work_events == 0 && self.core.is_idle() {
                // A pending offer implies an incomplete stage, which keeps
                // the engine non-idle — this break never strands a batch.
                debug_assert!(!self.offer_pending);
                return true; // only recurring crash/recover events remain
            }
            let next_done = self.q.peek_t();
            let next_arrival = self.next_arrival_spec.as_ref().map(|j| j.arrival);
            let take_done = match (next_done, next_arrival) {
                (None, None) => {
                    if self.offer_pending {
                        // Queue ran dry mid-batch (e.g. the batch freed
                        // the only busy cores): discharge and re-evaluate.
                        self.discharge_offer();
                        continue;
                    }
                    return true;
                }
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(d), Some(a)) => d <= a, // queue events first at ties
            };
            let next_t = if take_done {
                next_done.expect("take_done implies a queued event")
            } else {
                next_arrival.expect("!take_done implies an arrival")
            };
            if next_t > limit {
                if self.offer_pending {
                    // Same boundary rule as a past-horizon event: the
                    // batch discharges at its own timestamp, possibly
                    // scheduling work inside the horizon — re-evaluate.
                    self.discharge_offer();
                    continue;
                }
                return false; // paused at the horizon
            }
            if take_done {
                self.step_event();
            } else {
                self.step_arrival();
            }
            // Drain finished jobs immediately: the engine never
            // accumulates per-job completion state on the streaming path.
            if !self.core.completed.is_empty() {
                for c in self.core.completed.drain(..) {
                    self.max_finish = self.max_finish.max(c.finish);
                    self.jobs_completed += 1;
                    self.sink.job_completed(c);
                }
            }
        }
    }

    /// Discharge the deferred batch offer at the batch's own timestamp.
    fn discharge_offer(&mut self) {
        offer(
            self.core,
            &mut self.q,
            &mut self.launches,
            self.now,
            &mut self.work_events,
        );
        self.offer_pending = false;
    }

    /// Apply the earliest queued event (completion/retry/spec/crash/
    /// recover) — the `take_done` arm of the loop.
    fn step_event(&mut self) {
        let core = &mut *self.core;
        let q = &mut self.q;
        let ev = q.pop().expect("peeked event");
        debug_assert!(ev.t >= self.now, "event time regressed");
        if self.offer_pending && (ev.t != self.now || ev.kind != KIND_TASK) {
            // Batch boundary: discharge at the batch's timestamp,
            // before the clock moves or a non-plain event applies.
            offer(core, q, &mut self.launches, self.now, &mut self.work_events);
            self.offer_pending = false;
        }
        self.now = ev.t;
        let now = self.now;
        match ev.kind {
            KIND_TASK => {
                self.work_events -= 1;
                // Completions of killed/crashed attempts are stale
                // (the launch seq no longer matches) and are dropped.
                if core.is_stale(ev.a as usize, ev.b) {
                    // No state changed, so a deferred offer stays
                    // deferred: the per-event path's post-stale
                    // offer launches nothing.
                } else if self.batch_offers
                    && matches!(core.classify_task_event(ev.a as usize), TaskEventClass::Plain)
                {
                    // Plain same-t finish: apply now, notify and
                    // offer once at the batch boundary.
                    self.task_events += 1;
                    if let TaskEvent::Failed { .. } = core.task_event(now, ev.a as usize) {
                        unreachable!("plain-classified task event failed");
                    }
                    self.offer_pending = true;
                } else {
                    if self.offer_pending {
                        // A fail/boundary finish interrupts the
                        // batch: discharge first, apply after.
                        offer(core, q, &mut self.launches, now, &mut self.work_events);
                        self.offer_pending = false;
                    }
                    self.task_events += 1;
                    if let TaskEvent::Failed { stage, task, retry_at } =
                        core.task_event(now, ev.a as usize)
                    {
                        q.push(Ev::retry(retry_at, stage, task as u64));
                        self.work_events += 1;
                    }
                    if core.can_launch() {
                        offer(core, q, &mut self.launches, now, &mut self.work_events);
                    }
                }
            }
            KIND_RETRY => {
                self.work_events -= 1;
                core.retry_ready(now, ev.a, ev.b as u32);
                if core.can_launch() {
                    offer(core, q, &mut self.launches, now, &mut self.work_events);
                }
            }
            KIND_SPEC => {
                self.work_events -= 1;
                if let Some((fin, c2, seq)) = core.spec_wake(now, ev.a as usize, ev.b) {
                    q.push(Ev::task(fin, c2 as u64, seq));
                    self.work_events += 1;
                }
                if core.can_launch() {
                    offer(core, q, &mut self.launches, now, &mut self.work_events);
                }
            }
            KIND_RECOVER => {
                core.recover(now, ev.a as usize);
                if core.can_launch() {
                    offer(core, q, &mut self.launches, now, &mut self.work_events);
                }
            }
            KIND_CRASH => {
                core.crash(now, ev.a as usize);
                let recover_at = now + core.recover_delay_us();
                q.push(Ev::recover(recover_at, ev.a));
                // Next crash only after the core is back in service.
                if let Some(gap) = core.next_crash_gap_us(ev.a as usize) {
                    q.push(Ev::crash(recover_at + gap, ev.a));
                }
                if core.can_launch() {
                    offer(core, q, &mut self.launches, now, &mut self.work_events);
                }
            }
            _ => unreachable!("unknown event kind"),
        }
    }

    /// Submit the next stream arrival — the `!take_done` arm of the loop.
    fn step_arrival(&mut self) {
        let core = &mut *self.core;
        // Specs are moved (not cloned) into the engine on arrival.
        let spec = self.next_arrival_spec.take().expect("peeked arrival");
        debug_assert!(spec.arrival >= self.now, "stream arrivals regressed");
        if self.offer_pending {
            // Per-event mode offers before the arrival submits:
            // discharge the batch at its own timestamp first.
            offer(core, &mut self.q, &mut self.launches, self.now, &mut self.work_events);
            self.offer_pending = false;
        }
        self.now = spec.arrival;
        core.submit_job(self.now, spec)
            .expect("workload produced invalid job");
        self.next_arrival_spec = self.stream.next_job();
        self.peak_in_flight = self.peak_in_flight.max(core.in_flight_jobs());
        if core.can_launch() {
            offer(core, &mut self.q, &mut self.launches, self.now, &mut self.work_events);
        }
    }

    /// Current simulated instant (last processed event/arrival time).
    pub fn now(&self) -> TimeUs {
        self.now
    }

    /// The driven engine — the sharded runner re-couples the policy's
    /// virtual time through this at sync barriers, *between* `run_until`
    /// calls. Mutating scheduling state mid-epoch voids the schedule
    /// contract.
    pub fn core_mut(&mut self) -> &mut SchedCore {
        self.core
    }

    /// Finalize a completed run into its summary. Panics if work is still
    /// pending — call only after `run_until` returned `true`.
    pub fn finish(self) -> StreamSummary {
        self.core.set_batching(false);
        assert!(self.core.is_idle(), "simulation ended with stranded work");

        let makespan_s = crate::us_to_s(self.max_finish);
        let cores = self.core.cfg.cores as f64;
        let busy_core_us = self.core.busy_core_us();
        let utilization = if makespan_s > 0.0 {
            // Engine-side ledger (goodput + waste): re-execution, killed
            // clones and crash-lost attempts all count the core-time they
            // actually consumed. Fault-free runs reduce to the historical
            // sum of launch runtimes, bit-for-bit.
            busy_core_us as f64 / 1e6 / (cores * makespan_s)
        } else {
            0.0
        };
        StreamSummary {
            label: self.label,
            jobs_completed: self.jobs_completed,
            task_events: self.task_events,
            peak_in_flight_jobs: self.peak_in_flight,
            makespan_s,
            utilization,
            busy_core_us,
            fault: self.core.fault_stats.clone(),
        }
    }
}

/// Convenience: stream a workload through a fresh core and collect the
/// full report (the streamed twin of [`simulate`], used by the
/// differential tests).
pub fn simulate_stream<S: JobStream>(cfg: Config, stream: S) -> SimReport {
    let mut core = SchedCore::from_config(cfg);
    let mut sink = CollectSink::default();
    let summary = simulate_stream_into(&mut core, stream, &mut sink);
    SimReport {
        label: summary.label,
        completed: sink.completed,
        task_log: std::mem::take(&mut core.task_log),
        makespan_s: summary.makespan_s,
        utilization: summary.utilization,
        fault: summary.fault,
    }
}

// ---------------------------------------------------------------------------
// Reusable simulation context
// ---------------------------------------------------------------------------

/// A reusable simulation context: holds one [`SchedCore`] whose
/// allocations (slab arenas, heaps, scratch buffers) are recycled across
/// runs via [`SchedCore::reset`]. One lives in every sweep worker; results
/// are identical to building a fresh core per run.
#[derive(Default)]
pub struct SimCtx {
    core: Option<SchedCore>,
}

impl SimCtx {
    pub fn new() -> SimCtx {
        SimCtx { core: None }
    }

    /// Run one simulation, recycling this context's core.
    pub fn simulate(&mut self, cfg: &Config, jobs: Vec<JobSpec>) -> SimReport {
        let mut core = match self.core.take() {
            Some(mut core) => {
                core.reset(cfg.clone());
                core
            }
            None => SchedCore::from_config(cfg.clone()),
        };
        let report = simulate_into(&mut core, jobs);
        self.core = Some(core);
        report
    }

    /// Memoized idle response time (same process-wide cache as
    /// [`idle_response_time`]); cache misses are simulated on the
    /// recycled core.
    pub fn idle_response_time(&mut self, cfg: &Config, job: &JobSpec) -> f64 {
        idle_rt_memo(cfg, job, |cfg, j| {
            self.simulate(cfg, vec![j]).completed[0].response_time()
        })
    }
}

// ---------------------------------------------------------------------------
// Idle-response memoization
// ---------------------------------------------------------------------------

/// User-independent memo key for an idle run: every config field and
/// stage-structure field that can influence a single-job simulation,
/// floats captured exactly via their bit patterns. Deliberately excludes
/// user id, job name and arrival — hundreds of jobs sharing one template
/// (e.g. every "tiny" job of a scenario) collapse to one entry.
#[derive(Clone, PartialEq, Eq, Hash)]
struct IdleKey(Vec<u64>);

fn idle_key(cfg: &Config, job: &JobSpec) -> IdleKey {
    let mut k: Vec<u64> = Vec::with_capacity(14 + job.stages.len() * 10);
    k.push(cfg.cores as u64);
    k.push(cfg.task_overhead.to_bits());
    k.push(cfg.atr.to_bits());
    k.push(cfg.max_partition_bytes);
    k.push(cfg.advisory_partition_bytes);
    k.push(cfg.scheme as u64);
    k.push(cfg.seed);
    k.push(cfg.estimator_sigma.to_bits());
    k.push(job.weight.to_bits());
    // DAG-shape fingerprint: a single digest of the full parent wiring.
    // The per-stage fields below length-prefix each parent list, but the
    // digest makes shape distinctness independent of how those fields
    // evolve — two jobs of equal slot-time with different wiring (chain
    // vs fork-join) can never alias to one memoized baseline.
    k.push(dag_shape_fingerprint(job));
    // In a strict stage chain exactly one stage is selectable at any
    // instant, so the scheduling policy cannot influence an idle run —
    // those entries are shared across policy cells (the common case:
    // every paper workload is a chain). Any other DAG shape could order
    // sibling stages differently per policy, so it keys on the policy.
    let chain = job.stages.iter().enumerate().all(|(i, s)| {
        if i == 0 {
            s.parents.is_empty()
        } else {
            s.parents.len() == 1 && s.parents[0] == i - 1
        }
    });
    if chain {
        k.push(0);
    } else {
        k.push(1);
        k.push(cfg.policy as u64);
        k.push(cfg.grace_rsec.to_bits());
        k.push(cfg.bopf_burst_rsec.to_bits());
    }
    k.push(job.stages.len() as u64);
    for s in &job.stages {
        k.push(s.phase as u64);
        k.push(s.is_leaf_input as u64);
        k.push(s.input_bytes);
        k.push(s.slot_time.to_bits());
        k.push(s.max_parallelism.map_or(0, |m| m as u64 + 1));
        k.push(s.opcount as u64);
        k.push(s.parents.len() as u64);
        for &p in &s.parents {
            k.push(p as u64);
        }
        k.push(s.cost.regions().len() as u64);
        for &(f, w) in s.cost.regions() {
            k.push(f.to_bits());
            k.push(w.to_bits());
        }
    }
    IdleKey(k)
}

/// FNV-1a digest of a job's DAG *shape*: stage count plus every stage's
/// parent list, each length-prefixed so `[[0],[]]` and `[[],[0]]` mix
/// differently. Slot-times and costs are deliberately excluded — this
/// captures wiring only.
fn dag_shape_fingerprint(job: &JobSpec) -> u64 {
    fn mix(mut h: u64, v: u64) -> u64 {
        for b in v.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = mix(h, job.stages.len() as u64);
    for s in &job.stages {
        h = mix(h, s.parents.len() as u64);
        for &p in &s.parents {
            h = mix(h, p as u64);
        }
    }
    h
}

/// Hash-sharded segments of the idle-response memo: parallel shards (and
/// sweep workers) distribute across `IDLE_SEGMENTS` independent mutexes
/// instead of serializing on one process-wide lock. Keys land in a
/// segment by their own hash, so a key always maps to the same segment.
const IDLE_SEGMENTS: usize = 16;

static IDLE_CACHE: OnceLock<[Mutex<HashMap<IdleKey, f64>>; IDLE_SEGMENTS]> = OnceLock::new();
static IDLE_HITS: AtomicU64 = AtomicU64::new(0);
static IDLE_MISSES: AtomicU64 = AtomicU64::new(0);
static IDLE_CONTENDED: AtomicU64 = AtomicU64::new(0);

fn idle_segment(key: &IdleKey) -> &'static Mutex<HashMap<IdleKey, f64>> {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    let cache = IDLE_CACHE.get_or_init(Default::default);
    &cache[h.finish() as usize % IDLE_SEGMENTS]
}

/// Lock a segment, counting contended acquisitions (another thread held
/// the lock at the instant we asked — the metric the segment count is
/// meant to drive toward zero).
fn idle_lock(
    seg: &'static Mutex<HashMap<IdleKey, f64>>,
) -> std::sync::MutexGuard<'static, HashMap<IdleKey, f64>> {
    if let Ok(g) = seg.try_lock() {
        return g;
    }
    IDLE_CONTENDED.fetch_add(1, Ordering::Relaxed);
    seg.lock().unwrap()
}

fn idle_rt_memo(
    cfg: &Config,
    job: &JobSpec,
    run: impl FnOnce(&Config, JobSpec) -> f64,
) -> f64 {
    // Idle baselines are fault-free by definition: the slowdown
    // denominator is the job alone on a *healthy* cluster, which is also
    // why the memo key carries no fault fields.
    let clean;
    let cfg = if cfg.fault.enabled() {
        clean = Config {
            fault: Default::default(),
            ..cfg.clone()
        };
        &clean
    } else {
        cfg
    };
    let key = idle_key(cfg, job);
    let seg = idle_segment(&key);
    if let Some(&rt) = idle_lock(seg).get(&key) {
        IDLE_HITS.fetch_add(1, Ordering::Relaxed);
        return rt;
    }
    IDLE_MISSES.fetch_add(1, Ordering::Relaxed);
    // Simulate outside the lock: concurrent sweep workers missing on the
    // same key briefly duplicate work, but compute the identical
    // deterministic value, so the overwrite is benign.
    let mut j = job.clone();
    j.arrival = 0;
    let rt = run(cfg, j);
    idle_lock(seg).insert(key, rt);
    rt
}

/// (hits, misses, contended lock acquisitions) of the idle-response memo
/// cache — observability for the memoization test, the sweep report and
/// the sharded engine's contention check.
pub fn idle_cache_stats() -> (u64, u64, u64) {
    (
        IDLE_HITS.load(Ordering::Relaxed),
        IDLE_MISSES.load(Ordering::Relaxed),
        IDLE_CONTENDED.load(Ordering::Relaxed),
    )
}

/// Response time of one job run **alone** on an idle cluster under `cfg`
/// (denominator of the slowdown metric, §5.1.1). Memoized process-wide by
/// a user-independent shape key: slowdown denominators no longer re-run a
/// full simulation per job when hundreds of jobs share one template.
pub fn idle_response_time(cfg: &Config, job: &JobSpec) -> f64 {
    idle_rt_memo(cfg, job, |cfg, j| {
        simulate(cfg.clone(), vec![j]).completed[0].response_time()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::JobSpec;
    use crate::partition::SchemeKind;
    use crate::sched::PolicyKind;

    fn cfg(cores: u32, policy: PolicyKind) -> Config {
        Config {
            cores,
            task_overhead: 0.0,
            policy,
            log_tasks: true,
            ..Config::default()
        }
    }

    fn job(user: u32, arrival_s: f64, compute: f64) -> JobSpec {
        JobSpec::three_phase(
            user,
            "t",
            crate::s_to_us(arrival_s),
            compute,
            64 << 20,
            4,
            None,
        )
    }

    #[test]
    fn single_job_completes_with_expected_makespan() {
        // Load (leaf): 64 MB / 24 MB maxPartitionBytes = 3, raised to 4
        // cores → wall 0.256/4. Compute (shuffle, AQE): 64/24 → 3
        // partitions on 4 cores → wall 3.2/3. Collect: 1 task, 4 ms.
        let r = simulate(cfg(4, PolicyKind::Fifo), vec![job(1, 0.0, 3.2)]);
        assert_eq!(r.completed.len(), 1);
        let rt = r.completed[0].response_time();
        let expect = 3.2 * 0.08 / 4.0 + 3.2 / 3.0 + 0.004;
        assert!((rt - expect).abs() < 1e-6, "rt={rt} expect={expect}");
    }

    #[test]
    fn work_conservation_all_policies() {
        let jobs: Vec<JobSpec> = (0..6).map(|i| job(i % 3, i as f64 * 0.1, 1.0)).collect();
        for policy in PolicyKind::ALL {
            let r = simulate(cfg(4, policy), jobs.clone());
            assert_eq!(r.completed.len(), 6, "{}", policy.name());
            // With continuous pending work the cluster should be well
            // utilized until the tail.
            assert!(r.utilization > 0.5, "{} util={}", policy.name(), r.utilization);
        }
    }

    #[test]
    fn tasks_never_overlap_on_a_core() {
        let jobs: Vec<JobSpec> = (0..10).map(|i| job(i % 4, i as f64 * 0.05, 0.5)).collect();
        let r = simulate(cfg(4, PolicyKind::Uwfq), jobs);
        let mut by_core: std::collections::HashMap<usize, Vec<(u64, u64)>> =
            std::collections::HashMap::new();
        for t in &r.task_log {
            by_core.entry(t.core).or_default().push((t.started, t.finished));
        }
        for (_, mut spans) in by_core {
            spans.sort();
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0, "tasks overlap on core");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let jobs: Vec<JobSpec> = (0..8).map(|i| job(i % 2, i as f64 * 0.3, 0.7)).collect();
        let a = simulate(cfg(4, PolicyKind::Uwfq), jobs.clone());
        let b = simulate(cfg(4, PolicyKind::Uwfq), jobs);
        let fa: Vec<_> = a.completed.iter().map(|c| (c.job, c.finish)).collect();
        let fb: Vec<_> = b.completed.iter().map(|c| (c.job, c.finish)).collect();
        assert_eq!(fa, fb);
    }

    /// A 500-job mixed-user workload with bursts, duplicates arrival
    /// times (tie-breaking!) and varied sizes — the differential-test
    /// fixture for incremental vs. reference-scan selection.
    fn mixed_workload() -> Vec<JobSpec> {
        (0..500)
            .map(|i| {
                // 17 users with skewed activity; every 5th job arrives in
                // a same-instant burst to exercise tie-breaks.
                let user = ((i * 7) % 17) as u32;
                let arrival_s = if i % 5 == 0 {
                    (i / 5) as f64 * 0.25
                } else {
                    i as f64 * 0.04
                };
                let compute = 0.3 + ((i * 13) % 9) as f64 * 0.45;
                JobSpec::three_phase(
                    user,
                    &format!("m{i}"),
                    crate::s_to_us(arrival_s),
                    compute,
                    (32 + (i as u64 % 5) * 32) << 20,
                    4,
                    None,
                )
            })
            .collect()
    }

    #[test]
    fn incremental_selection_matches_reference_scan_all_policies() {
        // The incremental O(log n) indexes must reproduce the reference
        // snapshot-scan schedule *exactly* — same launches, same ties,
        // byte-identical (job, finish) completion orders — for every
        // policy. (Extends `deterministic_given_seed`: not merely
        // deterministic, but equivalent to the executable specification.)
        let jobs = mixed_workload();
        for policy in PolicyKind::ALL {
            let c = cfg(8, policy);
            let incremental = simulate(c.clone(), jobs.clone());
            let mut reference_core = SchedCore::from_config(c);
            reference_core.force_scan_select = true;
            let reference = simulate_with(reference_core, jobs.clone());
            let fi: Vec<_> = incremental
                .completed
                .iter()
                .map(|r| (r.job, r.finish))
                .collect();
            let fr: Vec<_> = reference
                .completed
                .iter()
                .map(|r| (r.job, r.finish))
                .collect();
            assert_eq!(fi.len(), jobs.len(), "{}", policy.name());
            assert_eq!(fi, fr, "{}: schedules diverged", policy.name());
        }
    }

    #[test]
    fn idle_rt_faster_with_runtime_partitioning_under_skew() {
        // One job, one 5× hot partition under 4-way default partitioning:
        // default RT suffers the straggler; ATR partitioning dilutes it
        // (Fig. 3).
        let skew = crate::core::job::CostProfile::skewed(0.25, 5.0);
        let mk = |scheme| {
            let mut c = cfg(4, PolicyKind::Fifo).with_scheme(scheme);
            c.atr = 0.1;
            c
        };
        let j = JobSpec::three_phase(1, "skewed", 0, 2.0, 64 << 20, 4, Some(skew));
        let rt_default = idle_response_time(&mk(SchemeKind::Size), &j);
        let rt_runtime = idle_response_time(&mk(SchemeKind::Runtime), &j);
        assert!(
            rt_runtime < rt_default * 0.75,
            "runtime partitioning should cut skewed RT: {rt_runtime} vs {rt_default}"
        );
    }

    #[test]
    fn sim_ctx_reuse_matches_fresh_cores_across_policies() {
        // One context re-used across policies and runs (the sweep worker
        // pattern) must reproduce fresh-core results exactly — including
        // returning to an earlier policy after the arenas grew.
        let jobs = mixed_workload();
        let mut ctx = SimCtx::new();
        for policy in [
            PolicyKind::Uwfq,
            PolicyKind::Fifo,
            PolicyKind::Ujf,
            PolicyKind::Uwfq,
            PolicyKind::Cfq,
            PolicyKind::Fair,
        ] {
            let c = cfg(8, policy);
            let reused = ctx.simulate(&c, jobs.clone());
            let fresh = simulate(c, jobs.clone());
            let fa: Vec<_> = reused.completed.iter().map(|r| (r.job, r.finish)).collect();
            let fb: Vec<_> = fresh.completed.iter().map(|r| (r.job, r.finish)).collect();
            assert_eq!(fa, fb, "{}: reused core diverged", policy.name());
            assert_eq!(reused.makespan_s, fresh.makespan_s, "{}", policy.name());
            assert_eq!(reused.utilization, fresh.utilization, "{}", policy.name());
        }
    }

    #[test]
    fn idle_response_time_is_memoized_by_shape() {
        // A unique job shape (weird slot_time so no other test shares it):
        // first call misses, the same template under a *different user*
        // hits, and both return the identical value.
        let c = cfg(4, PolicyKind::Uwfq);
        let ja = JobSpec::three_phase(1, "memo-a", 0, 0.734_621, 48 << 20, 4, None);
        let jb = JobSpec::three_phase(9, "memo-b", 5_000_000, 0.734_621, 48 << 20, 4, None);
        let rt_a = idle_response_time(&c, &ja);
        let (hits0, _, _) = idle_cache_stats();
        let rt_b = idle_response_time(&c, &jb);
        let (hits1, _, _) = idle_cache_stats();
        assert_eq!(rt_a, rt_b, "same shape must give bit-identical idle RT");
        assert!(hits1 > hits0, "second lookup of the shape must hit the cache");
        // A different shape misses and yields a different time.
        let jc = JobSpec::three_phase(1, "memo-c", 0, 1.469_242, 48 << 20, 4, None);
        assert_ne!(idle_response_time(&c, &jc), rt_a);
        // SimCtx shares the same cache.
        let mut ctx = SimCtx::new();
        assert_eq!(ctx.idle_response_time(&c, &jb), rt_a);
        // Chain-DAG idle runs are policy-invariant — the premise that
        // lets the cache share entries across policy cells. Verify it
        // for real: an *uncached* simulation under every policy must
        // reproduce the shared value bit-for-bit.
        for policy in PolicyKind::ALL {
            let mut j0 = ja.clone();
            j0.arrival = 0;
            let direct = simulate(cfg(4, policy), vec![j0]).completed[0].response_time();
            assert_eq!(
                direct,
                rt_a,
                "{}: chain idle RT must be policy-invariant",
                policy.name()
            );
        }
        // And the cached lookup under another policy is a shared hit.
        let (hits2, _, _) = idle_cache_stats();
        assert_eq!(idle_response_time(&cfg(4, PolicyKind::Fair), &ja), rt_a);
        let (hits3, _, _) = idle_cache_stats();
        assert!(hits3 > hits2, "chain shapes must share across policies");
    }

    #[test]
    fn idle_memo_distinguishes_equal_slot_time_dag_shapes() {
        // Two jobs with identical per-stage slot-times (so equal total
        // slot-time) but different wiring: a strict chain vs a fork-join
        // diamond. The diamond overlaps its middle stages, so its idle
        // response time is strictly shorter — if the memo key ignored
        // shape they would alias to whichever baseline ran first.
        fn stage(parents: Vec<usize>, slot: f64) -> crate::core::job::StageSpec {
            use crate::core::job::{CostProfile, StagePhase, StageSpec};
            StageSpec {
                phase: StagePhase::Generic,
                is_leaf_input: parents.is_empty(),
                input_bytes: 48 << 20,
                slot_time: slot,
                cost: CostProfile::uniform(),
                max_parallelism: None,
                opcount: 4,
                parents,
                demand: crate::core::task::ResourceVec::UNIT,
            }
        }
        let mk = |name: &str, wiring: [Vec<usize>; 4]| JobSpec {
            user: 1,
            name: name.into(),
            arrival: 0,
            weight: 1.0,
            stages: wiring.into_iter().map(|p| stage(p, 0.816_237)).collect(),
        };
        let chain = mk("shape-chain", [vec![], vec![0], vec![1], vec![2]]);
        let diamond = mk("shape-diamond", [vec![], vec![0], vec![0], vec![1, 2]]);
        assert!(chain.validate().is_ok() && diamond.validate().is_ok());
        assert_eq!(chain.slot_time().to_bits(), diamond.slot_time().to_bits());
        assert_ne!(
            super::dag_shape_fingerprint(&chain),
            super::dag_shape_fingerprint(&diamond),
            "wiring must change the shape fingerprint"
        );
        let c = cfg(4, PolicyKind::Fifo);
        let (_, miss0, _) = idle_cache_stats();
        let rt_chain = idle_response_time(&c, &chain);
        let rt_diamond = idle_response_time(&c, &diamond);
        let (_, miss1, _) = idle_cache_stats();
        assert!(
            miss1 >= miss0 + 2,
            "equal-slot-time shapes must be distinct cache entries"
        );
        assert!(
            rt_diamond < rt_chain,
            "fork-join overlaps its middle stages: {rt_diamond} vs {rt_chain}"
        );
    }

    #[test]
    fn streamed_equals_materialized_exact_path() {
        // The streaming driver with a collecting sink must reproduce the
        // exact path bit-for-bit (they share one event loop; this guards
        // the adapter and drain plumbing around it). Two policies here
        // keep the debug run fast; tests/stream_differential.rs covers
        // all five on every paper scenario.
        let jobs = mixed_workload();
        for policy in [PolicyKind::Uwfq, PolicyKind::Ujf] {
            let c = cfg(8, policy);
            let a = simulate(c.clone(), jobs.clone());
            let b = simulate_stream(
                c,
                crate::workload::stream::VecStream::new(jobs.clone()),
            );
            let fa: Vec<_> = a.completed.iter().map(|r| (r.job, r.finish)).collect();
            let fb: Vec<_> = b.completed.iter().map(|r| (r.job, r.finish)).collect();
            assert_eq!(fa, fb, "{}", policy.name());
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        }
    }

    #[test]
    fn stream_summary_counts_events_and_backlog() {
        let jobs: Vec<JobSpec> = (0..12).map(|i| job(i % 3, i as f64 * 0.05, 0.5)).collect();
        let mut probe = cfg(4, PolicyKind::Uwfq);
        probe.log_tasks = true;
        let tasks = simulate(probe, jobs.clone()).task_log.len() as u64;
        let mut core = SchedCore::from_config(cfg(4, PolicyKind::Uwfq));
        let mut sink = CollectSink::default();
        let summary = simulate_stream_into(
            &mut core,
            crate::workload::stream::VecStream::new(jobs),
            &mut sink,
        );
        assert_eq!(summary.jobs_completed, 12);
        assert_eq!(sink.completed.len(), 12);
        assert_eq!(summary.task_events, tasks);
        assert!(summary.peak_in_flight_jobs >= 1);
        // The engine retained nothing: completions were drained as they
        // happened.
        assert!(core.completed.is_empty());
        assert!(core.is_idle());
    }

    #[test]
    fn faulty_runs_complete_and_repeat_byte_identically() {
        // All three fault classes armed at once: every arrival still
        // completes, and a fixed fault seed reproduces the run exactly —
        // schedule, counters and ledger.
        let mut c = cfg(4, PolicyKind::Uwfq);
        c.fault.task_fail_prob = 0.2;
        c.fault.retry_backoff_s = 0.05;
        c.fault.straggler_prob = 0.1;
        c.fault.straggler_mult = 6.0;
        c.fault.spec_mult = 2.0;
        c.fault.crash_mttf_s = 20.0;
        c.fault.crash_recover_s = 2.0;
        c.fault.seed = 11;
        let jobs: Vec<JobSpec> = (0..30).map(|i| job(i % 4, i as f64 * 0.2, 0.8)).collect();
        let a = simulate(c.clone(), jobs.clone());
        assert_eq!(a.completed.len(), 30, "every arrival completes despite faults");
        assert!(a.fault.failures > 0, "fail rate 0.2 must fire");
        assert_eq!(a.fault.retries, a.fault.failures);
        assert!(a.fault.wasted_us > 0);
        let b = simulate(c, jobs);
        let fa: Vec<_> = a.completed.iter().map(|r| (r.job, r.finish)).collect();
        let fb: Vec<_> = b.completed.iter().map(|r| (r.job, r.finish)).collect();
        assert_eq!(fa, fb, "fixed fault seed must repeat byte-identically");
        assert_eq!(a.fault, b.fault);
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
    }

    #[test]
    fn event_core_matrix_agrees_byte_for_byte() {
        // Every cell of the (backend × batching) matrix must reproduce
        // the heap per-event reference schedule exactly — for every
        // policy, on the tie-break-heavy fixture, fault-free and with
        // all fault classes armed. (tests/invariants.rs drives the same
        // differential over random registry specs.)
        let fingerprint = |r: &SimReport| {
            (
                r.completed.iter().map(|c| (c.job, c.finish)).collect::<Vec<_>>(),
                r.utilization.to_bits(),
                r.fault.clone(),
            )
        };
        let cells = [
            (EventBackend::Heap, true),
            (EventBackend::Wheel, false),
            (EventBackend::Wheel, true),
        ];
        for policy in PolicyKind::ALL {
            let mut c = cfg(8, policy);
            c.fault.seed = 7;
            for faulty in [false, true] {
                let jobs = if faulty {
                    c.fault.task_fail_prob = 0.15;
                    c.fault.retry_backoff_s = 0.05;
                    c.fault.straggler_prob = 0.1;
                    c.fault.straggler_mult = 6.0;
                    c.fault.spec_mult = 2.0;
                    c.fault.crash_mttf_s = 15.0;
                    c.fault.crash_recover_s = 1.0;
                    (0..40)
                        .map(|i| job(i % 5, i as f64 * 0.15, 0.8))
                        .collect::<Vec<_>>()
                } else {
                    mixed_workload()
                };
                let reference = simulate_opts(
                    c.clone(),
                    jobs.clone(),
                    SimOpts { backend: EventBackend::Heap, batch: false },
                );
                let want = fingerprint(&reference);
                for (backend, batch) in cells {
                    let got = simulate_opts(c.clone(), jobs.clone(), SimOpts { backend, batch });
                    assert_eq!(
                        fingerprint(&got),
                        want,
                        "{} faulty={faulty} {backend:?} batch={batch} diverged",
                        policy.name()
                    );
                }
            }
        }
    }

    #[test]
    fn aggressive_crashes_never_strand_work() {
        // MTTF comparable to task runtimes on a tiny cluster: cores cycle
        // through blacklist/recover constantly (including phases where
        // every core is down) and the run must still drain.
        let mut c = cfg(2, PolicyKind::Fifo);
        c.fault.crash_mttf_s = 2.0;
        c.fault.crash_recover_s = 0.5;
        let jobs: Vec<JobSpec> = (0..6).map(|i| job(i % 2, i as f64 * 0.3, 0.6)).collect();
        let r = simulate(c, jobs);
        assert_eq!(r.completed.len(), 6);
        assert!(r.fault.crashes > 0, "mttf ~ runtime must crash");
        assert!(r.fault.tasks_lost_to_crash > 0);
        // Crash-lost attempts are requeued without a failure charge.
        assert_eq!(r.fault.failures, 0);
        assert_eq!(r.fault.retries, 0);
    }

    #[test]
    fn idle_baseline_ignores_fault_config() {
        // The slowdown denominator is the job alone on a healthy cluster:
        // fault knobs must not leak into it (nor into its memo key).
        let c = cfg(4, PolicyKind::Uwfq);
        let j = JobSpec::three_phase(1, "idle-fault", 0, 0.913_371, 48 << 20, 4, None);
        let clean_rt = idle_response_time(&c, &j);
        let mut faulty = c.clone();
        faulty.fault.task_fail_prob = 0.9;
        faulty.fault.crash_mttf_s = 1.0;
        assert_eq!(idle_response_time(&faulty, &j), clean_rt);
        let mut ctx = SimCtx::new();
        assert_eq!(ctx.idle_response_time(&faulty, &j), clean_rt);
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let r = simulate(
            cfg(2, PolicyKind::Fifo),
            vec![job(1, 0.0, 1.0), job(2, 0.01, 1.0)],
        );
        let first = r.completed.iter().find(|c| c.user == 1).unwrap();
        let second = r.completed.iter().find(|c| c.user == 2).unwrap();
        assert!(first.finish <= second.finish);
    }
}
