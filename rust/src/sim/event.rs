//! Named simulation event shared by the calendar queue and the
//! binary-heap fallback (`UWFQ_EVENT_HEAP=1`).
//!
//! Historically the event loop pushed bare `Reverse<(TimeUs, u8, u64,
//! u64)>` tuples and the field semantics lived only in a module
//! comment. `Ev` names the fields and carries the ordering contract in
//! its `Ord` impl so both event backends share one definition of
//! "earlier".
//!
//! Ordering (ascending; queues wrap in `Reverse` for a min-queue):
//!
//! 1. `t` — event time in integer microseconds. Earlier fires first.
//! 2. `kind` — at equal times, lower kinds fire first:
//!    completions (0) before retry-ready (1) before speculation wakes
//!    (2) before recoveries (3) before crashes (4). In particular a
//!    task finishing at exactly the instant a core crashes completes
//!    cleanly — the crash only takes the next task placed there.
//! 3. `a`, `b` — kind-specific payload, compared last so simultaneous
//!    same-kind events resolve deterministically (e.g. same-time
//!    completions free cores in ascending core order).
//!
//! Payload conventions per kind:
//!
//! | kind | meaning            | `a`       | `b`            |
//! |------|--------------------|-----------|----------------|
//! | 0    | task completion    | core idx  | launch seq     |
//! | 1    | retry backoff done | stage id  | task idx       |
//! | 2    | speculation wake   | core idx  | launch seq     |
//! | 3    | core recovers      | core idx  | 0              |
//! | 4    | core crashes       | core idx  | 0              |

use crate::TimeUs;

/// Task completion (stale-checked against the launch seq).
pub const KIND_TASK: u8 = 0;
/// Failed task's retry backoff expired; requeue it.
pub const KIND_RETRY: u8 = 1;
/// Straggler clone decision point for a running task.
pub const KIND_SPEC: u8 = 2;
/// Crashed core rejoins the cluster.
pub const KIND_RECOVER: u8 = 3;
/// Core crash (loses its running attempt, blacklists the core).
pub const KIND_CRASH: u8 = 4;

/// One scheduled simulation event. `Copy` and 32 bytes so the calendar
/// buckets can hold them by value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Ev {
    /// Fire time (integer microseconds since simulation start).
    pub t: TimeUs,
    /// Event kind (`KIND_*`); the same-time tie-break.
    pub kind: u8,
    /// First payload word (see the module table).
    pub a: u64,
    /// Second payload word (see the module table).
    pub b: u64,
}

impl Ev {
    /// Completion of the task launched on `core` with launch-seq `seq`.
    pub fn task(t: TimeUs, core: u64, seq: u64) -> Self {
        Ev { t, kind: KIND_TASK, a: core, b: seq }
    }

    /// Retry of `task` in `stage` becomes runnable again.
    pub fn retry(t: TimeUs, stage: u64, task: u64) -> Self {
        Ev { t, kind: KIND_RETRY, a: stage, b: task }
    }

    /// Speculation check for the task on `core` with launch-seq `seq`.
    pub fn spec(t: TimeUs, core: u64, seq: u64) -> Self {
        Ev { t, kind: KIND_SPEC, a: core, b: seq }
    }

    /// `core` rejoins after a crash window.
    pub fn recover(t: TimeUs, core: u64) -> Self {
        Ev { t, kind: KIND_RECOVER, a: core, b: 0 }
    }

    /// `core` crashes.
    pub fn crash(t: TimeUs, core: u64) -> Self {
        Ev { t, kind: KIND_CRASH, a: core, b: 0 }
    }

    /// Work events (completion/retry/spec) count toward the loop's
    /// outstanding-work tally; environment events (recover/crash) do
    /// not — a pending crash alone must not keep the loop alive.
    pub fn is_work(&self) -> bool {
        self.kind <= KIND_SPEC
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_kind_then_payload() {
        let a = Ev::task(10, 3, 7);
        let b = Ev::crash(10, 0);
        let c = Ev::task(11, 0, 0);
        let d = Ev::task(10, 3, 8);
        assert!(a < b, "lower kind wins at equal time");
        assert!(b < c, "earlier time wins over kind");
        assert!(a < d, "payload breaks same-kind ties");
    }

    #[test]
    fn matches_legacy_tuple_order() {
        // The `Ord` derive must reproduce the historical
        // `(t, kind, a, b)` tuple ordering bit-for-bit.
        let evs = [
            Ev::task(5, 2, 9),
            Ev::retry(5, 2, 9),
            Ev::spec(5, 1, 0),
            Ev::recover(4, 6),
            Ev::crash(5, 2),
            Ev::task(5, 2, 3),
        ];
        let mut by_ev = evs.to_vec();
        by_ev.sort();
        let mut by_tuple = evs.to_vec();
        by_tuple.sort_by_key(|e| (e.t, e.kind, e.a, e.b));
        assert_eq!(by_ev, by_tuple);
    }

    #[test]
    fn work_classification() {
        assert!(Ev::task(0, 0, 0).is_work());
        assert!(Ev::retry(0, 0, 0).is_work());
        assert!(Ev::spec(0, 0, 0).is_work());
        assert!(!Ev::recover(0, 0).is_work());
        assert!(!Ev::crash(0, 0).is_work());
    }
}
