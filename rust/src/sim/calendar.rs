//! Calendar-queue (timing-wheel) event structure for the simulation
//! loop, plus the [`EventQ`] facade that selects between it and the
//! reference binary heap (`UWFQ_EVENT_HEAP=1`).
//!
//! # Layout
//!
//! Time is split into fixed buckets of `2^SHIFT` µs (1024 µs). The
//! wheel is a ring of `NBUCKETS` (4096) unsorted `Vec<Ev>` buckets —
//! a ~4.19 s horizon. An event at time `t` lands in ring slot
//! `(t >> SHIFT) % NBUCKETS` if its bucket number is within the
//! horizon of the cursor; otherwise it goes to a spill `BinaryHeap`
//! (the "overflow"). Insert and pop are O(1) amortized: pops advance a
//! cursor monotonically, so each ring slot is visited once per horizon
//! rotation, and the per-bucket linear min-scan touches only the
//! handful of events sharing a 1 ms window.
//!
//! # Why no overflow migration
//!
//! The simulation only schedules events at `t >= now` (`now` is the
//! time of the last popped event or arrival), so every insert has
//! bucket number `>= cursor`. Inserts are ring-placed only when
//! `bucket_no - cursor < NBUCKETS`, and pops always remove the global
//! minimum, so live ring events always have bucket numbers in
//! `[cursor, cursor + NBUCKETS)` — each ring slot holds exactly one
//! bucket number and slots never alias. Overflow events are simply
//! compared against the ring minimum at pop time (overflow traffic is
//! rare: far-future crash clocks and long retry backoffs), which keeps
//! the structure exact without a migration sweep.
//!
//! # Ordering guarantee
//!
//! Pop order is the full [`Ev`] ordering — `(t, kind, a, b)`
//! ascending — bit-for-bit identical to the reference binary heap.
//! The ring finds the lowest-numbered non-empty bucket (strictly
//! earlier buckets ⇒ strictly smaller times), takes that bucket's
//! minimum under the full `Ev` order, and compares it against the
//! overflow minimum under the same order.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::event::{Ev, KIND_RECOVER};
use crate::TimeUs;

/// log2 of the bucket width in µs (1024 µs ≈ 1 ms per bucket).
const SHIFT: u32 = 10;
/// Ring size in buckets (~4.19 s horizon). Power of two for cheap
/// modulo.
const NBUCKETS: usize = 4096;

/// Where the cached minimum lives, so `pop` after `peek` is O(1).
#[derive(Clone, Copy)]
enum Loc {
    /// `(ring index, position within the bucket Vec)`.
    Ring(usize, usize),
    /// Minimum is the overflow heap's peek.
    Overflow,
}

/// Timing-wheel queue for the high-rate work events (completions,
/// retries, speculation wakes).
pub struct CalendarQueue {
    buckets: Vec<Vec<Ev>>,
    /// Bucket number (`t >> SHIFT`) of the most recently popped event.
    /// Monotonically non-decreasing; the ring scan starts here.
    cursor: u64,
    /// Live events in the ring (not counting overflow).
    ring_len: usize,
    /// Events beyond the ring horizon at insert time.
    overflow: BinaryHeap<Reverse<Ev>>,
    /// Cached `find_min` result; invalidated by pops, updated by
    /// pushes that beat it.
    cached: Option<(Ev, Loc)>,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl CalendarQueue {
    pub fn new() -> Self {
        CalendarQueue {
            buckets: (0..NBUCKETS).map(|_| Vec::new()).collect(),
            cursor: 0,
            ring_len: 0,
            overflow: BinaryHeap::new(),
            cached: None,
        }
    }

    pub fn len(&self) -> usize {
        self.ring_len + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&mut self, ev: Ev) {
        let bucket_no = ev.t >> SHIFT;
        debug_assert!(
            bucket_no >= self.cursor,
            "event scheduled before the queue cursor"
        );
        if bucket_no - self.cursor < NBUCKETS as u64 {
            let idx = (bucket_no as usize) & (NBUCKETS - 1);
            let pos = self.buckets[idx].len();
            self.buckets[idx].push(ev);
            self.ring_len += 1;
            if let Some((m, _)) = self.cached {
                if ev < m {
                    self.cached = Some((ev, Loc::Ring(idx, pos)));
                }
            }
        } else {
            self.overflow.push(Reverse(ev));
            if let Some((m, _)) = self.cached {
                if ev < m {
                    self.cached = Some((ev, Loc::Overflow));
                }
            }
        }
    }

    /// Locate the global minimum without removing it.
    fn find_min(&mut self) -> Option<(Ev, Loc)> {
        if let Some(hit) = self.cached {
            return Some(hit);
        }
        let ring_min = if self.ring_len > 0 {
            // First non-empty bucket at or after the cursor; strictly
            // earlier buckets hold strictly earlier times, so its
            // min is the ring min.
            let mut b = self.cursor;
            loop {
                let idx = (b as usize) & (NBUCKETS - 1);
                if !self.buckets[idx].is_empty() {
                    let mut best = 0;
                    for (i, e) in self.buckets[idx].iter().enumerate() {
                        if *e < self.buckets[idx][best] {
                            best = i;
                        }
                    }
                    break Some((self.buckets[idx][best], Loc::Ring(idx, best)));
                }
                b += 1;
                debug_assert!(b - self.cursor <= NBUCKETS as u64);
            }
        } else {
            None
        };
        let hit = match (ring_min, self.overflow.peek()) {
            (Some((r, loc)), Some(Reverse(o))) => {
                if r <= *o {
                    Some((r, loc))
                } else {
                    Some((*o, Loc::Overflow))
                }
            }
            (Some(hit), None) => Some(hit),
            (None, Some(Reverse(o))) => Some((*o, Loc::Overflow)),
            (None, None) => None,
        };
        self.cached = hit;
        hit
    }

    pub fn peek(&mut self) -> Option<Ev> {
        self.find_min().map(|(ev, _)| ev)
    }

    pub fn pop(&mut self) -> Option<Ev> {
        let (ev, loc) = self.find_min()?;
        match loc {
            Loc::Ring(idx, pos) => {
                self.buckets[idx].swap_remove(pos);
                self.ring_len -= 1;
            }
            Loc::Overflow => {
                self.overflow.pop();
            }
        }
        self.cursor = ev.t >> SHIFT;
        self.cached = None;
        Some(ev)
    }
}

/// Which inner structure backs the event queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventBackend {
    /// Calendar queue + small side heap for crash/recover events.
    Wheel,
    /// Single `BinaryHeap` over all kinds — the executable spec,
    /// selected by `UWFQ_EVENT_HEAP=1`.
    Heap,
}

/// Event queue facade: one `push`/`peek_t`/`pop` surface over both
/// backends, popping in identical order either way.
pub enum EventQ {
    Heap(BinaryHeap<Reverse<Ev>>),
    Wheel {
        cal: CalendarQueue,
        /// Low-rate environment events (crash/recover) stay on a tiny
        /// side heap so far-future crash clocks never bloat overflow.
        env: BinaryHeap<Reverse<Ev>>,
    },
}

impl EventQ {
    pub fn new(backend: EventBackend) -> Self {
        match backend {
            EventBackend::Heap => EventQ::Heap(BinaryHeap::new()),
            EventBackend::Wheel => EventQ::Wheel {
                cal: CalendarQueue::new(),
                env: BinaryHeap::new(),
            },
        }
    }

    pub fn push(&mut self, ev: Ev) {
        match self {
            EventQ::Heap(h) => h.push(Reverse(ev)),
            EventQ::Wheel { cal, env } => {
                if ev.kind >= KIND_RECOVER {
                    env.push(Reverse(ev));
                } else {
                    cal.push(ev);
                }
            }
        }
    }

    /// Time of the next event, if any (for the event-vs-arrival race).
    pub fn peek_t(&mut self) -> Option<TimeUs> {
        match self {
            EventQ::Heap(h) => h.peek().map(|Reverse(e)| e.t),
            EventQ::Wheel { cal, env } => {
                let c = cal.peek().map(|e| e.t);
                let e = env.peek().map(|Reverse(e)| e.t);
                match (c, e) {
                    (Some(c), Some(e)) => Some(c.min(e)),
                    (c, e) => c.or(e),
                }
            }
        }
    }

    pub fn pop(&mut self) -> Option<Ev> {
        match self {
            EventQ::Heap(h) => h.pop().map(|Reverse(e)| e),
            EventQ::Wheel { cal, env } => {
                // Work kinds (0–2) sort before env kinds (3–4) at
                // equal times, so `<=` picks the true global min.
                match (cal.peek(), env.peek()) {
                    (Some(c), Some(Reverse(e))) => {
                        if c <= *e {
                            cal.pop()
                        } else {
                            env.pop().map(|Reverse(e)| e)
                        }
                    }
                    (Some(_), None) => cal.pop(),
                    (None, Some(_)) => env.pop().map(|Reverse(e)| e),
                    (None, None) => None,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pops_in_full_ev_order() {
        let mut q = CalendarQueue::new();
        let evs = [
            Ev::task(2048, 1, 5),
            Ev::task(2048, 1, 4),
            Ev::retry(2048, 9, 0),
            Ev::task(100, 0, 1),
            Ev::spec(100, 0, 1),
        ];
        for e in evs {
            q.push(e);
        }
        let mut want = evs.to_vec();
        want.sort();
        let got: Vec<Ev> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn overflow_round_trips_far_future_events() {
        let mut q = CalendarQueue::new();
        let horizon_us = (NBUCKETS as u64) << SHIFT;
        let far = Ev::retry(horizon_us * 3, 7, 0);
        let near = Ev::task(512, 0, 1);
        q.push(far);
        q.push(near);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(near));
        // Cursor has advanced; the overflow event is now the min even
        // though it never migrates into the ring.
        assert_eq!(q.pop(), Some(far));
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_push_pop_matches_heap_reference() {
        let mut rng = Rng::new(0xCA1);
        let mut wheel = CalendarQueue::new();
        let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
        let mut now: TimeUs = 0;
        for i in 0..4000u64 {
            // Pushes at or after `now`, mixing near and far-horizon
            // deltas so both ring and overflow paths churn.
            let delta = match rng.below(4) {
                0 => rng.below(512),
                1 => rng.below(1 << SHIFT),
                2 => rng.below((NBUCKETS as u64) << SHIFT),
                _ => rng.below(4 * (NBUCKETS as u64) << SHIFT),
            };
            let ev = match rng.below(3) {
                0 => Ev::task(now + delta, rng.below(64), i),
                1 => Ev::retry(now + delta, rng.below(1000), rng.below(8)),
                _ => Ev::spec(now + delta, rng.below(64), i),
            };
            wheel.push(ev);
            heap.push(Reverse(ev));
            if rng.below(3) > 0 {
                let a = wheel.pop();
                let b = heap.pop().map(|Reverse(e)| e);
                assert_eq!(a, b);
                if let Some(e) = a {
                    now = e.t;
                }
            }
        }
        loop {
            let a = wheel.pop();
            let b = heap.pop().map(|Reverse(e)| e);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn facade_routes_env_kinds_to_side_heap_and_merges() {
        let mut q = EventQ::new(EventBackend::Wheel);
        q.push(Ev::crash(50, 3));
        q.push(Ev::task(50, 3, 1));
        q.push(Ev::recover(40, 2));
        assert_eq!(q.peek_t(), Some(40));
        assert_eq!(q.pop(), Some(Ev::recover(40, 2)));
        // Equal times: work kind 0 beats env kind 4.
        assert_eq!(q.pop(), Some(Ev::task(50, 3, 1)));
        assert_eq!(q.pop(), Some(Ev::crash(50, 3)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn heap_backend_is_a_plain_min_heap() {
        let mut q = EventQ::new(EventBackend::Heap);
        q.push(Ev::task(9, 0, 0));
        q.push(Ev::task(3, 5, 5));
        assert_eq!(q.peek_t(), Some(3));
        assert_eq!(q.pop(), Some(Ev::task(3, 5, 5)));
        assert_eq!(q.pop(), Some(Ev::task(9, 0, 0)));
    }
}
