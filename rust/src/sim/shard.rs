//! Sharded million-user engine — federated virtual time (the intra-run
//! parallelism the sweep engine cannot provide).
//!
//! One simulated cluster is split into `S` shards: users are partitioned
//! hash-stably ([`shard_of`]) and each shard runs its **own**
//! [`SchedCore`] + event queue over a dedicated core subset
//! ([`shard_cores`]: `cores/S`, deterministic remainder to the lowest
//! shard indices), advancing in parallel under `std::thread::scope`.
//! Cross-shard fairness is kept coupled by a periodic global
//! virtual-time sync barrier: every `shard_epoch_s` of *simulated* time,
//! all shards pause ([`StreamSim::run_until`]), publish their
//! `TwoLevelVtime` state, and re-couple to the population —
//!
//! * level: `v_global := v_ref = Σ n_s·v_s / Σ n_s` (user-count-weighted
//!   population mean), and
//! * rate: `r_total := R_cluster · n_s / Σ n_s` (each shard progresses
//!   at the cluster rate scaled by its live-user share).
//!
//! Level-setting every epoch is what makes the drift bound *provable and
//! non-accumulating*: each epoch restarts from the common `v_ref`, and
//! within one epoch a shard advances `v_global` by at most
//! `r_total · epoch ≤ R_cluster · epoch`, so the pre-sync spread — the
//! per-user normalized-service gap between any two shards — never
//! exceeds **one sync epoch of service at the cluster rate**
//! (`SyncStats::bound_rsec = cores × shard_epoch_s`; the engine reports
//! the observed `max_drift_rsec` and `tests/shard.rs` enforces the
//! bound on randomized registry specs).
//!
//! `S = 1` skips barriers and recoupling entirely and is byte-identical
//! to the unsharded engine by construction — it is the same
//! [`StreamSim`] driver, run uninterrupted. `S > 1` is deterministic
//! (repeat-identical) but *not* equal to the unsharded schedule: shards
//! serve disjoint user sets on disjoint cores, arrival sequence numbers
//! (and therefore fault plans) are shard-local, and the virtual systems
//! only re-couple at epoch granularity.
//!
//! # Cross-shard core lending (`shard_rebalance`)
//!
//! The static `cores/S` split collapses on skewed populations: a few
//! heavy users pin one shard at 100% while its siblings idle. With
//! `cfg.shard_rebalance` on, every shard additionally publishes its
//! backlog into the barrier snapshot — queued slot-seconds
//! ([`SchedCore::queued_slot_s`]), pending tasks, active users and free
//! usable cores — and every thread runs the **same pure function**
//! [`rebalance_cores`] over the same published vector, so all threads
//! derive the identical next allocation with no leader and no extra
//! synchronization. Moves are bounded by a per-shard floor
//! (`rebalance_min_cores`), a per-epoch migration cap (`rebalance_cap`),
//! a hysteresis factor ([`REBALANCE_HYSTERESIS`]), and each donor's
//! published free-core count — which is what lets
//! [`SchedCore::set_cores`] retire only-when-free slots: the shard does
//! not advance between publishing and applying, so a published-free core
//! is still free. UWFQ's `r_total` re-scales to the lent allocation
//! ([`crate::sched::vtime::TwoLevelVtime::recouple_to_rate`]); since the
//! rebalancer conserves the total (`Σ r_shard = R_cluster`), a shard
//! still advances by at most `R_cluster · epoch` resource-seconds per
//! epoch and the `cores × shard_epoch_s` drift bound is unchanged.
//! `shard_rebalance = false` (the default) takes none of these paths and
//! stays byte-identical to the static engine.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

use crate::config::Config;
use crate::core::SchedCore;
use crate::fault::FaultStats;
use crate::sim::{CompletionSink, SimOpts, StreamSim, StreamSummary};
use crate::workload::stream::{JobStream, ShardStream};
use crate::TimeUs;

pub use crate::workload::stream::shard_of;

/// Federated core allocation: `cores/S` per shard, the `cores % S`
/// remainder going to the lowest shard indices — deterministic, and the
/// subsets partition the cluster exactly. Panics unless
/// `1 ≤ shards ≤ cores` (every shard needs at least one core).
pub fn shard_cores(cores: u32, shards: u32) -> Vec<u32> {
    assert!(shards >= 1, "shards must be >= 1");
    assert!(
        shards <= cores,
        "shards ({shards}) exceed cores ({cores}): every shard needs a core"
    );
    let base = cores / shards;
    let extra = cores % shards;
    (0..shards).map(|s| base + u32::from(s < extra)).collect()
}

/// Sync-barrier telemetry of one sharded run.
#[derive(Clone, Debug, Default)]
pub struct SyncStats {
    /// Barrier epochs executed (0 when `S = 1`).
    pub epochs: u64,
    /// Max observed pre-sync `|v_shard − v_ref|` over all epochs, in
    /// resource-seconds of global virtual time.
    pub max_drift_rsec: f64,
    /// The provable ceiling: `cores × shard_epoch_s` — one epoch of
    /// service at the cluster rate.
    pub bound_rsec: f64,
    /// Total cores migrated by lending over the run (0 when
    /// `shard_rebalance` is off or `S = 1`).
    pub lend_events: u64,
    /// Max over epochs of (hottest shard backlog) / (mean shard backlog)
    /// among undrained shards — 1.0 is perfectly balanced; only recorded
    /// when lending is on.
    pub max_backlog_imbalance: f64,
}

/// Per-shard load snapshot published at the sync barrier — the input
/// vector of [`rebalance_cores`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardLoad {
    /// Queued (unlaunched) work in slot-seconds.
    pub backlog_rsec: f64,
    /// Queued (unlaunched) task count.
    pub pending: u64,
    /// Distinct users with at least one active stage.
    pub active_users: u64,
    /// Free usable cores — the shard's maximum donation this epoch.
    pub free_cores: u32,
    /// Stream drained and engine idle.
    pub done: bool,
}

/// Lending hysteresis: a core moves only when the receiver's per-core
/// backlog exceeds the donor's by this factor, so near-balanced loads
/// don't thrash cores back and forth across epochs.
pub const REBALANCE_HYSTERESIS: f64 = 1.5;

/// The pure-function core rebalancer: given the current allocation and
/// the synchronized load snapshot, return the next epoch's allocation.
///
/// Determinism is the whole design: every shard thread calls this with
/// byte-identical inputs (the published snapshot vector) and must derive
/// the identical output, so the function depends on nothing else — no
/// clock, no RNG, no thread identity. Greedy, one core at a time, at
/// most `cap` moves per epoch: the receiver is the undrained shard with
/// the heaviest per-core backlog (ties → lowest index), the donor the
/// shard with the lightest per-core backlog that still has published
/// free cores and sits above the `min_cores` floor (ties → lowest
/// index). A move happens only past [`REBALANCE_HYSTERESIS`], a shard
/// never both donates and receives in one epoch, and the total is
/// conserved by construction (`Σ next = Σ alloc`).
pub fn rebalance_cores(alloc: &[u32], loads: &[ShardLoad], min_cores: u32, cap: u32) -> Vec<u32> {
    let n = alloc.len();
    let mut next = alloc.to_vec();
    if n < 2 {
        return next;
    }
    // A shard can donate at most what it published free (the engine can
    // only retire idle cores) and never drops below the floor.
    let mut donate_left: Vec<u32> = (0..n)
        .map(|i| loads[i].free_cores.min(alloc[i].saturating_sub(min_cores)))
        .collect();
    let mut received = vec![false; n];
    let mut donated = vec![false; n];
    let per_core = |i: usize, next: &[u32]| loads[i].backlog_rsec / next[i].max(1) as f64;
    for _ in 0..cap {
        let recv = (0..n)
            .filter(|&i| !loads[i].done && !donated[i] && loads[i].backlog_rsec > 0.0)
            .max_by(|&a, &b| {
                per_core(a, &next)
                    .partial_cmp(&per_core(b, &next))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    // Strict order on ties: the lower index wins the max.
                    .then(b.cmp(&a))
            });
        let Some(recv) = recv else {
            break;
        };
        let donor = (0..n)
            .filter(|&i| {
                i != recv && !received[i] && donate_left[i] > 0 && next[i] > min_cores
            })
            .min_by(|&a, &b| {
                per_core(a, &next)
                    .partial_cmp(&per_core(b, &next))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
        let Some(donor) = donor else {
            break;
        };
        if per_core(recv, &next) <= REBALANCE_HYSTERESIS * per_core(donor, &next) {
            break; // close enough — hysteresis holds the allocation
        }
        next[recv] += 1;
        next[donor] -= 1;
        donate_left[donor] -= 1;
        received[recv] = true;
        donated[donor] = true;
    }
    next
}

/// One shard's outcome within a [`ShardRun`].
#[derive(Clone, Debug)]
pub struct ShardSummary {
    pub shard: u32,
    /// Cores dedicated to this shard ([`shard_cores`]).
    pub cores: u32,
    pub summary: StreamSummary,
}

/// Outcome of [`run_sharded`]: per-shard summaries and sinks plus the
/// exact cluster-level merge.
pub struct ShardRun<K> {
    /// Merged summary. Counters sum exactly; `peak_in_flight_jobs` is
    /// the **sum** of per-shard peaks (an upper bound on the true
    /// cluster peak — see `peak_in_flight_max` for the max-of-peaks);
    /// makespan is the max; utilization is recomputed exactly from the
    /// summed busy-core ledger over `cores × max-makespan`; fault
    /// ledgers merge with per-shard core-index offsets.
    pub summary: StreamSummary,
    /// Max of the per-shard peak-in-flight counters (each an exact peak
    /// of its shard; the cross-shard sum can overcount coincidence).
    pub peak_in_flight_max: usize,
    pub per_shard: Vec<ShardSummary>,
    /// Per-shard completion sinks, in shard order (users are disjoint
    /// across shards, so per-user reductions merge without collisions).
    pub sinks: Vec<K>,
    pub sync: SyncStats,
}

/// Run `cfg` sharded `cfg.shards` ways. `make_stream(s)` must
/// regenerate the **full** workload timeline (each shard filters it down
/// to its own users with O(1) extra state — per-user arrival order is
/// preserved verbatim); `make_sink(s)` builds each shard's completion
/// sink. Shards run in parallel scoped threads and join in shard order,
/// so the merge is deterministic regardless of thread scheduling.
///
/// Every shard publishes into lock-free slots and meets at a two-phase
/// [`Barrier`] per epoch (publish → read/recouple → release); a shard
/// that drains early keeps joining barriers with zero active users until
/// all shards finish, so the population reference never blocks.
pub fn run_sharded<S, K, FS, FK>(
    cfg: &Config,
    opts: SimOpts,
    make_stream: FS,
    make_sink: FK,
) -> ShardRun<K>
where
    S: JobStream,
    K: CompletionSink + Send,
    FS: Fn(u32) -> S + Sync,
    FK: Fn(u32) -> K + Sync,
{
    let shards = cfg.shards.max(1);
    let cores_by_shard = shard_cores(cfg.cores, shards);
    let epoch_us: TimeUs = crate::s_to_us(cfg.shard_epoch_s.max(1e-6));
    let cluster_cores = cfg.cores as f64;
    // Lending gate — every new code path below hides behind this, which
    // is what keeps `shard_rebalance = false` byte-identical to the
    // static engine. The floor is validated here, up front, instead of
    // letting an unsatisfiable allocation starve shards at epoch one.
    let lend = cfg.shard_rebalance && shards > 1;
    if cfg.shard_rebalance {
        assert!(
            cfg.rebalance_min_cores.saturating_mul(shards) <= cfg.cores,
            "rebalance_min_cores ({}) x shards ({}) exceeds cores ({}): \
             the per-shard floor is unsatisfiable",
            cfg.rebalance_min_cores,
            shards,
            cfg.cores
        );
    }

    // Published per-shard state: (active users, v_global bits, done),
    // plus the backlog snapshot when lending is on. Written before
    // barrier A, read between A and B — the barrier pair is the
    // synchronization; the atomics only make the slots shareable.
    let n_act: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
    let v_bits: Vec<AtomicU64> = (0..shards).map(|_| AtomicU64::new(0)).collect();
    let done_fl: Vec<AtomicBool> = (0..shards).map(|_| AtomicBool::new(false)).collect();
    let backlog_bits: Vec<AtomicU64> = (0..shards).map(|_| AtomicU64::new(0)).collect();
    let pend_ct: Vec<AtomicU64> = (0..shards).map(|_| AtomicU64::new(0)).collect();
    let user_ct: Vec<AtomicU64> = (0..shards).map(|_| AtomicU64::new(0)).collect();
    let free_ct: Vec<AtomicU64> = (0..shards).map(|_| AtomicU64::new(0)).collect();
    let barrier = Barrier::new(shards as usize);
    let sync = Mutex::new(SyncStats {
        epochs: 0,
        max_drift_rsec: 0.0,
        bound_rsec: cluster_cores * crate::us_to_s(epoch_us),
        lend_events: 0,
        max_backlog_imbalance: 0.0,
    });

    let mut results: Vec<(StreamSummary, K)> = Vec::with_capacity(shards as usize);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(shards as usize);
        for s in 0..shards {
            let mut shard_cfg = cfg.clone();
            shard_cfg.cores = cores_by_shard[s as usize];
            let (make_stream, make_sink) = (&make_stream, &make_sink);
            let (n_act, v_bits, done_fl) = (&n_act, &v_bits, &done_fl);
            let (backlog_bits, pend_ct, user_ct, free_ct) =
                (&backlog_bits, &pend_ct, &user_ct, &free_ct);
            let (barrier, sync) = (&barrier, &sync);
            let cores_by_shard = &cores_by_shard;
            handles.push(scope.spawn(move || {
                let mut core = SchedCore::from_config(shard_cfg);
                let mut sink = make_sink(s);
                let stream = ShardStream::new(make_stream(s), s, shards);
                let mut sim = StreamSim::new(&mut core, stream, &mut sink, opts);
                let summary = if shards == 1 {
                    // Unsharded fast path: no barriers, no recoupling —
                    // byte-identical to `simulate_stream_into_opts` by
                    // construction (same driver, one uninterrupted run).
                    let done = sim.run_until(TimeUs::MAX);
                    debug_assert!(done, "run_until(MAX) cannot pause");
                    sim.finish()
                } else {
                    let si = s as usize;
                    // Thread-local view of the lent allocation: every
                    // thread derives the identical vector each epoch from
                    // the same published snapshot, so no thread ever
                    // needs another's copy.
                    let mut alloc: Vec<u32> = cores_by_shard.clone();
                    let mut done = false;
                    let mut epoch: u64 = 1;
                    loop {
                        let t_bar = epoch.saturating_mul(epoch_us);
                        if !done {
                            done = sim.run_until(t_bar);
                        }
                        let (n, v) = if done {
                            // Drained shards stop contributing to the
                            // population reference but keep joining
                            // barriers until everyone is done.
                            (0usize, 0.0f64)
                        } else {
                            match sim.core_mut().policy.vtime_mut() {
                                Some(vt) => vt.sync_snapshot(crate::us_to_s(t_bar)),
                                None => (0, 0.0), // no virtual time: decoupled
                            }
                        };
                        n_act[si].store(n, Ordering::Relaxed);
                        v_bits[si].store(v.to_bits(), Ordering::Relaxed);
                        done_fl[si].store(done, Ordering::Relaxed);
                        if lend {
                            let c = sim.core_mut();
                            backlog_bits[si].store(c.queued_slot_s().to_bits(), Ordering::Relaxed);
                            pend_ct[si].store(c.pending_task_count() as u64, Ordering::Relaxed);
                            user_ct[si].store(c.active_user_count() as u64, Ordering::Relaxed);
                            free_ct[si].store(c.free_usable_cores() as u64, Ordering::Relaxed);
                        }
                        barrier.wait(); // A: everyone published
                        if done_fl.iter().all(|f| f.load(Ordering::Relaxed)) {
                            // Flags were all written before barrier A, so
                            // every shard takes this exit together.
                            break sim.finish();
                        }
                        // Core lending: all threads compute the identical
                        // next allocation from the published snapshot; each
                        // applies only its own slot. Donations are capped by
                        // published free cores, and the shard has not
                        // advanced since publishing, so retiring never hits
                        // a busy core.
                        let mut lent_rate = 0.0f64;
                        if lend {
                            let loads: Vec<ShardLoad> = (0..shards as usize)
                                .map(|i| ShardLoad {
                                    backlog_rsec: f64::from_bits(
                                        backlog_bits[i].load(Ordering::Relaxed),
                                    ),
                                    pending: pend_ct[i].load(Ordering::Relaxed),
                                    active_users: user_ct[i].load(Ordering::Relaxed),
                                    free_cores: free_ct[i].load(Ordering::Relaxed) as u32,
                                    done: done_fl[i].load(Ordering::Relaxed),
                                })
                                .collect();
                            let next = rebalance_cores(
                                &alloc,
                                &loads,
                                cfg.rebalance_min_cores,
                                cfg.rebalance_cap,
                            );
                            if next[si] != alloc[si] {
                                let got = sim.core_mut().set_cores(next[si]);
                                debug_assert_eq!(got, next[si], "lending shrink hit a busy core");
                            }
                            lent_rate = next[si] as f64;
                            if s == 0 {
                                let moved: u64 = next
                                    .iter()
                                    .zip(alloc.iter())
                                    .map(|(&a, &b)| u64::from(a.saturating_sub(b)))
                                    .sum();
                                let (mut bmax, mut bsum, mut live) = (0.0f64, 0.0f64, 0usize);
                                for l in loads.iter().filter(|l| !l.done) {
                                    bmax = bmax.max(l.backlog_rsec);
                                    bsum += l.backlog_rsec;
                                    live += 1;
                                }
                                let mut st = sync.lock().unwrap();
                                st.lend_events += moved;
                                if live > 0 && bsum > 0.0 {
                                    let imb = bmax / (bsum / live as f64);
                                    st.max_backlog_imbalance = st.max_backlog_imbalance.max(imb);
                                }
                            }
                            alloc = next;
                        }
                        let mut n_total = 0usize;
                        let mut acc = 0.0f64;
                        for (na, vb) in n_act.iter().zip(v_bits.iter()) {
                            let ni = na.load(Ordering::Relaxed);
                            n_total += ni;
                            acc += ni as f64 * f64::from_bits(vb.load(Ordering::Relaxed));
                        }
                        if n_total > 0 {
                            // Each shard computes the identical v_ref from
                            // the same published bits — no leader needed.
                            let v_ref = acc / n_total as f64;
                            if !done {
                                if let Some(vt) = sim.core_mut().policy.vtime_mut() {
                                    if lend {
                                        // The shard's capacity is its lent
                                        // allocation, not the population
                                        // share; Σ r = R_cluster either way.
                                        vt.recouple_to_rate(v_ref, lent_rate);
                                    } else {
                                        vt.recouple(v_ref, cluster_cores, n, n_total);
                                    }
                                }
                            }
                            if s == 0 {
                                let mut drift = 0.0f64;
                                for (na, vb) in n_act.iter().zip(v_bits.iter()) {
                                    if na.load(Ordering::Relaxed) > 0 {
                                        let vi = f64::from_bits(vb.load(Ordering::Relaxed));
                                        drift = drift.max((vi - v_ref).abs());
                                    }
                                }
                                let mut st = sync.lock().unwrap();
                                st.max_drift_rsec = st.max_drift_rsec.max(drift);
                            }
                        }
                        if s == 0 {
                            sync.lock().unwrap().epochs += 1;
                        }
                        barrier.wait(); // B: recoupling visible, epoch advances
                        epoch += 1;
                    }
                };
                (summary, sink)
            }));
        }
        for h in handles {
            results.push(h.join().expect("shard thread panicked"));
        }
    });

    // Deterministic shard-ordered merge. At S=1 every reduction is the
    // identity (sum/max of one element; utilization re-derives from the
    // same operands in the same order), so the merged summary is
    // byte-identical to the unsharded one.
    let mut per_shard = Vec::with_capacity(results.len());
    let mut sinks = Vec::with_capacity(results.len());
    let mut merged = StreamSummary {
        label: String::new(),
        jobs_completed: 0,
        task_events: 0,
        peak_in_flight_jobs: 0,
        makespan_s: 0.0,
        utilization: 0.0,
        busy_core_us: 0,
        fault: FaultStats::default(),
    };
    let mut peak_max = 0usize;
    let mut core_offset = 0usize;
    for (s, (summary, sink)) in results.into_iter().enumerate() {
        if s == 0 {
            merged.label = summary.label.clone();
        }
        merged.jobs_completed += summary.jobs_completed;
        merged.task_events += summary.task_events;
        merged.peak_in_flight_jobs += summary.peak_in_flight_jobs;
        peak_max = peak_max.max(summary.peak_in_flight_jobs);
        merged.makespan_s = merged.makespan_s.max(summary.makespan_s);
        merged.busy_core_us += summary.busy_core_us;
        merged.fault.merge(&summary.fault, core_offset);
        core_offset += cores_by_shard[s] as usize;
        per_shard.push(ShardSummary {
            shard: s as u32,
            cores: cores_by_shard[s],
            summary,
        });
        sinks.push(sink);
    }
    merged.utilization = if merged.makespan_s > 0.0 {
        merged.busy_core_us as f64 / 1e6 / (cluster_cores * merged.makespan_s)
    } else {
        0.0
    };

    ShardRun {
        summary: merged,
        peak_in_flight_max: peak_max,
        per_shard,
        sinks,
        sync: sync.into_inner().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::PolicyKind;
    use crate::sim::CollectSink;
    use crate::workload::stream::{scale_stream, ScaleParams};

    fn base_cfg(policy: PolicyKind) -> Config {
        Config {
            cores: 8,
            task_overhead: 0.0,
            policy,
            ..Config::default()
        }
    }

    fn params() -> ScaleParams {
        ScaleParams {
            users: 40,
            jobs: 600,
            cores: 8,
            target_utilization: 0.8,
            seed: 17,
        }
    }

    #[test]
    fn shard_cores_partitions_exactly() {
        assert_eq!(shard_cores(8, 1), vec![8]);
        assert_eq!(shard_cores(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(shard_cores(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(shard_cores(7, 3), vec![3, 2, 2]);
        for (cores, shards) in [(64u32, 5u32), (13, 13), (9, 2)] {
            let v = shard_cores(cores, shards);
            assert_eq!(v.iter().sum::<u32>(), cores);
            assert!(v.iter().all(|&c| c >= 1));
            // Deterministic remainder: earlier shards never smaller.
            assert!(v.windows(2).all(|w| w[0] >= w[1]));
        }
    }

    #[test]
    #[should_panic(expected = "exceed cores")]
    fn shard_cores_rejects_more_shards_than_cores() {
        shard_cores(4, 5);
    }

    #[test]
    fn one_shard_run_matches_unsharded_byte_for_byte() {
        let cfg = base_cfg(PolicyKind::Uwfq);
        let mut core = SchedCore::from_config(cfg.clone());
        let mut sink = CollectSink::default();
        let want = crate::sim::simulate_stream_into_opts(
            &mut core,
            scale_stream(&params()),
            &mut sink,
            SimOpts::default(),
        );
        let run = run_sharded(
            &cfg,
            SimOpts::default(),
            |_| scale_stream(&params()),
            |_| CollectSink::default(),
        );
        assert_eq!(run.per_shard.len(), 1);
        assert_eq!(run.sync.epochs, 0);
        assert_eq!(run.summary.jobs_completed, want.jobs_completed);
        assert_eq!(run.summary.task_events, want.task_events);
        assert_eq!(run.summary.peak_in_flight_jobs, want.peak_in_flight_jobs);
        assert_eq!(run.peak_in_flight_max, want.peak_in_flight_jobs);
        assert_eq!(run.summary.makespan_s.to_bits(), want.makespan_s.to_bits());
        assert_eq!(run.summary.utilization.to_bits(), want.utilization.to_bits());
        assert_eq!(run.summary.busy_core_us, want.busy_core_us);
        assert_eq!(run.summary.fault, want.fault);
        let a: Vec<_> = run.sinks[0].completed.iter().map(|c| (c.job, c.finish)).collect();
        let b: Vec<_> = sink.completed.iter().map(|c| (c.job, c.finish)).collect();
        assert_eq!(a, b, "S=1 completion schedule must be byte-identical");
    }

    #[test]
    fn four_shards_complete_everything_within_the_drift_bound() {
        let mut cfg = base_cfg(PolicyKind::Uwfq);
        cfg.shards = 4;
        cfg.shard_epoch_s = 2.0;
        let run = run_sharded(
            &cfg,
            SimOpts::default(),
            |_| scale_stream(&params()),
            |_| CollectSink::default(),
        );
        assert_eq!(run.per_shard.len(), 4);
        assert_eq!(run.summary.jobs_completed, 600);
        assert!(run.sync.epochs > 0, "multi-epoch run must sync");
        assert!(
            run.sync.max_drift_rsec <= run.sync.bound_rsec + 1e-9,
            "drift {} exceeds bound {}",
            run.sync.max_drift_rsec,
            run.sync.bound_rsec
        );
        // Users are disjoint across shards.
        let mut seen = std::collections::HashSet::new();
        for sink in &run.sinks {
            let mut local = std::collections::HashSet::new();
            for c in &sink.completed {
                local.insert(c.user);
            }
            for u in local {
                assert!(seen.insert(u), "user {u} completed in two shards");
            }
        }
    }

    fn load(backlog: f64, free: u32, done: bool) -> ShardLoad {
        ShardLoad {
            backlog_rsec: backlog,
            pending: if backlog > 0.0 { 1 } else { 0 },
            active_users: if backlog > 0.0 { 1 } else { 0 },
            free_cores: free,
            done,
        }
    }

    #[test]
    fn rebalancer_moves_cores_toward_backlog_within_all_limits() {
        // Shard 0 is hot, shards 1-3 idle with free cores: moves flow to
        // shard 0, bounded by the cap, and the total is conserved.
        let alloc = vec![2u32, 2, 2, 2];
        let loads = vec![
            load(100.0, 0, false),
            load(0.0, 2, false),
            load(0.0, 2, false),
            load(0.0, 2, true),
        ];
        let next = rebalance_cores(&alloc, &loads, 1, 2);
        assert_eq!(next.iter().sum::<u32>(), 8, "total conserved");
        assert_eq!(next[0], 4, "cap of 2 moves, all to the hot shard");
        assert!(next.iter().skip(1).all(|&c| c >= 1), "floor respected");
        // Floor: min_cores = 2 forbids any donation from 2-core shards.
        let held = rebalance_cores(&alloc, &loads, 2, 4);
        assert_eq!(held, alloc);
        // Free-core limit: a donor with nothing published free keeps its
        // allocation even above the floor.
        let busy = vec![
            load(100.0, 0, false),
            load(0.1, 0, false),
            load(0.1, 0, false),
            load(0.1, 0, false),
        ];
        assert_eq!(rebalance_cores(&alloc, &busy, 1, 4), alloc);
    }

    #[test]
    fn rebalancer_hysteresis_holds_near_balanced_loads() {
        let alloc = vec![4u32, 4];
        // 1.2x per-core imbalance — under the 1.5x hysteresis: no move.
        let mild = vec![load(12.0, 1, false), load(10.0, 2, false)];
        assert_eq!(rebalance_cores(&alloc, &mild, 1, 4), alloc);
        // 4x imbalance: cores move.
        let steep = vec![load(40.0, 1, false), load(10.0, 2, false)];
        let next = rebalance_cores(&alloc, &steep, 1, 4);
        assert!(next[0] > 4, "steep imbalance must trigger lending: {next:?}");
        assert_eq!(next.iter().sum::<u32>(), 8);
        // All drained: nothing to receive, nothing moves.
        let drained = vec![load(0.0, 4, true), load(0.0, 4, true)];
        assert_eq!(rebalance_cores(&alloc, &drained, 1, 4), alloc);
        // Single shard: identity.
        assert_eq!(rebalance_cores(&[8], &[load(9.0, 0, false)], 1, 4), vec![8]);
    }

    #[test]
    fn lending_run_completes_within_bound_and_repeats() {
        let mut cfg = base_cfg(PolicyKind::Uwfq);
        cfg.shards = 4;
        cfg.shard_epoch_s = 1.0;
        cfg.shard_rebalance = true;
        cfg.rebalance_cap = 2;
        let go = || {
            run_sharded(
                &cfg,
                SimOpts::default(),
                |_| scale_stream(&params()),
                |_| CollectSink::default(),
            )
        };
        let (a, b) = (go(), go());
        assert_eq!(a.summary.jobs_completed, 600);
        assert!(
            a.sync.max_drift_rsec <= a.sync.bound_rsec + 1e-9,
            "drift {} exceeds bound {} under lending",
            a.sync.max_drift_rsec,
            a.sync.bound_rsec
        );
        assert_eq!(a.summary.jobs_completed, b.summary.jobs_completed);
        assert_eq!(a.summary.makespan_s.to_bits(), b.summary.makespan_s.to_bits());
        assert_eq!(a.sync.lend_events, b.sync.lend_events);
        for (sa, sb) in a.sinks.iter().zip(b.sinks.iter()) {
            let fa: Vec<_> = sa.completed.iter().map(|c| (c.job, c.finish)).collect();
            let fb: Vec<_> = sb.completed.iter().map(|c| (c.job, c.finish)).collect();
            assert_eq!(fa, fb, "lending repeat diverged");
        }
    }

    #[test]
    #[should_panic(expected = "unsatisfiable")]
    fn lending_rejects_unsatisfiable_floor_up_front() {
        let mut cfg = base_cfg(PolicyKind::Uwfq);
        cfg.shards = 4;
        cfg.shard_rebalance = true;
        cfg.rebalance_min_cores = 3; // 3 x 4 > 8 cores
        run_sharded(
            &cfg,
            SimOpts::default(),
            |_| scale_stream(&params()),
            |_| CollectSink::default(),
        );
    }

    #[test]
    fn sharded_runs_repeat_deterministically() {
        for policy in [PolicyKind::Uwfq, PolicyKind::Fair] {
            let mut cfg = base_cfg(policy);
            cfg.shards = 3;
            cfg.shard_epoch_s = 1.5;
            cfg.fault.task_fail_prob = 0.05;
            cfg.fault.retry_backoff_s = 0.05;
            cfg.fault.seed = 9;
            let go = || {
                run_sharded(
                    &cfg,
                    SimOpts::default(),
                    |_| scale_stream(&params()),
                    |_| CollectSink::default(),
                )
            };
            let (a, b) = (go(), go());
            assert_eq!(a.summary.jobs_completed, b.summary.jobs_completed);
            assert_eq!(a.summary.makespan_s.to_bits(), b.summary.makespan_s.to_bits());
            assert_eq!(a.summary.utilization.to_bits(), b.summary.utilization.to_bits());
            assert_eq!(a.summary.fault, b.summary.fault);
            for (sa, sb) in a.sinks.iter().zip(b.sinks.iter()) {
                let fa: Vec<_> = sa.completed.iter().map(|c| (c.job, c.finish)).collect();
                let fb: Vec<_> = sb.completed.iter().map(|c| (c.job, c.finish)).collect();
                assert_eq!(fa, fb, "{}: sharded repeat diverged", policy.name());
            }
        }
    }
}
