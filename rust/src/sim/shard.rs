//! Sharded million-user engine — federated virtual time (the intra-run
//! parallelism the sweep engine cannot provide).
//!
//! One simulated cluster is split into `S` shards: users are partitioned
//! hash-stably ([`shard_of`]) and each shard runs its **own**
//! [`SchedCore`] + event queue over a dedicated core subset
//! ([`shard_cores`]: `cores/S`, deterministic remainder to the lowest
//! shard indices), advancing in parallel under `std::thread::scope`.
//! Cross-shard fairness is kept coupled by a periodic global
//! virtual-time sync barrier: every `shard_epoch_s` of *simulated* time,
//! all shards pause ([`StreamSim::run_until`]), publish their
//! `TwoLevelVtime` state, and re-couple to the population —
//!
//! * level: `v_global := v_ref = Σ n_s·v_s / Σ n_s` (user-count-weighted
//!   population mean), and
//! * rate: `r_total := R_cluster · n_s / Σ n_s` (each shard progresses
//!   at the cluster rate scaled by its live-user share).
//!
//! Level-setting every epoch is what makes the drift bound *provable and
//! non-accumulating*: each epoch restarts from the common `v_ref`, and
//! within one epoch a shard advances `v_global` by at most
//! `r_total · epoch ≤ R_cluster · epoch`, so the pre-sync spread — the
//! per-user normalized-service gap between any two shards — never
//! exceeds **one sync epoch of service at the cluster rate**
//! (`SyncStats::bound_rsec = cores × shard_epoch_s`; the engine reports
//! the observed `max_drift_rsec` and `tests/shard.rs` enforces the
//! bound on randomized registry specs).
//!
//! `S = 1` skips barriers and recoupling entirely and is byte-identical
//! to the unsharded engine by construction — it is the same
//! [`StreamSim`] driver, run uninterrupted. `S > 1` is deterministic
//! (repeat-identical) but *not* equal to the unsharded schedule: shards
//! serve disjoint user sets on disjoint cores, arrival sequence numbers
//! (and therefore fault plans) are shard-local, and the virtual systems
//! only re-couple at epoch granularity.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

use crate::config::Config;
use crate::core::SchedCore;
use crate::fault::FaultStats;
use crate::sim::{CompletionSink, SimOpts, StreamSim, StreamSummary};
use crate::workload::stream::{JobStream, ShardStream};
use crate::TimeUs;

pub use crate::workload::stream::shard_of;

/// Federated core allocation: `cores/S` per shard, the `cores % S`
/// remainder going to the lowest shard indices — deterministic, and the
/// subsets partition the cluster exactly. Panics unless
/// `1 ≤ shards ≤ cores` (every shard needs at least one core).
pub fn shard_cores(cores: u32, shards: u32) -> Vec<u32> {
    assert!(shards >= 1, "shards must be >= 1");
    assert!(
        shards <= cores,
        "shards ({shards}) exceed cores ({cores}): every shard needs a core"
    );
    let base = cores / shards;
    let extra = cores % shards;
    (0..shards).map(|s| base + u32::from(s < extra)).collect()
}

/// Sync-barrier telemetry of one sharded run.
#[derive(Clone, Debug, Default)]
pub struct SyncStats {
    /// Barrier epochs executed (0 when `S = 1`).
    pub epochs: u64,
    /// Max observed pre-sync `|v_shard − v_ref|` over all epochs, in
    /// resource-seconds of global virtual time.
    pub max_drift_rsec: f64,
    /// The provable ceiling: `cores × shard_epoch_s` — one epoch of
    /// service at the cluster rate.
    pub bound_rsec: f64,
}

/// One shard's outcome within a [`ShardRun`].
#[derive(Clone, Debug)]
pub struct ShardSummary {
    pub shard: u32,
    /// Cores dedicated to this shard ([`shard_cores`]).
    pub cores: u32,
    pub summary: StreamSummary,
}

/// Outcome of [`run_sharded`]: per-shard summaries and sinks plus the
/// exact cluster-level merge.
pub struct ShardRun<K> {
    /// Merged summary. Counters sum exactly; `peak_in_flight_jobs` is
    /// the **sum** of per-shard peaks (an upper bound on the true
    /// cluster peak — see `peak_in_flight_max` for the max-of-peaks);
    /// makespan is the max; utilization is recomputed exactly from the
    /// summed busy-core ledger over `cores × max-makespan`; fault
    /// ledgers merge with per-shard core-index offsets.
    pub summary: StreamSummary,
    /// Max of the per-shard peak-in-flight counters (each an exact peak
    /// of its shard; the cross-shard sum can overcount coincidence).
    pub peak_in_flight_max: usize,
    pub per_shard: Vec<ShardSummary>,
    /// Per-shard completion sinks, in shard order (users are disjoint
    /// across shards, so per-user reductions merge without collisions).
    pub sinks: Vec<K>,
    pub sync: SyncStats,
}

/// Run `cfg` sharded `cfg.shards` ways. `make_stream(s)` must
/// regenerate the **full** workload timeline (each shard filters it down
/// to its own users with O(1) extra state — per-user arrival order is
/// preserved verbatim); `make_sink(s)` builds each shard's completion
/// sink. Shards run in parallel scoped threads and join in shard order,
/// so the merge is deterministic regardless of thread scheduling.
///
/// Every shard publishes into lock-free slots and meets at a two-phase
/// [`Barrier`] per epoch (publish → read/recouple → release); a shard
/// that drains early keeps joining barriers with zero active users until
/// all shards finish, so the population reference never blocks.
pub fn run_sharded<S, K, FS, FK>(
    cfg: &Config,
    opts: SimOpts,
    make_stream: FS,
    make_sink: FK,
) -> ShardRun<K>
where
    S: JobStream,
    K: CompletionSink + Send,
    FS: Fn(u32) -> S + Sync,
    FK: Fn(u32) -> K + Sync,
{
    let shards = cfg.shards.max(1);
    let cores_by_shard = shard_cores(cfg.cores, shards);
    let epoch_us: TimeUs = crate::s_to_us(cfg.shard_epoch_s.max(1e-6));
    let cluster_cores = cfg.cores as f64;

    // Published per-shard state: (active users, v_global bits, done).
    // Written before barrier A, read between A and B — the barrier
    // pair is the synchronization; the atomics only make the slots
    // shareable.
    let n_act: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
    let v_bits: Vec<AtomicU64> = (0..shards).map(|_| AtomicU64::new(0)).collect();
    let done_fl: Vec<AtomicBool> = (0..shards).map(|_| AtomicBool::new(false)).collect();
    let barrier = Barrier::new(shards as usize);
    let sync = Mutex::new(SyncStats {
        epochs: 0,
        max_drift_rsec: 0.0,
        bound_rsec: cluster_cores * crate::us_to_s(epoch_us),
    });

    let mut results: Vec<(StreamSummary, K)> = Vec::with_capacity(shards as usize);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(shards as usize);
        for s in 0..shards {
            let mut shard_cfg = cfg.clone();
            shard_cfg.cores = cores_by_shard[s as usize];
            let (make_stream, make_sink) = (&make_stream, &make_sink);
            let (n_act, v_bits, done_fl) = (&n_act, &v_bits, &done_fl);
            let (barrier, sync) = (&barrier, &sync);
            handles.push(scope.spawn(move || {
                let mut core = SchedCore::from_config(shard_cfg);
                let mut sink = make_sink(s);
                let stream = ShardStream::new(make_stream(s), s, shards);
                let mut sim = StreamSim::new(&mut core, stream, &mut sink, opts);
                let summary = if shards == 1 {
                    // Unsharded fast path: no barriers, no recoupling —
                    // byte-identical to `simulate_stream_into_opts` by
                    // construction (same driver, one uninterrupted run).
                    let done = sim.run_until(TimeUs::MAX);
                    debug_assert!(done, "run_until(MAX) cannot pause");
                    sim.finish()
                } else {
                    let mut done = false;
                    let mut epoch: u64 = 1;
                    loop {
                        let t_bar = epoch.saturating_mul(epoch_us);
                        if !done {
                            done = sim.run_until(t_bar);
                        }
                        let (n, v) = if done {
                            // Drained shards stop contributing to the
                            // population reference but keep joining
                            // barriers until everyone is done.
                            (0usize, 0.0f64)
                        } else {
                            match sim.core_mut().policy.vtime_mut() {
                                Some(vt) => vt.sync_snapshot(crate::us_to_s(t_bar)),
                                None => (0, 0.0), // no virtual time: decoupled
                            }
                        };
                        n_act[s as usize].store(n, Ordering::Relaxed);
                        v_bits[s as usize].store(v.to_bits(), Ordering::Relaxed);
                        done_fl[s as usize].store(done, Ordering::Relaxed);
                        barrier.wait(); // A: everyone published
                        if done_fl.iter().all(|f| f.load(Ordering::Relaxed)) {
                            // Flags were all written before barrier A, so
                            // every shard takes this exit together.
                            break sim.finish();
                        }
                        let mut n_total = 0usize;
                        let mut acc = 0.0f64;
                        for (na, vb) in n_act.iter().zip(v_bits.iter()) {
                            let ni = na.load(Ordering::Relaxed);
                            n_total += ni;
                            acc += ni as f64 * f64::from_bits(vb.load(Ordering::Relaxed));
                        }
                        if n_total > 0 {
                            // Each shard computes the identical v_ref from
                            // the same published bits — no leader needed.
                            let v_ref = acc / n_total as f64;
                            if !done {
                                if let Some(vt) = sim.core_mut().policy.vtime_mut() {
                                    vt.recouple(v_ref, cluster_cores, n, n_total);
                                }
                            }
                            if s == 0 {
                                let mut drift = 0.0f64;
                                for (na, vb) in n_act.iter().zip(v_bits.iter()) {
                                    if na.load(Ordering::Relaxed) > 0 {
                                        let vi = f64::from_bits(vb.load(Ordering::Relaxed));
                                        drift = drift.max((vi - v_ref).abs());
                                    }
                                }
                                let mut st = sync.lock().unwrap();
                                st.max_drift_rsec = st.max_drift_rsec.max(drift);
                            }
                        }
                        if s == 0 {
                            sync.lock().unwrap().epochs += 1;
                        }
                        barrier.wait(); // B: recoupling visible, epoch advances
                        epoch += 1;
                    }
                };
                (summary, sink)
            }));
        }
        for h in handles {
            results.push(h.join().expect("shard thread panicked"));
        }
    });

    // Deterministic shard-ordered merge. At S=1 every reduction is the
    // identity (sum/max of one element; utilization re-derives from the
    // same operands in the same order), so the merged summary is
    // byte-identical to the unsharded one.
    let mut per_shard = Vec::with_capacity(results.len());
    let mut sinks = Vec::with_capacity(results.len());
    let mut merged = StreamSummary {
        label: String::new(),
        jobs_completed: 0,
        task_events: 0,
        peak_in_flight_jobs: 0,
        makespan_s: 0.0,
        utilization: 0.0,
        busy_core_us: 0,
        fault: FaultStats::default(),
    };
    let mut peak_max = 0usize;
    let mut core_offset = 0usize;
    for (s, (summary, sink)) in results.into_iter().enumerate() {
        if s == 0 {
            merged.label = summary.label.clone();
        }
        merged.jobs_completed += summary.jobs_completed;
        merged.task_events += summary.task_events;
        merged.peak_in_flight_jobs += summary.peak_in_flight_jobs;
        peak_max = peak_max.max(summary.peak_in_flight_jobs);
        merged.makespan_s = merged.makespan_s.max(summary.makespan_s);
        merged.busy_core_us += summary.busy_core_us;
        merged.fault.merge(&summary.fault, core_offset);
        core_offset += cores_by_shard[s] as usize;
        per_shard.push(ShardSummary {
            shard: s as u32,
            cores: cores_by_shard[s],
            summary,
        });
        sinks.push(sink);
    }
    merged.utilization = if merged.makespan_s > 0.0 {
        merged.busy_core_us as f64 / 1e6 / (cluster_cores * merged.makespan_s)
    } else {
        0.0
    };

    ShardRun {
        summary: merged,
        peak_in_flight_max: peak_max,
        per_shard,
        sinks,
        sync: sync.into_inner().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::PolicyKind;
    use crate::sim::CollectSink;
    use crate::workload::stream::{scale_stream, ScaleParams};

    fn base_cfg(policy: PolicyKind) -> Config {
        Config {
            cores: 8,
            task_overhead: 0.0,
            policy,
            ..Config::default()
        }
    }

    fn params() -> ScaleParams {
        ScaleParams {
            users: 40,
            jobs: 600,
            cores: 8,
            target_utilization: 0.8,
            seed: 17,
        }
    }

    #[test]
    fn shard_cores_partitions_exactly() {
        assert_eq!(shard_cores(8, 1), vec![8]);
        assert_eq!(shard_cores(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(shard_cores(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(shard_cores(7, 3), vec![3, 2, 2]);
        for (cores, shards) in [(64u32, 5u32), (13, 13), (9, 2)] {
            let v = shard_cores(cores, shards);
            assert_eq!(v.iter().sum::<u32>(), cores);
            assert!(v.iter().all(|&c| c >= 1));
            // Deterministic remainder: earlier shards never smaller.
            assert!(v.windows(2).all(|w| w[0] >= w[1]));
        }
    }

    #[test]
    #[should_panic(expected = "exceed cores")]
    fn shard_cores_rejects_more_shards_than_cores() {
        shard_cores(4, 5);
    }

    #[test]
    fn one_shard_run_matches_unsharded_byte_for_byte() {
        let cfg = base_cfg(PolicyKind::Uwfq);
        let mut core = SchedCore::from_config(cfg.clone());
        let mut sink = CollectSink::default();
        let want = crate::sim::simulate_stream_into_opts(
            &mut core,
            scale_stream(&params()),
            &mut sink,
            SimOpts::default(),
        );
        let run = run_sharded(
            &cfg,
            SimOpts::default(),
            |_| scale_stream(&params()),
            |_| CollectSink::default(),
        );
        assert_eq!(run.per_shard.len(), 1);
        assert_eq!(run.sync.epochs, 0);
        assert_eq!(run.summary.jobs_completed, want.jobs_completed);
        assert_eq!(run.summary.task_events, want.task_events);
        assert_eq!(run.summary.peak_in_flight_jobs, want.peak_in_flight_jobs);
        assert_eq!(run.peak_in_flight_max, want.peak_in_flight_jobs);
        assert_eq!(run.summary.makespan_s.to_bits(), want.makespan_s.to_bits());
        assert_eq!(run.summary.utilization.to_bits(), want.utilization.to_bits());
        assert_eq!(run.summary.busy_core_us, want.busy_core_us);
        assert_eq!(run.summary.fault, want.fault);
        let a: Vec<_> = run.sinks[0].completed.iter().map(|c| (c.job, c.finish)).collect();
        let b: Vec<_> = sink.completed.iter().map(|c| (c.job, c.finish)).collect();
        assert_eq!(a, b, "S=1 completion schedule must be byte-identical");
    }

    #[test]
    fn four_shards_complete_everything_within_the_drift_bound() {
        let mut cfg = base_cfg(PolicyKind::Uwfq);
        cfg.shards = 4;
        cfg.shard_epoch_s = 2.0;
        let run = run_sharded(
            &cfg,
            SimOpts::default(),
            |_| scale_stream(&params()),
            |_| CollectSink::default(),
        );
        assert_eq!(run.per_shard.len(), 4);
        assert_eq!(run.summary.jobs_completed, 600);
        assert!(run.sync.epochs > 0, "multi-epoch run must sync");
        assert!(
            run.sync.max_drift_rsec <= run.sync.bound_rsec + 1e-9,
            "drift {} exceeds bound {}",
            run.sync.max_drift_rsec,
            run.sync.bound_rsec
        );
        // Users are disjoint across shards.
        let mut seen = std::collections::HashSet::new();
        for sink in &run.sinks {
            let mut local = std::collections::HashSet::new();
            for c in &sink.completed {
                local.insert(c.user);
            }
            for u in local {
                assert!(seen.insert(u), "user {u} completed in two shards");
            }
        }
    }

    #[test]
    fn sharded_runs_repeat_deterministically() {
        for policy in [PolicyKind::Uwfq, PolicyKind::Fair] {
            let mut cfg = base_cfg(policy);
            cfg.shards = 3;
            cfg.shard_epoch_s = 1.5;
            cfg.fault.task_fail_prob = 0.05;
            cfg.fault.retry_backoff_s = 0.05;
            cfg.fault.seed = 9;
            let go = || {
                run_sharded(
                    &cfg,
                    SimOpts::default(),
                    |_| scale_stream(&params()),
                    |_| CollectSink::default(),
                )
            };
            let (a, b) = (go(), go());
            assert_eq!(a.summary.jobs_completed, b.summary.jobs_completed);
            assert_eq!(a.summary.makespan_s.to_bits(), b.summary.makespan_s.to_bits());
            assert_eq!(a.summary.utilization.to_bits(), b.summary.utilization.to_bits());
            assert_eq!(a.summary.fault, b.summary.fault);
            for (sa, sb) in a.sinks.iter().zip(b.sinks.iter()) {
                let fa: Vec<_> = sa.completed.iter().map(|c| (c.job, c.finish)).collect();
                let fb: Vec<_> = sb.completed.iter().map(|c| (c.job, c.finish)).collect();
                assert_eq!(fa, fb, "{}: sharded repeat diverged", policy.name());
            }
        }
    }
}
