//! Stage-input partitioning (paper §2.1.2, §3.2, §4.1.2).
//!
//! Two distinct phases, as in Spark:
//! * **file scan** (leaf stages): the input partitioner splits the input
//!   data into tasks;
//! * **shuffle** (non-leaf stages): outputs start at 200 partitions and AQE
//!   coalesces them down using the advisory partition size and a minimum
//!   partition count.
//!
//! [`SizeScheme`] reproduces Spark's defaults. [`RuntimeScheme`] is the
//! paper's contribution: split so that each task runs for about the
//! Advisory Task Runtime (ATR), both at scan time and as the AQE
//! minimum-partition override.

pub mod runtime;
pub mod size;

pub use runtime::RuntimeScheme;
pub use size::SizeScheme;

use crate::core::job::StageSpec;

/// AQE's fixed initial shuffle partition count (Spark default).
pub const AQE_INITIAL_PARTITIONS: u32 = 200;

/// A partitioning strategy: returns equal-width input ranges `[lo, hi)`
/// covering `[0, 1)`.
///
/// `est_slot_time` is the *estimated* stage sequential runtime from the
/// runtime estimator (runtime partitioning never sees ground truth).
pub trait PartitionScheme: Send {
    fn name(&self) -> &'static str;
    fn partition_count(&self, stage: &StageSpec, est_slot_time: f64, cores: u32) -> u32;

    fn partition(&self, stage: &StageSpec, est_slot_time: f64, cores: u32) -> Vec<(f64, f64)> {
        let mut n = self.partition_count(stage, est_slot_time, cores).max(1);
        if let Some(cap) = stage.max_parallelism {
            n = n.min(cap.max(1));
        }
        equal_ranges(n)
    }
}

/// `n` equal-width ranges covering `[0,1)` exactly.
pub fn equal_ranges(n: u32) -> Vec<(f64, f64)> {
    let n = n.max(1);
    (0..n)
        .map(|i| (i as f64 / n as f64, (i + 1) as f64 / n as f64))
        .collect()
}

/// Build a scheme by kind — config entry point. The `-P` suffix in the
/// paper's tables corresponds to `Kind::Runtime`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// Spark default partitioning (size-based + plain AQE).
    Size,
    /// The paper's runtime (ATR) partitioning, `-P` variants.
    Runtime,
}

impl SchemeKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Size => "default",
            SchemeKind::Runtime => "runtime",
        }
    }
    pub fn parse(s: &str) -> Option<SchemeKind> {
        match s.to_ascii_lowercase().as_str() {
            "size" | "default" => Some(SchemeKind::Size),
            "runtime" | "atr" | "p" => Some(SchemeKind::Runtime),
            _ => None,
        }
    }
}

pub fn make_scheme(
    kind: SchemeKind,
    max_partition_bytes: u64,
    advisory_partition_bytes: u64,
    atr: f64,
) -> Box<dyn PartitionScheme> {
    match kind {
        SchemeKind::Size => Box::new(SizeScheme::new(max_partition_bytes, advisory_partition_bytes)),
        SchemeKind::Runtime => Box::new(RuntimeScheme::new(
            atr,
            max_partition_bytes,
            advisory_partition_bytes,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_ranges_cover_unit() {
        for n in [1u32, 2, 7, 200] {
            let r = equal_ranges(n);
            assert_eq!(r.len(), n as usize);
            assert_eq!(r[0].0, 0.0);
            assert_eq!(r.last().unwrap().1, 1.0);
            for w in r.windows(2) {
                assert!((w[0].1 - w[1].0).abs() < 1e-12);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    #[test]
    fn kind_parse() {
        assert_eq!(SchemeKind::parse("default"), Some(SchemeKind::Size));
        assert_eq!(SchemeKind::parse("runtime"), Some(SchemeKind::Runtime));
        assert_eq!(SchemeKind::parse("x"), None);
    }
}
