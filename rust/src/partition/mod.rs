//! Stage-input partitioning (paper §2.1.2, §3.2, §4.1.2).
//!
//! Two distinct phases, as in Spark:
//! * **file scan** (leaf stages): the input partitioner splits the input
//!   data into tasks;
//! * **shuffle** (non-leaf stages): outputs start at 200 partitions and AQE
//!   coalesces them down using the advisory partition size and a minimum
//!   partition count.
//!
//! [`SizeScheme`] reproduces Spark's defaults. [`RuntimeScheme`] is the
//! paper's contribution: split so that each task runs for about the
//! Advisory Task Runtime (ATR), both at scan time and as the AQE
//! minimum-partition override.

pub mod runtime;
pub mod size;

pub use runtime::RuntimeScheme;
pub use size::SizeScheme;

use crate::core::job::StageSpec;

/// AQE's fixed initial shuffle partition count (Spark default).
pub const AQE_INITIAL_PARTITIONS: u32 = 200;

/// A partitioning strategy: returns equal-width input ranges `[lo, hi)`
/// covering `[0, 1)`.
///
/// `est_slot_time` is the *estimated* stage sequential runtime from the
/// runtime estimator (runtime partitioning never sees ground truth).
/// A scheme whose split depends on the cluster size (the size-based
/// scan's one-partition-per-core floor) captures the core count at
/// construction ([`make_scheme`]) — `partition_count` itself is a pure
/// function of the stage and the estimate.
pub trait PartitionScheme: Send {
    fn name(&self) -> &'static str;
    fn partition_count(&self, stage: &StageSpec, est_slot_time: f64) -> u32;

    fn partition(&self, stage: &StageSpec, est_slot_time: f64) -> Vec<(f64, f64)> {
        let mut n = self.partition_count(stage, est_slot_time).max(1);
        if let Some(cap) = stage.max_parallelism {
            n = n.min(cap.max(1));
        }
        equal_ranges(n)
    }
}

/// `n` equal-width ranges covering `[0,1)` exactly.
pub fn equal_ranges(n: u32) -> Vec<(f64, f64)> {
    let n = n.max(1);
    (0..n)
        .map(|i| (i as f64 / n as f64, (i + 1) as f64 / n as f64))
        .collect()
}

/// Build a scheme by kind — config entry point. The `-P` suffix in the
/// paper's tables corresponds to `Kind::Runtime`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// Spark default partitioning (size-based + plain AQE).
    Size,
    /// The paper's runtime (ATR) partitioning, `-P` variants.
    Runtime,
}

/// The spellings [`SchemeKind::parse`] accepts, for error messages.
const SCHEME_KINDS: &str = "size | default, runtime | atr | p | -P";

impl SchemeKind {
    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Size => "default",
            SchemeKind::Runtime => "runtime",
        }
    }

    /// Parse a scheme name. Accepts the paper's literal `-P` spelling for
    /// the runtime variant; rejections list the valid kinds.
    pub fn parse(s: &str) -> Result<SchemeKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "size" | "default" => Ok(SchemeKind::Size),
            "runtime" | "atr" | "p" | "-p" => Ok(SchemeKind::Runtime),
            _ => Err(format!("unknown scheme '{s}' (valid kinds: {SCHEME_KINDS})")),
        }
    }
}

/// Build a scheme bound to a cluster of `cores` executor cores.
pub fn make_scheme(
    kind: SchemeKind,
    cores: u32,
    max_partition_bytes: u64,
    advisory_partition_bytes: u64,
    atr: f64,
) -> Box<dyn PartitionScheme> {
    match kind {
        SchemeKind::Size => Box::new(SizeScheme::new(
            max_partition_bytes,
            advisory_partition_bytes,
            cores,
        )),
        SchemeKind::Runtime => Box::new(RuntimeScheme::new(
            atr,
            max_partition_bytes,
            advisory_partition_bytes,
            cores,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_ranges_cover_unit() {
        for n in [1u32, 2, 7, 200] {
            let r = equal_ranges(n);
            assert_eq!(r.len(), n as usize);
            assert_eq!(r[0].0, 0.0);
            assert_eq!(r.last().unwrap().1, 1.0);
            for w in r.windows(2) {
                assert!((w[0].1 - w[1].0).abs() < 1e-12);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    #[test]
    fn kind_parse() {
        assert_eq!(SchemeKind::parse("default"), Ok(SchemeKind::Size));
        assert_eq!(SchemeKind::parse("runtime"), Ok(SchemeKind::Runtime));
        // The paper's literal spelling for the runtime variants.
        assert_eq!(SchemeKind::parse("-P"), Ok(SchemeKind::Runtime));
        assert_eq!(SchemeKind::parse("-p"), Ok(SchemeKind::Runtime));
        let err = SchemeKind::parse("x").unwrap_err();
        assert!(err.contains("unknown scheme 'x'"), "{err}");
        assert!(err.contains("runtime") && err.contains("default"), "{err}");
    }
}
