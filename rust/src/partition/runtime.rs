//! The paper's runtime (ATR) partitioning (§3.2, §4.1.2).
//!
//! Partition count = `ceil(estimated stage runtime / ATR)`, where ATR is
//! the Advisory Task Runtime: the desired per-task duration. This mitigates
//! both task skew (hot data regions are split across more tasks) and
//! priority inversion (tasks release executor cores after ~ATR seconds, so
//! a newly arrived high-priority job waits at most ~ATR for a core).
//!
//! At shuffle stages the same estimate sets AQE's **minimum** partition
//! count, so coalescing "never goes down to an amount that would introduce
//! long-running tasks" while otherwise leaving AQE's size-based logic
//! intact (§4.1.2).

use super::{size::SizeScheme, PartitionScheme};
use crate::core::job::StageSpec;

pub struct RuntimeScheme {
    /// Advisory Task Runtime in seconds.
    pub atr: f64,
    size: SizeScheme,
}

impl RuntimeScheme {
    pub fn new(
        atr: f64,
        max_partition_bytes: u64,
        advisory_partition_bytes: u64,
        cores: u32,
    ) -> Self {
        assert!(atr > 0.0, "ATR must be positive");
        RuntimeScheme {
            atr,
            size: SizeScheme::new(max_partition_bytes, advisory_partition_bytes, cores),
        }
    }

    /// `Partition amount = Stage runtime / ATR` (§3.2), at least 1.
    pub fn runtime_count(&self, est_slot_time: f64) -> u32 {
        (est_slot_time / self.atr).ceil().max(1.0) as u32
    }
}

impl PartitionScheme for RuntimeScheme {
    fn name(&self) -> &'static str {
        "runtime"
    }

    fn partition_count(&self, stage: &StageSpec, est_slot_time: f64) -> u32 {
        let dynamic_min = self.runtime_count(est_slot_time);
        if stage.is_leaf_input {
            // File scan: runtime partitioning replaces the size-based
            // split outright — the split is a function of estimated
            // runtime and ATR only (§3.2).
            dynamic_min
        } else {
            // AQE coalescing with the dynamic minimum override.
            self.size.shuffle_count(stage, dynamic_min)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::{CostProfile, StagePhase, StageSpec};

    fn stage(leaf: bool, bytes: u64, slot: f64) -> StageSpec {
        StageSpec {
            phase: StagePhase::Compute,
            parents: if leaf { vec![] } else { vec![0] },
            is_leaf_input: leaf,
            input_bytes: bytes,
            slot_time: slot,
            cost: CostProfile::uniform(),
            max_parallelism: None,
            opcount: 1,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    #[test]
    fn leaf_count_is_runtime_over_atr() {
        let r = RuntimeScheme::new(0.25, 128 << 20, 64 << 20, 32);
        // 16 s of work at ATR 250 ms → 64 tasks, regardless of cores.
        assert_eq!(r.partition_count(&stage(true, 1 << 20, 16.0), 16.0), 64);
    }

    #[test]
    fn tiny_stage_gets_one_partition() {
        let r = RuntimeScheme::new(1.0, 128 << 20, 64 << 20, 32);
        assert_eq!(r.partition_count(&stage(true, 1 << 20, 0.01), 0.01), 1);
    }

    #[test]
    fn shuffle_min_override_prevents_coalesce_to_one() {
        let r = RuntimeScheme::new(0.5, 128 << 20, 64 << 20, 32);
        // Tiny shuffle output (would coalesce to 1 under default AQE) but
        // 10 s of estimated runtime → min 20 partitions.
        assert_eq!(r.partition_count(&stage(false, 1 << 20, 10.0), 10.0), 20);
    }

    #[test]
    fn shuffle_respects_size_when_larger() {
        let r = RuntimeScheme::new(10.0, 128 << 20, 64 << 20, 32);
        // Size-based coalescing wants 10 partitions; runtime min is 1 →
        // AQE's own sizing wins (minimal interference, §4.1.2).
        assert_eq!(r.partition_count(&stage(false, 640 << 20, 5.0), 5.0), 10);
    }

    #[test]
    fn uses_estimate_not_truth() {
        let r = RuntimeScheme::new(1.0, 128 << 20, 64 << 20, 32);
        let s = stage(true, 1 << 20, 100.0); // truth: 100 s
        // Estimator said 2 s → 2 partitions. Runtime partitioning must
        // consume the estimate only.
        assert_eq!(r.partition_count(&s, 2.0), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_atr() {
        RuntimeScheme::new(0.0, 1, 1, 1);
    }
}
