//! Spark's default size-based partitioning (paper §2.1.2).
//!
//! * Leaf (file-scan) stages: input is divided by `maxPartitionBytes`, but
//!   at least one partition per core so every stage can use the whole
//!   cluster ("dividing the data equally among the available cores"). The
//!   core count is bound at construction — see
//!   [`crate::partition::PartitionScheme`].
//! * Shuffle stages: AQE starts from 200 partitions and coalesces to
//!   `max(ceil(bytes / advisoryPartitionBytes), min_partitions)` with the
//!   Spark-default `min_partitions = 1` — which is exactly what lets AQE
//!   create long-running tasks (§4.1.2).

use super::{PartitionScheme, AQE_INITIAL_PARTITIONS};
use crate::core::job::StageSpec;

pub struct SizeScheme {
    max_partition_bytes: u64,
    advisory_partition_bytes: u64,
    /// Executor cores of the bound cluster (scan floor: one per core).
    cores: u32,
    /// AQE minimum coalesced partition count (Spark default 1). The
    /// runtime scheme raises this dynamically.
    pub min_partitions: u32,
}

impl SizeScheme {
    pub fn new(max_partition_bytes: u64, advisory_partition_bytes: u64, cores: u32) -> Self {
        SizeScheme {
            max_partition_bytes: max_partition_bytes.max(1),
            advisory_partition_bytes: advisory_partition_bytes.max(1),
            cores: cores.max(1),
            min_partitions: 1,
        }
    }

    pub fn leaf_count(&self, stage: &StageSpec) -> u32 {
        let by_size = stage.input_bytes.div_ceil(self.max_partition_bytes) as u32;
        by_size.max(self.cores).max(1)
    }

    pub fn shuffle_count(&self, stage: &StageSpec, min_partitions: u32) -> u32 {
        let by_size = stage.input_bytes.div_ceil(self.advisory_partition_bytes) as u32;
        by_size
            .max(min_partitions)
            .clamp(1, AQE_INITIAL_PARTITIONS)
    }
}

impl PartitionScheme for SizeScheme {
    fn name(&self) -> &'static str {
        "default"
    }

    fn partition_count(&self, stage: &StageSpec, _est_slot_time: f64) -> u32 {
        if stage.is_leaf_input {
            self.leaf_count(stage)
        } else {
            self.shuffle_count(stage, self.min_partitions)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::{CostProfile, StagePhase, StageSpec};

    fn leaf(bytes: u64) -> StageSpec {
        StageSpec {
            phase: StagePhase::Load,
            parents: vec![],
            is_leaf_input: true,
            input_bytes: bytes,
            slot_time: 1.0,
            cost: CostProfile::uniform(),
            max_parallelism: None,
            opcount: 1,
            demand: crate::core::task::ResourceVec::UNIT,
        }
    }

    fn shuffle(bytes: u64) -> StageSpec {
        let mut s = leaf(bytes);
        s.is_leaf_input = false;
        s.parents = vec![0];
        s
    }

    #[test]
    fn leaf_at_least_one_per_core() {
        let s = SizeScheme::new(128 << 20, 64 << 20, 32);
        // Small input still spreads across all cores.
        assert_eq!(s.partition_count(&leaf(1 << 20), 1.0), 32);
    }

    #[test]
    fn leaf_oversplits_when_max_partition_bytes_small() {
        // The paper §5.1: default maxPartitionBytes over-partitions their
        // 752 MB dataset — reproduce that behaviour.
        let s = SizeScheme::new(8 << 20, 64 << 20, 32);
        assert_eq!(s.partition_count(&leaf(752 << 20), 1.0), 94);
    }

    #[test]
    fn shuffle_coalesces_to_advisory() {
        let s = SizeScheme::new(128 << 20, 64 << 20, 32);
        assert_eq!(s.partition_count(&shuffle(640 << 20), 1.0), 10);
        // Tiny shuffle output coalesces all the way to min_partitions=1,
        // the long-running-task hazard the paper fixes.
        assert_eq!(s.partition_count(&shuffle(1 << 20), 1.0), 1);
    }

    #[test]
    fn shuffle_capped_at_200() {
        let s = SizeScheme::new(128 << 20, 1 << 20, 32);
        assert_eq!(s.partition_count(&shuffle(1 << 40), 1.0), 200);
    }

    #[test]
    fn respects_max_parallelism_cap() {
        let s = SizeScheme::new(128 << 20, 64 << 20, 32);
        let mut st = leaf(752 << 20);
        st.max_parallelism = Some(1);
        assert_eq!(s.partition(&st, 1.0).len(), 1);
    }
}
