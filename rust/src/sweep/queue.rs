//! Lock-free work distribution for the sweep engine.

use std::sync::atomic::{AtomicUsize, Ordering};

/// An atomic take-a-number queue over cell indices `0..len`: each worker
/// claims the next unclaimed index with one `fetch_add`. Claim order is
/// nondeterministic under contention — determinism is restored at merge
/// time, because every claimed index travels with its result and the
/// merge writes results back in index order (see
/// [`super::run_cells`]).
pub struct IndexQueue {
    next: AtomicUsize,
    len: usize,
}

impl IndexQueue {
    pub fn new(len: usize) -> IndexQueue {
        IndexQueue {
            next: AtomicUsize::new(0),
            len,
        }
    }

    /// Claim the next cell index, or `None` once the grid is exhausted.
    /// `Relaxed` suffices: the counter is the only state shared through
    /// the queue, and the scoped join at the end of the sweep provides
    /// the synchronization for the results themselves.
    pub fn claim(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        (i < self.len).then_some(i)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claims_each_index_exactly_once() {
        let q = IndexQueue::new(5);
        let mut seen: Vec<usize> = std::iter::from_fn(|| q.claim()).collect();
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        assert_eq!(q.claim(), None, "exhausted queue stays exhausted");
    }

    #[test]
    fn concurrent_claims_partition_the_range() {
        let q = IndexQueue::new(1000);
        let parts: Vec<Vec<usize>> = std::thread::scope(|s| {
            (0..4)
                .map(|_| s.spawn(|| std::iter::from_fn(|| q.claim()).collect::<Vec<_>>()))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let mut all: Vec<usize> = parts.into_iter().flatten().collect();
        all.sort();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn empty_queue() {
        let q = IndexQueue::new(0);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert_eq!(q.claim(), None);
    }
}
