//! The parallel sweep engine — deterministic multi-core execution of
//! experiment grids.
//!
//! The paper's evaluation (§5.2–§5.3) is a grid: every table, figure and
//! ablation runs (policy × partition-scheme × workload × seed) cells. Each
//! cell is an independent deterministic simulation, so the grid itself —
//! not just one simulation — should saturate the machine. This module
//! runs cells concurrently while keeping the output **byte-identical** to
//! sequential execution:
//!
//! * **Work distribution** is an atomic take-a-number queue
//!   ([`queue::IndexQueue`]) over the cell list — no channels, no locks,
//!   no crates; `std::thread::scope` keeps borrows plain references, so
//!   the build stays offline and dependency-free.
//! * **Determinism by merge order, not execution order**: workers return
//!   `(cell index, result)` pairs and [`run_cells`] writes them back into
//!   cell order. Since every cell is a deterministic function of its
//!   inputs (the simulator is seeded and single-threaded per cell), the
//!   merged vector is identical no matter how cells interleave across
//!   threads — verified end-to-end by the `sweep_differential` test,
//!   which asserts byte-identical table/CSV output at 1 vs N threads.
//! * **Allocation reuse**: each worker owns one [`SimCtx`] whose
//!   [`crate::core::SchedCore`] is recycled between cells
//!   ([`crate::core::SchedCore::reset`]) — slab arenas, heaps and scratch
//!   buffers stay warm instead of being rebuilt per run. Shared-read
//!   inputs (workloads) are borrowed by the cells and cloned only inside
//!   the worker that runs the cell.
//!
//! The bench layer ([`crate::bench`]) expresses every table/figure grid
//! as a cell list over this engine; `uwfq sweep --threads N` drives the
//! whole evaluation through it and records cells/s in `BENCH_sweep.json`.

pub mod queue;

use self::queue::IndexQueue;
use crate::sim::SimCtx;

/// Handle describing how grids should execute: `threads == 1` is the
/// sequential reference path (one worker, in-order), `threads > 1` the
/// parallel path with identical output. Passed through the bench layer so
/// every grid routes through the same engine.
#[derive(Clone, Copy, Debug)]
pub struct Sweep {
    threads: usize,
}

impl Sweep {
    /// Sequential execution — the reference semantics.
    pub fn seq() -> Sweep {
        Sweep { threads: 1 }
    }

    /// Parallel execution on `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Sweep {
        Sweep {
            threads: threads.max(1),
        }
    }

    /// Parallel execution on all available cores.
    pub fn auto() -> Sweep {
        Sweep::new(auto_threads(None))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` over every cell, merging results in cell order. See
    /// [`run_cells`].
    pub fn run<C, R, F>(&self, cells: &[C], f: F) -> Vec<R>
    where
        C: Sync,
        R: Send,
        F: Fn(&mut SimCtx, &C) -> R + Sync,
    {
        run_cells(cells, self.threads, f)
    }
}

impl Default for Sweep {
    fn default() -> Self {
        Sweep::seq()
    }
}

/// Resolve a `--threads` request: `None` or `Some(0)` means "all cores".
pub fn auto_threads(requested: Option<usize>) -> usize {
    match requested {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    }
}

/// Execute `f(ctx, &cells[i])` for every cell and return the results **in
/// cell order**, regardless of which worker ran which cell. With
/// `threads == 1` (or ≤ 1 cell) this degenerates to a plain in-order loop
/// over one reused [`SimCtx`] — the reference the parallel path is
/// byte-compared against.
pub fn run_cells<C, R, F>(cells: &[C], threads: usize, f: F) -> Vec<R>
where
    C: Sync,
    R: Send,
    F: Fn(&mut SimCtx, &C) -> R + Sync,
{
    let threads = threads.max(1).min(cells.len().max(1));
    if threads == 1 {
        let mut ctx = SimCtx::new();
        return cells.iter().map(|c| f(&mut ctx, c)).collect();
    }

    let queue = IndexQueue::new(cells.len());
    let parts: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| {
                    // One recycled core per worker; cells only borrow
                    // shared inputs and clone them here, inside the
                    // worker that runs the cell.
                    let mut ctx = SimCtx::new();
                    let mut out: Vec<(usize, R)> = Vec::new();
                    while let Some(i) = queue.claim() {
                        out.push((i, f(&mut ctx, &cells[i])));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });

    // Deterministic merge: results land in cell order.
    let mut slots: Vec<Option<R>> = (0..cells.len()).map(|_| None).collect();
    for part in parts {
        for (i, r) in part {
            debug_assert!(slots[i].is_none(), "cell {i} ran twice");
            slots[i] = Some(r);
        }
    }
    slots
        .into_iter()
        .map(|r| r.expect("cell never claimed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::core::job::JobSpec;
    use crate::sched::PolicyKind;

    #[test]
    fn results_arrive_in_cell_order() {
        // Cells with wildly uneven work: late cells finish before early
        // ones on the worker pool, but the merge restores cell order.
        let cells: Vec<u64> = vec![400_000, 7, 90_000, 1, 50_000, 3, 2, 600_000];
        let expect: Vec<u64> = cells.iter().map(|&n| (0..n).sum()).collect();
        for threads in [1, 2, 4, 16] {
            let got = run_cells(&cells, threads, |_, &n| (0..n).sum::<u64>());
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn more_workers_than_cells_is_fine() {
        let got = run_cells(&[10u64, 20], 8, |_, &n| n * 2);
        assert_eq!(got, vec![20, 40]);
        let empty: Vec<u64> = run_cells(&[], 4, |_, &n: &u64| n);
        assert!(empty.is_empty());
    }

    #[test]
    fn parallel_simulation_grid_matches_sequential() {
        // The real cell type: (policy, workload) simulations. Parallel
        // output must equal the sequential reference exactly.
        let jobs: Vec<JobSpec> = (0..60)
            .map(|i| {
                JobSpec::three_phase(
                    (i % 7) as u32,
                    &format!("g{i}"),
                    (i as u64) * 40_000,
                    0.4 + (i % 5) as f64 * 0.3,
                    (32 + (i as u64 % 3) * 32) << 20,
                    4,
                    None,
                )
            })
            .collect();
        let cells: Vec<Config> = PolicyKind::ALL
            .iter()
            .map(|&p| Config::default().with_cores(8).with_policy(p))
            .collect();
        let run = |threads: usize| -> Vec<Vec<(u64, u64)>> {
            run_cells(&cells, threads, |ctx, cfg| {
                ctx.simulate(cfg, jobs.clone())
                    .completed
                    .iter()
                    .map(|c| (c.job, c.finish))
                    .collect()
            })
        };
        let seq = run(1);
        assert!(seq.iter().all(|r| r.len() == 60));
        assert_eq!(run(3), seq);
        assert_eq!(run(5), seq);
    }
}
