//! Artifact manifest parsing and PJRT compilation.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::jsonout;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub block_rows: usize,
    pub cols: usize,
    pub tile: usize,
    pub agg_fanin: usize,
    /// (opcount k, file name) of each compute variant.
    pub compute: Vec<(u32, String)>,
    pub aggregate_file: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = jsonout::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let get_usize = |k: &str| {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("manifest missing '{k}'"))
        };
        let compute = v
            .get("compute")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'compute'"))?
            .iter()
            .map(|e| {
                let k = e
                    .get("k")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("compute entry missing 'k'"))?;
                let f = e
                    .get("file")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("compute entry missing 'file'"))?;
                Ok((k as u32, f.to_string()))
            })
            .collect::<Result<Vec<_>>>()?;
        let aggregate_file = v
            .get("aggregate")
            .and_then(|a| a.get("file"))
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow!("manifest missing 'aggregate.file'"))?
            .to_string();
        Ok(Manifest {
            block_rows: get_usize("block_rows")?,
            cols: get_usize("cols")?,
            tile: get_usize("tile")?,
            agg_fanin: get_usize("agg_fanin")?,
            compute,
            aggregate_file,
        })
    }
}

/// A compiled executable plus its expected input geometry.
pub struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Compiled {
    /// Execute with literal inputs; returns the single (tuple-unwrapped)
    /// output as an f32 vector.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple outputs.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Loads + compiles all artifacts on one PJRT CPU client.
///
/// One `ArtifactStore` per worker thread: the underlying client is not
/// `Sync`, and per-thread stores keep task execution embarrassingly
/// parallel (the paper's executor cores).
pub struct ArtifactStore {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compute: HashMap<u32, Compiled>,
    aggregate: Compiled,
    dir: PathBuf,
}

impl ArtifactStore {
    /// Compile every artifact in `dir` (expects `manifest.json`).
    pub fn load(dir: &Path) -> Result<ArtifactStore> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut compute = HashMap::new();
        for (k, file) in &manifest.compute {
            compute.insert(
                *k,
                compile_one(&client, &dir.join(file), &format!("compute_k{k}"))?,
            );
        }
        let aggregate = compile_one(&client, &dir.join(&manifest.aggregate_file), "aggregate")?;
        Ok(ArtifactStore {
            manifest,
            client,
            compute,
            aggregate,
            dir: dir.to_path_buf(),
        })
    }

    /// Default artifact directory: `$UWFQ_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("UWFQ_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Available op-count variants, ascending.
    pub fn variants(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.compute.keys().copied().collect();
        v.sort();
        v
    }

    /// The compute variant for op-count `k` (exact match required — the
    /// workload layer only requests compiled variants).
    pub fn compute(&self, k: u32) -> Result<&Compiled> {
        self.compute
            .get(&k)
            .ok_or_else(|| anyhow!("no compute artifact for k={k}; have {:?}", self.variants()))
    }

    /// Run the compute artifact on one (rows × cols) row-major block.
    pub fn run_compute_block(&self, k: u32, block: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(
            block.len() == m.block_rows * m.cols,
            "block has {} values, expected {}",
            block.len(),
            m.block_rows * m.cols
        );
        let x = xla::Literal::vec1(block).reshape(&[m.block_rows as i64, m.cols as i64])?;
        self.compute(k)?.run(&[x])
    }

    /// Run the aggregate artifact over per-task partials.
    ///
    /// `partials` is a list of (2×cols) [sum; sumsq] vectors with their
    /// row counts; zero-padded to the artifact fan-in, chunked if longer.
    /// Returns the (2×cols) [mean; var] result.
    pub fn run_aggregate(&self, partials: &[(Vec<f32>, f32)]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(!partials.is_empty(), "no partials to aggregate");
        let width = 2 * m.cols;
        for (p, _) in partials {
            anyhow::ensure!(p.len() == width, "partial has wrong width");
        }
        // Chunk over fan-in: fold chunk results back in as synthetic
        // partials (mean/var → sum/sumsq requires the count, which we
        // track as the chunk's total rows).
        let mut items: Vec<(Vec<f32>, f32)> = partials.to_vec();
        loop {
            let take = items.len().min(m.agg_fanin);
            let chunk: Vec<(Vec<f32>, f32)> = items.drain(..take).collect();
            let total_rows: f32 = chunk.iter().map(|c| c.1).sum();
            let mut flat = vec![0f32; m.agg_fanin * width];
            let mut counts = vec![0f32; m.agg_fanin];
            for (i, (p, n)) in chunk.iter().enumerate() {
                flat[i * width..(i + 1) * width].copy_from_slice(p);
                counts[i] = *n;
            }
            let p = xla::Literal::vec1(&flat).reshape(&[
                m.agg_fanin as i64,
                2,
                m.cols as i64,
            ])?;
            let c = xla::Literal::vec1(&counts).reshape(&[m.agg_fanin as i64])?;
            let out = self.aggregate.run(&[p, c])?; // [mean; var]
            if items.is_empty() {
                return Ok(out);
            }
            // Convert [mean; var] back to [sum; sumsq] for re-folding.
            let mut back = vec![0f32; width];
            for j in 0..m.cols {
                let mean = out[j];
                let var = out[m.cols + j];
                back[j] = mean * total_rows;
                back[m.cols + j] = (var + mean * mean) * total_rows;
            }
            items.insert(0, (back, total_rows));
        }
    }
}

fn compile_one(client: &xla::PjRtClient, path: &Path, name: &str) -> Result<Compiled> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
    )
    .with_context(|| format!("loading HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .with_context(|| format!("compiling {name}"))?;
    Ok(Compiled {
        exe,
        name: name.to_string(),
    })
}

// Tests live in rust/tests/runtime_roundtrip.rs (they need built
// artifacts); manifest parsing is unit-tested here.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_sample() {
        let dir = std::env::temp_dir().join("uwfq_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
  "block_rows": 4096, "cols": 8, "tile": 512, "agg_fanin": 32,
  "compute": [{"k": 1, "file": "c1.hlo.txt"}, {"k": 4, "file": "c4.hlo.txt"}],
  "aggregate": {"file": "agg.hlo.txt"}
}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.block_rows, 4096);
        assert_eq!(m.compute, vec![(1, "c1.hlo.txt".into()), (4, "c4.hlo.txt".into())]);
        assert_eq!(m.aggregate_file, "agg.hlo.txt");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_missing_fields_error() {
        let dir = std::env::temp_dir().join("uwfq_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"cols": 8}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_missing_file_error() {
        let dir = std::env::temp_dir().join("uwfq_manifest_none");
        std::fs::create_dir_all(&dir).ok();
        std::fs::remove_file(dir.join("manifest.json")).ok();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
