//! xla/PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place Python's output crosses into Rust, and it
//! happens once at startup: `manifest.json` → `HloModuleProto::from_text_file`
//! → `client.compile` → reusable [`Compiled`] executables. The request
//! path (task execution) only calls [`Compiled::run`].

pub mod artifacts;

pub use artifacts::{ArtifactStore, Compiled, Manifest};
