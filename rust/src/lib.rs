//! # uwfq — User Weighted Fair Queuing for multi-user Spark-like analytics
//!
//! Reproduction of *"Balancing Fairness and Performance in Multi-User Spark
//! Workloads with Dynamic Scheduling"* (Kažemaks et al., 2025): a
//! long-running, multi-user batch analytics engine with pluggable fair
//! schedulers and runtime-aware partitioning.
//!
//! The crate is organized as the paper's system diagram (Fig. 1/2):
//!
//! * [`core`] — the Spark-like substrate: jobs → DAG of stages → tasks →
//!   pools → task scheduler → executor cores.
//! * [`sched`] — scheduling policies: FIFO, Fair, UJF, CFQ and the paper's
//!   **UWFQ** (2-level virtual time, Algorithms 1–3, grace-period revival).
//! * [`partition`] — input partitioning: Spark's size-based default, the
//!   paper's **runtime (ATR) partitioning** (§3.2), and AQE coalescing with
//!   the runtime-derived minimum-partition override (§4.1.2).
//! * [`estimate`] — stage runtime estimators (perfect oracle + noisy).
//! * [`fault`] — deterministic fault injection: seeded task-failure /
//!   straggler / core-crash schedules ([`fault::FaultPlan`]), retry +
//!   speculation + blacklist recovery machinery in the engine, and the
//!   goodput-vs-waste ledger ([`fault::FaultStats`]).
//! * [`sim`] — a discrete-event cluster simulator (the DAS-5 testbed
//!   substitute) driving the same scheduler core as the real backend.
//! * [`exec`] — the real execution backend: a thread-per-core pool where
//!   every task executes the AOT-compiled analytics kernel via PJRT.
//! * [`runtime`] — the xla/PJRT artifact loader (`ArtifactStore`).
//! * [`data`] — deterministic synthetic trip-record blocks (NYC TLC
//!   stand-in).
//! * [`workload`] — the **scenario registry**
//!   ([`workload::registry`]): every workload — the paper's micro
//!   scenarios 1–2 (§5.2.1), the Google-trace macro workload (§5.3),
//!   streaming trace replay over real trace files
//!   ([`workload::traceio`]: chunked reads, one-pass §5.3 shaping,
//!   O(warmup + in-flight) state), CSV traces, the million-job scale
//!   workload, and the `bursty` / `heavytail` / `diurnal` stress
//!   scenarios — is defined once as a named entry with a typed
//!   parameter schema and a lazy [`workload::JobStream`] constructor;
//!   the materialized form is the registry's generic `collect()`
//!   adapter.
//! * [`metrics`] — response times, slowdowns, DVR/DSR (Eqs. 1–3), CDFs;
//!   plus bounded-memory streaming accumulators (P² quantiles, log-bin
//!   ECDF, per-user aggregates) for O(users)-memory runs.
//! * [`bench`] — the experiment harness regenerating every table and figure.
//! * [`sweep`] — the parallel sweep engine: deterministic multi-core
//!   execution of the benchmark grid (byte-identical to sequential).
//! * [`util`] — offline substrates: deterministic RNG, samplers, JSON/CSV
//!   writers, a bench harness and a property-testing kit (no external crates
//!   besides `xla`/`anyhow` are available in this environment).
//!
//! Python/JAX/Pallas exist only at build time (`make artifacts`); the
//! binary is self-contained once `artifacts/` is built.

// Style lints the codebase consciously deviates from (CI runs clippy
// with `-D warnings`): params structs are built by mutating a default,
// and several paper-shaped constructors take the paper's full knob list.
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::too_many_arguments)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod core;
pub mod data;
pub mod estimate;
pub mod exec;
pub mod fault;
pub mod metrics;
pub mod partition;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sweep;
pub mod util;
pub mod workload;

/// Microsecond-resolution engine clock (simulated or wall).
pub type TimeUs = u64;

/// Seconds as f64 — the unit of virtual time and slot-times.
pub fn us_to_s(us: TimeUs) -> f64 {
    us as f64 / 1e6
}

/// Seconds → microseconds (saturating at 0 for negatives).
pub fn s_to_us(s: f64) -> TimeUs {
    if s <= 0.0 {
        0
    } else {
        (s * 1e6).round() as TimeUs
    }
}

pub type UserId = u32;
pub type JobId = u64;
pub type StageId = u64;
pub type TaskId = u64;
