//! Deadline-violation fairness metrics (paper §5.1.1, Eqs. 1–3).
//!
//! Since no "true" UJF scheduler exists on real hardware, the paper runs a
//! practical UJF scheduler on the same workload and uses its execution
//! trace as the reference. For each job:
//!
//! `r_i = (T_end,target(i) − T_end,UJF(i)) / RT_UJF(i)`          (Eq. 1)
//!
//! `DVR = Σ max(0, r_i) / #violations`, `DSR = Σ max(0, −r_i) / #slacks`
//! (Eqs. 2–3). As printed, Eq. 2's denominator indicator is `r_i > 1`
//! while the "Violation #" column clearly counts `r_i > 0`; we default to
//! the `r_i > 0` reading (the mean of incurred proportional violations,
//! as the prose says) and expose the literal reading as an option.

use std::collections::HashMap;

use super::report::RunMetrics;
use crate::JobId;

/// Which jobs count in the DVR denominator (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DvrDenominator {
    /// `|{r_i > 0}|` — the reading consistent with the Violation # column.
    GreaterThanZero,
    /// `|{r_i > 1}|` — Eq. 2 as literally printed.
    GreaterThanOne,
}

#[derive(Clone, Debug)]
pub struct FairnessMetrics {
    pub dvr: f64,
    pub violations: usize,
    pub dsr: f64,
    pub slacks: usize,
    /// Per-job proportional violation `r_i` (Fig. 7 input), keyed by job.
    pub r: HashMap<JobId, f64>,
}

/// Compute DVR/DSR of `target` against the `ujf` reference run of the
/// same workload. Jobs are matched by job id via a sort-merge join: both
/// runs submit the same workload through the same engine, so ids align
/// and completion order is already nearly id-sorted — the sorts are
/// branch-predictable and the merge is linear, replacing the former
/// HashMap build-and-probe round-trip. Accumulating in id order also
/// makes the float sums independent of hash iteration order.
pub fn fairness_vs_ujf(
    target: &RunMetrics,
    ujf: &RunMetrics,
    denom: DvrDenominator,
) -> FairnessMetrics {
    let mut tgt: Vec<(JobId, f64)> = target.outcomes.iter().map(|o| (o.job, o.finish_s)).collect();
    let mut reference: Vec<(JobId, f64, f64)> = ujf
        .outcomes
        .iter()
        .map(|o| (o.job, o.finish_s, o.rt))
        .collect();
    tgt.sort_unstable_by_key(|&(job, _)| job);
    reference.sort_unstable_by_key(|&(job, _, _)| job);

    // Merge: engine job ids are unique within a run, so each id matches
    // at most once.
    let mut rs: Vec<(JobId, f64)> = Vec::with_capacity(tgt.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < tgt.len() && j < reference.len() {
        let (tj, t_end) = tgt[i];
        let (uj, ujf_end, ujf_rt) = reference[j];
        match tj.cmp(&uj) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                if ujf_rt > 0.0 {
                    rs.push((tj, (t_end - ujf_end) / ujf_rt));
                }
                i += 1;
                j += 1;
            }
        }
    }

    let violations = rs.iter().filter(|&&(_, ri)| ri > 0.0).count();
    let slacks = rs.iter().filter(|&&(_, ri)| ri <= 0.0).count();
    let dvr_count = match denom {
        DvrDenominator::GreaterThanZero => violations,
        DvrDenominator::GreaterThanOne => rs.iter().filter(|&&(_, ri)| ri > 1.0).count(),
    };
    let viol_sum: f64 = rs.iter().map(|&(_, ri)| ri.max(0.0)).sum();
    let slack_sum: f64 = rs.iter().map(|&(_, ri)| (-ri).max(0.0)).sum();
    let r: HashMap<JobId, f64> = rs.into_iter().collect();

    FairnessMetrics {
        dvr: if dvr_count > 0 {
            viol_sum / dvr_count as f64
        } else {
            0.0
        },
        violations,
        dsr: if slacks > 0 {
            slack_sum / slacks as f64
        } else {
            0.0
        },
        slacks,
        r,
    }
}

/// Per-user proportional violation of mean response times (Fig. 7): the
/// same `r` formula applied to user-average RTs instead of job end times.
pub fn user_violations_vs_ujf(target: &RunMetrics, ujf: &RunMetrics) -> Vec<(crate::UserId, f64)> {
    let mut users: Vec<crate::UserId> = target.outcomes.iter().map(|o| o.user).collect();
    users.sort();
    users.dedup();
    let mut out = Vec::new();
    for user in users {
        let t = target.mean_rt_of_user(user);
        let u = ujf.mean_rt_of_user(user);
        if u > 0.0 {
            out.push((user, (t - u) / u));
        }
    }
    out.sort_by_key(|&(u, _)| u);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::report::JobOutcome;
    use std::collections::HashMap as Map;

    fn run(label: &str, ends: &[(u64, f64, f64)]) -> RunMetrics {
        // (job, finish, rt)
        RunMetrics {
            label: label.into(),
            outcomes: ends
                .iter()
                .map(|&(job, finish_s, rt)| JobOutcome {
                    job,
                    user: job as u32 % 3,
                    name: format!("j{job}").into(),
                    submit_s: finish_s - rt,
                    finish_s,
                    slot_time: rt,
                    rt,
                    idle_rt: 1.0,
                })
                .collect(),
            makespan_s: 10.0,
            utilization: 1.0,
            user_class: Map::new(),
        }
    }

    #[test]
    fn dvr_dsr_basic() {
        let ujf = run("UJF", &[(1, 10.0, 5.0), (2, 20.0, 10.0), (3, 8.0, 4.0)]);
        // job1 ends 2.5s late (r=0.5), job2 5s early (r=-0.5), job3 equal.
        let tgt = run("X", &[(1, 12.5, 5.0), (2, 15.0, 10.0), (3, 8.0, 4.0)]);
        let f = fairness_vs_ujf(&tgt, &ujf, DvrDenominator::GreaterThanZero);
        assert_eq!(f.violations, 1);
        assert_eq!(f.slacks, 2); // r=0 counts as slack side (r_i <= 0)
        assert!((f.dvr - 0.5).abs() < 1e-9);
        assert!((f.dsr - 0.25).abs() < 1e-9);
        assert!((f.r[&1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn literal_denominator_reading() {
        let ujf = run("UJF", &[(1, 10.0, 5.0), (2, 10.0, 5.0)]);
        let tgt = run("X", &[(1, 20.0, 5.0), (2, 12.0, 5.0)]); // r = 2.0, 0.4
        let f0 = fairness_vs_ujf(&tgt, &ujf, DvrDenominator::GreaterThanZero);
        let f1 = fairness_vs_ujf(&tgt, &ujf, DvrDenominator::GreaterThanOne);
        assert!((f0.dvr - 1.2).abs() < 1e-9); // 2.4 / 2
        assert!((f1.dvr - 2.4).abs() < 1e-9); // 2.4 / 1
        assert_eq!(f0.violations, f1.violations);
    }

    #[test]
    fn identical_runs_are_clean() {
        let ujf = run("UJF", &[(1, 10.0, 5.0), (2, 20.0, 10.0)]);
        let f = fairness_vs_ujf(&ujf.clone(), &ujf, DvrDenominator::GreaterThanZero);
        assert_eq!(f.violations, 0);
        assert_eq!(f.dvr, 0.0);
        assert_eq!(f.slacks, 2);
        assert_eq!(f.dsr, 0.0);
    }

    #[test]
    fn unmatched_jobs_skipped() {
        let ujf = run("UJF", &[(1, 10.0, 5.0)]);
        let tgt = run("X", &[(1, 10.0, 5.0), (99, 4.0, 2.0)]);
        let f = fairness_vs_ujf(&tgt, &ujf, DvrDenominator::GreaterThanZero);
        assert_eq!(f.r.len(), 1);
    }

    #[test]
    fn user_level_violations() {
        let ujf = run("UJF", &[(1, 10.0, 4.0), (2, 10.0, 4.0)]);
        let tgt = run("X", &[(1, 10.0, 6.0), (2, 10.0, 2.0)]);
        let v = user_violations_vs_ujf(&tgt, &ujf);
        // user 1 = job1 (1%3=1), user 2 = job2: +0.5 and -0.5.
        let m: Map<u32, f64> = v.into_iter().collect();
        assert!((m[&1] - 0.5).abs() < 1e-9);
        assert!((m[&2] + 0.5).abs() < 1e-9);
    }
}

/// Jain's fairness index over per-user mean response times:
/// `J = (Σx)² / (n·Σx²)` ∈ (0, 1], 1 = perfectly equal.
///
/// Descriptive metric, NOT a ranking of scheduler fairness: user-job
/// fairness equalizes *resource shares*, which deliberately makes
/// response times *unequal* when users differ in demand (an infrequent
/// user's jobs should be much faster than a flooder's). Use it to
/// quantify RT dispersion across users alongside DVR/DSR, e.g. in
/// scenario 2 where all users have identical demand and equal shares do
/// imply similar RTs.
pub fn jain_index_user_rt(m: &RunMetrics) -> f64 {
    let mut users: Vec<crate::UserId> = m.outcomes.iter().map(|o| o.user).collect();
    users.sort();
    users.dedup();
    let xs: Vec<f64> = users
        .iter()
        .map(|&u| m.mean_rt_of_user(u))
        .filter(|&x| x > 0.0)
        .collect();
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    sum * sum / (xs.len() as f64 * sumsq)
}

#[cfg(test)]
mod jain_tests {
    use super::*;
    use crate::config::Config;
    use crate::sched::PolicyKind;
    use crate::workload::test_scenario2;

    #[test]
    fn jain_bounds_and_equality() {
        let ujf = {
            let w = test_scenario2(1, 4, 0.5);
            crate::bench::run_one(&Config::default().with_cores(8), &w)
        };
        let j = jain_index_user_rt(&ujf);
        assert!(j > 0.0 && j <= 1.0 + 1e-12, "jain {j}");
    }

    #[test]
    fn jain_detects_rt_dispersion() {
        // Constructed runs: equal per-user RTs → J = 1; one user 10×
        // slower than three equal users → J drops well below 1.
        use crate::metrics::report::JobOutcome;
        let mk = |rts: &[f64]| RunMetrics {
            label: "t".into(),
            outcomes: rts
                .iter()
                .enumerate()
                .map(|(i, &rt)| JobOutcome {
                    job: i as u64,
                    user: i as u32,
                    name: format!("j{i}").into(),
                    submit_s: 0.0,
                    finish_s: rt,
                    slot_time: rt,
                    rt,
                    idle_rt: 1.0,
                })
                .collect(),
            makespan_s: 10.0,
            utilization: 1.0,
            user_class: std::collections::HashMap::new(),
        };
        assert!((jain_index_user_rt(&mk(&[2.0, 2.0, 2.0, 2.0])) - 1.0).abs() < 1e-12);
        let skewed = jain_index_user_rt(&mk(&[1.0, 1.0, 1.0, 10.0]));
        assert!(skewed < 0.45, "jain {skewed}");
    }

    #[test]
    fn scenario2_equal_demand_users_have_similar_rts_under_uwfq() {
        // With identical per-user demand (scenario 2), equal shares do
        // imply similar per-user RTs: UWFQ's Jain index stays high.
        let w = test_scenario2(1, 6, 0.5);
        let j = jain_index_user_rt(&crate::bench::run_one(
            &Config::default().with_cores(8).with_policy(PolicyKind::Uwfq),
            &w,
        ));
        assert!(j > 0.8, "jain {j}");
    }
}
