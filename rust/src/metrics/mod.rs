//! Evaluation metrics (paper §5.1.1): response time, slowdown, and the
//! deadline-violation / slack fairness metrics computed against a UJF
//! reference execution.
//!
//! Two aggregation paths share the definitions: [`report::RunMetrics`]
//! retains every [`JobOutcome`] (the exact paper-table path) and
//! [`streaming`] folds completions into O(users + bins) accumulator
//! state (the `uwfq scale` million-job path).

pub mod cdf;
pub mod fairness;
pub mod report;
pub mod streaming;

pub use fairness::{FairnessMetrics, DvrDenominator};
pub use report::{JobOutcome, RunMetrics};
pub use streaming::{P2Quantile, StreamStats, StreamingEcdf, StreamingRunMetrics};
