//! Evaluation metrics (paper §5.1.1): response time, slowdown, and the
//! deadline-violation / slack fairness metrics computed against a UJF
//! reference execution.

pub mod cdf;
pub mod fairness;
pub mod report;

pub use fairness::{FairnessMetrics, DvrDenominator};
pub use report::{JobOutcome, RunMetrics};
