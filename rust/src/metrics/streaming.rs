//! Bounded-memory streaming metrics — O(users + bins) accumulators for
//! million-job runs.
//!
//! The exact path ([`super::report::RunMetrics`]) retains every
//! [`crate::metrics::JobOutcome`], which is what lets it compute the
//! paper tables precisely — and what caps run size at available memory.
//! This module provides the streaming twins used by `uwfq scale`:
//!
//! * [`StreamStats`] — count / mean / min / max in O(1).
//! * [`P2Quantile`] — the P² online quantile estimator (Jain & Chlamtac,
//!   CACM 1985): five markers per tracked quantile, O(1) per
//!   observation, no samples retained.
//! * [`StreamingEcdf`] — a fixed-bin (log-spaced) streaming ECDF: CDF
//!   queries, robust quantile inversion with error bounded by the bin
//!   resolution, and CSV-ready points.
//! * [`StreamingRunMetrics`] — a [`crate::sim::CompletionSink`] folding
//!   each finished job into the above plus incremental per-user
//!   aggregates (mean RT / slowdown per user, Jain fairness index) — the
//!   streaming counterpart of the fairness metrics' per-user inputs.
//!
//! Accuracy contract (asserted in CI, see `tests/scale_accuracy.rs` and
//! the unit tests below): on ≥50k-sample heavy-tailed workloads the
//! ECDF-inverted p50/p95/p99 are within 8 % relative error of the exact
//! quantiles (bin resolution ≈3.2 % with the default 512 log bins over
//! [1 ms, 10 000 s]), the P² estimates within 15 % (p50/p95) / 25 %
//! (p99), and the ECDF evaluated at its own bin edges within 0.02 of the
//! exact empirical CDF.

use std::collections::HashMap;
use std::sync::Arc;

use crate::core::dag::CompletedJob;
use crate::sim::CompletionSink;
use crate::util::stats;
use crate::UserId;

// ---------------------------------------------------------------------------
// Scalar accumulators
// ---------------------------------------------------------------------------

/// Count / sum / min / max in O(1) state.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl StreamStats {
    pub fn observe(&mut self, x: f64) {
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.count += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact merge of two accumulators: the result is what a single
    /// accumulator would hold had it observed both sample sets (count and
    /// sum are associative; min/max take care of empty sides).
    pub fn merge(&mut self, other: &StreamStats) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

// ---------------------------------------------------------------------------
// P² online quantile estimator
// ---------------------------------------------------------------------------

/// The P² algorithm (Jain & Chlamtac 1985): tracks one quantile with five
/// markers whose heights approximate the quantile curve by piecewise
/// parabolas. O(1) memory and time per observation.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights.
    q: [f64; 5],
    /// Actual marker positions (1-based sample counts).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: u64,
    /// First five observations (exact until the markers initialize).
    init: Vec<f64>,
}

impl P2Quantile {
    /// The running-median estimator (p = 0.5) — the one-pass trace
    /// shaper's runtime-tail filter statistic
    /// ([`crate::workload::traceio::shaping`]).
    pub fn median() -> P2Quantile {
        P2Quantile::new(0.5)
    }

    pub fn new(p: f64) -> P2Quantile {
        assert!(p > 0.0 && p < 1.0, "quantile must be in (0, 1)");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [0.0; 5],
            np: [0.0; 5],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            init: Vec::with_capacity(5),
        }
    }

    pub fn observe(&mut self, x: f64) {
        self.count += 1;
        if self.init.len() < 5 {
            self.init.push(x);
            if self.init.len() == 5 {
                self.init.sort_by(|a, b| a.partial_cmp(b).expect("finite sample"));
                for i in 0..5 {
                    self.q[i] = self.init[i];
                    self.n[i] = (i + 1) as f64;
                }
                let p = self.p;
                self.np = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0];
            }
            return;
        }

        // Cell k: q[k] <= x < q[k+1], extending the extremes as needed.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 1..4 {
                if x >= self.q[i] {
                    k = i;
                }
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Move interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let candidate = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < candidate && candidate < self.q[i + 1] {
                    candidate
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate. Exact while fewer than five samples have been
    /// seen.
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.count < 5 {
            let mut v = self.init.clone();
            v.sort_by(|a, b| a.partial_cmp(b).expect("finite sample"));
            return stats::percentile(&v, self.p * 100.0);
        }
        self.q[2]
    }

    pub fn count(&self) -> u64 {
        self.count
    }
}

// ---------------------------------------------------------------------------
// Fixed-bin streaming ECDF
// ---------------------------------------------------------------------------

/// Streaming empirical CDF over fixed log-spaced bins covering
/// `[lo, hi]`. Values at or below `lo` are counted exactly at the low
/// edge (visible at `cdf_at(lo)`), values above `hi` clamp into the last
/// bin, so total mass is always accounted. Log spacing keeps *relative*
/// value resolution constant — `(hi/lo)^(1/bins) − 1` per bin (≈3.2 % at
/// the 512-bin default over seven decades) — which is the right shape
/// for response-time distributions.
#[derive(Clone, Debug)]
pub struct StreamingEcdf {
    lo: f64,
    hi: f64,
    /// Mass at or below the low edge, kept out of the interior bins so
    /// `cdf_at(lo)` and `quantile` report it exactly at `lo` rather than
    /// smearing it to bin 0's upper edge.
    at_lo: u64,
    counts: Vec<u64>,
    total: u64,
}

impl StreamingEcdf {
    /// The default window for response-time metrics: 1 ms .. 10 000 s.
    pub fn response_times() -> StreamingEcdf {
        StreamingEcdf::new(1e-3, 1e4, 512)
    }

    pub fn new(lo: f64, hi: f64, bins: usize) -> StreamingEcdf {
        assert!(lo > 0.0 && hi > lo && bins > 0);
        StreamingEcdf {
            lo,
            hi,
            at_lo: 0,
            counts: vec![0; bins],
            total: 0,
        }
    }

    fn bin_of(&self, x: f64) -> usize {
        if !(x > self.lo) {
            return 0;
        }
        if x >= self.hi {
            return self.counts.len() - 1;
        }
        let frac = (x / self.lo).ln() / (self.hi / self.lo).ln();
        ((frac * self.counts.len() as f64) as usize).min(self.counts.len() - 1)
    }

    /// Number of bins whose upper edge lies at or below `x` — the bins
    /// whose whole mass is certainly ≤ x. Robust to fp rounding when `x`
    /// is exactly a bin edge (nudged up by well under one bin width).
    fn full_bins_below(&self, x: f64) -> usize {
        if x >= self.hi {
            return self.counts.len();
        }
        if !(x > self.lo) {
            return 0;
        }
        let frac = (x / self.lo).ln() / (self.hi / self.lo).ln();
        ((frac * self.counts.len() as f64 + 1e-9) as usize).min(self.counts.len())
    }

    /// Upper value edge of bin `b` (the value the bin's mass reports as).
    pub fn upper_edge(&self, b: usize) -> f64 {
        self.lo * (self.hi / self.lo).powf((b + 1) as f64 / self.counts.len() as f64)
    }

    pub fn observe(&mut self, x: f64) {
        if !(x > self.lo) {
            self.at_lo += 1;
        } else {
            let b = self.bin_of(x);
            self.counts[b] += 1;
        }
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    /// Fraction of observed mass wholly at or below `x`: exact at `lo`
    /// (where clamped low-edge mass lives) and at bin upper edges, an
    /// underestimate by at most one bin's mass for interior points (see
    /// [`StreamingEcdf::max_bin_mass`]). `x < lo` reports 0, `x ≥ hi`
    /// always reports 1.
    pub fn cdf_at(&self, x: f64) -> f64 {
        if self.total == 0 || x < self.lo {
            return 0.0;
        }
        let k = self.full_bins_below(x);
        let cum: u64 = self.at_lo + self.counts[..k].iter().sum::<u64>();
        cum as f64 / self.total as f64
    }

    /// Quantile by CDF inversion: the upper edge of the first bin where
    /// the cumulative mass reaches `p`. Error bounded by one bin's
    /// relative width (plus clamping at the window edges).
    pub fn quantile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        if target <= self.at_lo {
            return self.lo;
        }
        let mut cum = self.at_lo;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return self.upper_edge(b);
            }
        }
        self.hi
    }

    /// Non-empty bins as (upper edge, cumulative fraction) — CSV-ready,
    /// same long format as [`super::cdf::CdfSeries`]. Low-edge mass, if
    /// any, leads as an exact point at `lo`.
    pub fn points(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut cum = self.at_lo;
        if self.at_lo > 0 {
            out.push((self.lo, cum as f64 / self.total.max(1) as f64));
        }
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if c > 0 {
                out.push((self.upper_edge(b), cum as f64 / self.total.max(1) as f64));
            }
        }
        out
    }

    /// Exact merge: bin-wise count sum. Both histograms must share the
    /// same window and bin count — the merged CDF is then identical to
    /// one built from the union of the two sample streams (binning is
    /// per-sample and independent of arrival order).
    pub fn merge(&mut self, other: &StreamingEcdf) {
        assert!(
            self.lo == other.lo && self.hi == other.hi && self.counts.len() == other.counts.len(),
            "cannot merge ECDFs with different windows/bins: [{}, {}]x{} vs [{}, {}]x{}",
            self.lo,
            self.hi,
            self.counts.len(),
            other.lo,
            other.hi,
            other.counts.len()
        );
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.at_lo += other.at_lo;
        self.total += other.total;
    }

    /// Largest single-bin mass fraction — the worst-case CDF error at an
    /// arbitrary (non-edge) query point.
    pub fn max_bin_mass(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.counts.iter().copied().max().unwrap_or(0) as f64 / self.total as f64
    }
}

// ---------------------------------------------------------------------------
// Per-user incremental aggregates
// ---------------------------------------------------------------------------

/// One user's incremental aggregate.
#[derive(Clone, Debug, Default)]
pub struct UserAccum {
    pub jobs: u64,
    pub rt_sum: f64,
    pub slowdown_sum: f64,
}

impl UserAccum {
    pub fn mean_rt(&self) -> f64 {
        if self.jobs == 0 {
            0.0
        } else {
            self.rt_sum / self.jobs as f64
        }
    }
}

// ---------------------------------------------------------------------------
// The streaming run-metrics sink
// ---------------------------------------------------------------------------

/// Bounded-memory run metrics: a [`CompletionSink`] whose resident state
/// is O(users + quantile markers + ECDF bins) — independent of the number
/// of jobs streamed through it. The streaming counterpart of
/// [`super::report::RunMetrics`].
pub struct StreamingRunMetrics {
    pub label: String,
    /// Idle response time per interned job-kind name (slowdown
    /// denominators; O(distinct templates)).
    idle_rt: HashMap<Arc<str>, f64>,
    pub rt: StreamStats,
    pub slowdown: StreamStats,
    rt_p50: P2Quantile,
    rt_p95: P2Quantile,
    rt_p99: P2Quantile,
    pub rt_ecdf: StreamingEcdf,
    per_user: HashMap<UserId, UserAccum>,
    /// Set once another sink has been folded in. P² markers cannot be
    /// merged (the algorithm is order-sensitive and keeps no samples), so
    /// a merged sink answers quantile queries from the merged ECDF — which
    /// *is* exactly mergeable — instead of its now-partial P² state.
    merged: bool,
}

impl StreamingRunMetrics {
    pub fn new(label: &str, idle_rt: HashMap<Arc<str>, f64>) -> StreamingRunMetrics {
        StreamingRunMetrics {
            label: label.to_string(),
            idle_rt,
            rt: StreamStats::default(),
            slowdown: StreamStats::default(),
            rt_p50: P2Quantile::new(0.50),
            rt_p95: P2Quantile::new(0.95),
            rt_p99: P2Quantile::new(0.99),
            rt_ecdf: StreamingEcdf::response_times(),
            per_user: HashMap::new(),
            merged: false,
        }
    }

    /// Fold another sink's observations into this one — the reduction step
    /// for shard-local metric sinks. Counts, sums, extrema, the ECDF, and
    /// per-user aggregates merge *exactly* (each is a plain sum, so the
    /// result equals a single sink fed the union of both completion
    /// streams in any order). The P² marker states are NOT mergeable;
    /// after a merge [`Self::rt_quantile_p2`] transparently answers from
    /// the merged ECDF (error bounded by bin resolution, ≈3.2 % relative).
    pub fn merge_from(&mut self, other: &StreamingRunMetrics) {
        self.rt.merge(&other.rt);
        self.slowdown.merge(&other.slowdown);
        self.rt_ecdf.merge(&other.rt_ecdf);
        for (&u, acc) in &other.per_user {
            let e = self.per_user.entry(u).or_default();
            e.jobs += acc.jobs;
            e.rt_sum += acc.rt_sum;
            e.slowdown_sum += acc.slowdown_sum;
        }
        for (name, &idle) in &other.idle_rt {
            self.idle_rt.entry(name.clone()).or_insert(idle);
        }
        self.merged = true;
    }

    /// Whether this sink is a merge of several shard-local sinks (and thus
    /// answers P² quantile queries from the ECDF).
    pub fn is_merged(&self) -> bool {
        self.merged
    }

    pub fn jobs(&self) -> u64 {
        self.rt.count
    }

    pub fn mean_rt(&self) -> f64 {
        self.rt.mean()
    }

    pub fn mean_slowdown(&self) -> f64 {
        self.slowdown.mean()
    }

    /// P² response-time quantile estimates for p in {0.50, 0.95, 0.99}.
    /// On a merged sink (see [`Self::merge_from`]) this falls back to the
    /// ECDF inversion — P² marker states are not mergeable.
    pub fn rt_quantile_p2(&self, p: f64) -> f64 {
        if self.merged {
            return self.rt_ecdf.quantile(p);
        }
        if (p - 0.50).abs() < 1e-12 {
            self.rt_p50.value()
        } else if (p - 0.95).abs() < 1e-12 {
            self.rt_p95.value()
        } else if (p - 0.99).abs() < 1e-12 {
            self.rt_p99.value()
        } else {
            panic!("streaming quantiles track p50/p95/p99 only, got {p}")
        }
    }

    /// ECDF-inverted response-time quantile (error bounded by bin
    /// resolution; the robust estimate `uwfq scale` asserts on).
    pub fn rt_quantile_ecdf(&self, p: f64) -> f64 {
        self.rt_ecdf.quantile(p)
    }

    pub fn users(&self) -> Vec<UserId> {
        let mut u: Vec<UserId> = self.per_user.keys().copied().collect();
        u.sort_unstable();
        u
    }

    pub fn user(&self, u: UserId) -> Option<&UserAccum> {
        self.per_user.get(&u)
    }

    pub fn user_count(&self) -> usize {
        self.per_user.len()
    }

    /// Jain fairness index over per-user mean response times — the same
    /// definition (and caveats) as
    /// [`super::fairness::jain_index_user_rt`], computed from the
    /// incremental aggregates. Deterministic: accumulated in sorted user
    /// order.
    pub fn jain_index_user_rt(&self) -> f64 {
        let users = self.users();
        let xs: Vec<f64> = users
            .iter()
            .filter_map(|u| {
                let m = self.per_user[u].mean_rt();
                (m > 0.0).then_some(m)
            })
            .collect();
        if xs.is_empty() {
            return 1.0;
        }
        let sum: f64 = xs.iter().sum();
        let sumsq: f64 = xs.iter().map(|x| x * x).sum();
        sum * sum / (xs.len() as f64 * sumsq)
    }
}

impl CompletionSink for StreamingRunMetrics {
    fn job_completed(&mut self, c: CompletedJob) {
        let rt = c.response_time();
        self.rt.observe(rt);
        self.rt_p50.observe(rt);
        self.rt_p95.observe(rt);
        self.rt_p99.observe(rt);
        self.rt_ecdf.observe(rt);
        let idle = self.idle_rt.get(&c.name).copied().unwrap_or(0.0);
        let slowdown = if idle > 0.0 { rt / idle } else { 1.0 };
        self.slowdown.observe(slowdown);
        let acc = self.per_user.entry(c.user).or_default();
        acc.jobs += 1;
        acc.rt_sum += rt;
        acc.slowdown_sum += slowdown;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn stream_stats_basics() {
        let mut s = StreamStats::default();
        assert_eq!(s.mean(), 0.0);
        for x in [2.0, 4.0, 9.0] {
            s.observe(x);
        }
        assert_eq!(s.count, 3);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn p2_exact_below_five_samples() {
        let mut p = P2Quantile::new(0.5);
        p.observe(3.0);
        p.observe(1.0);
        assert!((p.value() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn p2_close_on_uniform() {
        // Uniform [0,1): P² should be within ~1–2 % absolute.
        let mut rng = Rng::new(7);
        let mut p50 = P2Quantile::new(0.5);
        let mut p95 = P2Quantile::new(0.95);
        for _ in 0..20_000 {
            let x = rng.f64();
            p50.observe(x);
            p95.observe(x);
        }
        assert!((p50.value() - 0.5).abs() < 0.02, "p50={}", p50.value());
        assert!((p95.value() - 0.95).abs() < 0.02, "p95={}", p95.value());
    }

    /// Samples from the gtrace job-size mixture (§5.3 generator shape):
    /// heavy-user lognormal(4.5, 1.1) with probability 0.4, light-user
    /// lognormal(2.6, 0.8) otherwise — heavy-tailed and bimodal-ish, the
    /// stress shape for streaming quantiles.
    fn gtrace_mixture(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                if rng.f64() < 0.4 {
                    rng.lognormal(4.5, 1.1)
                } else {
                    rng.lognormal(2.6, 0.8)
                }
            })
            .collect()
    }

    #[test]
    fn p2_within_documented_tolerance_on_50k_gtrace_mixture() {
        // The documented accuracy contract on the 50k-sample gtrace-shaped
        // distribution: p50/p95 within 15 %, p99 within 25 % relative.
        let xs = gtrace_mixture(50_000, 42);
        let mut p50 = P2Quantile::new(0.50);
        let mut p95 = P2Quantile::new(0.95);
        let mut p99 = P2Quantile::new(0.99);
        for &x in &xs {
            p50.observe(x);
            p95.observe(x);
            p99.observe(x);
        }
        let rel = |est: f64, exact: f64| (est - exact).abs() / exact;
        let e50 = crate::util::stats::percentile(&xs, 50.0);
        let e95 = crate::util::stats::percentile(&xs, 95.0);
        let e99 = crate::util::stats::percentile(&xs, 99.0);
        assert!(rel(p50.value(), e50) < 0.15, "p50 {} vs {}", p50.value(), e50);
        assert!(rel(p95.value(), e95) < 0.15, "p95 {} vs {}", p95.value(), e95);
        assert!(rel(p99.value(), e99) < 0.25, "p99 {} vs {}", p99.value(), e99);
    }

    #[test]
    fn ecdf_within_documented_tolerance_on_50k_gtrace_mixture() {
        let mut xs = gtrace_mixture(50_000, 9);
        let mut ecdf = StreamingEcdf::new(1e-2, 1e5, 512);
        for &x in &xs {
            ecdf.observe(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // At its own bin edges the streaming CDF matches the exact
        // empirical CDF to within fp/binning noise (documented ≤ 0.02).
        let exact_at = |v: f64| -> f64 {
            let idx = xs.partition_point(|&s| s <= v);
            idx as f64 / xs.len() as f64
        };
        let mut sup = 0.0f64;
        for b in 0..512 {
            let edge = ecdf.upper_edge(b);
            sup = sup.max((ecdf.cdf_at(edge) - exact_at(edge)).abs());
        }
        assert!(sup < 0.02, "sup CDF error at edges {sup}");
        // ECDF-inverted quantiles within one-bin relative resolution
        // (documented ≤ 8 %).
        for (p, pct) in [(0.50, 50.0), (0.95, 95.0), (0.99, 99.0)] {
            let exact = crate::util::stats::percentile(&xs, pct);
            let est = ecdf.quantile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.08, "p{pct} {est} vs {exact} (rel {rel})");
        }
    }

    #[test]
    fn ecdf_clamps_out_of_range_mass() {
        let mut e = StreamingEcdf::new(1.0, 100.0, 8);
        e.observe(0.001); // below lo → counted at the low edge
        e.observe(1e9); // above hi → last bin
        e.observe(10.0);
        assert_eq!(e.total(), 3);
        assert!((e.cdf_at(1e9) - 1.0).abs() < 1e-12);
        // Underflow mass sits exactly at the low edge, visible there and
        // at every point above it.
        assert!(e.cdf_at(e.upper_edge(0)) > 0.0);
        assert_eq!(e.cdf_at(0.5), 0.0);
        let pts = e.points();
        assert!(!pts.is_empty());
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
        assert!(e.max_bin_mass() >= 1.0 / 3.0);
    }

    #[test]
    fn ecdf_low_edge_mass_is_exact_at_lo() {
        // Regression: a sample at exactly `lo` used to land in bin 0 but
        // `cdf_at(lo)` summed zero full bins and reported 0.0, hiding it
        // until bin 0's upper edge. Low-edge mass must be visible at lo.
        let mut e = StreamingEcdf::new(1.0, 100.0, 8);
        e.observe(1.0);
        e.observe(1.0);
        e.observe(0.25); // below lo clamps to the same low-edge bucket
        e.observe(50.0);
        assert_eq!(e.total(), 4);
        assert!((e.cdf_at(1.0) - 0.75).abs() < 1e-12);
        assert_eq!(e.cdf_at(1.0 - 1e-9), 0.0);
        // Quantiles inside the low-edge mass invert to exactly lo, not
        // to bin 0's upper edge.
        assert_eq!(e.quantile(0.5), 1.0);
        assert_eq!(e.quantile(0.75), 1.0);
        assert!(e.quantile(1.0) > 1.0);
        // The low-edge point leads the CSV series at exactly lo.
        let pts = e.points();
        assert_eq!(pts[0], (1.0, 0.75));
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_exact_at_window_and_bin_edges() {
        let mut e = StreamingEcdf::new(1.0, 100.0, 8);
        let n = 64;
        for i in 0..n {
            // Spread strictly interior samples across the window.
            e.observe(1.0 + 99.0 * (i as f64 + 0.5) / n as f64);
        }
        // At hi the CDF is exactly 1 and the top quantile is exactly hi.
        assert_eq!(e.cdf_at(100.0), 1.0);
        assert_eq!(e.quantile(1.0), e.upper_edge(e.bins() - 1));
        // At every bin upper edge the CDF equals the cumulative bin mass
        // exactly (no interior-point underestimate).
        let mut cum = 0.0;
        for b in 0..e.bins() {
            let edge = e.upper_edge(b);
            let mass = e.cdf_at(edge) - cum;
            assert!(mass >= -1e-12, "bin {b} negative mass");
            cum = e.cdf_at(edge);
            // Edge-exactness: querying just below the edge must not see
            // this bin's mass; querying the edge must see all of it.
            if mass > 0.0 && b > 0 {
                assert!(e.cdf_at(edge * (1.0 - 1e-6)) < cum);
            }
        }
        assert!((cum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_merge_sums_low_edge_mass() {
        let mut a = StreamingEcdf::new(1.0, 100.0, 8);
        let mut b = StreamingEcdf::new(1.0, 100.0, 8);
        a.observe(1.0);
        a.observe(10.0);
        b.observe(0.5);
        b.observe(20.0);
        a.merge(&b);
        assert_eq!(a.total(), 4);
        assert!((a.cdf_at(1.0) - 0.5).abs() < 1e-12);
        assert_eq!(a.quantile(0.25), 1.0);
    }

    #[test]
    fn streaming_sink_matches_exact_aggregates() {
        // Feed a small synthetic completion list through the sink and
        // check count/mean/per-user/jain against the retained-path
        // formulas.
        let idle: HashMap<Arc<str>, f64> = [(Arc::from("t"), 2.0)].into_iter().collect();
        let mut sink = StreamingRunMetrics::new("X", idle);
        let rts = [2.0, 4.0, 6.0, 8.0];
        for (i, &rt) in rts.iter().enumerate() {
            sink.job_completed(CompletedJob {
                job: i as u64 + 1,
                user: (i % 2) as u32 + 1,
                name: Arc::from("t"),
                submit: 0,
                finish: crate::s_to_us(rt),
                slot_time: 1.0,
            });
        }
        assert_eq!(sink.jobs(), 4);
        assert!((sink.mean_rt() - 5.0).abs() < 1e-9);
        // slowdowns = rt / 2.0 → mean 2.5
        assert!((sink.mean_slowdown() - 2.5).abs() < 1e-9);
        assert_eq!(sink.users(), vec![1, 2]);
        // user 1 got rts {2, 6} → mean 4; user 2 got {4, 8} → mean 6.
        assert!((sink.user(1).unwrap().mean_rt() - 4.0).abs() < 1e-9);
        assert!((sink.user(2).unwrap().mean_rt() - 6.0).abs() < 1e-9);
        let jain = sink.jain_index_user_rt();
        // Jain of (4, 6): 100 / (2 * 52) ≈ 0.9615
        assert!((jain - 100.0 / 104.0).abs() < 1e-9);
        // Quantiles exact below 5 samples.
        assert!((sink.rt_quantile_p2(0.50) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn stream_stats_merge_is_exact_and_handles_empty_sides() {
        let mut a = StreamStats::default();
        let mut b = StreamStats::default();
        for x in [2.0, 9.0] {
            a.observe(x);
        }
        for x in [1.0, 4.0, 6.0] {
            b.observe(x);
        }
        let mut whole = StreamStats::default();
        for x in [2.0, 9.0, 1.0, 4.0, 6.0] {
            whole.observe(x);
        }
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.count, whole.count);
        assert!((m.sum - whole.sum).abs() < 1e-12);
        assert_eq!(m.min, whole.min);
        assert_eq!(m.max, whole.max);
        // Empty sides: empty←full copies, full←empty is a no-op.
        let mut e = StreamStats::default();
        e.merge(&a);
        assert_eq!((e.count, e.min, e.max), (a.count, a.min, a.max));
        let before = a.clone();
        a.merge(&StreamStats::default());
        assert_eq!((a.count, a.min, a.max), (before.count, before.min, before.max));
    }

    #[test]
    fn ecdf_merge_equals_union_stream() {
        let xs = gtrace_mixture(4_000, 11);
        let (left, right) = xs.split_at(1_500);
        let mut a = StreamingEcdf::response_times();
        let mut b = StreamingEcdf::response_times();
        let mut whole = StreamingEcdf::response_times();
        for &x in left {
            a.observe(x);
            whole.observe(x);
        }
        for &x in right {
            b.observe(x);
            whole.observe(x);
        }
        a.merge(&b);
        assert_eq!(a.total(), whole.total());
        for p in [0.1, 0.5, 0.95, 0.99] {
            assert_eq!(a.quantile(p).to_bits(), whole.quantile(p).to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "different windows")]
    fn ecdf_merge_rejects_mismatched_windows() {
        let mut a = StreamingEcdf::new(1.0, 10.0, 8);
        let b = StreamingEcdf::new(1.0, 100.0, 8);
        a.merge(&b);
    }

    #[test]
    fn merged_sink_matches_single_sink_exactly() {
        // Split a synthetic completion stream across two shard-local
        // sinks, merge, and compare against one sink fed everything: the
        // mergeable aggregates must agree exactly, and P² queries on the
        // merged sink must answer from the (exactly merged) ECDF.
        let idle: HashMap<Arc<str>, f64> = [(Arc::from("t"), 2.0)].into_iter().collect();
        let mut one = StreamingRunMetrics::new("X", idle.clone());
        let mut sa = StreamingRunMetrics::new("X", idle.clone());
        let mut sb = StreamingRunMetrics::new("X", idle);
        let mut rng = Rng::new(3);
        for i in 0..600u64 {
            let c = CompletedJob {
                job: i + 1,
                user: (i % 7) as u32 + 1,
                name: Arc::from("t"),
                submit: 0,
                finish: crate::s_to_us(rng.lognormal(1.0, 0.8)),
                slot_time: 1.0,
            };
            one.job_completed(c.clone());
            if i % 2 == 0 {
                sa.job_completed(c);
            } else {
                sb.job_completed(c);
            }
        }
        sa.merge_from(&sb);
        assert!(sa.is_merged());
        assert_eq!(sa.jobs(), one.jobs());
        assert!((sa.mean_rt() - one.mean_rt()).abs() < 1e-12);
        assert!((sa.mean_slowdown() - one.mean_slowdown()).abs() < 1e-12);
        assert_eq!(sa.users(), one.users());
        for u in one.users() {
            assert_eq!(sa.user(u).unwrap().jobs, one.user(u).unwrap().jobs);
            assert!((sa.user(u).unwrap().mean_rt() - one.user(u).unwrap().mean_rt()).abs() < 1e-12);
        }
        assert!((sa.jain_index_user_rt() - one.jain_index_user_rt()).abs() < 1e-12);
        for p in [0.50, 0.95, 0.99] {
            assert_eq!(
                sa.rt_quantile_ecdf(p).to_bits(),
                one.rt_quantile_ecdf(p).to_bits()
            );
            // Merged P² answers from the ECDF.
            assert_eq!(sa.rt_quantile_p2(p).to_bits(), one.rt_quantile_ecdf(p).to_bits());
        }
    }

    #[test]
    fn streaming_sink_matches_run_metrics_on_a_real_run() {
        // Stream a real (small) simulation into both sinks: mean RT and
        // mean slowdown must agree exactly (same values, same order).
        use crate::config::Config;
        use crate::sim;
        let w = crate::workload::test_scenario2(1, 4, 0.5);
        let cfg = Config::default().with_cores(8);
        let idle = crate::bench::idle_map(&cfg, &w);
        let exact = crate::bench::run_one(&cfg, &w);
        let mut core = crate::core::SchedCore::from_config(cfg);
        let mut sink = StreamingRunMetrics::new("stream", idle);
        let summary = sim::simulate_stream_into(&mut core, w.to_stream(), &mut sink);
        assert_eq!(sink.jobs() as usize, exact.outcomes.len());
        assert!((sink.mean_rt() - exact.mean_rt()).abs() < 1e-12);
        assert!((sink.mean_slowdown() - exact.mean_slowdown()).abs() < 1e-12);
        assert_eq!(summary.jobs_completed, sink.jobs());
        assert!(summary.peak_in_flight_jobs >= 1);
        // Per-user means match the exact per-user means.
        for u in sink.users() {
            let m = exact.mean_rt_of_user(u);
            assert!((sink.user(u).unwrap().mean_rt() - m).abs() < 1e-9, "user {u}");
        }
    }
}
