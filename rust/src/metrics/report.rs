//! Per-run metric aggregation: joins a scheduler run with the idle-system
//! reference (slowdowns) and exposes the groupings the paper's tables use.

use std::collections::HashMap;
use std::sync::Arc;

use crate::core::dag::CompletedJob;
use crate::util::stats;
use crate::workload::{UserClass, Workload};
use crate::{JobId, UserId};

/// One analytics job's outcome in a run.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub job: JobId,
    pub user: UserId,
    /// Interned job-kind name (shared with the spec/record).
    pub name: Arc<str>,
    pub submit_s: f64,
    pub finish_s: f64,
    /// Ground-truth sequential work.
    pub slot_time: f64,
    /// Response time (§5.1.1).
    pub rt: f64,
    /// RT of the same job alone on the idle cluster.
    pub idle_rt: f64,
}

impl JobOutcome {
    /// Slowdown `SL_i = RT_shared / RT_idle` (§5.1.1).
    pub fn slowdown(&self) -> f64 {
        if self.idle_rt > 0.0 {
            self.rt / self.idle_rt
        } else {
            1.0
        }
    }
}

/// All outcomes of one (scheduler × partitioner × workload) run.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub label: String,
    pub outcomes: Vec<JobOutcome>,
    pub makespan_s: f64,
    pub utilization: f64,
    pub user_class: HashMap<UserId, UserClass>,
}

impl RunMetrics {
    /// Join completed jobs with idle-system reference times.
    ///
    /// `idle_rt` maps a job *name* (workload job kind identity) to its
    /// idle response time; jobs are matched by name so the reference is
    /// computed once per distinct job shape.
    pub fn build(
        label: &str,
        workload: &Workload,
        completed: &[CompletedJob],
        idle_rt: &HashMap<Arc<str>, f64>,
        makespan_s: f64,
        utilization: f64,
    ) -> RunMetrics {
        let outcomes = completed
            .iter()
            .map(|c| JobOutcome {
                job: c.job,
                user: c.user,
                name: c.name.clone(),
                submit_s: crate::us_to_s(c.submit),
                finish_s: crate::us_to_s(c.finish),
                slot_time: c.slot_time,
                rt: c.response_time(),
                idle_rt: idle_rt.get(&c.name).copied().unwrap_or(0.0),
            })
            .collect();
        RunMetrics {
            label: label.to_string(),
            outcomes,
            makespan_s,
            utilization,
            user_class: workload.user_class.clone(),
        }
    }

    pub fn rts(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.rt).collect()
    }

    pub fn slowdowns(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.slowdown()).collect()
    }

    pub fn mean_rt(&self) -> f64 {
        stats::mean(&self.rts())
    }

    /// Mean RT of the worst 10 % of jobs (paper "Worst 10%").
    pub fn worst10_rt(&self) -> f64 {
        stats::worst_frac_mean(&self.rts(), 0.10)
    }

    pub fn mean_slowdown(&self) -> f64 {
        stats::mean(&self.slowdowns())
    }

    pub fn worst10_slowdown(&self) -> f64 {
        stats::worst_frac_mean(&self.slowdowns(), 0.10)
    }

    /// Mean RT over jobs of users in `class` (scenario 1's Freq./Infreq.).
    pub fn mean_rt_by_class(&self, class: UserClass) -> f64 {
        let rts: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| self.user_class.get(&o.user) == Some(&class))
            .map(|o| o.rt)
            .collect();
        stats::mean(&rts)
    }

    /// Mean RT of one user (scenario 2's First/Last columns, Fig. 7).
    pub fn mean_rt_of_user(&self, user: UserId) -> f64 {
        let rts: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| o.user == user)
            .map(|o| o.rt)
            .collect();
        stats::mean(&rts)
    }

    /// RTs of jobs whose *size* (idle RT) falls in the given percentile
    /// band of the run's job-size distribution — Table 2's 0-80 / 80-95 /
    /// 95-100 groupings.
    pub fn rt_by_size_band(&self, lo_pct: f64, hi_pct: f64) -> Vec<f64> {
        let sizes: Vec<f64> = self.outcomes.iter().map(|o| o.slot_time).collect();
        if sizes.is_empty() {
            return vec![];
        }
        let lo = if lo_pct <= 0.0 {
            f64::NEG_INFINITY
        } else {
            stats::percentile(&sizes, lo_pct)
        };
        let hi = if hi_pct >= 100.0 {
            f64::INFINITY
        } else {
            stats::percentile(&sizes, hi_pct)
        };
        self.outcomes
            .iter()
            .filter(|o| o.slot_time > lo && o.slot_time <= hi)
            .map(|o| o.rt)
            .collect()
    }

    /// Convenience: mean RT of a size band.
    pub fn mean_rt_band(&self, lo_pct: f64, hi_pct: f64) -> f64 {
        stats::mean(&self.rt_by_size_band(lo_pct, hi_pct))
    }

    /// Jobs of the infrequent users only (Fig. 5 CDF input).
    pub fn rts_of_class(&self, class: UserClass) -> Vec<f64> {
        self.outcomes
            .iter()
            .filter(|o| self.user_class.get(&o.user) == Some(&class))
            .map(|o| o.rt)
            .collect()
    }

    /// Completion timeline (finish times, seconds) — Fig. 6 CDF input.
    pub fn finish_times(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.finish_s).collect()
    }

    pub fn users(&self) -> Vec<UserId> {
        let mut u: Vec<UserId> = self.user_class.keys().copied().collect();
        u.sort();
        u
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::dag::CompletedJob;
    use crate::workload::Workload;

    fn mk() -> RunMetrics {
        let wl = Workload {
            name: "t".into(),
            jobs: vec![],
            user_class: [(1, UserClass::Frequent), (2, UserClass::Infrequent)]
                .into_iter()
                .collect(),
        };
        let completed = vec![
            CompletedJob {
                job: 1,
                user: 1,
                name: "tiny".into(),
                submit: 0,
                finish: 2_000_000,
                slot_time: 10.0,
            },
            CompletedJob {
                job: 2,
                user: 2,
                name: "short".into(),
                submit: 1_000_000,
                finish: 5_000_000,
                slot_time: 40.0,
            },
        ];
        let idle: HashMap<Arc<str>, f64> = [("tiny".into(), 1.0), ("short".into(), 2.0)]
            .into_iter()
            .collect();
        RunMetrics::build("Fair", &wl, &completed, &idle, 5.0, 0.9)
    }

    #[test]
    fn rt_and_slowdown() {
        let m = mk();
        assert_eq!(m.outcomes[0].rt, 2.0);
        assert_eq!(m.outcomes[1].rt, 4.0);
        assert_eq!(m.outcomes[0].slowdown(), 2.0);
        assert_eq!(m.outcomes[1].slowdown(), 2.0);
        assert_eq!(m.mean_rt(), 3.0);
    }

    #[test]
    fn class_split() {
        let m = mk();
        assert_eq!(m.mean_rt_by_class(UserClass::Frequent), 2.0);
        assert_eq!(m.mean_rt_by_class(UserClass::Infrequent), 4.0);
        assert_eq!(m.mean_rt_of_user(2), 4.0);
        assert_eq!(m.rts_of_class(UserClass::Frequent), vec![2.0]);
    }

    #[test]
    fn size_bands_partition_jobs() {
        let m = mk();
        let small = m.rt_by_size_band(0.0, 80.0);
        let large = m.rt_by_size_band(95.0, 100.0);
        assert!(!small.is_empty());
        // Both jobs land somewhere; bands should not both contain both.
        assert!(small.len() + large.len() <= 3);
    }

    #[test]
    fn missing_idle_rt_defaults_neutral() {
        let mut m = mk();
        m.outcomes[0].idle_rt = 0.0;
        assert_eq!(m.outcomes[0].slowdown(), 1.0);
    }
}
