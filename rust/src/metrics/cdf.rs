//! Empirical CDF emission for Figures 5 and 6.

use crate::util::csvout::Csv;
use crate::util::stats;

/// A named empirical CDF series.
#[derive(Clone, Debug)]
pub struct CdfSeries {
    pub label: String,
    /// (value, cumulative fraction), sorted by value.
    pub points: Vec<(f64, f64)>,
}

impl CdfSeries {
    pub fn from_samples(label: &str, samples: &[f64]) -> CdfSeries {
        CdfSeries {
            label: label.to_string(),
            points: stats::ecdf(samples),
        }
    }

    /// Fraction of samples ≤ x. Binary search on the sorted points —
    /// O(log n) per query (figure emission queries this per grid point).
    pub fn at(&self, x: f64) -> f64 {
        // partition_point: first index whose value exceeds x; the point
        // just before it (if any) carries the cumulative fraction at x.
        let idx = self.points.partition_point(|&(v, _)| v <= x);
        if idx == 0 {
            0.0
        } else {
            self.points[idx - 1].1
        }
    }
}

/// Write several CDF series to one long-format CSV
/// (`series,value,cum_frac`) for plotting.
pub fn write_cdfs(path: &str, series: &[CdfSeries]) -> std::io::Result<()> {
    let mut csv = Csv::create(path, &["series", "value", "cum_frac"])?;
    for s in series {
        for &(v, f) in &s.points {
            csv.row(&[s.label.clone(), format!("{v:.6}"), format!("{f:.6}")])?;
        }
    }
    csv.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_at_queries() {
        let c = CdfSeries::from_samples("x", &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(2.0), 0.5);
        assert_eq!(c.at(10.0), 1.0);
    }

    #[test]
    fn at_matches_linear_scan_reference() {
        // The binary search must reproduce the retired linear scan exactly,
        // including duplicate values and out-of-range queries.
        let samples = [0.5, 1.0, 1.0, 2.5, 2.5, 2.5, 7.0];
        let c = CdfSeries::from_samples("dup", &samples);
        let reference = |x: f64| {
            let mut frac = 0.0;
            for &(v, f) in &c.points {
                if v <= x {
                    frac = f;
                } else {
                    break;
                }
            }
            frac
        };
        for x in [-1.0, 0.0, 0.5, 0.75, 1.0, 2.5, 2.500001, 6.9, 7.0, 99.0] {
            assert_eq!(c.at(x), reference(x), "x={x}");
        }
    }

    #[test]
    fn write_and_readback() {
        let dir = std::env::temp_dir().join("uwfq_cdf_test");
        let p = dir.join("f.csv");
        let s = vec![
            CdfSeries::from_samples("A", &[1.0, 2.0]),
            CdfSeries::from_samples("B", &[3.0]),
        ];
        write_cdfs(p.to_str().unwrap(), &s).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("series,value,cum_frac\n"));
        assert_eq!(text.lines().count(), 4);
        std::fs::remove_dir_all(dir).ok();
    }
}
