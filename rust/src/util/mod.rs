//! Offline substrates: deterministic RNG + samplers, stats helpers, tiny
//! JSON/CSV emitters, a bench harness (`benchkit`) and a property-testing
//! kit (`propkit`).
//!
//! Only `xla` and `anyhow` are available as external crates in this
//! environment, so rand / serde / criterion / proptest equivalents live
//! here, scoped to exactly what the reproduction needs.

pub mod benchkit;
pub mod csvout;
pub mod jsonout;
pub mod propkit;
pub mod rng;
pub mod stats;

pub use rng::Rng;
