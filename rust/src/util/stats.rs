//! Small statistics helpers shared by metrics/ and the bench harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0.0 for empty input.
pub fn std(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100), linear interpolation, on a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// [`percentile`] on already-sorted input — no copy, no re-sort (the
/// streaming verify pass queries several percentiles of one big sorted
/// vector). 0.0 for empty input.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean of the worst (largest) `frac` of samples — the paper's
/// "Worst 10 %" columns use frac = 0.10.
pub fn worst_frac_mean(xs: &[f64], frac: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let n = ((v.len() as f64 * frac).ceil() as usize).max(1).min(v.len());
    mean(&v[..n])
}

/// Empirical CDF: sorted (value, cumulative fraction) steps.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.iter()
        .enumerate()
        .map(|(i, &x)| (x, (i + 1) as f64 / n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((median(&xs) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // Sorted variant agrees with the copying one and guards empty.
        assert_eq!(percentile_sorted(&xs, 37.2), percentile(&xs, 37.2));
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
    }

    #[test]
    fn worst_frac() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(worst_frac_mean(&xs, 0.10), 10.0);
        assert_eq!(worst_frac_mean(&xs, 0.20), 9.5);
        assert_eq!(worst_frac_mean(&xs, 1.0), 5.5);
    }

    #[test]
    fn ecdf_monotone() {
        let xs = [3.0, 1.0, 2.0];
        let c = ecdf(&xs);
        assert_eq!(c.len(), 3);
        assert_eq!(c[0], (1.0, 1.0 / 3.0));
        assert_eq!(c[2], (3.0, 1.0));
        assert!(c.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
    }
}
