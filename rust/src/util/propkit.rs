//! Property-testing kit (proptest is not available offline).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! retries with simple input shrinking hooks left to the caller (cases are
//! fully reproducible from the reported seed, which is the practical
//! shrinking story here: rerun `case(seed)` under a debugger).

use super::rng::Rng;

/// Run `prop(case_rng)` for `n` deterministic cases derived from `seed`.
/// Panics with the failing case seed on first failure.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, seed: u64, n: u32, mut prop: F) {
    let mut meta = Rng::new(seed);
    for case in 0..n {
        let case_seed = meta.next_u64();
        let mut r = Rng::new(case_seed);
        if let Err(msg) = prop(&mut r) {
            panic!(
                "property '{name}' failed on case {case} (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 below is below", 1, 100, |r| {
            let n = 1 + r.below(1000);
            let v = r.below(n);
            if v < n {
                Ok(())
            } else {
                Err(format!("{v} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure() {
        check("always fails", 2, 10, |_r| Err("nope".into()));
    }

    #[test]
    fn deterministic_case_seeds() {
        let mut seeds_a = Vec::new();
        check("collect a", 7, 5, |r| {
            seeds_a.push(r.next_u64());
            Ok(())
        });
        let mut seeds_b = Vec::new();
        check("collect b", 7, 5, |r| {
            seeds_b.push(r.next_u64());
            Ok(())
        });
        assert_eq!(seeds_a, seeds_b);
    }
}
