//! Minimal CSV writer (no serde available offline). Quotes fields that
//! need it; used by the figure/table emitters.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct Csv {
    w: BufWriter<File>,
}

impl Csv {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Csv> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        let line: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.w, "{}", line.join(","))
    }

    pub fn row_display(&mut self, fields: &[&dyn std::fmt::Display]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }

    pub fn finish(mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

fn escape(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("uwfq_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.row(&["x,y".into(), "q\"z".into()]).unwrap();
        c.row(&["1".into(), "2".into()]).unwrap();
        c.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n\"x,y\",\"q\"\"z\"\n1,2\n");
        std::fs::remove_dir_all(dir).ok();
    }
}
