//! Tiny bench harness for `harness = false` bench targets (criterion is not
//! available offline). Warmup + timed iterations, reports mean / p50 / p95
//! and throughput, machine-readable one-line summary per benchmark.
//! A [`JsonSink`] collects results into a `BENCH_*.json` file so the perf
//! trajectory is tracked across PRs.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::jsonout::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters {:>7}  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt(self.mean),
            fmt(self.p50),
            fmt(self.p95),
        );
    }
}

fn fmt(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget` (after ~budget/5 warmup); per-iteration
/// timing. Use for µs..ms scale operations.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup.
    let warm_until = Instant::now() + budget / 5;
    while Instant::now() < warm_until {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let until = Instant::now() + budget;
    while Instant::now() < until {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    finish(name, samples)
}

/// Bench with a fixed iteration count (for slow end-to-end runs).
pub fn bench_n<F: FnMut()>(name: &str, iters: u64, mut f: F) -> BenchResult {
    // One warmup iteration.
    f();
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    finish(name, samples)
}

fn finish(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    if samples.is_empty() {
        samples.push(Duration::ZERO);
    }
    samples.sort();
    let iters = samples.len() as u64;
    let total: Duration = samples.iter().sum();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[(samples.len() - 1) / 2],
        p95: samples[((samples.len() - 1) as f64 * 0.95) as usize],
    };
    r.report();
    r
}

/// Guard against the optimizer deleting benched work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects bench results (plus free-form metrics like task-events/s)
/// and writes them as one JSON document — the machine-readable artifact
/// CI archives to track perf across PRs.
#[derive(Default)]
pub struct JsonSink {
    results: Vec<Json>,
    metrics: BTreeMap<String, f64>,
}

impl JsonSink {
    pub fn new() -> Self {
        JsonSink::default()
    }

    /// Record a harness result (call right after `bench`/`bench_n`).
    pub fn record(&mut self, r: &BenchResult) {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(r.name.clone()));
        obj.insert("iters".to_string(), Json::Num(r.iters as f64));
        obj.insert("mean_s".to_string(), Json::Num(r.mean.as_secs_f64()));
        obj.insert("p50_s".to_string(), Json::Num(r.p50.as_secs_f64()));
        obj.insert("p95_s".to_string(), Json::Num(r.p95.as_secs_f64()));
        self.results.push(Json::Obj(obj));
    }

    /// Record a derived scalar (e.g. "sim_50k/UWFQ/task_events_per_s").
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.insert(name.to_string(), value);
    }

    /// Write the collected document to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut root = BTreeMap::new();
        root.insert("benches".to_string(), Json::Arr(self.results.clone()));
        root.insert(
            "metrics".to_string(),
            Json::Obj(
                self.metrics
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v)))
                    .collect(),
            ),
        );
        std::fs::write(path, Json::Obj(root).to_string_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_n_counts_iters() {
        let mut n = 0u64;
        let r = bench_n("noop", 10, || n += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(n, 11); // warmup + 10
        assert!(r.p50 <= r.p95);
    }

    #[test]
    fn bench_budget_runs() {
        let r = bench("spin", Duration::from_millis(20), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 10);
    }

    #[test]
    fn json_sink_roundtrips() {
        let mut sink = JsonSink::new();
        let r = bench_n("noop2", 3, || {});
        sink.record(&r);
        sink.metric("events_per_s", 1.5e6);
        let path = std::env::temp_dir().join("uwfq_bench_sink_test.json");
        let path = path.to_str().unwrap();
        sink.write(path).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let v = crate::util::jsonout::parse(&text).unwrap();
        assert_eq!(
            v.get("metrics").and_then(|m| m.get("events_per_s")).and_then(|x| x.as_f64()),
            Some(1.5e6)
        );
        let benches = v.get("benches").and_then(|b| b.as_arr()).unwrap();
        assert_eq!(benches[0].get("name").and_then(|n| n.as_str()), Some("noop2"));
        std::fs::remove_file(path).ok();
    }
}
